"""AOT exporter: manifest structure, shape bookkeeping, HLO text sanity."""

import json
import os
import subprocess
import sys

import pytest

HERE = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.fixture(scope="module")
def exported(tmp_path_factory):
    out = tmp_path_factory.mktemp("artifacts")
    subprocess.run(
        [sys.executable, "-m", "compile.aot", "--out-dir", str(out),
         "--n", "64", "--m", "32", "--mtilde", "8", "--steps", "4",
         "--losses", "hinge,squared"],
        cwd=HERE, check=True, capture_output=True,
    )
    return out


def test_manifest_lists_all_entries(exported):
    man = json.loads((exported / "manifest.json").read_text())
    names = set(man["entries"])
    assert {"partial_z", "grad_slice"} <= names
    for loss in ("hinge", "squared"):
        for op in ("dloss_u", "grad_fused", "svrg_inner", "loss_partial", "loss_from_z"):
            assert f"{op}_{loss}" in names
    assert "logistic" not in " ".join(names)


def test_manifest_shapes(exported):
    man = json.loads((exported / "manifest.json").read_text())
    cfg = man["config"]
    assert (cfg["n"], cfg["m"], cfg["mtilde"], cfg["steps"]) == (64, 32, 8, 4)
    e = man["entries"]["svrg_inner_hinge"]
    shapes = {i["name"]: tuple(i["shape"]) for i in e["inputs"]}
    assert shapes == {
        "x": (64, 8), "y": (64,), "w0": (8,), "wt": (8,), "mu": (8,),
        "idx": (4,), "gamma": (1,),
    }
    idx_dtype = [i for i in e["inputs"] if i["name"] == "idx"][0]["dtype"]
    assert idx_dtype == "i32"
    assert tuple(e["output_shape"]) == (8,)


def test_hlo_files_exist_and_are_text(exported):
    man = json.loads((exported / "manifest.json").read_text())
    for name, e in man["entries"].items():
        p = exported / e["file"]
        assert p.exists(), name
        head = p.read_text()[:200]
        assert "HloModule" in head, name


def test_hlo_has_no_custom_calls(exported):
    """interpret=True must lower to plain HLO the CPU PJRT client can run —
    a Mosaic/custom-call would only execute on a real TPU plugin."""
    man = json.loads((exported / "manifest.json").read_text())
    for name, e in man["entries"].items():
        text = (exported / e["file"]).read_text()
        assert "custom-call" not in text, name
