"""L2 correctness: distributed composition ≡ single-machine oracle.

These tests replicate what the rust coordinator does with the AOT entry
points — partition the data P×Q ways, mask w by B^t, reduce partial z
across feature blocks, broadcast u, collect gradient slices, mask by C^t —
and check the result equals `model.reference_mu` computed monolithically.
"""

import numpy as np
import jax.numpy as jnp
import pytest

from compile import model
from compile.kernels import ref

LOSSES = ref.LOSSES


def make_problem(N=120, M=60, P=3, Q=2, seed=0):
    rng = np.random.default_rng(seed)
    x = rng.uniform(-1, 1, size=(N, M)).astype(np.float32)
    wtrue = rng.uniform(-1, 1, size=(M,)).astype(np.float32)
    y = np.sign(x @ wtrue).astype(np.float32)
    y[y == 0] = 1.0
    w = rng.normal(scale=0.3, size=(M,)).astype(np.float32)
    return x, y, w, rng


def masks(rng, N, M, bfrac, cfrac, dfrac):
    bsz = max(1, int(round(bfrac * M)))
    csz = max(1, min(bsz, int(round(cfrac * M))))
    dsz = max(1, int(round(dfrac * N)))
    b_idx = rng.choice(M, size=bsz, replace=False)
    c_idx = rng.choice(b_idx, size=csz, replace=False)
    d_idx = rng.choice(N, size=dsz, replace=False)
    bmask = np.zeros(M, np.float32); bmask[b_idx] = 1
    cmask = np.zeros(M, np.float32); cmask[c_idx] = 1
    dmask = np.zeros(N, np.float32); dmask[d_idx] = 1
    return bmask, cmask, dmask


@pytest.mark.parametrize("loss", LOSSES)
@pytest.mark.parametrize("fracs", [(1.0, 1.0, 1.0), (0.85, 0.8, 0.85), (0.5, 0.3, 0.6)])
def test_distributed_mu_equals_oracle(loss, fracs):
    """P×Q-partitioned µ^t pipeline == monolithic reference_mu."""
    N, M, P, Q = 120, 60, 3, 2
    x, y, w, rng = make_problem(N, M, P, Q)
    bmask, cmask, dmask = masks(rng, N, M, *fracs)

    # --- what the rust coordinator does, expressed with the L2 entries ---
    n, m = N // P, M // Q
    wb = w * bmask
    z = np.zeros(N, np.float32)
    for p in range(P):
        rows = slice(p * n, (p + 1) * n)
        for q in range(Q):
            cols = slice(q * m, (q + 1) * m)
            # D^t gather: zero non-sampled rows (same as front-gather + pad)
            xblk = x[rows, cols] * dmask[rows, None]
            (zpart,) = model.partial_z(jnp.asarray(xblk), jnp.asarray(wb[cols]))
            z[rows] += np.asarray(zpart)
    u = np.zeros(N, np.float32)
    for p in range(P):
        rows = slice(p * n, (p + 1) * n)
        (up,) = model.make_dloss_u(loss)(jnp.asarray(z[rows]), jnp.asarray(y[rows] * dmask[rows]))
        u[rows] = np.asarray(up) * dmask[rows]
    g = np.zeros(M, np.float32)
    for p in range(P):
        rows = slice(p * n, (p + 1) * n)
        for q in range(Q):
            cols = slice(q * m, (q + 1) * m)
            (gs,) = model.grad_slice(jnp.asarray(x[rows, cols]), jnp.asarray(u[rows]))
            g[cols] += np.asarray(gs)
    mu = g * cmask / dmask.sum()

    want = model.reference_mu(
        jnp.asarray(x), jnp.asarray(y), jnp.asarray(w),
        jnp.asarray(bmask), jnp.asarray(cmask), jnp.asarray(dmask), loss=loss,
    )
    np.testing.assert_allclose(mu, np.asarray(want), rtol=1e-3, atol=1e-3)


@pytest.mark.parametrize("loss", LOSSES)
def test_loss_partial_sums_to_objective(loss):
    x, y, w, _ = make_problem()
    N, M, P, Q = 120, 60, 3, 2
    n, m = N // P, M // Q
    total = 0.0
    for p in range(P):
        rows = slice(p * n, (p + 1) * n)
        z = np.zeros(n, np.float32)
        for q in range(Q):
            cols = slice(q * m, (q + 1) * m)
            (zp,) = model.partial_z(jnp.asarray(x[rows, cols]), jnp.asarray(w[cols]))
            z += np.asarray(zp)
        total += float(np.sum(np.asarray(ref.loss_values(jnp.asarray(z), jnp.asarray(y[rows]), loss))))
    want = float(ref.loss_sum(jnp.asarray(x), jnp.asarray(y), jnp.asarray(w), loss))
    np.testing.assert_allclose(total, want, rtol=1e-4)


@pytest.mark.parametrize("loss", LOSSES)
def test_grad_fused_equals_slices(loss):
    """Fused single-partition entry == feature-sliced two-pass entries."""
    x, y, w, _ = make_problem(N=90, M=40)
    (g1,) = model.make_grad_fused(loss)(jnp.asarray(x), jnp.asarray(y), jnp.asarray(w))
    (z,) = model.partial_z(jnp.asarray(x), jnp.asarray(w))
    (u,) = model.make_dloss_u(loss)(z, jnp.asarray(y))
    (g2,) = model.grad_slice(jnp.asarray(x), u)
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g2), rtol=1e-3, atol=1e-3)


@pytest.mark.parametrize("loss", LOSSES)
def test_svrg_inner_entry_matches_reference(loss):
    x, y, _, rng = make_problem(N=64, M=16, P=1, Q=1)
    w0 = rng.normal(scale=0.2, size=16).astype(np.float32)
    wt = rng.normal(scale=0.2, size=16).astype(np.float32)
    mu = rng.normal(scale=0.05, size=16).astype(np.float32)
    idx = rng.integers(0, 64, size=12).astype(np.int32)
    (got,) = model.make_svrg_inner(loss)(
        jnp.asarray(x), jnp.asarray(y), jnp.asarray(w0), jnp.asarray(wt),
        jnp.asarray(mu), jnp.asarray(idx), jnp.asarray([0.03], jnp.float32),
    )
    want = ref.svrg_inner(
        jnp.asarray(x), jnp.asarray(y), jnp.asarray(w0), jnp.asarray(wt),
        jnp.asarray(mu), jnp.asarray(idx), 0.03, loss,
    )
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-3, atol=1e-3)
