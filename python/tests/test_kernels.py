"""L1 correctness: every Pallas kernel vs its pure-jnp oracle.

Hypothesis drives shapes (including awkward non-tile-multiple edges) and
values; assert_allclose at f32 tolerances is the pass criterion.
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import common, linear_grad, losses, matvec, ref, svrg

LOSSES = ref.LOSSES
SEED = st.integers(min_value=0, max_value=2**31 - 1)


def make_data(n, m, seed):
    rng = np.random.default_rng(seed)
    x = rng.uniform(-1.0, 1.0, size=(n, m)).astype(np.float32)
    y = np.where(rng.random(n) < 0.5, -1.0, 1.0).astype(np.float32)
    w = rng.normal(scale=0.5, size=(m,)).astype(np.float32)
    return jnp.asarray(x), jnp.asarray(y), jnp.asarray(w)


# ---------------------------------------------------------------------------
# matvec / rmatvec
# ---------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(n=st.integers(1, 400), m=st.integers(1, 200), seed=SEED)
def test_matvec_matches_oracle(n, m, seed):
    x, _, w = make_data(n, m, seed)
    np.testing.assert_allclose(
        matvec.matvec(x, w), ref.matvec(x, w), rtol=1e-4, atol=1e-4
    )


@settings(max_examples=20, deadline=None)
@given(n=st.integers(1, 400), m=st.integers(1, 200), seed=SEED)
def test_rmatvec_matches_oracle(n, m, seed):
    x, _, _ = make_data(n, m, seed)
    u = jnp.asarray(np.random.default_rng(seed + 1).normal(size=(n,)).astype(np.float32))
    np.testing.assert_allclose(
        matvec.rmatvec(x, u), ref.rmatvec(x, u), rtol=1e-3, atol=1e-3
    )


@pytest.mark.parametrize("rt,ft", [(8, 8), (32, 128), (128, 256), (7, 13)])
def test_matvec_tile_invariance(rt, ft):
    """Tile sizes are a schedule choice; the numbers must not move."""
    x, _, w = make_data(150, 90, 7)
    base = ref.matvec(x, w)
    np.testing.assert_allclose(
        matvec.matvec(x, w, row_tile=rt, feat_tile=ft), base, rtol=1e-4, atol=1e-4
    )


# ---------------------------------------------------------------------------
# loss / dloss / fused gradient
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("loss", LOSSES)
@settings(max_examples=12, deadline=None)
@given(n=st.integers(1, 300), m=st.integers(1, 150), seed=SEED)
def test_fused_grad_matches_oracle(loss, n, m, seed):
    x, y, w = make_data(n, m, seed)
    np.testing.assert_allclose(
        linear_grad.linear_grad_sum(x, y, w, loss=loss),
        ref.linear_grad_sum(x, y, w, loss),
        rtol=1e-3, atol=1e-3,
    )


@pytest.mark.parametrize("loss", LOSSES)
@settings(max_examples=12, deadline=None)
@given(n=st.integers(1, 300), m=st.integers(1, 150), seed=SEED)
def test_loss_sum_matches_oracle(loss, n, m, seed):
    x, y, w = make_data(n, m, seed)
    got = losses.loss_sum(x, y, w, loss=loss)[0]
    want = ref.loss_sum(x, y, w, loss)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-3)


@pytest.mark.parametrize("loss", LOSSES)
@settings(max_examples=12, deadline=None)
@given(n=st.integers(1, 500), seed=SEED)
def test_loss_sum_from_z_matches_oracle(loss, n, seed):
    rng = np.random.default_rng(seed)
    z = jnp.asarray(rng.normal(size=(n,)).astype(np.float32))
    y = jnp.asarray(np.where(rng.random(n) < 0.5, -1.0, 1.0).astype(np.float32))
    got = losses.loss_sum_from_z(z, y, loss=loss)[0]
    want = jnp.sum(ref.loss_values(z, y, loss))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-3)


@pytest.mark.parametrize("loss", LOSSES)
@settings(max_examples=12, deadline=None)
@given(n=st.integers(1, 500), seed=SEED)
def test_dloss_matches_oracle(loss, n, seed):
    rng = np.random.default_rng(seed)
    z = jnp.asarray(rng.normal(size=(n,)).astype(np.float32))
    y = jnp.asarray(np.where(rng.random(n) < 0.5, -1.0, 1.0).astype(np.float32))
    np.testing.assert_allclose(
        losses.dloss_vec(z, y, loss=loss),
        ref.dloss_values(z, y, loss),
        rtol=1e-5, atol=1e-6,
    )


@pytest.mark.parametrize("loss", LOSSES)
def test_grad_two_pass_equals_fused(loss):
    """matvec → dloss → rmatvec composition ≡ the fused kernel."""
    x, y, w = make_data(257, 65, 3)
    z = matvec.matvec(x, w)
    u = losses.dloss_vec(z, y, loss=loss)
    g2 = matvec.rmatvec(x, u)
    g1 = linear_grad.linear_grad_sum(x, y, w, loss=loss)
    np.testing.assert_allclose(g1, g2, rtol=1e-3, atol=1e-3)


def test_padding_rows_are_free():
    """Explicitly appended zero rows must not change gradient sums."""
    x, y, w = make_data(100, 40, 11)
    xp = jnp.concatenate([x, jnp.zeros((28, 40), jnp.float32)])
    yp = jnp.concatenate([y, jnp.zeros((28,), jnp.float32)])
    for loss in LOSSES:
        np.testing.assert_allclose(
            linear_grad.linear_grad_sum(xp, yp, w, loss=loss),
            linear_grad.linear_grad_sum(x, y, w, loss=loss),
            rtol=1e-4, atol=1e-4,
        )


# ---------------------------------------------------------------------------
# SVRG inner loop
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("loss", LOSSES)
@settings(max_examples=8, deadline=None)
@given(
    n=st.integers(2, 200),
    mt=st.integers(1, 64),
    steps=st.integers(1, 24),
    seed=SEED,
)
def test_svrg_inner_matches_oracle(loss, n, mt, steps, seed):
    x, y, w0 = make_data(n, mt, seed)
    rng = np.random.default_rng(seed + 2)
    wt = jnp.asarray(rng.normal(scale=0.5, size=(mt,)).astype(np.float32))
    mu = jnp.asarray(rng.normal(scale=0.05, size=(mt,)).astype(np.float32))
    idx = jnp.asarray(rng.integers(0, n, size=steps).astype(np.int32))
    gamma = np.float32(0.05)
    got = svrg.svrg_inner(x, y, w0, wt, mu, idx, jnp.asarray([gamma]), loss=loss)
    want = ref.svrg_inner(x, y, w0, wt, mu, idx, gamma, loss)
    np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-3)


@pytest.mark.parametrize("loss", LOSSES)
@settings(max_examples=6, deadline=None)
@given(
    n=st.integers(2, 150),
    mt=st.integers(1, 48),
    steps=st.integers(1, 20),
    seed=SEED,
)
def test_svrg_inner_avg_matches_oracle(loss, n, mt, steps, seed):
    x, y, w0 = make_data(n, mt, seed)
    rng = np.random.default_rng(seed + 3)
    wt = jnp.asarray(rng.normal(scale=0.5, size=(mt,)).astype(np.float32))
    mu = jnp.asarray(rng.normal(scale=0.05, size=(mt,)).astype(np.float32))
    idx = jnp.asarray(rng.integers(0, n, size=steps).astype(np.int32))
    gamma = np.float32(0.05)
    got = svrg.svrg_inner_avg(x, y, w0, wt, mu, idx, jnp.asarray([gamma]), loss=loss)
    want = ref.svrg_inner_avg(x, y, w0, wt, mu, idx, gamma, loss)
    np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-3)


def test_svrg_avg_of_one_step_equals_step():
    x, y, w0 = make_data(40, 8, 21)
    idx = jnp.asarray([3], jnp.int32)
    mu = jnp.asarray(np.full(8, 0.1, np.float32))
    g = jnp.asarray([0.05], jnp.float32)
    a = svrg.svrg_inner_avg(x, y, w0, w0, mu, idx, g, loss="hinge")
    b = svrg.svrg_inner(x, y, w0, w0, mu, idx, g, loss="hinge")
    np.testing.assert_allclose(a, b, rtol=1e-6)


def test_svrg_zero_gamma_is_identity():
    x, y, w0 = make_data(50, 16, 5)
    idx = jnp.zeros((8,), jnp.int32)
    out = svrg.svrg_inner(
        x, y, w0, w0, jnp.zeros((16,), jnp.float32), idx,
        jnp.asarray([0.0], jnp.float32), loss="hinge",
    )
    np.testing.assert_allclose(out, w0, atol=0)


def test_svrg_wt_equals_w0_reduces_to_sgd_with_mu():
    """When w^(i) == w^t at step 0 the first update is exactly −γµ−γ(g−g)=−γµ."""
    x, y, w0 = make_data(30, 8, 9)
    mu = jnp.full((8,), 0.25, jnp.float32)
    idx = jnp.asarray([4], jnp.int32)
    out = svrg.svrg_inner(x, y, w0, w0, mu, idx, jnp.asarray([0.1], jnp.float32), loss="hinge")
    np.testing.assert_allclose(out, w0 - 0.1 * mu, rtol=1e-5, atol=1e-6)


def test_pad_to_helper():
    a = jnp.ones((5, 3))
    b = common.pad_to(a, 0, 4)
    assert b.shape == (8, 3)
    np.testing.assert_allclose(np.asarray(b[5:]), 0.0)
    assert common.pad_to(a, 0, 5) is a
