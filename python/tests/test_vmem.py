"""The VMEM/roofline estimator: sanity of the static model."""

from compile import vmem


def test_all_kernels_fit_vmem_at_default_bucket():
    for e in vmem.estimate(1000, 120, 24, 32):
        assert e.fits(double_buffered=True), e


def test_matvec_kernels_are_memory_bound():
    # rank-1-ish reductions: intensity ≈ 2 flops/4 bytes ⇒ far below the
    # MXU knee — the DESIGN.md §Hardware-Adaptation claim
    for e in vmem.estimate(50_000, 6_000, 1_200, 32):
        if e.name in ("partial_z", "grad_slice"):
            assert e.bound == "HBM-bound", e
            assert e.intensity < 2.0

def test_paper_scale_blocks_exceed_single_tile_budget_gracefully():
    # 50k×6k block does not fit VMEM whole — the tiling must be what fits
    es = {e.name: e for e in vmem.estimate(50_000, 6_000, 1_200, 32)}
    tile_bytes = es["partial_z"].vmem_bytes
    assert tile_bytes < vmem.VMEM_BYTES  # a tile fits even if X does not


def test_report_renders():
    r = vmem.report(1000, 120, 24, 32)
    assert "partial_z" in r and "svrg_inner" in r
    assert "Mi" in r


def test_estimate_scales_with_shape():
    small = {e.name: e for e in vmem.estimate(100, 30, 10, 16)}
    large = {e.name: e for e in vmem.estimate(1000, 300, 100, 16)}
    assert large["partial_z"].flops > small["partial_z"].flops * 50
