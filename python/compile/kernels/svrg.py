"""SVRG inner-loop kernel (Algorithm 1, steps 13-17).

One worker (p, q) owns the sub-block ``X_sub = x^{p,q,π_q(p)}`` (n × m̃)
and runs L variance-reduced steps on its parameter slice:

    w^{(i+1)} = w^{(i)} − γ [ f'(x_j·w^{(i)}) x_j − f'(x_j·w^t) x_j + µ ]

with j = idx[i] a freshly sampled local row per step.  The whole loop is a
single kernel so that X_sub stays resident (on TPU: in VMEM) across all L
steps — L row-gathers + 2L tiny matvecs never touch HBM again.  The row
indices are sampled by the rust coordinator (it owns all RNG streams) and
passed in as an int32 vector.

The per-step reference gradient f'(x_j·w^t) x_j is recomputed rather than
cached: with single-row batches the recompute is one dot product, and it
keeps the kernel's memory footprint at O(n·m̃) exactly like the paper's
Spark implementation.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import common


def _make_avg_kernel(loss: str, steps: int):
    tail_start = 0  # uniform (Polyak) average of all L iterates

    def kernel(x_ref, y_ref, w0_ref, wt_ref, mu_ref, idx_ref, gamma_ref, o_ref):
        wt = wt_ref[...]
        mu = mu_ref[...]
        gamma = gamma_ref[0]

        def body(i, carry):
            w, acc = carry
            j = idx_ref[i]
            xj = pl.load(x_ref, (pl.dslice(j, 1), slice(None)))[0]
            yj = pl.load(y_ref, (pl.dslice(j, 1),))[0]
            u_cur = common.dloss(xj @ w, yj, loss)
            u_ref_ = common.dloss(xj @ wt, yj, loss)
            w = w - gamma * ((u_cur - u_ref_) * xj + mu)
            acc = acc + jnp.where(i >= tail_start, w, jnp.zeros_like(w))
            return w, acc

        _, acc = jax.lax.fori_loop(0, steps, body, (w0_ref[...], jnp.zeros_like(w0_ref[...])))
        o_ref[...] = acc / (steps - tail_start)

    return kernel


@functools.partial(jax.jit, static_argnames=("loss",))
def svrg_inner_avg(x, y, w0, wt, mu, idx, gamma, *, loss: str):
    """Like :func:`svrg_inner` but returns the uniform iterate average
    ``mean(w^(1) … w^(L))`` — RADiSA-avg's combiner (Polyak averaging)."""
    n, mt = x.shape
    (steps,) = idx.shape
    return pl.pallas_call(
        _make_avg_kernel(loss, int(steps)),
        grid=(1,),
        in_specs=[
            pl.BlockSpec((n, mt), lambda i: (0, 0)),
            pl.BlockSpec((n,), lambda i: (0,)),
            pl.BlockSpec((mt,), lambda i: (0,)),
            pl.BlockSpec((mt,), lambda i: (0,)),
            pl.BlockSpec((mt,), lambda i: (0,)),
            pl.BlockSpec((steps,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((mt,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((mt,), x.dtype),
        interpret=common.INTERPRET,
    )(x, y, w0, wt, mu, idx, gamma)


def _make_kernel(loss: str, steps: int):
    def kernel(x_ref, y_ref, w0_ref, wt_ref, mu_ref, idx_ref, gamma_ref, o_ref):
        wt = wt_ref[...]
        mu = mu_ref[...]
        gamma = gamma_ref[0]

        def body(i, w):
            j = idx_ref[i]
            xj = pl.load(x_ref, (pl.dslice(j, 1), slice(None)))[0]
            yj = pl.load(y_ref, (pl.dslice(j, 1),))[0]
            u_cur = common.dloss(xj @ w, yj, loss)
            u_ref_ = common.dloss(xj @ wt, yj, loss)
            return w - gamma * ((u_cur - u_ref_) * xj + mu)

        o_ref[...] = jax.lax.fori_loop(0, steps, body, w0_ref[...])

    return kernel


@functools.partial(jax.jit, static_argnames=("loss",))
def svrg_inner(x, y, w0, wt, mu, idx, gamma, *, loss: str):
    """Run ``idx.shape[0]`` SVRG steps on one sub-block; returns w^{(L)}.

    Shapes: x (n, m̃), y (n,), w0/wt/mu (m̃,), idx (L,) int32, gamma (1,).
    """
    n, mt = x.shape
    (steps,) = idx.shape
    return pl.pallas_call(
        _make_kernel(loss, int(steps)),
        grid=(1,),
        in_specs=[
            pl.BlockSpec((n, mt), lambda i: (0, 0)),
            pl.BlockSpec((n,), lambda i: (0,)),
            pl.BlockSpec((mt,), lambda i: (0,)),
            pl.BlockSpec((mt,), lambda i: (0,)),
            pl.BlockSpec((mt,), lambda i: (0,)),
            pl.BlockSpec((steps,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((mt,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((mt,), x.dtype),
        interpret=common.INTERPRET,
    )(x, y, w0, wt, mu, idx, gamma)
