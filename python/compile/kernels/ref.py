"""Pure-jnp correctness oracles for every Pallas kernel (L1).

Everything the kernels in this package compute has an exact, obviously
correct jnp counterpart here. pytest asserts ``allclose`` between the two
on hypothesis-driven shape/value sweeps; the rust NativeEngine implements
the same math a third time and is cross-checked through the AOT artifacts
in the cargo integration tests.

Conventions (shared by kernels, model.py and the rust engines):

* losses are functions of the margin/residual ``z = x·w`` and label ``y``;
* reductions return **sums**, not means — the coordinator divides by the
  relevant ``d^t``/batch count so that zero-padded rows are free;
* hinge uses the subgradient ``-y·1[y z < 1]`` (the paper's SVM setting).
"""

from __future__ import annotations

import jax.numpy as jnp

LOSSES = ("hinge", "logistic", "squared")


# ---------------------------------------------------------------------------
# scalar loss + dloss/dz, vectorized over z/y
# ---------------------------------------------------------------------------

def loss_values(z: jnp.ndarray, y: jnp.ndarray, loss: str) -> jnp.ndarray:
    """Per-row loss values f(z_i, y_i)."""
    if loss == "hinge":
        return jnp.maximum(0.0, 1.0 - y * z)
    if loss == "logistic":
        # log(1 + exp(-yz)) computed stably
        return jnp.logaddexp(0.0, -y * z)
    if loss == "squared":
        return 0.5 * (z - y) ** 2
    raise ValueError(f"unknown loss {loss!r}")


def dloss_values(z: jnp.ndarray, y: jnp.ndarray, loss: str) -> jnp.ndarray:
    """Per-row derivative u_i = ∂f/∂z (z_i, y_i)."""
    if loss == "hinge":
        return jnp.where(y * z < 1.0, -y, 0.0)
    if loss == "logistic":
        # -y * sigmoid(-y z)
        return -y / (1.0 + jnp.exp(y * z))
    if loss == "squared":
        return z - y
    raise ValueError(f"unknown loss {loss!r}")


# ---------------------------------------------------------------------------
# linear-model reductions
# ---------------------------------------------------------------------------

def matvec(x: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """Partial margins z = X w for a local feature block."""
    return x @ w


def rmatvec(x: jnp.ndarray, u: jnp.ndarray) -> jnp.ndarray:
    """Gradient accumulation g = Xᵀ u (sum over rows, unnormalized)."""
    return x.T @ u


def loss_sum(x: jnp.ndarray, y: jnp.ndarray, w: jnp.ndarray, loss: str) -> jnp.ndarray:
    """Σ_i f(x_i·w, y_i) (sum — caller divides)."""
    return jnp.sum(loss_values(x @ w, y, loss))


def linear_grad_sum(x: jnp.ndarray, y: jnp.ndarray, w: jnp.ndarray, loss: str) -> jnp.ndarray:
    """Fused Σ_i ∇_w f(x_i·w, y_i) = Xᵀ u with u_i = f'(x_i·w, y_i)."""
    u = dloss_values(x @ w, y, loss)
    return x.T @ u


# ---------------------------------------------------------------------------
# SVRG inner loop (Algorithm 1, steps 13-17, one (q, π_q(p)) sub-block)
# ---------------------------------------------------------------------------

def svrg_inner(
    x: jnp.ndarray,
    y: jnp.ndarray,
    w0: jnp.ndarray,
    wt: jnp.ndarray,
    mu: jnp.ndarray,
    idx: jnp.ndarray,
    gamma,
    loss: str,
) -> jnp.ndarray:
    """L SVRG steps on one parameter sub-block.

    ``w^{(i+1)} = w^{(i)} − γ [ f'(x_j·w^{(i)}) x_j − f'(x_j·w^t) x_j + µ ]``
    with ``j = idx[i]`` a random local row per step (paper, step 16).
    """
    w = w0
    for i in range(int(idx.shape[0])):
        xj = x[idx[i]]
        yj = y[idx[i]]
        g_cur = dloss_values(xj @ w, yj, loss) * xj
        g_ref = dloss_values(xj @ wt, yj, loss) * xj
        w = w - gamma * (g_cur - g_ref + mu)
    return w


def svrg_inner_avg(x, y, w0, wt, mu, idx, gamma, loss):
    """Iterate-averaged variant (RADiSA-avg combiner): uniform mean of the
    iterates w^(1) … w^(L) (Polyak averaging)."""
    steps = int(idx.shape[0])
    tail_start = 0
    w = w0
    acc = jnp.zeros_like(w0)
    for i in range(steps):
        xj = x[idx[i]]
        yj = y[idx[i]]
        g_cur = dloss_values(xj @ w, yj, loss) * xj
        g_ref = dloss_values(xj @ wt, yj, loss) * xj
        w = w - gamma * (g_cur - g_ref + mu)
        if i >= tail_start:
            acc = acc + w
    return acc / (steps - tail_start)
