"""Fused linear-model gradient kernel: g = Xᵀ f'(Xw, y) in one pass.

This is the single-partition hot-spot (used by the quickstart, the
µ^t estimate when a feature block fits in one tile, and as the baseline
the two-pass ``matvec``/``rmatvec`` pair is benchmarked against).

Grid is over row tiles only; the full parameter vector w stays resident
(on TPU: in VMEM — fine for the sub-block widths m̃ = M/QP the paper's
partitioning produces).  Each grid step computes its row-tile margin
``z = X_blk w``, the loss derivative ``u = f'(z, y)``, and accumulates
``uᵀ X_blk`` into the shared output block.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import common


def _make_kernel(loss: str):
    def kernel(x_ref, y_ref, w_ref, o_ref):
        i = pl.program_id(0)

        @pl.when(i == 0)
        def _init():
            o_ref[...] = jnp.zeros_like(o_ref)

        z = x_ref[...] @ w_ref[...]
        u = common.dloss(z, y_ref[...], loss)
        o_ref[...] += u @ x_ref[...]

    return kernel


@functools.partial(jax.jit, static_argnames=("loss", "row_tile"))
def linear_grad_sum(x, y, w, *, loss: str, row_tile: int = common.ROW_TILE):
    """Σ_i ∇_w f(x_i·w, y_i) (unnormalized — caller divides)."""
    n, m = x.shape
    rt = min(row_tile, n)
    # Row axis is accumulated: pad with zero rows (u(0, 0) = 0 for every
    # supported loss, so padding contributes nothing to the sum).
    xp = common.pad_to(x, 0, rt)
    yp = common.pad_to(y, 0, rt)
    np_ = xp.shape[0]
    return pl.pallas_call(
        _make_kernel(loss),
        grid=(np_ // rt,),
        in_specs=[
            pl.BlockSpec((rt, m), lambda i: (i, 0)),
            pl.BlockSpec((rt,), lambda i: (i,)),
            pl.BlockSpec((m,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((m,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((m,), x.dtype),
        interpret=common.INTERPRET,
    )(xp, yp, w)
