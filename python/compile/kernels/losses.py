"""Loss-evaluation kernels.

* ``dloss_vec`` — elementwise u_i = f'(z_i, y_i); the leader broadcasts u
  to all feature-partition workers during the µ^t estimate.
* ``loss_sum``  — Σ_i f(x_i·w_blk, y_i) over a local block (row-tiled,
  scalar accumulated); partial sums are reduced across partitions by the
  rust coordinator to report the paper's objective F(ω).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import common


def _make_dloss_kernel(loss: str):
    def kernel(z_ref, y_ref, o_ref):
        o_ref[...] = common.dloss(z_ref[...], y_ref[...], loss)

    return kernel


@functools.partial(jax.jit, static_argnames=("loss", "row_tile"))
def dloss_vec(z, y, *, loss: str, row_tile: int = common.ROW_TILE):
    """u = f'(z, y) elementwise."""
    (n,) = z.shape
    rt = min(row_tile, n)
    return pl.pallas_call(
        _make_dloss_kernel(loss),
        grid=(common.cdiv(n, rt),),
        in_specs=[
            pl.BlockSpec((rt,), lambda i: (i,)),
            pl.BlockSpec((rt,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((rt,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), z.dtype),
        interpret=common.INTERPRET,
    )(z, y)


def _make_loss_z_kernel(loss: str):
    def kernel(z_ref, y_ref, o_ref):
        i = pl.program_id(0)

        @pl.when(i == 0)
        def _init():
            o_ref[...] = jnp.zeros_like(o_ref)

        o_ref[...] += jnp.sum(common.floss(z_ref[...], y_ref[...], loss))[None]

    return kernel


@functools.partial(jax.jit, static_argnames=("loss", "row_tile"))
def loss_sum_from_z(z, y, *, loss: str, row_tile: int = common.ROW_TILE):
    """Σ_i f(z_i, y_i) from pre-reduced margins (distributed objective:
    the leader sums partial z across the Q feature blocks first)."""
    (n,) = z.shape
    rt = min(row_tile, n)
    zp = common.pad_to(z, 0, rt)
    yp = common.pad_to(y, 0, rt)
    np_ = zp.shape[0]
    pad = np_ - n
    out = pl.pallas_call(
        _make_loss_z_kernel(loss),
        grid=(np_ // rt,),
        in_specs=[
            pl.BlockSpec((rt,), lambda i: (i,)),
            pl.BlockSpec((rt,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((1,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((1,), z.dtype),
        interpret=common.INTERPRET,
    )(zp, yp)
    if pad:
        zero = jnp.zeros((), dtype=z.dtype)
        out = out - pad * common.floss(zero, zero, loss)
    return out


def _make_loss_sum_kernel(loss: str):
    def kernel(x_ref, y_ref, w_ref, o_ref):
        i = pl.program_id(0)

        @pl.when(i == 0)
        def _init():
            o_ref[...] = jnp.zeros_like(o_ref)

        z = x_ref[...] @ w_ref[...]
        o_ref[...] += jnp.sum(common.floss(z, y_ref[...], loss))[None]

    return kernel


@functools.partial(jax.jit, static_argnames=("loss", "row_tile"))
def loss_sum(x, y, w, *, loss: str, row_tile: int = common.ROW_TILE):
    """Σ_i f(x_i·w, y_i) for a local block (shape (1,) for AOT-friendliness)."""
    n, m = x.shape
    rt = min(row_tile, n)
    # Row axis is accumulated: pad with zero rows, then subtract the
    # trace-time constant f(0, 0)·pad the zero rows contributed.
    xp = common.pad_to(x, 0, rt)
    yp = common.pad_to(y, 0, rt)
    np_ = xp.shape[0]
    pad = np_ - n
    out = pl.pallas_call(
        _make_loss_sum_kernel(loss),
        grid=(np_ // rt,),
        in_specs=[
            pl.BlockSpec((rt, m), lambda i: (i, 0)),
            pl.BlockSpec((rt,), lambda i: (i,)),
            pl.BlockSpec((m,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((1,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((1,), x.dtype),
        interpret=common.INTERPRET,
    )(xp, yp, w)
    if pad:
        zero = jnp.zeros((), dtype=x.dtype)
        out = out - pad * common.floss(zero, zero, loss)
    return out
