"""Row/feature-tiled matvec kernels: the distributed inner-product halves.

In the doubly distributed setting each worker (p, q) holds a block
``X^{p,q}`` of the data matrix.  Estimating the stochastic full gradient
µ^t (Algorithm 1, step 8) decomposes into

* ``partial z``: every worker computes ``z_part = X_blk · w_blk`` over its
  local features (rust reduces the partial sums across q to get the full
  margins z_j = x_j^{B^t} w_{B^t}), then
* ``rmatvec``:   every worker computes its gradient slice
  ``g_blk = X_blkᵀ · u`` from the broadcast derivative vector u.

Both are Pallas kernels tiled so one (row-tile × feature-tile) block of X
is resident per grid step — exactly the HBM→VMEM schedule a TPU wants.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import common


def _matvec_kernel(x_ref, w_ref, o_ref):
    """o[rows] += X[rows, feats] @ w[feats] for one (i, j) grid step."""
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += x_ref[...] @ w_ref[...]


@functools.partial(jax.jit, static_argnames=("row_tile", "feat_tile"))
def matvec(x, w, *, row_tile: int = common.ROW_TILE, feat_tile: int = common.FEAT_TILE):
    """z = X @ w with a (rows, feats) grid; feature axis accumulated."""
    n, m = x.shape
    rt, ft = min(row_tile, n), min(feat_tile, m)
    # Feature axis is accumulated: pad it so edge tiles are all-zero.
    xp = common.pad_to(common.pad_to(x, 1, ft), 0, rt)
    wp = common.pad_to(w, 0, ft)
    np_, mp = xp.shape
    grid = (np_ // rt, mp // ft)
    out = pl.pallas_call(
        _matvec_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((rt, ft), lambda i, j: (i, j)),
            pl.BlockSpec((ft,), lambda i, j: (j,)),
        ],
        out_specs=pl.BlockSpec((rt,), lambda i, j: (i,)),
        out_shape=jax.ShapeDtypeStruct((np_,), x.dtype),
        interpret=common.INTERPRET,
    )(xp, wp)
    return out[:n]


def _rmatvec_kernel(x_ref, u_ref, o_ref):
    """o[feats] += u[rows] @ X[rows, feats] for one (j, i) grid step."""
    i = pl.program_id(1)

    @pl.when(i == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += u_ref[...] @ x_ref[...]


@functools.partial(jax.jit, static_argnames=("row_tile", "feat_tile"))
def rmatvec(x, u, *, row_tile: int = common.ROW_TILE, feat_tile: int = common.FEAT_TILE):
    """g = Xᵀ @ u (unnormalized sum over rows), row axis accumulated."""
    n, m = x.shape
    rt, ft = min(row_tile, n), min(feat_tile, m)
    # Row axis is accumulated: pad it so edge tiles are all-zero.
    xp = common.pad_to(common.pad_to(x, 0, rt), 1, ft)
    up = common.pad_to(u, 0, rt)
    np_, mp = xp.shape
    grid = (mp // ft, np_ // rt)
    out = pl.pallas_call(
        _rmatvec_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((rt, ft), lambda j, i: (i, j)),
            pl.BlockSpec((rt,), lambda j, i: (i,)),
        ],
        out_specs=pl.BlockSpec((ft,), lambda j, i: (j,)),
        out_shape=jax.ShapeDtypeStruct((mp,), x.dtype),
        interpret=common.INTERPRET,
    )(xp, up)
    return out[:m]
