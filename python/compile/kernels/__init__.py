"""L1 Pallas kernels for the SODDA compute hot-spots.

Each kernel has a pure-jnp oracle in :mod:`.ref`; pytest keeps them equal.
"""

from . import common, linear_grad, losses, matvec, ref, svrg

__all__ = ["common", "linear_grad", "losses", "matvec", "ref", "svrg"]
