"""Shared pieces for the Pallas kernels.

All kernels in this package are built with ``interpret=True``: the CPU
PJRT plugin (the runtime the rust coordinator embeds) cannot execute the
Mosaic custom-calls that real-TPU Pallas lowering emits, while interpret
mode lowers to plain HLO that runs anywhere.  The Block/grid structure is
still written the way a TPU would want it (feature tiles sized for VMEM,
row-tile accumulation) — see DESIGN.md §Hardware-Adaptation.
"""

from __future__ import annotations

import jax.numpy as jnp

# Interpret mode everywhere (CPU PJRT execution path).
INTERPRET = True

# Default tile sizes — multiples of the TPU (8, 128) f32 VMEM tiling.
# 1024×512 f32 = 2 MiB per resident X block: inside a TPU core's ~16 MiB
# VMEM with double-buffering, and large enough that the grid is 1-2 steps
# at the default partition shapes (perf log A2-A3 in EXPERIMENTS.md §Perf:
# shrinking the grid from (8,1) to (1,1) cut the compiled kernel time
# ~2.6× — each grid step pays a dynamic-update-slice round trip in the
# lowered HLO, the interpret-mode analogue of a TPU grid-step stall).
ROW_TILE = 1024
FEAT_TILE = 512


def dloss(z: jnp.ndarray, y: jnp.ndarray, loss: str) -> jnp.ndarray:
    """∂f/∂z for the supported losses, traceable inside a kernel."""
    if loss == "hinge":
        return jnp.where(y * z < 1.0, -y, jnp.zeros_like(y))
    if loss == "logistic":
        return -y / (1.0 + jnp.exp(y * z))
    if loss == "squared":
        return z - y
    raise ValueError(f"unknown loss {loss!r}")


def floss(z: jnp.ndarray, y: jnp.ndarray, loss: str) -> jnp.ndarray:
    """f(z, y) for the supported losses, traceable inside a kernel."""
    if loss == "hinge":
        return jnp.maximum(0.0, 1.0 - y * z)
    if loss == "logistic":
        return jnp.logaddexp(0.0, -y * z)
    if loss == "squared":
        return 0.5 * (z - y) ** 2
    raise ValueError(f"unknown loss {loss!r}")


def cdiv(a: int, b: int) -> int:
    return -(-a // b)


def pad_to(arr: jnp.ndarray, axis: int, multiple: int) -> jnp.ndarray:
    """Zero-pad ``arr`` along ``axis`` up to the next multiple.

    Accumulating kernels revisit one output block across grid steps; a
    partial edge tile would otherwise fold uninitialized out-of-bounds
    lanes into the sum, so every wrapper pads its reduction axes first.
    Zero rows/features contribute exactly zero to all our sums (for the
    loss kernel the trace-time constant f(0, 0)·pad is subtracted).
    """
    size = arr.shape[axis]
    pad = (-size) % multiple
    if pad == 0:
        return arr
    widths = [(0, 0)] * arr.ndim
    widths[axis] = (0, pad)
    return jnp.pad(arr, widths)
