"""L2 — the JAX compute graphs the rust coordinator executes via PJRT.

Each public function here is one AOT entry point: :mod:`compile.aot`
lowers it at a fixed shape to HLO text under ``artifacts/`` and records it
in ``artifacts/manifest.json``; ``rust/src/runtime`` loads and compiles
each one exactly once per process and calls it from the training loop.

Masking conventions (how the paper's random sets map onto fixed shapes):

* **B^t (features used in inner products)** — rust zeroes the excluded
  coordinates of ``w`` before calling ``partial_z``; ``x_j^{B} w_B`` is
  then literally ``x_j · w_masked``.
* **C^t (gradient coordinates computed)** — rust zeroes the excluded
  coordinates of the returned gradient slice (``\\bar∇`` in the paper is
  exactly "gradient with non-C coordinates set to 0").
* **D^t (observations sampled)** — rust gathers the sampled rows into the
  front of the fixed-shape buffer and zero-pads the tail; zero rows have
  ``u = f'(0,0) = 0`` so they add nothing to any gradient sum, and the
  loss entry subtracts the trace-time pad constant.

All reductions return sums; normalization (1/d^t, 1/N …) is rust's job.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernels import linear_grad, losses, matvec, svrg


# ---------------------------------------------------------------------------
# µ^t estimation pieces (Algorithm 1, steps 5-8), per (p, q) worker
# ---------------------------------------------------------------------------

def partial_z(x, w):
    """Partial margins ``z_part = X_blk · w_blk`` for one feature block.

    The leader sums the Q partial vectors to get z_j = x_j^{B^t} w_{B^t}.
    """
    return (matvec.matvec(x, w),)


def make_dloss_u(loss: str):
    """u = f'(z, y): broadcast to feature workers after the z-reduce."""

    def dloss_u(z, y):
        return (losses.dloss_vec(z, y, loss=loss),)

    dloss_u.__name__ = f"dloss_u_{loss}"
    return dloss_u


def grad_slice(x, u):
    """Gradient slice ``g_blk = X_blkᵀ u`` (sum over sampled rows)."""
    return (matvec.rmatvec(x, u),)


def make_grad_fused(loss: str):
    """Single-partition fused gradient Σ ∇f (quickstart / small blocks)."""

    def grad_fused(x, y, w):
        return (linear_grad.linear_grad_sum(x, y, w, loss=loss),)

    grad_fused.__name__ = f"grad_fused_{loss}"
    return grad_fused


# ---------------------------------------------------------------------------
# SVRG inner loop (steps 13-17), per (p, q) worker
# ---------------------------------------------------------------------------

def make_svrg_inner(loss: str):
    def svrg_inner(x, y, w0, wt, mu, idx, gamma):
        return (svrg.svrg_inner(x, y, w0, wt, mu, idx, gamma, loss=loss),)

    svrg_inner.__name__ = f"svrg_inner_{loss}"
    return svrg_inner


def make_svrg_inner_avg(loss: str):
    """RADiSA-avg's iterate-averaged inner loop."""

    def svrg_inner_avg(x, y, w0, wt, mu, idx, gamma):
        return (svrg.svrg_inner_avg(x, y, w0, wt, mu, idx, gamma, loss=loss),)

    svrg_inner_avg.__name__ = f"svrg_inner_avg_{loss}"
    return svrg_inner_avg


# ---------------------------------------------------------------------------
# Objective evaluation (reporting F(ω) each outer iteration)
# ---------------------------------------------------------------------------

def make_loss_partial(loss: str):
    def loss_partial(x, y, w):
        return (losses.loss_sum(x, y, w, loss=loss),)

    loss_partial.__name__ = f"loss_partial_{loss}"
    return loss_partial


def make_loss_from_z(loss: str):
    """Distributed objective: leader reduces partial z across feature
    blocks, then each observation partition evaluates Σ f(z, y)."""

    def loss_from_z(z, y):
        return (losses.loss_sum_from_z(z, y, loss=loss),)

    loss_from_z.__name__ = f"loss_from_z_{loss}"
    return loss_from_z


# ---------------------------------------------------------------------------
# Pure-jnp reference composition (pytest cross-checks; never exported)
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("loss",))
def reference_mu(x_full, y, w, bmask, cmask, dmask, loss: str):
    """Oracle for the whole µ^t estimate on a single machine.

    µ^t = (1/d) Σ_{j∈D} \\bar∇_{w_C} f_j(x_j^B w_B), computed without any
    partitioning — the distributed composition must match this exactly.
    """
    from .kernels import ref

    wb = w * bmask
    z = x_full @ wb
    u = ref.dloss_values(z, y, loss) * dmask
    g = x_full.T @ u
    d = jnp.sum(dmask)
    return (g * cmask) / d
