"""AOT exporter: lower every L2 entry point to HLO text + manifest.

The interchange format is HLO **text**, not a serialized HloModuleProto:
jax ≥ 0.5 emits protos with 64-bit instruction ids that the xla crate's
bundled xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text
parser reassigns ids and round-trips cleanly (see /opt/xla-example).

Every entry is lowered with ``return_tuple=True`` — the rust side unwraps
with ``to_tuple1()``.  ``manifest.json`` records, per entry: the artifact
file, the input names/shapes/dtypes and the output shape, plus the global
shape configuration so the rust runtime can validate its padding buckets
against what was actually compiled.

Usage (what ``make artifacts`` runs)::

    python -m compile.aot --out-dir ../artifacts \
        --n 1000 --m 300 --mtilde 60 --steps 32
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

F32 = jnp.float32
I32 = jnp.int32


def spec(shape, dtype=F32):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def to_hlo_text(lowered) -> str:
    """stablehlo → XlaComputation → HLO text (ids reassigned by parser)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def entry_table(n: int, m: int, mtilde: int, steps: int, losses):
    """(name, fn, arg specs, arg names, out shape) for every entry point.

    Shapes: n = rows per observation partition, m = features per feature
    block (M/Q), m̃ = features per sub-block (M/QP), steps = L.
    """
    entries = []
    # loss-independent distributed pieces
    entries.append(
        ("partial_z", model.partial_z,
         [spec((n, m)), spec((m,))], ["x", "w"], (n,))
    )
    entries.append(
        ("grad_slice", model.grad_slice,
         [spec((n, m)), spec((n,))], ["x", "u"], (m,))
    )
    for loss in losses:
        entries.append(
            (f"dloss_u_{loss}", model.make_dloss_u(loss),
             [spec((n,)), spec((n,))], ["z", "y"], (n,))
        )
        entries.append(
            (f"grad_fused_{loss}", model.make_grad_fused(loss),
             [spec((n, m)), spec((n,)), spec((m,))], ["x", "y", "w"], (m,))
        )
        entries.append(
            (f"svrg_inner_avg_{loss}", model.make_svrg_inner_avg(loss),
             [spec((n, mtilde)), spec((n,)), spec((mtilde,)), spec((mtilde,)),
              spec((mtilde,)), spec((steps,), I32), spec((1,))],
             ["x", "y", "w0", "wt", "mu", "idx", "gamma"], (mtilde,))
        )
        entries.append(
            (f"svrg_inner_{loss}", model.make_svrg_inner(loss),
             [spec((n, mtilde)), spec((n,)), spec((mtilde,)), spec((mtilde,)),
              spec((mtilde,)), spec((steps,), I32), spec((1,))],
             ["x", "y", "w0", "wt", "mu", "idx", "gamma"], (mtilde,))
        )
        entries.append(
            (f"loss_partial_{loss}", model.make_loss_partial(loss),
             [spec((n, m)), spec((n,)), spec((m,))], ["x", "y", "w"], (1,))
        )
        entries.append(
            (f"loss_from_z_{loss}", model.make_loss_from_z(loss),
             [spec((n,)), spec((n,))], ["z", "y"], (1,))
        )
    return entries


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--out", default=None,
                    help="legacy single-file mode: also write the fused "
                         "hinge gradient HLO to this path")
    ap.add_argument("--n", type=int, default=1000,
                    help="rows per observation partition")
    ap.add_argument("--m", type=int, default=300,
                    help="features per feature block (M/Q)")
    ap.add_argument("--mtilde", type=int, default=60,
                    help="features per sub-block (M/QP)")
    ap.add_argument("--steps", type=int, default=32,
                    help="inner-loop length L baked into svrg_inner")
    ap.add_argument("--losses", default="hinge,logistic,squared")
    args = ap.parse_args()

    losses = [s for s in args.losses.split(",") if s]
    os.makedirs(args.out_dir, exist_ok=True)

    manifest = {
        "schema": 1,
        "config": {
            "n": args.n, "m": args.m, "mtilde": args.mtilde,
            "steps": args.steps, "losses": losses, "dtype": "f32",
        },
        "entries": {},
    }

    for name, fn, specs, arg_names, out_shape in entry_table(
        args.n, args.m, args.mtilde, args.steps, losses
    ):
        lowered = jax.jit(fn).lower(*specs)
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        path = os.path.join(args.out_dir, fname)
        with open(path, "w") as f:
            f.write(text)
        manifest["entries"][name] = {
            "file": fname,
            "sha256": hashlib.sha256(text.encode()).hexdigest()[:16],
            "inputs": [
                {
                    "name": an,
                    "shape": list(s.shape),
                    "dtype": "i32" if s.dtype == I32 else "f32",
                }
                for an, s in zip(arg_names, specs)
            ],
            "output_shape": list(out_shape),
        }
        print(f"  lowered {name:24s} -> {fname} ({len(text)} chars)")

    man_path = os.path.join(args.out_dir, "manifest.json")
    with open(man_path, "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {man_path} ({len(manifest['entries'])} entries)")

    if args.out:
        lowered = jax.jit(model.make_grad_fused("hinge")).lower(
            spec((args.n, args.m)), spec((args.n,)), spec((args.m,))
        )
        with open(args.out, "w") as f:
            f.write(to_hlo_text(lowered))
        print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
