"""VMEM footprint + roofline estimator for the L1 Pallas kernels.

Interpret-mode timings on CPU say nothing about TPU performance; what
carries over is the *structure* the BlockSpecs encode.  This tool
computes, for a given artifact shape bucket:

* per-kernel VMEM residency (blocks held per grid step),
* arithmetic intensity (flops / HBM byte) and the implied roofline
  bound (memory- vs MXU-bound) on a v4-like core,
* whether double-buffered blocks fit the ~16 MiB VMEM budget.

Usage::

    python -m compile.vmem --n 1000 --m 120 --mtilde 24 --steps 32

The numbers feed EXPERIMENTS.md §Perf (TPU estimate) and DESIGN.md
§Hardware-Adaptation.
"""

from __future__ import annotations

import argparse
from dataclasses import dataclass

from .kernels import common

# v4-ish single-core envelope
HBM_BW = 300e9        # bytes/s effective
MXU_F32 = 70e12 / 4   # f32 (non-bf16) matmul peak ≈ MXU/4
VMEM_BYTES = 16 * 2**20


@dataclass
class KernelEstimate:
    name: str
    vmem_bytes: int
    flops: float
    hbm_bytes: float

    @property
    def intensity(self) -> float:
        return self.flops / max(self.hbm_bytes, 1.0)

    @property
    def bound(self) -> str:
        # roofline knee: intensity where MXU peak == BW * intensity
        knee = MXU_F32 / HBM_BW
        return "MXU-bound" if self.intensity >= knee else "HBM-bound"

    @property
    def est_time_s(self) -> float:
        return max(self.flops / MXU_F32, self.hbm_bytes / HBM_BW)

    def fits(self, double_buffered: bool = True) -> bool:
        mult = 2 if double_buffered else 1
        return self.vmem_bytes * mult <= VMEM_BYTES


def estimate(n: int, m: int, mtilde: int, steps: int) -> list[KernelEstimate]:
    rt = min(common.ROW_TILE, n)
    ft = min(common.FEAT_TILE, m)
    f32 = 4
    out = []
    # partial_z: X tile (rt×ft) + w tile (ft) resident; streams all of X once
    out.append(KernelEstimate(
        "partial_z", (rt * ft + ft + rt) * f32, 2.0 * n * m, (n * m + m + n) * f32,
    ))
    # grad_slice: same tiles, transposed reduction
    out.append(KernelEstimate(
        "grad_slice", (rt * ft + rt + ft) * f32, 2.0 * n * m, (n * m + n + m) * f32,
    ))
    # fused gradient: one pass, two matvecs worth of flops
    out.append(KernelEstimate(
        "grad_fused", (rt * m + m + rt) * f32, 4.0 * n * m, (n * m + n + m) * f32,
    ))
    # svrg_inner: whole sub-block resident for all L steps
    out.append(KernelEstimate(
        "svrg_inner", (n * mtilde + n + 4 * mtilde + steps) * f32,
        6.0 * steps * mtilde, (n * mtilde + n + 3 * mtilde) * f32,
    ))
    return out


def report(n: int, m: int, mtilde: int, steps: int) -> str:
    lines = [
        f"shape bucket: n={n} m={m} m̃={mtilde} L={steps} "
        f"(tiles {min(common.ROW_TILE, n)}×{min(common.FEAT_TILE, m)})",
        f"{'kernel':<12} {'VMEM':>10} {'2xbuf fits':>10} {'intensity':>10} "
        f"{'bound':>10} {'est time':>12}",
    ]
    for e in estimate(n, m, mtilde, steps):
        lines.append(
            f"{e.name:<12} {e.vmem_bytes / 2**20:>8.2f}Mi {str(e.fits()):>10} "
            f"{e.intensity:>10.2f} {e.bound:>10} {e.est_time_s * 1e6:>10.1f}µs"
        )
    return "\n".join(lines)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--n", type=int, default=1000)
    ap.add_argument("--m", type=int, default=120)
    ap.add_argument("--mtilde", type=int, default=24)
    ap.add_argument("--steps", type=int, default=32)
    args = ap.parse_args()
    print(report(args.n, args.m, args.mtilde, args.steps))


if __name__ == "__main__":
    main()
