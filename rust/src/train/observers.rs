//! Ready-made observers for
//! [`Trainer::run_with_observer`](crate::train::Trainer::run_with_observer)
//! (`FnMut(&IterRecord) -> ControlFlow<()>`): the paper's headline is
//! *early-iteration* superiority, so stopping a run at a loss target or
//! a time budget is a first-class scenario, not post-processing.

use std::ops::ControlFlow;

use crate::metrics::IterRecord;

/// Stop once the objective reaches `target` (time-to-loss experiments).
pub fn loss_below(target: f64) -> impl FnMut(&IterRecord) -> ControlFlow<()> {
    move |r| if r.loss <= target { ControlFlow::Break(()) } else { ControlFlow::Continue(()) }
}

/// Stop once the run has spent `budget_s` simulated cluster seconds
/// (deadline budgets on the paper's time axis).
pub fn sim_deadline(budget_s: f64) -> impl FnMut(&IterRecord) -> ControlFlow<()> {
    move |r| if r.sim_s >= budget_s { ControlFlow::Break(()) } else { ControlFlow::Continue(()) }
}

/// Stop once the run has spent `budget_s` wall-clock seconds in this
/// process.
pub fn wall_deadline(budget_s: f64) -> impl FnMut(&IterRecord) -> ControlFlow<()> {
    move |r| if r.wall_s >= budget_s { ControlFlow::Break(()) } else { ControlFlow::Continue(()) }
}

/// Stop after outer iteration `t` is recorded (truncated runs).
pub fn at_iteration(t: usize) -> impl FnMut(&IterRecord) -> ControlFlow<()> {
    move |r| if r.iter >= t { ControlFlow::Break(()) } else { ControlFlow::Continue(()) }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(iter: usize, loss: f64, sim_s: f64) -> IterRecord {
        IterRecord { iter, loss, wall_s: sim_s, sim_s, comm_bytes: 0, grad_coord_evals: 0 }
    }

    #[test]
    fn observers_trigger_on_their_condition() {
        let mut o = loss_below(0.5);
        assert!(o(&rec(1, 0.9, 0.0)).is_continue());
        assert!(o(&rec(2, 0.4, 0.0)).is_break());

        let mut o = sim_deadline(1.0);
        assert!(o(&rec(1, 0.9, 0.5)).is_continue());
        assert!(o(&rec(2, 0.9, 1.2)).is_break());

        let mut o = at_iteration(2);
        assert!(o(&rec(1, 0.9, 0.0)).is_continue());
        assert!(o(&rec(2, 0.9, 0.0)).is_break());
    }
}
