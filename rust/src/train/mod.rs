//! The training session layer: a reusable, observable [`Trainer`].
//!
//! A `Trainer` stages everything expensive exactly once — materializing
//! the dataset, partitioning it into the `P×Q` [`crate::data::Grid`],
//! building the compute engine (for XLA: compiling + device-staging the
//! AOT artifacts), and launching the worker [`Cluster`] — and then runs
//! any number of *runs* against that staged session. Rebuilding this
//! state per run is the dominant avoidable cost in sweep workloads
//! (cf. Dünner et al., arXiv:1612.01437), so the figure/table harnesses
//! and the examples all drive one session per dataset.
//!
//! Three ways to drive a session:
//!
//! * [`Trainer::run`] — run the configured `T` outer iterations.
//! * [`Trainer::step`] — one outer iteration at a time; the loop body
//!   lives in [`step`](self) and is independently testable.
//! * [`Trainer::run_with_observer`] — `run` with a streaming callback
//!   `FnMut(&IterRecord) -> ControlFlow<()>` that sees every recorded
//!   iteration as it lands and can stop the run early (loss targets,
//!   simulated-time deadlines, wall-clock budgets — see [`observers`]).
//!
//! Between runs: [`Trainer::reconfigure`] starts a fresh run with a new
//! (compatible) config on the same staged dataset/cluster/engine,
//! [`Trainer::warm_start`] seeds ω^0 with a previous iterate for
//! resumed/chained runs, and [`Trainer::reset`] restarts from scratch.
//! Across processes the lifecycle is symmetrical: [`Trainer::checkpoint`]
//! snapshots the run as a serializable [`RunState`] and
//! [`Trainer::resume`] continues it bit-for-bit in a fresh session.
//!
//! Unreliable clusters: a [`FaultPlan`] (set via `SODDA_FAULT_PLAN` or
//! [`Trainer::set_fault_plan`]) schedules deterministic worker kills;
//! the leader detects each death, respawns the worker from its shard
//! and replays the in-flight phase, so a faulted run's trajectory is
//! bit-identical to the fault-free one (the recoveries are logged in
//! [`History::faults`]).
//!
//! The legacy free functions `coordinator::train` /
//! `coordinator::train_with_engine` are thin shims over this type.

mod checkpoint;
mod faults;
mod step;

pub mod observers;

pub use checkpoint::{CheckpointObserver, RunState, CHECKPOINT_FORMAT};
pub use faults::{FaultEvent, FaultPlan, FAULT_PLAN_ENV};

use std::ops::ControlFlow;
use std::sync::Arc;
use std::time::Instant;

use anyhow::{ensure, Context, Result};

use crate::cluster::{Cluster, SimNet};
use crate::config::{EngineKind, ExecutorKind, ExperimentConfig, ShardWeighting};
use crate::data::{Dataset, Grid, Layout};
use crate::engine::ComputeEngine;
use crate::engine::NativeEngine;
use crate::metrics::{History, IterRecord};
use crate::util::rng::Rng;

/// Result of one training run.
pub struct TrainOutcome {
    /// final parameter vector ω^T
    pub w: Vec<f32>,
    pub history: History,
    /// simulated-network totals for reporting
    pub comm_bytes: u64,
    pub comm_msgs: u64,
}

/// Per-run mutable state; replaced wholesale by `reset`/`reconfigure`/
/// `warm_start` while the staged session (dataset, cluster, engine)
/// stays put. (The *serializable* snapshot of this state is the public
/// [`RunState`] produced by [`Trainer::checkpoint`].)
struct RunCore {
    w: Vec<f32>,
    history: History,
    net: SimNet,
    rng_sets: Rng,
    rng_perm: Rng,
    rng_rows: Rng,
    /// completed outer iterations (0 = freshly (re)configured)
    t: usize,
    grad_coord_evals: u64,
    t_start: Instant,
}

/// A staged, reusable training session (see the module docs).
pub struct Trainer {
    cfg: ExperimentConfig,
    ds: Arc<Dataset>,
    engine: Arc<dyn ComputeEngine>,
    /// Leader-side elementwise ops (u = f'(z,y), Σf(z,y)) are O(n) scalar
    /// maps — dispatching them through PJRT costs more than computing
    /// them (perf log A1 in EXPERIMENTS.md §Perf): the leader always uses
    /// the native engine, workers use the configured engine.
    leader_engine: Arc<dyn ComputeEngine>,
    cluster: Cluster,
    state: RunCore,
    /// Recycled per-iteration buffers (see the `step` module docs and
    /// the README "Steady-state memory" section). Deliberately
    /// **outside** `RunState`: `reset`/`reconfigure`/`warm_start` swap
    /// the run state but keep the warm buffers — pooling never changes
    /// numbers, only where they are written.
    ws: step::Workspace,
    /// Session-level fault schedule (see [`FaultPlan`]): kills are armed
    /// immediately before the phase they target, recovered workers are
    /// logged to [`History::faults`]. Read from `SODDA_FAULT_PLAN` at
    /// staging; [`Trainer::set_fault_plan`] overrides. Deliberately not
    /// part of [`RunState`] — a plan describes the *cluster's* failures,
    /// not the run's math (recovery is bit-transparent), so a resumed
    /// run re-reads its environment.
    fault_plan: Option<FaultPlan>,
}

/// Build the engine named by the config. The XLA engine loads the AOT
/// artifacts from `$SODDA_ARTIFACTS` (default `artifacts/`); it is only
/// available when the crate is built with the `xla` cargo feature.
pub fn build_engine(cfg: &ExperimentConfig) -> Result<Arc<dyn ComputeEngine>> {
    match cfg.engine {
        EngineKind::Native => Ok(Arc::new(NativeEngine)),
        #[cfg(feature = "xla")]
        EngineKind::Xla => {
            // the AOT kernels are compiled at one uniform block shape;
            // ragged layouts would need per-(p,q,k) artifacts
            anyhow::ensure!(
                crate::data::Layout::shape_is_uniform(cfg.data.n(), cfg.data.m(), cfg.p, cfg.q),
                "engine `xla` requires an evenly divisible grid: N={} M={} on {}x{} \
                 is ragged (use the native engine or an evenly divisible shape)",
                cfg.data.n(),
                cfg.data.m(),
                cfg.p,
                cfg.q
            );
            let dir =
                crate::util::env::read("SODDA_ARTIFACTS").unwrap_or_else(|| "artifacts".into());
            let rt = Arc::new(
                crate::runtime::XlaRuntime::load(&dir).context(
                    "loading AOT artifacts (build them with `make artifacts` at the partition shape)",
                )?,
            );
            let n_per = cfg.data.n() / cfg.p;
            let m_per = cfg.data.m() / cfg.q;
            let mtilde = m_per / cfg.p;
            Ok(Arc::new(crate::engine::XlaEngine::new(rt, n_per, m_per, mtilde, cfg.inner_steps)?))
        }
        #[cfg(not(feature = "xla"))]
        EngineKind::Xla => anyhow::bail!(
            "engine `xla` requested but this build has no PJRT support; \
             rebuild with `cargo build --features xla`"
        ),
    }
}

impl Trainer {
    /// Stage a full session from a config: materialize the dataset, build
    /// the engine, partition, launch the cluster.
    pub fn new(cfg: ExperimentConfig) -> Result<Trainer> {
        cfg.validate()?;
        let ds = cfg
            .data
            .try_materialize(cfg.seed)
            .with_context(|| format!("materializing dataset for {:?}", cfg.name))?;
        Self::with_dataset(cfg, ds)
    }

    /// Stage a session around a caller-provided dataset (figure harnesses
    /// materialize once and hand the same dataset to several sessions;
    /// pass an `Arc<Dataset>` to share it without copying).
    pub fn with_dataset(
        cfg: ExperimentConfig,
        ds: impl Into<Arc<Dataset>>,
    ) -> Result<Trainer> {
        cfg.validate()?;
        let engine = build_engine(&cfg)?;
        Self::with_parts(cfg, ds, engine)
    }

    /// Stage a session around a caller-provided dataset *and* engine
    /// (integration tests cross-check native vs XLA this way).
    pub fn with_parts(
        cfg: ExperimentConfig,
        ds: impl Into<Arc<Dataset>>,
        engine: Arc<dyn ComputeEngine>,
    ) -> Result<Trainer> {
        cfg.validate()?;
        // a shape-specialized engine must match at staging time, not
        // panic mid-run when the first inner loop ships a wrong-length
        // idx vector (reconfigure enforces the same invariant)
        if let Some(steps) = engine.fixed_inner_steps() {
            ensure!(
                cfg.inner_steps == steps,
                "engine kernels are compiled at L={steps}, config {:?} wants L={}",
                cfg.name,
                cfg.inner_steps
            );
        }
        let ds: Arc<Dataset> = ds.into();
        ensure!(
            ds.n() == cfg.data.n() && ds.m() == cfg.data.m(),
            "dataset is {}x{} but config {:?} expects {}x{}",
            ds.n(),
            ds.m(),
            cfg.name,
            cfg.data.n(),
            cfg.data.m()
        );
        let layout = staged_layout(&cfg)?;
        let grid = Grid::partition_with_layout(ds.as_ref(), layout)?;
        let kind = ExecutorKind::resolve(cfg.executor)
            .with_context(|| format!("resolving executor for {:?}", cfg.name))?;
        let cluster = Cluster::launch_with(grid, Arc::clone(&engine), cfg.loss, kind);
        // a set-but-malformed SODDA_FAULT_PLAN fails here, at staging —
        // not silently mid-run after the expensive state is built
        let fault_plan = FaultPlan::from_env()
            .with_context(|| format!("staging {:?}", cfg.name))?
            .filter(|plan| !plan.is_empty());
        Ok(Trainer {
            state: fresh_state(&cfg, cluster.layout.m_total),
            cfg,
            ds,
            engine,
            leader_engine: Arc::new(NativeEngine),
            cluster,
            ws: step::Workspace::default(),
            fault_plan,
        })
    }

    // ---- accessors -------------------------------------------------------

    pub fn config(&self) -> &ExperimentConfig {
        &self.cfg
    }

    pub fn dataset(&self) -> &Dataset {
        &self.ds
    }

    pub fn engine(&self) -> &Arc<dyn ComputeEngine> {
        &self.engine
    }

    /// The executor running this session's workers (resolved at staging
    /// from the config pin, the `SODDA_EXECUTOR` env knob, or the
    /// in-process default — see [`ExecutorKind::resolve`]).
    pub fn executor(&self) -> ExecutorKind {
        self.cluster.executor()
    }

    /// Simulated cluster seconds accumulated by the current run's cost
    /// model (benches report this next to measured `wall_ns_per_iter`).
    ///
    /// *Note*: subsumed by [`Trainer::checkpoint`], whose [`RunState`]
    /// carries `sim_s` next to the byte/message totals; prefer the
    /// snapshot when reading more than one counter.
    pub fn sim_seconds(&self) -> f64 {
        self.state.net.sim_s()
    }

    /// The session's fault schedule, if any (staged from
    /// `SODDA_FAULT_PLAN` or set via [`Trainer::set_fault_plan`]).
    pub fn fault_plan(&self) -> Option<&FaultPlan> {
        self.fault_plan.as_ref()
    }

    /// Replace the session's fault schedule (`None` disables injection).
    /// Overrides whatever `SODDA_FAULT_PLAN` staged. Takes effect from
    /// the next outer iteration; because recovery is bit-transparent the
    /// trajectory is unchanged either way — only [`History::faults`]
    /// and the cluster's respawn log notice.
    pub fn set_fault_plan(&mut self, plan: Option<FaultPlan>) {
        self.fault_plan = plan.filter(|p| !p.is_empty());
    }

    /// Completed outer iterations of the current run.
    pub fn iteration(&self) -> usize {
        self.state.t
    }

    /// Current iterate ω^t.
    pub fn weights(&self) -> &[f32] {
        &self.state.w
    }

    /// History of the current run. The iteration-0 record `F(ω^0)` is
    /// evaluated lazily when the run starts (first `step`/`run`), so a
    /// freshly staged or reconfigured session has an empty history.
    pub fn history(&self) -> &History {
        &self.state.history
    }

    /// Has the current run reached its configured `outer_iters`?
    pub fn is_done(&self) -> bool {
        self.state.t >= self.cfg.outer_iters
    }

    /// Snapshot the current run as a [`TrainOutcome`] (clones).
    ///
    /// *Note*: for a snapshot a later session can continue from, use
    /// [`Trainer::checkpoint`] — a [`RunState`] carries the RNG streams
    /// and accumulators that `TrainOutcome` (a reporting type) does not.
    pub fn outcome(&self) -> TrainOutcome {
        TrainOutcome {
            w: self.state.w.clone(),
            history: self.state.history.clone(),
            comm_bytes: self.state.net.total_bytes(),
            comm_msgs: self.state.net.total_msgs(),
        }
    }

    // ---- driving a run ---------------------------------------------------

    /// One outer iteration. Returns the [`IterRecord`] when this
    /// iteration was recorded (per `eval_every`), `None` otherwise.
    /// Erroring on a finished run keeps silent no-op loops from hiding
    /// bugs — `warm_start`/`reconfigure`/`reset` start the next run.
    pub fn step(&mut self) -> Result<Option<IterRecord>> {
        ensure!(
            !self.is_done(),
            "run {:?} already complete after {} iterations; \
             use warm_start/reconfigure/reset to start another run",
            self.cfg.name,
            self.cfg.outer_iters
        );
        self.ensure_initial_record();
        self.state.t += 1;
        Ok(self.iterate())
    }

    /// Drive the current run to completion. Like [`Trainer::step`], an
    /// already-completed run is an error — a sweep that forgot to
    /// `reconfigure`/`reset` would otherwise silently get the previous
    /// outcome back.
    pub fn run(&mut self) -> Result<TrainOutcome> {
        self.run_with_observer(|_| ControlFlow::Continue(()))
    }

    /// Drive the current run to completion, streaming every recorded
    /// [`IterRecord`] (including iteration 0 when starting fresh) to the
    /// observer. `ControlFlow::Break` stops the run early; the returned
    /// outcome's history is truncated at the last observed record, and
    /// the run can be resumed by calling `run`/`step` again.
    pub fn run_with_observer(
        &mut self,
        mut observer: impl FnMut(&IterRecord) -> ControlFlow<()>,
    ) -> Result<TrainOutcome> {
        ensure!(
            !self.is_done(),
            "run {:?} already complete after {} iterations; \
             use warm_start/reconfigure/reset to start another run",
            self.cfg.name,
            self.cfg.outer_iters
        );
        // deliver iteration 0 only when it lands now — a run resumed
        // after an early break at iteration 0 already delivered it
        if self.state.t == 0 && self.state.history.records.is_empty() {
            self.ensure_initial_record();
            let first = self.state.history.records[0];
            if observer(&first).is_break() {
                return Ok(self.outcome());
            }
        }
        while !self.is_done() {
            if let Some(rec) = self.step()? {
                if observer(&rec).is_break() {
                    break;
                }
            }
        }
        Ok(self.outcome())
    }

    // ---- starting the next run ------------------------------------------

    /// Restart the current config from scratch: ω^0 = 0, fresh RNG
    /// streams, fresh cost model. The staged dataset/cluster/engine are
    /// untouched.
    pub fn reset(&mut self) {
        self.state = fresh_state(&self.cfg, self.cluster.layout.m_total);
    }

    /// Start a fresh run from a caller-provided initial iterate ω^0
    /// (resumed/chained runs; warm-started baseline comparisons).
    pub fn warm_start(&mut self, w0: &[f32]) -> Result<()> {
        ensure!(
            w0.len() == self.cluster.layout.m_total,
            "warm_start: w0 has {} coordinates, model has {}",
            w0.len(),
            self.cluster.layout.m_total
        );
        self.state = fresh_state(&self.cfg, self.cluster.layout.m_total);
        self.state.w.copy_from_slice(w0);
        Ok(())
    }

    /// Start a fresh run under a new config on the same staged session.
    ///
    /// Everything staged must stay valid, so the new config must keep the
    /// session's dataset dimensions, partition grid, loss, and engine
    /// kind (workers own their shards and loss; the XLA engine is
    /// compiled at a fixed inner-loop length). Name, algorithm,
    /// fractions, schedule, seed, iteration counts, eval cadence and
    /// network model are free — which is exactly what the fig2/table2
    /// sweeps vary. Note the session keeps the dataset it was staged
    /// with: `cfg.seed` reseeds the training streams only.
    pub fn reconfigure(&mut self, cfg: ExperimentConfig) -> Result<()> {
        cfg.validate()?;
        ensure!(
            cfg.data.n() == self.ds.n() && cfg.data.m() == self.ds.m(),
            "reconfigure: session dataset is {}x{}, new config expects {}x{}",
            self.ds.n(),
            self.ds.m(),
            cfg.data.n(),
            cfg.data.m()
        );
        ensure!(
            cfg.p == self.cfg.p && cfg.q == self.cfg.q,
            "reconfigure: session grid is {}x{}, new config wants {}x{} (stage a new Trainer)",
            self.cfg.p,
            self.cfg.q,
            cfg.p,
            cfg.q
        );
        ensure!(
            cfg.loss == self.cfg.loss,
            "reconfigure: session workers hold loss {}, new config wants {} (stage a new Trainer)",
            self.cfg.loss.name(),
            cfg.loss.name()
        );
        ensure!(
            cfg.engine == self.cfg.engine,
            "reconfigure: session engine kind {:?} != requested {:?} (stage a new Trainer)",
            self.cfg.engine,
            cfg.engine
        );
        // the transport was launched at staging; a config that resolves
        // to the other executor needs a new session
        let kind = ExecutorKind::resolve(cfg.executor)?;
        ensure!(
            kind == self.cluster.executor(),
            "reconfigure: session executor is {}, new config resolves to {kind} \
             (stage a new Trainer)",
            self.cluster.executor()
        );
        // ask the engine the session actually holds, not the config kind —
        // with_parts sessions can hold a shape-specialized engine under a
        // Native-tagged config (the cross-check tests do exactly that)
        if let Some(steps) = self.engine.fixed_inner_steps() {
            ensure!(
                cfg.inner_steps == steps,
                "reconfigure: engine kernels are compiled at L={steps}, new config wants L={}",
                cfg.inner_steps
            );
        }
        self.cfg = cfg;
        self.reset();
        Ok(())
    }

    /// Push the iteration-0 record `F(ω^0)` if it isn't there yet.
    /// Lazy (first `step`/`run`) so that staging, `reconfigure` and the
    /// reconfigure-then-`warm_start` idiom never pay for an objective
    /// evaluation that the next call would immediately discard.
    fn ensure_initial_record(&mut self) {
        if self.state.t == 0 && self.state.history.records.is_empty() {
            // the run's wall clock starts when the run does, not at
            // staging — sessions may sit staged for a while before use
            self.state.t_start = Instant::now();
            let loss = self.objective_now();
            let rec = IterRecord {
                iter: 0,
                loss,
                wall_s: self.state.t_start.elapsed().as_secs_f64(),
                sim_s: 0.0,
                comm_bytes: 0,
                grad_coord_evals: 0,
            };
            self.state.history.push(rec);
        }
    }
}

/// The run's cost model: network parameters + the (validated) cluster
/// profile resolved against the P·Q grid. An unset profile is the
/// bit-frozen uniform default.
fn sim_net_for(cfg: &ExperimentConfig) -> SimNet {
    let profile = cfg.cluster_profile.clone().unwrap_or_default();
    SimNet::new(cfg.network.unwrap_or_default(), &profile, cfg.p * cfg.q)
}

/// The session's row/column boundary vectors. `Balanced` keeps the
/// frozen equal-split layout; `Throughput` sizes row shards by worker
/// rate (a row partition is barrier-bound by its *slowest* worker
/// across the Q feature blocks) so skewed profiles finish phases
/// together. A uniform profile falls back to the balanced boundary
/// vectors bit-for-bit.
fn staged_layout(cfg: &ExperimentConfig) -> Result<Layout> {
    let (n, m) = (cfg.data.n(), cfg.data.m());
    match cfg.shard_weighting {
        ShardWeighting::Balanced => Layout::new(n, m, cfg.p, cfg.q),
        ShardWeighting::Throughput => {
            let profile = cfg.cluster_profile.clone().unwrap_or_default();
            let rates = profile.rates(cfg.p * cfg.q);
            let weights: Vec<f64> = (0..cfg.p)
                .map(|pi| {
                    (0..cfg.q).map(|qi| rates[pi * cfg.q + qi]).fold(f64::INFINITY, f64::min)
                })
                .collect();
            if weights.windows(2).all(|w| w[0] == w[1]) {
                Layout::new(n, m, cfg.p, cfg.q)
            } else {
                Layout::weighted(n, m, cfg.p, cfg.q, &weights)
            }
        }
    }
}

fn fresh_state(cfg: &ExperimentConfig, m_total: usize) -> RunCore {
    // independent RNG streams (see util::rng docs)
    let root = Rng::seed_from_u64(cfg.seed);
    RunCore {
        w: vec![0.0f32; m_total],
        history: History::new(&cfg.name),
        net: sim_net_for(cfg),
        rng_sets: root.fork(0xB0),
        rng_perm: root.fork(0xC0),
        rng_rows: root.fork(0xD0),
        t: 0,
        grad_coord_evals: 0,
        t_start: Instant::now(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::AlgorithmKind;

    fn cfg(iters: usize) -> ExperimentConfig {
        ExperimentConfig::builder()
            .name("trainer-unit")
            .dense(200, 24)
            .grid(2, 2)
            .inner_steps(4)
            .outer_iters(iters)
            .seed(3)
            .build()
            .unwrap()
    }

    #[test]
    fn iteration_zero_is_recorded_lazily_at_run_start() {
        let mut t = Trainer::new(cfg(5)).unwrap();
        assert_eq!(t.iteration(), 0);
        assert!(t.history().records.is_empty(), "no objective eval until the run starts");
        assert!(!t.is_done());
        t.step().unwrap();
        assert_eq!(t.history().records[0].iter, 0);
        assert_eq!(t.history().records.len(), 2); // F(ω^0) + iteration 1
    }

    #[test]
    fn step_advances_and_errors_when_done() {
        let mut t = Trainer::new(cfg(2)).unwrap();
        assert!(t.step().unwrap().is_some());
        assert!(t.step().unwrap().is_some());
        assert!(t.is_done());
        assert!(t.step().is_err());
        assert!(t.run().is_err(), "run() on a completed run must not return stale results");
    }

    #[test]
    fn eval_cadence_controls_step_records() {
        let c = cfg(5).to_builder().eval_every(2).build().unwrap();
        let mut t = Trainer::new(c).unwrap();
        let mut recorded = Vec::new();
        while !t.is_done() {
            if let Some(r) = t.step().unwrap() {
                recorded.push(r.iter);
            }
        }
        // every 2nd iteration plus the final one
        assert_eq!(recorded, vec![2, 4, 5]);
    }

    #[test]
    fn reset_reproduces_the_same_run() {
        let mut t = Trainer::new(cfg(4)).unwrap();
        let a = t.run().unwrap();
        t.reset();
        let b = t.run().unwrap();
        assert_eq!(a.w, b.w);
        assert_eq!(a.history.losses(), b.history.losses());
    }

    #[test]
    fn pooled_workspace_never_changes_numbers() {
        // dropping every recycled buffer between steps forces the cold
        // fresh-allocation path; the trajectory must be bit-identical
        let mut warm = Trainer::new(cfg(4)).unwrap();
        let a = warm.run().unwrap();
        let mut cold = Trainer::new(cfg(4)).unwrap();
        while !cold.is_done() {
            cold.drop_scratch();
            cold.step().unwrap();
        }
        let b = cold.outcome();
        assert_eq!(a.w, b.w);
        assert_eq!(a.history.losses(), b.history.losses());
    }

    #[test]
    fn reconfigure_rejects_incompatible_sessions() {
        let mut t = Trainer::new(cfg(3)).unwrap();
        let other_grid = cfg(3).to_builder().grid(2, 1).build().unwrap();
        assert!(t.reconfigure(other_grid).is_err());
        let other_loss =
            cfg(3).to_builder().loss(crate::loss::Loss::Logistic).build().unwrap();
        assert!(t.reconfigure(other_loss).is_err());
        let other_dims = cfg(3).to_builder().dense(400, 24).build().unwrap();
        assert!(t.reconfigure(other_dims).is_err());
        // compatible: algorithm/fractions/seed changes
        let variant = cfg(3)
            .to_builder()
            .algorithm(AlgorithmKind::RadisaAvg)
            .seed(11)
            .build()
            .unwrap();
        assert!(t.reconfigure(variant).is_ok());
    }

    #[test]
    fn observer_sees_iteration_zero_first() {
        let mut t = Trainer::new(cfg(3)).unwrap();
        let mut seen = Vec::new();
        t.run_with_observer(|r| {
            seen.push(r.iter);
            ControlFlow::Continue(())
        })
        .unwrap();
        assert_eq!(seen, vec![0, 1, 2, 3]);
    }
}
