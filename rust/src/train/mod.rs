//! The training session layer: a reusable, observable [`Trainer`].
//!
//! A `Trainer` stages everything expensive exactly once — materializing
//! the dataset, partitioning it into the `P×Q` [`crate::data::Grid`],
//! building the compute engine (for XLA: compiling + device-staging the
//! AOT artifacts), and launching the worker [`Cluster`] — and then runs
//! any number of *runs* against that staged session. Rebuilding this
//! state per run is the dominant avoidable cost in sweep workloads
//! (cf. Dünner et al., arXiv:1612.01437), so the figure/table harnesses
//! and the examples all drive one session per dataset.
//!
//! Three ways to drive a session:
//!
//! * [`Trainer::run`] — run the configured `T` outer iterations.
//! * [`Trainer::step`] — one outer iteration at a time; the loop body
//!   lives in [`step`](self) and is independently testable.
//! * [`Trainer::run_with_observer`] — `run` with a streaming callback
//!   `FnMut(&IterRecord) -> ControlFlow<()>` that sees every recorded
//!   iteration as it lands and can stop the run early (loss targets,
//!   simulated-time deadlines, wall-clock budgets — see [`observers`]).
//!
//! Between runs: [`Trainer::reconfigure`] starts a fresh run with a new
//! (compatible) config on the same staged dataset/cluster/engine,
//! [`Trainer::warm_start`] seeds ω^0 with a previous iterate for
//! resumed/chained runs, and [`Trainer::reset`] restarts from scratch.
//! Across processes the lifecycle is symmetrical: [`Trainer::checkpoint`]
//! snapshots the run as a serializable [`RunState`] and
//! [`Trainer::resume`] continues it bit-for-bit in a fresh session.
//!
//! Unreliable clusters: a [`FaultPlan`] (set via `SODDA_FAULT_PLAN` or
//! [`Trainer::set_fault_plan`]) schedules deterministic worker kills;
//! the leader detects each death, respawns the worker from its shard
//! and replays the in-flight phase, so a faulted run's trajectory is
//! bit-identical to the fault-free one (the recoveries are logged in
//! [`History::faults`]). When recovery is exhausted — a `!perm` event,
//! or [`crate::config::RecoveryPolicy`] retries running out — the
//! worker is *permanently* lost: the trainer rolls the interrupted
//! iteration back to its start, re-shards the surviving data onto a
//! grid one observation row (or feature column) smaller, charges the
//! simulated network for the shuffle (logged in [`History::reshards`]),
//! and re-runs the iteration on the shrunk cluster. The degraded run
//! continues the same trajectory *as if staged on the smaller grid*,
//! which is what the equivalence tests in `tests/faults.rs` pin down.
//!
//! The legacy free functions `coordinator::train` /
//! `coordinator::train_with_engine` are thin shims over this type.

mod checkpoint;
mod faults;
mod step;

pub mod observers;

pub use checkpoint::{
    CheckpointObserver, RunState, CHECKPOINT_DELTA_FORMAT, CHECKPOINT_FORMAT,
};
pub use faults::{FaultEvent, FaultPlan, FAULT_PLAN_ENV};

use std::ops::ControlFlow;
use std::sync::Arc;
use std::time::Instant;

use anyhow::{bail, ensure, Context, Result};

use crate::cluster::{Cluster, LateSet, SimNet};
use crate::config::{
    ClusterProfile, EngineKind, ExecutorKind, ExperimentConfig, ShardWeighting, StalenessPolicy,
};
use crate::data::{Dataset, Grid, Layout};
use crate::engine::ComputeEngine;
use crate::engine::NativeEngine;
use crate::metrics::{History, IterRecord, ReshardRecord};
use crate::util::rng::Rng;

/// Result of one training run.
pub struct TrainOutcome {
    /// final parameter vector ω^T
    pub w: Vec<f32>,
    pub history: History,
    /// simulated-network totals for reporting
    pub comm_bytes: u64,
    pub comm_msgs: u64,
}

/// Per-run mutable state; replaced wholesale by `reset`/`reconfigure`/
/// `warm_start` while the staged session (dataset, cluster, engine)
/// stays put. (The *serializable* snapshot of this state is the public
/// [`RunState`] produced by [`Trainer::checkpoint`].)
struct RunCore {
    w: Vec<f32>,
    history: History,
    net: SimNet,
    rng_sets: Rng,
    rng_perm: Rng,
    rng_rows: Rng,
    /// completed outer iterations (0 = freshly (re)configured)
    t: usize,
    grad_coord_evals: u64,
    t_start: Instant,
    /// bounded-staleness: replies parked past a quorum cut, waiting to
    /// fold into a later iteration (always empty under the hard
    /// barrier). Part of the run's math, so checkpoints carry it.
    late: LateSet,
}

/// Iteration-start snapshot for the permanent-loss rollback. A failed
/// `iterate` leaves `w` (SVRG write-backs land as replies arrive), the
/// RNG streams and the cost counters mid-iteration; [`Trainer::step`]
/// restores all of them before re-running the iteration on the
/// re-sharded grid. The buffers persist across iterations (the `w`
/// copy reuses its allocation) so the steady-state iteration stays
/// inside the O(1)-allocations budget pinned by `tests/alloc_regression`.
#[derive(Default)]
struct Rollback {
    w: Vec<f32>,
    rng_sets: [u64; 4],
    rng_perm: [u64; 4],
    rng_rows: [u64; 4],
    sim_s: f64,
    bytes: u64,
    msgs: u64,
    grad_coord_evals: u64,
    /// records.len() at iteration start (pushes only happen at iteration
    /// end today, but truncating keeps the snapshot future-proof)
    records: usize,
    /// history.staleness.len() at iteration start (staleness records
    /// land mid-iteration, before the SVRG phase can fail)
    staleness: usize,
    /// parked late replies at iteration start — a failed quorum
    /// iteration may have parked new entries or drained old ones
    late: LateSet,
}

/// A staged, reusable training session (see the module docs).
pub struct Trainer {
    cfg: ExperimentConfig,
    ds: Arc<Dataset>,
    engine: Arc<dyn ComputeEngine>,
    /// Leader-side elementwise ops (u = f'(z,y), Σf(z,y)) are O(n) scalar
    /// maps — dispatching them through PJRT costs more than computing
    /// them (perf log A1 in EXPERIMENTS.md §Perf): the leader always uses
    /// the native engine, workers use the configured engine.
    leader_engine: Arc<dyn ComputeEngine>,
    cluster: Cluster,
    state: RunCore,
    /// Recycled per-iteration buffers (see the `step` module docs and
    /// the README "Steady-state memory" section). Deliberately
    /// **outside** `RunState`: `reset`/`reconfigure`/`warm_start` swap
    /// the run state but keep the warm buffers — pooling never changes
    /// numbers, only where they are written.
    ws: step::Workspace,
    /// Session-level fault schedule (see [`FaultPlan`]): kills are armed
    /// immediately before the phase they target, recovered workers are
    /// logged to [`History::faults`]. Read from `SODDA_FAULT_PLAN` at
    /// staging; [`Trainer::set_fault_plan`] overrides. Deliberately not
    /// part of [`RunState`] — a plan describes the *cluster's* failures,
    /// not the run's math (recovery is bit-transparent), so a resumed
    /// run re-reads its environment.
    fault_plan: Option<FaultPlan>,
    /// Bounded-staleness aggregation policy (see [`StalenessPolicy`]):
    /// resolved at staging from the explicit config pin or the
    /// `SODDA_STALENESS` env knob; `None` (or a full quorum) keeps the
    /// frozen hard-barrier path bit-for-bit.
    staleness: Option<StalenessPolicy>,
    /// Persistent iteration-start snapshot for permanent-loss rollback.
    rollback: Rollback,
}

/// Build the engine named by the config. The XLA engine loads the AOT
/// artifacts from `$SODDA_ARTIFACTS` (default `artifacts/`); it is only
/// available when the crate is built with the `xla` cargo feature.
pub fn build_engine(cfg: &ExperimentConfig) -> Result<Arc<dyn ComputeEngine>> {
    match cfg.engine {
        EngineKind::Native => Ok(Arc::new(NativeEngine)),
        #[cfg(feature = "xla")]
        EngineKind::Xla => {
            // the AOT kernels are compiled at one uniform block shape;
            // ragged layouts would need per-(p,q,k) artifacts
            anyhow::ensure!(
                crate::data::Layout::shape_is_uniform(cfg.data.n(), cfg.data.m(), cfg.p, cfg.q),
                "engine `xla` requires an evenly divisible grid: N={} M={} on {}x{} \
                 is ragged (use the native engine or an evenly divisible shape)",
                cfg.data.n(),
                cfg.data.m(),
                cfg.p,
                cfg.q
            );
            let dir =
                crate::util::env::read("SODDA_ARTIFACTS").unwrap_or_else(|| "artifacts".into());
            let rt = Arc::new(
                crate::runtime::XlaRuntime::load(&dir).context(
                    "loading AOT artifacts (build them with `make artifacts` at the partition shape)",
                )?,
            );
            let n_per = cfg.data.n() / cfg.p;
            let m_per = cfg.data.m() / cfg.q;
            let mtilde = m_per / cfg.p;
            Ok(Arc::new(crate::engine::XlaEngine::new(rt, n_per, m_per, mtilde, cfg.inner_steps)?))
        }
        #[cfg(not(feature = "xla"))]
        EngineKind::Xla => anyhow::bail!(
            "engine `xla` requested but this build has no PJRT support; \
             rebuild with `cargo build --features xla`"
        ),
    }
}

impl Trainer {
    /// Stage a full session from a config: materialize the dataset, build
    /// the engine, partition, launch the cluster.
    pub fn new(cfg: ExperimentConfig) -> Result<Trainer> {
        cfg.validate()?;
        let ds = cfg
            .data
            .try_materialize(cfg.seed)
            .with_context(|| format!("materializing dataset for {:?}", cfg.name))?;
        Self::with_dataset(cfg, ds)
    }

    /// Stage a session around a caller-provided dataset (figure harnesses
    /// materialize once and hand the same dataset to several sessions;
    /// pass an `Arc<Dataset>` to share it without copying).
    pub fn with_dataset(
        cfg: ExperimentConfig,
        ds: impl Into<Arc<Dataset>>,
    ) -> Result<Trainer> {
        cfg.validate()?;
        let engine = build_engine(&cfg)?;
        Self::with_parts(cfg, ds, engine)
    }

    /// Stage a session around a caller-provided dataset *and* engine
    /// (integration tests cross-check native vs XLA this way).
    pub fn with_parts(
        cfg: ExperimentConfig,
        ds: impl Into<Arc<Dataset>>,
        engine: Arc<dyn ComputeEngine>,
    ) -> Result<Trainer> {
        cfg.validate()?;
        // a shape-specialized engine must match at staging time, not
        // panic mid-run when the first inner loop ships a wrong-length
        // idx vector (reconfigure enforces the same invariant)
        if let Some(steps) = engine.fixed_inner_steps() {
            ensure!(
                cfg.inner_steps == steps,
                "engine kernels are compiled at L={steps}, config {:?} wants L={}",
                cfg.name,
                cfg.inner_steps
            );
        }
        let ds: Arc<Dataset> = ds.into();
        ensure!(
            ds.n() == cfg.data.n() && ds.m() == cfg.data.m(),
            "dataset is {}x{} but config {:?} expects {}x{}",
            ds.n(),
            ds.m(),
            cfg.name,
            cfg.data.n(),
            cfg.data.m()
        );
        let layout = staged_layout(&cfg, ds.as_ref())?;
        let grid = Grid::partition_with_layout(ds.as_ref(), layout)?;
        let kind = ExecutorKind::resolve(cfg.executor)
            .with_context(|| format!("resolving executor for {:?}", cfg.name))?;
        let cluster = Cluster::launch_with_policy(
            grid,
            Arc::clone(&engine),
            cfg.loss,
            kind,
            cfg.recovery.unwrap_or_default(),
        );
        // a set-but-malformed SODDA_FAULT_PLAN fails here, at staging —
        // not silently mid-run after the expensive state is built
        let fault_plan = FaultPlan::from_env()
            .with_context(|| format!("staging {:?}", cfg.name))?
            .filter(|plan| !plan.is_empty());
        let staleness =
            staged_staleness(&cfg).with_context(|| format!("staging {:?}", cfg.name))?;
        Ok(Trainer {
            state: fresh_state(&cfg, cluster.layout.m_total),
            cfg,
            ds,
            engine,
            leader_engine: Arc::new(NativeEngine),
            cluster,
            ws: step::Workspace::default(),
            fault_plan,
            staleness,
            rollback: Rollback::default(),
        })
    }

    // ---- accessors -------------------------------------------------------

    pub fn config(&self) -> &ExperimentConfig {
        &self.cfg
    }

    pub fn dataset(&self) -> &Dataset {
        &self.ds
    }

    pub fn engine(&self) -> &Arc<dyn ComputeEngine> {
        &self.engine
    }

    /// The executor running this session's workers (resolved at staging
    /// from the config pin, the `SODDA_EXECUTOR` env knob, or the
    /// in-process default — see [`ExecutorKind::resolve`]).
    pub fn executor(&self) -> ExecutorKind {
        self.cluster.executor()
    }

    /// Simulated cluster seconds accumulated by the current run's cost
    /// model (benches report this next to measured `wall_ns_per_iter`).
    ///
    /// *Note*: subsumed by [`Trainer::checkpoint`], whose [`RunState`]
    /// carries `sim_s` next to the byte/message totals; prefer the
    /// snapshot when reading more than one counter.
    pub fn sim_seconds(&self) -> f64 {
        self.state.net.sim_s()
    }

    /// The session's fault schedule, if any (staged from
    /// `SODDA_FAULT_PLAN` or set via [`Trainer::set_fault_plan`]).
    pub fn fault_plan(&self) -> Option<&FaultPlan> {
        self.fault_plan.as_ref()
    }

    /// The session's bounded-staleness policy, if any (an explicit
    /// `.staleness(...)` pin, or staged from `SODDA_STALENESS`). `None`
    /// — and any full-quorum policy — is the hard barrier.
    pub fn staleness(&self) -> Option<StalenessPolicy> {
        self.staleness
    }

    /// Replace the session's fault schedule (`None` disables injection).
    /// Overrides whatever `SODDA_FAULT_PLAN` staged. Takes effect from
    /// the next outer iteration; because recovery is bit-transparent the
    /// trajectory is unchanged either way — only [`History::faults`]
    /// and the cluster's respawn log notice.
    pub fn set_fault_plan(&mut self, plan: Option<FaultPlan>) {
        self.fault_plan = plan.filter(|p| !p.is_empty());
    }

    /// Completed outer iterations of the current run.
    pub fn iteration(&self) -> usize {
        self.state.t
    }

    /// Current iterate ω^t.
    pub fn weights(&self) -> &[f32] {
        &self.state.w
    }

    /// History of the current run. The iteration-0 record `F(ω^0)` is
    /// evaluated lazily when the run starts (first `step`/`run`), so a
    /// freshly staged or reconfigured session has an empty history.
    pub fn history(&self) -> &History {
        &self.state.history
    }

    /// Has the current run reached its configured `outer_iters`?
    pub fn is_done(&self) -> bool {
        self.state.t >= self.cfg.outer_iters
    }

    /// Snapshot the current run as a [`TrainOutcome`] (clones).
    ///
    /// *Note*: for a snapshot a later session can continue from, use
    /// [`Trainer::checkpoint`] — a [`RunState`] carries the RNG streams
    /// and accumulators that `TrainOutcome` (a reporting type) does not.
    pub fn outcome(&self) -> TrainOutcome {
        TrainOutcome {
            w: self.state.w.clone(),
            history: self.state.history.clone(),
            comm_bytes: self.state.net.total_bytes(),
            comm_msgs: self.state.net.total_msgs(),
        }
    }

    // ---- driving a run ---------------------------------------------------

    /// One outer iteration. Returns the [`IterRecord`] when this
    /// iteration was recorded (per `eval_every`), `None` otherwise.
    /// Erroring on a finished run keeps silent no-op loops from hiding
    /// bugs — `warm_start`/`reconfigure`/`reset` start the next run.
    ///
    /// A worker permanently lost mid-iteration (see the module docs) is
    /// handled here: the iteration rolls back to its start, the session
    /// re-shards onto a shrunk grid, and the iteration re-runs. Only an
    /// unrecoverable loss — the last worker of a `1×1` grid — errors.
    pub fn step(&mut self) -> Result<Option<IterRecord>> {
        ensure!(
            !self.is_done(),
            "run {:?} already complete after {} iterations; \
             use warm_start/reconfigure/reset to start another run",
            self.cfg.name,
            self.cfg.outer_iters
        );
        self.ensure_initial_record()?;
        self.state.t += 1;
        loop {
            self.save_rollback_point();
            match self.iterate() {
                Ok(rec) => return Ok(rec),
                Err(lost) => {
                    self.restore_rollback_point();
                    let worker = lost.worker;
                    self.reshard_after_loss(worker).with_context(|| {
                        format!(
                            "run {:?}: worker {worker} permanently lost at iteration {}",
                            self.cfg.name, self.state.t
                        )
                    })?;
                }
            }
        }
    }

    /// Snapshot everything `iterate` mutates before it records. Cheap:
    /// one `memcpy` of ω plus a handful of scalars, into retained buffers.
    fn save_rollback_point(&mut self) {
        let rb = &mut self.rollback;
        rb.w.clear();
        rb.w.extend_from_slice(&self.state.w);
        rb.rng_sets = self.state.rng_sets.state();
        rb.rng_perm = self.state.rng_perm.state();
        rb.rng_rows = self.state.rng_rows.state();
        rb.sim_s = self.state.net.sim_s();
        rb.bytes = self.state.net.total_bytes();
        rb.msgs = self.state.net.total_msgs();
        rb.grad_coord_evals = self.state.grad_coord_evals;
        rb.records = self.state.history.records.len();
        rb.staleness = self.state.history.staleness.len();
        // empty under the hard barrier, so the default path clones
        // nothing and stays inside the O(1)-allocations budget
        rb.late.clone_from(&self.state.late);
    }

    /// Undo a half-finished iteration (see [`Rollback`]). `History::faults`
    /// is deliberately *not* rewound: the kills really happened, and the
    /// arm-time logging in `step::arm_due_faults` is what keeps the fault
    /// log identical across executors.
    fn restore_rollback_point(&mut self) {
        let rb = &self.rollback;
        self.state.w.copy_from_slice(&rb.w);
        self.state.rng_sets = Rng::from_state(rb.rng_sets);
        self.state.rng_perm = Rng::from_state(rb.rng_perm);
        self.state.rng_rows = Rng::from_state(rb.rng_rows);
        self.state.net.restore(rb.sim_s, rb.bytes, rb.msgs);
        self.state.grad_coord_evals = rb.grad_coord_evals;
        self.state.history.records.truncate(rb.records);
        self.state.history.staleness.truncate(rb.staleness);
        self.state.late.clone_from(&rb.late);
    }

    /// Elastic degradation after a permanent worker loss: shrink the grid
    /// by one observation-row partition (or one feature column once
    /// `P == 1`), rebuild the cluster profile without the lost machine,
    /// recompute the layout under the session's [`ShardWeighting`],
    /// restage the surviving data onto a fresh cluster of the same
    /// executor, and charge the [`SimNet`] for the shuffle — every
    /// re-staged byte crosses the wire, and the phase's makespan is the
    /// slowest worker's staging time under the shrunk profile. The
    /// shuffle is logged as a [`ReshardRecord`].
    fn reshard_after_loss(&mut self, lost: usize) -> Result<()> {
        let (p, q) = (self.cfg.p, self.cfg.q);
        let (p2, q2) = if p > 1 {
            (p - 1, q)
        } else if q > 1 {
            (p, q - 1)
        } else {
            bail!("the only worker of the 1x1 grid is gone — nothing left to re-shard onto")
        };
        // Re-enumerate the surviving machines: drop the lost worker's
        // rate and keep the first P₂·Q₂ of the rest (the grid loses a
        // whole row/column of slots, so the trailing survivors idle out).
        // A uniform profile is count-independent and carries over as-is.
        let old = self.cfg.cluster_profile.clone().unwrap_or_default();
        let profile2 = if old.is_uniform() {
            old.clone()
        } else {
            let mut rates = old.rates(p * q);
            rates.remove(lost);
            rates.truncate(p2 * q2);
            ClusterProfile::explicit(rates)
                .with_flops_per_sec(old.flops_per_sec())
                .with_link_latency_factor(old.link_latency_factor())
        };
        let cfg2 = self
            .cfg
            .to_builder()
            .grid(p2, q2)
            .cluster_profile(profile2)
            .build()
            .context("building the shrunk-grid config")?;
        let layout = staged_layout(&cfg2, &self.ds)?;
        let grid = Grid::partition_with_layout(self.ds.as_ref(), layout)?;

        // Shuffle accounting: every surviving shard moves to its new
        // owner. Bytes = wire size of each re-staged block (matrix +
        // labels); makespan = the slowest worker's staging time, with
        // block bytes as the work proxy.
        let mut net = sim_net_for(&cfg2);
        net.restore(
            self.state.net.sim_s(),
            self.state.net.total_bytes(),
            self.state.net.total_msgs(),
        );
        let before = net.sim_s();
        let mut bytes = 0u64;
        let mut makespan = 0f64;
        for b in grid.blocks() {
            let blk = (b.x.approx_bytes() + 4 * b.y.len()) as u64;
            bytes += blk;
            makespan = makespan.max(net.worker_s(b.p * q2 + b.q, blk as f64));
        }
        net.phase(makespan, bytes, (p2 * q2) as u64, 1);
        let sim_s = net.sim_s() - before;

        let cluster = Cluster::launch_with_policy(
            grid,
            Arc::clone(&self.engine),
            cfg2.loss,
            self.cluster.executor(),
            cfg2.recovery.unwrap_or_default(),
        );
        // honest accounting: what the SimNet was charged is exactly what
        // the new cluster's retained store holds
        debug_assert_eq!(
            bytes,
            cluster.staged_bytes(),
            "re-shard shuffle charge != bytes actually re-staged"
        );
        self.state.history.reshards.push(ReshardRecord {
            iter: self.state.t,
            worker: lost,
            from_p: p,
            from_q: q,
            to_p: p2,
            to_q: q2,
            bytes,
            sim_s,
        });
        self.state.net = net;
        self.cluster = cluster;
        self.cfg = cfg2;
        // per-iteration buffers are sized to the old grid; drop them
        self.ws = step::Workspace::default();
        // parked replies reference the dead grid's partitions and worker
        // ids — they cannot fold into the re-sharded run
        self.state.late.clear();
        // fault events at or before the interrupted iteration targeted
        // the old grid and were already armed — the re-run must not
        // re-arm them (worker ids have been renumbered anyway)
        if let Some(plan) = self.fault_plan.as_mut() {
            plan.prune_through(self.state.t);
        }
        if self.fault_plan.as_ref().is_some_and(FaultPlan::is_empty) {
            self.fault_plan = None;
        }
        Ok(())
    }

    /// Drive the current run to completion. Like [`Trainer::step`], an
    /// already-completed run is an error — a sweep that forgot to
    /// `reconfigure`/`reset` would otherwise silently get the previous
    /// outcome back.
    pub fn run(&mut self) -> Result<TrainOutcome> {
        self.run_with_observer(|_| ControlFlow::Continue(()))
    }

    /// Drive the current run to completion, streaming every recorded
    /// [`IterRecord`] (including iteration 0 when starting fresh) to the
    /// observer. `ControlFlow::Break` stops the run early; the returned
    /// outcome's history is truncated at the last observed record, and
    /// the run can be resumed by calling `run`/`step` again.
    pub fn run_with_observer(
        &mut self,
        mut observer: impl FnMut(&IterRecord) -> ControlFlow<()>,
    ) -> Result<TrainOutcome> {
        ensure!(
            !self.is_done(),
            "run {:?} already complete after {} iterations; \
             use warm_start/reconfigure/reset to start another run",
            self.cfg.name,
            self.cfg.outer_iters
        );
        // deliver iteration 0 only when it lands now — a run resumed
        // after an early break at iteration 0 already delivered it
        if self.state.t == 0 && self.state.history.records.is_empty() {
            self.ensure_initial_record()?;
            let first = self.state.history.records[0];
            if observer(&first).is_break() {
                return Ok(self.outcome());
            }
        }
        while !self.is_done() {
            if let Some(rec) = self.step()? {
                if observer(&rec).is_break() {
                    break;
                }
            }
        }
        Ok(self.outcome())
    }

    // ---- starting the next run ------------------------------------------

    /// Restart the current config from scratch: ω^0 = 0, fresh RNG
    /// streams, fresh cost model. The staged dataset/cluster/engine are
    /// untouched.
    pub fn reset(&mut self) {
        self.state = fresh_state(&self.cfg, self.cluster.layout.m_total);
    }

    /// Start a fresh run from a caller-provided initial iterate ω^0
    /// (resumed/chained runs; warm-started baseline comparisons).
    pub fn warm_start(&mut self, w0: &[f32]) -> Result<()> {
        ensure!(
            w0.len() == self.cluster.layout.m_total,
            "warm_start: w0 has {} coordinates, model has {}",
            w0.len(),
            self.cluster.layout.m_total
        );
        self.state = fresh_state(&self.cfg, self.cluster.layout.m_total);
        self.state.w.copy_from_slice(w0);
        Ok(())
    }

    /// Start a fresh run under a new config on the same staged session.
    ///
    /// Everything staged must stay valid, so the new config must keep the
    /// session's dataset dimensions, loss, and engine kind (workers own
    /// their shards and loss; the XLA engine is compiled at a fixed
    /// inner-loop length). A *grid* change is allowed when the session's
    /// engine is not shape-specialized: the dataset is re-partitioned and
    /// the cluster relaunched through the same restaging machinery as
    /// elastic re-sharding — but voluntarily, between runs, off the
    /// simulated clock (no shuffle charge, no [`ReshardRecord`]). Name,
    /// algorithm, fractions, schedule, seed, iteration counts, eval
    /// cadence and network model are free — which is exactly what the
    /// fig2/table2 sweeps vary. Note the session keeps the dataset it was
    /// staged with: `cfg.seed` reseeds the training streams only.
    pub fn reconfigure(&mut self, cfg: ExperimentConfig) -> Result<()> {
        cfg.validate()?;
        ensure!(
            cfg.data.n() == self.ds.n() && cfg.data.m() == self.ds.m(),
            "reconfigure: session dataset is {}x{}, new config expects {}x{}",
            self.ds.n(),
            self.ds.m(),
            cfg.data.n(),
            cfg.data.m()
        );
        ensure!(
            cfg.loss == self.cfg.loss,
            "reconfigure: session workers hold loss {}, new config wants {} (stage a new Trainer)",
            self.cfg.loss.name(),
            cfg.loss.name()
        );
        ensure!(
            cfg.engine == self.cfg.engine,
            "reconfigure: session engine kind {:?} != requested {:?} (stage a new Trainer)",
            self.cfg.engine,
            cfg.engine
        );
        // the transport was launched at staging; a config that resolves
        // to the other executor needs a new session
        let kind = ExecutorKind::resolve(cfg.executor)?;
        ensure!(
            kind == self.cluster.executor(),
            "reconfigure: session executor is {}, new config resolves to {kind} \
             (stage a new Trainer)",
            self.cluster.executor()
        );
        // ask the engine the session actually holds, not the config kind —
        // with_parts sessions can hold a shape-specialized engine under a
        // Native-tagged config (the cross-check tests do exactly that)
        if let Some(steps) = self.engine.fixed_inner_steps() {
            ensure!(
                cfg.inner_steps == steps,
                "reconfigure: engine kernels are compiled at L={steps}, new config wants L={}",
                cfg.inner_steps
            );
        }
        if cfg.p != self.cfg.p || cfg.q != self.cfg.q {
            // shape-specialized (AOT) kernels are compiled at one block
            // shape — a different grid needs different artifacts
            ensure!(
                self.engine.fixed_inner_steps().is_none(),
                "reconfigure: session holds shape-specialized kernels compiled for the \
                 {}x{} grid; a {}x{} grid needs a new Trainer",
                self.cfg.p,
                self.cfg.q,
                cfg.p,
                cfg.q
            );
            let layout = staged_layout(&cfg, &self.ds)?;
            let grid = Grid::partition_with_layout(self.ds.as_ref(), layout)?;
            self.cluster = Cluster::launch_with_policy(
                grid,
                Arc::clone(&self.engine),
                cfg.loss,
                kind,
                cfg.recovery.unwrap_or_default(),
            );
            self.ws = step::Workspace::default();
        }
        self.cfg = cfg;
        self.reset();
        Ok(())
    }

    /// Push the iteration-0 record `F(ω^0)` if it isn't there yet.
    /// Lazy (first `step`/`run`) so that staging, `reconfigure` and the
    /// reconfigure-then-`warm_start` idiom never pay for an objective
    /// evaluation that the next call would immediately discard.
    fn ensure_initial_record(&mut self) -> Result<()> {
        if self.state.t == 0 && self.state.history.records.is_empty() {
            // the run's wall clock starts when the run does, not at
            // staging — sessions may sit staged for a while before use
            self.state.t_start = Instant::now();
            // a permanent loss during the iteration-0 evaluation (no
            // fault plan can arm before iteration 1) would mean the
            // cluster died before the run began — surface it as an error
            let loss = self.objective_now()?;
            let rec = IterRecord {
                iter: 0,
                loss,
                wall_s: self.state.t_start.elapsed().as_secs_f64(),
                sim_s: 0.0,
                comm_bytes: 0,
                grad_coord_evals: 0,
            };
            self.state.history.push(rec);
        }
        Ok(())
    }
}

/// The run's cost model: network parameters + the (validated) cluster
/// profile resolved against the P·Q grid. An unset profile is the
/// bit-frozen uniform default.
fn sim_net_for(cfg: &ExperimentConfig) -> SimNet {
    let profile = cfg.cluster_profile.clone().unwrap_or_default();
    SimNet::new(cfg.network.unwrap_or_default(), &profile, cfg.p * cfg.q)
}

/// The session's row/column boundary vectors. `Balanced` keeps the
/// frozen equal-split layout; `Throughput` sizes row shards by worker
/// rate (a row partition is barrier-bound by its *slowest* worker
/// across the Q feature blocks) so skewed profiles finish phases
/// together. A uniform profile falls back to the balanced boundary
/// vectors bit-for-bit — unless the dataset is sparse, in which case
/// `Throughput` splits by *nnz mass* ([`Layout::weighted_by_cost`] with
/// per-row nnz as the cost): on skewed-density CSR data equal row
/// counts are not equal work, so the density-aware split is what makes
/// shards actually finish together. Dense `Throughput` layouts are
/// unchanged (every row costs the same).
fn staged_layout(cfg: &ExperimentConfig, ds: &Dataset) -> Result<Layout> {
    let (n, m) = (cfg.data.n(), cfg.data.m());
    match cfg.shard_weighting {
        ShardWeighting::Balanced => Layout::new(n, m, cfg.p, cfg.q),
        ShardWeighting::Throughput => {
            let profile = cfg.cluster_profile.clone().unwrap_or_default();
            let rates = profile.rates(cfg.p * cfg.q);
            let weights: Vec<f64> = (0..cfg.p)
                .map(|pi| {
                    (0..cfg.q).map(|qi| rates[pi * cfg.q + qi]).fold(f64::INFINITY, f64::min)
                })
                .collect();
            let uniform = weights.windows(2).all(|w| w[0] == w[1]);
            match ds.x.row_costs() {
                Some(costs) => Layout::weighted_by_cost(n, m, cfg.p, cfg.q, &weights, &costs),
                None if uniform => Layout::new(n, m, cfg.p, cfg.q),
                None => Layout::weighted(n, m, cfg.p, cfg.q, &weights),
            }
        }
    }
}

/// Resolve the session's bounded-staleness policy, mirroring the fault
/// plan's contract: an explicit `.staleness(...)` config pin always
/// wins; otherwise a non-empty `SODDA_STALENESS` is parsed and
/// validated here, at staging — not silently mid-run. Empty/unset
/// keeps the hard barrier.
fn staged_staleness(cfg: &ExperimentConfig) -> Result<Option<StalenessPolicy>> {
    if cfg.staleness.is_some() {
        return Ok(cfg.staleness);
    }
    match crate::util::env::read(StalenessPolicy::ENV) {
        Some(raw) if !raw.trim().is_empty() => {
            let pol: StalenessPolicy = raw
                .trim()
                .parse()
                .map_err(|e: String| anyhow::anyhow!("{}: {e}", StalenessPolicy::ENV))?;
            pol.validate().with_context(|| StalenessPolicy::ENV)?;
            Ok(Some(pol))
        }
        _ => Ok(None),
    }
}

fn fresh_state(cfg: &ExperimentConfig, m_total: usize) -> RunCore {
    // independent RNG streams (see util::rng docs)
    let root = Rng::seed_from_u64(cfg.seed);
    RunCore {
        w: vec![0.0f32; m_total],
        history: History::new(&cfg.name),
        net: sim_net_for(cfg),
        rng_sets: root.fork(0xB0),
        rng_perm: root.fork(0xC0),
        rng_rows: root.fork(0xD0),
        t: 0,
        grad_coord_evals: 0,
        t_start: Instant::now(),
        late: LateSet::default(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::AlgorithmKind;

    fn cfg(iters: usize) -> ExperimentConfig {
        ExperimentConfig::builder()
            .name("trainer-unit")
            .dense(200, 24)
            .grid(2, 2)
            .inner_steps(4)
            .outer_iters(iters)
            .seed(3)
            .build()
            .unwrap()
    }

    #[test]
    fn iteration_zero_is_recorded_lazily_at_run_start() {
        let mut t = Trainer::new(cfg(5)).unwrap();
        assert_eq!(t.iteration(), 0);
        assert!(t.history().records.is_empty(), "no objective eval until the run starts");
        assert!(!t.is_done());
        t.step().unwrap();
        assert_eq!(t.history().records[0].iter, 0);
        assert_eq!(t.history().records.len(), 2); // F(ω^0) + iteration 1
    }

    #[test]
    fn step_advances_and_errors_when_done() {
        let mut t = Trainer::new(cfg(2)).unwrap();
        assert!(t.step().unwrap().is_some());
        assert!(t.step().unwrap().is_some());
        assert!(t.is_done());
        assert!(t.step().is_err());
        assert!(t.run().is_err(), "run() on a completed run must not return stale results");
    }

    #[test]
    fn eval_cadence_controls_step_records() {
        let c = cfg(5).to_builder().eval_every(2).build().unwrap();
        let mut t = Trainer::new(c).unwrap();
        let mut recorded = Vec::new();
        while !t.is_done() {
            if let Some(r) = t.step().unwrap() {
                recorded.push(r.iter);
            }
        }
        // every 2nd iteration plus the final one
        assert_eq!(recorded, vec![2, 4, 5]);
    }

    #[test]
    fn reset_reproduces_the_same_run() {
        let mut t = Trainer::new(cfg(4)).unwrap();
        let a = t.run().unwrap();
        t.reset();
        let b = t.run().unwrap();
        assert_eq!(a.w, b.w);
        assert_eq!(a.history.losses(), b.history.losses());
    }

    #[test]
    fn pooled_workspace_never_changes_numbers() {
        // dropping every recycled buffer between steps forces the cold
        // fresh-allocation path; the trajectory must be bit-identical
        let mut warm = Trainer::new(cfg(4)).unwrap();
        let a = warm.run().unwrap();
        let mut cold = Trainer::new(cfg(4)).unwrap();
        while !cold.is_done() {
            cold.drop_scratch();
            cold.step().unwrap();
        }
        let b = cold.outcome();
        assert_eq!(a.w, b.w);
        assert_eq!(a.history.losses(), b.history.losses());
    }

    #[test]
    fn reconfigure_rejects_incompatible_sessions() {
        let mut t = Trainer::new(cfg(3)).unwrap();
        let other_loss =
            cfg(3).to_builder().loss(crate::loss::Loss::Logistic).build().unwrap();
        assert!(t.reconfigure(other_loss).is_err());
        let other_dims = cfg(3).to_builder().dense(400, 24).build().unwrap();
        assert!(t.reconfigure(other_dims).is_err());
        // compatible: algorithm/fractions/seed changes
        let variant = cfg(3)
            .to_builder()
            .algorithm(AlgorithmKind::RadisaAvg)
            .seed(11)
            .build()
            .unwrap();
        assert!(t.reconfigure(variant).is_ok());
    }

    #[test]
    fn reconfigure_restages_grid_changes() {
        // a grid change re-partitions the staged dataset in place; the
        // restaged session's run must be bit-identical to a session
        // staged fresh at the new grid
        let mut t = Trainer::new(cfg(4)).unwrap();
        t.run().unwrap();
        let shrunk = cfg(4).to_builder().grid(2, 1).build().unwrap();
        t.reconfigure(shrunk.clone()).unwrap();
        assert_eq!(t.cluster.layout.p, 2);
        assert_eq!(t.cluster.layout.q, 1);
        let a = t.run().unwrap();
        let b = Trainer::new(shrunk).unwrap().run().unwrap();
        assert_eq!(a.w, b.w);
        assert_eq!(a.history.losses(), b.history.losses());
        assert_eq!(a.comm_bytes, b.comm_bytes);
    }

    #[test]
    fn throughput_staging_splits_sparse_rows_by_nnz_mass() {
        use crate::data::{CsrMatrix, Store};

        // 60 rows x 8 cols: the first 20 rows are 6x denser than the
        // rest, so count-balanced shards would give partition 0 three
        // quarters of the work
        let rows: Vec<Vec<(usize, f32)>> = (0..60)
            .map(|r| {
                let nnz = if r < 20 { 6 } else { 1 };
                (0..nnz).map(|j| (j, 1.0 + r as f32)).collect()
            })
            .collect();
        let ds = Dataset {
            x: Store::Sparse(CsrMatrix::from_row_entries(60, 8, rows)),
            y: vec![1.0; 60],
            name: "skewed".into(),
        };
        let costs = ds.x.row_costs().unwrap();
        let base = ExperimentConfig::builder()
            .name("nnz-staging")
            .sparse(60, 8, 3)
            .grid(2, 2)
            .outer_iters(1)
            .build()
            .unwrap();

        // Balanced weighting ignores density (frozen legacy layout)
        let balanced = staged_layout(&base, &ds).unwrap();
        assert_eq!(balanced.row_bounds(), Layout::new(60, 8, 2, 2).unwrap().row_bounds());

        // Throughput weighting on CSR splits by nnz mass even under a
        // uniform profile: each shard carries ~half the nonzeros
        let thr = base.to_builder().shard_weighting(ShardWeighting::Throughput).build().unwrap();
        let l = staged_layout(&thr, &ds).unwrap();
        assert_eq!(
            l.row_bounds(),
            Layout::weighted_by_cost(60, 8, 2, 2, &[1.0, 1.0], &costs).unwrap().row_bounds()
        );
        assert_ne!(l.row_bounds(), balanced.row_bounds());
        let cut = l.row_bounds()[1];
        let mass: f64 = costs[..cut].iter().sum();
        let total: f64 = costs.iter().sum();
        assert!(
            (mass / total - 0.5).abs() < 0.05,
            "nnz mass below the cut should be ~half, got {} of {}",
            mass,
            total
        );

        // dense Throughput layouts are unchanged by the cost-aware path
        let dense_thr =
            cfg(1).to_builder().shard_weighting(ShardWeighting::Throughput).build().unwrap();
        let dense_ds = dense_thr.data.try_materialize(3).unwrap();
        let dl = staged_layout(&dense_thr, &dense_ds).unwrap();
        assert_eq!(dl.row_bounds(), Layout::new(200, 24, 2, 2).unwrap().row_bounds());
    }

    #[test]
    fn permanent_loss_shrinks_the_grid_and_continues() {
        let mut t = Trainer::new(cfg(4)).unwrap();
        t.set_fault_plan(Some("3@2:grad!perm".parse().unwrap()));
        let out = t.run().unwrap();
        // the 2x2 grid lost an observation-row partition
        assert_eq!((t.config().p, t.config().q), (1, 2));
        assert_eq!(out.history.reshards.len(), 1);
        let r = &out.history.reshards[0];
        assert_eq!((r.iter, r.worker), (2, 3));
        assert_eq!((r.from_p, r.from_q, r.to_p, r.to_q), (2, 2, 1, 2));
        assert!(r.bytes > 0, "re-staging the survivors moves bytes");
        assert!(r.sim_s > 0.0, "the shuffle costs simulated time");
        // the interrupted iteration was rolled back and re-run: the full
        // horizon completes and every iteration lands exactly once
        assert_eq!(out.history.records.len(), 5); // F(ω^0) + 4 iterations
        assert!(t.is_done());
        assert!(out.history.faults.iter().any(|f| f.perm), "the kill is logged as permanent");
        // the degraded tail is the shrunk grid's own trajectory: from the
        // rollback point on, the run is the 1x2 session's math (pinned
        // exhaustively in tests/faults.rs)
        assert!(out.history.losses().iter().all(|l| l.is_finite()));
    }

    #[test]
    fn observer_sees_iteration_zero_first() {
        let mut t = Trainer::new(cfg(3)).unwrap();
        let mut seen = Vec::new();
        t.run_with_observer(|r| {
            seen.push(r.iter);
            ControlFlow::Continue(())
        })
        .unwrap();
        assert_eq!(seen, vec![0, 1, 2, 3]);
    }
}
