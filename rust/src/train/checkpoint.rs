//! Checkpoint/resume: a versioned, serializable snapshot of a run.
//!
//! [`Trainer::checkpoint`] freezes the current run — iterate ω^t, the
//! recorded history, all three RNG streams, the cost-model accumulators
//! and the completed-iteration count — into a [`RunState`].
//! [`Trainer::resume`] stages a *fresh* session from the config
//! (dataset, partition grid, engine and cluster are derived state, so
//! they are rebuilt, not serialized) and installs the snapshot after
//! validating it against the staged session. Because every stochastic
//! choice flows from the three xoshiro streams and the cost model is
//! pure accumulation, the resumed run continues the exact trajectory: a
//! checkpoint taken at any `t` followed by `resume` reproduces the
//! uninterrupted run's remaining records bit-for-bit (`wall_s`
//! excepted — wall clocks restart with the process).
//!
//! The on-disk format is the crate's hand-rolled JSON, tagged
//! [`CHECKPOINT_FORMAT`]. RNG registers and the u64 counters serialize
//! as **decimal strings**: a JSON number is an `f64` and cannot carry
//! all 64 bits. `f32`/`f64` payloads are exact — `f32 → f64` widening
//! is lossless and the writer emits shortest-round-trip `f64` text.
//!
//! ## Durability
//!
//! Every write is **atomic**: the JSON lands in a `.tmp` sibling first
//! and is renamed over the target, so a crash mid-save can never leave
//! a truncated checkpoint where a good one used to be — the previous
//! snapshot survives, and the leftover `.tmp` is overwritten by the
//! next save.
//!
//! For long runs with a tight cadence, [`CheckpointObserver::incremental`]
//! switches to **delta mode**: a full snapshot is written once, and
//! subsequent saves write only the dirty state — changed ω coordinates,
//! the new history tail, the RNG registers and counters — to a
//! `<path>.delta` sibling (format [`CHECKPOINT_DELTA_FORMAT`]). When
//! more than half the coordinates are dirty the observer *compacts*:
//! writes a fresh full snapshot and drops the delta. [`RunState::load`]
//! applies a matching delta transparently (a stale delta — one whose
//! base iteration does not match the full snapshot, as left by a crash
//! between compaction's two steps — is ignored; the full snapshot is
//! authoritative).

use std::path::{Path, PathBuf};
use std::sync::Arc;
use std::time::Instant;

use anyhow::{ensure, Context, Result};

use super::{sim_net_for, RunCore, TrainOutcome, Trainer};
use crate::cluster::LateSet;
use crate::config::{ExecutorKind, ExperimentConfig};
use crate::data::Dataset;
use crate::metrics::History;
use crate::util::json::{self, Value};
use crate::util::rng::Rng;

/// Format tag of the checkpoint schema this build reads and writes.
/// [`RunState::from_json`] rejects anything else — resuming from a
/// half-understood snapshot would corrupt a trajectory silently.
pub const CHECKPOINT_FORMAT: &str = "sodda-checkpoint-v1";

/// Format tag of the incremental-delta schema (see the module docs'
/// Durability section). A delta rides on the full snapshot it was
/// diffed against and is never loaded on its own.
pub const CHECKPOINT_DELTA_FORMAT: &str = "sodda-checkpoint-delta-v1";

/// The `.delta` sibling of a checkpoint path (`out/ckpt.json` →
/// `out/ckpt.json.delta`).
fn delta_path(path: &Path) -> PathBuf {
    let mut name = path.file_name().unwrap_or_default().to_os_string();
    name.push(".delta");
    path.with_file_name(name)
}

/// Crash-safe write: parent dirs, then `.tmp` sibling, then rename.
fn atomic_write(path: &Path, text: &str) -> Result<()> {
    if let Some(dir) = path.parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir).with_context(|| format!("creating {}", dir.display()))?;
        }
    }
    let mut tmp = path.file_name().unwrap_or_default().to_os_string();
    tmp.push(".tmp");
    let tmp = path.with_file_name(tmp);
    std::fs::write(&tmp, text).with_context(|| format!("writing {}", tmp.display()))?;
    std::fs::rename(&tmp, path)
        .with_context(|| format!("renaming {} into place", tmp.display()))
}

/// The serializable state of one run at an outer-iteration boundary —
/// everything [`Trainer::resume`] needs that is not derivable from the
/// [`ExperimentConfig`]. Produced by [`Trainer::checkpoint`]; see the
/// module docs for the exactness contract.
#[derive(Debug, Clone)]
pub struct RunState {
    /// name of the run this snapshot belongs to (validated on resume)
    pub run: String,
    /// executor the session ran on when the snapshot was taken.
    /// **Provenance, not a constraint**: the two executors are
    /// bit-identical (the cross-executor resume tests in
    /// `tests/faults.rs` pin this), so a resume may stage either kind —
    /// the field records where the numbers came from for wall-clock
    /// bookkeeping.
    pub executor: ExecutorKind,
    /// completed outer iterations
    pub t: usize,
    /// iterate ω^t
    pub w: Vec<f32>,
    pub history: History,
    /// xoshiro256** registers of the set-sampling stream
    pub rng_sets: [u64; 4],
    /// … of the π_q permutation stream
    pub rng_perm: [u64; 4],
    /// … of the SVRG row-sampling stream
    pub rng_rows: [u64; 4],
    /// simulated-network accumulators ([`crate::cluster::SimNet`])
    pub sim_s: f64,
    pub comm_bytes: u64,
    pub comm_msgs: u64,
    pub grad_coord_evals: u64,
    /// bounded-staleness: replies parked past a quorum cut at snapshot
    /// time. Part of the trajectory (they fold into later iterations),
    /// so resume must carry them; always empty under the hard barrier,
    /// and serialized only when non-empty so barrier checkpoints are
    /// byte-identical to the pre-staleness format.
    pub late: LateSet,
}

fn rng_to_json(s: [u64; 4]) -> Value {
    Value::Arr(s.iter().map(|x| json::s(x.to_string())).collect())
}

fn rng_from_json(v: &Value) -> Result<[u64; 4]> {
    let arr = v.as_arr()?;
    ensure!(arr.len() == 4, "rng state must have 4 registers, found {}", arr.len());
    let mut out = [0u64; 4];
    for (o, x) in out.iter_mut().zip(arr) {
        *o = x.as_str()?.parse().context("bad rng register")?;
    }
    Ok(out)
}

fn u64_from_json(v: &Value, key: &str) -> Result<u64> {
    v.get(key)?.as_str()?.parse().with_context(|| format!("bad u64 counter {key:?}"))
}

impl RunState {
    pub fn to_json(&self) -> Value {
        let mut fields = vec![
            ("format", json::s(CHECKPOINT_FORMAT)),
            ("run", json::s(self.run.clone())),
            ("executor", json::s(self.executor.to_string())),
            ("t", json::num(self.t as f64)),
            ("sim_s", json::num(self.sim_s)),
            ("comm_bytes", json::s(self.comm_bytes.to_string())),
            ("comm_msgs", json::s(self.comm_msgs.to_string())),
            ("grad_coord_evals", json::s(self.grad_coord_evals.to_string())),
            ("rng_sets", rng_to_json(self.rng_sets)),
            ("rng_perm", rng_to_json(self.rng_perm)),
            ("rng_rows", rng_to_json(self.rng_rows)),
            ("w", Value::Arr(self.w.iter().map(|&x| json::num(x as f64)).collect())),
            ("history", self.history.to_json()),
        ];
        if !self.late.is_empty() {
            fields.push(("late_set", self.late.to_json_value()));
        }
        json::obj(fields)
    }

    pub fn from_json(v: &Value) -> Result<RunState> {
        let format = v.get("format")?.as_str()?;
        ensure!(
            format == CHECKPOINT_FORMAT,
            "unsupported checkpoint format {format:?} (this build reads {CHECKPOINT_FORMAT:?})"
        );
        let executor: ExecutorKind =
            v.get("executor")?.as_str()?.parse().map_err(anyhow::Error::msg)?;
        let w = v
            .get("w")?
            .as_arr()?
            .iter()
            .map(|x| Ok(x.as_f64()? as f32))
            .collect::<Result<Vec<f32>>>()?;
        Ok(RunState {
            run: v.get("run")?.as_str()?.to_string(),
            executor,
            t: v.get("t")?.as_usize()?,
            w,
            history: History::from_json(v.get("history")?)?,
            rng_sets: rng_from_json(v.get("rng_sets")?).context("rng_sets")?,
            rng_perm: rng_from_json(v.get("rng_perm")?).context("rng_perm")?,
            rng_rows: rng_from_json(v.get("rng_rows")?).context("rng_rows")?,
            sim_s: v.get("sim_s")?.as_f64()?,
            comm_bytes: u64_from_json(v, "comm_bytes")?,
            comm_msgs: u64_from_json(v, "comm_msgs")?,
            grad_coord_evals: u64_from_json(v, "grad_coord_evals")?,
            late: v
                .opt("late_set")
                .map(LateSet::from_json_value)
                .transpose()
                .context("late_set")?
                .unwrap_or_default(),
        })
    }

    /// Write the snapshot to `path` (creating parent directories).
    /// Atomic: a crash mid-save leaves the previous checkpoint intact
    /// (see the module docs' Durability section).
    pub fn save(&self, path: &Path) -> Result<()> {
        atomic_write(path, &self.to_json().to_string_pretty())
            .with_context(|| format!("writing checkpoint {}", path.display()))
    }

    /// Write only what changed since `base` (a full snapshot already on
    /// disk) to `path` — changed ω coordinates, the history tail, RNG
    /// registers and counters. Atomic like [`RunState::save`]. The delta
    /// is only loadable next to its base: [`RunState::load`] of the full
    /// snapshot's path applies it transparently.
    pub fn save_delta(&self, base: &RunState, path: &Path) -> Result<()> {
        ensure!(
            base.run == self.run && base.w.len() == self.w.len() && base.t <= self.t,
            "delta checkpoint: base (run {:?}, t={}, {} coords) does not underlie \
             run {:?}, t={}, {} coords",
            base.run,
            base.t,
            base.w.len(),
            self.run,
            self.t,
            self.w.len()
        );
        atomic_write(path, &self.delta_to_json(base).to_string_pretty())
            .with_context(|| format!("writing delta checkpoint {}", path.display()))
    }

    fn delta_to_json(&self, base: &RunState) -> Value {
        let mut dw_idx = Vec::new();
        let mut dw_val = Vec::new();
        for (i, (&a, &b)) in base.w.iter().zip(&self.w).enumerate() {
            if a != b {
                dw_idx.push(json::num(i as f64));
                dw_val.push(json::num(b as f64));
            }
        }
        // history is append-only at iteration boundaries, so the
        // since-base tails are pure index suffixes
        let tail = History {
            run: self.history.run.clone(),
            records: self.history.records[base.history.records.len()..].to_vec(),
            faults: self.history.faults[base.history.faults.len()..].to_vec(),
            reshards: self.history.reshards[base.history.reshards.len()..].to_vec(),
            staleness: self.history.staleness[base.history.staleness.len()..].to_vec(),
        };
        let mut fields = vec![
            ("format", json::s(CHECKPOINT_DELTA_FORMAT)),
            ("run", json::s(self.run.clone())),
            ("executor", json::s(self.executor.to_string())),
            ("base_t", json::num(base.t as f64)),
            ("base_records", json::num(base.history.records.len() as f64)),
            ("t", json::num(self.t as f64)),
            ("sim_s", json::num(self.sim_s)),
            ("comm_bytes", json::s(self.comm_bytes.to_string())),
            ("comm_msgs", json::s(self.comm_msgs.to_string())),
            ("grad_coord_evals", json::s(self.grad_coord_evals.to_string())),
            ("rng_sets", rng_to_json(self.rng_sets)),
            ("rng_perm", rng_to_json(self.rng_perm)),
            ("rng_rows", rng_to_json(self.rng_rows)),
            ("dw_idx", Value::Arr(dw_idx)),
            ("dw_val", Value::Arr(dw_val)),
            ("history_tail", tail.to_json()),
        ];
        // the parked set is replaced wholesale on apply (entries both
        // arrive and drain between snapshots), so an absent key means
        // "empty now", not "unchanged"
        if !self.late.is_empty() {
            fields.push(("late_set", self.late.to_json_value()));
        }
        json::obj(fields)
    }

    /// Reconstruct the full state `base` + delta. Errors if the delta
    /// does not ride on exactly this base.
    fn apply_delta(base: &RunState, v: &Value) -> Result<RunState> {
        let format = v.get("format")?.as_str()?;
        ensure!(
            format == CHECKPOINT_DELTA_FORMAT,
            "unsupported delta format {format:?} (this build reads {CHECKPOINT_DELTA_FORMAT:?})"
        );
        ensure!(
            v.get("run")?.as_str()? == base.run && v.get("base_t")?.as_usize()? == base.t,
            "delta does not ride on this snapshot (run {:?}, t={})",
            base.run,
            base.t
        );
        let mut out = base.clone();
        out.executor = v.get("executor")?.as_str()?.parse().map_err(anyhow::Error::msg)?;
        out.t = v.get("t")?.as_usize()?;
        out.sim_s = v.get("sim_s")?.as_f64()?;
        out.comm_bytes = u64_from_json(v, "comm_bytes")?;
        out.comm_msgs = u64_from_json(v, "comm_msgs")?;
        out.grad_coord_evals = u64_from_json(v, "grad_coord_evals")?;
        out.rng_sets = rng_from_json(v.get("rng_sets")?).context("rng_sets")?;
        out.rng_perm = rng_from_json(v.get("rng_perm")?).context("rng_perm")?;
        out.rng_rows = rng_from_json(v.get("rng_rows")?).context("rng_rows")?;
        let idx = v.get("dw_idx")?.as_arr()?;
        let val = v.get("dw_val")?.as_arr()?;
        ensure!(idx.len() == val.len(), "delta dw_idx/dw_val length mismatch");
        for (i, x) in idx.iter().zip(val) {
            let i = i.as_usize()?;
            ensure!(i < out.w.len(), "delta coordinate {i} out of range");
            out.w[i] = x.as_f64()? as f32;
        }
        let tail = History::from_json(v.get("history_tail")?).context("history_tail")?;
        out.history.records.extend_from_slice(&tail.records);
        out.history.faults.extend_from_slice(&tail.faults);
        out.history.reshards.extend_from_slice(&tail.reshards);
        out.history.staleness.extend_from_slice(&tail.staleness);
        out.late = v
            .opt("late_set")
            .map(LateSet::from_json_value)
            .transpose()
            .context("late_set")?
            .unwrap_or_default();
        Ok(out)
    }

    /// Read a snapshot written by [`RunState::save`]. A matching
    /// `<path>.delta` sibling (delta mode, see the module docs) is
    /// applied transparently; a *stale* delta — base iteration not
    /// matching the snapshot, as left by a crash between compaction's
    /// full write and delta removal — is ignored.
    pub fn load(path: &Path) -> Result<RunState> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading checkpoint {}", path.display()))?;
        let v = Value::parse(&text)
            .with_context(|| format!("parsing checkpoint {}", path.display()))?;
        if v.get("format").ok().and_then(|f| f.as_str().ok()) == Some(CHECKPOINT_DELTA_FORMAT) {
            anyhow::bail!(
                "{} is an incremental delta; load the full snapshot it rides on \
                 (same path without the .delta suffix)",
                path.display()
            );
        }
        let snap = RunState::from_json(&v)?;
        let dpath = delta_path(path);
        let Ok(dtext) = std::fs::read_to_string(&dpath) else {
            return Ok(snap);
        };
        let dv = Value::parse(&dtext)
            .with_context(|| format!("parsing delta checkpoint {}", dpath.display()))?;
        let fresh = dv.get("run").and_then(|r| Ok(r.as_str()? == snap.run)).unwrap_or(false)
            && dv.get("base_t").and_then(Value::as_usize).map_or(false, |t| t == snap.t);
        if fresh {
            RunState::apply_delta(&snap, &dv)
                .with_context(|| format!("applying delta checkpoint {}", dpath.display()))
        } else {
            Ok(snap)
        }
    }
}

/// Periodic checkpoint writer for step-driven loops (and the engine
/// behind [`Trainer::run_with_checkpoints`]). Unlike the
/// [`observers`](super::observers) closures this is *not* an
/// `FnMut(&IterRecord)` — a snapshot needs the whole run state, which
/// the record stream deliberately does not carry — so it observes the
/// trainer between steps instead:
///
/// ```no_run
/// # fn main() -> anyhow::Result<()> {
/// # let cfg = sodda::ExperimentConfig::builder().name("ckpt").dense(200, 24)
/// #     .grid(2, 2).outer_iters(10).build()?;
/// let mut trainer = sodda::Trainer::new(cfg)?;
/// let obs = sodda::train::CheckpointObserver::new("out/ckpt.json", 5);
/// while !trainer.is_done() {
///     trainer.step()?;
///     obs.observe(&trainer)?;
/// }
/// # Ok(()) }
/// ```
pub struct CheckpointObserver {
    path: PathBuf,
    every: usize,
    /// delta mode: keep the last *full* snapshot on disk as the diff
    /// base, writing dirty state to the `.delta` sibling in between
    incremental: bool,
    base: std::cell::RefCell<Option<RunState>>,
}

impl CheckpointObserver {
    /// Write to `path` every `every` completed iterations (and at run
    /// completion, so the final state is always on disk). Every write
    /// is a full snapshot.
    pub fn new(path: impl Into<PathBuf>, every: usize) -> CheckpointObserver {
        CheckpointObserver {
            path: path.into(),
            every: every.max(1),
            incremental: false,
            base: std::cell::RefCell::new(None),
        }
    }

    /// Like [`CheckpointObserver::new`], but in **delta mode**: the
    /// first write is a full snapshot, subsequent writes diff against it
    /// into `<path>.delta` — and compact back to a full snapshot once
    /// more than half the coordinates are dirty. [`RunState::load`] of
    /// `path` reconstructs the latest state either way.
    pub fn incremental(path: impl Into<PathBuf>, every: usize) -> CheckpointObserver {
        CheckpointObserver { incremental: true, ..CheckpointObserver::new(path, every) }
    }

    /// Snapshot `trainer` if its iteration count hits the cadence.
    /// Returns whether a checkpoint (full or delta) was written.
    pub fn observe(&self, trainer: &Trainer) -> Result<bool> {
        if !(trainer.iteration() % self.every == 0 || trainer.is_done()) {
            return Ok(false);
        }
        let state = trainer.checkpoint();
        let ctx = || format!("checkpointing {:?} at iteration {}", state.run, state.t);
        if self.incremental {
            let mut base = self.base.borrow_mut();
            if let Some(b) = base.as_ref() {
                let dirty = b.w.iter().zip(&state.w).filter(|(x, y)| x != y).count();
                if b.run == state.run && b.t <= state.t && 2 * dirty <= state.w.len() {
                    state.save_delta(b, &delta_path(&self.path)).with_context(ctx)?;
                    return Ok(true);
                }
            }
            // first write, or compaction: the full snapshot becomes the
            // new base and any delta riding on the old one is dropped
            // (a crash between these two steps leaves a stale delta,
            // which `load` ignores)
            state.save(&self.path).with_context(ctx)?;
            let _ = std::fs::remove_file(delta_path(&self.path));
            *base = Some(state);
            return Ok(true);
        }
        state.save(&self.path).with_context(ctx)?;
        Ok(true)
    }
}

impl Trainer {
    /// Snapshot the current run as a serializable [`RunState`] (clones;
    /// the run continues unaffected). Meaningful at outer-iteration
    /// boundaries — which is the only place callers can be, since
    /// [`Trainer::step`] is atomic.
    pub fn checkpoint(&self) -> RunState {
        RunState {
            run: self.cfg.name.clone(),
            executor: self.cluster.executor(),
            t: self.state.t,
            w: self.state.w.clone(),
            history: self.state.history.clone(),
            rng_sets: self.state.rng_sets.state(),
            rng_perm: self.state.rng_perm.state(),
            rng_rows: self.state.rng_rows.state(),
            sim_s: self.state.net.sim_s(),
            comm_bytes: self.state.net.total_bytes(),
            comm_msgs: self.state.net.total_msgs(),
            grad_coord_evals: self.state.grad_coord_evals,
            late: self.state.late.clone(),
        }
    }

    /// Stage a fresh session from `cfg` and continue the checkpointed
    /// run. The config must be the one the snapshot was taken under (or
    /// an equivalent: same name, model width, executor resolution, and
    /// at least `state.t` outer iterations) — mismatches are staging
    /// errors, not mid-run surprises.
    pub fn resume(cfg: ExperimentConfig, state: RunState) -> Result<Trainer> {
        let mut trainer = Trainer::new(cfg)?;
        trainer.install(state)?;
        Ok(trainer)
    }

    /// [`Trainer::resume`] around a caller-provided dataset (the same
    /// sharing contract as [`Trainer::with_dataset`]).
    pub fn resume_with_dataset(
        cfg: ExperimentConfig,
        ds: impl Into<Arc<Dataset>>,
        state: RunState,
    ) -> Result<Trainer> {
        let mut trainer = Trainer::with_dataset(cfg, ds)?;
        trainer.install(state)?;
        Ok(trainer)
    }

    /// Drive the current run to completion, writing a [`RunState`] to
    /// `path` every `every` iterations and at completion (see
    /// [`CheckpointObserver`]).
    pub fn run_with_checkpoints(
        &mut self,
        path: impl Into<PathBuf>,
        every: usize,
    ) -> Result<TrainOutcome> {
        ensure!(
            !self.is_done(),
            "run {:?} already complete after {} iterations; \
             use warm_start/reconfigure/reset to start another run",
            self.cfg.name,
            self.cfg.outer_iters
        );
        let obs = CheckpointObserver::new(path, every);
        while !self.is_done() {
            self.step()?;
            obs.observe(self)?;
        }
        Ok(self.outcome())
    }

    /// Validate `snap` against this freshly staged session and swap it
    /// in as the current run state.
    fn install(&mut self, snap: RunState) -> Result<()> {
        ensure!(
            snap.run == self.cfg.name,
            "checkpoint belongs to run {:?}, config stages {:?}",
            snap.run,
            self.cfg.name
        );
        ensure!(
            snap.w.len() == self.cluster.layout.m_total,
            "checkpoint iterate has {} coordinates, staged model has {}",
            snap.w.len(),
            self.cluster.layout.m_total
        );
        ensure!(
            snap.t <= self.cfg.outer_iters,
            "checkpoint is at iteration {} but config runs only {}",
            snap.t,
            self.cfg.outer_iters
        );
        // deliberately no executor check: the two executors are
        // bit-identical, so a snapshot resumes on either kind —
        // `snap.executor` is provenance, not a constraint (the
        // cross-executor tests in tests/faults.rs pin the bit-identity)
        let mut net = sim_net_for(&self.cfg);
        net.restore(snap.sim_s, snap.comm_bytes, snap.comm_msgs);
        self.state = RunCore {
            w: snap.w,
            history: snap.history,
            net,
            rng_sets: Rng::from_state(snap.rng_sets),
            rng_perm: Rng::from_state(snap.rng_perm),
            rng_rows: Rng::from_state(snap.rng_rows),
            t: snap.t,
            grad_coord_evals: snap.grad_coord_evals,
            t_start: Instant::now(),
            late: snap.late,
        };
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg(iters: usize) -> ExperimentConfig {
        ExperimentConfig::builder()
            .name("ckpt-unit")
            .dense(200, 24)
            .grid(2, 2)
            .inner_steps(4)
            .outer_iters(iters)
            .seed(5)
            .build()
            .unwrap()
    }

    #[test]
    fn run_state_round_trips_through_json() {
        let mut t = Trainer::new(cfg(6)).unwrap();
        for _ in 0..3 {
            t.step().unwrap();
        }
        let snap = t.checkpoint();
        let text = snap.to_json().to_string_pretty();
        let back = RunState::from_json(&Value::parse(&text).unwrap()).unwrap();
        assert_eq!(back.run, snap.run);
        assert_eq!(back.executor, snap.executor);
        assert_eq!(back.t, snap.t);
        assert_eq!(back.w, snap.w, "iterate must survive the text round trip bit-for-bit");
        assert_eq!(back.rng_sets, snap.rng_sets);
        assert_eq!(back.rng_perm, snap.rng_perm);
        assert_eq!(back.rng_rows, snap.rng_rows);
        assert_eq!(back.sim_s, snap.sim_s);
        assert_eq!(back.comm_bytes, snap.comm_bytes);
        assert_eq!(back.comm_msgs, snap.comm_msgs);
        assert_eq!(back.grad_coord_evals, snap.grad_coord_evals);
        assert_eq!(back.history.records, snap.history.records);
    }

    #[test]
    fn rng_registers_survive_as_full_u64s() {
        // a register with > 53 significant bits would be mangled by an
        // f64 JSON number; the string encoding must not lose it
        let snap = rng_from_json(&rng_to_json([u64::MAX, 1, 0x8000_0000_0000_0001, 42])).unwrap();
        assert_eq!(snap, [u64::MAX, 1, 0x8000_0000_0000_0001, 42]);
    }

    #[test]
    fn resume_validates_the_staged_session() {
        let mut t = Trainer::new(cfg(6)).unwrap();
        t.step().unwrap();
        let snap = t.checkpoint();

        let renamed = cfg(6).to_builder().name("other").build().unwrap();
        assert!(Trainer::resume(renamed, snap.clone()).is_err(), "name mismatch");

        let narrow = ExperimentConfig::builder()
            .name("ckpt-unit")
            .dense(200, 16)
            .grid(2, 2)
            .inner_steps(4)
            .outer_iters(6)
            .seed(5)
            .build()
            .unwrap();
        assert!(Trainer::resume(narrow, snap.clone()).is_err(), "width mismatch");

        let mut past = snap.clone();
        past.t = 99;
        assert!(Trainer::resume(cfg(6), past).is_err(), "t beyond the horizon");

        assert!(Trainer::resume(cfg(6), snap).is_ok());
    }

    fn tmp(name: &str) -> PathBuf {
        std::env::temp_dir().join(format!("sodda-ckpt-unit-{}-{name}", std::process::id()))
    }

    #[test]
    fn mid_save_crash_leaves_the_previous_checkpoint_intact() {
        let dir = tmp("atomic");
        let path = dir.join("ckpt.json");
        let mut t = Trainer::new(cfg(4)).unwrap();
        t.step().unwrap();
        let good = t.checkpoint();
        good.save(&path).unwrap();

        // simulate a crash mid-save: a truncated payload sits in the
        // .tmp sibling, never renamed over the target
        let stale_tmp = dir.join("ckpt.json.tmp");
        let half = good.to_json().to_string_pretty();
        std::fs::write(&stale_tmp, &half[..half.len() / 2]).unwrap();
        let back = RunState::load(&path).unwrap();
        assert_eq!(back.t, good.t);
        assert_eq!(back.w, good.w, "the previous checkpoint must survive a crashed save");

        // and the next save simply overwrites the leftover .tmp
        t.step().unwrap();
        t.checkpoint().save(&path).unwrap();
        assert_eq!(RunState::load(&path).unwrap().t, 2);

        // a checkpoint truncated in place (torn copy, bad disk) fails
        // loudly rather than resuming a corrupt trajectory
        std::fs::write(&path, &half[..half.len() / 2]).unwrap();
        assert!(RunState::load(&path).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn incremental_observer_round_trips_through_delta_and_compaction() {
        let dir = tmp("delta");
        let path = dir.join("ckpt.json");
        let obs = CheckpointObserver::incremental(&path, 1);
        let mut t = Trainer::new(cfg(5)).unwrap();
        t.step().unwrap();
        obs.observe(&t).unwrap(); // full base at t=1
        let base = RunState::load(&path).unwrap();
        assert_eq!(base.t, 1);

        t.step().unwrap();
        obs.observe(&t).unwrap();
        let live = t.checkpoint();
        // whether this write was a delta or a compaction, load must
        // reconstruct the live state exactly
        let loaded = RunState::load(&path).unwrap();
        assert_eq!(loaded.t, 2);
        assert_eq!(loaded.w, live.w, "delta apply must reproduce ω bit-for-bit");
        assert_eq!(loaded.rng_rows, live.rng_rows);
        assert_eq!(loaded.comm_bytes, live.comm_bytes);
        assert_eq!(loaded.history.records, live.history.records);

        // ...and resuming from the reconstructed state continues the
        // exact trajectory
        let mut resumed = Trainer::resume(cfg(5), loaded).unwrap();
        let a = resumed.run().unwrap();
        while !t.is_done() {
            t.step().unwrap();
        }
        assert_eq!(a.w, t.outcome().w);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn stale_delta_is_ignored_and_bare_delta_is_rejected() {
        let dir = tmp("stale");
        let path = dir.join("ckpt.json");
        let mut t = Trainer::new(cfg(4)).unwrap();
        t.step().unwrap();
        let s1 = t.checkpoint();
        s1.save(&path).unwrap();
        t.step().unwrap();
        let s2 = t.checkpoint();
        s2.save_delta(&s1, &super::delta_path(&path)).unwrap();
        assert_eq!(RunState::load(&path).unwrap().t, 2, "matching delta applies");

        // interrupted compaction: a newer full snapshot lands but the
        // old delta was not yet removed — the delta no longer matches
        // and must be ignored
        t.step().unwrap();
        t.checkpoint().save(&path).unwrap();
        assert_eq!(RunState::load(&path).unwrap().t, 3, "stale delta is ignored");

        // a delta path on its own is not a loadable checkpoint
        assert!(RunState::load(&super::delta_path(&path)).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn resume_crosses_executors() {
        // provenance only: a snapshot taken on one executor resumes on
        // the other (the bit-identity of the two transports is pinned
        // end-to-end in tests/faults.rs)
        let mut t = Trainer::new(cfg(4)).unwrap();
        t.step().unwrap();
        let mut snap = t.checkpoint();
        snap.executor = match snap.executor {
            ExecutorKind::InProcess => ExecutorKind::Threaded,
            ExecutorKind::Threaded => ExecutorKind::InProcess,
        };
        assert!(Trainer::resume(cfg(4), snap).is_ok());
    }

    #[test]
    fn late_set_round_trips_and_stays_out_of_barrier_snapshots() {
        use crate::cluster::{LateReply, LateSlice};

        let mut t = Trainer::new(cfg(4)).unwrap();
        t.step().unwrap();
        let barrier = t.checkpoint();
        assert!(barrier.late.is_empty());
        let text = barrier.to_json().to_string_pretty();
        assert!(
            !text.contains("late_set"),
            "a barrier snapshot must not grow a late_set key (format is frozen)"
        );

        // a quorum-mode snapshot carries its parked replies exactly
        let mut snap = barrier.clone();
        snap.late.entries.push(LateReply {
            iter: 1,
            worker: 2,
            slice: LateSlice::Mu { p: 0, part: vec![0.25, -1.5] },
        });
        snap.late.entries.push(LateReply {
            iter: 1,
            worker: 3,
            slice: LateSlice::Grad { cols: vec![4, 9], data: vec![1.0, 2.0], inv_d: 0.125 },
        });
        let text = snap.to_json().to_string_pretty();
        let back = RunState::from_json(&Value::parse(&text).unwrap()).unwrap();
        assert_eq!(back.late, snap.late, "parked replies must survive the text round trip");

        // delta apply REPLACES the parked set: present key installs it...
        let delta = snap.delta_to_json(&barrier);
        let applied = RunState::apply_delta(&barrier, &delta).unwrap();
        assert_eq!(applied.late, snap.late);
        // ...and an absent key (everything drained since) empties it
        let drained = barrier.clone();
        let delta = drained.delta_to_json(&snap);
        assert!(!delta.to_string_pretty().contains("late_set"));
        let applied = RunState::apply_delta(&snap, &delta).unwrap();
        assert!(applied.late.is_empty(), "an absent late_set key must clear the parked set");
    }

    #[test]
    fn from_json_rejects_other_formats() {
        let mut t = Trainer::new(cfg(2)).unwrap();
        t.step().unwrap();
        let text = t.checkpoint().to_json().to_string_pretty();
        let bad = text.replace(CHECKPOINT_FORMAT, "sodda-checkpoint-v999");
        assert!(RunState::from_json(&Value::parse(&bad).unwrap()).is_err());
    }
}
