//! Checkpoint/resume: a versioned, serializable snapshot of a run.
//!
//! [`Trainer::checkpoint`] freezes the current run — iterate ω^t, the
//! recorded history, all three RNG streams, the cost-model accumulators
//! and the completed-iteration count — into a [`RunState`].
//! [`Trainer::resume`] stages a *fresh* session from the config
//! (dataset, partition grid, engine and cluster are derived state, so
//! they are rebuilt, not serialized) and installs the snapshot after
//! validating it against the staged session. Because every stochastic
//! choice flows from the three xoshiro streams and the cost model is
//! pure accumulation, the resumed run continues the exact trajectory: a
//! checkpoint taken at any `t` followed by `resume` reproduces the
//! uninterrupted run's remaining records bit-for-bit (`wall_s`
//! excepted — wall clocks restart with the process).
//!
//! The on-disk format is the crate's hand-rolled JSON, tagged
//! [`CHECKPOINT_FORMAT`]. RNG registers and the u64 counters serialize
//! as **decimal strings**: a JSON number is an `f64` and cannot carry
//! all 64 bits. `f32`/`f64` payloads are exact — `f32 → f64` widening
//! is lossless and the writer emits shortest-round-trip `f64` text.

use std::path::{Path, PathBuf};
use std::sync::Arc;
use std::time::Instant;

use anyhow::{ensure, Context, Result};

use super::{sim_net_for, RunCore, TrainOutcome, Trainer};
use crate::config::{ExecutorKind, ExperimentConfig};
use crate::data::Dataset;
use crate::metrics::History;
use crate::util::json::{self, Value};
use crate::util::rng::Rng;

/// Format tag of the checkpoint schema this build reads and writes.
/// [`RunState::from_json`] rejects anything else — resuming from a
/// half-understood snapshot would corrupt a trajectory silently.
pub const CHECKPOINT_FORMAT: &str = "sodda-checkpoint-v1";

/// The serializable state of one run at an outer-iteration boundary —
/// everything [`Trainer::resume`] needs that is not derivable from the
/// [`ExperimentConfig`]. Produced by [`Trainer::checkpoint`]; see the
/// module docs for the exactness contract.
#[derive(Debug, Clone)]
pub struct RunState {
    /// name of the run this snapshot belongs to (validated on resume)
    pub run: String,
    /// executor the session ran on when the snapshot was taken. The two
    /// executors are bit-identical, but a resume that silently switches
    /// runtimes would invalidate wall-clock comparisons — resume
    /// validates the staged session resolves to the same kind.
    pub executor: ExecutorKind,
    /// completed outer iterations
    pub t: usize,
    /// iterate ω^t
    pub w: Vec<f32>,
    pub history: History,
    /// xoshiro256** registers of the set-sampling stream
    pub rng_sets: [u64; 4],
    /// … of the π_q permutation stream
    pub rng_perm: [u64; 4],
    /// … of the SVRG row-sampling stream
    pub rng_rows: [u64; 4],
    /// simulated-network accumulators ([`crate::cluster::SimNet`])
    pub sim_s: f64,
    pub comm_bytes: u64,
    pub comm_msgs: u64,
    pub grad_coord_evals: u64,
}

fn rng_to_json(s: [u64; 4]) -> Value {
    Value::Arr(s.iter().map(|x| json::s(x.to_string())).collect())
}

fn rng_from_json(v: &Value) -> Result<[u64; 4]> {
    let arr = v.as_arr()?;
    ensure!(arr.len() == 4, "rng state must have 4 registers, found {}", arr.len());
    let mut out = [0u64; 4];
    for (o, x) in out.iter_mut().zip(arr) {
        *o = x.as_str()?.parse().context("bad rng register")?;
    }
    Ok(out)
}

fn u64_from_json(v: &Value, key: &str) -> Result<u64> {
    v.get(key)?.as_str()?.parse().with_context(|| format!("bad u64 counter {key:?}"))
}

impl RunState {
    pub fn to_json(&self) -> Value {
        json::obj(vec![
            ("format", json::s(CHECKPOINT_FORMAT)),
            ("run", json::s(self.run.clone())),
            ("executor", json::s(self.executor.to_string())),
            ("t", json::num(self.t as f64)),
            ("sim_s", json::num(self.sim_s)),
            ("comm_bytes", json::s(self.comm_bytes.to_string())),
            ("comm_msgs", json::s(self.comm_msgs.to_string())),
            ("grad_coord_evals", json::s(self.grad_coord_evals.to_string())),
            ("rng_sets", rng_to_json(self.rng_sets)),
            ("rng_perm", rng_to_json(self.rng_perm)),
            ("rng_rows", rng_to_json(self.rng_rows)),
            ("w", Value::Arr(self.w.iter().map(|&x| json::num(x as f64)).collect())),
            ("history", self.history.to_json()),
        ])
    }

    pub fn from_json(v: &Value) -> Result<RunState> {
        let format = v.get("format")?.as_str()?;
        ensure!(
            format == CHECKPOINT_FORMAT,
            "unsupported checkpoint format {format:?} (this build reads {CHECKPOINT_FORMAT:?})"
        );
        let executor: ExecutorKind =
            v.get("executor")?.as_str()?.parse().map_err(anyhow::Error::msg)?;
        let w = v
            .get("w")?
            .as_arr()?
            .iter()
            .map(|x| Ok(x.as_f64()? as f32))
            .collect::<Result<Vec<f32>>>()?;
        Ok(RunState {
            run: v.get("run")?.as_str()?.to_string(),
            executor,
            t: v.get("t")?.as_usize()?,
            w,
            history: History::from_json(v.get("history")?)?,
            rng_sets: rng_from_json(v.get("rng_sets")?).context("rng_sets")?,
            rng_perm: rng_from_json(v.get("rng_perm")?).context("rng_perm")?,
            rng_rows: rng_from_json(v.get("rng_rows")?).context("rng_rows")?,
            sim_s: v.get("sim_s")?.as_f64()?,
            comm_bytes: u64_from_json(v, "comm_bytes")?,
            comm_msgs: u64_from_json(v, "comm_msgs")?,
            grad_coord_evals: u64_from_json(v, "grad_coord_evals")?,
        })
    }

    /// Write the snapshot to `path` (creating parent directories).
    pub fn save(&self, path: &Path) -> Result<()> {
        if let Some(dir) = path.parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir)
                    .with_context(|| format!("creating {}", dir.display()))?;
            }
        }
        std::fs::write(path, self.to_json().to_string_pretty())
            .with_context(|| format!("writing checkpoint {}", path.display()))
    }

    /// Read a snapshot written by [`RunState::save`].
    pub fn load(path: &Path) -> Result<RunState> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading checkpoint {}", path.display()))?;
        let v = Value::parse(&text)
            .with_context(|| format!("parsing checkpoint {}", path.display()))?;
        RunState::from_json(&v)
    }
}

/// Periodic checkpoint writer for step-driven loops (and the engine
/// behind [`Trainer::run_with_checkpoints`]). Unlike the
/// [`observers`](super::observers) closures this is *not* an
/// `FnMut(&IterRecord)` — a snapshot needs the whole run state, which
/// the record stream deliberately does not carry — so it observes the
/// trainer between steps instead:
///
/// ```no_run
/// # fn main() -> anyhow::Result<()> {
/// # let cfg = sodda::ExperimentConfig::builder().name("ckpt").dense(200, 24)
/// #     .grid(2, 2).outer_iters(10).build()?;
/// let mut trainer = sodda::Trainer::new(cfg)?;
/// let obs = sodda::train::CheckpointObserver::new("out/ckpt.json", 5);
/// while !trainer.is_done() {
///     trainer.step()?;
///     obs.observe(&trainer)?;
/// }
/// # Ok(()) }
/// ```
pub struct CheckpointObserver {
    path: PathBuf,
    every: usize,
}

impl CheckpointObserver {
    /// Write to `path` every `every` completed iterations (and at run
    /// completion, so the final state is always on disk).
    pub fn new(path: impl Into<PathBuf>, every: usize) -> CheckpointObserver {
        CheckpointObserver { path: path.into(), every: every.max(1) }
    }

    /// Snapshot `trainer` if its iteration count hits the cadence.
    /// Returns whether a checkpoint was written.
    pub fn observe(&self, trainer: &Trainer) -> Result<bool> {
        if trainer.iteration() % self.every == 0 || trainer.is_done() {
            let state = trainer.checkpoint();
            state.save(&self.path).with_context(|| {
                format!("checkpointing {:?} at iteration {}", state.run, state.t)
            })?;
            return Ok(true);
        }
        Ok(false)
    }
}

impl Trainer {
    /// Snapshot the current run as a serializable [`RunState`] (clones;
    /// the run continues unaffected). Meaningful at outer-iteration
    /// boundaries — which is the only place callers can be, since
    /// [`Trainer::step`] is atomic.
    pub fn checkpoint(&self) -> RunState {
        RunState {
            run: self.cfg.name.clone(),
            executor: self.cluster.executor(),
            t: self.state.t,
            w: self.state.w.clone(),
            history: self.state.history.clone(),
            rng_sets: self.state.rng_sets.state(),
            rng_perm: self.state.rng_perm.state(),
            rng_rows: self.state.rng_rows.state(),
            sim_s: self.state.net.sim_s(),
            comm_bytes: self.state.net.total_bytes(),
            comm_msgs: self.state.net.total_msgs(),
            grad_coord_evals: self.state.grad_coord_evals,
        }
    }

    /// Stage a fresh session from `cfg` and continue the checkpointed
    /// run. The config must be the one the snapshot was taken under (or
    /// an equivalent: same name, model width, executor resolution, and
    /// at least `state.t` outer iterations) — mismatches are staging
    /// errors, not mid-run surprises.
    pub fn resume(cfg: ExperimentConfig, state: RunState) -> Result<Trainer> {
        let mut trainer = Trainer::new(cfg)?;
        trainer.install(state)?;
        Ok(trainer)
    }

    /// [`Trainer::resume`] around a caller-provided dataset (the same
    /// sharing contract as [`Trainer::with_dataset`]).
    pub fn resume_with_dataset(
        cfg: ExperimentConfig,
        ds: impl Into<Arc<Dataset>>,
        state: RunState,
    ) -> Result<Trainer> {
        let mut trainer = Trainer::with_dataset(cfg, ds)?;
        trainer.install(state)?;
        Ok(trainer)
    }

    /// Drive the current run to completion, writing a [`RunState`] to
    /// `path` every `every` iterations and at completion (see
    /// [`CheckpointObserver`]).
    pub fn run_with_checkpoints(
        &mut self,
        path: impl Into<PathBuf>,
        every: usize,
    ) -> Result<TrainOutcome> {
        ensure!(
            !self.is_done(),
            "run {:?} already complete after {} iterations; \
             use warm_start/reconfigure/reset to start another run",
            self.cfg.name,
            self.cfg.outer_iters
        );
        let obs = CheckpointObserver::new(path, every);
        while !self.is_done() {
            self.step()?;
            obs.observe(self)?;
        }
        Ok(self.outcome())
    }

    /// Validate `snap` against this freshly staged session and swap it
    /// in as the current run state.
    fn install(&mut self, snap: RunState) -> Result<()> {
        ensure!(
            snap.run == self.cfg.name,
            "checkpoint belongs to run {:?}, config stages {:?}",
            snap.run,
            self.cfg.name
        );
        ensure!(
            snap.w.len() == self.cluster.layout.m_total,
            "checkpoint iterate has {} coordinates, staged model has {}",
            snap.w.len(),
            self.cluster.layout.m_total
        );
        ensure!(
            snap.t <= self.cfg.outer_iters,
            "checkpoint is at iteration {} but config runs only {}",
            snap.t,
            self.cfg.outer_iters
        );
        ensure!(
            snap.executor == self.cluster.executor(),
            "checkpoint was taken on the {} executor, this session resolved to {}",
            snap.executor,
            self.cluster.executor()
        );
        let mut net = sim_net_for(&self.cfg);
        net.restore(snap.sim_s, snap.comm_bytes, snap.comm_msgs);
        self.state = RunCore {
            w: snap.w,
            history: snap.history,
            net,
            rng_sets: Rng::from_state(snap.rng_sets),
            rng_perm: Rng::from_state(snap.rng_perm),
            rng_rows: Rng::from_state(snap.rng_rows),
            t: snap.t,
            grad_coord_evals: snap.grad_coord_evals,
            t_start: Instant::now(),
        };
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg(iters: usize) -> ExperimentConfig {
        ExperimentConfig::builder()
            .name("ckpt-unit")
            .dense(200, 24)
            .grid(2, 2)
            .inner_steps(4)
            .outer_iters(iters)
            .seed(5)
            .build()
            .unwrap()
    }

    #[test]
    fn run_state_round_trips_through_json() {
        let mut t = Trainer::new(cfg(6)).unwrap();
        for _ in 0..3 {
            t.step().unwrap();
        }
        let snap = t.checkpoint();
        let text = snap.to_json().to_string_pretty();
        let back = RunState::from_json(&Value::parse(&text).unwrap()).unwrap();
        assert_eq!(back.run, snap.run);
        assert_eq!(back.executor, snap.executor);
        assert_eq!(back.t, snap.t);
        assert_eq!(back.w, snap.w, "iterate must survive the text round trip bit-for-bit");
        assert_eq!(back.rng_sets, snap.rng_sets);
        assert_eq!(back.rng_perm, snap.rng_perm);
        assert_eq!(back.rng_rows, snap.rng_rows);
        assert_eq!(back.sim_s, snap.sim_s);
        assert_eq!(back.comm_bytes, snap.comm_bytes);
        assert_eq!(back.comm_msgs, snap.comm_msgs);
        assert_eq!(back.grad_coord_evals, snap.grad_coord_evals);
        assert_eq!(back.history.records, snap.history.records);
    }

    #[test]
    fn rng_registers_survive_as_full_u64s() {
        // a register with > 53 significant bits would be mangled by an
        // f64 JSON number; the string encoding must not lose it
        let snap = rng_from_json(&rng_to_json([u64::MAX, 1, 0x8000_0000_0000_0001, 42])).unwrap();
        assert_eq!(snap, [u64::MAX, 1, 0x8000_0000_0000_0001, 42]);
    }

    #[test]
    fn resume_validates_the_staged_session() {
        let mut t = Trainer::new(cfg(6)).unwrap();
        t.step().unwrap();
        let snap = t.checkpoint();

        let renamed = cfg(6).to_builder().name("other").build().unwrap();
        assert!(Trainer::resume(renamed, snap.clone()).is_err(), "name mismatch");

        let narrow = ExperimentConfig::builder()
            .name("ckpt-unit")
            .dense(200, 16)
            .grid(2, 2)
            .inner_steps(4)
            .outer_iters(6)
            .seed(5)
            .build()
            .unwrap();
        assert!(Trainer::resume(narrow, snap.clone()).is_err(), "width mismatch");

        let mut past = snap.clone();
        past.t = 99;
        assert!(Trainer::resume(cfg(6), past).is_err(), "t beyond the horizon");

        assert!(Trainer::resume(cfg(6), snap).is_ok());
    }

    #[test]
    fn from_json_rejects_other_formats() {
        let mut t = Trainer::new(cfg(2)).unwrap();
        t.step().unwrap();
        let text = t.checkpoint().to_json().to_string_pretty();
        let bad = text.replace(CHECKPOINT_FORMAT, "sodda-checkpoint-v999");
        assert!(RunState::from_json(&Value::parse(&bad).unwrap()).is_err());
    }
}
