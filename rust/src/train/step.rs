//! One outer iteration of Algorithm 1 (and the RADiSA variants), split
//! out of the session type so the loop body is independently testable:
//! [`Trainer::step`] is `t += 1` plus exactly one call into this module.
//!
//! Structure (SODDA; RADiSA variants take the full sets):
//!
//! 1. draw `(B^t, C^t, D^t)` (steps 5-7);
//! 2. **µ^t estimate** (step 8) — distributed: workers compute partial
//!    margins over B^t-masked parameters, the leader reduces z across
//!    feature blocks, broadcasts `u = f'(z, y)`, workers return gradient
//!    slices, the leader projects onto C^t and divides by `d^t`;
//! 3. draw permutations `π_q` and run the `P×Q` parallel SVRG inner
//!    loops on disjoint sub-blocks (steps 10-18);
//! 4. concatenate sub-blocks into `ω^{t+1}` (step 19).

use std::sync::Arc;

use super::Trainer;
use crate::cluster::SvrgTask;
use crate::config::AlgorithmKind;
use crate::coordinator::sampling::{self, SampleSets};
use crate::metrics::IterRecord;

impl Trainer {
    /// Run outer iteration `self.state.t` (already advanced by `step`).
    /// Returns the record when this iteration hits the eval cadence.
    pub(super) fn iterate(&mut self) -> Option<IterRecord> {
        let cfg = &self.cfg;
        let (p, q) = (cfg.p, cfg.q);
        let (n_total, m_total) = (self.cluster.layout.n_total, self.cluster.layout.m_total);
        let t = self.state.t;
        let gamma = cfg.schedule.gamma(t) as f32;

        // ---- sets (steps 5-7) -----------------------------------------------
        let sets = match cfg.algorithm {
            AlgorithmKind::Sodda => {
                SampleSets::draw(&mut self.state.rng_sets, n_total, m_total, &cfg.fractions)
            }
            AlgorithmKind::Radisa | AlgorithmKind::RadisaAvg => SampleSets::full(n_total, m_total),
        };
        let rows_arc: Vec<Arc<Vec<u32>>> =
            sampling::rows_per_partition(&sets.d, self.cluster.layout.row_bounds())
                .into_iter()
                .map(Arc::new)
                .collect();

        // ---- µ^t estimate (step 8) ------------------------------------------
        let w_masked = sampling::mask_keep(&self.state.w, &sets.b);
        let w_blocks: Vec<Arc<Vec<f32>>> = (0..q)
            .map(|qi| Arc::new(w_masked[self.cluster.layout.block_cols(qi)].to_vec()))
            .collect();

        {
            // phase-1 cost, identical for both paths below: the fused
            // reply (`u`) is exactly as long as the unfused one (`z`)
            let mut bytes = 0u64;
            let mut max_flops = 0f64;
            for pi in 0..p {
                for qi in 0..q {
                    let cols = self.cluster.layout.block_cols(qi);
                    let bq = SampleSets::count_in_range(&sets.b, cols.start, cols.end);
                    bytes += 4 * (bq as u64 + rows_arc[pi].len() as u64);
                    let fl =
                        2.0 * rows_arc[pi].len() as f64 * bq as f64 * self.cluster.density_at(pi, qi);
                    max_flops = max_flops.max(fl);
                }
            }
            self.state.net.phase(max_flops, bytes, 2 * (p * q) as u64, 1);
        }

        // u = f'(z, y): fused on-worker when the grid has one feature
        // block, z-reduce + leader dloss otherwise (the cluster picks)
        let u_per_p: Vec<Arc<Vec<f32>>> = self
            .cluster
            .partial_u(&w_blocks, &rows_arc, self.leader_engine.as_ref(), cfg.loss)
            .into_iter()
            .map(Arc::new)
            .collect();
        self.state.net.local(sets.d.len() as f64);

        let mut g = self.cluster.grad(&u_per_p, &rows_arc);
        {
            let mut bytes = 0u64;
            let mut max_flops = 0f64;
            for pi in 0..p {
                for qi in 0..q {
                    let cols = self.cluster.layout.block_cols(qi);
                    let cq = SampleSets::count_in_range(&sets.c, cols.start, cols.end);
                    bytes += 4 * (rows_arc[pi].len() as u64 + cq as u64);
                    let fl =
                        2.0 * rows_arc[pi].len() as f64 * cq as f64 * self.cluster.density_at(pi, qi);
                    max_flops = max_flops.max(fl);
                }
            }
            self.state.net.phase(max_flops, bytes, 2 * (p * q) as u64, 1);
        }

        // µ = (g ∘ C) / d^t
        sampling::project_inplace(&mut g, &sets.c);
        let inv_d = 1.0 / sets.d.len() as f32;
        for v in g.iter_mut() {
            *v *= inv_d;
        }
        let mu = g;
        self.state.net.local(sets.c.len() as f64);
        self.state.grad_coord_evals += (sets.c.len() * sets.d.len()) as u64;

        // ---- inner loops (steps 9-18) + assembly (step 19) ------------------
        // All three algorithms run one parallel sub-epoch: π_q assigns each
        // worker a disjoint sub-block (bijection ⇒ disjoint cover of ω_[q]).
        // SODDA/RADiSA write back the last iterate; RADiSA-avg writes back
        // the suffix-averaged iterate (its "-avg" combiner).
        let avg = cfg.algorithm == AlgorithmKind::RadisaAvg;
        let mut tasks: Vec<SvrgTask> = Vec::with_capacity(p * q);
        let mut task_cols: Vec<std::ops::Range<usize>> = Vec::with_capacity(p * q);
        let mut task_density: Vec<f64> = Vec::with_capacity(p * q);
        for qi in 0..q {
            let perm = self.state.rng_perm.permutation(p);
            for pi in 0..p {
                let k = perm[pi] as usize;
                let gcols = self.cluster.layout.global_cols(qi, k);
                tasks.push(SvrgTask {
                    p: pi,
                    q: qi,
                    cols: self.cluster.layout.sub_cols(qi, k),
                    w0: self.state.w[gcols.clone()].to_vec(),
                    wt: self.state.w[gcols.clone()].to_vec(),
                    mu: mu[gcols.clone()].to_vec(),
                    idx: self
                        .state
                        .rng_rows
                        .sample_with_replacement(self.cluster.layout.rows_in(pi), cfg.inner_steps),
                    gamma,
                    avg,
                });
                task_cols.push(gcols);
                task_density.push(self.cluster.density_at(pi, qi));
            }
        }
        for (ti, w_l) in self.cluster.svrg(tasks) {
            self.state.w[task_cols[ti].clone()].copy_from_slice(&w_l);
        }
        // cost from the actual (ragged) sub-block dims: the phase waits
        // on the slowest worker — the max (width × density) task — while
        // traffic and coordinate evals sum the true widths
        let mut max_flops = 0f64;
        let mut bytes = 0u64;
        let mut inner_evals = 0u64;
        for (ti, gcols) in task_cols.iter().enumerate() {
            let width = gcols.len();
            let fl = 6.0 * cfg.inner_steps as f64 * width as f64 * task_density[ti];
            max_flops = max_flops.max(fl);
            bytes += 4 * (3 * width as u64 + cfg.inner_steps as u64 + width as u64);
            inner_evals += (cfg.inner_steps * width) as u64;
        }
        self.state.net.phase(max_flops, bytes, 2 * (p * q) as u64, 1);
        self.state.grad_coord_evals += inner_evals;

        // ---- reporting -------------------------------------------------------
        if t % cfg.eval_every == 0 || t == cfg.outer_iters {
            let rec = IterRecord {
                iter: t,
                loss: self.objective_now(),
                wall_s: self.state.t_start.elapsed().as_secs_f64(),
                sim_s: self.state.net.sim_s(),
                comm_bytes: self.state.net.total_bytes(),
                grad_coord_evals: self.state.grad_coord_evals,
            };
            self.state.history.push(rec);
            Some(rec)
        } else {
            None
        }
    }

    /// Distributed objective F(ω^t) = (1/N) Σ f(x_i·ω, y_i): partial-z
    /// reduce across feature blocks, loss sum per observation partition.
    /// Not charged to the cost model (the paper evaluates loss curves
    /// offline).
    pub(super) fn objective_now(&self) -> f64 {
        let q = self.cluster.q;
        let w = &self.state.w;
        let w_blocks: Vec<Arc<Vec<f32>>> = (0..q)
            .map(|qi| Arc::new(w[self.cluster.layout.block_cols(qi)].to_vec()))
            .collect();
        let rows: Vec<Arc<Vec<u32>>> = (0..self.cluster.p)
            .map(|pi| Arc::new((0..self.cluster.layout.rows_in(pi) as u32).collect()))
            .collect();
        let total =
            self.cluster.block_loss(&w_blocks, &rows, self.leader_engine.as_ref(), self.cfg.loss);
        total / self.cluster.layout.n_total as f64
    }
}
