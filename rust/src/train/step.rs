//! One outer iteration of Algorithm 1 (and the RADiSA variants), split
//! out of the session type so the loop body is independently testable:
//! [`Trainer::step`] is `t += 1` plus exactly one call into this module.
//!
//! Structure (SODDA; RADiSA variants take the full sets):
//!
//! 1. draw `(B^t, C^t, D^t)` (steps 5-7);
//! 2. **µ^t estimate** (step 8) — distributed: workers compute partial
//!    margins over the sampled parameters, the leader reduces z across
//!    feature blocks, broadcasts `u = f'(z, y)`, workers return gradient
//!    slices, the leader projects onto C^t and divides by `d^t`. When
//!    `|B^t| < M` (resp. `|C^t| < M`) the phase runs **sampled-width**:
//!    per-block sorted local id lists with compact `w`/gradient payloads
//!    (`Cluster::partial_u_cols_into` / `Cluster::grad_cols_into`), so
//!    real FLOPs and wire bytes match what the cost model charges; the
//!    `|B| == M` full sets (RADiSA) keep the frozen full-width path
//!    bit-for-bit (see README "Sampled-width execution");
//! 3. draw permutations `π_q` and run the `P×Q` parallel SVRG inner
//!    loops on disjoint sub-blocks (steps 10-18);
//! 4. concatenate sub-blocks into `ω^{t+1}` (step 19).
//!
//! Every per-iteration buffer lives in the session's [`Workspace`] and
//! is refilled in place, so a steady-state iteration performs O(1) heap
//! allocations instead of O(P·Q) per phase (see README "Steady-state
//! memory"; `tests/alloc_regression.rs` gates the budget and pins
//! bit-for-bit equality against the fresh-allocation path).

use std::ops::Range;
use std::sync::Arc;

use super::faults::FaultPlan;
use super::Trainer;
use crate::cluster::{Cluster, PermanentLoss, QuorumCtx, QuorumStats, SimNet, SvrgTask};
use crate::config::{AlgorithmKind, StalenessPolicy};
use crate::coordinator::sampling::{self, SampleSets};
use crate::metrics::{FaultPhase, FaultRecord, History, IterRecord, StalenessRecord};
use crate::util::arc_mut;

/// Arm this `(iter, phase)`'s scheduled kills right before the phase's
/// sends: [`Cluster::inject_fault`] puts the kill FIFO-ahead of the
/// phase command in the victim's mailbox, so the worker dies *cleanly
/// between commands* and the leader's recovery replay is bit-exact (see
/// the cluster module docs). Every worker participates in every phase,
/// so an armed kill always fires within its phase. Recovered faults are
/// observability-only — they land in [`History::faults`], never in the
/// trajectory. The records are pushed here, at *arm* time, before any
/// transport-dependent recovery runs — so the fault log is identical
/// across executors even when a `!perm` kill (or exhausted respawn
/// retries) later escalates the phase to [`PermanentLoss`].
fn arm_due_faults(
    plan: Option<&FaultPlan>,
    cluster: &Cluster,
    history: &mut History,
    iter: usize,
    phase: FaultPhase,
    workers: usize,
) {
    let Some(plan) = plan else { return };
    for (worker, perm) in plan.kills_for(iter, phase, workers) {
        if perm {
            cluster.inject_permanent_fault(worker);
        } else {
            cluster.inject_fault(worker);
        }
        history.faults.push(FaultRecord { iter, worker, phase, perm });
    }
}

/// Stretch this `(iter, phase)`'s modeled per-worker times by the armed
/// transient slowdowns (`~slow:F` plan events). No plan or no due
/// events leaves the times untouched, keeping default trajectories
/// bit-frozen. Under a hard barrier a slowdown simply stretches the
/// phase's simulated makespan; under a staleness policy it pushes the
/// worker past the quorum cut so its reply is parked.
fn apply_slowdowns(plan: Option<&FaultPlan>, iter: usize, phase: FaultPhase, times: &mut [f64]) {
    let Some(plan) = plan else { return };
    for (worker, factor) in plan.slowdowns_for(iter, phase, times.len()) {
        times[worker] *= factor;
    }
}

/// The phase's simulated makespan under the active staleness policy: a
/// hard barrier (`None`) waits for the slowest modeled worker, while a
/// quorum policy charges the [`SimNet::quorum_cut`] and fills `mask`
/// with the membership it implies. The barrier arm reproduces the
/// historical incremental fold bit-for-bit — same values, and `f64::max`
/// is order-independent without NaNs.
fn quorum_makespan(
    policy: Option<StalenessPolicy>,
    times: &[f64],
    sorted: &mut Vec<f64>,
    mask: &mut Vec<bool>,
) -> f64 {
    match policy {
        Some(pol) => {
            let cut = SimNet::quorum_cut(times, sorted, pol.quorum_frac, pol.timeout_factor);
            mask.clear();
            mask.extend(times.iter().map(|&s| s <= cut));
            cut
        }
        None => times.iter().fold(0.0f64, |a, &b| a.max(b)),
    }
}

/// The session's reusable iteration state: masked/sliced parameter
/// buffers, per-partition row and `u` vectors, the gradient/µ vector,
/// the SVRG task payloads, and `objective_now`'s cached full-row index
/// vectors and w-block slices. Buffers shared with worker threads are
/// `Arc`s recycled through [`arc_mut`] — each phase is a strict barrier,
/// so the leader is the sole owner again by refill time. Survives
/// `reset`/`reconfigure`/`warm_start` (the staged layout never changes),
/// which also keeps warm-session sweeps allocation-free.
#[derive(Default)]
pub(super) struct Workspace {
    /// `(B^t, C^t, D^t)` of the current iteration
    sets: SampleSets,
    /// without-replacement sampling's index-array scratch
    sets_scratch: Vec<u32>,
    /// per-partition local row ids of D^t (phase payloads)
    rows: Vec<Arc<Vec<u32>>>,
    /// per-feature-block sorted local ids of `B^t ∩ block` (sampled-path
    /// phase-1 payloads; unused when `|B| == M`)
    bcols: Vec<Arc<Vec<u32>>>,
    /// per-feature-block sorted local ids of `C^t ∩ block` (sampled-path
    /// phase-2 payloads; unused when `|C| == M`)
    ccols: Vec<Arc<Vec<u32>>>,
    /// `w ∘ 1_B` (full model width; full-width path only)
    w_masked: Vec<f32>,
    /// per-feature-block phase-1 parameter payloads: compact `w[B∩block]`
    /// slices on the sampled path (length `|B∩block|`), full-block
    /// slices of `w_masked` on the `|B| == M` path
    w_blocks: Vec<Arc<Vec<f32>>>,
    /// per-partition loss derivatives `u` (phase payloads)
    u: Vec<Arc<Vec<f32>>>,
    /// full-model ω^t snapshot shared by every SVRG task of a phase
    w_snap: Arc<Vec<f32>>,
    /// gradient accumulator, projected + scaled into µ^t in place, then
    /// shared by every SVRG task of the phase
    mu: Arc<Vec<f32>>,
    /// π_q permutation buffer
    perm: Vec<u32>,
    /// SVRG task assembly (drained by `svrg_run`, capacity retained)
    tasks: Vec<SvrgTask>,
    /// global column range per task (write-back targets + cost model)
    task_cols: Vec<Range<usize>>,
    /// block density per task (cost model)
    task_density: Vec<f64>,
    /// `objective_now`: full-row id vectors per partition — computed once
    /// per session (the layout is fixed at staging)
    eval_rows: Vec<Arc<Vec<u32>>>,
    /// `objective_now`: per-feature-block slices of the current iterate
    eval_w_blocks: Vec<Arc<Vec<f32>>>,
    /// bounded-staleness: modeled per-worker phase seconds (wid order),
    /// also the barrier path's makespan source
    times: Vec<f64>,
    /// bounded-staleness: sort scratch for the quorum cut
    times_sorted: Vec<f64>,
    /// bounded-staleness: quorum membership of the current phase
    quorum_mask: Vec<bool>,
    /// bounded-staleness: per-feature-block stale-fold weight of this
    /// iteration (damps the SVRG step size on touched blocks)
    stale_mass: Vec<f64>,
}

impl Trainer {
    /// Drop every pooled buffer — the session [`Workspace`] and the
    /// cluster's reply pools — forcing the next iteration back onto the
    /// cold, fresh-allocation path. Trajectories are unaffected (pooling
    /// only recycles allocations); the alloc-regression harness uses
    /// this to measure pooled vs fresh on the very same session.
    pub fn drop_scratch(&mut self) {
        self.ws = Workspace::default();
        self.cluster.drop_scratch();
    }

    /// Run outer iteration `self.state.t` (already advanced by `step`).
    /// Returns the record when this iteration hits the eval cadence.
    /// `Err` means a worker was permanently lost mid-phase — the
    /// iteration is incomplete and its side effects are undone by the
    /// caller's rollback (`Trainer::step` re-shards and re-runs).
    pub(super) fn iterate(&mut self) -> Result<Option<IterRecord>, PermanentLoss> {
        let Trainer { cfg, cluster, leader_engine, state, ws, fault_plan, staleness, .. } = self;
        let fault_plan = fault_plan.as_ref();
        // a full-quorum policy is the hard barrier; route it through the
        // frozen path so default configs stay bit-for-bit unchanged
        let policy = (*staleness).filter(|pol| !pol.is_barrier());
        let mut mu_stats = QuorumStats::default();
        let mut grad_stats = QuorumStats::default();
        let (p, q) = (cfg.p, cfg.q);
        let (n_total, m_total) = (cluster.layout.n_total, cluster.layout.m_total);
        let t = state.t;
        let gamma = cfg.schedule.gamma(t) as f32;

        // ---- sets (steps 5-7) -----------------------------------------------
        match cfg.algorithm {
            AlgorithmKind::Sodda => SampleSets::draw_into(
                &mut state.rng_sets,
                n_total,
                m_total,
                &cfg.fractions,
                &mut ws.sets,
                &mut ws.sets_scratch,
            ),
            AlgorithmKind::Radisa | AlgorithmKind::RadisaAvg => {
                SampleSets::full_into(n_total, m_total, &mut ws.sets)
            }
        }
        ws.rows.resize_with(p, Default::default);
        sampling::rows_per_partition_into(
            &ws.sets.d,
            cluster.layout.row_bounds(),
            ws.rows.iter_mut().map(arc_mut),
        );

        // ---- µ^t estimate (step 8) ------------------------------------------
        // Sampled-width execution: when B^t (resp. C^t) is a strict
        // subset of the columns, the phase ships sorted block-local id
        // lists plus **compact** payloads, so worker FLOPs and wire
        // bytes scale with |B∩block| / |C∩block| — exactly what the
        // cost loops below charge. |B| == M (RADiSA, full-fraction
        // SODDA) keeps the frozen full-width path bit-for-bit.
        // |D^t| is fixed for the whole iteration: it scales µ below and
        // stamps parked gradient slices so late folds land in µ-units
        let inv_d = 1.0 / ws.sets.d.len() as f32;
        let b_sampled = ws.sets.b.len() < m_total;
        ws.w_blocks.resize_with(q, Default::default);
        if b_sampled {
            // one boundary walk splits the sorted B^t into per-block
            // local ids (the same walk that splits D^t into rows)
            ws.bcols.resize_with(q, Default::default);
            sampling::rows_per_partition_into(
                &ws.sets.b,
                cluster.layout.col_bounds(),
                ws.bcols.iter_mut().map(arc_mut),
            );
            for (qi, wb) in ws.w_blocks.iter_mut().enumerate() {
                let base = cluster.layout.block_cols(qi).start;
                let dst = arc_mut(wb);
                dst.clear();
                dst.extend(ws.bcols[qi].iter().map(|&ci| state.w[base + ci as usize]));
            }
        } else {
            sampling::mask_keep_into(&state.w, &ws.sets.b, &mut ws.w_masked);
            for (qi, wb) in ws.w_blocks.iter_mut().enumerate() {
                let dst = arc_mut(wb);
                dst.clear();
                dst.extend_from_slice(&ws.w_masked[cluster.layout.block_cols(qi)]);
            }
        }

        {
            // phase-1 cost, identical for the fused/unfused paths below:
            // the fused reply (`u`) is exactly as long as the unfused
            // one (`z`). Per-block sampled widths come straight from the
            // intersection lists (the full path covers every column) —
            // no per-(p,q) binary searches.
            let mut bytes = 0u64;
            ws.times.clear();
            ws.times.resize(p * q, 0.0);
            for qi in 0..q {
                let bq =
                    if b_sampled { ws.bcols[qi].len() } else { cluster.layout.cols_in(qi) };
                // cost-model honesty: the `w` payload this phase puts on
                // the channel is exactly as long as the width it charges
                debug_assert_eq!(
                    bq,
                    ws.w_blocks[qi].len(),
                    "phase-1 charged width != shipped w payload"
                );
                for pi in 0..p {
                    bytes += 4 * (bq as u64 + ws.rows[pi].len() as u64);
                    let fl =
                        2.0 * ws.rows[pi].len() as f64 * bq as f64 * cluster.density_at(pi, qi);
                    ws.times[pi * q + qi] = state.net.worker_s(pi * q + qi, fl);
                }
            }
            apply_slowdowns(fault_plan, t, FaultPhase::Mu, &mut ws.times);
            let makespan =
                quorum_makespan(policy, &ws.times, &mut ws.times_sorted, &mut ws.quorum_mask);
            state.net.phase(makespan, bytes, 2 * (p * q) as u64, 1);
        }

        // u = f'(z, y): fused on-worker when the grid has one feature
        // block, z-reduce + leader dloss otherwise (the cluster picks)
        arm_due_faults(fault_plan, cluster, &mut state.history, t, FaultPhase::Mu, p * q);
        let leader = leader_engine.as_ref();
        if let Some(pol) = policy {
            let mut ctx = QuorumCtx {
                mask: &ws.quorum_mask,
                iter: t,
                max_staleness_iters: pol.max_staleness_iters,
                inv_d: inv_d as f64,
                late: &mut state.late,
                stats: &mut mu_stats,
            };
            let bcols = if b_sampled { Some(&ws.bcols[..]) } else { None };
            cluster.partial_u_quorum_into(
                &ws.w_blocks,
                bcols,
                &ws.rows,
                leader,
                cfg.loss,
                &mut ws.u,
                &mut ctx,
            )?;
        } else if b_sampled {
            cluster.partial_u_cols_into(
                &ws.w_blocks,
                &ws.bcols,
                &ws.rows,
                leader,
                cfg.loss,
                &mut ws.u,
            )?;
        } else {
            cluster.partial_u_into(&ws.w_blocks, &ws.rows, leader, cfg.loss, &mut ws.u)?;
        }
        state.net.local(ws.sets.d.len() as f64);

        let c_sampled = ws.sets.c.len() < m_total;
        if c_sampled {
            ws.ccols.resize_with(q, Default::default);
            sampling::rows_per_partition_into(
                &ws.sets.c,
                cluster.layout.col_bounds(),
                ws.ccols.iter_mut().map(arc_mut),
            );
        }
        {
            // phase-2 cost, charged up front so a quorum policy knows the
            // membership mask before the replies fold. The charge order on
            // the accumulator is unchanged (phase-1, |D| dloss, phase-2),
            // so barrier trajectories keep their exact sim_s bits.
            let mut bytes = 0u64;
            ws.times.clear();
            ws.times.resize(p * q, 0.0);
            for qi in 0..q {
                let cq =
                    if c_sampled { ws.ccols[qi].len() } else { cluster.layout.cols_in(qi) };
                for pi in 0..p {
                    bytes += 4 * (ws.rows[pi].len() as u64 + cq as u64);
                    let fl =
                        2.0 * ws.rows[pi].len() as f64 * cq as f64 * cluster.density_at(pi, qi);
                    ws.times[pi * q + qi] = state.net.worker_s(pi * q + qi, fl);
                }
            }
            apply_slowdowns(fault_plan, t, FaultPhase::Grad, &mut ws.times);
            let makespan =
                quorum_makespan(policy, &ws.times, &mut ws.times_sorted, &mut ws.quorum_mask);
            state.net.phase(makespan, bytes, 2 * (p * q) as u64, 1);
        }
        arm_due_faults(fault_plan, cluster, &mut state.history, t, FaultPhase::Grad, p * q);
        let g = arc_mut(&mut ws.mu);
        if let Some(pol) = policy {
            let mut ctx = QuorumCtx {
                mask: &ws.quorum_mask,
                iter: t,
                max_staleness_iters: pol.max_staleness_iters,
                inv_d: inv_d as f64,
                late: &mut state.late,
                stats: &mut grad_stats,
            };
            let ccols = if c_sampled { Some(&ws.ccols[..]) } else { None };
            cluster.grad_quorum_into(&ws.u, ccols, &ws.rows, g, &mut ctx)?;
        } else if c_sampled {
            // compact |C∩block| replies, scattered into g at the C^t
            // offsets (g returns already projected onto C^t); the
            // cluster debug-asserts each reply length against its id
            // list, so the cq charge above is the actual reply size
            cluster.grad_cols_into(&ws.u, &ws.ccols, &ws.rows, g)?;
        } else {
            cluster.grad_into(&ws.u, &ws.rows, g)?;
        }

        // µ = (g ∘ C) / d^t — in place; `ws.mu` then ships to every task
        if c_sampled {
            // already projected by the compact scatter; scale the C^t
            // coordinates only — O(|C|), not O(M)
            for &ci in ws.sets.c.iter() {
                g[ci as usize] *= inv_d;
            }
        } else {
            sampling::project_inplace(g, &ws.sets.c);
            for v in g.iter_mut() {
                *v *= inv_d;
            }
        }
        if let Some(pol) = policy {
            // drain due parked gradient slices into the fresh µ. Each
            // carries its origin |D| stamp, so the age-discounted fold
            // lands in µ-units regardless of this iteration's |D^t|;
            // blocks a stale slice (or a phase-1 µ fold) touched get
            // their SVRG step damped below.
            ws.stale_mass.clear();
            ws.stale_mass.resize(q, 0.0);
            if mu_stats.fold_weight > 0.0 {
                for mass in ws.stale_mass.iter_mut() {
                    // a stale µ part perturbs every block through u
                    *mass += mu_stats.fold_weight;
                }
            }
            let layout = &cluster.layout;
            let mass = &mut ws.stale_mass;
            let (folds, drops) =
                state.late.fold_grad_into(t, pol.max_staleness_iters, g, |cols, w| {
                    for (qi, m) in mass.iter_mut().enumerate() {
                        let r = layout.block_cols(qi);
                        if cols.iter().any(|&c| r.contains(&(c as usize))) {
                            *m += w as f64;
                        }
                    }
                });
            grad_stats.folds += folds;
            grad_stats.drops += drops;
        }
        state.net.local(ws.sets.c.len() as f64);
        state.grad_coord_evals += (ws.sets.c.len() * ws.sets.d.len()) as u64;

        if policy.is_some() {
            let workers = p * q;
            let rec = StalenessRecord {
                iter: t,
                mu_quorum: mu_stats.quorum,
                grad_quorum: grad_stats.quorum,
                workers,
                late: mu_stats.parked + grad_stats.parked,
                folds: mu_stats.folds + grad_stats.folds,
                drops: mu_stats.drops + grad_stats.drops,
            };
            let trivial = rec.mu_quorum == workers
                && rec.grad_quorum == workers
                && rec.late == 0
                && rec.folds == 0
                && rec.drops == 0;
            if !trivial {
                state.history.staleness.push(rec);
            }
        }

        // ---- inner loops (steps 9-18) + assembly (step 19) ------------------
        // All three algorithms run one parallel sub-epoch: π_q assigns each
        // worker a disjoint sub-block (bijection ⇒ disjoint cover of ω_[q]).
        // SODDA/RADiSA write back the last iterate; RADiSA-avg writes back
        // the suffix-averaged iterate (its "-avg" combiner). One snapshot
        // of ω^t serves every task as both w⁰ and the SVRG reference
        // (they are the same vector at the start of the sub-epoch).
        {
            let wsnap = arc_mut(&mut ws.w_snap);
            wsnap.clear();
            wsnap.extend_from_slice(&state.w);
        }
        let avg = cfg.algorithm == AlgorithmKind::RadisaAvg;
        ws.tasks.clear();
        ws.task_cols.clear();
        ws.task_density.clear();
        for qi in 0..q {
            // per-block step damping: blocks whose µ absorbed stale mass
            // this iteration take shorter SVRG steps (γ / (1 + mass)),
            // so a heavily-discounted fold cannot fling the iterate
            let gamma_q = match policy {
                Some(_) => match ws.stale_mass.get(qi) {
                    Some(&m) if m > 0.0 => gamma * (1.0 / (1.0 + m)) as f32,
                    _ => gamma,
                },
                None => gamma,
            };
            state.rng_perm.permutation_into(p, &mut ws.perm);
            for pi in 0..p {
                let k = ws.perm[pi] as usize;
                let gcols = cluster.layout.global_cols(qi, k);
                let mut idx = cluster.recycled_idx_buf();
                state.rng_rows.sample_with_replacement_into(
                    cluster.layout.rows_in(pi),
                    cfg.inner_steps,
                    arc_mut(&mut idx),
                );
                ws.tasks.push(SvrgTask {
                    p: pi,
                    q: qi,
                    cols: cluster.layout.sub_cols(qi, k),
                    gcols: gcols.clone(),
                    w: Arc::clone(&ws.w_snap),
                    mu: Arc::clone(&ws.mu),
                    idx,
                    gamma: gamma_q,
                    avg,
                });
                ws.task_cols.push(gcols);
                ws.task_density.push(cluster.density_at(pi, qi));
            }
        }
        arm_due_faults(fault_plan, cluster, &mut state.history, t, FaultPhase::Inner, p * q);
        {
            let w = &mut state.w;
            let task_cols = &ws.task_cols;
            cluster.svrg_run(&mut ws.tasks, |ti, w_l| {
                w[task_cols[ti].clone()].copy_from_slice(w_l);
            })?;
        }
        // cost from the actual (ragged) sub-block dims: the phase waits
        // on the slowest worker — the max per-worker (width × density) /
        // rate task — while traffic and coordinate evals sum the true
        // widths. Tasks were pushed qi-major, so task ti ran on worker
        // (ti % p)·Q + ti / p.
        let mut max_s = 0f64;
        let mut bytes = 0u64;
        let mut inner_evals = 0u64;
        for (ti, gcols) in ws.task_cols.iter().enumerate() {
            let width = gcols.len();
            let fl = 6.0 * cfg.inner_steps as f64 * width as f64 * ws.task_density[ti];
            max_s = max_s.max(state.net.worker_s((ti % p) * q + ti / p, fl));
            bytes += 4 * (3 * width as u64 + cfg.inner_steps as u64 + width as u64);
            inner_evals += (cfg.inner_steps * width) as u64;
        }
        state.net.phase(max_s, bytes, 2 * (p * q) as u64, 1);
        state.grad_coord_evals += inner_evals;

        // ---- reporting -------------------------------------------------------
        if t % self.cfg.eval_every == 0 || t == self.cfg.outer_iters {
            let rec = IterRecord {
                iter: t,
                loss: self.objective_now()?,
                wall_s: self.state.t_start.elapsed().as_secs_f64(),
                sim_s: self.state.net.sim_s(),
                comm_bytes: self.state.net.total_bytes(),
                grad_coord_evals: self.state.grad_coord_evals,
            };
            self.state.history.push(rec);
            Ok(Some(rec))
        } else {
            Ok(None)
        }
    }

    /// Distributed objective F(ω^t) = (1/N) Σ f(x_i·ω, y_i): partial-z
    /// reduce across feature blocks, loss sum per observation partition.
    /// Not charged to the cost model (the paper evaluates loss curves
    /// offline). The full-row index vectors are computed once per
    /// session and the w-block slices are refilled in place, so repeat
    /// evaluations allocate nothing.
    pub(super) fn objective_now(&mut self) -> Result<f64, PermanentLoss> {
        let Trainer { cfg, cluster, leader_engine, state, ws, .. } = self;
        if ws.eval_rows.len() != cluster.p {
            ws.eval_rows = (0..cluster.p)
                .map(|pi| Arc::new((0..cluster.layout.rows_in(pi) as u32).collect()))
                .collect();
        }
        ws.eval_w_blocks.resize_with(cluster.q, Default::default);
        for (qi, wb) in ws.eval_w_blocks.iter_mut().enumerate() {
            let dst = arc_mut(wb);
            dst.clear();
            dst.extend_from_slice(&state.w[cluster.layout.block_cols(qi)]);
        }
        let total = cluster.block_loss(
            &ws.eval_w_blocks,
            &ws.eval_rows,
            leader_engine.as_ref(),
            cfg.loss,
        )?;
        Ok(total / cluster.layout.n_total as f64)
    }
}
