//! Deterministic fault plans: *which worker dies when*.
//!
//! A [`FaultPlan`] is a seeded (or hand-written) schedule of worker
//! kills — `(outer iteration, phase, worker)` triples. The trainer arms
//! each due kill via [`crate::cluster::Cluster::inject_fault`]
//! immediately before the phase's sends, so the victim's mailbox sees
//! the kill FIFO-ordered ahead of the phase command and recovery is
//! bit-transparent (see the cluster module docs). Because recovery
//! changes no numbers, a plan can be applied to *any* run — the
//! `SODDA_FAULT_PLAN` environment variable turns every test of a CI
//! lane into a fault-recovery test without touching its assertions.
//!
//! Plans use a compact text syntax, one event per comma-separated
//! entry: `worker@iter:phase` (e.g. `"2@3:mu,0@5:inner"` kills worker
//! 2 in iteration 3's µ-phase and worker 0 in iteration 5's inner
//! loops). Phases are `mu` | `grad` | `inner`.

use std::fmt;
use std::str::FromStr;

use anyhow::{ensure, Context, Result};

use crate::metrics::FaultPhase;
use crate::util::rng::Rng;

/// One scheduled kill.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FaultEvent {
    /// outer iteration (1-based, like the trainer's `t`)
    pub iter: usize,
    pub phase: FaultPhase,
    /// linear worker id (`p·Q + q`)
    pub worker: usize,
}

impl fmt::Display for FaultEvent {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}@{}:{}", self.worker, self.iter, self.phase)
    }
}

impl FromStr for FaultEvent {
    type Err = anyhow::Error;
    fn from_str(s: &str) -> Result<FaultEvent> {
        let (worker, rest) =
            s.split_once('@').with_context(|| format!("fault event {s:?}: expected worker@iter:phase"))?;
        let (iter, phase) =
            rest.split_once(':').with_context(|| format!("fault event {s:?}: expected worker@iter:phase"))?;
        Ok(FaultEvent {
            worker: worker.trim().parse().with_context(|| format!("fault event {s:?}: bad worker id"))?,
            iter: iter.trim().parse().with_context(|| format!("fault event {s:?}: bad iteration"))?,
            phase: phase.trim().parse()?,
        })
    }
}

/// A deterministic schedule of worker kills, applied by the trainer.
///
/// Application is **lenient by design**: events addressing a worker
/// outside the run's grid or an iteration past the run's horizon are
/// ignored. That is what makes one environment-level plan (the
/// `rust-faults` CI lane's kill matrix) applicable across every test's
/// grid size — and since recovery is bit-exact, the ignored/applied
/// distinction never shows up in numbers.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct FaultPlan {
    events: Vec<FaultEvent>,
}

/// Environment variable holding a plan applied to every staged trainer
/// (unless overridden via [`crate::Trainer::set_fault_plan`]).
pub const FAULT_PLAN_ENV: &str = "SODDA_FAULT_PLAN";

impl FaultPlan {
    pub fn new(events: Vec<FaultEvent>) -> FaultPlan {
        FaultPlan { events }
    }

    /// `kills` seeded kills spread over `workers` workers, `iters`
    /// outer iterations and all three phases. Same seed → same plan,
    /// independent of any training RNG stream (the plan draws from its
    /// own generator, and recovery itself consumes no RNG).
    pub fn seeded(seed: u64, kills: usize, workers: usize, iters: usize) -> FaultPlan {
        let mut rng = Rng::seed_from_u64(seed).fork(0xFA);
        let events = (0..kills)
            .map(|_| FaultEvent {
                iter: 1 + rng.below(iters.max(1)),
                phase: match rng.below(3) {
                    0 => FaultPhase::Mu,
                    1 => FaultPhase::Grad,
                    _ => FaultPhase::Inner,
                },
                worker: rng.below(workers.max(1)),
            })
            .collect();
        FaultPlan { events }
    }

    /// Read the plan from `SODDA_FAULT_PLAN`. `Ok(None)` when unset or
    /// blank; a set-but-unparseable value is an error (a silently
    /// ignored typo would fake fault coverage).
    pub fn from_env() -> Result<Option<FaultPlan>> {
        match crate::util::env::read(FAULT_PLAN_ENV) {
            Some(v) if !v.trim().is_empty() => {
                let plan = v.parse().with_context(|| format!("{FAULT_PLAN_ENV}={v:?}"))?;
                Ok(Some(plan))
            }
            _ => Ok(None),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    pub fn events(&self) -> &[FaultEvent] {
        &self.events
    }

    /// Workers due to die in `(iter, phase)` on a `workers`-sized grid
    /// (deduplicated — killing a dead worker twice in one phase is one
    /// kill; out-of-range events are ignored, see the type docs).
    pub(crate) fn kills_for(&self, iter: usize, phase: FaultPhase, workers: usize) -> Vec<usize> {
        let mut due: Vec<usize> = self
            .events
            .iter()
            .filter(|e| e.iter == iter && e.phase == phase && e.worker < workers)
            .map(|e| e.worker)
            .collect();
        due.sort_unstable();
        due.dedup();
        due
    }
}

impl fmt::Display for FaultPlan {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        for (i, e) in self.events.iter().enumerate() {
            if i > 0 {
                f.write_str(",")?;
            }
            write!(f, "{e}")?;
        }
        Ok(())
    }
}

impl FromStr for FaultPlan {
    type Err = anyhow::Error;
    fn from_str(s: &str) -> Result<FaultPlan> {
        let mut events = Vec::new();
        for part in s.split(',') {
            let part = part.trim();
            ensure!(!part.is_empty(), "fault plan {s:?}: empty event");
            events.push(part.parse()?);
        }
        Ok(FaultPlan { events })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plan_parses_and_round_trips() {
        let plan: FaultPlan = "2@3:mu, 0@5:inner,1@1:grad".parse().unwrap();
        assert_eq!(plan.events().len(), 3);
        assert_eq!(
            plan.events()[0],
            FaultEvent { iter: 3, phase: FaultPhase::Mu, worker: 2 }
        );
        let back: FaultPlan = plan.to_string().parse().unwrap();
        assert_eq!(back, plan);
    }

    #[test]
    fn bad_plans_are_errors() {
        assert!("".parse::<FaultPlan>().is_err());
        assert!("2@3".parse::<FaultPlan>().is_err(), "missing phase");
        assert!("2:mu".parse::<FaultPlan>().is_err(), "missing iter");
        assert!("x@3:mu".parse::<FaultPlan>().is_err(), "bad worker");
        assert!("2@3:outer".parse::<FaultPlan>().is_err(), "bad phase");
        assert!("2@3:mu,,1@1:grad".parse::<FaultPlan>().is_err(), "empty entry");
    }

    #[test]
    fn kills_for_filters_dedups_and_ignores_out_of_range() {
        let plan: FaultPlan = "2@3:mu,2@3:mu,0@3:mu,9@3:mu,1@4:mu,0@3:grad".parse().unwrap();
        assert_eq!(plan.kills_for(3, FaultPhase::Mu, 4), vec![0, 2]);
        assert_eq!(plan.kills_for(3, FaultPhase::Grad, 4), vec![0]);
        assert_eq!(plan.kills_for(4, FaultPhase::Mu, 4), vec![1]);
        assert_eq!(plan.kills_for(3, FaultPhase::Inner, 4), Vec::<usize>::new());
        // worker 9 exists on a bigger grid
        assert_eq!(plan.kills_for(3, FaultPhase::Mu, 16), vec![0, 2, 9]);
    }

    #[test]
    fn seeded_plans_are_reproducible_and_in_range() {
        let a = FaultPlan::seeded(7, 5, 6, 20);
        let b = FaultPlan::seeded(7, 5, 6, 20);
        assert_eq!(a, b);
        assert_eq!(a.events().len(), 5);
        for e in a.events() {
            assert!(e.worker < 6 && e.iter >= 1 && e.iter <= 20, "{e}");
        }
        assert_ne!(FaultPlan::seeded(8, 5, 6, 20), a, "different seed, different plan");
    }
}
