//! Deterministic fault plans: *which worker dies when*.
//!
//! A [`FaultPlan`] is a seeded (or hand-written) schedule of worker
//! kills — `(outer iteration, phase, worker)` triples. The trainer arms
//! each due kill via [`crate::cluster::Cluster::inject_fault`]
//! immediately before the phase's sends, so the victim's mailbox sees
//! the kill FIFO-ordered ahead of the phase command and recovery is
//! bit-transparent (see the cluster module docs). Because recovery
//! changes no numbers, a plan can be applied to *any* run — the
//! `SODDA_FAULT_PLAN` environment variable turns every test of a CI
//! lane into a fault-recovery test without touching its assertions.
//!
//! Plans use a compact text syntax, one event per comma-separated
//! entry: `worker@iter:phase` (e.g. `"2@3:mu,0@5:inner"` kills worker
//! 2 in iteration 3's µ-phase and worker 0 in iteration 5's inner
//! loops). Phases are `mu` | `grad` | `inner`. A `!perm` suffix
//! (`"1@2:grad!perm"`) marks the loss *permanent*: the leader skips
//! the respawn path entirely and escalates, so the trainer's
//! re-shard-and-continue machinery is exercised deterministically.
//!
//! A `~slow:F` suffix (`"2@3:mu~slow:4"`, `F ≥ 1`) schedules a
//! **transient slowdown** instead of a kill: the worker survives, but
//! its modeled time for that one phase is multiplied by `F`. Slowdowns
//! drive the bounded-staleness quorum machinery (the straggler misses
//! the quorum cut and its reply is parked — see the README's
//! "Bounded-staleness aggregation" section); under a hard barrier they
//! simply stretch the phase's simulated makespan. A slowdown cannot be
//! permanent — `!perm` and `~slow` on one event is a parse error.

use std::fmt;
use std::str::FromStr;

use anyhow::{ensure, Context, Result};

use crate::metrics::FaultPhase;
use crate::util::rng::Rng;

/// One scheduled fault: a kill (`slow: None`) or a transient slowdown.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FaultEvent {
    /// outer iteration (1-based, like the trainer's `t`)
    pub iter: usize,
    pub phase: FaultPhase,
    /// linear worker id (`p·Q + q`)
    pub worker: usize,
    /// permanent loss: respawn is refused and the leader escalates
    /// (re-shard onto a shrunk grid) instead of recovering in place
    pub perm: bool,
    /// transient slowdown: the worker survives but its modeled time for
    /// this one phase is multiplied by the factor (`~slow:F`, `F ≥ 1`)
    pub slow: Option<f64>,
}

impl fmt::Display for FaultEvent {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}@{}:{}", self.worker, self.iter, self.phase)?;
        if self.perm {
            f.write_str("!perm")?;
        }
        if let Some(factor) = self.slow {
            write!(f, "~slow:{factor}")?;
        }
        Ok(())
    }
}

impl FromStr for FaultEvent {
    type Err = anyhow::Error;
    fn from_str(s: &str) -> Result<FaultEvent> {
        let (body, perm) = match s.split_once('!') {
            Some((body, flag)) => {
                ensure!(
                    flag.trim().eq_ignore_ascii_case("perm"),
                    "fault event {s:?}: unknown modifier {flag:?} (only !perm)"
                );
                (body, true)
            }
            None => (s, false),
        };
        let (body, slow) = match body.split_once('~') {
            Some((body, modifier)) => {
                let factor = modifier.trim().strip_prefix("slow:").with_context(|| {
                    format!("fault event {s:?}: unknown modifier {modifier:?} (only ~slow:F)")
                })?;
                let factor: f64 = factor
                    .trim()
                    .parse()
                    .with_context(|| format!("fault event {s:?}: bad slowdown factor"))?;
                ensure!(
                    factor.is_finite() && factor >= 1.0,
                    "fault event {s:?}: slowdown factor must be finite and >= 1"
                );
                (body, Some(factor))
            }
            None => (body, None),
        };
        ensure!(
            !(perm && slow.is_some()),
            "fault event {s:?}: a transient slowdown cannot be permanent"
        );
        let (worker, rest) = body
            .split_once('@')
            .with_context(|| format!("fault event {s:?}: expected worker@iter:phase[!perm]"))?;
        let (iter, phase) = rest
            .split_once(':')
            .with_context(|| format!("fault event {s:?}: expected worker@iter:phase[!perm]"))?;
        Ok(FaultEvent {
            worker: worker.trim().parse().with_context(|| format!("fault event {s:?}: bad worker id"))?,
            iter: iter.trim().parse().with_context(|| format!("fault event {s:?}: bad iteration"))?,
            phase: phase.trim().parse()?,
            perm,
            slow,
        })
    }
}

/// A deterministic schedule of worker kills, applied by the trainer.
///
/// Application is **lenient by design**: events addressing a worker
/// outside the run's grid or an iteration past the run's horizon are
/// ignored. That is what makes one environment-level plan (the
/// `rust-faults` CI lane's kill matrix) applicable across every test's
/// grid size — and since recovery is bit-exact, the ignored/applied
/// distinction never shows up in numbers.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct FaultPlan {
    events: Vec<FaultEvent>,
}

/// Environment variable holding a plan applied to every staged trainer
/// (unless overridden via [`crate::Trainer::set_fault_plan`]).
pub const FAULT_PLAN_ENV: &str = "SODDA_FAULT_PLAN";

impl FaultPlan {
    pub fn new(events: Vec<FaultEvent>) -> FaultPlan {
        FaultPlan { events }
    }

    /// `kills` seeded kills spread over `workers` workers, `iters`
    /// outer iterations and all three phases. Same seed → same plan,
    /// independent of any training RNG stream (the plan draws from its
    /// own generator, and recovery itself consumes no RNG).
    pub fn seeded(seed: u64, kills: usize, workers: usize, iters: usize) -> FaultPlan {
        let mut rng = Rng::seed_from_u64(seed).fork(0xFA);
        let events = (0..kills)
            .map(|_| FaultEvent {
                iter: 1 + rng.below(iters.max(1)),
                phase: match rng.below(3) {
                    0 => FaultPhase::Mu,
                    1 => FaultPhase::Grad,
                    _ => FaultPhase::Inner,
                },
                worker: rng.below(workers.max(1)),
                perm: false,
                slow: None,
            })
            .collect();
        FaultPlan { events }
    }

    /// Like [`FaultPlan::seeded`], but roughly one event in three is a
    /// permanent loss (`!perm`). Draws an extra RNG value per event, so
    /// it is deliberately *not* bit-compatible with `seeded` — use it
    /// where the escalation path itself is under test (e.g. the
    /// round-trip property test over the full syntax).
    pub fn seeded_with_perm(seed: u64, kills: usize, workers: usize, iters: usize) -> FaultPlan {
        let mut rng = Rng::seed_from_u64(seed).fork(0xFA);
        let events = (0..kills)
            .map(|_| FaultEvent {
                iter: 1 + rng.below(iters.max(1)),
                phase: match rng.below(3) {
                    0 => FaultPhase::Mu,
                    1 => FaultPhase::Grad,
                    _ => FaultPhase::Inner,
                },
                worker: rng.below(workers.max(1)),
                perm: rng.below(3) == 0,
                slow: None,
            })
            .collect();
        FaultPlan { events }
    }

    /// Read the plan from `SODDA_FAULT_PLAN`. `Ok(None)` when unset or
    /// blank; a set-but-unparseable value is an error (a silently
    /// ignored typo would fake fault coverage).
    pub fn from_env() -> Result<Option<FaultPlan>> {
        match crate::util::env::read(FAULT_PLAN_ENV) {
            Some(v) if !v.trim().is_empty() => {
                let plan = v.parse().with_context(|| format!("{FAULT_PLAN_ENV}={v:?}"))?;
                Ok(Some(plan))
            }
            _ => Ok(None),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    pub fn events(&self) -> &[FaultEvent] {
        &self.events
    }

    /// Workers due to die in `(iter, phase)` on a `workers`-sized grid,
    /// each with its permanence flag (deduplicated — killing a dead
    /// worker twice in one phase is one kill, and a permanent event
    /// absorbs a transient one on the same worker; out-of-range events
    /// are ignored, see the type docs). Slowdown events are not kills
    /// and never appear here — see [`FaultPlan::slowdowns_for`].
    pub(crate) fn kills_for(
        &self,
        iter: usize,
        phase: FaultPhase,
        workers: usize,
    ) -> Vec<(usize, bool)> {
        let mut due: Vec<(usize, bool)> = self
            .events
            .iter()
            .filter(|e| {
                e.iter == iter && e.phase == phase && e.worker < workers && e.slow.is_none()
            })
            .map(|e| (e.worker, e.perm))
            .collect();
        // sort puts (w, false) before (w, true); keep the perm entry
        due.sort_unstable();
        due.reverse();
        due.dedup_by_key(|&mut (w, _)| w);
        due.reverse();
        due
    }

    /// Transient slowdowns (`~slow:F`) armed for `(iter, phase)` on a
    /// `workers`-sized grid: `(worker, factor)` pairs sorted by worker
    /// id, deduplicated to the **largest** factor per worker (two
    /// slowdowns on one worker in one phase don't stack — the worst
    /// one governs). Out-of-range events are ignored, like kills.
    pub(crate) fn slowdowns_for(
        &self,
        iter: usize,
        phase: FaultPhase,
        workers: usize,
    ) -> Vec<(usize, f64)> {
        let mut due: Vec<(usize, f64)> = self
            .events
            .iter()
            .filter(|e| e.iter == iter && e.phase == phase && e.worker < workers)
            .filter_map(|e| e.slow.map(|f| (e.worker, f)))
            .collect();
        due.sort_unstable_by(|a, b| a.0.cmp(&b.0).then(b.1.total_cmp(&a.1)));
        due.dedup_by_key(|&mut (w, _)| w);
        due
    }

    /// Drop every event scheduled at or before `iter` — called after a
    /// re-shard so already-consumed events (whose worker ids addressed
    /// the *old* grid) can't re-arm against the shrunk one when the
    /// interrupted iteration is re-run.
    pub(crate) fn prune_through(&mut self, iter: usize) {
        self.events.retain(|e| e.iter > iter);
    }
}

impl fmt::Display for FaultPlan {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        for (i, e) in self.events.iter().enumerate() {
            if i > 0 {
                f.write_str(",")?;
            }
            write!(f, "{e}")?;
        }
        Ok(())
    }
}

impl FromStr for FaultPlan {
    type Err = anyhow::Error;
    fn from_str(s: &str) -> Result<FaultPlan> {
        let mut events = Vec::new();
        for part in s.split(',') {
            let part = part.trim();
            ensure!(!part.is_empty(), "fault plan {s:?}: empty event");
            events.push(part.parse()?);
        }
        Ok(FaultPlan { events })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plan_parses_and_round_trips() {
        let plan: FaultPlan = "2@3:mu, 0@5:inner,1@1:grad".parse().unwrap();
        assert_eq!(plan.events().len(), 3);
        assert_eq!(
            plan.events()[0],
            FaultEvent { iter: 3, phase: FaultPhase::Mu, worker: 2, perm: false, slow: None }
        );
        let back: FaultPlan = plan.to_string().parse().unwrap();
        assert_eq!(back, plan);
    }

    #[test]
    fn perm_suffix_parses_and_displays() {
        let plan: FaultPlan = "1@2:grad!perm, 0@5:mu".parse().unwrap();
        assert_eq!(
            plan.events()[0],
            FaultEvent { iter: 2, phase: FaultPhase::Grad, worker: 1, perm: true, slow: None }
        );
        assert!(!plan.events()[1].perm);
        assert_eq!(plan.to_string(), "1@2:grad!perm,0@5:mu");
        // lenient on whitespace and case around the modifier
        let e: FaultEvent = " 3@7:inner ! PERM ".trim().parse().unwrap();
        assert!(e.perm);
        assert!("1@2:grad!forever".parse::<FaultEvent>().is_err(), "unknown modifier");
        assert!("1@2:grad!".parse::<FaultEvent>().is_err(), "empty modifier");
    }

    #[test]
    fn bad_plans_are_errors() {
        assert!("".parse::<FaultPlan>().is_err());
        assert!("2@3".parse::<FaultPlan>().is_err(), "missing phase");
        assert!("2:mu".parse::<FaultPlan>().is_err(), "missing iter");
        assert!("x@3:mu".parse::<FaultPlan>().is_err(), "bad worker");
        assert!("2@3:outer".parse::<FaultPlan>().is_err(), "bad phase");
        assert!("2@3:mu,,1@1:grad".parse::<FaultPlan>().is_err(), "empty entry");
    }

    #[test]
    fn kills_for_filters_dedups_and_ignores_out_of_range() {
        let plan: FaultPlan = "2@3:mu,2@3:mu,0@3:mu,9@3:mu,1@4:mu,0@3:grad".parse().unwrap();
        assert_eq!(plan.kills_for(3, FaultPhase::Mu, 4), vec![(0, false), (2, false)]);
        assert_eq!(plan.kills_for(3, FaultPhase::Grad, 4), vec![(0, false)]);
        assert_eq!(plan.kills_for(4, FaultPhase::Mu, 4), vec![(1, false)]);
        assert_eq!(plan.kills_for(3, FaultPhase::Inner, 4), Vec::<(usize, bool)>::new());
        // worker 9 exists on a bigger grid
        assert_eq!(
            plan.kills_for(3, FaultPhase::Mu, 16),
            vec![(0, false), (2, false), (9, false)]
        );
    }

    #[test]
    fn perm_event_absorbs_transient_duplicate() {
        let plan: FaultPlan = "2@3:mu,2@3:mu!perm,0@3:mu".parse().unwrap();
        assert_eq!(plan.kills_for(3, FaultPhase::Mu, 4), vec![(0, false), (2, true)]);
        let plan: FaultPlan = "2@3:mu!perm,2@3:mu".parse().unwrap();
        assert_eq!(plan.kills_for(3, FaultPhase::Mu, 4), vec![(2, true)]);
    }

    #[test]
    fn prune_through_drops_consumed_iterations() {
        let mut plan: FaultPlan = "2@3:mu,0@5:inner,1@1:grad!perm".parse().unwrap();
        plan.prune_through(3);
        assert_eq!(plan.events().len(), 1);
        assert_eq!(plan.events()[0].iter, 5);
        plan.prune_through(5);
        assert!(plan.is_empty());
    }

    #[test]
    fn seeded_plans_are_reproducible_and_in_range() {
        let a = FaultPlan::seeded(7, 5, 6, 20);
        let b = FaultPlan::seeded(7, 5, 6, 20);
        assert_eq!(a, b);
        assert_eq!(a.events().len(), 5);
        for e in a.events() {
            assert!(e.worker < 6 && e.iter >= 1 && e.iter <= 20, "{e}");
            assert!(!e.perm, "plain seeded plans stay transient");
        }
        assert_ne!(FaultPlan::seeded(8, 5, 6, 20), a, "different seed, different plan");
    }

    #[test]
    fn slow_suffix_parses_and_round_trips() {
        let plan: FaultPlan = "2@3:mu~slow:4, 0@5:grad~slow:1.5,1@1:inner".parse().unwrap();
        assert_eq!(
            plan.events()[0],
            FaultEvent { iter: 3, phase: FaultPhase::Mu, worker: 2, perm: false, slow: Some(4.0) }
        );
        assert_eq!(plan.events()[1].slow, Some(1.5));
        assert_eq!(plan.events()[2].slow, None);
        assert_eq!(plan.to_string(), "2@3:mu~slow:4,0@5:grad~slow:1.5,1@1:inner");
        let back: FaultPlan = plan.to_string().parse().unwrap();
        assert_eq!(back, plan);
        assert!("2@3:mu~slow:0.5".parse::<FaultEvent>().is_err(), "factor below 1");
        assert!("2@3:mu~slow:inf".parse::<FaultEvent>().is_err(), "non-finite factor");
        assert!("2@3:mu~slow:".parse::<FaultEvent>().is_err(), "missing factor");
        assert!("2@3:mu~fast:2".parse::<FaultEvent>().is_err(), "unknown modifier");
        assert!("2@3:mu~slow:4!perm".parse::<FaultEvent>().is_err(), "slowdown cannot be perm");
    }

    #[test]
    fn slowdowns_for_filters_dedups_and_keeps_the_max() {
        let plan: FaultPlan =
            "2@3:mu~slow:2,2@3:mu~slow:4,0@3:mu~slow:1.5,9@3:mu~slow:8,2@3:mu,1@4:grad~slow:3"
                .parse()
                .unwrap();
        assert_eq!(plan.slowdowns_for(3, FaultPhase::Mu, 4), vec![(0, 1.5), (2, 4.0)]);
        assert_eq!(plan.slowdowns_for(4, FaultPhase::Grad, 4), vec![(1, 3.0)]);
        assert_eq!(plan.slowdowns_for(3, FaultPhase::Grad, 4), Vec::<(usize, f64)>::new());
        // worker 9 exists on a bigger grid
        assert_eq!(plan.slowdowns_for(3, FaultPhase::Mu, 16)[2], (9, 8.0));
        // the kill on worker 2 is independent of its slowdowns, and
        // slowdown events never surface as kills
        assert_eq!(plan.kills_for(3, FaultPhase::Mu, 4), vec![(2, false)]);
        assert_eq!(plan.kills_for(4, FaultPhase::Grad, 4), Vec::<(usize, bool)>::new());
    }

    #[test]
    fn display_from_str_round_trips_over_slowdown_plans() {
        // property test over the extended syntax: every third event of a
        // seeded plan becomes a slowdown with a varied factor
        for seed in 0..64u64 {
            let mut plan = FaultPlan::seeded(seed, 6, 8, 12);
            for (i, e) in plan.events.iter_mut().enumerate() {
                if i % 3 == 0 {
                    e.slow = Some(1.0 + i as f64 * 0.75 + seed as f64 * 0.125);
                }
            }
            let text = plan.to_string();
            let back: FaultPlan = text.parse().unwrap_or_else(|e| panic!("{text:?}: {e}"));
            assert_eq!(back, plan, "round trip failed for {text:?}");
        }
    }

    #[test]
    fn display_from_str_round_trips_over_seeded_plans() {
        // property test over the full syntax, including !perm events
        let mut saw_perm = false;
        let mut saw_transient = false;
        for seed in 0..64u64 {
            let plan = FaultPlan::seeded_with_perm(seed, 6, 8, 12);
            saw_perm |= plan.events().iter().any(|e| e.perm);
            saw_transient |= plan.events().iter().any(|e| !e.perm);
            let text = plan.to_string();
            let back: FaultPlan = text.parse().unwrap_or_else(|e| panic!("{text:?}: {e}"));
            assert_eq!(back, plan, "round trip failed for {text:?}");
        }
        assert!(saw_perm && saw_transient, "the sweep must cover both event kinds");
    }
}
