//! The sanctioned environment-variable surface.
//!
//! Every knob the crate reads from the process environment —
//! `SODDA_EXECUTOR`, `SODDA_FAULT_PLAN`, `SODDA_ARTIFACTS`,
//! `BENCH_QUICK`, `BENCH_OUT` — goes through [`read`]. The `raw_env`
//! lint in `xtask` rejects `std::env::var` / `set_var` / `remove_var`
//! anywhere else in the tree, which is what makes env-dependent tests
//! safe to run concurrently: every *mutation* goes through this module
//! and serializes on one process-wide lock, so two tests can't
//! interleave a set/restore pair and leak a knob into each other.
//!
//! ## Locking discipline
//!
//! - [`read`] takes **no** lock. Tests legitimately hold the lock
//!   across a whole stage-and-train scope (set `SODDA_FAULT_PLAN`,
//!   build a `Trainer` that reads it, assert, restore); if reads
//!   locked too, that pattern would self-deadlock. A read is a single
//!   `std::env::var` call — the OS-level race this leaves open (a read
//!   concurrent with a mutation elsewhere) existed under the old
//!   ad-hoc mutexes too and is exactly what holding [`lock`] or a
//!   [`ScopedEnv`] for the duration of the sensitive scope prevents.
//! - [`set`] / [`unset`] acquire the lock per call. Never call them
//!   while already holding [`lock`] or a [`ScopedEnv`] — the lock is
//!   not reentrant. Inside a scope, use [`ScopedEnv::with`] instead.

use std::sync::{Mutex, MutexGuard};

/// One lock for the whole process. Not reentrant.
static ENV_LOCK: Mutex<()> = Mutex::new(());

/// Hold the env lock for a scope that *reads* a knob some other test
/// might mutate (e.g. staging a `Trainer` while the fault-plan suite
/// runs). A panic in a previous holder is fine — the guard's state is
/// `()`, so a poisoned lock is recovered, not propagated.
pub fn lock() -> MutexGuard<'static, ()> {
    ENV_LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

/// Read a knob. `None` when unset or not valid UTF-8. Lock-free — see
/// the module docs for why.
pub fn read(name: &str) -> Option<String> {
    std::env::var(name).ok()
}

/// Set a knob, serialized against every other mutation. Must not be
/// called while holding [`lock`] or a [`ScopedEnv`].
pub fn set(name: &str, value: &str) {
    let _g = lock();
    std::env::set_var(name, value);
}

/// Remove a knob, serialized against every other mutation. Must not be
/// called while holding [`lock`] or a [`ScopedEnv`].
pub fn unset(name: &str) {
    let _g = lock();
    std::env::remove_var(name);
}

/// RAII env scope for tests: holds the process lock, applies
/// overrides, and restores every prior value (in reverse order, even
/// on panic) when dropped. Replaces the per-file save/restore mutexes
/// the executor and fault suites used to carry.
///
/// ```
/// let _env = sodda::util::env::ScopedEnv::new().with("BENCH_QUICK", Some("1"));
/// assert_eq!(sodda::util::env::read("BENCH_QUICK").as_deref(), Some("1"));
/// ```
pub struct ScopedEnv {
    saved: Vec<(String, Option<String>)>,
    _guard: MutexGuard<'static, ()>,
}

impl ScopedEnv {
    #[allow(clippy::new_without_default)] // a lock acquisition is not a Default
    pub fn new() -> ScopedEnv {
        ScopedEnv { saved: Vec::new(), _guard: lock() }
    }

    /// Override `name` (`Some` sets, `None` unsets), remembering the
    /// prior value for restore-on-drop.
    pub fn with(mut self, name: &str, value: Option<&str>) -> ScopedEnv {
        self.saved.push((name.to_string(), std::env::var(name).ok()));
        match value {
            Some(v) => std::env::set_var(name, v),
            None => std::env::remove_var(name),
        }
        self
    }
}

impl Drop for ScopedEnv {
    fn drop(&mut self) {
        for (name, prior) in self.saved.drain(..).rev() {
            match prior {
                Some(v) => std::env::set_var(name, v),
                None => std::env::remove_var(name),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scoped_env_sets_unsets_and_restores() {
        // distinct knob per test: tests in this module run concurrently
        // and only synchronize while actually holding the lock
        const KNOB: &str = "SODDA_ENV_SELFTEST_RESTORE";
        set(KNOB, "outer");
        {
            let _env = ScopedEnv::new().with(KNOB, Some("inner")).with(KNOB, None);
            assert_eq!(read(KNOB), None, "latest override wins");
        }
        assert_eq!(read(KNOB).as_deref(), Some("outer"), "restored in reverse order");
        unset(KNOB);
        assert_eq!(read(KNOB), None);
    }

    #[test]
    fn scoped_env_restores_on_panic() {
        const KNOB: &str = "SODDA_ENV_SELFTEST_PANIC";
        // The guard is dropped during unwind, so the knob never leaks
        // into other tests even when the body dies.
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let _env = ScopedEnv::new().with(KNOB, Some("doomed"));
            panic!("boom");
        }));
        assert!(r.is_err());
        assert_eq!(read(KNOB), None);
    }
}
