//! Counting global allocator — the measurement side of the
//! zero-allocation steady state.
//!
//! [`CountingAlloc`] wraps [`System`] and counts allocation *events*
//! (`alloc`, `alloc_zeroed`, `realloc`; frees are not events) in a
//! relaxed atomic. The crate never installs it; test and bench binaries
//! that want to measure opt in:
//!
//! ```ignore
//! #[global_allocator]
//! static ALLOC: sodda::util::alloc::CountingAlloc = sodda::util::alloc::CountingAlloc::new();
//!
//! let before = ALLOC.allocations();
//! run_steady_state_work();
//! let allocs = ALLOC.allocations() - before;
//! ```
//!
//! The counter is process-global, so it sees worker-thread allocations
//! too — exactly what the steady-state budget wants to bound. Consumers:
//! `tests/alloc_regression.rs` (per-outer-iteration budget + 10×
//! pooled-vs-fresh assertion) and `benches/full_iteration.rs` (the
//! `allocs_per_iter` column gated by `repro bench-gate`; see
//! [`crate::util::bench::Bench::set_alloc_counter`]).

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

/// A [`System`]-backed allocator that counts allocation events.
pub struct CountingAlloc {
    allocs: AtomicU64,
}

impl CountingAlloc {
    pub const fn new() -> Self {
        CountingAlloc { allocs: AtomicU64::new(0) }
    }

    /// Allocation events since process start (relaxed; exact once the
    /// threads of interest have quiesced or are the only ones running).
    pub fn allocations(&self) -> u64 {
        self.allocs.load(Ordering::Relaxed)
    }
}

impl Default for CountingAlloc {
    fn default() -> Self {
        Self::new()
    }
}

// SAFETY: every method delegates to `System` with the caller's layout
// and pointer passed through unchanged, so `System`'s contract *is*
// this type's contract: the caller owes us a valid (layout, ptr)
// pairing and we owe them whatever `System` returns. The only added
// behaviour is a relaxed atomic increment, which allocates nothing,
// never unwinds, and has no memory effects beyond its own counter —
// it cannot invalidate the layout/pointer invariants in either
// direction. (Relaxed is enough: readers only want an event count,
// not ordering against the allocations themselves.)
unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        self.allocs.fetch_add(1, Ordering::Relaxed);
        // SAFETY: caller guarantees `layout` has non-zero size
        // (GlobalAlloc's precondition), which we forward verbatim.
        unsafe { System.alloc(layout) }
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        self.allocs.fetch_add(1, Ordering::Relaxed);
        // SAFETY: as `alloc` — layout forwarded unchanged.
        unsafe { System.alloc_zeroed(layout) }
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        // growing (or shrinking) a buffer is an allocation event: the
        // pooled paths must not be doing it in steady state either
        self.allocs.fetch_add(1, Ordering::Relaxed);
        // SAFETY: caller guarantees `ptr` came from this allocator with
        // `layout`, and `new_size` is non-zero and rounds into a valid
        // layout; since we allocate via `System`, the block is legal to
        // hand back to `System.realloc`.
        unsafe { System.realloc(ptr, layout, new_size) }
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        // SAFETY: caller guarantees `ptr` was allocated by this
        // allocator (hence by `System`) with this exact `layout`.
        unsafe { System.dealloc(ptr, layout) }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Drives every `GlobalAlloc` method through raw pointers the way a
    /// collection would. Runs under Miri in CI (`rust-miri` lane) with
    /// strict provenance, which is the point: the test itself is the
    /// unsafe-audit fixture for the delegation above.
    #[test]
    fn raw_alloc_roundtrip_counts_events_and_preserves_contents() {
        let a = CountingAlloc::new();
        let before = a.allocations();

        let layout = Layout::from_size_align(64, 8).unwrap();
        // SAFETY: `layout` has non-zero size; every pointer below is
        // used within the size it was allocated (or reallocated) with
        // and freed exactly once with its current layout.
        unsafe {
            let p = a.alloc(layout);
            assert!(!p.is_null());
            std::ptr::write_bytes(p, 0xAB, 64);
            assert_eq!(*p, 0xAB);
            assert_eq!(*p.add(63), 0xAB);

            let q = a.realloc(p, layout, 128);
            assert!(!q.is_null());
            // realloc preserves the old contents up to min(old, new)
            assert_eq!(*q, 0xAB);
            assert_eq!(*q.add(63), 0xAB);
            a.dealloc(q, Layout::from_size_align(128, 8).unwrap());

            let z = a.alloc_zeroed(layout);
            assert!(!z.is_null());
            assert_eq!(*z, 0);
            assert_eq!(*z.add(63), 0);
            a.dealloc(z, layout);
        }

        // alloc + realloc + alloc_zeroed are events; the two frees are
        // not. Other live threads could inflate this, so assert >=
        // under the normal harness; single-threaded Miri sees exactly 3.
        assert!(a.allocations() - before >= 3);
        #[cfg(miri)]
        assert_eq!(a.allocations() - before, 3);
    }
}
