//! Counting global allocator — the measurement side of the
//! zero-allocation steady state.
//!
//! [`CountingAlloc`] wraps [`System`] and counts allocation *events*
//! (`alloc`, `alloc_zeroed`, `realloc`; frees are not events) in a
//! relaxed atomic. The crate never installs it; test and bench binaries
//! that want to measure opt in:
//!
//! ```ignore
//! #[global_allocator]
//! static ALLOC: sodda::util::alloc::CountingAlloc = sodda::util::alloc::CountingAlloc::new();
//!
//! let before = ALLOC.allocations();
//! run_steady_state_work();
//! let allocs = ALLOC.allocations() - before;
//! ```
//!
//! The counter is process-global, so it sees worker-thread allocations
//! too — exactly what the steady-state budget wants to bound. Consumers:
//! `tests/alloc_regression.rs` (per-outer-iteration budget + 10×
//! pooled-vs-fresh assertion) and `benches/full_iteration.rs` (the
//! `allocs_per_iter` column gated by `repro bench-gate`; see
//! [`crate::util::bench::Bench::set_alloc_counter`]).

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

/// A [`System`]-backed allocator that counts allocation events.
pub struct CountingAlloc {
    allocs: AtomicU64,
}

impl CountingAlloc {
    pub const fn new() -> Self {
        CountingAlloc { allocs: AtomicU64::new(0) }
    }

    /// Allocation events since process start (relaxed; exact once the
    /// threads of interest have quiesced or are the only ones running).
    pub fn allocations(&self) -> u64 {
        self.allocs.load(Ordering::Relaxed)
    }
}

impl Default for CountingAlloc {
    fn default() -> Self {
        Self::new()
    }
}

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        self.allocs.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        self.allocs.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        // growing (or shrinking) a buffer is an allocation event: the
        // pooled paths must not be doing it in steady state either
        self.allocs.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}
