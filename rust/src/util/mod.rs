//! In-tree utility substrates.
//!
//! The build is fully offline (only `xla` + `anyhow` are vendored), so the
//! small generic pieces a crates.io project would pull in are implemented
//! here, each with its own tests:
//!
//! * [`rng`] — deterministic xoshiro256** PRNG + sampling helpers
//! * [`json`] — minimal JSON parser/emitter (manifest, metrics, configs)
//! * [`cli`] — flag parser for the `repro` binary and examples
//! * [`bench`] — micro-benchmark harness (criterion-style reporting)
//! * [`testing`] — assert helpers + a tiny property-test driver

pub mod bench;
pub mod cli;
pub mod json;
pub mod rng;
pub mod testing;
