//! In-tree utility substrates.
//!
//! The build is fully offline (only `xla` + `anyhow` are vendored), so the
//! small generic pieces a crates.io project would pull in are implemented
//! here, each with its own tests:
//!
//! * [`rng`] — deterministic xoshiro256** PRNG + sampling helpers
//! * [`json`] — minimal JSON parser/emitter (manifest, metrics, configs)
//! * [`cli`] — flag parser for the `repro` binary and examples
//! * [`bench`] — micro-benchmark harness (criterion-style reporting)
//! * [`alloc`] — counting global allocator for alloc-regression gates
//! * [`env`] — the sanctioned env-var surface + process-wide test lock
//! * [`testing`] — assert helpers + a tiny property-test driver

pub mod alloc;
pub mod bench;
pub mod cli;
pub mod env;
pub mod json;
pub mod rng;
pub mod testing;

use std::sync::Arc;

/// Mutable access to a recycled [`Arc`] buffer: reuses the allocation
/// when the caller holds the only strong reference, swaps in a fresh
/// default otherwise (never blocks, never clones the payload).
///
/// The pooled training paths share per-iteration buffers with worker
/// threads via `Arc`; each phase is a strict send-all/receive-all
/// barrier, so by the time the leader refills a buffer for the next
/// iteration every worker clone has been dropped and `Arc::get_mut`
/// succeeds — the `Arc::new` arm is a cold-start/safety fallback, not a
/// steady-state path.
pub fn arc_mut<T: Default>(slot: &mut Arc<T>) -> &mut T {
    if Arc::get_mut(slot).is_none() {
        *slot = Arc::new(T::default());
    }
    Arc::get_mut(slot).expect("freshly created Arc is unique")
}

#[cfg(test)]
mod arc_tests {
    use super::*;

    #[test]
    fn arc_mut_reuses_unique_and_replaces_shared() {
        let mut slot: Arc<Vec<u32>> = Arc::new(vec![1, 2, 3]);
        let ptr = Arc::as_ptr(&slot);
        arc_mut(&mut slot).push(4);
        assert_eq!(*slot, vec![1, 2, 3, 4]);
        assert_eq!(Arc::as_ptr(&slot), ptr, "unique Arc must be reused in place");

        let held = Arc::clone(&slot);
        arc_mut(&mut slot).clear();
        assert!(slot.is_empty(), "shared slot must be replaced, not mutated");
        assert_eq!(*held, vec![1, 2, 3, 4], "the old clone is untouched");
    }
}
