//! Deterministic PRNG (xoshiro256** seeded via splitmix64) and the
//! sampling primitives Algorithm 1 needs: uniform ranges, Bernoulli,
//! permutations (`π_q`), and without-replacement subsets (`B^t`, `C^t`,
//! `D^t`).
//!
//! Determinism contract: a run is fully reproducible from
//! `ExperimentConfig::seed`; every stochastic component draws from a
//! stream forked with a distinct tag so adding a consumer never perturbs
//! the others (the Table 2 seed-variation experiment depends on this).

/// xoshiro256** — 64-bit, fast, passes BigCrush; plenty for experiment
/// reproducibility (no crypto use).
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    pub fn seed_from_u64(seed: u64) -> Self {
        let mut sm = seed;
        let s = [splitmix64(&mut sm), splitmix64(&mut sm), splitmix64(&mut sm), splitmix64(&mut sm)];
        Rng { s }
    }

    /// Snapshot the raw xoshiro256** registers (checkpointing). Feeding
    /// them back through [`Rng::from_state`] resumes the stream exactly
    /// where it left off.
    pub fn state(&self) -> [u64; 4] {
        self.s
    }

    /// Rebuild a generator from a [`Rng::state`] snapshot.
    pub fn from_state(s: [u64; 4]) -> Rng {
        Rng { s }
    }

    /// Derive an independent stream for a named consumer.
    pub fn fork(&self, tag: u64) -> Rng {
        // hash the current state with the tag through splitmix
        let mut sm = self.s[0] ^ self.s[1].rotate_left(17) ^ tag.wrapping_mul(0x9E3779B97F4A7C15);
        let s = [splitmix64(&mut sm), splitmix64(&mut sm), splitmix64(&mut sm), splitmix64(&mut sm)];
        Rng { s }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, n)` (Lemire rejection-free-enough via widening mul).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0, "below(0)");
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn unit_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in the half-open `[lo, hi)`.
    #[inline]
    pub fn f32_range(&mut self, lo: f32, hi: f32) -> f32 {
        f32_in_range(self.unit_f64(), lo, hi)
    }

    /// Bernoulli(p).
    #[inline]
    pub fn bool_with(&mut self, p: f64) -> bool {
        self.unit_f64() < p
    }

    /// Random permutation of `0..n` (Fisher-Yates) — the paper's `π_q`.
    pub fn permutation(&mut self, n: usize) -> Vec<u32> {
        let mut v = Vec::new();
        self.permutation_into(n, &mut v);
        v
    }

    /// In-place [`Self::permutation`]: identical draws, identical result,
    /// written into a caller-provided (recycled) buffer.
    pub fn permutation_into(&mut self, n: usize, v: &mut Vec<u32>) {
        v.clear();
        v.extend(0..n as u32);
        for i in (1..n).rev() {
            v.swap(i, self.below(i + 1));
        }
    }

    /// `k` distinct values from `0..n`, sorted — the paper's
    /// "elements randomly sampled without replacement" (steps 5-7).
    pub fn sample_without_replacement(&mut self, n: usize, k: usize) -> Vec<u32> {
        let (mut out, mut scratch) = (Vec::new(), Vec::new());
        self.sample_without_replacement_into(n, k, &mut out, &mut scratch);
        out
    }

    /// In-place [`Self::sample_without_replacement`]: identical draws and
    /// result; `scratch` holds the partial-Fisher-Yates index array so
    /// the steady state allocates nothing.
    pub fn sample_without_replacement_into(
        &mut self,
        n: usize,
        k: usize,
        out: &mut Vec<u32>,
        scratch: &mut Vec<u32>,
    ) {
        assert!(k <= n, "sample {k} from {n}");
        if k == n {
            out.clear();
            out.extend(0..n as u32);
            return;
        }
        // partial Fisher-Yates over an index array
        scratch.clear();
        scratch.extend(0..n as u32);
        for i in 0..k {
            let j = i + self.below(n - i);
            scratch.swap(i, j);
        }
        out.clear();
        out.extend_from_slice(&scratch[..k]);
        out.sort_unstable();
    }

    /// `k` values from `0..n` **with** replacement (inner-loop row picks,
    /// step 15's `randomly pick j ∈ {1..n}`).
    pub fn sample_with_replacement(&mut self, n: usize, k: usize) -> Vec<u32> {
        let mut out = Vec::new();
        self.sample_with_replacement_into(n, k, &mut out);
        out
    }

    /// In-place [`Self::sample_with_replacement`] (identical draws).
    pub fn sample_with_replacement_into(&mut self, n: usize, k: usize, out: &mut Vec<u32>) {
        out.clear();
        out.extend((0..k).map(|_| self.below(n) as u32));
    }
}

/// Map a unit draw onto `[lo, hi)`. Although `unit_f64()` is strictly
/// below 1, `u as f32` rounds up to exactly 1.0 for any `u ≥ 1 − 2⁻²⁵`,
/// and the affine map itself can round onto `hi` even for `u < 1` —
/// both would leak `hi` out of the half-open interval, so the result is
/// clamped to the largest representable value below `hi`.
#[inline]
fn f32_in_range(u: f64, lo: f32, hi: f32) -> f32 {
    let v = lo + (hi - lo) * u as f32;
    if v >= hi && lo < hi {
        next_below(hi)
    } else {
        v
    }
}

/// Largest f32 strictly below `x` (finite, non-NaN `x` only — callers
/// pass literal interval bounds).
fn next_below(x: f32) -> f32 {
    if x > 0.0 {
        f32::from_bits(x.to_bits() - 1)
    } else if x < 0.0 {
        f32::from_bits(x.to_bits() + 1)
    } else {
        // below ±0.0 sits the smallest-magnitude negative subnormal
        -f32::from_bits(1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let mut a = Rng::seed_from_u64(42);
        let mut b = Rng::seed_from_u64(42);
        let mut c = Rng::seed_from_u64(43);
        let va: Vec<u64> = (0..10).map(|_| a.next_u64()).collect();
        let vb: Vec<u64> = (0..10).map(|_| b.next_u64()).collect();
        let vc: Vec<u64> = (0..10).map(|_| c.next_u64()).collect();
        assert_eq!(va, vb);
        assert_ne!(va, vc);
    }

    #[test]
    fn state_roundtrip_resumes_the_stream() {
        let mut a = Rng::seed_from_u64(99);
        for _ in 0..17 {
            a.next_u64();
        }
        let snap = a.state();
        let tail: Vec<u64> = (0..8).map(|_| a.next_u64()).collect();
        let mut b = Rng::from_state(snap);
        let replay: Vec<u64> = (0..8).map(|_| b.next_u64()).collect();
        assert_eq!(tail, replay);
    }

    #[test]
    fn forks_are_independent() {
        let root = Rng::seed_from_u64(1);
        let mut f1 = root.fork(1);
        let mut f2 = root.fork(2);
        assert_ne!(f1.next_u64(), f2.next_u64());
        // forking again with same tag reproduces
        let mut f1b = root.fork(1);
        let mut f1c = root.fork(1);
        assert_eq!(f1b.next_u64(), f1c.next_u64());
    }

    #[test]
    fn below_is_in_range_and_roughly_uniform() {
        let mut rng = Rng::seed_from_u64(7);
        let mut counts = [0usize; 10];
        for _ in 0..100_000 {
            counts[rng.below(10)] += 1;
        }
        for &c in &counts {
            assert!((8_000..12_000).contains(&c), "bucket count {c}");
        }
    }

    #[test]
    fn unit_f64_bounds_and_mean() {
        let mut rng = Rng::seed_from_u64(3);
        let mut sum = 0.0;
        for _ in 0..100_000 {
            let v = rng.unit_f64();
            assert!((0.0..1.0).contains(&v));
            sum += v;
        }
        let mean = sum / 100_000.0;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn permutation_is_bijection() {
        let mut rng = Rng::seed_from_u64(5);
        for n in [1usize, 2, 7, 100] {
            let p = rng.permutation(n);
            let mut seen = vec![false; n];
            for &v in &p {
                assert!(!seen[v as usize]);
                seen[v as usize] = true;
            }
        }
    }

    #[test]
    fn wor_sample_distinct_sorted_in_range() {
        let mut rng = Rng::seed_from_u64(11);
        for (n, k) in [(10usize, 3usize), (100, 100), (1000, 1), (50, 49)] {
            let s = rng.sample_without_replacement(n, k);
            assert_eq!(s.len(), k);
            assert!(s.windows(2).all(|w| w[0] < w[1]), "sorted+distinct");
            assert!(s.iter().all(|&v| (v as usize) < n));
        }
    }

    #[test]
    fn wor_full_is_identity() {
        let mut rng = Rng::seed_from_u64(2);
        assert_eq!(rng.sample_without_replacement(5, 5), vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn wor_is_unbiasedish() {
        // each element of 0..20 should appear in a k=10 sample about half
        // the time
        let mut rng = Rng::seed_from_u64(13);
        let mut counts = [0usize; 20];
        for _ in 0..20_000 {
            for v in rng.sample_without_replacement(20, 10) {
                counts[v as usize] += 1;
            }
        }
        for &c in &counts {
            assert!((9_000..11_000).contains(&c), "count {c}");
        }
    }

    #[test]
    fn f32_range_is_half_open_at_the_boundary() {
        // a unit draw this close to 1 rounds to exactly 1.0f32 — the old
        // `lo + (hi-lo) * u as f32` returned exactly `hi`
        let u = 1.0 - 2f64.powi(-60);
        assert_eq!(u as f32, 1.0, "test premise: u rounds up to 1.0f32");
        let v = f32_in_range(u, -1.0, 1.0);
        assert!((-1.0..1.0).contains(&v), "clamped into [lo, hi): {v}");
        // affine rounding onto hi with u strictly below 1 clamps too
        let v = f32_in_range(1.0 - f64::EPSILON, 0.0, 0.1);
        assert!((0.0..0.1).contains(&v), "{v}");
        // zero and negative hi endpoints
        assert!(f32_in_range(1.0, -1.0, 0.0) < 0.0);
        assert!(f32_in_range(1.0, -2.0, -1.0) < -1.0);
        // degenerate interval stays put
        assert_eq!(f32_in_range(0.999_999, 2.0, 2.0), 2.0);
        // interior draws are untouched
        assert_eq!(f32_in_range(0.5, 0.0, 2.0), 1.0);
    }

    #[test]
    fn f32_range_bulk_bounds() {
        let mut rng = Rng::seed_from_u64(21);
        for _ in 0..100_000 {
            let v = rng.f32_range(-1.0, 1.0);
            assert!((-1.0..1.0).contains(&v), "{v}");
        }
    }

    #[test]
    fn into_variants_match_allocating_draws() {
        // same seed, interleaved calls: the _into variants must consume
        // the identical draw sequence and produce identical values
        let mut a = Rng::seed_from_u64(29);
        let mut b = Rng::seed_from_u64(29);
        let (mut out, mut scratch) = (Vec::new(), Vec::new());
        let (mut perm, mut wr) = (Vec::new(), Vec::new());
        for (n, k) in [(10usize, 3usize), (50, 50), (40, 39), (7, 1)] {
            b.sample_without_replacement_into(n, k, &mut out, &mut scratch);
            assert_eq!(a.sample_without_replacement(n, k), out);
            b.permutation_into(n, &mut perm);
            assert_eq!(a.permutation(n), perm);
            b.sample_with_replacement_into(n, k, &mut wr);
            assert_eq!(a.sample_with_replacement(n, k), wr);
        }
    }

    #[test]
    fn with_replacement_in_range() {
        let mut rng = Rng::seed_from_u64(17);
        let s = rng.sample_with_replacement(4, 1000);
        assert_eq!(s.len(), 1000);
        assert!(s.iter().all(|&v| v < 4));
        // with replacement duplicates must occur
        assert!(s.windows(2).any(|w| w[0] == w[1]));
    }
}
