//! Minimal JSON: a recursive-descent parser and a pretty emitter.
//!
//! Used for `artifacts/manifest.json` (written by python), run-metric
//! dumps, and experiment configs. Supports the full JSON grammar except
//! `\uXXXX` surrogate pairs outside the BMP (the manifest never contains
//! them); numbers round-trip through f64.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{anyhow, bail, Result};

/// A JSON value. Objects preserve sorted key order via BTreeMap.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Value>),
    Obj(BTreeMap<String, Value>),
}

impl Value {
    pub fn parse(text: &str) -> Result<Value> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            bail!("trailing data at byte {}", p.i);
        }
        Ok(v)
    }

    // -- typed accessors ---------------------------------------------------

    pub fn get(&self, key: &str) -> Result<&Value> {
        match self {
            Value::Obj(m) => m.get(key).ok_or_else(|| anyhow!("missing key {key:?}")),
            _ => bail!("not an object (looking up {key:?})"),
        }
    }

    pub fn opt(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Value::Str(s) => Ok(s),
            _ => bail!("not a string: {self:?}"),
        }
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Value::Bool(b) => Ok(*b),
            _ => bail!("not a bool: {self:?}"),
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Value::Num(n) => Ok(*n),
            _ => bail!("not a number: {self:?}"),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        let n = self.as_f64()?;
        if n < 0.0 || n.fract() != 0.0 {
            bail!("not a non-negative integer: {n}");
        }
        Ok(n as usize)
    }

    pub fn as_arr(&self) -> Result<&[Value]> {
        match self {
            Value::Arr(v) => Ok(v),
            _ => bail!("not an array: {self:?}"),
        }
    }

    pub fn as_obj(&self) -> Result<&BTreeMap<String, Value>> {
        match self {
            Value::Obj(m) => Ok(m),
            _ => bail!("not an object"),
        }
    }

    // -- emission ------------------------------------------------------------

    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.emit(&mut s, 0);
        s
    }

    fn emit(&self, out: &mut String, indent: usize) {
        match self {
            Value::Null => out.push_str("null"),
            Value::Bool(b) => {
                let _ = write!(out, "{b}");
            }
            Value::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Value::Str(s) => emit_str(out, s),
            Value::Arr(v) => {
                if v.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push('[');
                for (i, item) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    out.push('\n');
                    out.push_str(&"  ".repeat(indent + 1));
                    item.emit(out, indent + 1);
                }
                out.push('\n');
                out.push_str(&"  ".repeat(indent));
                out.push(']');
            }
            Value::Obj(m) => {
                if m.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    out.push('\n');
                    out.push_str(&"  ".repeat(indent + 1));
                    emit_str(out, k);
                    out.push_str(": ");
                    v.emit(out, indent + 1);
                }
                out.push('\n');
                out.push_str(&"  ".repeat(indent));
                out.push('}');
            }
        }
    }
}

fn emit_str(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Convenience builders.
pub fn obj(pairs: Vec<(&str, Value)>) -> Value {
    Value::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

pub fn num(n: f64) -> Value {
    Value::Num(n)
}

pub fn s(v: impl Into<String>) -> Value {
    Value::Str(v.into())
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.b.get(self.i).copied().ok_or_else(|| anyhow!("unexpected end of input"))
    }

    fn expect(&mut self, c: u8) -> Result<()> {
        if self.peek()? != c {
            bail!("expected {:?} at byte {}, found {:?}", c as char, self.i, self.peek()? as char);
        }
        self.i += 1;
        Ok(())
    }

    fn value(&mut self) -> Result<Value> {
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Value::Str(self.string()?)),
            b't' => self.lit("true", Value::Bool(true)),
            b'f' => self.lit("false", Value::Bool(false)),
            b'n' => self.lit("null", Value::Null),
            _ => self.number(),
        }
    }

    fn lit(&mut self, word: &str, v: Value) -> Result<Value> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            bail!("invalid literal at byte {}", self.i)
        }
    }

    fn object(&mut self) -> Result<Value> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Value::Obj(m));
        }
        loop {
            self.ws();
            let key = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let val = self.value()?;
            m.insert(key, val);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    return Ok(Value::Obj(m));
                }
                c => bail!("expected ',' or '}}' at byte {}, found {:?}", self.i, c as char),
            }
        }
    }

    fn array(&mut self) -> Result<Value> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Value::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    return Ok(Value::Arr(v));
                }
                c => bail!("expected ',' or ']' at byte {}, found {:?}", self.i, c as char),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(out),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b't' => out.push('\t'),
                        b'r' => out.push('\r'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                bail!("truncated \\u escape");
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])?;
                            let code = u32::from_str_radix(hex, 16)?;
                            self.i += 4;
                            out.push(char::from_u32(code).ok_or_else(|| anyhow!("bad \\u{hex}"))?);
                        }
                        _ => bail!("bad escape at byte {}", self.i),
                    }
                }
                c if c < 0x80 => out.push(c as char),
                _ => {
                    // multi-byte utf-8: re-decode from the byte slice
                    let start = self.i - 1;
                    let rest = std::str::from_utf8(&self.b[start..])?;
                    let ch = rest.chars().next().unwrap();
                    out.push(ch);
                    self.i = start + ch.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Value> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.i += 1;
        }
        let text = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Value::Num(text.parse::<f64>().map_err(|e| anyhow!("bad number {text:?}: {e}"))?))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Value::parse("null").unwrap(), Value::Null);
        assert_eq!(Value::parse("true").unwrap(), Value::Bool(true));
        assert_eq!(Value::parse("-1.5e3").unwrap(), Value::Num(-1500.0));
        assert_eq!(Value::parse("\"a\\nb\"").unwrap(), Value::Str("a\nb".into()));
    }

    #[test]
    fn parses_nested() {
        let v = Value::parse(r#"{"a": [1, 2, {"b": "c"}], "d": {}}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(v.get("a").unwrap().as_arr().unwrap()[2].get("b").unwrap().as_str().unwrap(), "c");
        assert!(v.get("d").unwrap().as_obj().unwrap().is_empty());
    }

    #[test]
    fn roundtrips_through_pretty() {
        let src = r#"{"config": {"n": 64, "losses": ["hinge", "squared"]}, "ok": true}"#;
        let v = Value::parse(src).unwrap();
        let back = Value::parse(&v.to_string_pretty()).unwrap();
        assert_eq!(v, back);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Value::parse("{").is_err());
        assert!(Value::parse("[1,]").is_err());
        assert!(Value::parse("1 2").is_err());
        assert!(Value::parse("'single'").is_err());
    }

    #[test]
    fn unicode_and_escapes() {
        let v = Value::parse(r#""π é""#).unwrap();
        assert_eq!(v, Value::Str("π é".into()));
        let emitted = Value::Str("a\"b\\c\n".into()).to_string_pretty();
        assert_eq!(Value::parse(&emitted).unwrap(), Value::Str("a\"b\\c\n".into()));
    }

    #[test]
    fn usize_accessor_guards() {
        assert_eq!(Value::parse("42").unwrap().as_usize().unwrap(), 42);
        assert!(Value::parse("4.2").unwrap().as_usize().is_err());
        assert!(Value::parse("-1").unwrap().as_usize().is_err());
    }

    #[test]
    fn real_manifest_shape() {
        let text = std::fs::read_to_string(
            std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts/manifest.json"),
        );
        if let Ok(text) = text {
            let v = Value::parse(&text).unwrap();
            assert!(v.get("entries").is_ok());
        }
    }
}
