//! Tiny CLI argument parser for the `repro` binary and examples:
//! `prog <subcommand> --key value --flag` with typed getters.

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Result};

#[derive(Debug, Clone, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    flags: BTreeMap<String, String>,
    present: Vec<String>,
}

impl Args {
    /// Parse from `std::env::args()` (skipping argv[0]).
    pub fn from_env() -> Result<Args> {
        Self::parse(std::env::args().skip(1))
    }

    pub fn parse(items: impl IntoIterator<Item = String>) -> Result<Args> {
        let mut out = Args::default();
        let mut items = items.into_iter().peekable();
        if let Some(first) = items.peek() {
            if !first.starts_with('-') {
                out.subcommand = items.next();
            }
        }
        while let Some(item) = items.next() {
            let Some(name) = item.strip_prefix("--") else {
                bail!("unexpected positional argument {item:?}");
            };
            let name = name.to_string();
            // --key=value or --key value or bare flag
            if let Some((k, v)) = name.split_once('=') {
                out.flags.insert(k.to_string(), v.to_string());
                out.present.push(k.to_string());
            } else if items.peek().is_some_and(|n| !n.starts_with("--")) {
                out.flags.insert(name.clone(), items.next().unwrap());
                out.present.push(name);
            } else {
                out.present.push(name.clone());
                out.flags.insert(name, String::new());
            }
        }
        Ok(out)
    }

    pub fn has(&self, name: &str) -> bool {
        self.present.iter().any(|p| p == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(|s| s.as_str()).filter(|s| !s.is_empty())
    }

    pub fn str_or(&self, name: &str, default: &str) -> String {
        self.get(name).unwrap_or(default).to_string()
    }

    pub fn required(&self, name: &str) -> Result<&str> {
        self.get(name).ok_or_else(|| anyhow!("missing required flag --{name}"))
    }

    pub fn parse_or<T: std::str::FromStr>(&self, name: &str, default: T) -> Result<T>
    where
        T::Err: std::fmt::Display,
    {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v.parse::<T>().map_err(|e| anyhow!("--{name} {v:?}: {e}")),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from)).unwrap()
    }

    #[test]
    fn subcommand_and_flags() {
        let a = parse("train --preset small --iters 40 --verbose");
        assert_eq!(a.subcommand.as_deref(), Some("train"));
        assert_eq!(a.get("preset"), Some("small"));
        assert_eq!(a.parse_or("iters", 0usize).unwrap(), 40);
        assert!(a.has("verbose"));
        assert!(!a.has("quiet"));
    }

    #[test]
    fn equals_syntax() {
        let a = parse("--p=5 --q=3");
        assert_eq!(a.parse_or("p", 0usize).unwrap(), 5);
        assert_eq!(a.parse_or("q", 0usize).unwrap(), 3);
    }

    #[test]
    fn negative_number_values() {
        let a = parse("bench --offset -3");
        assert_eq!(a.get("offset"), Some("-3"));
    }

    #[test]
    fn defaults_and_required() {
        let a = parse("run");
        assert_eq!(a.str_or("engine", "native"), "native");
        assert!(a.required("preset").is_err());
        assert_eq!(a.parse_or("scale", 50usize).unwrap(), 50);
    }

    #[test]
    fn rejects_stray_positional() {
        assert!(Args::parse(vec!["run".into(), "oops".into()]).is_err());
    }
}
