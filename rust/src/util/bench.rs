//! Micro-benchmark harness (criterion is unavailable offline).
//!
//! Usage from a `harness = false` bench target:
//! ```no_run
//! let mut b = sodda::util::bench::Bench::from_env("kernels");
//! b.bench("row_dot/1k", || { /* work */ });
//! b.finish();
//! ```
//!
//! Reports min/median/mean per iteration after a warmup phase, and
//! writes the rows as machine-readable JSON so the perf trajectory can
//! be tracked and CI can gate regressions (`repro bench-gate`). Knobs:
//!
//! * `BENCH_QUICK=1` — one-tenth measurement budget (CI smoke);
//! * `BENCH_OUT=path.json` — report destination; defaults to
//!   `target/bench/<group>.json`, creating directories as needed.
//!
//! JSON schema (`"schema": "sodda-bench-v1"`): top level `group`,
//! `quick` and `rows`; each row `{group, name, iters, min_ns,
//! median_ns, mean_ns}` plus `throughput_melem_s` when the benchmark
//! declared its per-iteration element count ([`Bench::bench_elems`])
//! and `allocs_per_iter` when the binary registered an allocation
//! counter ([`Bench::set_alloc_counter`] + a
//! [`crate::util::alloc::CountingAlloc`] global allocator) — heap
//! allocation events per benchmark iteration over the measurement
//! phase, gated absolutely (not by ratio) via `max_allocs_per_iter`
//! baseline entries. Benchmarks can attach further numeric columns to
//! their latest row with [`Bench::annotate`] (the end-to-end rows
//! record `wall_ns_per_iter` next to the SimNet `sim_ns_per_iter`).

use std::time::{Duration, Instant};

use crate::util::json::{self, Value};

pub struct Bench {
    group: String,
    /// target measurement time per benchmark
    budget: Duration,
    warmup: Duration,
    rows: Vec<Row>,
    /// quick mode (`BENCH_QUICK=1`): one-tenth budget for CI smoke
    pub quick: bool,
    /// global allocation-event counter (see [`Bench::set_alloc_counter`])
    alloc_counter: Option<fn() -> u64>,
}

struct Row {
    name: String,
    /// work items per iteration (0 = no throughput column)
    elems: u64,
    stats: Stats,
    /// allocation events per iteration during measurement (counter set)
    allocs_per_iter: Option<f64>,
    /// caller-annotated extra numeric columns ([`Bench::annotate`]),
    /// e.g. `wall_ns_per_iter` / `sim_ns_per_iter`
    extra: Vec<(String, f64)>,
}

#[derive(Debug, Clone, Copy)]
pub struct Stats {
    pub iters: u64,
    pub min_ns: f64,
    pub median_ns: f64,
    pub mean_ns: f64,
}

impl Bench {
    pub fn from_env(group: &str) -> Bench {
        let quick = crate::util::env::read("BENCH_QUICK").is_some_and(|v| v == "1");
        let (budget, warmup) = if quick {
            (Duration::from_millis(200), Duration::from_millis(50))
        } else {
            (Duration::from_secs(2), Duration::from_millis(300))
        };
        println!("== bench group: {group} (quick={quick}) ==");
        let group = group.to_string();
        Bench { group, budget, warmup, rows: Vec::new(), quick, alloc_counter: None }
    }

    /// Register a process-global allocation-event counter (typically
    /// `|| ALLOC.allocations()` over a
    /// [`crate::util::alloc::CountingAlloc`] installed as the binary's
    /// `#[global_allocator]`). Every subsequent row records
    /// `allocs_per_iter` — allocation events per benchmark iteration
    /// during the measurement phase (warmup excluded, so one-time
    /// warm-up allocations don't count against steady-state budgets).
    pub fn set_alloc_counter(&mut self, counter: fn() -> u64) {
        self.alloc_counter = Some(counter);
    }

    /// Time `f`, batching iterations adaptively.
    pub fn bench<R>(&mut self, name: &str, f: impl FnMut() -> R) -> Stats {
        self.bench_elems(name, 0, f)
    }

    /// Like [`Self::bench`], but records `elems` work items per
    /// iteration (rows × cols, nnz, …) so the JSON report carries a
    /// throughput column in Melem/s.
    pub fn bench_elems<R>(&mut self, name: &str, elems: u64, mut f: impl FnMut() -> R) -> Stats {
        // warmup + estimate cost (quick mode keeps the floors low so CI
        // smoke stays fast even for second-long end-to-end benchmarks)
        let min_calls = if self.quick { 1 } else { 3 };
        let min_samples = if self.quick { 2 } else { 5 };
        let warm_start = Instant::now();
        let mut calls = 0u64;
        while warm_start.elapsed() < self.warmup || calls < min_calls {
            std::hint::black_box(f());
            calls += 1;
        }
        let est_ns = (warm_start.elapsed().as_nanos() as f64 / calls as f64).max(1.0);
        // sample in batches so Instant overhead stays < ~1%
        let batch = ((100_000.0 / est_ns).ceil() as u64).clamp(1, 10_000);
        // pre-reserve so the harness's own sample vector never grows
        // inside the measured window (max 200 samples, see below)
        let mut samples: Vec<f64> = Vec::with_capacity(200);
        let allocs_before = self.alloc_counter.map(|c| c());
        let start = Instant::now();
        let mut total_iters = 0u64;
        while start.elapsed() < self.budget || samples.len() < min_samples {
            let t0 = Instant::now();
            for _ in 0..batch {
                std::hint::black_box(f());
            }
            samples.push(t0.elapsed().as_nanos() as f64 / batch as f64);
            total_iters += batch;
            if samples.len() >= 200 {
                break;
            }
        }
        let allocs_per_iter = match (self.alloc_counter, allocs_before) {
            (Some(c), Some(before)) => {
                Some(c().saturating_sub(before) as f64 / total_iters as f64)
            }
            _ => None,
        };
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let stats = Stats {
            iters: total_iters,
            min_ns: samples[0],
            median_ns: samples[samples.len() / 2],
            mean_ns: samples.iter().sum::<f64>() / samples.len() as f64,
        };
        let alloc_note =
            allocs_per_iter.map(|a| format!("   {a:.1} allocs/iter")).unwrap_or_default();
        println!(
            "{:<40} {:>12} {:>12} {:>12}   ({} iters){alloc_note}",
            format!("{}/{}", self.group, name),
            fmt_ns(stats.min_ns),
            fmt_ns(stats.median_ns),
            fmt_ns(stats.mean_ns),
            stats.iters
        );
        self.rows.push(Row {
            name: name.to_string(),
            elems,
            stats,
            allocs_per_iter,
            extra: Vec::new(),
        });
        stats
    }

    /// Attach an extra numeric column to the most recently recorded row
    /// (it lands in the row's JSON object verbatim). The convention for
    /// end-to-end rows is `wall_ns_per_iter` (measured wall-clock, =
    /// the row's median) next to `sim_ns_per_iter` (the SimNet charge),
    /// so the cost model can be validated against real time.
    pub fn annotate(&mut self, key: &str, value: f64) {
        let row = self.rows.last_mut().expect("annotate() needs a recorded row");
        row.extra.push((key.to_string(), value));
    }

    /// Assemble the JSON report for the recorded rows.
    fn report(&self) -> Value {
        let rows: Vec<Value> = self
            .rows
            .iter()
            .map(|row| {
                let mut pairs = vec![
                    ("group", json::s(self.group.clone())),
                    ("name", json::s(row.name.clone())),
                    ("iters", json::num(row.stats.iters as f64)),
                    ("min_ns", json::num(row.stats.min_ns)),
                    ("median_ns", json::num(row.stats.median_ns)),
                    ("mean_ns", json::num(row.stats.mean_ns)),
                ];
                if row.elems > 0 {
                    // elems per ns × 1e3 = millions of elements per second
                    pairs.push((
                        "throughput_melem_s",
                        json::num(row.elems as f64 / row.stats.median_ns * 1e3),
                    ));
                }
                if let Some(a) = row.allocs_per_iter {
                    pairs.push(("allocs_per_iter", json::num(a)));
                }
                for (k, v) in &row.extra {
                    pairs.push((k.as_str(), json::num(*v)));
                }
                json::obj(pairs)
            })
            .collect();
        json::obj(vec![
            ("schema", json::s("sodda-bench-v1")),
            ("group", json::s(self.group.clone())),
            ("quick", Value::Bool(self.quick)),
            ("rows", Value::Arr(rows)),
        ])
    }

    /// Print the summary, write the JSON report (`BENCH_OUT`, defaulting
    /// to `target/bench/<group>.json`), and return the JSON text.
    pub fn finish(self) -> String {
        let text = self.report().to_string_pretty();
        let path = crate::util::env::read("BENCH_OUT")
            .unwrap_or_else(|| format!("target/bench/{}.json", self.group));
        let path = std::path::PathBuf::from(path);
        if let Some(dir) = path.parent() {
            if !dir.as_os_str().is_empty() {
                let _ = std::fs::create_dir_all(dir);
            }
        }
        match std::fs::write(&path, &text) {
            Ok(()) => println!("(wrote {})", path.display()),
            Err(e) => eprintln!("(could not write {}: {e})", path.display()),
        }
        text
    }
}

/// Compare bench reports against a baseline
/// (`{"max_ratio": 1.5, "entries": [{group, name, median_ns?,
/// max_allocs_per_iter?}, …]}`). Returns one line per problem:
///
/// * a median slower than `max_ratio × median_ns` (when the entry gates
///   time);
/// * an `allocs_per_iter` above `max_allocs_per_iter` — an **absolute**
///   budget, not a ratio: allocation counts are deterministic, so a
///   pooled path that starts allocating again should fail loudly — or a
///   gated row whose report carries no alloc count at all (the bench
///   binary stopped counting);
/// * a baseline entry the current run never produced (a silently
///   dropped benchmark should fail the gate too).
///
/// Current rows without a baseline entry are ignored so new benchmarks
/// can land before their baseline is recorded.
pub fn regressions(baseline: &Value, current: &[Value], max_ratio: f64) -> anyhow::Result<Vec<String>> {
    use std::collections::BTreeMap;
    let mut medians: BTreeMap<(String, String), f64> = BTreeMap::new();
    let mut allocs: BTreeMap<(String, String), f64> = BTreeMap::new();
    for report in current {
        for row in report.get("rows")?.as_arr()? {
            let key =
                (row.get("group")?.as_str()?.to_string(), row.get("name")?.as_str()?.to_string());
            medians.insert(key.clone(), row.get("median_ns")?.as_f64()?);
            if let Some(a) = row.opt("allocs_per_iter") {
                allocs.insert(key, a.as_f64()?);
            }
        }
    }
    let mut out = Vec::new();
    for e in baseline.get("entries")?.as_arr()? {
        let group = e.get("group")?.as_str()?.to_string();
        let name = e.get("name")?.as_str()?.to_string();
        let key = (group.clone(), name.clone());
        if !medians.contains_key(&key) {
            out.push(format!("{group}/{name}: baseline entry missing from current run"));
            continue;
        }
        if let Some(base) = e.opt("median_ns") {
            let base = base.as_f64()?;
            let cur = medians[&key];
            if cur > max_ratio * base {
                out.push(format!(
                    "{group}/{name}: median {cur:.0} ns > {max_ratio}x baseline {base:.0} ns ({:.2}x)",
                    cur / base
                ));
            }
        }
        if let Some(budget) = e.opt("max_allocs_per_iter") {
            let budget = budget.as_f64()?;
            match allocs.get(&key) {
                None => out.push(format!(
                    "{group}/{name}: baseline gates allocs_per_iter but the current row \
                     reports none (bench binary not counting allocations?)"
                )),
                Some(&cur) if cur > budget => out.push(format!(
                    "{group}/{name}: {cur:.1} allocs/iter > budget {budget}"
                )),
                Some(_) => {}
            }
        }
    }
    Ok(out)
}

fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.0} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something_sane_and_emits_json() {
        let out = std::env::temp_dir().join("sodda-bench-selftest/selftest.json");
        let _env = crate::util::env::ScopedEnv::new()
            .with("BENCH_QUICK", Some("1"))
            .with("BENCH_OUT", Some(out.to_str().unwrap()));
        let mut b = Bench::from_env("selftest");
        let s = b.bench_elems("noop-ish", 2, || std::hint::black_box(1 + 1));
        assert!(s.min_ns >= 0.0 && s.median_ns < 1e6, "{s:?}");
        let text = b.finish();
        let v = Value::parse(&text).unwrap();
        assert_eq!(v.get("group").unwrap().as_str().unwrap(), "selftest");
        let rows = v.get("rows").unwrap().as_arr().unwrap();
        assert_eq!(rows.len(), 1);
        assert_eq!(rows[0].get("name").unwrap().as_str().unwrap(), "noop-ish");
        assert!(rows[0].get("throughput_melem_s").unwrap().as_f64().unwrap() > 0.0);
        // BENCH_OUT file round-trips
        let on_disk = std::fs::read_to_string(&out).unwrap();
        assert_eq!(Value::parse(&on_disk).unwrap(), v);
    }

    #[test]
    fn gate_flags_regressions_and_missing_rows_only() {
        let base = Value::parse(
            r#"{"max_ratio": 1.5, "entries": [
                {"group": "g", "name": "fast", "median_ns": 100.0},
                {"group": "g", "name": "slow", "median_ns": 100.0},
                {"group": "g", "name": "gone", "median_ns": 100.0}
            ]}"#,
        )
        .unwrap();
        let cur = Value::parse(
            r#"{"schema": "sodda-bench-v1", "group": "g", "quick": true, "rows": [
                {"group": "g", "name": "fast", "iters": 1, "min_ns": 1, "median_ns": 120.0, "mean_ns": 1},
                {"group": "g", "name": "slow", "iters": 1, "min_ns": 1, "median_ns": 200.0, "mean_ns": 1},
                {"group": "g", "name": "new-bench", "iters": 1, "min_ns": 1, "median_ns": 9.0, "mean_ns": 1}
            ]}"#,
        )
        .unwrap();
        let probs = regressions(&base, &[cur], 1.5).unwrap();
        assert_eq!(probs.len(), 2, "{probs:?}");
        assert!(probs.iter().any(|p| p.contains("g/slow")), "{probs:?}");
        assert!(probs.iter().any(|p| p.contains("g/gone")), "{probs:?}");
    }

    #[test]
    fn alloc_counter_adds_column() {
        use std::sync::atomic::{AtomicU64, Ordering};
        static FAKE: AtomicU64 = AtomicU64::new(0);
        fn fake_counter() -> u64 {
            FAKE.fetch_add(500, Ordering::Relaxed)
        }
        let _env = crate::util::env::ScopedEnv::new().with("BENCH_QUICK", Some("1"));
        // no finish()/BENCH_OUT here — inspect the report directly so
        // this test cannot race the env-var round-trip test above
        let mut b = Bench::from_env("alloc-selftest");
        b.set_alloc_counter(fake_counter);
        b.bench("counted", || std::hint::black_box(2 + 2));
        let v = b.report();
        let rows = v.get("rows").unwrap().as_arr().unwrap();
        let a = rows[0].get("allocs_per_iter").unwrap().as_f64().unwrap();
        assert!(a > 0.0, "fake counter advances between reads: {a}");
    }

    #[test]
    fn gate_enforces_absolute_alloc_budgets() {
        let base = Value::parse(
            r#"{"max_ratio": 1.5, "entries": [
                {"group": "g", "name": "lean", "max_allocs_per_iter": 10},
                {"group": "g", "name": "fat", "max_allocs_per_iter": 10},
                {"group": "g", "name": "blind", "max_allocs_per_iter": 10},
                {"group": "g", "name": "timed", "median_ns": 100.0, "max_allocs_per_iter": 10}
            ]}"#,
        )
        .unwrap();
        let cur = Value::parse(
            r#"{"schema": "sodda-bench-v1", "group": "g", "quick": true, "rows": [
                {"group": "g", "name": "lean", "iters": 1, "min_ns": 1, "median_ns": 900.0, "mean_ns": 1, "allocs_per_iter": 3.5},
                {"group": "g", "name": "fat", "iters": 1, "min_ns": 1, "median_ns": 1.0, "mean_ns": 1, "allocs_per_iter": 250.0},
                {"group": "g", "name": "blind", "iters": 1, "min_ns": 1, "median_ns": 1.0, "mean_ns": 1},
                {"group": "g", "name": "timed", "iters": 1, "min_ns": 1, "median_ns": 200.0, "mean_ns": 1, "allocs_per_iter": 2.0}
            ]}"#,
        )
        .unwrap();
        let probs = regressions(&base, &[cur], 1.5).unwrap();
        // lean passes (no median gate on its entry, allocs under budget);
        // fat busts the budget; blind is gated but uncounted; timed
        // regresses on time only
        assert_eq!(probs.len(), 3, "{probs:?}");
        assert!(probs.iter().any(|p| p.contains("g/fat") && p.contains("budget")), "{probs:?}");
        assert!(probs.iter().any(|p| p.contains("g/blind")), "{probs:?}");
        assert!(probs.iter().any(|p| p.contains("g/timed") && p.contains("median")), "{probs:?}");
    }

    #[test]
    fn annotate_attaches_columns_to_the_latest_row() {
        let _env = crate::util::env::ScopedEnv::new().with("BENCH_QUICK", Some("1"));
        let mut b = Bench::from_env("annotate-selftest");
        b.bench("first", || std::hint::black_box(1 + 1));
        let s = b.bench("second", || std::hint::black_box(2 + 2));
        b.annotate("wall_ns_per_iter", s.median_ns);
        b.annotate("sim_ns_per_iter", 123.5);
        let v = b.report();
        let rows = v.get("rows").unwrap().as_arr().unwrap();
        assert!(rows[0].opt("wall_ns_per_iter").is_none(), "only the latest row is annotated");
        let wall = rows[1].get("wall_ns_per_iter").unwrap().as_f64().unwrap();
        assert_eq!(wall, s.median_ns);
        assert_eq!(rows[1].get("sim_ns_per_iter").unwrap().as_f64().unwrap(), 123.5);
    }

    #[test]
    fn fmt_ns_units() {
        assert!(fmt_ns(500.0).contains("ns"));
        assert!(fmt_ns(5_000.0).contains("µs"));
        assert!(fmt_ns(5_000_000.0).contains("ms"));
    }
}
