//! Micro-benchmark harness (criterion is unavailable offline).
//!
//! Usage from a `harness = false` bench target:
//! ```no_run
//! let mut b = sodda::util::bench::Bench::from_env("kernels");
//! b.bench("row_dot/1k", || { /* work */ });
//! b.finish();
//! ```
//! Reports min/median/mean per iteration after a warmup phase, and writes
//! a CSV next to the binary's working dir for EXPERIMENTS.md.

use std::time::{Duration, Instant};

pub struct Bench {
    group: String,
    /// target measurement time per benchmark
    budget: Duration,
    warmup: Duration,
    rows: Vec<(String, Stats)>,
    /// quick mode (`BENCH_QUICK=1`): one-tenth budget for CI smoke
    pub quick: bool,
}

#[derive(Debug, Clone, Copy)]
pub struct Stats {
    pub iters: u64,
    pub min_ns: f64,
    pub median_ns: f64,
    pub mean_ns: f64,
}

impl Bench {
    pub fn from_env(group: &str) -> Bench {
        let quick = std::env::var("BENCH_QUICK").is_ok_and(|v| v == "1");
        let (budget, warmup) = if quick {
            (Duration::from_millis(200), Duration::from_millis(50))
        } else {
            (Duration::from_secs(2), Duration::from_millis(300))
        };
        println!("== bench group: {group} (quick={quick}) ==");
        Bench { group: group.to_string(), budget, warmup, rows: Vec::new(), quick }
    }

    /// Time `f`, batching iterations adaptively.
    pub fn bench<R>(&mut self, name: &str, mut f: impl FnMut() -> R) -> Stats {
        // warmup + estimate cost
        let warm_start = Instant::now();
        let mut calls = 0u64;
        while warm_start.elapsed() < self.warmup || calls < 3 {
            std::hint::black_box(f());
            calls += 1;
        }
        let est_ns = (warm_start.elapsed().as_nanos() as f64 / calls as f64).max(1.0);
        // sample in batches so Instant overhead stays < ~1%
        let batch = ((100_000.0 / est_ns).ceil() as u64).clamp(1, 10_000);
        let mut samples: Vec<f64> = Vec::new();
        let start = Instant::now();
        let mut total_iters = 0u64;
        while start.elapsed() < self.budget || samples.len() < 5 {
            let t0 = Instant::now();
            for _ in 0..batch {
                std::hint::black_box(f());
            }
            samples.push(t0.elapsed().as_nanos() as f64 / batch as f64);
            total_iters += batch;
            if samples.len() >= 200 {
                break;
            }
        }
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let stats = Stats {
            iters: total_iters,
            min_ns: samples[0],
            median_ns: samples[samples.len() / 2],
            mean_ns: samples.iter().sum::<f64>() / samples.len() as f64,
        };
        println!(
            "{:<40} {:>12} {:>12} {:>12}   ({} iters)",
            format!("{}/{}", self.group, name),
            fmt_ns(stats.min_ns),
            fmt_ns(stats.median_ns),
            fmt_ns(stats.mean_ns),
            stats.iters
        );
        self.rows.push((name.to_string(), stats));
        stats
    }

    /// Print the summary table; returns CSV content for persistence.
    pub fn finish(self) -> String {
        let mut csv = String::from("group,name,min_ns,median_ns,mean_ns,iters\n");
        for (name, s) in &self.rows {
            csv.push_str(&format!(
                "{},{},{:.1},{:.1},{:.1},{}\n",
                self.group, name, s.min_ns, s.median_ns, s.mean_ns, s.iters
            ));
        }
        let path = format!("target/bench-{}.csv", self.group);
        let _ = std::fs::write(&path, &csv);
        println!("(wrote {path})");
        csv
    }
}

fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.0} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something_sane() {
        std::env::set_var("BENCH_QUICK", "1");
        let mut b = Bench::from_env("selftest");
        let s = b.bench("noop-ish", || std::hint::black_box(1 + 1));
        assert!(s.min_ns >= 0.0 && s.median_ns < 1e6, "{s:?}");
        let csv = b.finish();
        assert!(csv.contains("selftest,noop-ish"));
    }

    #[test]
    fn fmt_ns_units() {
        assert!(fmt_ns(500.0).contains("ns"));
        assert!(fmt_ns(5_000.0).contains("µs"));
        assert!(fmt_ns(5_000_000.0).contains("ms"));
    }
}
