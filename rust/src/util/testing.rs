//! Test support: float comparison + a tiny property-test driver
//! (proptest is unavailable offline; `forall` gives us seeded random
//! case generation with shrink-free but reproducible failure reports).

use super::rng::Rng;

/// Relative+absolute float closeness (mirrors numpy's allclose).
pub fn close(a: f32, b: f32, rtol: f32, atol: f32) -> bool {
    (a - b).abs() <= atol + rtol * b.abs()
}

pub fn assert_close_slice(a: &[f32], b: &[f32], rtol: f32, atol: f32, ctx: &str) {
    assert_eq!(a.len(), b.len(), "{ctx}: length mismatch {} vs {}", a.len(), b.len());
    for (i, (&x, &y)) in a.iter().zip(b).enumerate() {
        assert!(
            close(x, y, rtol, atol),
            "{ctx}: element {i}: {x} vs {y} (rtol={rtol}, atol={atol})"
        );
    }
}

/// Run `cases` randomized test cases; on failure the panic message names
/// the case index and seed so the exact case can be replayed with
/// `forall_case`.
pub fn forall(cases: usize, seed: u64, mut f: impl FnMut(&mut Rng)) {
    for case in 0..cases {
        forall_case(case, seed, &mut f);
    }
}

/// Replay a single property case.
pub fn forall_case(case: usize, seed: u64, f: &mut impl FnMut(&mut Rng)) {
    let mut rng = Rng::seed_from_u64(seed ^ (case as u64).wrapping_mul(0x9E3779B97F4A7C15));
    let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(&mut rng)));
    if let Err(payload) = result {
        let msg = payload
            .downcast_ref::<String>()
            .cloned()
            .or_else(|| payload.downcast_ref::<&str>().map(|s| s.to_string()))
            .unwrap_or_else(|| "<non-string panic>".into());
        panic!("property failed at case {case} (seed {seed}): {msg}");
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn close_semantics() {
        assert!(close(1.0, 1.0 + 1e-7, 1e-5, 0.0));
        assert!(!close(1.0, 1.1, 1e-5, 0.0));
        assert!(close(0.0, 1e-9, 0.0, 1e-8));
    }

    #[test]
    fn forall_runs_all_cases() {
        let counter = std::sync::atomic::AtomicUsize::new(0);
        forall(25, 1, |_| {
            counter.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        });
        assert_eq!(counter.load(std::sync::atomic::Ordering::Relaxed), 25);
    }

    #[test]
    #[should_panic(expected = "property failed at case")]
    fn forall_reports_case() {
        forall(10, 2, |rng| {
            // fail eventually
            assert!(rng.below(4) != 3, "hit the bad value");
        });
    }

    #[test]
    fn forall_is_deterministic() {
        let mut first: Vec<u64> = vec![];
        forall(5, 3, |rng| first.push(rng.next_u64()));
        let mut second: Vec<u64> = vec![];
        forall(5, 3, |rng| second.push(rng.next_u64()));
        assert_eq!(first, second);
    }
}

/// `assert_close!(a, b)` / `assert_close!(a, b, rtol, atol)` for f32/f64
/// scalars (approx-crate replacement).
#[macro_export]
macro_rules! assert_close {
    ($a:expr, $b:expr) => {
        $crate::assert_close!($a, $b, 1e-4, 1e-5)
    };
    ($a:expr, $b:expr, $rtol:expr) => {
        $crate::assert_close!($a, $b, $rtol, 1e-5)
    };
    ($a:expr, $b:expr, $rtol:expr, $atol:expr) => {{
        let (a, b) = ($a as f64, $b as f64);
        assert!(
            (a - b).abs() <= $atol as f64 + $rtol as f64 * b.abs(),
            "assert_close failed: {} vs {} (rtol={}, atol={})",
            a,
            b,
            $rtol,
            $atol
        );
    }};
}
