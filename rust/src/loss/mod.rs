//! Loss functions of the linear model `f(z, y)` with `z = x·w`.
//!
//! The paper's experiments use the binary hinge SVM; logistic and squared
//! losses are the other two objectives §3 names as fitting the model
//! `F(ω) = (1/N) Σ f_i(x_i ω)`. The rust definitions mirror
//! `python/compile/kernels/ref.py` *exactly* — the XLA engine and the
//! native engine must be interchangeable up to f32 rounding, which the
//! integration tests assert.

/// Supported loss functions.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Loss {
    /// `max(0, 1 − y·z)` — the paper's SVM objective (subgradient used).
    Hinge,
    /// `log(1 + exp(−y·z))`.
    Logistic,
    /// `½ (z − y)²`.
    Squared,
}

impl Loss {
    pub const ALL: [Loss; 3] = [Loss::Hinge, Loss::Logistic, Loss::Squared];

    /// Loss value `f(z, y)`.
    #[inline]
    pub fn value(self, z: f32, y: f32) -> f32 {
        match self {
            Loss::Hinge => (1.0 - y * z).max(0.0),
            Loss::Logistic => {
                // stable log(1 + exp(-yz)) = max(0, -yz) + log1p(exp(-|yz|))
                let t = -y * z;
                t.max(0.0) + (-t.abs()).exp().ln_1p()
            }
            Loss::Squared => 0.5 * (z - y) * (z - y),
        }
    }

    /// Derivative `u = ∂f/∂z (z, y)` (subgradient for hinge).
    #[inline]
    pub fn dloss(self, z: f32, y: f32) -> f32 {
        match self {
            Loss::Hinge => {
                if y * z < 1.0 {
                    -y
                } else {
                    0.0
                }
            }
            Loss::Logistic => -y / (1.0 + (y * z).exp()),
            Loss::Squared => z - y,
        }
    }

    /// Name used by the artifact manifest entries (`grad_fused_hinge`, …).
    pub fn name(self) -> &'static str {
        match self {
            Loss::Hinge => "hinge",
            Loss::Logistic => "logistic",
            Loss::Squared => "squared",
        }
    }
}

impl std::str::FromStr for Loss {
    type Err = String;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s.to_ascii_lowercase().as_str() {
            "hinge" => Ok(Loss::Hinge),
            "logistic" => Ok(Loss::Logistic),
            "squared" => Ok(Loss::Squared),
            other => Err(format!("unknown loss {other:?} (hinge|logistic|squared)")),
        }
    }
}

impl std::fmt::Display for Loss {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::assert_close;

    /// Central finite difference of `value` wrt z.
    fn fd(loss: Loss, z: f32, y: f32) -> f32 {
        let h = 1e-3f32;
        (loss.value(z + h, y) - loss.value(z - h, y)) / (2.0 * h)
    }

    #[test]
    fn derivative_matches_finite_difference_smooth() {
        for loss in [Loss::Logistic, Loss::Squared] {
            for &y in &[-1.0f32, 1.0] {
                for i in -20..=20 {
                    let z = i as f32 * 0.37;
                    assert_close!(loss.dloss(z, y), fd(loss, z, y), 1e-2, 1e-3);
                }
            }
        }
    }

    #[test]
    fn hinge_subgradient_matches_fd_away_from_kink() {
        for &y in &[-1.0f32, 1.0] {
            for i in -20..=20 {
                let z = i as f32 * 0.37 + 0.013; // avoid yz == 1 exactly
                if (y * z - 1.0).abs() > 1e-2 {
                    assert_close!(Loss::Hinge.dloss(z, y), fd(Loss::Hinge, z, y), 0.0, 1e-3);
                }
            }
        }
    }

    #[test]
    fn values_at_zero_margin() {
        assert_eq!(Loss::Hinge.value(0.0, 1.0), 1.0);
        assert_close!(Loss::Logistic.value(0.0, 1.0), std::f32::consts::LN_2);
        assert_eq!(Loss::Squared.value(0.0, 1.0), 0.5);
    }

    #[test]
    fn logistic_is_stable_at_extremes() {
        assert!(Loss::Logistic.value(1e4, 1.0).is_finite());
        assert!(Loss::Logistic.value(-1e4, 1.0).is_finite());
        assert!(Loss::Logistic.dloss(-1e4, 1.0).is_finite());
        assert_close!(Loss::Logistic.dloss(-1e4, 1.0), -1.0);
        assert_close!(Loss::Logistic.dloss(1e4, 1.0), 0.0);
    }

    #[test]
    fn zero_inputs_have_zero_derivative() {
        // Padding invariant: u(0, 0) = 0 for every loss (relied on by the
        // zero-pad conventions shared with the pallas kernels).
        for loss in Loss::ALL {
            assert_eq!(loss.dloss(0.0, 0.0), 0.0);
        }
    }

    #[test]
    fn parse_roundtrip() {
        for loss in Loss::ALL {
            assert_eq!(loss.name().parse::<Loss>().unwrap(), loss);
        }
        assert!("huber".parse::<Loss>().is_err());
    }
}
