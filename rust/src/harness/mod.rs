//! Experiment harness: regenerates every table and figure of the paper's
//! §5 evaluation (the `repro` subcommand help is the experiment index).
//!
//! Each `figN`/`tableN` function runs the right set of configurations,
//! writes one CSV per curve under the output directory, and prints a
//! summary. Scales default to the presets' laptop divisors; pass
//! `--scale 1` for paper-sized runs.
//!
//! Every sweep stages **one [`Trainer`] session per dataset** and
//! `reconfigure`s it between runs, so the dataset is materialized,
//! partitioned and engine-staged once per preset instead of once per
//! curve — re-staging per run is the dominant avoidable cost in these
//! workloads.
//!
//! Calibration note: all comparisons use the paper's learning-rate shape
//! `γ_t = γ0/(1+√(t−1))` with one shared `γ0 = 0.08`, chosen once so the
//! first iterations of *all* algorithms are in the stable (non-overshoot)
//! regime at laptop partition sizes — the paper's `γ0 = 1` is tuned to
//! its 50k×6k partitions.

pub mod theory;

use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::config::{
    preset, AlgorithmKind, DataConfig, EngineKind, ExperimentConfig, ExperimentConfigBuilder,
    Preset, SamplingFractions, Schedule,
};
use crate::metrics::plot::{self, Curve};
use crate::metrics::{seed_variation, History};
use crate::train::Trainer;

/// Shared harness options (from the CLI).
#[derive(Debug, Clone)]
pub struct Opts {
    pub out_dir: PathBuf,
    /// dataset scale divisor (0 ⇒ preset default)
    pub scale: usize,
    pub iters: usize,
    pub engine: EngineKind,
    pub p: usize,
    pub q: usize,
    pub inner_steps: usize,
    pub gamma0: f64,
    pub seed: u64,
}

impl Default for Opts {
    fn default() -> Self {
        Self {
            out_dir: "results".into(),
            scale: 0,
            iters: 30,
            engine: EngineKind::Native,
            p: 5,
            q: 3,
            inner_steps: 32,
            gamma0: 0.08,
            seed: 1,
        }
    }
}

impl Opts {
    fn scale_for(&self, pr: &Preset) -> usize {
        if self.scale == 0 {
            pr.default_scale
        } else {
            self.scale
        }
    }

    /// Builder pre-loaded with the harness-wide settings (hinge loss —
    /// the paper's SVM objective throughout §5 — and the shared γ0).
    fn builder(&self, name: &str, data: DataConfig, algo: AlgorithmKind) -> ExperimentConfigBuilder {
        ExperimentConfig::builder()
            .name(name)
            .data(data)
            .grid(self.p, self.q)
            .algorithm(algo)
            .inner_steps(self.inner_steps)
            .outer_iters(self.iters)
            .schedule(Schedule::ScaledSqrt { gamma0: self.gamma0 })
            .seed(self.seed)
            .engine(self.engine)
    }
}

/// Run the session's current config to completion, write its CSV,
/// return the history.
fn run_curve(opts: &Opts, session: &mut Trainer) -> Result<History> {
    let name = session.config().name.clone();
    let out = session.run().with_context(|| format!("running {name}"))?;
    let path = opts.out_dir.join(format!("{name}.csv"));
    out.history.write_csv(&path)?;
    println!(
        "  {:<44} final F = {:.4}   sim {:.2}s   comm {:.1} MB",
        name,
        out.history.final_loss().unwrap_or(f64::NAN),
        out.history.records.last().map(|r| r.sim_s).unwrap_or(0.0),
        out.comm_bytes as f64 / 1e6
    );
    Ok(out.history)
}

/// Stage one session for a sweep, with the XLA shape hint on failure.
fn stage_session(cfg: ExperimentConfig, ds: crate::data::Dataset) -> Result<Trainer> {
    let steps = cfg.inner_steps;
    Trainer::with_dataset(cfg, ds).with_context(|| {
        format!(
            "staging session (XLA needs artifacts at the partition shape; \
             see `make artifacts N_PER=… M_PER=… MTILDE=… STEPS={steps}`)"
        )
    })
}

// ---------------------------------------------------------------------------
// Table 1 & Table 3 — dataset summaries
// ---------------------------------------------------------------------------

/// Table 1: synthetic dense dataset configurations at the active scale.
pub fn table1(opts: &Opts) -> Result<String> {
    let mut rows = String::new();
    rows.push_str("data size                     | small | medium | large\n");
    let mut line_pq = String::from("P x Q                         ");
    let mut line_size = String::from("size of each partition (n x m)");
    let mut line_exec = String::from("paper Spark executors         ");
    for name in ["small", "medium", "large"] {
        let pr = preset(name).unwrap();
        let dc = pr.data_config(opts.scale_for(pr), opts.p, opts.q);
        line_pq.push_str(&format!("| {} x {} ", opts.p, opts.q));
        line_size.push_str(&format!("| {} x {} ", dc.n() / opts.p, dc.m() / opts.q));
        line_exec.push_str(&format!("| {} ", pr.executors));
    }
    rows.push_str(&line_pq);
    rows.push('\n');
    rows.push_str(&line_size);
    rows.push('\n');
    rows.push_str(&line_exec);
    rows.push('\n');
    println!("== Table 1 (scale: preset/{}x) ==\n{rows}", opts.scale);
    std::fs::create_dir_all(&opts.out_dir)?;
    std::fs::write(opts.out_dir.join("table1.txt"), &rows)?;
    Ok(rows)
}

/// Table 3: the sparse SemMed-substitute datasets, with measured nnz.
pub fn table3(opts: &Opts) -> Result<String> {
    let mut rows = String::from("dataset    | N | M | n x m per partition | avg nnz/row\n");
    for name in ["diag-neg10", "loc-neg5"] {
        let pr = preset(name).unwrap();
        let dc = pr.data_config(opts.scale_for(pr), opts.p, opts.q);
        let ds = dc.try_materialize(opts.seed)?;
        let nnz = ds.x.nnz() as f64 / ds.n() as f64;
        rows.push_str(&format!(
            "{name} | {} | {} | {} x {} | {nnz:.1}\n",
            ds.n(),
            ds.m(),
            ds.n() / opts.p,
            ds.m() / opts.q
        ));
    }
    println!("== Table 3 (SemMed substitutes) ==\n{rows}");
    std::fs::create_dir_all(&opts.out_dir)?;
    std::fs::write(opts.out_dir.join("table3.txt"), &rows)?;
    Ok(rows)
}

// ---------------------------------------------------------------------------
// Figure 2 — (b, c, d) sweeps on the small dataset, vs RADiSA-avg
// ---------------------------------------------------------------------------

/// One Figure-2 panel. Panels follow the paper:
/// a: d ∈ {60..90}%, b = c = 100%;  b: c ∈ {40..80}%, b = 100%;
/// c: b = c ∈ {65..95}%;  d/e/f: b ∈ {95, 85, 75}% × c sweep;
/// g: long-run extension of d.
pub fn fig2(opts: &Opts, panel: char) -> Result<()> {
    let mut variants: Vec<(String, SamplingFractions)> = Vec::new();
    let f = |b: f64, c: f64, d: f64| SamplingFractions { b, c, d };
    let mut iters = opts.iters;
    match panel {
        'a' => {
            for d in [0.6, 0.7, 0.8, 0.9] {
                variants.push((format!("fig2a_sodda_d{:02.0}", d * 100.0), f(1.0, 1.0, d)));
            }
        }
        'b' => {
            for c in [0.4, 0.6, 0.8] {
                variants.push((format!("fig2b_sodda_c{:02.0}", c * 100.0), f(1.0, c, 0.85)));
            }
        }
        'c' => {
            for bc in [0.65, 0.75, 0.85, 0.95] {
                variants.push((format!("fig2c_sodda_bc{:02.0}", bc * 100.0), f(bc, bc, 0.85)));
            }
        }
        'd' | 'e' | 'f' | 'g' => {
            let b = match panel {
                'd' | 'g' => 0.95,
                'e' => 0.85,
                _ => 0.75,
            };
            if panel == 'g' {
                iters = opts.iters * 3; // long-run extension
            }
            for c in [0.4f64, 0.6, 0.8] {
                let c = c.min(b);
                variants.push((
                    format!("fig2{panel}_sodda_b{:02.0}_c{:02.0}", b * 100.0, c * 100.0),
                    f(b, c, 0.85),
                ));
            }
        }
        other => anyhow::bail!("unknown fig2 panel {other:?} (a-g)"),
    }

    let pr = preset("small").unwrap();
    let dc = pr.data_config(opts.scale_for(pr), opts.p, opts.q);
    let ds = dc.try_materialize(opts.seed)?;
    println!("== Figure 2({panel}) on {} ({}x{}) ==", ds.name, ds.n(), ds.m());

    // one staged session for the whole panel: every variant and the
    // RADiSA-avg benchmark reuse the same dataset/grid/engine/cluster
    let base = opts
        .builder("fig2-session", dc.clone(), AlgorithmKind::Sodda)
        .outer_iters(iters)
        .build()?;
    let mut session = stage_session(base.clone(), ds)?;
    let mut curves = Vec::new();
    for (name, fr) in variants {
        session.reconfigure(base.to_builder().name(&name).fractions(fr).build()?)?;
        let h = run_curve(opts, &mut session)?;
        curves.push(Curve::from_history(name, &h, true));
    }
    let name = format!("fig2{panel}_radisa_avg");
    session.reconfigure(
        base.to_builder().name(&name).algorithm(AlgorithmKind::RadisaAvg).build()?,
    )?;
    let h = run_curve(opts, &mut session)?;
    curves.push(Curve::from_history(name, &h, true));
    render(opts, &format!("fig2{panel}"), &format!("Figure 2({panel}) — small dataset"), &curves)?;
    Ok(())
}

/// Write the SVG + ASCII render of one figure's curves.
fn render(opts: &Opts, stem: &str, title: &str, curves: &[Curve]) -> Result<()> {
    std::fs::create_dir_all(&opts.out_dir)?;
    std::fs::write(
        opts.out_dir.join(format!("{stem}.svg")),
        plot::svg(curves, title, "simulated cluster seconds"),
    )?;
    std::fs::write(opts.out_dir.join(format!("{stem}.txt")), plot::ascii(curves, 22, 72))?;
    Ok(())
}

// ---------------------------------------------------------------------------
// Figure 3 — mid & large datasets, 3 seeds, SODDA vs RADiSA-avg
// ---------------------------------------------------------------------------

pub fn fig3(opts: &Opts) -> Result<()> {
    for name in ["medium", "large"] {
        let pr = preset(name).unwrap();
        let dc = pr.data_config(opts.scale_for(pr), opts.p, opts.q);
        println!("== Figure 3: {name} ==");
        let mut curves = Vec::new();
        for seed in [1u64, 2, 3] {
            // the dataset itself is seeded, so each seed is its own session
            let ds = dc.try_materialize(seed)?;
            let base = opts
                .builder(&format!("fig3_{name}_session"), dc.clone(), AlgorithmKind::Sodda)
                .seed(seed)
                .build()?;
            let mut session = stage_session(base.clone(), ds)?;
            for algo in [AlgorithmKind::Sodda, AlgorithmKind::RadisaAvg] {
                let run_name = format!("fig3_{name}_{algo}_seed{seed}");
                session.reconfigure(
                    base.to_builder().name(&run_name).algorithm(algo).build()?,
                )?;
                let h = run_curve(opts, &mut session)?;
                curves.push(Curve::from_history(run_name, &h, true));
            }
        }
        render(opts, &format!("fig3_{name}"), &format!("Figure 3 — {name} dataset, 3 seeds"), &curves)?;
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// Table 2 — seed variation on the large dataset (10 seeds × 40 iters)
// ---------------------------------------------------------------------------

pub fn table2(opts: &Opts) -> Result<String> {
    let pr = preset("large").unwrap();
    let dc = pr.data_config(opts.scale_for(pr), opts.p, opts.q);
    let ds = dc.try_materialize(opts.seed)?;
    println!("== Table 2 (seed variation, {} seeds × {} iters) ==", 10, opts.iters);
    // one session serves all 2 algorithms × 10 seeds (the dataset is
    // fixed here; `seed` only reseeds the training streams)
    let base = opts.builder("table2-session", dc.clone(), AlgorithmKind::Sodda).build()?;
    let mut session = stage_session(base.clone(), ds)?;
    let mut out = String::from("algorithm | avg(max-avg) | avg(avg-min) | max(max-avg) | max(avg-min)\n");
    for algo in [AlgorithmKind::Sodda, AlgorithmKind::RadisaAvg] {
        let mut curves: Vec<Vec<f64>> = Vec::new();
        for seed in 0..10u64 {
            session.reconfigure(
                base.to_builder()
                    .name(format!("table2_{algo}_seed{seed}"))
                    .algorithm(algo)
                    .seed(seed)
                    .build()?,
            )?;
            curves.push(session.run()?.history.losses());
        }
        let v = seed_variation(&curves);
        out.push_str(&format!(
            "{algo} | {:.4e} | {:.4e} | {:.4e} | {:.4e}\n",
            v.avg_max_minus_avg, v.avg_avg_minus_min, v.max_max_minus_avg, v.max_avg_minus_min
        ));
    }
    println!("{out}");
    std::fs::create_dir_all(&opts.out_dir)?;
    std::fs::write(opts.out_dir.join("table2.txt"), &out)?;
    Ok(out)
}

// ---------------------------------------------------------------------------
// Figure 4 — sparse SemMed substitutes, SODDA vs RADiSA-avg
// ---------------------------------------------------------------------------

pub fn fig4(opts: &Opts) -> Result<()> {
    for name in ["diag-neg10", "loc-neg5"] {
        let pr = preset(name).unwrap();
        let dc = pr.data_config(opts.scale_for(pr), opts.p, opts.q);
        let ds = dc.try_materialize(opts.seed)?;
        println!("== Figure 4: {name} ({}x{}, sparse) ==", ds.n(), ds.m());
        let base = opts.builder(&format!("fig4_{name}_session"), dc.clone(), AlgorithmKind::Sodda).build()?;
        let mut session = stage_session(base.clone(), ds)?;
        let mut curves = Vec::new();
        for algo in [AlgorithmKind::Sodda, AlgorithmKind::RadisaAvg] {
            let run_name = format!("fig4_{}_{algo}", name.replace('-', "_"));
            session.reconfigure(base.to_builder().name(&run_name).algorithm(algo).build()?)?;
            let h = run_curve(opts, &mut session)?;
            curves.push(Curve::from_history(run_name, &h, true));
        }
        render(opts, &format!("fig4_{}", name.replace('-', "_")), &format!("Figure 4 — {name} (sparse)"), &curves)?;
    }
    Ok(())
}

/// Print who-wins summary for a pair of histories (used by the CLI and
/// EXPERIMENTS.md): time for each algorithm to reach a set of loss levels.
pub fn time_to_loss_summary(sodda: &History, ravg: &History) -> String {
    let f0 = sodda.losses()[0];
    let best = sodda
        .min_loss()
        .unwrap()
        .max(ravg.min_loss().unwrap());
    let mut out = String::from("target_loss,sodda_sim_s,radisa_avg_sim_s\n");
    for frac in [0.8, 0.6, 0.4, 0.3] {
        let target = best + (f0 - best) * frac;
        let a = sodda.time_to_loss(target).map(|t| format!("{t:.3}")).unwrap_or_else(|| "-".into());
        let b = ravg.time_to_loss(target).map(|t| format!("{t:.3}")).unwrap_or_else(|| "-".into());
        out.push_str(&format!("{target:.4},{a},{b}\n"));
    }
    out
}

/// Load a curve back (used by tests of the harness itself).
pub fn read_curve(path: &Path) -> Result<Vec<(usize, f64, f64)>> {
    let text = std::fs::read_to_string(path)?;
    let mut out = Vec::new();
    for line in text.lines().skip(1) {
        let mut it = line.split(',');
        let iter: usize = it.next().unwrap_or("0").parse()?;
        let loss: f64 = it.next().unwrap_or("0").parse()?;
        let _wall = it.next();
        let sim: f64 = it.next().unwrap_or("0").parse()?;
        out.push((iter, loss, sim));
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_opts(dir: &str) -> Opts {
        Opts {
            out_dir: std::env::temp_dir().join(dir),
            scale: 2000, // tiny datasets for the harness's own tests
            iters: 3,
            p: 2,
            q: 2,
            inner_steps: 4,
            ..Opts::default()
        }
    }

    #[test]
    fn table1_renders() {
        let o = tiny_opts("sodda-t1");
        let t = table1(&o).unwrap();
        assert!(t.contains("small"));
        assert!(o.out_dir.join("table1.txt").exists());
    }

    #[test]
    fn fig2_panel_a_writes_curves() {
        let o = tiny_opts("sodda-f2");
        fig2(&o, 'a').unwrap();
        let curve = read_curve(&o.out_dir.join("fig2a_sodda_d60.csv")).unwrap();
        assert_eq!(curve.len(), 4); // iter 0 + 3
        assert!(o.out_dir.join("fig2a_radisa_avg.csv").exists());
    }

    #[test]
    fn fig2_rejects_unknown_panel() {
        assert!(fig2(&tiny_opts("sodda-f2x"), 'z').is_err());
    }

    #[test]
    fn table3_measures_sparsity() {
        let o = tiny_opts("sodda-t3");
        let t = table3(&o).unwrap();
        assert!(t.contains("diag-neg10"));
    }

    #[test]
    fn time_to_loss_summary_format() {
        use crate::metrics::IterRecord;
        let mut a = History::new("a");
        let mut b = History::new("b");
        for i in 0..5 {
            let rec = |loss: f64, s: f64| IterRecord {
                iter: i,
                loss,
                wall_s: s,
                sim_s: s,
                comm_bytes: 0,
                grad_coord_evals: 0,
            };
            a.push(rec(1.0 / (i + 1) as f64, i as f64 * 0.5));
            b.push(rec(1.2 / (i + 1) as f64, i as f64 * 0.7));
        }
        let s = time_to_loss_summary(&a, &b);
        assert!(s.starts_with("target_loss"));
        assert_eq!(s.lines().count(), 5);
    }
}
