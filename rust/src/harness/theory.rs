//! Empirical checks of the paper's Theorems 1-4 (§4): does the measured
//! convergence behave the way the analysis predicts?
//!
//! * Theorem 2 (γ_t = 1/t): `E[F(w^t) − F*] ≤ Q/(1+t)` — we fit
//!   `log(F_t − F*)` against `log t` and report the slope (should be
//!   ≤ about −1 asymptotically, i.e. at least sublinear O(1/t)).
//! * Theorem 3 (constant γ): linear convergence **to a neighborhood** —
//!   the error should drop geometrically then floor; we report the floor
//!   and the geometric-phase rate, and that a *smaller* γ gives a lower
//!   floor (the paper's trade-off discussion after eq. (6)).
//! * Theorem 4: with a sufficiently small constant γ the iterates keep
//!   improving (no divergence) — checked via monotone trend.

use anyhow::Result;

use super::Opts;
use crate::config::{ExperimentConfig, Schedule};
use crate::loss::Loss;
use crate::train::Trainer;

/// Results of the rate fits (also written to `theory.txt`).
#[derive(Debug, Clone)]
pub struct TheoryReport {
    /// slope of log(F_t − F*) vs log t under γ_t = 1/t
    pub invt_slope: f64,
    /// error floor under the larger constant γ
    pub floor_large_gamma: f64,
    /// error floor under the smaller constant γ
    pub floor_small_gamma: f64,
    /// geometric-phase per-iteration contraction under constant γ
    pub contraction: f64,
}

fn base_cfg(o: &Opts, name: &str) -> Result<ExperimentConfig> {
    ExperimentConfig::builder()
        .name(name)
        .dense(1200, 72)
        .grid(3, 2)
        .loss(Loss::Squared) // strongly convex objective, as the theorems assume
        .inner_steps(o.inner_steps.min(16))
        .outer_iters(120)
        .schedule(Schedule::InvT { gamma0: 0.08 })
        .seed(o.seed)
        .build()
}

/// Estimate F* by running much longer with a diminishing rate.
fn estimate_fstar(o: &Opts, session: &mut Trainer) -> Result<f64> {
    session.reconfigure(
        base_cfg(o, "theory_fstar")?
            .to_builder()
            .outer_iters(400)
            .schedule(Schedule::ScaledSqrt { gamma0: 0.05 })
            .build()?,
    )?;
    Ok(session.run()?.history.min_loss().unwrap())
}

fn linear_fit(xs: &[f64], ys: &[f64]) -> (f64, f64) {
    let n = xs.len() as f64;
    let mx = xs.iter().sum::<f64>() / n;
    let my = ys.iter().sum::<f64>() / n;
    let mut num = 0.0;
    let mut den = 0.0;
    for (x, y) in xs.iter().zip(ys) {
        num += (x - mx) * (y - my);
        den += (x - mx) * (x - mx);
    }
    let slope = num / den.max(1e-12);
    (slope, my - slope * mx)
}

pub fn run(o: &Opts) -> Result<TheoryReport> {
    println!("== theory checks (Theorems 2-4 empirics) ==");
    // every theory run shares one dataset/grid/loss — one staged session
    let mut session = Trainer::new(base_cfg(o, "theory_session")?)?;
    let fstar = estimate_fstar(o, &mut session)?;
    println!("  estimated F* = {fstar:.5}");

    // --- Theorem 2: 1/t rate --------------------------------------------
    session.reconfigure(base_cfg(o, "theory_invt")?)?;
    let hist = session.run()?.history;
    let (mut xs, mut ys) = (Vec::new(), Vec::new());
    for r in hist.records.iter().filter(|r| r.iter >= 10) {
        let gap = r.loss - fstar;
        if gap > 1e-8 {
            xs.push((r.iter as f64).ln());
            ys.push(gap.ln());
        }
    }
    let (invt_slope, _) = linear_fit(&xs, &ys);
    println!("  Theorem 2: log-gap slope under γ=1/t: {invt_slope:.2} (≤ ~-0.5 ⇒ sublinear+)");

    // --- Theorem 3: constant γ floors ------------------------------------
    let mut run_const = |gamma: f64, name: &str| -> Result<Vec<f64>> {
        session.reconfigure(
            base_cfg(o, name)?
                .to_builder()
                .schedule(Schedule::Constant { gamma })
                .outer_iters(150)
                .build()?,
        )?;
        Ok(session.run()?.history.losses())
    };
    let hi = run_const(0.02, "theory_const_hi")?;
    let lo = run_const(0.005, "theory_const_lo")?;
    let floor = |l: &[f64]| {
        let tail = &l[l.len() - 20..];
        tail.iter().sum::<f64>() / tail.len() as f64 - fstar
    };
    let floor_large_gamma = floor(&hi);
    let floor_small_gamma = floor(&lo);
    println!(
        "  Theorem 3: error floor γ=0.02: {floor_large_gamma:.5}; γ=0.005: {floor_small_gamma:.5} \
         (smaller γ ⇒ lower floor)"
    );

    // geometric contraction over the early phase of the large-γ run
    let mut ratios = Vec::new();
    for w in hi[1..16].windows(2) {
        let (a, b) = (w[0] - fstar, w[1] - fstar);
        if a > 1e-9 && b > 1e-9 {
            ratios.push(b / a);
        }
    }
    let contraction = ratios.iter().sum::<f64>() / ratios.len().max(1) as f64;
    println!("  Theorem 3: early-phase contraction factor ≈ {contraction:.3} (< 1 ⇒ linear phase)");

    // --- Theorem 4: small constant γ keeps improving ----------------------
    let safe = run_const(0.002, "theory_const_safe")?;
    let improving = safe.last().unwrap() < &safe[5];
    println!("  Theorem 4: tiny constant γ still improving at T: {improving}");
    anyhow::ensure!(improving, "Theorem 4 check failed: no improvement under safe constant γ");

    let report = TheoryReport { invt_slope, floor_large_gamma, floor_small_gamma, contraction };
    std::fs::create_dir_all(&o.out_dir)?;
    std::fs::write(
        o.out_dir.join("theory.txt"),
        format!(
            "invt_slope {invt_slope:.3}\nfloor_gamma_0.02 {floor_large_gamma:.6}\n\
             floor_gamma_0.005 {floor_small_gamma:.6}\ncontraction {contraction:.4}\n"
        ),
    )?;
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn linear_fit_recovers_line() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        let ys = [3.0, 5.0, 7.0, 9.0];
        let (m, b) = linear_fit(&xs, &ys);
        crate::assert_close!(m, 2.0, 1e-9);
        crate::assert_close!(b, 1.0, 1e-9);
    }

    #[test]
    #[ignore = "several hundred training iterations; run with --ignored"]
    fn theorems_hold_empirically() {
        let o = Opts { out_dir: std::env::temp_dir().join("sodda-theory"), ..Opts::default() };
        let r = run(&o).unwrap();
        assert!(r.invt_slope < -0.3, "expected sublinear-ish decay, slope {}", r.invt_slope);
        assert!(r.contraction < 1.0);
        assert!(r.floor_small_gamma <= r.floor_large_gamma * 1.5);
    }
}
