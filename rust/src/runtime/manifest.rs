//! `artifacts/manifest.json` schema — written by `python/compile/aot.py`,
//! consumed here to validate shapes before anything touches PJRT.

use std::collections::HashMap;
use std::path::Path;

use anyhow::{ensure, Context, Result};

use crate::util::json::Value;

#[derive(Debug, Clone)]
pub struct Manifest {
    pub schema: u32,
    pub config: ManifestConfig,
    pub entries: HashMap<String, Entry>,
}

/// The shape bucket every artifact was lowered at.
#[derive(Debug, Clone)]
pub struct ManifestConfig {
    /// rows per observation partition
    pub n: usize,
    /// features per feature block (M/Q)
    pub m: usize,
    /// features per sub-block (M/QP)
    pub mtilde: usize,
    /// inner-loop length L baked into svrg_inner
    pub steps: usize,
    pub losses: Vec<String>,
    pub dtype: String,
}

#[derive(Debug, Clone)]
pub struct Entry {
    pub file: String,
    pub sha256: String,
    pub inputs: Vec<TensorSpec>,
    pub output_shape: Vec<usize>,
}

#[derive(Debug, Clone)]
pub struct TensorSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: String,
}

impl Manifest {
    pub fn load(artifacts_dir: &Path) -> Result<Self> {
        let path = artifacts_dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} — run `make artifacts` first"))?;
        let man = Self::parse(&text).context("parsing manifest.json")?;
        ensure!(man.schema == 1, "unsupported manifest schema {}", man.schema);
        ensure!(man.config.dtype == "f32", "only f32 artifacts supported");
        Ok(man)
    }

    pub fn parse(text: &str) -> Result<Self> {
        let v = Value::parse(text)?;
        let c = v.get("config")?;
        let config = ManifestConfig {
            n: c.get("n")?.as_usize()?,
            m: c.get("m")?.as_usize()?,
            mtilde: c.get("mtilde")?.as_usize()?,
            steps: c.get("steps")?.as_usize()?,
            losses: c
                .get("losses")?
                .as_arr()?
                .iter()
                .map(|l| Ok(l.as_str()?.to_string()))
                .collect::<Result<Vec<_>>>()?,
            dtype: c.get("dtype")?.as_str()?.to_string(),
        };
        let mut entries = HashMap::new();
        for (name, e) in v.get("entries")?.as_obj()? {
            let inputs = e
                .get("inputs")?
                .as_arr()?
                .iter()
                .map(|i| {
                    Ok(TensorSpec {
                        name: i.get("name")?.as_str()?.to_string(),
                        shape: i
                            .get("shape")?
                            .as_arr()?
                            .iter()
                            .map(|d| d.as_usize())
                            .collect::<Result<Vec<_>>>()?,
                        dtype: i.get("dtype")?.as_str()?.to_string(),
                    })
                })
                .collect::<Result<Vec<_>>>()?;
            entries.insert(
                name.clone(),
                Entry {
                    file: e.get("file")?.as_str()?.to_string(),
                    sha256: e.opt("sha256").map(|s| s.as_str().map(String::from)).transpose()?.unwrap_or_default(),
                    inputs,
                    output_shape: e
                        .get("output_shape")?
                        .as_arr()?
                        .iter()
                        .map(|d| d.as_usize())
                        .collect::<Result<Vec<_>>>()?,
                },
            );
        }
        Ok(Manifest { schema: v.get("schema")?.as_usize()? as u32, config, entries })
    }

    pub fn entry(&self, name: &str) -> Result<&Entry> {
        self.entries
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("artifact entry {name:?} missing — re-run `make artifacts`"))
    }

    /// Check the artifact bucket can serve a (P, Q)-partitioned dataset.
    pub fn validate_for(&self, n_per: usize, m_per: usize, mtilde: usize, steps: usize) -> Result<()> {
        let c = &self.config;
        ensure!(
            c.n == n_per && c.m == m_per && c.mtilde == mtilde,
            "artifact shapes (n={}, m={}, m̃={}) do not match dataset partitioning \
             (n={n_per}, m={m_per}, m̃={mtilde}); rebuild with `make artifacts N={n_per} M_PER={m_per} MTILDE={mtilde}`",
            c.n, c.m, c.mtilde
        );
        ensure!(
            c.steps == steps,
            "artifact inner-loop length L={} != configured L={steps}",
            c.steps
        );
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_json() -> String {
        r#"{
            "schema": 1,
            "config": {"n": 64, "m": 32, "mtilde": 8, "steps": 4,
                        "losses": ["hinge"], "dtype": "f32"},
            "entries": {
                "partial_z": {
                    "file": "partial_z.hlo.txt",
                    "inputs": [
                        {"name": "x", "shape": [64, 32], "dtype": "f32"},
                        {"name": "w", "shape": [32], "dtype": "f32"}
                    ],
                    "output_shape": [64]
                }
            }
        }"#
        .to_string()
    }

    #[test]
    fn parses_and_validates() {
        let man = Manifest::parse(&sample_json()).unwrap();
        assert!(man.entry("partial_z").is_ok());
        assert!(man.entry("nope").is_err());
        assert!(man.validate_for(64, 32, 8, 4).is_ok());
        assert!(man.validate_for(64, 32, 8, 5).is_err());
        assert!(man.validate_for(128, 32, 8, 4).is_err());
    }
}
