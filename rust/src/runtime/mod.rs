//! PJRT runtime: loads the AOT HLO-text artifacts and executes them.
//!
//! The `xla` crate's handles are `Rc`-based (`!Send`), so one dedicated
//! OS thread owns the `PjRtClient`, every compiled executable, and all
//! device-resident staged buffers; the rest of the process talks to it
//! through an mpsc request channel. This mirrors a production deployment
//! where one PJRT context serves the whole coordinator (the CPU client
//! itself multithreads across cores internally).
//!
//! Compilation is lazy (first call per entry) and cached. Large static
//! operands — the data blocks — are staged once as `PjRtBuffer`s via
//! [`XlaRuntime::stage`] and referenced by key afterwards, so the steady
//! state moves only the small per-call vectors (w, u, idx, γ).

mod manifest;

pub use manifest::{Entry, Manifest, ManifestConfig, TensorSpec};

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::mpsc;
use std::sync::Mutex;

use anyhow::{anyhow, Result};

/// One call argument.
#[derive(Debug, Clone)]
pub enum Input {
    /// f32 tensor with dims (row-major).
    F32(Vec<f32>, Vec<usize>),
    /// i32 tensor with dims.
    I32(Vec<i32>, Vec<usize>),
    /// Reference to a buffer previously uploaded with [`XlaRuntime::stage`].
    Staged(String),
}

enum Request {
    Stage { key: String, data: Vec<f32>, dims: Vec<usize>, reply: mpsc::Sender<Result<()>> },
    Call { entry: String, inputs: Vec<Input>, reply: mpsc::Sender<Result<Vec<f32>>> },
}

/// Handle to the PJRT actor thread. Cheap to clone behind `Arc`.
pub struct XlaRuntime {
    tx: Mutex<mpsc::Sender<Request>>,
    pub manifest: Manifest,
}

impl XlaRuntime {
    /// Load the manifest and spin up the PJRT actor for `artifacts_dir`.
    pub fn load(artifacts_dir: impl AsRef<Path>) -> Result<Self> {
        let dir = artifacts_dir.as_ref().to_path_buf();
        let manifest = Manifest::load(&dir)?;
        let man2 = manifest.clone();
        let (tx, rx) = mpsc::channel::<Request>();
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
        std::thread::Builder::new()
            .name("pjrt-actor".into())
            .spawn(move || actor_main(dir, man2, rx, ready_tx))
            .map_err(|e| anyhow!("spawning pjrt actor: {e}"))?;
        ready_rx.recv().map_err(|_| anyhow!("pjrt actor died during startup"))??;
        Ok(Self { tx: Mutex::new(tx), manifest })
    }

    /// Upload a device-resident f32 buffer reusable across calls.
    pub fn stage(&self, key: impl Into<String>, data: Vec<f32>, dims: Vec<usize>) -> Result<()> {
        let (reply, rx) = mpsc::channel();
        self.send(Request::Stage { key: key.into(), data, dims, reply })?;
        rx.recv().map_err(|_| anyhow!("pjrt actor gone"))?
    }

    /// Execute `entry` with `inputs` (order must match the manifest) and
    /// return the flattened f32 output.
    pub fn call(&self, entry: &str, inputs: Vec<Input>) -> Result<Vec<f32>> {
        let (reply, rx) = mpsc::channel();
        self.send(Request::Call { entry: entry.to_string(), inputs, reply })?;
        rx.recv().map_err(|_| anyhow!("pjrt actor gone"))?
    }

    fn send(&self, req: Request) -> Result<()> {
        self.tx
            .lock()
            .map_err(|_| anyhow!("pjrt sender poisoned"))?
            .send(req)
            .map_err(|_| anyhow!("pjrt actor gone"))
    }
}

fn xerr(e: xla::Error) -> anyhow::Error {
    anyhow!("xla: {e}")
}

fn actor_main(
    dir: PathBuf,
    manifest: Manifest,
    rx: mpsc::Receiver<Request>,
    ready: mpsc::Sender<Result<()>>,
) {
    let client = match xla::PjRtClient::cpu().map_err(xerr) {
        Ok(c) => {
            let _ = ready.send(Ok(()));
            c
        }
        Err(e) => {
            let _ = ready.send(Err(e));
            return;
        }
    };
    let mut exes: HashMap<String, xla::PjRtLoadedExecutable> = HashMap::new();
    let mut staged: HashMap<String, xla::PjRtBuffer> = HashMap::new();

    while let Ok(req) = rx.recv() {
        match req {
            Request::Stage { key, data, dims, reply } => {
                let r = client
                    .buffer_from_host_buffer(&data, &dims, None)
                    .map_err(xerr)
                    .map(|buf| {
                        staged.insert(key, buf);
                    });
                let _ = reply.send(r);
            }
            Request::Call { entry, inputs, reply } => {
                let _ = reply.send(run_call(&client, &dir, &manifest, &mut exes, &staged, &entry, inputs));
            }
        }
    }
}

fn run_call(
    client: &xla::PjRtClient,
    dir: &Path,
    manifest: &Manifest,
    exes: &mut HashMap<String, xla::PjRtLoadedExecutable>,
    staged: &HashMap<String, xla::PjRtBuffer>,
    entry: &str,
    inputs: Vec<Input>,
) -> Result<Vec<f32>> {
    if !exes.contains_key(entry) {
        let meta = manifest.entry(entry)?;
        let path = dir.join(&meta.file);
        let proto = xla::HloModuleProto::from_text_file(&path).map_err(xerr)?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client.compile(&comp).map_err(xerr)?;
        exes.insert(entry.to_string(), exe);
    }
    let exe = &exes[entry];

    // Fresh inputs become device buffers; staged keys are looked up.
    let mut owned: Vec<xla::PjRtBuffer> = Vec::new();
    let mut order: Vec<usize> = Vec::new(); // index into owned (usize::MAX => staged)
    let mut staged_refs: Vec<&xla::PjRtBuffer> = Vec::new();
    for inp in &inputs {
        match inp {
            Input::F32(data, dims) => {
                owned.push(client.buffer_from_host_buffer(data, dims, None).map_err(xerr)?);
                order.push(owned.len() - 1);
            }
            Input::I32(data, dims) => {
                owned.push(client.buffer_from_host_buffer(data, dims, None).map_err(xerr)?);
                order.push(owned.len() - 1);
            }
            Input::Staged(key) => {
                let buf = staged
                    .get(key)
                    .ok_or_else(|| anyhow!("staged buffer {key:?} not found"))?;
                staged_refs.push(buf);
                order.push(usize::MAX - (staged_refs.len() - 1));
            }
        }
    }
    let args: Vec<&xla::PjRtBuffer> = order
        .iter()
        .map(|&i| {
            if i >= usize::MAX - staged_refs.len() {
                staged_refs[usize::MAX - i]
            } else {
                &owned[i]
            }
        })
        .collect();

    let result = exe.execute_b(&args).map_err(xerr)?;
    let lit = result[0][0].to_literal_sync().map_err(xerr)?;
    // entries are lowered with return_tuple=True
    let out = lit.to_tuple1().map_err(xerr)?;
    out.to_vec::<f32>().map_err(xerr)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn missing_dir_is_a_clean_error() {
        let err = match XlaRuntime::load("/definitely/not/here") {
            Err(e) => e,
            Ok(_) => panic!("expected error"),
        };
        assert!(format!("{err:#}").contains("make artifacts"));
    }
}
