//! XLA engine: drives the AOT JAX/Pallas artifacts through PJRT.
//!
//! Padding/masking conventions (shared with `python/compile/model.py`):
//!
//! * artifacts are compiled at the fixed bucket `(n, m, m̃, L)` recorded
//!   in the manifest; [`XlaEngine::new`] validates the dataset partition
//!   dims against it and refuses to run on a mismatch;
//! * row subsets (`D^t`) are expressed by scattering `u` into a
//!   zero-filled full-length vector — zero rows contribute exactly zero
//!   to every gradient sum;
//! * each block `x^{p,q}` (and each sub-block used by the inner loop) is
//!   densified and staged on device **once**, keyed by [`BlockKey`]; the
//!   steady-state per-call traffic is only the small parameter vectors.

// staging keys are only membership-tested, never iterated — hash order
// can't reach any computed number: lint:allow(hash_containers)
use std::collections::HashSet;
use std::ops::Range;
use std::sync::{Arc, Mutex};

use anyhow::Result;

use super::{BlockKey, ComputeEngine};
use crate::data::Store;
use crate::loss::Loss;
use crate::runtime::{Input, XlaRuntime};

pub struct XlaEngine {
    rt: Arc<XlaRuntime>,
    /// keys already staged on device ("x:p:q", "xsub:p:q:k", "y:p:q")
    staged: Mutex<HashSet<String>>, // lint:allow(hash_containers)
    n: usize,
    m: usize,
    mtilde: usize,
    steps: usize,
}

impl XlaEngine {
    /// Wrap a loaded runtime, validating the artifact bucket against the
    /// dataset partitioning (`n_per × m_per` blocks, `m̃`-wide sub-blocks,
    /// inner-loop length L).
    pub fn new(rt: Arc<XlaRuntime>, n_per: usize, m_per: usize, mtilde: usize, steps: usize) -> Result<Self> {
        rt.manifest.validate_for(n_per, m_per, mtilde, steps)?;
        // lint:allow(hash_containers)
        Ok(Self { rt, staged: Mutex::new(HashSet::new()), n: n_per, m: m_per, mtilde, steps })
    }

    fn ensure_block(&self, key: BlockKey, x: &Store) {
        let skey = format!("x:{}:{}", key.p, key.q);
        let mut staged = self.staged.lock().unwrap();
        if staged.contains(&skey) {
            return;
        }
        let mut data = vec![0.0f32; self.n * self.m];
        for r in 0..self.n {
            x.copy_row_range(r, 0, self.m, &mut data[r * self.m..(r + 1) * self.m]);
        }
        self.rt.stage(skey.clone(), data, vec![self.n, self.m]).expect("staging block");
        staged.insert(skey);
    }

    fn ensure_sub_block(&self, key: BlockKey, x: &Store, cols: &Range<usize>) -> String {
        let k = cols.start / self.mtilde;
        let skey = format!("xsub:{}:{}:{k}", key.p, key.q);
        let mut staged = self.staged.lock().unwrap();
        if !staged.contains(&skey) {
            let mut data = vec![0.0f32; self.n * self.mtilde];
            for r in 0..self.n {
                x.copy_row_range(r, cols.start, cols.end, &mut data[r * self.mtilde..(r + 1) * self.mtilde]);
            }
            self.rt.stage(skey.clone(), data, vec![self.n, self.mtilde]).expect("staging sub-block");
            staged.insert(skey.clone());
        }
        skey
    }

    fn ensure_labels(&self, key: BlockKey, y: &[f32]) -> String {
        let skey = format!("y:{}:{}", key.p, key.q);
        let mut staged = self.staged.lock().unwrap();
        if !staged.contains(&skey) {
            self.rt.stage(skey.clone(), y.to_vec(), vec![self.n]).expect("staging labels");
            staged.insert(skey.clone());
        }
        skey
    }

    fn pad(&self, v: &[f32]) -> Vec<f32> {
        let mut out = vec![0.0f32; self.n];
        out[..v.len()].copy_from_slice(v);
        out
    }
}

impl ComputeEngine for XlaEngine {
    fn name(&self) -> &'static str {
        "xla"
    }

    fn fixed_inner_steps(&self) -> Option<usize> {
        Some(self.steps)
    }

    fn partial_z(&self, key: BlockKey, x: &Store, cols: Range<usize>, w: &[f32], rows: &[u32]) -> Vec<f32> {
        assert_eq!(cols, 0..self.m, "XLA engine computes z over full blocks");
        self.ensure_block(key, x);
        let z = self
            .rt
            .call(
                "partial_z",
                vec![
                    Input::Staged(format!("x:{}:{}", key.p, key.q)),
                    Input::F32(w.to_vec(), vec![self.m]),
                ],
            )
            .expect("partial_z");
        rows.iter().map(|&r| z[r as usize]).collect()
    }

    fn dloss_u(&self, loss: Loss, z: &[f32], y: &[f32]) -> Vec<f32> {
        let len = z.len();
        let u = self
            .rt
            .call(
                &format!("dloss_u_{}", loss.name()),
                vec![Input::F32(self.pad(z), vec![self.n]), Input::F32(self.pad(y), vec![self.n])],
            )
            .expect("dloss_u");
        u[..len].to_vec()
    }

    fn grad_slice(&self, key: BlockKey, x: &Store, cols: Range<usize>, rows: &[u32], u: &[f32]) -> Vec<f32> {
        assert_eq!(cols, 0..self.m, "XLA engine computes gradient slices over full blocks");
        self.ensure_block(key, x);
        // scatter u onto the full row space; zero rows contribute zero
        let mut uf = vec![0.0f32; self.n];
        for (&r, &uk) in rows.iter().zip(u) {
            uf[r as usize] = uk;
        }
        self.rt
            .call(
                "grad_slice",
                vec![
                    Input::Staged(format!("x:{}:{}", key.p, key.q)),
                    Input::F32(uf, vec![self.n]),
                ],
            )
            .expect("grad_slice")
    }

    fn svrg_inner(
        &self,
        key: BlockKey,
        loss: Loss,
        x: &Store,
        y: &[f32],
        cols: Range<usize>,
        w0: &[f32],
        wt: &[f32],
        mu: &[f32],
        idx: &[u32],
        gamma: f32,
    ) -> Vec<f32> {
        assert_eq!(cols.len(), self.mtilde, "XLA svrg_inner runs on m̃-wide sub-blocks");
        assert_eq!(idx.len(), self.steps, "idx length must equal the compiled L");
        let xkey = self.ensure_sub_block(key, x, &cols);
        let ykey = self.ensure_labels(key, y);
        self.rt
            .call(
                &format!("svrg_inner_{}", loss.name()),
                vec![
                    Input::Staged(xkey),
                    Input::Staged(ykey),
                    Input::F32(w0.to_vec(), vec![self.mtilde]),
                    Input::F32(wt.to_vec(), vec![self.mtilde]),
                    Input::F32(mu.to_vec(), vec![self.mtilde]),
                    Input::I32(idx.iter().map(|&v| v as i32).collect(), vec![self.steps]),
                    Input::F32(vec![gamma], vec![1]),
                ],
            )
            .expect("svrg_inner")
    }

    fn svrg_inner_avg(
        &self,
        key: BlockKey,
        loss: Loss,
        x: &Store,
        y: &[f32],
        cols: Range<usize>,
        w0: &[f32],
        wt: &[f32],
        mu: &[f32],
        idx: &[u32],
        gamma: f32,
    ) -> Vec<f32> {
        assert_eq!(cols.len(), self.mtilde, "XLA svrg_inner_avg runs on m̃-wide sub-blocks");
        assert_eq!(idx.len(), self.steps, "idx length must equal the compiled L");
        let xkey = self.ensure_sub_block(key, x, &cols);
        let ykey = self.ensure_labels(key, y);
        self.rt
            .call(
                &format!("svrg_inner_avg_{}", loss.name()),
                vec![
                    Input::Staged(xkey),
                    Input::Staged(ykey),
                    Input::F32(w0.to_vec(), vec![self.mtilde]),
                    Input::F32(wt.to_vec(), vec![self.mtilde]),
                    Input::F32(mu.to_vec(), vec![self.mtilde]),
                    Input::I32(idx.iter().map(|&v| v as i32).collect(), vec![self.steps]),
                    Input::F32(vec![gamma], vec![1]),
                ],
            )
            .expect("svrg_inner_avg")
    }

    fn loss_from_z(&self, loss: Loss, z: &[f32], y: &[f32]) -> f64 {
        let pad = self.n - z.len();
        let out = self
            .rt
            .call(
                &format!("loss_from_z_{}", loss.name()),
                vec![Input::F32(self.pad(z), vec![self.n]), Input::F32(self.pad(y), vec![self.n])],
            )
            .expect("loss_from_z");
        // zero-padded rows each contributed f(0, 0)
        out[0] as f64 - pad as f64 * loss.value(0.0, 0.0) as f64
    }
}
