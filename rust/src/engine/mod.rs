//! Compute engines: the numeric backends the coordinator drives.
//!
//! Two interchangeable implementations of [`ComputeEngine`]:
//!
//! * [`NativeEngine`] — pure-rust math, sparse-aware, zero staging cost.
//!   Always available; the baseline the XLA path is validated against.
//!   A thin adapter over the batched [`kernels`] layer (storage format
//!   resolved once per call, not once per row).
//! * [`XlaEngine`] — executes the AOT-compiled JAX/Pallas artifacts
//!   through the PJRT CPU client ([`crate::runtime`]). This is the
//!   "python never on the request path" production configuration.
//!
//! The coordinator is engine-generic; integration tests assert the two
//! engines produce identical training trajectories (up to f32 rounding).
//!
//! ## Sampled-width entry points
//!
//! SODDA's sampled sets travel as explicit sorted **block-local column
//! subsets** with compact parameter/gradient payloads:
//! [`ComputeEngine::partial_z_cols_into`],
//! [`ComputeEngine::partial_u_cols_into`] and
//! [`ComputeEngine::grad_cols_into`] do O(|subset|)-width work per row
//! instead of O(block width). The trait defaults densify (scatter the
//! compact `w` / gather from the full-width slice) and delegate to the
//! full-width methods, so the XLA engine and external engines keep
//! working unchanged; the native engine overrides them with true
//! gather-dot (dense) and sorted-intersection (CSR) kernels. The
//! sampled path is deterministic and matches the masked full-width path
//! to accumulation-order rounding (README "Sampled-width execution").

pub mod kernels;
mod native;
#[cfg(feature = "xla")]
mod xla;

pub use native::NativeEngine;
#[cfg(feature = "xla")]
pub use xla::XlaEngine;

use std::ops::Range;

use crate::data::Store;
use crate::loss::Loss;

/// Identifies a worker's shard so engines can cache per-block state
/// (the XLA engine stages each block on device exactly once).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct BlockKey {
    pub p: usize,
    pub q: usize,
}

/// Scatter a compact subset `w` onto a zero-filled full block width —
/// the densify step shared by the default (non-subset-aware) `_cols`
/// engine paths.
fn densify_w(idx: &[u32], w: &[f32], m: usize) -> Vec<f32> {
    let mut w_full = vec![0.0f32; m];
    for (&i, &wv) in idx.iter().zip(w) {
        w_full[i as usize] = wv;
    }
    w_full
}

/// Numeric backend for the per-block operations of Algorithm 1.
///
/// Row index slices refer to rows of the *local* block; column ranges are
/// block-local. Parameter slices (`w`, `mu`, …) are local to the column
/// range passed. All reductions are **sums** (normalization happens in
/// the coordinator), matching the AOT artifact conventions.
///
/// `Send + Sync` is load-bearing, not a formality: the threaded executor
/// shares one engine `Arc` across all P×Q worker threads calling these
/// methods concurrently, so every implementation must be safe to invoke
/// in parallel from multiple threads (engines keep per-call state on the
/// stack or in caller-provided buffers; per-block caches must be
/// internally synchronized).
pub trait ComputeEngine: Send + Sync {
    /// Backend name for logs/metrics.
    fn name(&self) -> &'static str;

    /// Inner-loop length the backend's kernels are compiled at, when the
    /// engine is shape-specialized (the AOT XLA artifacts); `None` for
    /// shape-agnostic engines. Sessions refuse to `reconfigure` to a
    /// different `inner_steps` when this is `Some`.
    fn fixed_inner_steps(&self) -> Option<usize> {
        None
    }

    /// Partial margins `z_k = x_{rows[k]}[cols] · w` (steps 5-8: the
    /// feature-block contribution to `x_j^{B^t} w_{B^t}`; `w` comes in
    /// pre-masked by B^t).
    fn partial_z(&self, key: BlockKey, x: &Store, cols: Range<usize>, w: &[f32], rows: &[u32]) -> Vec<f32>;

    /// In-place [`Self::partial_z`]: clears and refills a caller-provided
    /// (recycled) buffer. The default delegates to the allocating method
    /// and copies, so every engine keeps working unchanged; engines with
    /// true in-place kernels (the native one) override it to make the
    /// steady state allocation-free. Same contract for every `_into`
    /// method below: identical bits, only the buffer's origin differs.
    fn partial_z_into(
        &self,
        key: BlockKey,
        x: &Store,
        cols: Range<usize>,
        w: &[f32],
        rows: &[u32],
        out: &mut Vec<f32>,
    ) {
        let z = self.partial_z(key, x, cols, w, rows);
        out.clear();
        out.extend_from_slice(&z);
    }

    /// Sampled-width [`Self::partial_z`]: margins over an explicit
    /// **sorted block-local column subset** `idx` with a compact `w`
    /// (`w.len() == idx.len()`), so a low-fraction SODDA iteration does
    /// O(rows·|B∩block|) work instead of O(rows·block width). The
    /// default scatters the compact `w` onto the full block width and
    /// delegates to [`Self::partial_z_into`] — numerically the masked
    /// full-width path — so shape-specialized engines (the AOT XLA
    /// artifacts) keep working unchanged; engines with true subset
    /// kernels (the native one) override it.
    fn partial_z_cols_into(
        &self,
        key: BlockKey,
        x: &Store,
        idx: &[u32],
        w: &[f32],
        rows: &[u32],
        out: &mut Vec<f32>,
    ) {
        let m = x.cols();
        self.partial_z_into(key, x, 0..m, &densify_w(idx, w, m), rows, out)
    }

    /// Elementwise derivative `u_k = f'(z_k, y_k)`.
    fn dloss_u(&self, loss: Loss, z: &[f32], y: &[f32]) -> Vec<f32>;

    /// In-place [`Self::dloss_u`] (see [`Self::partial_z_into`]).
    fn dloss_u_into(&self, loss: Loss, z: &[f32], y: &[f32], out: &mut Vec<f32>) {
        let u = self.dloss_u(loss, z, y);
        out.clear();
        out.extend_from_slice(&u);
    }

    /// Fused batched margin + loss derivative over one block:
    /// `u_k = f'(x_{rows[k]}[cols]·w, y[rows[k]])`, with `y` the block's
    /// full local label vector. Only meaningful when the block holds the
    /// complete margin (Q = 1 grids — the [`crate::cluster`] fast path).
    /// The default composes [`Self::partial_z`] + [`Self::dloss_u`], so
    /// engines without a fused kernel (the XLA engine, remote workers)
    /// pick it up with identical behavior.
    #[allow(clippy::too_many_arguments)]
    fn partial_u(&self, key: BlockKey, loss: Loss, x: &Store, cols: Range<usize>, w: &[f32], rows: &[u32], y: &[f32]) -> Vec<f32> {
        let z = self.partial_z(key, x, cols, w, rows);
        let y_rows: Vec<f32> = rows.iter().map(|&r| y[r as usize]).collect();
        self.dloss_u(loss, &z, &y_rows)
    }

    /// In-place [`Self::partial_u`] (see [`Self::partial_z_into`]).
    #[allow(clippy::too_many_arguments)]
    fn partial_u_into(
        &self,
        key: BlockKey,
        loss: Loss,
        x: &Store,
        cols: Range<usize>,
        w: &[f32],
        rows: &[u32],
        y: &[f32],
        out: &mut Vec<f32>,
    ) {
        let u = self.partial_u(key, loss, x, cols, w, rows, y);
        out.clear();
        out.extend_from_slice(&u);
    }

    /// Sampled-width [`Self::partial_u_into`]: the fused subset margin +
    /// derivative (`Q = 1` grids). Default: scatter-and-delegate, like
    /// [`Self::partial_z_cols_into`].
    #[allow(clippy::too_many_arguments)]
    fn partial_u_cols_into(
        &self,
        key: BlockKey,
        loss: Loss,
        x: &Store,
        idx: &[u32],
        w: &[f32],
        rows: &[u32],
        y: &[f32],
        out: &mut Vec<f32>,
    ) {
        let m = x.cols();
        self.partial_u_into(key, loss, x, 0..m, &densify_w(idx, w, m), rows, y, out)
    }

    /// Fused batched margin + loss value `Σ_k f(x_{rows[k]}[cols]·w, y[rows[k]])`
    /// (objective evaluation). Same Q = 1 caveat and default composition
    /// as [`Self::partial_u`].
    #[allow(clippy::too_many_arguments)]
    fn block_loss(&self, key: BlockKey, loss: Loss, x: &Store, cols: Range<usize>, w: &[f32], rows: &[u32], y: &[f32]) -> f64 {
        let z = self.partial_z(key, x, cols, w, rows);
        let y_rows: Vec<f32> = rows.iter().map(|&r| y[r as usize]).collect();
        self.loss_from_z(loss, &z, &y_rows)
    }

    /// [`Self::block_loss`] with a caller-provided margin scratch buffer
    /// (cluster workers hold one per thread). The default ignores the
    /// scratch and delegates; the native engine overrides.
    #[allow(clippy::too_many_arguments)]
    fn block_loss_scratch(
        &self,
        key: BlockKey,
        loss: Loss,
        x: &Store,
        cols: Range<usize>,
        w: &[f32],
        rows: &[u32],
        y: &[f32],
        z_scratch: &mut Vec<f32>,
    ) -> f64 {
        let _ = z_scratch;
        self.block_loss(key, loss, x, cols, w, rows, y)
    }

    /// Gradient slice `g[cols] = Σ_k u_k · x_{rows[k]}[cols]`.
    fn grad_slice(&self, key: BlockKey, x: &Store, cols: Range<usize>, rows: &[u32], u: &[f32]) -> Vec<f32>;

    /// In-place [`Self::grad_slice`] (see [`Self::partial_z_into`]).
    fn grad_slice_into(
        &self,
        key: BlockKey,
        x: &Store,
        cols: Range<usize>,
        rows: &[u32],
        u: &[f32],
        out: &mut Vec<f32>,
    ) {
        let g = self.grad_slice(key, x, cols, rows, u);
        out.clear();
        out.extend_from_slice(&g);
    }

    /// Sampled-width [`Self::grad_slice_into`]: emits the **compact**
    /// gradient slice over the sorted block-local subset `idx`
    /// (`out.len() == idx.len()`), so phase-2 work and reply payloads
    /// scale with `|C∩block|`, not the block width. The default computes
    /// the full-width slice and gathers the subset out of it (the XLA
    /// engine inherits this densify-then-gather composition); the
    /// native engine overrides with the true intersection kernels.
    fn grad_cols_into(
        &self,
        key: BlockKey,
        x: &Store,
        idx: &[u32],
        rows: &[u32],
        u: &[f32],
        out: &mut Vec<f32>,
    ) {
        let m = x.cols();
        let g = self.grad_slice(key, x, 0..m, rows, u);
        out.clear();
        out.extend(idx.iter().map(|&i| g[i as usize]));
    }

    /// L SVRG steps on one sub-block (Algorithm 1 step 16). `idx` holds
    /// the pre-sampled local row per step; returns `w^{(L)}`.
    #[allow(clippy::too_many_arguments)]
    fn svrg_inner(
        &self,
        key: BlockKey,
        loss: Loss,
        x: &Store,
        y: &[f32],
        cols: Range<usize>,
        w0: &[f32],
        wt: &[f32],
        mu: &[f32],
        idx: &[u32],
        gamma: f32,
    ) -> Vec<f32>;

    /// In-place [`Self::svrg_inner`] (see [`Self::partial_z_into`]).
    #[allow(clippy::too_many_arguments)]
    fn svrg_inner_into(
        &self,
        key: BlockKey,
        loss: Loss,
        x: &Store,
        y: &[f32],
        cols: Range<usize>,
        w0: &[f32],
        wt: &[f32],
        mu: &[f32],
        idx: &[u32],
        gamma: f32,
        out: &mut Vec<f32>,
    ) {
        let w = self.svrg_inner(key, loss, x, y, cols, w0, wt, mu, idx, gamma);
        out.clear();
        out.extend_from_slice(&w);
    }

    /// `Σ_k f(z_k, y_k)` from pre-reduced margins (objective reporting).
    fn loss_from_z(&self, loss: Loss, z: &[f32], y: &[f32]) -> f64;

    /// RADiSA-avg's combiner: same L steps as [`Self::svrg_inner`] but
    /// returns the **uniform iterate average** `mean(w^(1) … w^(L))`
    /// instead of the last iterate (Polyak averaging — the "-avg" in the
    /// benchmark's name; see PAPERS.md on the [13] reconstruction).
    #[allow(clippy::too_many_arguments)]
    fn svrg_inner_avg(
        &self,
        key: BlockKey,
        loss: Loss,
        x: &Store,
        y: &[f32],
        cols: Range<usize>,
        w0: &[f32],
        wt: &[f32],
        mu: &[f32],
        idx: &[u32],
        gamma: f32,
    ) -> Vec<f32>;

    /// In-place [`Self::svrg_inner_avg`]: `out` receives the iterate
    /// average, `w_scratch` may be used for the working iterate (the
    /// default ignores it; cluster workers pass per-thread scratch).
    #[allow(clippy::too_many_arguments)]
    fn svrg_inner_avg_into(
        &self,
        key: BlockKey,
        loss: Loss,
        x: &Store,
        y: &[f32],
        cols: Range<usize>,
        w0: &[f32],
        wt: &[f32],
        mu: &[f32],
        idx: &[u32],
        gamma: f32,
        out: &mut Vec<f32>,
        w_scratch: &mut Vec<f32>,
    ) {
        let _ = w_scratch;
        let w = self.svrg_inner_avg(key, loss, x, y, cols, w0, wt, mu, idx, gamma);
        out.clear();
        out.extend_from_slice(&w);
    }
}

#[cfg(test)]
pub(crate) mod testutil {
    use super::*;
    use crate::data::DenseMatrix;

    /// Tiny deterministic block shared by engine tests.
    pub fn block(n: usize, m: usize, seed: u64) -> (Store, Vec<f32>) {
        let mut rng = crate::util::rng::Rng::seed_from_u64(seed);
        let mut d = DenseMatrix::zeros(n, m);
        for v in d.data.iter_mut() {
            *v = rng.f32_range(-1.0, 1.0);
        }
        let y = (0..n).map(|_| if rng.bool_with(0.5) { 1.0 } else { -1.0 }).collect();
        (Store::Dense(d), y)
    }
}
