//! Batched, monomorphized compute kernels — the native hot path.
//!
//! [`NativeEngine`](super::NativeEngine) used to walk the [`Store`] enum
//! one row at a time: an enum `match`, a slice re-borrow and the bounds
//! checks per row, twice per SVRG step. These kernels resolve the
//! storage format **once per call**, then run unrolled dense loops
//! (8-wide accumulators, two rows per pass — see `data::dense::dot8`)
//! or CSR gather loops over the whole row set. Fusions on top of the
//! batching:
//!
//! * [`partial_u`] — margin + loss derivative in one pass (no
//!   intermediate `z` vector, no label gather);
//! * [`block_loss`] — margin + loss value (objective evaluation);
//! * [`svrg_inner`] / [`svrg_inner_avg`] — the inner step's current and
//!   reference row-dots share one traversal of the sampled row.
//!
//! Every kernel is **bit-for-bit identical** to the per-row scalar path
//! it replaces (`tests/kernels_prop.rs` asserts this across random
//! shapes, column sub-ranges and empty row sets): the per-row
//! accumulation order is shared with `Store`'s scalar ops, so only
//! dispatch, fusion and blocking differ — never the arithmetic.
//!
//! Each kernel comes in two forms: an `_into` entry point that clears
//! and refills a caller-provided buffer (the zero-allocation steady
//! state — cluster workers recycle their reply buffers through these),
//! and the original allocating signature, now a thin wrapper over the
//! `_into` form. Same order, same bits, only the buffer's origin
//! differs.

use std::ops::Range;

use crate::data::{CsrMatrix, DenseMatrix, Store};
use crate::loss::Loss;

/// Row primitives the generic kernel bodies are written against. Both
/// impls are thin `#[inline]` forwards to the concrete accessors, so
/// each public kernel monomorphizes to one dense and one CSR body.
trait RowOps {
    fn dot2(&self, r: usize, lo: usize, hi: usize, wa: &[f32], wb: &[f32]) -> (f32, f32);
    fn axpy(&self, r: usize, lo: usize, hi: usize, scale: f32, out: &mut [f32]);
}

impl RowOps for DenseMatrix {
    #[inline]
    fn dot2(&self, r: usize, lo: usize, hi: usize, wa: &[f32], wb: &[f32]) -> (f32, f32) {
        self.row_dot2_range(r, lo, hi, wa, wb)
    }

    #[inline]
    fn axpy(&self, r: usize, lo: usize, hi: usize, scale: f32, out: &mut [f32]) {
        self.add_row_scaled_range(r, lo, hi, scale, out)
    }
}

impl RowOps for CsrMatrix {
    #[inline]
    fn dot2(&self, r: usize, lo: usize, hi: usize, wa: &[f32], wb: &[f32]) -> (f32, f32) {
        self.row_dot2_range(r, lo, hi, wa, wb)
    }

    #[inline]
    fn axpy(&self, r: usize, lo: usize, hi: usize, scale: f32, out: &mut [f32]) {
        self.add_row_scaled_range(r, lo, hi, scale, out)
    }
}

/// Batched margins `z_k = x_{rows[k]}[cols] · w` (steps 5-8 of
/// Algorithm 1: the feature-block contribution to `x_j^{B^t} w_{B^t}`).
pub fn partial_z(x: &Store, cols: Range<usize>, w: &[f32], rows: &[u32]) -> Vec<f32> {
    let mut z = Vec::new();
    partial_z_into(x, cols, w, rows, &mut z);
    z
}

/// In-place [`partial_z`]: clears and refills a caller-provided buffer
/// (zero allocations once the buffer's capacity covers the row set).
/// Identical accumulation order, so identical bits.
pub fn partial_z_into(x: &Store, cols: Range<usize>, w: &[f32], rows: &[u32], z: &mut Vec<f32>) {
    debug_assert_eq!(w.len(), cols.len());
    z.clear();
    z.resize(rows.len(), 0.0);
    match x {
        Store::Dense(m) => m.rows_dot_range_into(rows, cols.start, cols.end, w, z),
        Store::Sparse(m) => m.rows_dot_range_into(rows, cols.start, cols.end, w, z),
    }
}

/// Batched gradient slice `g[cols] = Σ_k u_k · x_{rows[k]}[cols]`.
pub fn grad_slice(x: &Store, cols: Range<usize>, rows: &[u32], u: &[f32]) -> Vec<f32> {
    let mut g = Vec::new();
    grad_slice_into(x, cols, rows, u, &mut g);
    g
}

/// In-place [`grad_slice`] (zeroes the buffer, then accumulates in row
/// order — bit-for-bit the allocating path).
pub fn grad_slice_into(x: &Store, cols: Range<usize>, rows: &[u32], u: &[f32], g: &mut Vec<f32>) {
    debug_assert_eq!(rows.len(), u.len());
    g.clear();
    g.resize(cols.len(), 0.0);
    match x {
        Store::Dense(m) => m.add_rows_scaled_range(rows, u, cols.start, cols.end, g),
        Store::Sparse(m) => m.add_rows_scaled_range(rows, u, cols.start, cols.end, g),
    }
}

/// Sampled-width [`partial_z`]: margins over an explicit **sorted
/// block-local column subset** with a compact `w`
/// (`w.len() == idx.len()`), so FLOPs scale with `|B ∩ block|` instead
/// of the block width. Dense blocks gather-dot over the compacted
/// columns ([`DenseMatrix::rows_dot_cols_into`]); CSR blocks intersect
/// each row's stored entries with the subset
/// ([`CsrMatrix::rows_dot_cols_into`]). Matches the masked full-width
/// path to accumulation-order rounding (`tests/sampled.rs`), and is
/// itself deterministic — the sum order depends only on the subset.
pub fn partial_z_cols(x: &Store, idx: &[u32], w: &[f32], rows: &[u32]) -> Vec<f32> {
    let mut z = Vec::new();
    partial_z_cols_into(x, idx, w, rows, &mut z);
    z
}

/// In-place [`partial_z_cols`] (recycled buffer, identical values).
pub fn partial_z_cols_into(x: &Store, idx: &[u32], w: &[f32], rows: &[u32], z: &mut Vec<f32>) {
    debug_assert_eq!(w.len(), idx.len());
    z.clear();
    z.resize(rows.len(), 0.0);
    match x {
        Store::Dense(m) => m.rows_dot_cols_into(rows, idx, w, z),
        Store::Sparse(m) => m.rows_dot_cols_into(rows, idx, w, z),
    }
}

/// Sampled-width [`grad_slice`]: emits the **compact** gradient slice
/// `g[k] = Σ_j u_j · x_{rows[j]}[idx[k]]` (`g.len() == idx.len()`), so
/// both the work and the reply payload scale with `|C ∩ block|`.
pub fn grad_cols(x: &Store, idx: &[u32], rows: &[u32], u: &[f32]) -> Vec<f32> {
    let mut g = Vec::new();
    grad_cols_into(x, idx, rows, u, &mut g);
    g
}

/// In-place [`grad_cols`] (zeroes the buffer, then accumulates in row
/// order like the full-width path).
pub fn grad_cols_into(x: &Store, idx: &[u32], rows: &[u32], u: &[f32], g: &mut Vec<f32>) {
    debug_assert_eq!(rows.len(), u.len());
    g.clear();
    g.resize(idx.len(), 0.0);
    match x {
        Store::Dense(m) => m.add_rows_scaled_cols(rows, u, idx, g),
        Store::Sparse(m) => m.add_rows_scaled_cols(rows, u, idx, g),
    }
}

/// Sampled-width [`partial_u`]: fused subset margin + loss derivative
/// (the `Q == 1` worker fast path under sampling).
pub fn partial_u_cols(loss: Loss, x: &Store, idx: &[u32], w: &[f32], rows: &[u32], y: &[f32]) -> Vec<f32> {
    let mut u = Vec::new();
    partial_u_cols_into(loss, x, idx, w, rows, y, &mut u);
    u
}

/// In-place [`partial_u_cols`].
pub fn partial_u_cols_into(
    loss: Loss,
    x: &Store,
    idx: &[u32],
    w: &[f32],
    rows: &[u32],
    y: &[f32],
    u: &mut Vec<f32>,
) {
    partial_z_cols_into(x, idx, w, rows, u);
    for (uk, &r) in u.iter_mut().zip(rows) {
        *uk = loss.dloss(*uk, y[r as usize]);
    }
}

/// Fused `partial_z` + `dloss_u`: `u_k = f'(x_{rows[k]}[cols]·w, y[rows[k]])`.
/// `y` is the block's full local label vector (length = block rows). The
/// margin buffer is computed with the batched paired dots and turned
/// into `u` in place — one allocation, no label gather.
pub fn partial_u(loss: Loss, x: &Store, cols: Range<usize>, w: &[f32], rows: &[u32], y: &[f32]) -> Vec<f32> {
    let mut u = Vec::new();
    partial_u_into(loss, x, cols, w, rows, y, &mut u);
    u
}

/// In-place [`partial_u`] — margin + derivative into a recycled buffer.
pub fn partial_u_into(
    loss: Loss,
    x: &Store,
    cols: Range<usize>,
    w: &[f32],
    rows: &[u32],
    y: &[f32],
    u: &mut Vec<f32>,
) {
    partial_z_into(x, cols, w, rows, u);
    for (uk, &r) in u.iter_mut().zip(rows) {
        *uk = loss.dloss(*uk, y[r as usize]);
    }
}

/// Fused `partial_z` + `loss_from_z`: `Σ_k f(x_{rows[k]}[cols]·w, y[rows[k]])`
/// (objective evaluation, reduced in row order like the unfused path).
pub fn block_loss(loss: Loss, x: &Store, cols: Range<usize>, w: &[f32], rows: &[u32], y: &[f32]) -> f64 {
    let mut z = Vec::new();
    block_loss_with(loss, x, cols, w, rows, y, &mut z)
}

/// [`block_loss`] with a caller-provided margin scratch buffer (the
/// cluster workers hold one per thread, so steady-state objective
/// evaluations allocate nothing).
pub fn block_loss_with(
    loss: Loss,
    x: &Store,
    cols: Range<usize>,
    w: &[f32],
    rows: &[u32],
    y: &[f32],
    z: &mut Vec<f32>,
) -> f64 {
    partial_z_into(x, cols, w, rows, z);
    z.iter().zip(rows).map(|(&zk, &r)| loss.value(zk, y[r as usize]) as f64).sum()
}

/// L SVRG steps on one sub-block (Algorithm 1 step 16), last iterate.
/// The current and reference margins of each step share one traversal
/// of the sampled row ([`DenseMatrix::row_dot2_range`] /
/// [`CsrMatrix::row_dot2_range`]).
#[allow(clippy::too_many_arguments)]
pub fn svrg_inner(
    loss: Loss,
    x: &Store,
    y: &[f32],
    cols: Range<usize>,
    w0: &[f32],
    wt: &[f32],
    mu: &[f32],
    idx: &[u32],
    gamma: f32,
) -> Vec<f32> {
    let mut w = Vec::new();
    svrg_inner_into(loss, x, y, cols, w0, wt, mu, idx, gamma, &mut w);
    w
}

/// In-place [`svrg_inner`]: `out` becomes `w^{(L)}` (recycled buffer,
/// zero steady-state allocations, identical arithmetic).
#[allow(clippy::too_many_arguments)]
pub fn svrg_inner_into(
    loss: Loss,
    x: &Store,
    y: &[f32],
    cols: Range<usize>,
    w0: &[f32],
    wt: &[f32],
    mu: &[f32],
    idx: &[u32],
    gamma: f32,
    out: &mut Vec<f32>,
) {
    // the accumulator is untouched when avg = false (resized to 0)
    let mut acc = Vec::new();
    match x {
        Store::Dense(m) => {
            svrg_impl_into(loss, m, y, cols, w0, wt, mu, idx, gamma, false, out, &mut acc)
        }
        Store::Sparse(m) => {
            svrg_impl_into(loss, m, y, cols, w0, wt, mu, idx, gamma, false, out, &mut acc)
        }
    }
}

/// RADiSA-avg's combiner: same steps as [`svrg_inner`] but returns the
/// uniform (Polyak) average of the L iterates.
#[allow(clippy::too_many_arguments)]
pub fn svrg_inner_avg(
    loss: Loss,
    x: &Store,
    y: &[f32],
    cols: Range<usize>,
    w0: &[f32],
    wt: &[f32],
    mu: &[f32],
    idx: &[u32],
    gamma: f32,
) -> Vec<f32> {
    let (mut acc, mut w) = (Vec::new(), Vec::new());
    svrg_inner_avg_into(loss, x, y, cols, w0, wt, mu, idx, gamma, &mut acc, &mut w);
    acc
}

/// In-place [`svrg_inner_avg`]: `out` becomes the iterate average,
/// `w_scratch` holds the working iterate (both recycled).
#[allow(clippy::too_many_arguments)]
pub fn svrg_inner_avg_into(
    loss: Loss,
    x: &Store,
    y: &[f32],
    cols: Range<usize>,
    w0: &[f32],
    wt: &[f32],
    mu: &[f32],
    idx: &[u32],
    gamma: f32,
    out: &mut Vec<f32>,
    w_scratch: &mut Vec<f32>,
) {
    match x {
        Store::Dense(m) => {
            svrg_impl_into(loss, m, y, cols, w0, wt, mu, idx, gamma, true, w_scratch, out)
        }
        Store::Sparse(m) => {
            svrg_impl_into(loss, m, y, cols, w0, wt, mu, idx, gamma, true, w_scratch, out)
        }
    }
}

#[allow(clippy::too_many_arguments)]
fn svrg_impl_into<M: RowOps>(
    loss: Loss,
    m: &M,
    y: &[f32],
    cols: Range<usize>,
    w0: &[f32],
    wt: &[f32],
    mu: &[f32],
    idx: &[u32],
    gamma: f32,
    avg: bool,
    w: &mut Vec<f32>,
    acc: &mut Vec<f32>,
) {
    let mt = cols.len();
    debug_assert!(w0.len() == mt && wt.len() == mt && mu.len() == mt);
    let (lo, hi) = (cols.start, cols.end);
    w.clear();
    w.extend_from_slice(w0);
    acc.clear();
    acc.resize(if avg { mt } else { 0 }, 0.0);
    for &j in idx {
        let j = j as usize;
        // fused: current + reference margins in one traversal of row j
        let (z_cur, z_ref) = m.dot2(j, lo, hi, w, wt);
        let du = loss.dloss(z_cur, y[j]) - loss.dloss(z_ref, y[j]);
        // w -= γ·(du·x_j + µ)
        if du != 0.0 {
            m.axpy(j, lo, hi, -gamma * du, w);
        }
        for (wk, &mk) in w.iter_mut().zip(mu) {
            *wk -= gamma * mk;
        }
        if avg {
            for (a, &wk) in acc.iter_mut().zip(w.iter()) {
                *a += wk;
            }
        }
    }
    if avg {
        // uniform (Polyak) average of all L iterates
        let inv = 1.0 / idx.len() as f32;
        for a in acc.iter_mut() {
            *a *= inv;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::assert_close;
    use crate::engine::testutil::block;

    #[test]
    fn partial_z_matches_per_row_store_path() {
        let (x, _) = block(10, 12, 1);
        let w: Vec<f32> = (0..5).map(|i| 0.2 * i as f32 - 0.4).collect();
        let rows: Vec<u32> = vec![0, 3, 7, 9];
        let z = partial_z(&x, 4..9, &w, &rows);
        let want: Vec<f32> = rows.iter().map(|&r| x.row_dot_range(r as usize, 4, 9, &w)).collect();
        assert_eq!(z, want);
    }

    #[test]
    fn grad_slice_matches_per_row_store_path() {
        let (x, _) = block(8, 6, 2);
        let rows: Vec<u32> = (0..8).collect();
        let u: Vec<f32> = (0..8).map(|v| if v % 2 == 0 { 0.0 } else { v as f32 * 0.1 }).collect();
        let g = grad_slice(&x, 1..6, &rows, &u);
        let mut want = vec![0.0f32; 5];
        for (&r, &uk) in rows.iter().zip(&u) {
            x.add_row_scaled_range(r as usize, 1, 6, uk, &mut want);
        }
        assert_eq!(g, want);
    }

    #[test]
    fn fused_partial_u_and_block_loss_match_composition() {
        let (x, y) = block(12, 8, 3);
        let w: Vec<f32> = (0..8).map(|i| (i as f32 * 0.33).sin()).collect();
        let rows: Vec<u32> = vec![1, 4, 4, 11];
        for loss in Loss::ALL {
            let z = partial_z(&x, 0..8, &w, &rows);
            let y_rows: Vec<f32> = rows.iter().map(|&r| y[r as usize]).collect();
            let want_u: Vec<f32> =
                z.iter().zip(&y_rows).map(|(&zk, &yk)| loss.dloss(zk, yk)).collect();
            assert_eq!(partial_u(loss, &x, 0..8, &w, &rows, &y), want_u, "{loss}");
            let want_l: f64 =
                z.iter().zip(&y_rows).map(|(&zk, &yk)| loss.value(zk, yk) as f64).sum();
            assert_eq!(block_loss(loss, &x, 0..8, &w, &rows, &y), want_l, "{loss}");
        }
    }

    #[test]
    fn subset_kernels_match_masked_full_width() {
        let (x, y) = block(10, 12, 11);
        let idx: Vec<u32> = vec![1, 4, 5, 9, 11];
        let w: Vec<f32> = (0..5).map(|i| 0.3 - 0.1 * i as f32).collect();
        let mut w_full = vec![0.0f32; 12];
        for (k, &i) in idx.iter().enumerate() {
            w_full[i as usize] = w[k];
        }
        let rows: Vec<u32> = vec![0, 2, 7, 7, 9];
        let z = partial_z_cols(&x, &idx, &w, &rows);
        let z_ref = partial_z(&x, 0..12, &w_full, &rows);
        for (a, b) in z.iter().zip(&z_ref) {
            assert_close!(*a, *b, 1e-5, 1e-6);
        }
        let u: Vec<f32> = (0..5).map(|i| if i == 2 { 0.0 } else { i as f32 * 0.2 - 0.3 }).collect();
        let g = grad_cols(&x, &idx, &rows, &u);
        let g_ref = grad_slice(&x, 0..12, &rows, &u);
        assert_eq!(g.len(), idx.len());
        for (k, &i) in idx.iter().enumerate() {
            assert_close!(g[k], g_ref[i as usize], 1e-5, 1e-6);
        }
        for loss in Loss::ALL {
            let uc = partial_u_cols(loss, &x, &idx, &w, &rows, &y);
            let want: Vec<f32> =
                z.iter().zip(&rows).map(|(&zk, &r)| loss.dloss(zk, y[r as usize])).collect();
            assert_eq!(uc, want, "{loss}");
        }
    }

    #[test]
    fn subset_kernels_handle_empty_sets() {
        let (x, y) = block(5, 4, 12);
        // empty subset: zero-length margins contribution, empty grad
        assert_eq!(partial_z_cols(&x, &[], &[], &[0, 1]), vec![0.0f32; 2]);
        assert!(grad_cols(&x, &[], &[0, 1], &[0.5, 0.5]).is_empty());
        // empty row set
        assert!(partial_z_cols(&x, &[1, 3], &[0.5, 0.5], &[]).is_empty());
        assert_eq!(grad_cols(&x, &[1, 3], &[], &[]), vec![0.0f32; 2]);
        assert!(partial_u_cols(Loss::Hinge, &x, &[0], &[0.5], &[], &y).is_empty());
    }

    #[test]
    fn subset_into_variants_on_dirty_buffers_match_allocating() {
        let (x, y) = block(9, 8, 13);
        let idx: Vec<u32> = vec![0, 2, 6];
        let w: Vec<f32> = vec![0.4, -0.2, 0.9];
        let rows: Vec<u32> = vec![3, 8, 1];
        let u: Vec<f32> = vec![0.1, -0.5, 0.7];
        let mut dirty = vec![5.0f32; 11];
        partial_z_cols_into(&x, &idx, &w, &rows, &mut dirty);
        assert_eq!(dirty, partial_z_cols(&x, &idx, &w, &rows));
        dirty.push(-2.0);
        grad_cols_into(&x, &idx, &rows, &u, &mut dirty);
        assert_eq!(dirty, grad_cols(&x, &idx, &rows, &u));
        dirty.push(3.0);
        partial_u_cols_into(Loss::Logistic, &x, &idx, &w, &rows, &y, &mut dirty);
        assert_eq!(dirty, partial_u_cols(Loss::Logistic, &x, &idx, &w, &rows, &y));
    }

    #[test]
    fn empty_row_set_yields_zeros() {
        let (x, y) = block(5, 4, 4);
        let w = vec![0.5f32; 4];
        assert!(partial_z(&x, 0..4, &w, &[]).is_empty());
        assert!(partial_u(Loss::Hinge, &x, 0..4, &w, &[], &y).is_empty());
        assert_eq!(grad_slice(&x, 0..4, &[], &[]), vec![0.0f32; 4]);
        assert_eq!(block_loss(Loss::Hinge, &x, 0..4, &w, &[], &y), 0.0);
    }

    #[test]
    fn into_variants_on_dirty_buffers_match_allocating_path() {
        // recycled buffers arrive with stale contents and excess length;
        // every _into kernel must clear/resize before writing
        let (x, y) = block(11, 9, 7);
        let w: Vec<f32> = (0..6).map(|i| (i as f32 * 0.27).sin()).collect();
        let rows: Vec<u32> = vec![2, 9, 0, 5, 5];
        let u_in: Vec<f32> = (0..5).map(|v| v as f32 * 0.2 - 0.3).collect();
        let mut dirty = vec![9.0f32; 17];
        partial_z_into(&x, 1..7, &w, &rows, &mut dirty);
        assert_eq!(dirty, partial_z(&x, 1..7, &w, &rows));
        dirty.resize(13, -3.0);
        grad_slice_into(&x, 1..7, &rows, &u_in, &mut dirty);
        assert_eq!(dirty, grad_slice(&x, 1..7, &rows, &u_in));
        dirty.push(42.0);
        partial_u_into(Loss::Logistic, &x, 1..7, &w, &rows, &y, &mut dirty);
        assert_eq!(dirty, partial_u(Loss::Logistic, &x, 1..7, &w, &rows, &y));
        dirty.push(7.0);
        let got = block_loss_with(Loss::Hinge, &x, 1..7, &w, &rows, &y, &mut dirty);
        assert_eq!(got, block_loss(Loss::Hinge, &x, 1..7, &w, &rows, &y));

        let w0: Vec<f32> = (0..6).map(|i| 0.1 * i as f32 - 0.2).collect();
        let wt: Vec<f32> = (0..6).map(|i| (i as f32 * 0.4).cos() * 0.3).collect();
        let mu: Vec<f32> = (0..6).map(|i| 0.05 * i as f32).collect();
        let idx: Vec<u32> = vec![3, 0, 10, 7, 3];
        let mut out = vec![1.0f32; 2];
        svrg_inner_into(Loss::Hinge, &x, &y, 1..7, &w0, &wt, &mu, &idx, 0.07, &mut out);
        assert_eq!(out, svrg_inner(Loss::Hinge, &x, &y, 1..7, &w0, &wt, &mu, &idx, 0.07));
        let mut scratch = vec![5.0f32; 40];
        out.push(0.5);
        svrg_inner_avg_into(
            Loss::Hinge, &x, &y, 1..7, &w0, &wt, &mu, &idx, 0.07, &mut out, &mut scratch,
        );
        assert_eq!(out, svrg_inner_avg(Loss::Hinge, &x, &y, 1..7, &w0, &wt, &mu, &idx, 0.07));
    }

    #[test]
    fn svrg_zero_gamma_is_identity() {
        let (x, y) = block(6, 4, 5);
        let w0 = vec![0.3f32; 4];
        let out = svrg_inner(Loss::Hinge, &x, &y, 0..4, &w0, &w0, &[0.0; 4], &[0, 1, 2], 0.0);
        assert_eq!(out, w0);
    }

    #[test]
    fn svrg_avg_of_constant_trajectory_is_the_constant() {
        let (x, y) = block(6, 4, 6);
        let w0 = vec![0.25f32; 4];
        // γ = 0 keeps every iterate at w0, so the average is w0
        let out = svrg_inner_avg(Loss::Hinge, &x, &y, 0..4, &w0, &w0, &[0.0; 4], &[2, 5, 1], 0.0);
        for v in out {
            assert_close!(v, 0.25, 1e-6, 1e-7);
        }
    }
}
