//! Pure-rust reference engine.
//!
//! Implements the identical math as the Pallas kernels (see
//! `python/compile/kernels/ref.py`) directly over [`Store`] blocks, which
//! makes it sparse-aware: §5.2's CSR datasets never densify on this path.

use std::ops::Range;

use super::{BlockKey, ComputeEngine};
use crate::data::Store;
use crate::loss::Loss;

/// Always-available rust backend.
#[derive(Debug, Default, Clone, Copy)]
pub struct NativeEngine;

impl ComputeEngine for NativeEngine {
    fn name(&self) -> &'static str {
        "native"
    }

    fn partial_z(&self, _key: BlockKey, x: &Store, cols: Range<usize>, w: &[f32], rows: &[u32]) -> Vec<f32> {
        debug_assert_eq!(w.len(), cols.len());
        rows.iter()
            .map(|&r| x.row_dot_range(r as usize, cols.start, cols.end, w))
            .collect()
    }

    fn dloss_u(&self, loss: Loss, z: &[f32], y: &[f32]) -> Vec<f32> {
        debug_assert_eq!(z.len(), y.len());
        z.iter().zip(y).map(|(&z, &y)| loss.dloss(z, y)).collect()
    }

    fn grad_slice(&self, _key: BlockKey, x: &Store, cols: Range<usize>, rows: &[u32], u: &[f32]) -> Vec<f32> {
        debug_assert_eq!(rows.len(), u.len());
        let mut g = vec![0.0f32; cols.len()];
        for (&r, &uk) in rows.iter().zip(u) {
            x.add_row_scaled_range(r as usize, cols.start, cols.end, uk, &mut g);
        }
        g
    }

    fn svrg_inner(
        &self,
        _key: BlockKey,
        loss: Loss,
        x: &Store,
        y: &[f32],
        cols: Range<usize>,
        w0: &[f32],
        wt: &[f32],
        mu: &[f32],
        idx: &[u32],
        gamma: f32,
    ) -> Vec<f32> {
        let mt = cols.len();
        debug_assert!(w0.len() == mt && wt.len() == mt && mu.len() == mt);
        let mut w = w0.to_vec();
        // Reusable buffer for −γ(u_cur − u_ref)·x_j − γµ updates: the axpy
        // is applied in place, no per-step allocation.
        for &j in idx {
            let j = j as usize;
            let z_cur = x.row_dot_range(j, cols.start, cols.end, &w);
            let z_ref = x.row_dot_range(j, cols.start, cols.end, wt);
            let u_cur = loss.dloss(z_cur, y[j]);
            let u_ref = loss.dloss(z_ref, y[j]);
            let du = u_cur - u_ref;
            // w -= γ·(du·x_j + µ)
            if du != 0.0 {
                x.add_row_scaled_range(j, cols.start, cols.end, -gamma * du, &mut w);
            }
            for (wk, &mk) in w.iter_mut().zip(mu) {
                *wk -= gamma * mk;
            }
        }
        w
    }

    fn loss_from_z(&self, loss: Loss, z: &[f32], y: &[f32]) -> f64 {
        z.iter().zip(y).map(|(&z, &y)| loss.value(z, y) as f64).sum()
    }

    fn svrg_inner_avg(
        &self,
        _key: BlockKey,
        loss: Loss,
        x: &Store,
        y: &[f32],
        cols: Range<usize>,
        w0: &[f32],
        wt: &[f32],
        mu: &[f32],
        idx: &[u32],
        gamma: f32,
    ) -> Vec<f32> {
        let mt = cols.len();
        let steps = idx.len();
        let tail_start = 0; // uniform (Polyak) average of all L iterates
        let mut w = w0.to_vec();
        let mut acc = vec![0.0f32; mt];
        for (i, &j) in idx.iter().enumerate() {
            let j = j as usize;
            let z_cur = x.row_dot_range(j, cols.start, cols.end, &w);
            let z_ref = x.row_dot_range(j, cols.start, cols.end, wt);
            let du = loss.dloss(z_cur, y[j]) - loss.dloss(z_ref, y[j]);
            if du != 0.0 {
                x.add_row_scaled_range(j, cols.start, cols.end, -gamma * du, &mut w);
            }
            for (wk, &mk) in w.iter_mut().zip(mu) {
                *wk -= gamma * mk;
            }
            if i >= tail_start {
                for (a, &wk) in acc.iter_mut().zip(&w) {
                    *a += wk;
                }
            }
        }
        let inv = 1.0 / (steps - tail_start) as f32;
        for a in acc.iter_mut() {
            *a *= inv;
        }
        acc
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::assert_close;
    use crate::engine::testutil::block;

    const K: BlockKey = BlockKey { p: 0, q: 0 };

    #[test]
    fn partial_z_matches_naive() {
        let (x, _) = block(10, 6, 1);
        let w = vec![0.5f32; 3];
        let rows: Vec<u32> = vec![0, 3, 7];
        let z = NativeEngine.partial_z(K, &x, 2..5, &w, &rows);
        for (k, &r) in rows.iter().enumerate() {
            let mut buf = vec![0.0f32; 3];
            x.copy_row_range(r as usize, 2, 5, &mut buf);
            let naive: f32 = buf.iter().map(|v| v * 0.5).sum();
            assert_close!(z[k], naive, 1e-4, 1e-5);
        }
    }

    #[test]
    fn grad_slice_matches_transpose_product() {
        let (x, _) = block(8, 5, 2);
        let rows: Vec<u32> = (0..8).collect();
        let u: Vec<f32> = (0..8).map(|v| v as f32 * 0.1 - 0.3).collect();
        let g = NativeEngine.grad_slice(K, &x, 0..5, &rows, &u);
        let mut want = vec![0.0f32; 5];
        for r in 0..8 {
            let mut buf = vec![0.0f32; 5];
            x.copy_row_range(r, 0, 5, &mut buf);
            for c in 0..5 {
                want[c] += u[r] * buf[c];
            }
        }
        for c in 0..5 {
            assert_close!(g[c], want[c], 1e-4, 1e-4);
        }
    }

    #[test]
    fn svrg_zero_gamma_identity() {
        let (x, y) = block(6, 4, 3);
        let w0 = vec![0.3f32; 4];
        let out = NativeEngine.svrg_inner(
            K,
            Loss::Hinge, &x, &y, 0..4, &w0, &w0, &[0.0; 4], &[0, 1, 2], 0.0,
        );
        assert_eq!(out, w0);
    }

    #[test]
    fn svrg_first_step_is_minus_gamma_mu_when_w_eq_wt() {
        let (x, y) = block(6, 4, 4);
        let w0 = vec![0.3f32; 4];
        let mu = vec![0.25f32; 4];
        let out = NativeEngine.svrg_inner(K, Loss::Hinge, &x, &y, 0..4, &w0, &w0, &mu, &[2], 0.1);
        for k in 0..4 {
            assert_close!(out[k], 0.3 - 0.1 * 0.25, 1e-4, 1e-6);
        }
    }

    #[test]
    fn loss_from_z_sums() {
        let z = [0.0f32, 2.0];
        let y = [1.0f32, 1.0];
        // hinge: 1 + 0
        assert_close!(NativeEngine.loss_from_z(Loss::Hinge, &z, &y) as f32, 1.0);
    }
}
