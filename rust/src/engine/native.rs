//! Pure-rust reference engine.
//!
//! Implements the identical math as the Pallas kernels (see
//! `python/compile/kernels/ref.py`) directly over [`Store`] blocks, which
//! makes it sparse-aware: §5.2's CSR datasets never densify on this path.
//!
//! Since the batched-kernel refactor this type is a thin adapter over
//! [`super::kernels`]: every per-block operation resolves the storage
//! format once per call and runs the monomorphized batched loops, and
//! the fused entry points ([`ComputeEngine::partial_u`],
//! [`ComputeEngine::block_loss`], the one-traversal SVRG step) are
//! overridden with their fused implementations. The `_into` entry
//! points are overridden too, forwarding to the true in-place kernels —
//! this is what makes the cluster's recycled reply buffers
//! allocation-free on the native path (engines relying on the trait
//! defaults still work, they just allocate internally).

use std::ops::Range;

use super::{kernels, BlockKey, ComputeEngine};
use crate::data::Store;
use crate::loss::Loss;

/// Always-available rust backend.
#[derive(Debug, Default, Clone, Copy)]
pub struct NativeEngine;

impl ComputeEngine for NativeEngine {
    fn name(&self) -> &'static str {
        "native"
    }

    fn partial_z(&self, _key: BlockKey, x: &Store, cols: Range<usize>, w: &[f32], rows: &[u32]) -> Vec<f32> {
        kernels::partial_z(x, cols, w, rows)
    }

    fn partial_z_into(
        &self,
        _key: BlockKey,
        x: &Store,
        cols: Range<usize>,
        w: &[f32],
        rows: &[u32],
        out: &mut Vec<f32>,
    ) {
        kernels::partial_z_into(x, cols, w, rows, out)
    }

    fn partial_z_cols_into(
        &self,
        _key: BlockKey,
        x: &Store,
        idx: &[u32],
        w: &[f32],
        rows: &[u32],
        out: &mut Vec<f32>,
    ) {
        kernels::partial_z_cols_into(x, idx, w, rows, out)
    }

    fn dloss_u(&self, loss: Loss, z: &[f32], y: &[f32]) -> Vec<f32> {
        debug_assert_eq!(z.len(), y.len());
        z.iter().zip(y).map(|(&z, &y)| loss.dloss(z, y)).collect()
    }

    fn dloss_u_into(&self, loss: Loss, z: &[f32], y: &[f32], out: &mut Vec<f32>) {
        debug_assert_eq!(z.len(), y.len());
        out.clear();
        out.extend(z.iter().zip(y).map(|(&z, &y)| loss.dloss(z, y)));
    }

    fn partial_u(&self, _key: BlockKey, loss: Loss, x: &Store, cols: Range<usize>, w: &[f32], rows: &[u32], y: &[f32]) -> Vec<f32> {
        kernels::partial_u(loss, x, cols, w, rows, y)
    }

    fn partial_u_into(
        &self,
        _key: BlockKey,
        loss: Loss,
        x: &Store,
        cols: Range<usize>,
        w: &[f32],
        rows: &[u32],
        y: &[f32],
        out: &mut Vec<f32>,
    ) {
        kernels::partial_u_into(loss, x, cols, w, rows, y, out)
    }

    fn partial_u_cols_into(
        &self,
        _key: BlockKey,
        loss: Loss,
        x: &Store,
        idx: &[u32],
        w: &[f32],
        rows: &[u32],
        y: &[f32],
        out: &mut Vec<f32>,
    ) {
        kernels::partial_u_cols_into(loss, x, idx, w, rows, y, out)
    }

    fn block_loss(&self, _key: BlockKey, loss: Loss, x: &Store, cols: Range<usize>, w: &[f32], rows: &[u32], y: &[f32]) -> f64 {
        kernels::block_loss(loss, x, cols, w, rows, y)
    }

    fn block_loss_scratch(
        &self,
        _key: BlockKey,
        loss: Loss,
        x: &Store,
        cols: Range<usize>,
        w: &[f32],
        rows: &[u32],
        y: &[f32],
        z_scratch: &mut Vec<f32>,
    ) -> f64 {
        kernels::block_loss_with(loss, x, cols, w, rows, y, z_scratch)
    }

    fn grad_slice(&self, _key: BlockKey, x: &Store, cols: Range<usize>, rows: &[u32], u: &[f32]) -> Vec<f32> {
        kernels::grad_slice(x, cols, rows, u)
    }

    fn grad_slice_into(
        &self,
        _key: BlockKey,
        x: &Store,
        cols: Range<usize>,
        rows: &[u32],
        u: &[f32],
        out: &mut Vec<f32>,
    ) {
        kernels::grad_slice_into(x, cols, rows, u, out)
    }

    fn grad_cols_into(
        &self,
        _key: BlockKey,
        x: &Store,
        idx: &[u32],
        rows: &[u32],
        u: &[f32],
        out: &mut Vec<f32>,
    ) {
        kernels::grad_cols_into(x, idx, rows, u, out)
    }

    fn svrg_inner(
        &self,
        _key: BlockKey,
        loss: Loss,
        x: &Store,
        y: &[f32],
        cols: Range<usize>,
        w0: &[f32],
        wt: &[f32],
        mu: &[f32],
        idx: &[u32],
        gamma: f32,
    ) -> Vec<f32> {
        kernels::svrg_inner(loss, x, y, cols, w0, wt, mu, idx, gamma)
    }

    fn svrg_inner_into(
        &self,
        _key: BlockKey,
        loss: Loss,
        x: &Store,
        y: &[f32],
        cols: Range<usize>,
        w0: &[f32],
        wt: &[f32],
        mu: &[f32],
        idx: &[u32],
        gamma: f32,
        out: &mut Vec<f32>,
    ) {
        kernels::svrg_inner_into(loss, x, y, cols, w0, wt, mu, idx, gamma, out)
    }

    fn loss_from_z(&self, loss: Loss, z: &[f32], y: &[f32]) -> f64 {
        z.iter().zip(y).map(|(&z, &y)| loss.value(z, y) as f64).sum()
    }

    fn svrg_inner_avg(
        &self,
        _key: BlockKey,
        loss: Loss,
        x: &Store,
        y: &[f32],
        cols: Range<usize>,
        w0: &[f32],
        wt: &[f32],
        mu: &[f32],
        idx: &[u32],
        gamma: f32,
    ) -> Vec<f32> {
        kernels::svrg_inner_avg(loss, x, y, cols, w0, wt, mu, idx, gamma)
    }

    fn svrg_inner_avg_into(
        &self,
        _key: BlockKey,
        loss: Loss,
        x: &Store,
        y: &[f32],
        cols: Range<usize>,
        w0: &[f32],
        wt: &[f32],
        mu: &[f32],
        idx: &[u32],
        gamma: f32,
        out: &mut Vec<f32>,
        w_scratch: &mut Vec<f32>,
    ) {
        kernels::svrg_inner_avg_into(loss, x, y, cols, w0, wt, mu, idx, gamma, out, w_scratch)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::assert_close;
    use crate::engine::testutil::block;

    const K: BlockKey = BlockKey { p: 0, q: 0 };

    #[test]
    fn partial_z_matches_naive() {
        let (x, _) = block(10, 6, 1);
        let w = vec![0.5f32; 3];
        let rows: Vec<u32> = vec![0, 3, 7];
        let z = NativeEngine.partial_z(K, &x, 2..5, &w, &rows);
        for (k, &r) in rows.iter().enumerate() {
            let mut buf = vec![0.0f32; 3];
            x.copy_row_range(r as usize, 2, 5, &mut buf);
            let naive: f32 = buf.iter().map(|v| v * 0.5).sum();
            assert_close!(z[k], naive, 1e-4, 1e-5);
        }
    }

    #[test]
    fn grad_slice_matches_transpose_product() {
        let (x, _) = block(8, 5, 2);
        let rows: Vec<u32> = (0..8).collect();
        let u: Vec<f32> = (0..8).map(|v| v as f32 * 0.1 - 0.3).collect();
        let g = NativeEngine.grad_slice(K, &x, 0..5, &rows, &u);
        let mut want = vec![0.0f32; 5];
        for r in 0..8 {
            let mut buf = vec![0.0f32; 5];
            x.copy_row_range(r, 0, 5, &mut buf);
            for c in 0..5 {
                want[c] += u[r] * buf[c];
            }
        }
        for c in 0..5 {
            assert_close!(g[c], want[c], 1e-4, 1e-4);
        }
    }

    #[test]
    fn svrg_zero_gamma_identity() {
        let (x, y) = block(6, 4, 3);
        let w0 = vec![0.3f32; 4];
        let out = NativeEngine.svrg_inner(
            K,
            Loss::Hinge, &x, &y, 0..4, &w0, &w0, &[0.0; 4], &[0, 1, 2], 0.0,
        );
        assert_eq!(out, w0);
    }

    #[test]
    fn svrg_first_step_is_minus_gamma_mu_when_w_eq_wt() {
        let (x, y) = block(6, 4, 4);
        let w0 = vec![0.3f32; 4];
        let mu = vec![0.25f32; 4];
        let out = NativeEngine.svrg_inner(K, Loss::Hinge, &x, &y, 0..4, &w0, &w0, &mu, &[2], 0.1);
        for k in 0..4 {
            assert_close!(out[k], 0.3 - 0.1 * 0.25, 1e-4, 1e-6);
        }
    }

    #[test]
    fn loss_from_z_sums() {
        let z = [0.0f32, 2.0];
        let y = [1.0f32, 1.0];
        // hinge: 1 + 0
        assert_close!(NativeEngine.loss_from_z(Loss::Hinge, &z, &y) as f32, 1.0);
    }

    #[test]
    fn into_overrides_match_allocating_methods() {
        let (x, y) = block(10, 8, 9);
        let w: Vec<f32> = (0..8).map(|i| (i as f32 * 0.19).sin() * 0.5).collect();
        let rows: Vec<u32> = vec![1, 6, 6, 9, 0];
        let mut buf = vec![7.0f32; 3];
        NativeEngine.partial_z_into(K, &x, 0..8, &w, &rows, &mut buf);
        assert_eq!(buf, NativeEngine.partial_z(K, &x, 0..8, &w, &rows));
        NativeEngine.partial_u_into(K, Loss::Hinge, &x, 0..8, &w, &rows, &y, &mut buf);
        assert_eq!(buf, NativeEngine.partial_u(K, Loss::Hinge, &x, 0..8, &w, &rows, &y));
        let u: Vec<f32> = (0..5).map(|v| v as f32 * 0.3 - 0.6).collect();
        NativeEngine.grad_slice_into(K, &x, 0..8, &rows, &u, &mut buf);
        assert_eq!(buf, NativeEngine.grad_slice(K, &x, 0..8, &rows, &u));
        let mut scratch = Vec::new();
        let got =
            NativeEngine.block_loss_scratch(K, Loss::Hinge, &x, 0..8, &w, &rows, &y, &mut scratch);
        assert_eq!(got, NativeEngine.block_loss(K, Loss::Hinge, &x, 0..8, &w, &rows, &y));
        let z = NativeEngine.partial_z(K, &x, 0..8, &w, &rows);
        let y_rows: Vec<f32> = rows.iter().map(|&r| y[r as usize]).collect();
        NativeEngine.dloss_u_into(Loss::Logistic, &z, &y_rows, &mut buf);
        assert_eq!(buf, NativeEngine.dloss_u(Loss::Logistic, &z, &y_rows));
    }

    /// An engine that deliberately relies on every trait default — the
    /// stand-in for the XLA engine (and any external backend) in tests
    /// that must run without the `xla` feature.
    struct DefaultEngine;

    impl ComputeEngine for DefaultEngine {
        fn name(&self) -> &'static str {
            "default"
        }

        fn partial_z(
            &self,
            k: BlockKey,
            x: &Store,
            cols: std::ops::Range<usize>,
            w: &[f32],
            rows: &[u32],
        ) -> Vec<f32> {
            NativeEngine.partial_z(k, x, cols, w, rows)
        }

        fn dloss_u(&self, loss: Loss, z: &[f32], y: &[f32]) -> Vec<f32> {
            NativeEngine.dloss_u(loss, z, y)
        }

        fn grad_slice(
            &self,
            k: BlockKey,
            x: &Store,
            cols: std::ops::Range<usize>,
            rows: &[u32],
            u: &[f32],
        ) -> Vec<f32> {
            NativeEngine.grad_slice(k, x, cols, rows, u)
        }

        fn svrg_inner(
            &self,
            k: BlockKey,
            loss: Loss,
            x: &Store,
            y: &[f32],
            cols: std::ops::Range<usize>,
            w0: &[f32],
            wt: &[f32],
            mu: &[f32],
            idx: &[u32],
            gamma: f32,
        ) -> Vec<f32> {
            NativeEngine.svrg_inner(k, loss, x, y, cols, w0, wt, mu, idx, gamma)
        }

        fn loss_from_z(&self, loss: Loss, z: &[f32], y: &[f32]) -> f64 {
            NativeEngine.loss_from_z(loss, z, y)
        }

        fn svrg_inner_avg(
            &self,
            k: BlockKey,
            loss: Loss,
            x: &Store,
            y: &[f32],
            cols: std::ops::Range<usize>,
            w0: &[f32],
            wt: &[f32],
            mu: &[f32],
            idx: &[u32],
            gamma: f32,
        ) -> Vec<f32> {
            NativeEngine.svrg_inner_avg(k, loss, x, y, cols, w0, wt, mu, idx, gamma)
        }
    }

    #[test]
    fn subset_overrides_match_densify_defaults_to_tolerance() {
        // the native subset kernels vs the trait's scatter/gather
        // defaults (what a default-relying engine like XLA executes):
        // same numbers up to accumulation-order rounding
        let (x, y) = block(12, 10, 21);
        let idx: Vec<u32> = vec![0, 3, 4, 8];
        let w: Vec<f32> = vec![0.5, -0.25, 0.8, -0.6];
        let rows: Vec<u32> = vec![1, 5, 5, 11, 0];
        let u: Vec<f32> = vec![0.2, 0.0, -0.7, 0.4, 1.1];
        let (mut a, mut b) = (Vec::new(), Vec::new());
        NativeEngine.partial_z_cols_into(K, &x, &idx, &w, &rows, &mut a);
        DefaultEngine.partial_z_cols_into(K, &x, &idx, &w, &rows, &mut b);
        crate::util::testing::assert_close_slice(&a, &b, 1e-5, 1e-6, "partial_z_cols");
        NativeEngine.partial_u_cols_into(K, Loss::Logistic, &x, &idx, &w, &rows, &y, &mut a);
        DefaultEngine.partial_u_cols_into(K, Loss::Logistic, &x, &idx, &w, &rows, &y, &mut b);
        crate::util::testing::assert_close_slice(&a, &b, 1e-5, 1e-6, "partial_u_cols");
        NativeEngine.grad_cols_into(K, &x, &idx, &rows, &u, &mut a);
        DefaultEngine.grad_cols_into(K, &x, &idx, &rows, &u, &mut b);
        crate::util::testing::assert_close_slice(&a, &b, 1e-5, 1e-6, "grad_cols");
        assert_eq!(a.len(), idx.len(), "compact slice length");
    }

    #[test]
    fn fused_entry_points_match_default_composition() {
        // the trait's default partial_u/block_loss compose partial_z +
        // dloss_u / loss_from_z; the native overrides fuse the passes —
        // results must be bit-identical
        let (x, y) = block(9, 7, 8);
        let w: Vec<f32> = (0..7).map(|i| (i as f32 * 0.21).cos() * 0.4).collect();
        let rows: Vec<u32> = vec![0, 2, 5, 8];
        for loss in Loss::ALL {
            let z = NativeEngine.partial_z(K, &x, 0..7, &w, &rows);
            let y_rows: Vec<f32> = rows.iter().map(|&r| y[r as usize]).collect();
            assert_eq!(
                NativeEngine.partial_u(K, loss, &x, 0..7, &w, &rows, &y),
                NativeEngine.dloss_u(loss, &z, &y_rows),
                "{loss}"
            );
            assert_eq!(
                NativeEngine.block_loss(K, loss, &x, 0..7, &w, &rows, &y),
                NativeEngine.loss_from_z(loss, &z, &y_rows),
                "{loss}"
            );
        }
    }
}
