//! The outer training loop (leader side).

use std::sync::Arc;
use std::time::Instant;

use anyhow::{Context, Result};

use super::sampling::{self, SampleSets};
use crate::cluster::{Cluster, CostModel, SimNet, SvrgTask};
use crate::config::{AlgorithmKind, EngineKind, ExperimentConfig};
use crate::data::{Dataset, Grid};
use crate::engine::{ComputeEngine, NativeEngine, XlaEngine};
use crate::metrics::{History, IterRecord};
use crate::runtime::XlaRuntime;
use crate::util::rng::Rng;

/// Result of one training run.
pub struct TrainOutcome {
    /// final parameter vector ω^T
    pub w: Vec<f32>,
    pub history: History,
    /// simulated-network totals for reporting
    pub comm_bytes: u64,
    pub comm_msgs: u64,
}

/// Materialize the dataset from the config and train.
pub fn train(cfg: &ExperimentConfig) -> Result<TrainOutcome> {
    cfg.validate()?;
    let ds = cfg.data.materialize(cfg.seed);
    let engine = build_engine(cfg)?;
    train_on(cfg, &ds, engine)
}

/// Train on a caller-provided dataset with a caller-provided engine
/// (integration tests use this to cross-check native vs XLA, and the
/// figure harnesses use it to reuse one dataset across many runs).
pub fn train_with_engine(
    cfg: &ExperimentConfig,
    ds: &Dataset,
    engine: Arc<dyn ComputeEngine>,
) -> Result<TrainOutcome> {
    cfg.validate()?;
    train_on(cfg, ds, engine)
}

/// Build the engine named by the config. The XLA engine loads the AOT
/// artifacts from `$SODDA_ARTIFACTS` (default `artifacts/`).
pub fn build_engine(cfg: &ExperimentConfig) -> Result<Arc<dyn ComputeEngine>> {
    match cfg.engine {
        EngineKind::Native => Ok(Arc::new(NativeEngine)),
        EngineKind::Xla => {
            let dir = std::env::var("SODDA_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
            let rt = Arc::new(XlaRuntime::load(&dir).context("loading AOT artifacts")?);
            let n_per = cfg.data.n() / cfg.p;
            let m_per = cfg.data.m() / cfg.q;
            let mtilde = m_per / cfg.p;
            Ok(Arc::new(XlaEngine::new(rt, n_per, m_per, mtilde, cfg.inner_steps)?))
        }
    }
}

fn train_on(cfg: &ExperimentConfig, ds: &Dataset, engine: Arc<dyn ComputeEngine>) -> Result<TrainOutcome> {
    let grid = Grid::partition(ds, cfg.p, cfg.q)?;
    let (p, q) = (cfg.p, cfg.q);
    let (n_per, m_per, mtilde) = (grid.n_per, grid.m_per, grid.mtilde);
    let (n_total, m_total) = (grid.n_total, grid.m_total);
    let loss = cfg.loss;
    // Leader-side elementwise ops (u = f'(z,y), Σf(z,y)) are O(n) scalar
    // maps — dispatching them through PJRT costs more than computing them
    // (perf log A1 in EXPERIMENTS.md §Perf): the leader always uses the
    // native engine for them, workers use the configured engine.
    let leader_engine: Arc<dyn ComputeEngine> = Arc::new(NativeEngine);
    let cluster = Cluster::launch(grid, engine, loss);

    let cost = CostModel { net: cfg.network.unwrap_or_default(), ..CostModel::default() };
    let mut net = SimNet::new(cost);

    // independent RNG streams (see util::rng docs)
    let root = Rng::seed_from_u64(cfg.seed);
    let mut rng_sets = root.fork(0xB0);
    let mut rng_perm = root.fork(0xC0);
    let mut rng_rows = root.fork(0xD0);

    let mut w = vec![0.0f32; m_total];
    let mut history = History::new(&cfg.name);
    let mut grad_coord_evals: u64 = 0;
    let t_start = Instant::now();

    // iteration 0 record: F(ω^0) = F(0)
    history.push(IterRecord {
        iter: 0,
        loss: objective(&cluster, &leader_engine, loss, &w, n_total),
        wall_s: t_start.elapsed().as_secs_f64(),
        sim_s: 0.0,
        comm_bytes: 0,
        grad_coord_evals: 0,
    });

    for t in 1..=cfg.outer_iters {
        let gamma = cfg.schedule.gamma(t) as f32;

        // ---- sets (steps 5-7) ---------------------------------------------
        let sets = match cfg.algorithm {
            AlgorithmKind::Sodda => SampleSets::draw(&mut rng_sets, n_total, m_total, &cfg.fractions),
            AlgorithmKind::Radisa | AlgorithmKind::RadisaAvg => SampleSets::full(n_total, m_total),
        };
        let rows_arc: Vec<Arc<Vec<u32>>> = sampling::rows_per_partition(&sets.d, p, n_per)
            .into_iter()
            .map(Arc::new)
            .collect();

        // ---- µ^t estimate (step 8) ------------------------------------------
        let w_masked = sampling::mask_keep(&w, &sets.b);
        let w_blocks: Vec<Arc<Vec<f32>>> =
            (0..q).map(|qi| Arc::new(w_masked[qi * m_per..(qi + 1) * m_per].to_vec())).collect();

        let z = cluster.partial_z(&w_blocks, &rows_arc);
        {
            let mut bytes = 0u64;
            let mut max_flops = 0f64;
            for pi in 0..p {
                for qi in 0..q {
                    let bq = SampleSets::count_in_range(&sets.b, qi * m_per, (qi + 1) * m_per);
                    bytes += 4 * (bq as u64 + rows_arc[pi].len() as u64);
                    let fl = 2.0 * rows_arc[pi].len() as f64 * bq as f64 * cluster.density_at(pi, qi);
                    max_flops = max_flops.max(fl);
                }
            }
            net.phase(max_flops, bytes, 2 * (p * q) as u64, 1);
        }

        // u = f'(z, y) at the reduce site (leader)
        let mut u_per_p: Vec<Arc<Vec<f32>>> = Vec::with_capacity(p);
        for pi in 0..p {
            let y_rows: Vec<f32> = rows_arc[pi].iter().map(|&r| cluster.y[pi][r as usize]).collect();
            u_per_p.push(Arc::new(leader_engine.dloss_u(loss, &z[pi], &y_rows)));
        }
        net.local(sets.d.len() as f64);

        let mut g = cluster.grad(&u_per_p, &rows_arc);
        {
            let mut bytes = 0u64;
            let mut max_flops = 0f64;
            for pi in 0..p {
                for qi in 0..q {
                    let cq = SampleSets::count_in_range(&sets.c, qi * m_per, (qi + 1) * m_per);
                    bytes += 4 * (rows_arc[pi].len() as u64 + cq as u64);
                    let fl = 2.0 * rows_arc[pi].len() as f64 * cq as f64 * cluster.density_at(pi, qi);
                    max_flops = max_flops.max(fl);
                }
            }
            net.phase(max_flops, bytes, 2 * (p * q) as u64, 1);
        }

        // µ = (g ∘ C) / d^t
        sampling::project_inplace(&mut g, &sets.c);
        let inv_d = 1.0 / sets.d.len() as f32;
        for v in g.iter_mut() {
            *v *= inv_d;
        }
        let mu = g;
        net.local(sets.c.len() as f64);
        grad_coord_evals += (sets.c.len() * sets.d.len()) as u64;

        // ---- inner loops (steps 9-18) + assembly (step 19) ------------------
        // All three algorithms run one parallel sub-epoch: π_q assigns each
        // worker a disjoint sub-block (bijection ⇒ disjoint cover of ω_[q]).
        // SODDA/RADiSA write back the last iterate; RADiSA-avg writes back
        // the suffix-averaged iterate (its "-avg" combiner).
        let avg = cfg.algorithm == AlgorithmKind::RadisaAvg;
        let mut tasks: Vec<SvrgTask> = Vec::with_capacity(p * q);
        let mut task_cols: Vec<std::ops::Range<usize>> = Vec::with_capacity(p * q);
        for qi in 0..q {
            let perm = rng_perm.permutation(p);
            for pi in 0..p {
                let k = perm[pi] as usize;
                let gcols = qi * m_per + k * mtilde..qi * m_per + (k + 1) * mtilde;
                tasks.push(SvrgTask {
                    p: pi,
                    q: qi,
                    cols: k * mtilde..(k + 1) * mtilde,
                    w0: w[gcols.clone()].to_vec(),
                    wt: w[gcols.clone()].to_vec(),
                    mu: mu[gcols.clone()].to_vec(),
                    idx: rng_rows.sample_with_replacement(n_per, cfg.inner_steps),
                    gamma,
                    avg,
                });
                task_cols.push(gcols);
            }
        }
        for (ti, w_l) in cluster.svrg(tasks) {
            w[task_cols[ti].clone()].copy_from_slice(&w_l);
        }
        let max_density = (0..p)
            .flat_map(|pi| (0..q).map(move |qi| (pi, qi)))
            .fold(0.0f64, |acc, (pi, qi)| acc.max(cluster.density_at(pi, qi)));
        let flops = 6.0 * cfg.inner_steps as f64 * mtilde as f64 * max_density;
        let bytes = ((p * q) as u64) * 4 * (3 * mtilde as u64 + cfg.inner_steps as u64 + mtilde as u64);
        net.phase(flops, bytes, 2 * (p * q) as u64, 1);
        grad_coord_evals += (p * q * cfg.inner_steps * mtilde) as u64;

        // ---- reporting -------------------------------------------------------
        if t % cfg.eval_every == 0 || t == cfg.outer_iters {
            history.push(IterRecord {
                iter: t,
                loss: objective(&cluster, &leader_engine, loss, &w, n_total),
                wall_s: t_start.elapsed().as_secs_f64(),
                sim_s: net.sim_s(),
                comm_bytes: net.total_bytes(),
                grad_coord_evals,
            });
        }
    }

    Ok(TrainOutcome {
        w,
        history,
        comm_bytes: net.total_bytes(),
        comm_msgs: net.total_msgs(),
    })
}

/// Distributed objective F(ω) = (1/N) Σ f(x_i·ω, y_i): partial-z reduce
/// across feature blocks, loss sum per observation partition. Not charged
/// to the cost model (the paper evaluates loss curves offline).
fn objective(
    cluster: &Cluster,
    engine: &Arc<dyn ComputeEngine>,
    loss: crate::loss::Loss,
    w: &[f32],
    n_total: usize,
) -> f64 {
    let q = cluster.q;
    let m_per = cluster.m_per;
    let w_blocks: Vec<Arc<Vec<f32>>> =
        (0..q).map(|qi| Arc::new(w[qi * m_per..(qi + 1) * m_per].to_vec())).collect();
    let rows: Vec<Arc<Vec<u32>>> =
        (0..cluster.p).map(|_| Arc::new((0..cluster.n_per as u32).collect())).collect();
    let z = cluster.partial_z(&w_blocks, &rows);
    let mut total = 0.0f64;
    for pi in 0..cluster.p {
        total += engine.loss_from_z(loss, &z[pi], &cluster.y[pi]);
    }
    total / n_total as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{DataConfig, SamplingFractions, Schedule};
    use crate::loss::Loss;

    fn base_cfg(algo: AlgorithmKind) -> ExperimentConfig {
        ExperimentConfig {
            name: format!("test-{algo}"),
            data: DataConfig::Dense { n: 300, m: 60 },
            p: 3,
            q: 2,
            loss: Loss::Hinge,
            algorithm: algo,
            fractions: SamplingFractions::PAPER,
            inner_steps: 16,
            outer_iters: 12,
            schedule: Schedule::PaperSqrt,
            seed: 7,
            engine: EngineKind::Native,
            network: None,
            eval_every: 1,
        }
    }

    #[test]
    fn sodda_decreases_hinge_loss() {
        let out = train(&base_cfg(AlgorithmKind::Sodda)).unwrap();
        let losses = out.history.losses();
        assert_eq!(losses.len(), 13);
        let f0 = losses[0];
        let fmin = out.history.min_loss().unwrap();
        assert!(fmin < 0.6 * f0, "loss should drop substantially: {f0} -> {fmin}");
    }

    #[test]
    fn radisa_decreases_loss_too() {
        let out = train(&base_cfg(AlgorithmKind::Radisa)).unwrap();
        assert!(out.history.min_loss().unwrap() < 0.6 * out.history.losses()[0]);
    }

    #[test]
    fn radisa_avg_runs_and_decreases() {
        let out = train(&base_cfg(AlgorithmKind::RadisaAvg)).unwrap();
        assert!(out.history.min_loss().unwrap() < 0.8 * out.history.losses()[0]);
    }

    #[test]
    fn deterministic_given_seed() {
        let a = train(&base_cfg(AlgorithmKind::Sodda)).unwrap();
        let b = train(&base_cfg(AlgorithmKind::Sodda)).unwrap();
        assert_eq!(a.w, b.w);
        assert_eq!(a.history.losses(), b.history.losses());
        let mut cfg = base_cfg(AlgorithmKind::Sodda);
        cfg.seed = 8;
        let c = train(&cfg).unwrap();
        assert_ne!(a.w, c.w);
    }

    #[test]
    fn sodda_moves_less_data_than_radisa() {
        let a = train(&base_cfg(AlgorithmKind::Sodda)).unwrap();
        let b = train(&base_cfg(AlgorithmKind::Radisa)).unwrap();
        assert!(
            a.comm_bytes < b.comm_bytes,
            "sampled sets must shrink traffic: {} vs {}",
            a.comm_bytes,
            b.comm_bytes
        );
    }

    #[test]
    fn sparse_dataset_trains() {
        let mut cfg = base_cfg(AlgorithmKind::Sodda);
        cfg.data = DataConfig::Sparse { n: 300, m: 120, avg_nnz: 10 };
        let out = train(&cfg).unwrap();
        assert!(out.history.min_loss().unwrap() < out.history.losses()[0]);
    }

    #[test]
    fn radisa_avg_differs_from_radisa() {
        let a = train(&base_cfg(AlgorithmKind::Radisa)).unwrap();
        let b = train(&base_cfg(AlgorithmKind::RadisaAvg)).unwrap();
        assert_ne!(a.w, b.w, "the avg combiner must change the trajectory");
    }

    #[test]
    fn sim_time_monotone_and_positive() {
        let out = train(&base_cfg(AlgorithmKind::Sodda)).unwrap();
        let times: Vec<f64> = out.history.records.iter().map(|r| r.sim_s).collect();
        assert!(times.windows(2).all(|w| w[0] <= w[1]));
        assert!(*times.last().unwrap() > 0.0);
    }
}
