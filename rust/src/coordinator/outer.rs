//! Legacy one-shot entry points, kept as thin shims over the session
//! type [`Trainer`](crate::train::Trainer): each call stages a fresh
//! session and drives it to completion. Sweeps and anything that runs
//! more than once per dataset should hold a `Trainer` instead and
//! `reconfigure` between runs — staging (materialize + partition +
//! engine build + cluster launch) is the dominant avoidable cost.

use std::sync::Arc;

use anyhow::Result;

use crate::config::ExperimentConfig;
use crate::data::Dataset;
use crate::engine::ComputeEngine;
use crate::train::Trainer;

pub use crate::train::{build_engine, TrainOutcome};

/// Materialize the dataset from the config and train once.
pub fn train(cfg: &ExperimentConfig) -> Result<TrainOutcome> {
    Trainer::new(cfg.clone())?.run()
}

/// Train once on a caller-provided dataset with a caller-provided engine
/// (integration tests use this to cross-check native vs XLA). The
/// dataset is cloned into the session; hold a [`Trainer`] directly to
/// share one staged copy across runs.
pub fn train_with_engine(
    cfg: &ExperimentConfig,
    ds: &Dataset,
    engine: Arc<dyn ComputeEngine>,
) -> Result<TrainOutcome> {
    Trainer::with_parts(cfg.clone(), ds.clone(), engine)?.run()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{AlgorithmKind, DataConfig};

    fn base_cfg(algo: AlgorithmKind) -> ExperimentConfig {
        ExperimentConfig::builder()
            .name(format!("test-{algo}"))
            .dense(300, 60)
            .grid(3, 2)
            .algorithm(algo)
            .inner_steps(16)
            .outer_iters(12)
            .schedule(crate::config::Schedule::PaperSqrt)
            .seed(7)
            .build()
            .unwrap()
    }

    #[test]
    fn sodda_decreases_hinge_loss() {
        let out = train(&base_cfg(AlgorithmKind::Sodda)).unwrap();
        let losses = out.history.losses();
        assert_eq!(losses.len(), 13);
        let f0 = losses[0];
        let fmin = out.history.min_loss().unwrap();
        assert!(fmin < 0.6 * f0, "loss should drop substantially: {f0} -> {fmin}");
    }

    #[test]
    fn radisa_decreases_loss_too() {
        let out = train(&base_cfg(AlgorithmKind::Radisa)).unwrap();
        assert!(out.history.min_loss().unwrap() < 0.6 * out.history.losses()[0]);
    }

    #[test]
    fn radisa_avg_runs_and_decreases() {
        let out = train(&base_cfg(AlgorithmKind::RadisaAvg)).unwrap();
        assert!(out.history.min_loss().unwrap() < 0.8 * out.history.losses()[0]);
    }

    #[test]
    fn deterministic_given_seed() {
        let a = train(&base_cfg(AlgorithmKind::Sodda)).unwrap();
        let b = train(&base_cfg(AlgorithmKind::Sodda)).unwrap();
        assert_eq!(a.w, b.w);
        assert_eq!(a.history.losses(), b.history.losses());
        let cfg = base_cfg(AlgorithmKind::Sodda).to_builder().seed(8).build().unwrap();
        let c = train(&cfg).unwrap();
        assert_ne!(a.w, c.w);
    }

    #[test]
    fn sodda_moves_less_data_than_radisa() {
        let a = train(&base_cfg(AlgorithmKind::Sodda)).unwrap();
        let b = train(&base_cfg(AlgorithmKind::Radisa)).unwrap();
        assert!(
            a.comm_bytes < b.comm_bytes,
            "sampled sets must shrink traffic: {} vs {}",
            a.comm_bytes,
            b.comm_bytes
        );
    }

    #[test]
    fn sparse_dataset_trains() {
        let cfg = base_cfg(AlgorithmKind::Sodda)
            .to_builder()
            .data(DataConfig::Sparse { n: 300, m: 120, avg_nnz: 10 })
            .build()
            .unwrap();
        let out = train(&cfg).unwrap();
        assert!(out.history.min_loss().unwrap() < out.history.losses()[0]);
    }

    #[test]
    fn radisa_avg_differs_from_radisa() {
        let a = train(&base_cfg(AlgorithmKind::Radisa)).unwrap();
        let b = train(&base_cfg(AlgorithmKind::RadisaAvg)).unwrap();
        assert_ne!(a.w, b.w, "the avg combiner must change the trajectory");
    }

    #[test]
    fn sim_time_monotone_and_positive() {
        let out = train(&base_cfg(AlgorithmKind::Sodda)).unwrap();
        let times: Vec<f64> = out.history.records.iter().map(|r| r.sim_s).collect();
        assert!(times.windows(2).all(|w| w[0] <= w[1]));
        assert!(*times.last().unwrap() > 0.0);
    }
}
