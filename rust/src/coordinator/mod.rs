//! The paper's algorithms: SODDA (Algorithm 1) and the RADiSA /
//! RADiSA-avg baselines, orchestrated over the simulated cluster.
//!
//! Structure per outer iteration `t` (SODDA):
//!
//! 1. draw `(B^t, C^t, D^t)` ([`sampling::SampleSets`]);
//! 2. **µ^t estimate** — distributed: workers compute partial margins
//!    over B^t-masked parameters, the leader reduces z across feature
//!    blocks, broadcasts `u = f'(z, y)`, workers return gradient slices,
//!    the leader projects onto C^t and divides by `d^t`;
//! 3. draw permutations `π_q` and run the `P×Q` parallel SVRG inner
//!    loops on disjoint sub-blocks (steps 10-18);
//! 4. concatenate sub-blocks into `ω^{t+1}` (step 19).
//!
//! RADiSA is SODDA at `(b,c,d) = (100%, 100%, 100%)` (Corollary 1);
//! RADiSA-avg is the paper's benchmark combiner: every worker updates its
//! **whole** local feature block `ω_[q]` and the leader averages the P
//! copies (the strategy §3 motivates the sub-block split against).
//!
//! The outer loop itself lives in [`crate::train`]: a reusable
//! [`crate::train::Trainer`] session owns the staged dataset, grid,
//! engine and cluster, and [`outer`] keeps the legacy one-shot
//! `train`/`train_with_engine` entry points as shims over it.

pub mod baselines;
pub mod outer;
pub mod sampling;

pub use outer::{build_engine, train, train_with_engine, TrainOutcome};
