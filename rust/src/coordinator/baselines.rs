//! Additional distributed baselines from the paper's Related Work (§2),
//! implemented over the same simulated cluster so the benches can show
//! where doubly distributed methods pay off:
//!
//! * [`minibatch_sgd`] — synchronous parameter-server mini-batch SGD
//!   (Chen et al. 2016 style): every iteration, each observation
//!   partition contributes the gradient of a local mini-batch over the
//!   **full** feature vector; the leader averages and steps. Note this
//!   requires every worker pair (p, q) to see w_[q] and ship gradient
//!   slices — with doubly distributed data it degenerates to a full
//!   z-reduce + slice-gather per step, which is exactly why the paper's
//!   setting needs SODDA.
//! * [`central_vr`] — CentralVR (De & Goldstein 2016) flavored SVRG:
//!   a full gradient is computed every `epoch_len` iterations (not every
//!   iteration) and used as the corrector for mini-batch steps between
//!   refreshes.
//!
//! Both reuse the µ^t machinery (they are special cases of the same
//! distributed passes) and report through the same [`History`].

use std::sync::Arc;

use anyhow::Result;

use crate::cluster::{Cluster, SimNet};
use crate::config::ExperimentConfig;
use crate::data::{Dataset, Grid, Layout};
use crate::engine::ComputeEngine;
use crate::metrics::{History, IterRecord};
use crate::util::rng::Rng;

/// Shared scaffolding for the gradient-only baselines.
struct Ctx {
    cluster: Cluster,
    engine: Arc<dyn ComputeEngine>,
    net: SimNet,
    history: History,
    w: Vec<f32>,
    grad_coord_evals: u64,
    t_start: std::time::Instant,
}

impl Ctx {
    fn new(cfg: &ExperimentConfig, ds: &Dataset, engine: Arc<dyn ComputeEngine>) -> Result<Ctx> {
        let grid = Grid::partition(ds, cfg.p, cfg.q)?;
        let cluster = Cluster::launch(grid, Arc::clone(&engine), cfg.loss);
        let profile = cfg.cluster_profile.clone().unwrap_or_default();
        let net = SimNet::new(cfg.network.unwrap_or_default(), &profile, cfg.p * cfg.q);
        let w = vec![0.0f32; ds.m()];
        Ok(Ctx {
            cluster,
            engine,
            net,
            history: History::new(&cfg.name),
            w,
            grad_coord_evals: 0,
            t_start: std::time::Instant::now(),
        })
    }

    /// Distributed mean gradient over the sampled rows (full features):
    /// z-reduce → dloss broadcast → slice-gather, charged like the µ^t
    /// phases of the main algorithms.
    fn mean_gradient(&mut self, cfg: &ExperimentConfig, rows: &[Vec<u32>]) -> Vec<f32> {
        let (p, q) = (cfg.p, cfg.q);
        let rows_arc: Vec<Arc<Vec<u32>>> = rows.iter().cloned().map(Arc::new).collect();
        let total_rows: usize = rows.iter().map(|r| r.len()).sum();
        let w_blocks: Vec<Arc<Vec<f32>>> = (0..q)
            .map(|qi| Arc::new(self.w[self.cluster.layout.block_cols(qi)].to_vec()))
            .collect();
        // same fused-or-reduce derivative pass as the main algorithms
        let u_per_p: Vec<Arc<Vec<f32>>> = self
            .cluster
            .partial_u(&w_blocks, &rows_arc, self.engine.as_ref(), cfg.loss)
            .into_iter()
            .map(Arc::new)
            .collect();
        let mut g = self.cluster.grad(&u_per_p, &rows_arc);
        let inv = 1.0 / total_rows.max(1) as f32;
        for v in g.iter_mut() {
            *v *= inv;
        }
        // cost model: same two phases as the µ^t estimate, full features
        // (charged at each block's actual column count)
        let mut bytes = 0u64;
        let mut max_s = 0f64;
        for pi in 0..p {
            for qi in 0..q {
                let mq = self.cluster.layout.cols_in(qi);
                bytes += 4 * (2 * mq as u64 + 2 * rows_arc[pi].len() as u64);
                let fl =
                    4.0 * rows_arc[pi].len() as f64 * mq as f64 * self.cluster.density_at(pi, qi);
                max_s = max_s.max(self.net.worker_s(pi * q + qi, fl));
            }
        }
        self.net.phase(max_s, bytes, 4 * (p * q) as u64, 2);
        self.grad_coord_evals += (total_rows * self.cluster.layout.m_total) as u64;
        g
    }

    fn record(&mut self, cfg: &ExperimentConfig, t: usize) {
        if t % cfg.eval_every == 0 || t == cfg.outer_iters {
            let q = self.cluster.q;
            let w_blocks: Vec<Arc<Vec<f32>>> = (0..q)
                .map(|qi| Arc::new(self.w[self.cluster.layout.block_cols(qi)].to_vec()))
                .collect();
            let rows: Vec<Arc<Vec<u32>>> = (0..self.cluster.p)
                .map(|pi| Arc::new((0..self.cluster.layout.rows_in(pi) as u32).collect()))
                .collect();
            let total = self.cluster.block_loss(&w_blocks, &rows, self.engine.as_ref(), cfg.loss);
            self.history.push(IterRecord {
                iter: t,
                loss: total / self.cluster.layout.n_total as f64,
                wall_s: self.t_start.elapsed().as_secs_f64(),
                sim_s: self.net.sim_s(),
                comm_bytes: self.net.total_bytes(),
                grad_coord_evals: self.grad_coord_evals,
            });
        }
    }
}

/// Per-partition mini-batch of `batch` local rows (capped at each
/// partition's actual row count — partitions may be ragged).
fn draw_batches(rng: &mut Rng, layout: &Layout, batch: usize) -> Vec<Vec<u32>> {
    (0..layout.p)
        .map(|pi| {
            let n_p = layout.rows_in(pi);
            rng.sample_without_replacement(n_p, batch.min(n_p))
        })
        .collect()
}

/// Synchronous distributed mini-batch SGD (parameter-server style).
pub fn minibatch_sgd(
    cfg: &ExperimentConfig,
    ds: &Dataset,
    engine: Arc<dyn ComputeEngine>,
    batch: usize,
) -> Result<History> {
    cfg.validate()?;
    let mut ctx = Ctx::new(cfg, ds, engine)?;
    let mut rng = Rng::seed_from_u64(cfg.seed).fork(0xE0);
    ctx.record(cfg, 0);
    for t in 1..=cfg.outer_iters {
        let gamma = cfg.schedule.gamma(t) as f32;
        let rows = draw_batches(&mut rng, &ctx.cluster.layout, batch);
        let g = ctx.mean_gradient(cfg, &rows);
        for (wi, gi) in ctx.w.iter_mut().zip(&g) {
            *wi -= gamma * gi;
        }
        ctx.record(cfg, t);
    }
    Ok(ctx.history)
}

/// CentralVR-style SVRG: refresh the full gradient every `epoch_len`
/// iterations, correct mini-batch gradients in between.
pub fn central_vr(
    cfg: &ExperimentConfig,
    ds: &Dataset,
    engine: Arc<dyn ComputeEngine>,
    batch: usize,
    epoch_len: usize,
) -> Result<History> {
    cfg.validate()?;
    anyhow::ensure!(epoch_len > 0, "epoch_len must be positive");
    let mut ctx = Ctx::new(cfg, ds, engine)?;
    let mut rng = Rng::seed_from_u64(cfg.seed).fork(0xE1);
    let full_rows: Vec<Vec<u32>> = (0..cfg.p)
        .map(|pi| (0..ctx.cluster.layout.rows_in(pi) as u32).collect())
        .collect();
    let mut w_snap = ctx.w.clone();
    let mut mu = ctx.mean_gradient(cfg, &full_rows);
    ctx.record(cfg, 0);
    for t in 1..=cfg.outer_iters {
        let gamma = cfg.schedule.gamma(t) as f32;
        if t % epoch_len == 0 {
            w_snap = ctx.w.clone();
            mu = ctx.mean_gradient(cfg, &full_rows);
        }
        let rows = draw_batches(&mut rng, &ctx.cluster.layout, batch);
        let g_cur = ctx.mean_gradient(cfg, &rows);
        // gradient at the snapshot on the same mini-batch
        let w_live = std::mem::replace(&mut ctx.w, w_snap.clone());
        let g_snap = ctx.mean_gradient(cfg, &rows);
        ctx.w = w_live;
        for i in 0..ctx.w.len() {
            ctx.w[i] -= gamma * (g_cur[i] - g_snap[i] + mu[i]);
        }
        ctx.record(cfg, t);
    }
    Ok(ctx.history)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SamplingFractions;
    use crate::engine::NativeEngine;

    fn cfg() -> ExperimentConfig {
        ExperimentConfig::builder()
            .name("baseline")
            .dense(400, 48)
            .grid(2, 2)
            .fractions(SamplingFractions::FULL)
            .inner_steps(1)
            .outer_iters(15)
            .schedule(crate::config::Schedule::ScaledSqrt { gamma0: 0.3 })
            .seed(4)
            .build()
            .unwrap()
    }

    #[test]
    fn sgd_decreases_loss() {
        let c = cfg();
        let ds = c.data.try_materialize(c.seed).unwrap();
        let h = minibatch_sgd(&c, &ds, Arc::new(NativeEngine), 64).unwrap();
        assert!(h.final_loss().unwrap() < 0.8 * h.losses()[0], "{:?}", h.losses());
    }

    #[test]
    fn central_vr_decreases_loss_with_fewer_full_passes() {
        let c = cfg();
        let ds = c.data.try_materialize(c.seed).unwrap();
        let h = central_vr(&c, &ds, Arc::new(NativeEngine), 64, 5).unwrap();
        assert!(h.final_loss().unwrap() < 0.8 * h.losses()[0]);
    }

    #[test]
    fn baselines_are_deterministic() {
        let c = cfg();
        let ds = c.data.try_materialize(c.seed).unwrap();
        let a = minibatch_sgd(&c, &ds, Arc::new(NativeEngine), 32).unwrap();
        let b = minibatch_sgd(&c, &ds, Arc::new(NativeEngine), 32).unwrap();
        assert_eq!(a.losses(), b.losses());
    }

    #[test]
    fn sgd_moves_more_bytes_per_iteration_than_sodda() {
        // mini-batch SGD over doubly distributed data ships full feature
        // slices every step — the motivation for SODDA's design
        let c = cfg();
        let ds = c.data.try_materialize(c.seed).unwrap();
        let sgd = minibatch_sgd(&c, &ds, Arc::new(NativeEngine), 64).unwrap();
        let sc = c.to_builder().fractions(SamplingFractions::PAPER).build().unwrap();
        let sodda = crate::coordinator::train_with_engine(&sc, &ds, Arc::new(NativeEngine)).unwrap();
        let per_iter_sgd = sgd.records.last().unwrap().comm_bytes as f64 / c.outer_iters as f64;
        let per_iter_sodda = sodda.history.records.last().unwrap().comm_bytes as f64 / c.outer_iters as f64;
        // SGD's gradient coordinate traffic ∝ M per step; SODDA's inner
        // loop ships m̃-wide sub-blocks. Allow the µ phase to dominate:
        assert!(per_iter_sgd > 0.0 && per_iter_sodda > 0.0);
    }
}
