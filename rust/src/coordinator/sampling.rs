//! The stochastic index machinery of Algorithm 1 (steps 5-7, 10, 15):
//! the `(B^t, C^t, D^t)` sets, the per-feature-block permutations `π_q`,
//! and the partition-local decompositions the cluster phases need.

use crate::config::SamplingFractions;
use crate::util::rng::Rng;

/// One iteration's sampled index sets (global ids, sorted).
#[derive(Debug, Clone)]
pub struct SampleSets {
    /// B^t — features used in inner products (`x_j^{B^t} w_{B^t}`)
    pub b: Vec<u32>,
    /// C^t ⊆ B^t — gradient coordinates actually evaluated
    pub c: Vec<u32>,
    /// D^t — observations used for the µ^t estimate
    pub d: Vec<u32>,
}

impl SampleSets {
    /// Draw per the paper: `b^t` features, `c^t ⊆ B^t`, `d^t` rows, all
    /// without replacement. Sizes are `round(frac · dim)`, min 1.
    pub fn draw(rng: &mut Rng, n: usize, m: usize, fr: &SamplingFractions) -> SampleSets {
        let bsz = size_of(fr.b, m);
        let csz = size_of(fr.c, m).min(bsz);
        let dsz = size_of(fr.d, n);
        let b = rng.sample_without_replacement(m, bsz);
        // sample C from within B
        let mut c: Vec<u32> = rng
            .sample_without_replacement(bsz, csz)
            .into_iter()
            .map(|i| b[i as usize])
            .collect();
        c.sort_unstable();
        let d = rng.sample_without_replacement(n, dsz);
        SampleSets { b, c, d }
    }

    /// RADiSA's exact sets: `B = C = [M]`, `D = [N]`.
    pub fn full(n: usize, m: usize) -> SampleSets {
        SampleSets {
            b: (0..m as u32).collect(),
            c: (0..m as u32).collect(),
            d: (0..n as u32).collect(),
        }
    }

    /// |B ∩ [lo, hi)| for a sorted id list (block intersection sizes for
    /// the cost model).
    pub fn count_in_range(sorted: &[u32], lo: usize, hi: usize) -> usize {
        let a = sorted.partition_point(|&v| (v as usize) < lo);
        let b = sorted.partition_point(|&v| (v as usize) < hi);
        b - a
    }
}

fn size_of(frac: f64, dim: usize) -> usize {
    ((frac * dim as f64).round() as usize).clamp(1, dim)
}

/// Split sorted global row ids into per-partition local ids, driven by
/// the layout's row boundaries (`row_bounds[p]..row_bounds[p+1]` is
/// partition `p` — see [`crate::data::Layout::row_bounds`]).
///
/// The uniform-grid predecessor computed `r / n_per` and clamped with
/// `.min(p - 1)`, which silently mapped out-of-range rows onto the last
/// partition with wrong local ids whenever `N % P != 0`; boundary
/// bisection has no such failure mode, and the debug assertions make
/// any out-of-range id loud instead of silent.
pub fn rows_per_partition(d: &[u32], row_bounds: &[usize]) -> Vec<Vec<u32>> {
    let p = row_bounds.len() - 1;
    let mut out = vec![Vec::new(); p];
    let mut pi = 0usize;
    for &r in d {
        let r = r as usize;
        // `d` is sorted, so the owning partition only ever advances
        while pi + 1 < p && r >= row_bounds[pi + 1] {
            pi += 1;
        }
        debug_assert!(
            r >= row_bounds[pi] && r < row_bounds[pi + 1],
            "row id {r} outside partition {pi} [{}, {}) — ids must be sorted and < N",
            row_bounds[pi],
            row_bounds[pi + 1]
        );
        out[pi].push((r - row_bounds[pi]) as u32);
    }
    out
}

/// `w ∘ 1_B`: copy of `w` with non-B coordinates zeroed.
pub fn mask_keep(w: &[f32], keep_sorted: &[u32]) -> Vec<f32> {
    let mut out = vec![0.0f32; w.len()];
    for &i in keep_sorted {
        out[i as usize] = w[i as usize];
    }
    out
}

/// Zero every coordinate of `g` outside the sorted keep-set (the paper's
/// `\bar∇_{ω_C}` projection).
pub fn project_inplace(g: &mut [f32], keep_sorted: &[u32]) {
    let mut keep_iter = keep_sorted.iter().peekable();
    for (i, v) in g.iter_mut().enumerate() {
        match keep_iter.peek() {
            Some(&&k) if k as usize == i => {
                keep_iter.next();
            }
            _ => *v = 0.0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::testing::forall;

    #[test]
    fn draw_respects_sizes_and_subset() {
        forall(50, 42, |rng| {
            let n = 1 + rng.below(200);
            let m = 1 + rng.below(100);
            let fr = SamplingFractions {
                b: 0.05 + rng.unit_f64() * 0.95,
                c: 0.0,
                d: 0.05 + rng.unit_f64() * 0.95,
            };
            let fr = SamplingFractions { c: fr.b * rng.unit_f64().max(0.05), ..fr };
            let s = SampleSets::draw(rng, n, m, &fr);
            assert!(!s.b.is_empty() && s.b.len() <= m);
            assert!(!s.d.is_empty() && s.d.len() <= n);
            assert!(s.c.len() <= s.b.len());
            // C ⊆ B
            assert!(s.c.iter().all(|c| s.b.binary_search(c).is_ok()));
            // sorted unique
            assert!(s.b.windows(2).all(|w| w[0] < w[1]));
            assert!(s.c.windows(2).all(|w| w[0] < w[1]));
            assert!(s.d.windows(2).all(|w| w[0] < w[1]));
        });
    }

    #[test]
    fn full_sets() {
        let s = SampleSets::full(3, 2);
        assert_eq!(s.b, vec![0, 1]);
        assert_eq!(s.c, vec![0, 1]);
        assert_eq!(s.d, vec![0, 1, 2]);
    }

    #[test]
    fn count_in_range_binary_search() {
        let v = vec![1u32, 3, 4, 9, 10];
        assert_eq!(SampleSets::count_in_range(&v, 0, 5), 3);
        assert_eq!(SampleSets::count_in_range(&v, 5, 9), 0);
        assert_eq!(SampleSets::count_in_range(&v, 9, 11), 2);
    }

    #[test]
    fn rows_split_preserves_everything() {
        use crate::data::partition::split_points;
        forall(30, 7, |rng| {
            let p = 1 + rng.below(5);
            // both evenly divisible and ragged totals
            let n = p * (1 + rng.below(50)) + rng.below(p);
            let bounds = split_points(n, p);
            let k = 1 + rng.below(n);
            let d = rng.sample_without_replacement(n, k);
            let split = rows_per_partition(&d, &bounds);
            let total: usize = split.iter().map(|v| v.len()).sum();
            assert_eq!(total, d.len());
            for (pi, rows) in split.iter().enumerate() {
                for &r in rows {
                    assert!((r as usize) < bounds[pi + 1] - bounds[pi], "local id in-bounds");
                    let global = bounds[pi] + r as usize;
                    assert!(d.binary_search(&(global as u32)).is_ok());
                }
            }
        });
    }

    #[test]
    fn ragged_split_regression_indivisible_n() {
        // N = 10 over P = 3 → bounds [0, 3, 6, 10]. The old uniform
        // arithmetic (n_per = 3, clamp to p-1) sent rows 9 to partition 2
        // with local id 9 - 2·3 = 3 — out of a 3-row uniform partition
        // and, worse, silently wrong for any ragged layout.
        let bounds = [0usize, 3, 6, 10];
        let d: Vec<u32> = (0..10).collect();
        let split = rows_per_partition(&d, &bounds);
        assert_eq!(split[0], vec![0, 1, 2]);
        assert_eq!(split[1], vec![0, 1, 2]);
        assert_eq!(split[2], vec![0, 1, 2, 3]);
        for (pi, rows) in split.iter().enumerate() {
            for &r in rows {
                assert!((r as usize) < bounds[pi + 1] - bounds[pi]);
            }
        }
    }

    #[test]
    fn masking_and_projection() {
        let w = vec![1.0, 2.0, 3.0, 4.0];
        let masked = mask_keep(&w, &[1, 3]);
        assert_eq!(masked, vec![0.0, 2.0, 0.0, 4.0]);
        let mut g = vec![1.0, 1.0, 1.0, 1.0];
        project_inplace(&mut g, &[0, 2]);
        assert_eq!(g, vec![1.0, 0.0, 1.0, 0.0]);
    }

    #[test]
    fn projection_of_full_set_is_identity() {
        let mut g = vec![1.0, 2.0, 3.0];
        project_inplace(&mut g, &[0, 1, 2]);
        assert_eq!(g, vec![1.0, 2.0, 3.0]);
    }
}
