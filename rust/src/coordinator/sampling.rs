//! The stochastic index machinery of Algorithm 1 (steps 5-7, 10, 15):
//! the `(B^t, C^t, D^t)` sets, the per-feature-block permutations `π_q`,
//! and the partition-local decompositions the cluster phases need.

use crate::config::SamplingFractions;
use crate::util::rng::Rng;

/// One iteration's sampled index sets (global ids, sorted).
#[derive(Debug, Clone, Default)]
pub struct SampleSets {
    /// B^t — features used in inner products (`x_j^{B^t} w_{B^t}`)
    pub b: Vec<u32>,
    /// C^t ⊆ B^t — gradient coordinates actually evaluated
    pub c: Vec<u32>,
    /// D^t — observations used for the µ^t estimate
    pub d: Vec<u32>,
}

impl SampleSets {
    /// Draw per the paper: `b^t` features, `c^t ⊆ B^t`, `d^t` rows, all
    /// without replacement. Sizes are `round(frac · dim)`, min 1.
    pub fn draw(rng: &mut Rng, n: usize, m: usize, fr: &SamplingFractions) -> SampleSets {
        let mut sets = SampleSets::default();
        let mut scratch = Vec::new();
        Self::draw_into(rng, n, m, fr, &mut sets, &mut scratch);
        sets
    }

    /// In-place [`SampleSets::draw`]: identical RNG draws and values,
    /// refilling recycled buffers (`scratch` holds the without-
    /// replacement index array). Set sizes are constant across
    /// iterations, so after warm-up this allocates nothing.
    pub fn draw_into(
        rng: &mut Rng,
        n: usize,
        m: usize,
        fr: &SamplingFractions,
        sets: &mut SampleSets,
        scratch: &mut Vec<u32>,
    ) {
        let bsz = size_of(fr.b, m);
        let csz = size_of(fr.c, m).min(bsz);
        let dsz = size_of(fr.d, n);
        rng.sample_without_replacement_into(m, bsz, &mut sets.b, scratch);
        // sample C from within B: indices into B first, then map + sort
        rng.sample_without_replacement_into(bsz, csz, &mut sets.c, scratch);
        for ci in sets.c.iter_mut() {
            *ci = sets.b[*ci as usize];
        }
        sets.c.sort_unstable();
        rng.sample_without_replacement_into(n, dsz, &mut sets.d, scratch);
    }

    /// RADiSA's exact sets: `B = C = [M]`, `D = [N]`.
    pub fn full(n: usize, m: usize) -> SampleSets {
        let mut sets = SampleSets::default();
        Self::full_into(n, m, &mut sets);
        sets
    }

    /// In-place [`SampleSets::full`].
    pub fn full_into(n: usize, m: usize, sets: &mut SampleSets) {
        sets.b.clear();
        sets.b.extend(0..m as u32);
        sets.c.clear();
        sets.c.extend(0..m as u32);
        sets.d.clear();
        sets.d.extend(0..n as u32);
    }

    /// |B ∩ [lo, hi)| for a sorted id list (block intersection sizes for
    /// the cost model).
    pub fn count_in_range(sorted: &[u32], lo: usize, hi: usize) -> usize {
        let a = sorted.partition_point(|&v| (v as usize) < lo);
        let b = sorted.partition_point(|&v| (v as usize) < hi);
        b - a
    }
}

fn size_of(frac: f64, dim: usize) -> usize {
    ((frac * dim as f64).round() as usize).clamp(1, dim)
}

/// Split sorted global row ids into per-partition local ids, driven by
/// the layout's row boundaries (`row_bounds[p]..row_bounds[p+1]` is
/// partition `p` — see [`crate::data::Layout::row_bounds`]).
///
/// The uniform-grid predecessor computed `r / n_per` and clamped with
/// `.min(p - 1)`, which silently mapped out-of-range rows onto the last
/// partition with wrong local ids whenever `N % P != 0`; boundary
/// bisection has no such failure mode, and the debug assertions make
/// any out-of-range id loud instead of silent.
pub fn rows_per_partition(d: &[u32], row_bounds: &[usize]) -> Vec<Vec<u32>> {
    let mut out = vec![Vec::new(); row_bounds.len() - 1];
    rows_per_partition_into(d, row_bounds, out.iter_mut());
    out
}

/// In-place [`rows_per_partition`]: clears and refills one caller-
/// provided buffer per partition. `out` must yield at least `P` buffers
/// (`row_bounds.len() - 1`); extras are cleared. Accepts an iterator so
/// callers can hand out `&mut Vec<u32>` views into recycled `Arc`
/// buffers ([`crate::util::arc_mut`]) without an intermediate
/// collection.
pub fn rows_per_partition_into<'a>(
    d: &[u32],
    row_bounds: &[usize],
    out: impl IntoIterator<Item = &'a mut Vec<u32>>,
) {
    let p = row_bounds.len() - 1;
    let mut it = out.into_iter();
    let mut cur = it.next().expect("at least P row buffers");
    cur.clear();
    let mut pi = 0usize;
    for &r in d {
        let r = r as usize;
        // `d` is sorted, so the owning partition only ever advances
        while pi + 1 < p && r >= row_bounds[pi + 1] {
            pi += 1;
            cur = it.next().expect("at least P row buffers");
            cur.clear();
        }
        debug_assert!(
            r >= row_bounds[pi] && r < row_bounds[pi + 1],
            "row id {r} outside partition {pi} [{}, {}) — ids must be sorted and < N",
            row_bounds[pi],
            row_bounds[pi + 1]
        );
        cur.push((r - row_bounds[pi]) as u32);
    }
    // partitions past the last sampled row (and any extra buffers)
    for rest in it {
        rest.clear();
    }
}

/// `w ∘ 1_B`: copy of `w` with non-B coordinates zeroed.
pub fn mask_keep(w: &[f32], keep_sorted: &[u32]) -> Vec<f32> {
    let mut out = Vec::new();
    mask_keep_into(w, keep_sorted, &mut out);
    out
}

/// In-place [`mask_keep`] (recycled buffer, identical values).
pub fn mask_keep_into(w: &[f32], keep_sorted: &[u32], out: &mut Vec<f32>) {
    out.clear();
    out.resize(w.len(), 0.0);
    for &i in keep_sorted {
        out[i as usize] = w[i as usize];
    }
}

/// Zero every coordinate of `g` outside the sorted keep-set (the paper's
/// `\bar∇_{ω_C}` projection).
pub fn project_inplace(g: &mut [f32], keep_sorted: &[u32]) {
    let mut keep_iter = keep_sorted.iter().peekable();
    for (i, v) in g.iter_mut().enumerate() {
        match keep_iter.peek() {
            Some(&&k) if k as usize == i => {
                keep_iter.next();
            }
            _ => *v = 0.0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::testing::forall;

    #[test]
    fn draw_respects_sizes_and_subset() {
        forall(50, 42, |rng| {
            let n = 1 + rng.below(200);
            let m = 1 + rng.below(100);
            let fr = SamplingFractions {
                b: 0.05 + rng.unit_f64() * 0.95,
                c: 0.0,
                d: 0.05 + rng.unit_f64() * 0.95,
            };
            let fr = SamplingFractions { c: fr.b * rng.unit_f64().max(0.05), ..fr };
            let s = SampleSets::draw(rng, n, m, &fr);
            assert!(!s.b.is_empty() && s.b.len() <= m);
            assert!(!s.d.is_empty() && s.d.len() <= n);
            assert!(s.c.len() <= s.b.len());
            // C ⊆ B
            assert!(s.c.iter().all(|c| s.b.binary_search(c).is_ok()));
            // sorted unique
            assert!(s.b.windows(2).all(|w| w[0] < w[1]));
            assert!(s.c.windows(2).all(|w| w[0] < w[1]));
            assert!(s.d.windows(2).all(|w| w[0] < w[1]));
        });
    }

    #[test]
    fn full_sets() {
        let s = SampleSets::full(3, 2);
        assert_eq!(s.b, vec![0, 1]);
        assert_eq!(s.c, vec![0, 1]);
        assert_eq!(s.d, vec![0, 1, 2]);
    }

    #[test]
    fn count_in_range_binary_search() {
        let v = vec![1u32, 3, 4, 9, 10];
        assert_eq!(SampleSets::count_in_range(&v, 0, 5), 3);
        assert_eq!(SampleSets::count_in_range(&v, 5, 9), 0);
        assert_eq!(SampleSets::count_in_range(&v, 9, 11), 2);
    }

    #[test]
    fn rows_split_preserves_everything() {
        use crate::data::partition::split_points;
        forall(30, 7, |rng| {
            let p = 1 + rng.below(5);
            // both evenly divisible and ragged totals
            let n = p * (1 + rng.below(50)) + rng.below(p);
            let bounds = split_points(n, p);
            let k = 1 + rng.below(n);
            let d = rng.sample_without_replacement(n, k);
            let split = rows_per_partition(&d, &bounds);
            let total: usize = split.iter().map(|v| v.len()).sum();
            assert_eq!(total, d.len());
            for (pi, rows) in split.iter().enumerate() {
                for &r in rows {
                    assert!((r as usize) < bounds[pi + 1] - bounds[pi], "local id in-bounds");
                    let global = bounds[pi] + r as usize;
                    assert!(d.binary_search(&(global as u32)).is_ok());
                }
            }
        });
    }

    #[test]
    fn ragged_split_regression_indivisible_n() {
        // N = 10 over P = 3 → bounds [0, 3, 6, 10]. The old uniform
        // arithmetic (n_per = 3, clamp to p-1) sent rows 9 to partition 2
        // with local id 9 - 2·3 = 3 — out of a 3-row uniform partition
        // and, worse, silently wrong for any ragged layout.
        let bounds = [0usize, 3, 6, 10];
        let d: Vec<u32> = (0..10).collect();
        let split = rows_per_partition(&d, &bounds);
        assert_eq!(split[0], vec![0, 1, 2]);
        assert_eq!(split[1], vec![0, 1, 2]);
        assert_eq!(split[2], vec![0, 1, 2, 3]);
        for (pi, rows) in split.iter().enumerate() {
            for &r in rows {
                assert!((r as usize) < bounds[pi + 1] - bounds[pi]);
            }
        }
    }

    #[test]
    fn draw_into_matches_draw_exactly() {
        // same seed, recycled (dirty) buffers: identical draws and sets
        forall(20, 77, |rng| {
            let n = 1 + rng.below(120);
            let m = 1 + rng.below(60);
            let fr = SamplingFractions { b: 0.6, c: 0.4, d: 0.7 };
            let mut a = rng.clone();
            let mut b = rng.clone();
            let want = SampleSets::draw(&mut a, n, m, &fr);
            let mut sets = SampleSets { b: vec![9; 3], c: vec![7; 9], d: vec![1; 1] };
            let mut scratch = vec![4u32; 2];
            SampleSets::draw_into(&mut b, n, m, &fr, &mut sets, &mut scratch);
            assert_eq!(sets.b, want.b);
            assert_eq!(sets.c, want.c);
            assert_eq!(sets.d, want.d);
            assert_eq!(a.next_u64(), b.next_u64(), "identical draw consumption");
        });
    }

    #[test]
    fn rows_into_matches_allocating_with_dirty_and_extra_buffers() {
        let bounds = [0usize, 3, 6, 10];
        let d: Vec<u32> = vec![0, 2, 7, 9];
        let want = rows_per_partition(&d, &bounds);
        // dirty contents, one extra buffer: refilled/cleared in place
        let mut bufs: Vec<Vec<u32>> = vec![vec![42; 5], vec![42], vec![], vec![42; 2]];
        rows_per_partition_into(&d, &bounds, bufs.iter_mut());
        assert_eq!(&bufs[..3], &want[..]);
        assert!(bufs[3].is_empty(), "extra buffers are cleared");
        // empty middle partition
        let d2: Vec<u32> = vec![1, 8];
        let want2 = rows_per_partition(&d2, &bounds);
        rows_per_partition_into(&d2, &bounds, bufs.iter_mut().take(3));
        assert_eq!(&bufs[..3], &want2[..]);
    }

    #[test]
    fn masking_and_projection() {
        let w = vec![1.0, 2.0, 3.0, 4.0];
        let masked = mask_keep(&w, &[1, 3]);
        assert_eq!(masked, vec![0.0, 2.0, 0.0, 4.0]);
        let mut g = vec![1.0, 1.0, 1.0, 1.0];
        project_inplace(&mut g, &[0, 2]);
        assert_eq!(g, vec![1.0, 0.0, 1.0, 0.0]);
    }

    #[test]
    fn projection_of_full_set_is_identity() {
        let mut g = vec![1.0, 2.0, 3.0];
        project_inplace(&mut g, &[0, 1, 2]);
        assert_eq!(g, vec![1.0, 2.0, 3.0]);
    }
}
