//! Terminal (ASCII) and SVG plotting of loss curves — the figure
//! renderer behind `repro fig2/fig3/fig4 --svg` and the examples.
//!
//! No plotting crates exist offline; SVG is tiny to emit by hand and
//! renders the paper's figures faithfully (log-y loss vs simulated time).

use std::fmt::Write as _;

/// One named curve: (x, y) points.
#[derive(Debug, Clone)]
pub struct Curve {
    pub name: String,
    pub points: Vec<(f64, f64)>,
}

impl Curve {
    pub fn new(name: impl Into<String>, points: Vec<(f64, f64)>) -> Self {
        Self { name: name.into(), points }
    }

    pub fn from_history(name: impl Into<String>, h: &crate::metrics::History, time_axis: bool) -> Self {
        let points = h
            .records
            .iter()
            .map(|r| (if time_axis { r.sim_s } else { r.iter as f64 }, r.loss))
            .collect();
        Self::new(name, points)
    }
}

fn bounds(curves: &[Curve]) -> (f64, f64, f64, f64) {
    let (mut x0, mut x1) = (f64::MAX, f64::MIN);
    let (mut y0, mut y1) = (f64::MAX, f64::MIN);
    for c in curves {
        for &(x, y) in &c.points {
            x0 = x0.min(x);
            x1 = x1.max(x);
            y0 = y0.min(y);
            y1 = y1.max(y);
        }
    }
    if x0 >= x1 {
        x1 = x0 + 1.0;
    }
    if y0 >= y1 {
        y1 = y0 + 1.0;
    }
    (x0, x1, y0, y1)
}

/// Render curves as an ASCII chart (rows × cols characters).
pub fn ascii(curves: &[Curve], rows: usize, cols: usize) -> String {
    assert!(!curves.is_empty());
    let (x0, x1, y0, y1) = bounds(curves);
    let marks = ['*', 'o', '+', 'x', '#', '@', '%', '&'];
    let mut grid = vec![vec![' '; cols]; rows];
    for (ci, c) in curves.iter().enumerate() {
        let mark = marks[ci % marks.len()];
        for &(x, y) in &c.points {
            let col = (((x - x0) / (x1 - x0)) * (cols - 1) as f64).round() as usize;
            let row = (((y - y0) / (y1 - y0)) * (rows - 1) as f64).round() as usize;
            let row = rows - 1 - row.min(rows - 1);
            grid[row][col.min(cols - 1)] = mark;
        }
    }
    let mut out = String::new();
    let _ = writeln!(out, "{y1:>10.4} ┐");
    for row in grid {
        let line: String = row.into_iter().collect();
        let _ = writeln!(out, "{:>10} │{line}", "");
    }
    let _ = writeln!(out, "{y0:>10.4} └{}", "─".repeat(cols));
    let _ = writeln!(out, "{:>12}{x0:<12.4}{:>width$}{x1:.4}", "", "", width = cols.saturating_sub(24));
    for (ci, c) in curves.iter().enumerate() {
        let _ = writeln!(out, "  {} {}", marks[ci % marks.len()], c.name);
    }
    out
}

const PALETTE: [&str; 8] =
    ["#1f77b4", "#d62728", "#2ca02c", "#ff7f0e", "#9467bd", "#8c564b", "#17becf", "#7f7f7f"];

/// Render curves as a standalone SVG (loss vs x, linear axes), in the
/// visual style of the paper's matplotlib figures.
pub fn svg(curves: &[Curve], title: &str, xlabel: &str) -> String {
    let (w, h) = (760.0, 480.0);
    let (ml, mr, mt, mb) = (70.0, 20.0, 40.0, 50.0);
    let (x0, x1, y0, y1) = bounds(curves);
    let px = |x: f64| ml + (x - x0) / (x1 - x0) * (w - ml - mr);
    let py = |y: f64| h - mb - (y - y0) / (y1 - y0) * (h - mt - mb);

    let mut s = String::new();
    let _ = write!(
        s,
        r#"<svg xmlns="http://www.w3.org/2000/svg" width="{w}" height="{h}" viewBox="0 0 {w} {h}">
<rect width="{w}" height="{h}" fill="white"/>
<text x="{}" y="24" text-anchor="middle" font-family="sans-serif" font-size="16">{title}</text>
"#,
        w / 2.0
    );
    // axes
    let _ = write!(
        s,
        r#"<line x1="{ml}" y1="{}" x2="{}" y2="{}" stroke="black"/>
<line x1="{ml}" y1="{mt}" x2="{ml}" y2="{}" stroke="black"/>
"#,
        h - mb,
        w - mr,
        h - mb,
        h - mb
    );
    // ticks (5 per axis)
    for i in 0..=4 {
        let fx = x0 + (x1 - x0) * i as f64 / 4.0;
        let fy = y0 + (y1 - y0) * i as f64 / 4.0;
        let _ = write!(
            s,
            r##"<text x="{:.1}" y="{:.1}" text-anchor="middle" font-family="sans-serif" font-size="11">{:.3}</text>
<text x="{:.1}" y="{:.1}" text-anchor="end" font-family="sans-serif" font-size="11">{:.3}</text>
<line x1="{:.1}" y1="{:.1}" x2="{:.1}" y2="{:.1}" stroke="#ddd"/>
"##,
            px(fx),
            h - mb + 18.0,
            fx,
            ml - 6.0,
            py(fy) + 4.0,
            fy,
            px(fx),
            h - mb,
            px(fx),
            mt
        );
    }
    let _ = write!(
        s,
        r#"<text x="{}" y="{}" text-anchor="middle" font-family="sans-serif" font-size="13">{xlabel}</text>
<text x="18" y="{}" text-anchor="middle" font-family="sans-serif" font-size="13" transform="rotate(-90 18 {})">objective F(w)</text>
"#,
        (ml + w - mr) / 2.0,
        h - 12.0,
        (mt + h - mb) / 2.0,
        (mt + h - mb) / 2.0
    );
    for (ci, c) in curves.iter().enumerate() {
        let color = PALETTE[ci % PALETTE.len()];
        let pts: Vec<String> = c.points.iter().map(|&(x, y)| format!("{:.1},{:.1}", px(x), py(y))).collect();
        let _ = write!(
            s,
            r#"<polyline points="{}" fill="none" stroke="{color}" stroke-width="1.8"/>
"#,
            pts.join(" ")
        );
        let ly = mt + 18.0 * ci as f64 + 10.0;
        let _ = write!(
            s,
            r#"<line x1="{}" y1="{ly}" x2="{}" y2="{ly}" stroke="{color}" stroke-width="3"/>
<text x="{}" y="{}" font-family="sans-serif" font-size="12">{}</text>
"#,
            w - mr - 180.0,
            w - mr - 150.0,
            w - mr - 144.0,
            ly + 4.0,
            c.name
        );
    }
    s.push_str("</svg>\n");
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Vec<Curve> {
        vec![
            Curve::new("sodda", vec![(0.0, 1.0), (1.0, 0.5), (2.0, 0.3)]),
            Curve::new("radisa-avg", vec![(0.0, 1.0), (1.5, 0.4), (3.0, 0.25)]),
        ]
    }

    #[test]
    fn ascii_contains_marks_and_legend() {
        let a = ascii(&sample(), 10, 40);
        assert!(a.contains('*') && a.contains('o'));
        assert!(a.contains("sodda"));
        assert!(a.contains("radisa-avg"));
    }

    #[test]
    fn svg_is_wellformed_enough() {
        let s = svg(&sample(), "Figure X", "simulated seconds");
        assert!(s.starts_with("<svg"));
        assert!(s.ends_with("</svg>\n"));
        assert_eq!(s.matches("<polyline").count(), 2);
        assert!(s.contains("Figure X"));
    }

    #[test]
    fn degenerate_single_point_does_not_panic() {
        let c = vec![Curve::new("p", vec![(1.0, 2.0)])];
        let _ = ascii(&c, 5, 20);
        let _ = svg(&c, "t", "x");
    }

    #[test]
    fn from_history_axes() {
        use crate::metrics::{History, IterRecord};
        let mut h = History::new("x");
        h.push(IterRecord { iter: 3, loss: 0.5, wall_s: 1.0, sim_s: 2.0, comm_bytes: 0, grad_coord_evals: 0 });
        let t = Curve::from_history("a", &h, true);
        assert_eq!(t.points, vec![(2.0, 0.5)]);
        let i = Curve::from_history("b", &h, false);
        assert_eq!(i.points, vec![(3.0, 0.5)]);
    }
}
