//! Seed-variation statistics (paper Table 2).
//!
//! For S seeds × T iterations of objective values, the paper reports the
//! average and maximum over iterations of `max_s − avg_s` and
//! `avg_s − min_s`, where max/avg/min are taken across seeds at a fixed
//! iteration.

/// Table 2 row for one algorithm.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SeedVariation {
    pub avg_max_minus_avg: f64,
    pub avg_avg_minus_min: f64,
    pub max_max_minus_avg: f64,
    pub max_avg_minus_min: f64,
}

/// `curves[s][t]` = objective at iteration t for seed s. All curves must
/// have equal length ≥ 1.
pub fn seed_variation(curves: &[Vec<f64>]) -> SeedVariation {
    assert!(!curves.is_empty(), "need at least one seed");
    let t_len = curves[0].len();
    assert!(t_len > 0 && curves.iter().all(|c| c.len() == t_len), "ragged curves");

    let s = curves.len() as f64;
    let mut sum_hi = 0.0f64;
    let mut sum_lo = 0.0f64;
    let mut max_hi = f64::MIN;
    let mut max_lo = f64::MIN;
    for t in 0..t_len {
        let vals = curves.iter().map(|c| c[t]);
        let mx = vals.clone().fold(f64::MIN, f64::max);
        let mn = vals.clone().fold(f64::MAX, f64::min);
        let avg = vals.sum::<f64>() / s;
        sum_hi += mx - avg;
        sum_lo += avg - mn;
        max_hi = max_hi.max(mx - avg);
        max_lo = max_lo.max(avg - mn);
    }
    SeedVariation {
        avg_max_minus_avg: sum_hi / t_len as f64,
        avg_avg_minus_min: sum_lo / t_len as f64,
        max_max_minus_avg: max_hi,
        max_avg_minus_min: max_lo,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::assert_close;

    #[test]
    fn identical_seeds_have_zero_variation() {
        let v = seed_variation(&[vec![1.0, 0.5], vec![1.0, 0.5], vec![1.0, 0.5]]);
        assert_eq!(v.avg_max_minus_avg, 0.0);
        assert_eq!(v.max_avg_minus_min, 0.0);
    }

    #[test]
    fn hand_computed_case() {
        // t=0: vals {1, 2, 3}: max-avg = 1, avg-min = 1
        // t=1: vals {0, 0, 3}: max-avg = 2, avg-min = 1
        let v = seed_variation(&[vec![1.0, 0.0], vec![2.0, 0.0], vec![3.0, 3.0]]);
        assert_close!(v.avg_max_minus_avg, 1.5);
        assert_close!(v.avg_avg_minus_min, 1.0);
        assert_close!(v.max_max_minus_avg, 2.0);
        assert_close!(v.max_avg_minus_min, 1.0);
    }

    #[test]
    #[should_panic(expected = "ragged")]
    fn rejects_ragged() {
        seed_variation(&[vec![1.0], vec![1.0, 2.0]]);
    }
}
