//! Run metrics: per-iteration history, CSV/JSON emission and the
//! seed-variation statistics behind the paper's Table 2.

pub mod plot;
mod stats;

pub use stats::{seed_variation, SeedVariation};

use std::io::Write;

use crate::util::json::{self, Value};

/// One outer iteration's record.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct IterRecord {
    pub iter: usize,
    /// objective F(w^t) (evaluated every `eval_every` iterations)
    pub loss: f64,
    /// wall-clock seconds since training start (this process)
    pub wall_s: f64,
    /// simulated cluster seconds (max worker compute + SimNet comm)
    pub sim_s: f64,
    /// cumulative bytes moved over the simulated network
    pub comm_bytes: u64,
    /// cumulative scalar gradient-coordinate evaluations — the paper's
    /// "number of gradient coordinate computations" saving in §1
    pub grad_coord_evals: u64,
}

/// Which phase of an outer iteration a fault was injected into.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultPhase {
    /// the µ^t-estimate z/u pass (phase 1)
    Mu,
    /// the gradient-slice pass (phase 2)
    Grad,
    /// the parallel SVRG inner loops (phase 3)
    Inner,
}

impl std::fmt::Display for FaultPhase {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            FaultPhase::Mu => "mu",
            FaultPhase::Grad => "grad",
            FaultPhase::Inner => "inner",
        })
    }
}

impl std::str::FromStr for FaultPhase {
    type Err = anyhow::Error;
    fn from_str(s: &str) -> anyhow::Result<FaultPhase> {
        match s {
            "mu" => Ok(FaultPhase::Mu),
            "grad" => Ok(FaultPhase::Grad),
            "inner" => Ok(FaultPhase::Inner),
            other => anyhow::bail!("unknown fault phase {other:?} (expected mu|grad|inner)"),
        }
    }
}

/// One injected worker fault (recorded by the trainer at arm time;
/// transient recovery is bit-transparent, so for `perm: false` this is
/// pure observability — a `perm: true` record marks the loss the
/// re-shard step reacted to).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FaultRecord {
    /// outer iteration the kill landed in
    pub iter: usize,
    /// linear worker id (`p·Q + q`) **on the grid at arm time**
    pub worker: usize,
    pub phase: FaultPhase,
    /// permanent loss: the worker was not respawned; the trainer
    /// re-sharded onto a shrunk grid (see [`ReshardRecord`])
    pub perm: bool,
}

/// One live re-shard: the trainer's reaction to a permanent worker
/// loss, with the simulated shuffle cost actually charged to SimNet.
/// (Voluntary `reconfigure` grid changes restage through the same
/// machinery but run between sessions, off the simulated clock — they
/// don't append here.)
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ReshardRecord {
    /// outer iteration that was interrupted and re-run on the new grid
    pub iter: usize,
    /// worker permanently lost (id on the pre-shrink grid)
    pub worker: usize,
    pub from_p: usize,
    pub from_q: usize,
    pub to_p: usize,
    pub to_q: usize,
    /// bytes of shard payload re-staged over the simulated network —
    /// equal to the summed `approx_bytes()` of every re-staged block
    pub bytes: u64,
    /// simulated seconds the shuffle cost (makespan + wire time)
    pub sim_s: f64,
}

/// One outer iteration's bounded-staleness accounting (recorded only
/// when a `StalenessPolicy` with `quorum_frac < 1` is active and the
/// iteration deviated from the full barrier in some way).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct StalenessRecord {
    /// outer iteration
    pub iter: usize,
    /// block replies inside the µ-phase quorum (out of `workers`)
    pub mu_quorum: usize,
    /// block replies inside the gradient-phase quorum
    pub grad_quorum: usize,
    /// grid size P·Q at this iteration
    pub workers: usize,
    /// replies parked in the `LateSet` this iteration
    pub late: usize,
    /// parked replies folded into this iteration's aggregates
    pub folds: usize,
    /// parked replies dropped for exceeding `max_staleness_iters`
    pub drops: usize,
}

/// Append-only training history.
#[derive(Debug, Clone, Default)]
pub struct History {
    pub run: String,
    pub records: Vec<IterRecord>,
    /// faults injected and recovered during the run (empty for a
    /// fault-free run; **not** part of trajectory-equality comparisons,
    /// which go through [`History::records`]/[`History::losses`] — a
    /// recovered run is bit-identical to a fault-free one everywhere
    /// else)
    pub faults: Vec<FaultRecord>,
    /// live re-shards (permanent losses and `reconfigure` grid changes)
    pub reshards: Vec<ReshardRecord>,
    /// bounded-staleness accounting (empty for barrier runs)
    pub staleness: Vec<StalenessRecord>,
}

impl History {
    pub fn new(run: impl Into<String>) -> Self {
        Self {
            run: run.into(),
            records: Vec::new(),
            faults: Vec::new(),
            reshards: Vec::new(),
            staleness: Vec::new(),
        }
    }

    pub fn push(&mut self, rec: IterRecord) {
        self.records.push(rec);
    }

    pub fn final_loss(&self) -> Option<f64> {
        self.records.last().map(|r| r.loss)
    }

    pub fn min_loss(&self) -> Option<f64> {
        self.records.iter().map(|r| r.loss).fold(None, |a, b| Some(a.map_or(b, |a: f64| a.min(b))))
    }

    /// Loss values in iteration order (used by comparison harnesses).
    pub fn losses(&self) -> Vec<f64> {
        self.records.iter().map(|r| r.loss).collect()
    }

    /// First simulated time at which loss ≤ `target` (linear scan).
    pub fn time_to_loss(&self, target: f64) -> Option<f64> {
        self.records.iter().find(|r| r.loss <= target).map(|r| r.sim_s)
    }

    pub fn to_csv(&self) -> String {
        let mut s = String::from("iter,loss,wall_s,sim_s,comm_bytes,grad_coord_evals\n");
        for r in &self.records {
            s.push_str(&format!(
                "{},{:.6e},{:.6},{:.6},{},{}\n",
                r.iter, r.loss, r.wall_s, r.sim_s, r.comm_bytes, r.grad_coord_evals
            ));
        }
        s
    }

    pub fn write_csv(&self, path: &std::path::Path) -> std::io::Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut f = std::fs::File::create(path)?;
        f.write_all(self.to_csv().as_bytes())
    }

    pub fn to_json(&self) -> Value {
        let mut fields = vec![
            ("run", json::s(self.run.clone())),
            (
                "records",
                Value::Arr(
                    self.records
                        .iter()
                        .map(|r| {
                            json::obj(vec![
                                ("iter", json::num(r.iter as f64)),
                                ("loss", json::num(r.loss)),
                                ("wall_s", json::num(r.wall_s)),
                                ("sim_s", json::num(r.sim_s)),
                                ("comm_bytes", json::num(r.comm_bytes as f64)),
                                ("grad_coord_evals", json::num(r.grad_coord_evals as f64)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ];
        // emitted only for runs that actually saw faults, keeping
        // fault-free histories byte-identical to the legacy schema
        if !self.faults.is_empty() {
            fields.push((
                "faults",
                Value::Arr(
                    self.faults
                        .iter()
                        .map(|f| {
                            let mut rec = vec![
                                ("iter", json::num(f.iter as f64)),
                                ("worker", json::num(f.worker as f64)),
                                ("phase", json::s(f.phase.to_string())),
                            ];
                            // emitted only for escalated faults, keeping
                            // transient records on the legacy schema
                            if f.perm {
                                rec.push(("perm", Value::Bool(true)));
                            }
                            json::obj(rec)
                        })
                        .collect(),
                ),
            ));
        }
        if !self.reshards.is_empty() {
            fields.push((
                "reshards",
                Value::Arr(
                    self.reshards
                        .iter()
                        .map(|r| {
                            json::obj(vec![
                                ("iter", json::num(r.iter as f64)),
                                ("worker", json::num(r.worker as f64)),
                                ("from_p", json::num(r.from_p as f64)),
                                ("from_q", json::num(r.from_q as f64)),
                                ("to_p", json::num(r.to_p as f64)),
                                ("to_q", json::num(r.to_q as f64)),
                                ("bytes", json::num(r.bytes as f64)),
                                ("sim_s", json::num(r.sim_s)),
                            ])
                        })
                        .collect(),
                ),
            ));
        }
        if !self.staleness.is_empty() {
            fields.push((
                "staleness",
                Value::Arr(
                    self.staleness
                        .iter()
                        .map(|s| {
                            json::obj(vec![
                                ("iter", json::num(s.iter as f64)),
                                ("mu_quorum", json::num(s.mu_quorum as f64)),
                                ("grad_quorum", json::num(s.grad_quorum as f64)),
                                ("workers", json::num(s.workers as f64)),
                                ("late", json::num(s.late as f64)),
                                ("folds", json::num(s.folds as f64)),
                                ("drops", json::num(s.drops as f64)),
                            ])
                        })
                        .collect(),
                ),
            ));
        }
        json::obj(fields)
    }

    pub fn from_json(v: &Value) -> anyhow::Result<History> {
        let mut h = History::new(v.get("run")?.as_str()?);
        for r in v.get("records")?.as_arr()? {
            h.push(IterRecord {
                iter: r.get("iter")?.as_usize()?,
                loss: r.get("loss")?.as_f64()?,
                wall_s: r.get("wall_s")?.as_f64()?,
                sim_s: r.get("sim_s")?.as_f64()?,
                comm_bytes: r.get("comm_bytes")?.as_f64()? as u64,
                grad_coord_evals: r.get("grad_coord_evals")?.as_f64()? as u64,
            });
        }
        if let Some(faults) = v.opt("faults") {
            for f in faults.as_arr()? {
                h.faults.push(FaultRecord {
                    iter: f.get("iter")?.as_usize()?,
                    worker: f.get("worker")?.as_usize()?,
                    phase: f.get("phase")?.as_str()?.parse()?,
                    perm: f.opt("perm").map(|b| b.as_bool()).transpose()?.unwrap_or(false),
                });
            }
        }
        if let Some(reshards) = v.opt("reshards") {
            for r in reshards.as_arr()? {
                h.reshards.push(ReshardRecord {
                    iter: r.get("iter")?.as_usize()?,
                    worker: r.get("worker")?.as_usize()?,
                    from_p: r.get("from_p")?.as_usize()?,
                    from_q: r.get("from_q")?.as_usize()?,
                    to_p: r.get("to_p")?.as_usize()?,
                    to_q: r.get("to_q")?.as_usize()?,
                    bytes: r.get("bytes")?.as_f64()? as u64,
                    sim_s: r.get("sim_s")?.as_f64()?,
                });
            }
        }
        if let Some(staleness) = v.opt("staleness") {
            for s in staleness.as_arr()? {
                h.staleness.push(StalenessRecord {
                    iter: s.get("iter")?.as_usize()?,
                    mu_quorum: s.get("mu_quorum")?.as_usize()?,
                    grad_quorum: s.get("grad_quorum")?.as_usize()?,
                    workers: s.get("workers")?.as_usize()?,
                    late: s.get("late")?.as_usize()?,
                    folds: s.get("folds")?.as_usize()?,
                    drops: s.get("drops")?.as_usize()?,
                });
            }
        }
        Ok(h)
    }

    pub fn write_json(&self, path: &std::path::Path) -> std::io::Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut f = std::fs::File::create(path)?;
        f.write_all(self.to_json().to_string_pretty().as_bytes())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(iter: usize, loss: f64, sim_s: f64) -> IterRecord {
        IterRecord { iter, loss, wall_s: sim_s, sim_s, comm_bytes: 10, grad_coord_evals: 100 }
    }

    #[test]
    fn push_and_summaries() {
        let mut h = History::new("t");
        h.push(rec(1, 1.0, 0.1));
        h.push(rec(2, 0.4, 0.2));
        h.push(rec(3, 0.6, 0.3));
        assert_eq!(h.final_loss(), Some(0.6));
        assert_eq!(h.min_loss(), Some(0.4));
        assert_eq!(h.time_to_loss(0.5), Some(0.2));
        assert_eq!(h.time_to_loss(0.1), None);
    }

    #[test]
    fn csv_has_header_and_rows() {
        let mut h = History::new("t");
        h.push(rec(1, 0.5, 0.1));
        let csv = h.to_csv();
        assert!(csv.starts_with("iter,loss"));
        assert_eq!(csv.lines().count(), 2);
    }

    #[test]
    fn json_roundtrip() {
        let mut h = History::new("t");
        h.push(rec(1, 0.5, 0.1));
        let v = crate::util::json::Value::parse(&h.to_json().to_string_pretty()).unwrap();
        let back = History::from_json(&v).unwrap();
        assert_eq!(back.records, h.records);
        assert_eq!(back.run, "t");
    }

    #[test]
    fn fault_records_round_trip_and_stay_off_the_legacy_schema() {
        let mut h = History::new("t");
        h.push(rec(1, 0.5, 0.1));
        assert!(
            !h.to_json().to_string_pretty().contains("faults"),
            "fault-free history must keep the legacy schema"
        );
        h.faults.push(FaultRecord { iter: 3, worker: 2, phase: FaultPhase::Inner, perm: false });
        h.faults.push(FaultRecord { iter: 5, worker: 0, phase: FaultPhase::Mu, perm: true });
        let text = h.to_json().to_string_pretty();
        assert_eq!(
            text.matches("perm").count(),
            1,
            "only escalated faults carry the perm key"
        );
        let v = crate::util::json::Value::parse(&text).unwrap();
        let back = History::from_json(&v).unwrap();
        assert_eq!(back.faults, h.faults);
    }

    #[test]
    fn reshard_records_round_trip_and_stay_off_the_legacy_schema() {
        let mut h = History::new("t");
        h.push(rec(1, 0.5, 0.1));
        assert!(
            !h.to_json().to_string_pretty().contains("reshards"),
            "reshard-free history must keep the legacy schema"
        );
        h.reshards.push(ReshardRecord {
            iter: 4,
            worker: 2,
            from_p: 3,
            from_q: 2,
            to_p: 2,
            to_q: 2,
            bytes: 12_345,
            sim_s: 0.75,
        });
        let v = crate::util::json::Value::parse(&h.to_json().to_string_pretty()).unwrap();
        let back = History::from_json(&v).unwrap();
        assert_eq!(back.reshards, h.reshards);
    }

    #[test]
    fn staleness_records_round_trip_and_stay_off_the_legacy_schema() {
        let mut h = History::new("t");
        h.push(rec(1, 0.5, 0.1));
        assert!(
            !h.to_json().to_string_pretty().contains("staleness"),
            "barrier history must keep the legacy schema"
        );
        h.staleness.push(StalenessRecord {
            iter: 2,
            mu_quorum: 5,
            grad_quorum: 6,
            workers: 6,
            late: 1,
            folds: 1,
            drops: 0,
        });
        h.staleness.push(StalenessRecord {
            iter: 4,
            mu_quorum: 4,
            grad_quorum: 5,
            workers: 6,
            late: 2,
            folds: 0,
            drops: 2,
        });
        let v = crate::util::json::Value::parse(&h.to_json().to_string_pretty()).unwrap();
        let back = History::from_json(&v).unwrap();
        assert_eq!(back.staleness, h.staleness);
    }

    #[test]
    fn fault_phase_parses_its_display() {
        for p in [FaultPhase::Mu, FaultPhase::Grad, FaultPhase::Inner] {
            assert_eq!(p.to_string().parse::<FaultPhase>().unwrap(), p);
        }
        assert!("outer".parse::<FaultPhase>().is_err());
    }
}
