//! `repro` — the SODDA launcher.
//!
//! Subcommands:
//!   train        one training run (preset or explicit dims, any algorithm)
//!   table1/2/3   regenerate the paper's tables
//!   fig2/3/4     regenerate the paper's figures (CSV curves under --out)
//!   perf         per-phase timing breakdown for the perf log
//!   help         this text
//!
//! Examples:
//!   repro train --preset small --algo sodda --iters 40
//!   repro train --n 5000 --m 360 --algo radisa-avg --engine xla
//!   repro train --preset small --target-loss 0.1
//!   repro train --preset small --profile one-slow:4 --weighted --faults 2@3:mu
//!   repro train --preset small --checkpoint run.ckpt --checkpoint-every 5
//!   repro fig2 --panel a --out results
//!   repro fig3 --scale 100 --iters 20

use std::ops::ControlFlow;
use std::time::Instant;

use anyhow::{bail, Context, Result};

use sodda::config::{
    preset, AlgorithmKind, DataConfig, ExecutorKind, ExperimentConfig, Schedule, ShardWeighting,
};
use sodda::harness::{self, Opts};
use sodda::loss::Loss;
use sodda::util::cli::Args;
use sodda::util::json;
use sodda::{RunState, Trainer};

const HELP: &str = "\
repro — SODDA (Fang & Klabjan 2018) reproduction driver

USAGE: repro <subcommand> [flags]

SUBCOMMANDS
  train    run one configuration and write its loss curve
  table1   print Table 1 (synthetic dataset configurations)
  table2   run the 10-seed variation study (Table 2)
  table3   print Table 3 (sparse SemMed-substitute datasets)
  fig2     (b,c,d) sweeps vs RADiSA-avg on `small` — panels a..g
  fig3     SODDA vs RADiSA-avg on medium+large, 3 seeds
  fig4     SODDA vs RADiSA-avg on the sparse datasets
  perf     per-phase wall-clock breakdown (EXPERIMENTS.md §Perf);
           also writes a machine-readable report (--json NAME, default
           perf.json under --out)
  bench-gate  compare bench JSON (--dir, default target/bench) against
           a checked-in baseline (--baseline, default
           benches/baseline.json); non-zero exit on any median slower
           than max_ratio x baseline or any allocs_per_iter above its
           absolute max_allocs_per_iter budget (--max-ratio overrides the file)
  theory   empirical checks of Theorems 2-4 (rates, error floors)
  gen-data materialize a dataset to LIBSVM text or SODDA binary
  baselines  mini-batch SGD + CentralVR vs SODDA on one dataset

COMMON FLAGS
  --out DIR        output directory (default results)
  --scale K        dataset scale divisor (default: preset laptop scale)
  --iters T        outer iterations (default 30; table2 40)
  --engine E       native | xla (default native; xla needs --features xla)
  --p P --q Q      partition grid (default 5 x 3, the paper's)
  --steps L        inner-loop length (default 32)
  --gamma0 G       learning-rate scale (default 0.08, see README)
  --seed S         RNG seed (default 1)
  --executor X     in-process | threaded (default: SODDA_EXECUTOR env,
                   else in-process; see README \"Execution modes\")
  --threads        shorthand for --executor threaded
  --profile P      cluster heterogeneity for the cost model: uniform |
                   one-slow[:f] | long-tail[:f] | explicit:r0,r1,...
                   (default uniform; see README \"Fault tolerance\")
  --shard-weighting W  balanced | throughput — throughput sizes row
                   shards by the worker rates in --profile
  --weighted       shorthand for --shard-weighting throughput

TRAIN FLAGS
  --preset NAME    small | medium | large | diag-neg10 | loc-neg5
  --n N --m M      explicit dense dims (instead of --preset)
  --data FILE      load a .svm/.libsvm or .bin dataset from disk
  --sparse-nnz K   make explicit dims sparse with avg K nnz/row
  --algo A         sodda | radisa | radisa-avg (default sodda)
  --loss F         hinge | logistic | squared (default hinge)
  --b --c --d      sampling fractions (default 0.85/0.80/0.85)
  --target-loss F  stop early once F(w) reaches this value
  --faults PLAN    kill schedule worker@iter:phase[!perm][,...] with
                   phases mu | grad | inner (e.g. \"2@3:mu,1@4:grad!perm\");
                   transient recovery is bit-transparent, a !perm event
                   is a permanent loss: the run re-shards onto a shrunk
                   grid and continues. Overrides the SODDA_FAULT_PLAN
                   environment variable
  --recovery R[:B[:P]]  escalation policy: R respawn retries per fault
                   (linear backoff B ms between attempts) before the
                   leader declares the worker permanently lost; P ms
                   liveness-probe interval (default 3:10:100)
  --staleness Q[:S[:T]]  bounded-staleness quorum: release each mu/
                   gradient phase once ceil(Q * P*Q) block replies land
                   (or after T x the fastest worker's modeled time);
                   stragglers park and fold into a later iteration at
                   age-discounted weight, dropped past S iterations
                   (default 1:2:4 — Q=1 is the hard barrier, bit-for-
                   bit. Overrides the SODDA_STALENESS environment
                   variable; see README \"Bounded-staleness\")
  --checkpoint F   write a resumable snapshot to <out>/F every
                   --checkpoint-every K iterations (default 1) and at
                   the end; excludes --target-loss
  --resume F       continue from a snapshot file written by
                   --checkpoint (pass the original run's config flags)
";

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn opts_from(args: &Args) -> Result<Opts> {
    let mut o = Opts {
        out_dir: args.str_or("out", "results").into(),
        scale: args.parse_or("scale", 0usize)?,
        iters: args.parse_or("iters", 30usize)?,
        engine: args.str_or("engine", "native").parse().map_err(|e: String| anyhow::anyhow!(e))?,
        p: args.parse_or("p", 5usize)?,
        q: args.parse_or("q", 3usize)?,
        inner_steps: args.parse_or("steps", 32usize)?,
        gamma0: args.parse_or("gamma0", 0.08f64)?,
        seed: args.parse_or("seed", 1u64)?,
    };
    if args.has("iters") {
        o.iters = args.parse_or("iters", o.iters)?;
    }
    Ok(o)
}

fn data_config(args: &Args, o: &Opts) -> Result<DataConfig> {
    if let Some(path) = args.get("data") {
        // dims must be declared (or discoverable) for partition validation
        let probe = if path.ends_with(".bin") {
            sodda::data::io::read_binary(std::path::Path::new(path))?
        } else {
            sodda::data::io::read_libsvm(std::path::Path::new(path), args.parse_or("m", 0usize)?)?
        };
        return Ok(DataConfig::File { path: path.to_string(), n: probe.n(), m: probe.m() });
    }
    if let Some(name) = args.get("preset") {
        let pr = preset(name).with_context(|| format!("unknown preset {name:?}"))?;
        Ok(pr.data_config(if o.scale == 0 { pr.default_scale } else { o.scale }, o.p, o.q))
    } else {
        let n = args.parse_or("n", 5000usize)?;
        let m = args.parse_or("m", 360usize)?;
        match args.get("sparse-nnz") {
            Some(_) => Ok(DataConfig::Sparse { n, m, avg_nnz: args.parse_or("sparse-nnz", 20usize)? }),
            None => Ok(DataConfig::Dense { n, m }),
        }
    }
}

fn run() -> Result<()> {
    let args = Args::from_env()?;
    let o = opts_from(&args)?;
    match args.subcommand.as_deref() {
        None | Some("help") => {
            print!("{HELP}");
            Ok(())
        }
        Some("train") => cmd_train(&args, &o),
        Some("table1") => harness::table1(&o).map(drop),
        Some("table2") => {
            let mut o = o;
            if !args.has("iters") {
                o.iters = 40; // the paper's Table 2 protocol
            }
            harness::table2(&o).map(drop)
        }
        Some("table3") => harness::table3(&o).map(drop),
        Some("fig2") => {
            let panel = args.str_or("panel", "a");
            let panel = panel.chars().next().unwrap_or('a');
            harness::fig2(&o, panel)
        }
        Some("fig3") => harness::fig3(&o),
        Some("fig4") => harness::fig4(&o),
        Some("perf") => cmd_perf(&args, &o),
        Some("bench-gate") => cmd_bench_gate(&args),
        Some("theory") => sodda::harness::theory::run(&o).map(drop),
        Some("gen-data") => cmd_gen_data(&args, &o),
        Some("baselines") => cmd_baselines(&args, &o),
        Some(other) => bail!("unknown subcommand {other:?}; try `repro help`"),
    }
}

/// Assemble the `train`/`perf`/`baselines` config from CLI flags through
/// the validating builder (`algo` is parsed once by the caller, which
/// also needs it for naming/printing).
fn cfg_from(
    args: &Args,
    o: &Opts,
    name: &str,
    data: DataConfig,
    algo: AlgorithmKind,
) -> Result<ExperimentConfig> {
    let loss: Loss = args.str_or("loss", "hinge").parse().map_err(|e: String| anyhow::anyhow!(e))?;
    let mut b = ExperimentConfig::builder()
        .name(args.str_or("name", name))
        .data(data)
        .grid(o.p, o.q)
        .loss(loss)
        .algorithm(algo)
        .fractions_bcd(
            args.parse_or("b", 0.85f64)?,
            args.parse_or("c", 0.80f64)?,
            args.parse_or("d", 0.85f64)?,
        )
        .inner_steps(o.inner_steps)
        .outer_iters(o.iters)
        .schedule(Schedule::ScaledSqrt { gamma0: o.gamma0 })
        .seed(o.seed)
        .engine(o.engine)
        .eval_every(args.parse_or("eval-every", 1usize)?);
    // executor knobs: bare --threads is shorthand, an explicit
    // --executor wins, otherwise the builder leaves the choice to
    // SODDA_EXECUTOR / the in-process default (ExecutorKind::resolve)
    if args.has("threads") {
        b = b.executor(ExecutorKind::Threaded);
    }
    if let Some(e) = args.get("executor") {
        b = b.executor(e.parse().map_err(|e: String| anyhow::anyhow!(e))?);
    }
    // heterogeneity knobs: bare --weighted is shorthand, an explicit
    // --shard-weighting wins (mirrors the --threads/--executor pair)
    if let Some(p) = args.get("profile") {
        b = b.cluster_profile(p.parse().map_err(|e: String| anyhow::anyhow!(e))?);
    }
    if args.has("weighted") {
        b = b.shard_weighting(ShardWeighting::Throughput);
    }
    if let Some(w) = args.get("shard-weighting") {
        b = b.shard_weighting(w.parse().map_err(|e: String| anyhow::anyhow!(e))?);
    }
    if let Some(r) = args.get("recovery") {
        b = b.recovery(r.parse().map_err(|e: String| anyhow::anyhow!(e))?);
    }
    if let Some(s) = args.get("staleness") {
        b = b.staleness(s.parse().map_err(|e: String| anyhow::anyhow!(e))?);
    }
    b.build()
}

fn parse_algo(args: &Args) -> Result<AlgorithmKind> {
    args.str_or("algo", "sodda").parse().map_err(|e: String| anyhow::anyhow!(e))
}

fn cmd_train(args: &Args, o: &Opts) -> Result<()> {
    let data = data_config(args, o)?;
    let algo = parse_algo(args)?;
    let cfg = cfg_from(args, o, &format!("train_{algo}"), data, algo)?;
    println!("config:\n{}", cfg.to_json());
    let ds = cfg.data.try_materialize(cfg.seed)?;
    println!("dataset {} ({} x {})", ds.name, ds.n(), ds.m());
    // --resume continues a checkpointed run mid-trajectory; the config
    // assembled above must describe the same session (validated at
    // staging: run name, width, iteration horizon — the snapshot's
    // executor is provenance only, so resuming on the other one is fine)
    let mut trainer = match args.get("resume") {
        Some(path) => {
            let snap = RunState::load(std::path::Path::new(path))?;
            let t = Trainer::resume_with_dataset(cfg.clone(), ds, snap)?;
            println!("resumed {path} at iteration {}", t.iteration());
            t
        }
        None => Trainer::with_dataset(cfg.clone(), ds)?,
    };
    if let Some(plan) = args.get("faults") {
        trainer.set_fault_plan(Some(plan.parse()?));
    }
    println!(
        "engine {}, algorithm {}, executor {}\n",
        trainer.engine().name(),
        cfg.algorithm,
        trainer.executor()
    );

    let target = args.parse_or("target-loss", f64::NEG_INFINITY)?;
    let t0 = Instant::now();
    fn print_record(r: &sodda::metrics::IterRecord) {
        println!("{:4}   {:.5}   {:8.3}  {:8.2}", r.iter, r.loss, r.sim_s, r.comm_bytes as f64 / 1e6);
    }
    let out = if trainer.is_done() {
        println!("snapshot is already at the final iteration; writing its history");
        trainer.outcome()
    } else if let Some(name) = args.get("checkpoint") {
        if args.has("target-loss") {
            bail!("--checkpoint and --target-loss are mutually exclusive");
        }
        let every = args.parse_or("checkpoint-every", 1usize)?;
        let ckpt = o.out_dir.join(name);
        println!("checkpointing to {} every {every} iteration(s)", ckpt.display());
        let out = trainer.run_with_checkpoints(&ckpt, every)?;
        println!("iter   F(w)       sim_s     comm_MB");
        out.history.records.iter().for_each(print_record);
        out
    } else {
        println!("iter   F(w)       sim_s     comm_MB");
        trainer.run_with_observer(|r| {
            print_record(r);
            if r.loss <= target {
                ControlFlow::Break(())
            } else {
                ControlFlow::Continue(())
            }
        })?
    };
    if !out.history.faults.is_empty() {
        let log: Vec<String> = out
            .history
            .faults
            .iter()
            .map(|f| {
                format!("{}@{}:{}{}", f.worker, f.iter, f.phase, if f.perm { "!perm" } else { "" })
            })
            .collect();
        println!("survived {} injected fault(s): {}", log.len(), log.join(","));
    }
    for r in &out.history.reshards {
        println!(
            "permanent loss of worker {} at iter {}: re-sharded {}x{} -> {}x{} \
             ({:.2} MB shuffled, {:.3} sim s)",
            r.worker,
            r.iter,
            r.from_p,
            r.from_q,
            r.to_p,
            r.to_q,
            r.bytes as f64 / 1e6,
            r.sim_s
        );
    }
    let path = o.out_dir.join(format!("{}.csv", cfg.name));
    out.history.write_csv(&path)?;
    out.history.write_json(&o.out_dir.join(format!("{}.json", cfg.name)))?;
    let stopped = trainer.iteration();
    if stopped < cfg.outer_iters {
        println!("\nearly stop: reached --target-loss {target} at iteration {stopped}");
    }
    println!(
        "\ndone in {:.2}s wall; final F = {:.5}; wrote {}",
        t0.elapsed().as_secs_f64(),
        out.history.final_loss().unwrap_or(f64::NAN),
        path.display()
    );
    Ok(())
}

/// Materialize a preset/explicit dataset to disk (LIBSVM or binary).
fn cmd_gen_data(args: &Args, o: &Opts) -> Result<()> {
    use sodda::data::io;
    let data = data_config(args, o)?;
    let ds = data.try_materialize(o.seed)?;
    let format = args.str_or("format", "libsvm");
    let default_name = format!(
        "{}.{}",
        ds.name,
        if format == "binary" { "bin" } else { "svm" }
    );
    let path = o.out_dir.join(args.str_or("file", &default_name));
    std::fs::create_dir_all(&o.out_dir)?;
    match format.as_str() {
        "libsvm" => io::write_libsvm(&ds, &path)?,
        "binary" => io::write_binary(&ds, &path)?,
        other => bail!("unknown --format {other:?} (libsvm|binary)"),
    }
    // round-trip check so the file is guaranteed loadable
    let back = match format.as_str() {
        "libsvm" => io::read_libsvm(&path, ds.m())?,
        _ => io::read_binary(&path)?,
    };
    anyhow::ensure!(back.n() == ds.n() && back.m() == ds.m(), "round-trip mismatch");
    println!(
        "wrote {} ({} x {}, {} nnz, {} bytes)",
        path.display(),
        ds.n(),
        ds.m(),
        ds.x.nnz(),
        std::fs::metadata(&path)?.len()
    );
    Ok(())
}

/// Related-work baselines head-to-head (§2): mini-batch SGD, CentralVR.
fn cmd_baselines(args: &Args, o: &Opts) -> Result<()> {
    use sodda::coordinator::baselines;
    use sodda::engine::NativeEngine;
    use std::sync::Arc;
    let data = data_config(args, o)?;
    let batch = args.parse_or("batch", 128usize)?;
    let cfg = cfg_from(args, o, "baselines", data, parse_algo(args)?)?;
    let ds = Arc::new(cfg.data.try_materialize(cfg.seed)?);
    println!("dataset {} ({} x {})\n", ds.name, ds.n(), ds.m());
    let mut trainer = Trainer::with_dataset(cfg.clone(), Arc::clone(&ds))?;
    let main_algo = cfg.algorithm.to_string();
    let main_hist = trainer.run()?.history;
    let sgd = baselines::minibatch_sgd(&cfg, &ds, Arc::new(NativeEngine), batch)?;
    let cvr = baselines::central_vr(&cfg, &ds, Arc::new(NativeEngine), batch, 10)?;
    println!("{:<12} {:>10} {:>10} {:>12}", "method", "final F", "sim_s", "comm MB");
    for (name, h) in [(main_algo.as_str(), &main_hist), ("sgd", &sgd), ("central-vr", &cvr)] {
        let last = h.records.last().unwrap();
        println!(
            "{name:<12} {:>10.4} {:>10.3} {:>12.2}",
            last.loss,
            last.sim_s,
            last.comm_bytes as f64 / 1e6
        );
        h.write_csv(&o.out_dir.join(format!("baseline_{name}.csv")))?;
    }
    Ok(())
}

/// Phase-level wall-clock breakdown on a standard run. The session is
/// staged once and reused across the warm-up, timed and eval-off runs —
/// so the measurement isolates the training path from staging cost.
fn cmd_perf(args: &Args, o: &Opts) -> Result<()> {
    let data = data_config(args, o)?;
    println!("== perf breakdown ({} x {}, engine {:?}) ==", data.n(), data.m(), o.engine);
    let mut o_short = o.clone();
    o_short.iters = o.iters.min(10);
    let cfg = cfg_from(args, &o_short, "perf", data, parse_algo(args)?)?;
    let ds = cfg.data.try_materialize(cfg.seed)?;
    let mut trainer = Trainer::with_dataset(cfg.clone(), ds)?;
    // warm-up run (XLA: compiles + stages), then timed run on the session
    let _ = trainer.run()?;
    trainer.reset();
    let t0 = Instant::now();
    let out = trainer.run()?;
    let wall = t0.elapsed().as_secs_f64();
    println!(
        "{} iterations in {wall:.3}s wall ({:.1} ms/iter) — engine {}",
        cfg.outer_iters,
        1e3 * wall / cfg.outer_iters as f64,
        trainer.engine().name()
    );
    // eval-off run isolates the training path from objective evaluation
    trainer.reconfigure(cfg.to_builder().eval_every(cfg.outer_iters).build()?)?;
    let t1 = Instant::now();
    let _ = trainer.run()?;
    let train_only = t1.elapsed().as_secs_f64();
    println!(
        "training path only: {train_only:.3}s ({:.1} ms/iter); objective eval: {:.1} ms/iter",
        1e3 * train_only / cfg.outer_iters as f64,
        1e3 * (wall - train_only) / cfg.outer_iters as f64,
    );
    println!("sim totals: {:.2} MB comm, {} msgs", out.comm_bytes as f64 / 1e6, out.comm_msgs);

    // machine-readable report for the perf trajectory (BENCH_*.json);
    // wall_ns_per_iter is the eval-off training path, sim_ns_per_iter
    // the SimNet charge for the same run — the pair lets the trajectory
    // track real executor time next to modeled network time
    let iters = cfg.outer_iters as f64;
    let report = json::obj(vec![
        ("schema", json::s("sodda-perf-v1")),
        ("engine", json::s(trainer.engine().name())),
        ("executor", json::s(trainer.executor().to_string())),
        ("algo", json::s(cfg.algorithm.to_string())),
        ("n", json::num(cfg.data.n() as f64)),
        ("m", json::num(cfg.data.m() as f64)),
        ("p", json::num(cfg.p as f64)),
        ("q", json::num(cfg.q as f64)),
        ("inner_steps", json::num(cfg.inner_steps as f64)),
        ("outer_iters", json::num(iters)),
        (
            "phases",
            json::obj(vec![
                ("total_ms_per_iter", json::num(1e3 * wall / iters)),
                ("train_ms_per_iter", json::num(1e3 * train_only / iters)),
                ("eval_ms_per_iter", json::num(1e3 * (wall - train_only) / iters)),
            ]),
        ),
        ("wall_ns_per_iter", json::num(1e9 * train_only / iters)),
        ("sim_ns_per_iter", json::num(1e9 * trainer.sim_seconds() / iters)),
        ("comm_mb", json::num(out.comm_bytes as f64 / 1e6)),
        ("comm_msgs", json::num(out.comm_msgs as f64)),
    ]);
    std::fs::create_dir_all(&o.out_dir)?;
    let json_path = o.out_dir.join(args.str_or("json", "perf.json"));
    std::fs::write(&json_path, report.to_string_pretty())?;
    println!("wrote {}", json_path.display());
    Ok(())
}

/// CI regression gate: compare the bench JSON reports under `--dir`
/// against the checked-in baseline (README §Benchmarks). Exits non-zero
/// when a gated median regresses past the allowed ratio.
fn cmd_bench_gate(args: &Args) -> Result<()> {
    use sodda::util::bench;

    let baseline_path = args.str_or("baseline", "benches/baseline.json");
    let dir = std::path::PathBuf::from(args.str_or("dir", "target/bench"));
    let baseline = json::Value::parse(
        &std::fs::read_to_string(&baseline_path)
            .with_context(|| format!("reading baseline {baseline_path}"))?,
    )
    .with_context(|| format!("parsing {baseline_path}"))?;
    let max_ratio = match args.get("max-ratio") {
        Some(v) => v.parse::<f64>().map_err(|e| anyhow::anyhow!("--max-ratio {v:?}: {e}"))?,
        None => baseline.opt("max_ratio").map(|v| v.as_f64()).transpose()?.unwrap_or(1.5),
    };
    let mut reports = Vec::new();
    for entry in
        std::fs::read_dir(&dir).with_context(|| format!("reading bench dir {}", dir.display()))?
    {
        let path = entry?.path();
        if path.extension().is_some_and(|e| e == "json") {
            let text = std::fs::read_to_string(&path)?;
            reports.push(
                json::Value::parse(&text)
                    .with_context(|| format!("parsing {}", path.display()))?,
            );
        }
    }
    anyhow::ensure!(
        !reports.is_empty(),
        "no bench JSON under {} — run the bench targets first (BENCH_QUICK=1 cargo bench)",
        dir.display()
    );
    println!(
        "bench-gate: {} report file(s) vs {baseline_path} (max ratio {max_ratio})",
        reports.len()
    );
    let problems = bench::regressions(&baseline, &reports, max_ratio)?;
    if problems.is_empty() {
        println!("bench-gate: OK");
        Ok(())
    } else {
        for p in &problems {
            eprintln!("REGRESSION: {p}");
        }
        bail!("{} benchmark regression(s)", problems.len())
    }
}
