//! # SODDA — StOchastic Doubly Distributed Algorithm
//!
//! Production-grade reproduction of *"A Stochastic Large-scale Machine
//! Learning Algorithm for Distributed Features and Observations"*
//! (Fang & Klabjan, 2018).
//!
//! ## The session API
//!
//! Training runs through a reusable, observable [`Trainer`] session.
//! Configs come from a validating builder; a session stages the
//! expensive state (dataset, `P×Q` partition grid, compute engine,
//! worker cluster) exactly once and then runs any number of runs
//! against it — sweeps `reconfigure` between runs instead of re-staging:
//!
//! ```no_run
//! use std::ops::ControlFlow;
//! use sodda::{ExperimentConfig, Trainer};
//!
//! fn main() -> anyhow::Result<()> {
//!     let cfg = ExperimentConfig::builder()
//!         .name("quickstart")
//!         .dense(5000, 360) // §5.1 synthetic SVM data
//!         .grid(5, 3)       // the paper's P×Q partitioning
//!         .outer_iters(25)
//!         .build()?;        // validated: shape, fractions, schedule
//!
//!     let mut trainer = Trainer::new(cfg)?;
//!     let outcome = trainer.run_with_observer(|rec| {
//!         println!("iter {:3}  F = {:.4}", rec.iter, rec.loss);
//!         if rec.loss < 0.05 { ControlFlow::Break(()) } else { ControlFlow::Continue(()) }
//!     })?;
//!     println!("final F = {:.4}", outcome.history.final_loss().unwrap());
//!
//!     // same staged session, next run: warm-started RADiSA-avg
//!     let variant = trainer
//!         .config()
//!         .to_builder()
//!         .name("ravg-warm")
//!         .algorithm(sodda::config::AlgorithmKind::RadisaAvg)
//!         .build()?;
//!     trainer.reconfigure(variant)?;
//!     trainer.warm_start(&outcome.w)?;
//!     let chained = trainer.run()?;
//!     println!("chained F = {:.4}", chained.history.final_loss().unwrap());
//!     Ok(())
//! }
//! ```
//!
//! Observers (`FnMut(&IterRecord) -> ControlFlow<()>`) make streaming
//! loss curves, early stopping and deadline budgets first-class — see
//! [`train::observers`]. [`Trainer::step`] drives a run one outer
//! iteration at a time for custom loops.
//!
//! ## The stack
//!
//! The crate is the **Layer-3 coordinator** of a three-layer stack:
//!
//! * **L3 (this crate)** — the doubly distributed training runtime:
//!   a leader and `P×Q` workers exchanging messages over a simulated
//!   cluster ([`cluster`]), the [`Trainer`] session driving the SODDA /
//!   RADiSA / RADiSA-avg outer loops ([`train`], [`coordinator`]), data
//!   partitioning ([`data`]), and metrics. The native hot path is the
//!   batched kernel layer ([`engine::kernels`]): storage format
//!   resolved once per call, monomorphized dense/CSR loops, fused
//!   margin+derivative and one-traversal SVRG steps — benchmarked by
//!   the `harness = false` bench targets (`BENCH_QUICK`/`BENCH_OUT`
//!   knobs, JSON reports gated in CI by `repro bench-gate`; see
//!   README §Benchmarks).
//! * **L2 (python/compile/model.py, build-time)** — JAX compute graphs
//!   (stochastic full-gradient estimate, SVRG inner loop, loss eval),
//!   AOT-lowered to HLO text under `artifacts/`.
//! * **L1 (python/compile/kernels/, build-time)** — Pallas row-tile
//!   gradient kernels called from L2.
//!
//! With the `xla` cargo feature (default **off**), the [`runtime`]
//! module loads the HLO artifacts through the PJRT CPU client (`xla`
//! crate); python never runs on the training path. The pure-rust
//! [`engine::NativeEngine`] implements the identical math, is always
//! available, and is cross-checked against the XLA path in the
//! integration tests.

pub mod util;

pub mod cluster;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod engine;
pub mod harness;
pub mod loss;
pub mod metrics;
#[cfg(feature = "xla")]
pub mod runtime;
pub mod train;

pub use config::{ExperimentConfig, ExperimentConfigBuilder, StalenessPolicy};
pub use train::{FaultEvent, FaultPlan, RunState, TrainOutcome, Trainer};
