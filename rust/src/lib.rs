//! # SODDA — StOchastic Doubly Distributed Algorithm
//!
//! Production-grade reproduction of *"A Stochastic Large-scale Machine
//! Learning Algorithm for Distributed Features and Observations"*
//! (Fang & Klabjan, 2018).
//!
//! The crate is the **Layer-3 coordinator** of a three-layer stack:
//!
//! * **L3 (this crate)** — the doubly distributed training runtime:
//!   a leader and `P×Q` workers exchanging messages over a simulated
//!   cluster ([`cluster`]), the SODDA / RADiSA / RADiSA-avg outer loops
//!   ([`coordinator`]), data partitioning ([`data`]), and metrics.
//! * **L2 (python/compile/model.py, build-time)** — JAX compute graphs
//!   (stochastic full-gradient estimate, SVRG inner loop, loss eval),
//!   AOT-lowered to HLO text under `artifacts/`.
//! * **L1 (python/compile/kernels/, build-time)** — Pallas row-tile
//!   gradient kernels called from L2.
//!
//! At runtime the [`runtime`] module loads the HLO artifacts through the
//! PJRT CPU client (`xla` crate); python never runs on the training path.
//! A pure-rust [`engine::NativeEngine`] implements the identical math and
//! is cross-checked against the XLA path in the integration tests.

pub mod util;

pub mod config;
pub mod data;
pub mod loss;
pub mod engine;
pub mod runtime;
pub mod cluster;
pub mod coordinator;
pub mod harness;
pub mod metrics;

pub use config::ExperimentConfig;
