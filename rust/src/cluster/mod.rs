//! Simulated doubly distributed cluster: one leader (the caller) and
//! `P×Q` persistent worker threads, message-passing only.
//!
//! Each worker owns its shard `x^{p,q}` outright (the leader never
//! touches block data after launch — exactly the paper's Spark layout
//! where partitions live on executors) plus a shared [`ComputeEngine`].
//! The leader orchestrates the three phases of Algorithm 1 through typed
//! commands and collects replies over a single mpsc channel; the
//! [`simnet::SimNet`] cost model charges each phase (see DESIGN.md).

pub mod simnet;

pub use simnet::{CostModel, SimNet};

use std::ops::Range;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;

use crate::data::{Block, Grid, Layout};
use crate::engine::{BlockKey, ComputeEngine};
use crate::loss::Loss;

/// Commands the leader sends to a worker.
enum Cmd {
    /// z_part = X[rows, :] · w  (w pre-masked by B^t, full block width)
    PartialZ { w: Arc<Vec<f32>>, rows: Arc<Vec<u32>> },
    /// u = f'(X[rows, :]·w, y[rows]) — fused margin + loss derivative
    /// (batched `partial_u` engine entry point); only dispatched on
    /// Q = 1 grids, where the block holds the complete margin
    PartialU { w: Arc<Vec<f32>>, rows: Arc<Vec<u32>> },
    /// Σ_rows f(X[rows, :]·w, y[rows]) — fused objective term
    /// (batched `block_loss` engine entry point); Q = 1 grids only
    BlockLoss { w: Arc<Vec<f32>>, rows: Arc<Vec<u32>> },
    /// g = Σ_rows u·x_row over the full block width
    GradSlice { u: Arc<Vec<f32>>, rows: Arc<Vec<u32>> },
    /// L SVRG steps on the sub-block `cols` (block-local range); `avg`
    /// selects RADiSA-avg's suffix-averaged combiner
    Svrg { cols: Range<usize>, w0: Vec<f32>, wt: Vec<f32>, mu: Vec<f32>, idx: Vec<u32>, gamma: f32, avg: bool },
    Shutdown,
}

/// Worker replies (tagged with the worker's linear id by the channel).
enum Reply {
    Z(Vec<f32>),
    U(Vec<f32>),
    Loss(f64),
    Grad(Vec<f32>),
    W(Vec<f32>),
}

struct Worker {
    p: usize,
    q: usize,
    block: Block,
    engine: Arc<dyn ComputeEngine>,
    loss: Loss,
}

impl Worker {
    fn run(self, rx: Receiver<Cmd>, tx: Sender<(usize, Reply)>, id: usize) {
        let key = BlockKey { p: self.p, q: self.q };
        let m = self.block.x.cols();
        while let Ok(cmd) = rx.recv() {
            let reply = match cmd {
                Cmd::PartialZ { w, rows } => {
                    Reply::Z(self.engine.partial_z(key, &self.block.x, 0..m, &w, &rows))
                }
                Cmd::PartialU { w, rows } => Reply::U(self.engine.partial_u(
                    key,
                    self.loss,
                    &self.block.x,
                    0..m,
                    &w,
                    &rows,
                    &self.block.y,
                )),
                Cmd::BlockLoss { w, rows } => Reply::Loss(self.engine.block_loss(
                    key,
                    self.loss,
                    &self.block.x,
                    0..m,
                    &w,
                    &rows,
                    &self.block.y,
                )),
                Cmd::GradSlice { u, rows } => {
                    Reply::Grad(self.engine.grad_slice(key, &self.block.x, 0..m, &rows, &u))
                }
                Cmd::Svrg { cols, w0, wt, mu, idx, gamma, avg } => {
                    let e = &self.engine;
                    let (x, y) = (&self.block.x, &self.block.y);
                    Reply::W(if avg {
                        e.svrg_inner_avg(key, self.loss, x, y, cols, &w0, &wt, &mu, &idx, gamma)
                    } else {
                        e.svrg_inner(key, self.loss, x, y, cols, &w0, &wt, &mu, &idx, gamma)
                    })
                }
                Cmd::Shutdown => break,
            };
            if tx.send((id, reply)).is_err() {
                break;
            }
        }
    }
}

/// One SVRG assignment for the inner-loop phase.
pub struct SvrgTask {
    pub p: usize,
    pub q: usize,
    /// block-local column range — `Layout::sub_cols(q, k)` for every
    /// algorithm (widths are per-block ragged); RADiSA-avg differs only
    /// in the `avg` combiner below, not in the columns it owns
    pub cols: Range<usize>,
    pub w0: Vec<f32>,
    pub wt: Vec<f32>,
    pub mu: Vec<f32>,
    pub idx: Vec<u32>,
    pub gamma: f32,
    /// use the suffix-averaged combiner (RADiSA-avg)
    pub avg: bool,
}

/// Handle to the launched cluster (leader side).
pub struct Cluster {
    pub p: usize,
    pub q: usize,
    /// the grid's partition geometry (ragged boundary vectors) — the
    /// leader's only source of block dims after blocks move to workers
    pub layout: Layout,
    /// labels per observation partition (leader copy, for dloss/loss)
    pub y: Vec<Vec<f32>>,
    /// density (nnz fraction) per worker `[p][q]`, for the cost model
    pub density: Vec<f64>,
    cmd_txs: Vec<Sender<Cmd>>,
    reply_rx: Receiver<(usize, Reply)>,
    handles: Vec<JoinHandle<()>>,
}

impl Cluster {
    /// Move the grid's blocks into worker threads.
    pub fn launch(grid: Grid, engine: Arc<dyn ComputeEngine>, loss: Loss) -> Cluster {
        let layout = grid.layout.clone();
        let (p, q) = (layout.p, layout.q);
        let y: Vec<Vec<f32>> = (0..p).map(|pi| grid.block(pi, 0).y.clone()).collect();
        let density: Vec<f64> = grid
            .blocks()
            .map(|b| b.x.nnz() as f64 / (b.x.rows() as f64 * b.x.cols() as f64).max(1.0))
            .collect();

        let (reply_tx, reply_rx) = channel();
        let mut cmd_txs = Vec::with_capacity(p * q);
        let mut handles = Vec::with_capacity(p * q);
        // Grid stores blocks row-major [p][q]; consume it in that order.
        let mut blocks: Vec<Block> = Vec::with_capacity(p * q);
        for pi in 0..p {
            for qi in 0..q {
                blocks.push(grid.block(pi, qi).clone());
            }
        }
        for (id, block) in blocks.into_iter().enumerate() {
            let (tx, rx) = channel();
            cmd_txs.push(tx);
            let worker = Worker { p: block.p, q: block.q, block, engine: Arc::clone(&engine), loss };
            let reply = reply_tx.clone();
            handles.push(
                std::thread::Builder::new()
                    .name(format!("worker-{id}"))
                    .spawn(move || worker.run(rx, reply, id))
                    .expect("spawn worker"),
            );
        }
        Cluster { p, q, layout, y, density, cmd_txs, reply_rx, handles }
    }

    #[inline]
    fn wid(&self, p: usize, q: usize) -> usize {
        p * self.q + q
    }

    pub fn density_at(&self, p: usize, q: usize) -> f64 {
        self.density[self.wid(p, q)]
    }

    /// Phase 1 of the µ^t estimate: partial margins, reduced over feature
    /// partitions. `w_blocks[q]` is the (masked) parameter slice of block
    /// q; `rows[p]` the sampled local row ids of partition p. Returns
    /// `z[p][k] = x_{rows[p][k]}^{B} · w_B`.
    pub fn partial_z(&self, w_blocks: &[Arc<Vec<f32>>], rows: &[Arc<Vec<u32>>]) -> Vec<Vec<f32>> {
        for pi in 0..self.p {
            for qi in 0..self.q {
                self.cmd_txs[self.wid(pi, qi)]
                    .send(Cmd::PartialZ { w: Arc::clone(&w_blocks[qi]), rows: Arc::clone(&rows[pi]) })
                    .expect("worker alive");
            }
        }
        // buffer replies by worker id, then reduce in a fixed order —
        // f32 addition is non-associative and runs must be reproducible
        let mut parts: Vec<Option<Vec<f32>>> = (0..self.p * self.q).map(|_| None).collect();
        for _ in 0..self.p * self.q {
            let (id, reply) = self.reply_rx.recv().expect("worker alive");
            let Reply::Z(part) = reply else { panic!("expected Z reply") };
            parts[id] = Some(part);
        }
        let mut z: Vec<Vec<f32>> = rows.iter().map(|r| vec![0.0f32; r.len()]).collect();
        for (id, part) in parts.into_iter().enumerate() {
            let pi = id / self.q;
            for (acc, v) in z[pi].iter_mut().zip(part.expect("reply")) {
                *acc += v;
            }
        }
        z
    }

    /// Phase-1 derivative `u[p][k] = f'(z_k, y_k)`. On single-feature-
    /// block grids (`Q == 1`) each block already holds the complete
    /// margin, so workers compute `u` locally through the engines' fused
    /// batched `partial_u` entry point — no leader-side z reduce + dloss
    /// round. On `Q > 1` grids the margins are reduced across feature
    /// blocks here and `leader` applies the derivative; both paths
    /// produce bit-identical numbers.
    pub fn partial_u(
        &self,
        w_blocks: &[Arc<Vec<f32>>],
        rows: &[Arc<Vec<u32>>],
        leader: &dyn ComputeEngine,
        loss: Loss,
    ) -> Vec<Vec<f32>> {
        if self.q > 1 {
            let z = self.partial_z(w_blocks, rows);
            return (0..self.p)
                .map(|pi| {
                    let y_rows: Vec<f32> =
                        rows[pi].iter().map(|&r| self.y[pi][r as usize]).collect();
                    leader.dloss_u(loss, &z[pi], &y_rows)
                })
                .collect();
        }
        for pi in 0..self.p {
            self.cmd_txs[self.wid(pi, 0)]
                .send(Cmd::PartialU { w: Arc::clone(&w_blocks[0]), rows: Arc::clone(&rows[pi]) })
                .expect("worker alive");
        }
        let mut parts: Vec<Option<Vec<f32>>> = (0..self.p).map(|_| None).collect();
        for _ in 0..self.p {
            let (id, reply) = self.reply_rx.recv().expect("worker alive");
            let Reply::U(u) = reply else { panic!("expected U reply") };
            parts[id] = Some(u); // worker id == p index when q == 1
        }
        parts.into_iter().map(|u| u.expect("reply")).collect()
    }

    /// Distributed objective term `Σ_k f(z_k, y_k)` over the given rows.
    /// `Q == 1` grids use the workers' fused `block_loss` entry point;
    /// `Q > 1` grids reduce z here and `leader` sums the loss values.
    /// Either way the reduce runs in worker order, so the f64 total is
    /// deterministic.
    pub fn block_loss(
        &self,
        w_blocks: &[Arc<Vec<f32>>],
        rows: &[Arc<Vec<u32>>],
        leader: &dyn ComputeEngine,
        loss: Loss,
    ) -> f64 {
        if self.q > 1 {
            let z = self.partial_z(w_blocks, rows);
            return (0..self.p)
                .map(|pi| {
                    let y_rows: Vec<f32> =
                        rows[pi].iter().map(|&r| self.y[pi][r as usize]).collect();
                    leader.loss_from_z(loss, &z[pi], &y_rows)
                })
                .sum();
        }
        for pi in 0..self.p {
            self.cmd_txs[self.wid(pi, 0)]
                .send(Cmd::BlockLoss { w: Arc::clone(&w_blocks[0]), rows: Arc::clone(&rows[pi]) })
                .expect("worker alive");
        }
        let mut parts = vec![0.0f64; self.p];
        for _ in 0..self.p {
            let (id, reply) = self.reply_rx.recv().expect("worker alive");
            let Reply::Loss(v) = reply else { panic!("expected Loss reply") };
            parts[id] = v;
        }
        parts.iter().sum()
    }

    /// Phase 2: gradient slices. `u[p]` aligned with `rows[p]`. Returns
    /// the global gradient-sum vector (length `m_total`), summed over
    /// observation partitions per feature block.
    pub fn grad(&self, u: &[Arc<Vec<f32>>], rows: &[Arc<Vec<u32>>]) -> Vec<f32> {
        for pi in 0..self.p {
            for qi in 0..self.q {
                self.cmd_txs[self.wid(pi, qi)]
                    .send(Cmd::GradSlice { u: Arc::clone(&u[pi]), rows: Arc::clone(&rows[pi]) })
                    .expect("worker alive");
            }
        }
        let mut parts: Vec<Option<Vec<f32>>> = (0..self.p * self.q).map(|_| None).collect();
        for _ in 0..self.p * self.q {
            let (id, reply) = self.reply_rx.recv().expect("worker alive");
            let Reply::Grad(slice) = reply else { panic!("expected Grad reply") };
            parts[id] = Some(slice);
        }
        let mut g = vec![0.0f32; self.layout.m_total];
        for (id, slice) in parts.into_iter().enumerate() {
            let qi = id % self.q;
            let base = self.layout.block_cols(qi).start;
            for (k, v) in slice.expect("reply").into_iter().enumerate() {
                g[base + k] += v;
            }
        }
        g
    }

    /// Phase 3: the parallel inner loops. Returns `(task_index, w_L)` in
    /// completion order.
    pub fn svrg(&self, tasks: Vec<SvrgTask>) -> Vec<(usize, Vec<f32>)> {
        let n = tasks.len();
        let mut id_to_task: Vec<usize> = vec![usize::MAX; self.p * self.q];
        for (ti, t) in tasks.into_iter().enumerate() {
            let wid = self.wid(t.p, t.q);
            assert_eq!(id_to_task[wid], usize::MAX, "one task per worker per phase");
            id_to_task[wid] = ti;
            self.cmd_txs[wid]
                .send(Cmd::Svrg {
                    cols: t.cols,
                    w0: t.w0,
                    wt: t.wt,
                    mu: t.mu,
                    idx: t.idx,
                    gamma: t.gamma,
                    avg: t.avg,
                })
                .expect("worker alive");
        }
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            let (id, reply) = self.reply_rx.recv().expect("worker alive");
            let Reply::W(w) = reply else { panic!("expected W reply") };
            out.push((id_to_task[id], w));
        }
        out
    }
}

impl Drop for Cluster {
    fn drop(&mut self) {
        for tx in &self.cmd_txs {
            let _ = tx.send(Cmd::Shutdown);
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::engine::NativeEngine;
    use crate::util::testing::assert_close_slice;

    fn cluster(n: usize, m: usize, p: usize, q: usize, seed: u64) -> (Cluster, crate::data::Dataset) {
        let ds = synth::dense_zhang(n, m, seed);
        let grid = Grid::partition(&ds, p, q).unwrap();
        let c = Cluster::launch(grid, Arc::new(NativeEngine), Loss::Hinge);
        (c, ds)
    }

    #[test]
    fn partial_z_matches_serial_matvec() {
        let (c, ds) = cluster(30, 12, 3, 2, 1);
        let w: Vec<f32> = (0..12).map(|i| 0.1 * i as f32 - 0.5).collect();
        let w_blocks: Vec<Arc<Vec<f32>>> =
            (0..2).map(|qi| Arc::new(w[qi * 6..(qi + 1) * 6].to_vec())).collect();
        let rows: Vec<Arc<Vec<u32>>> = (0..3).map(|_| Arc::new((0..10u32).collect())).collect();
        let z = c.partial_z(&w_blocks, &rows);
        for pi in 0..3 {
            for k in 0..10 {
                let gr = pi * 10 + k;
                let want = ds.x.row_dot_range(gr, 0, 12, &w);
                crate::assert_close!(z[pi][k], want, 1e-4, 1e-4);
            }
        }
    }

    #[test]
    fn grad_matches_serial_rmatvec() {
        let (c, ds) = cluster(20, 8, 2, 2, 2);
        let rows: Vec<Arc<Vec<u32>>> = (0..2).map(|_| Arc::new((0..10u32).collect())).collect();
        let u: Vec<Arc<Vec<f32>>> =
            (0..2).map(|pi| Arc::new((0..10).map(|k| (pi * 10 + k) as f32 * 0.1).collect())).collect();
        let g = c.grad(&u, &rows);
        let mut want = vec![0.0f32; 8];
        for gr in 0..20 {
            let uv = gr as f32 * 0.1;
            let mut row = vec![0.0f32; 8];
            ds.x.copy_row_range(gr, 0, 8, &mut row);
            for cidx in 0..8 {
                want[cidx] += uv * row[cidx];
            }
        }
        assert_close_slice(&g, &want, 1e-3, 1e-3, "grad");
    }

    #[test]
    fn svrg_tasks_route_to_correct_workers() {
        let (c, _ds) = cluster(20, 8, 2, 2, 3);
        // zero gamma => w_L == w0, so routing shows through the payloads
        let tasks = vec![
            SvrgTask { p: 0, q: 0, cols: 0..2, w0: vec![1.0, 2.0], wt: vec![1.0, 2.0], mu: vec![0.0; 2], idx: vec![0; 4], gamma: 0.0, avg: false },
            SvrgTask { p: 1, q: 1, cols: 2..4, w0: vec![3.0, 4.0], wt: vec![3.0, 4.0], mu: vec![0.0; 2], idx: vec![0; 4], gamma: 0.0, avg: true },
        ];
        let mut out = c.svrg(tasks);
        out.sort_by_key(|(ti, _)| *ti);
        assert_eq!(out[0].1, vec![1.0, 2.0]);
        assert_eq!(out[1].1, vec![3.0, 4.0]);
    }

    #[test]
    fn fused_partial_u_matches_z_then_dloss_on_q1() {
        let (c, _ds) = cluster(30, 12, 3, 1, 6);
        let w: Vec<f32> = (0..12).map(|i| 0.05 * i as f32 - 0.2).collect();
        let w_blocks = vec![Arc::new(w)];
        let rows: Vec<Arc<Vec<u32>>> = (0..3).map(|_| Arc::new((0..10u32).collect())).collect();
        let u = c.partial_u(&w_blocks, &rows, &NativeEngine, Loss::Hinge);
        let z = c.partial_z(&w_blocks, &rows);
        for pi in 0..3 {
            for k in 0..10 {
                let want = Loss::Hinge.dloss(z[pi][k], c.y[pi][k]);
                assert_eq!(u[pi][k], want, "p={pi} k={k}");
            }
        }
    }

    #[test]
    fn fused_block_loss_matches_serial_objective_on_q1() {
        let (c, ds) = cluster(30, 12, 3, 1, 7);
        let w: Vec<f32> = (0..12).map(|i| (i as f32 * 0.4).sin() * 0.3).collect();
        let w_blocks = vec![Arc::new(w.clone())];
        let rows: Vec<Arc<Vec<u32>>> = (0..3).map(|_| Arc::new((0..10u32).collect())).collect();
        let total = c.block_loss(&w_blocks, &rows, &NativeEngine, Loss::Hinge);
        crate::assert_close!(total / 30.0, ds.objective(&w, Loss::Hinge), 1e-4, 1e-5);
    }

    #[test]
    fn partial_u_reduce_path_matches_manual_composition_on_q2() {
        // Q > 1: partial_u must fall back to z-reduce + leader dloss,
        // bit-identical to composing the phases by hand
        let (c, _ds) = cluster(20, 8, 2, 2, 8);
        let w: Vec<f32> = (0..8).map(|i| (i as f32 * 0.3).cos() * 0.4).collect();
        let w_blocks: Vec<Arc<Vec<f32>>> =
            (0..2).map(|qi| Arc::new(w[qi * 4..(qi + 1) * 4].to_vec())).collect();
        let rows: Vec<Arc<Vec<u32>>> = (0..2).map(|_| Arc::new(vec![0u32, 3, 7])).collect();
        let u = c.partial_u(&w_blocks, &rows, &NativeEngine, Loss::Hinge);
        let z = c.partial_z(&w_blocks, &rows);
        for pi in 0..2 {
            let y_rows: Vec<f32> = rows[pi].iter().map(|&r| c.y[pi][r as usize]).collect();
            let want = NativeEngine.dloss_u(Loss::Hinge, &z[pi], &y_rows);
            assert_eq!(u[pi], want, "p={pi}");
        }
        let total = c.block_loss(&w_blocks, &rows, &NativeEngine, Loss::Hinge);
        let want: f64 = (0..2)
            .map(|pi| {
                let y_rows: Vec<f32> = rows[pi].iter().map(|&r| c.y[pi][r as usize]).collect();
                NativeEngine.loss_from_z(Loss::Hinge, &z[pi], &y_rows)
            })
            .sum();
        assert_eq!(total, want);
    }

    #[test]
    fn ragged_partial_z_and_grad_match_serial() {
        // 21 rows over P=2 (10/11), 9 cols over Q=2 (4/5): exercises the
        // boundary-offset assembly paths with genuinely uneven blocks
        let (c, ds) = cluster(21, 9, 2, 2, 9);
        let w: Vec<f32> = (0..9).map(|i| 0.1 * i as f32 - 0.3).collect();
        let w_blocks: Vec<Arc<Vec<f32>>> =
            (0..2).map(|qi| Arc::new(w[c.layout.block_cols(qi)].to_vec())).collect();
        let rows: Vec<Arc<Vec<u32>>> = (0..2)
            .map(|pi| Arc::new((0..c.layout.rows_in(pi) as u32).collect()))
            .collect();
        let z = c.partial_z(&w_blocks, &rows);
        for pi in 0..2 {
            assert_eq!(z[pi].len(), c.layout.rows_in(pi));
            for k in 0..c.layout.rows_in(pi) {
                let gr = c.layout.block_rows(pi).start + k;
                let want = ds.x.row_dot_range(gr, 0, 9, &w);
                crate::assert_close!(z[pi][k], want, 1e-4, 1e-4);
            }
        }
        let u: Vec<Arc<Vec<f32>>> = (0..2)
            .map(|pi| {
                let base = c.layout.block_rows(pi).start;
                Arc::new((0..c.layout.rows_in(pi)).map(|k| (base + k) as f32 * 0.1).collect())
            })
            .collect();
        let g = c.grad(&u, &rows);
        let mut want = vec![0.0f32; 9];
        for gr in 0..21 {
            let uv = gr as f32 * 0.1;
            let mut row = vec![0.0f32; 9];
            ds.x.copy_row_range(gr, 0, 9, &mut row);
            for (cidx, &xv) in row.iter().enumerate() {
                want[cidx] += uv * xv;
            }
        }
        assert_close_slice(&g, &want, 1e-3, 1e-3, "ragged grad");
    }

    #[test]
    fn density_is_one_for_dense() {
        let (c, _) = cluster(10, 4, 1, 2, 4);
        crate::assert_close!(c.density_at(0, 0), 1.0, 1e-9, 1e-9);
    }

    #[test]
    fn shutdown_is_clean() {
        let (c, _) = cluster(10, 4, 2, 2, 5);
        drop(c); // Drop joins all workers; hang = test timeout
    }
}
