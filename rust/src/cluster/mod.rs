//! Doubly distributed cluster: one leader (the caller) and `P×Q`
//! workers, message-passing only.
//!
//! Each worker owns its shard `x^{p,q}` outright (the leader never
//! touches block data after launch — exactly the paper's Spark layout
//! where partitions live on executors) plus a shared [`ComputeEngine`].
//! The leader orchestrates the three phases of Algorithm 1 through typed
//! commands and collects tagged replies; the [`simnet::SimNet`] cost
//! model charges each phase (parameterized by the validated
//! [`crate::config::ClusterProfile`] — see the README's "Fault tolerance
//! & heterogeneous clusters" section).
//!
//! *How* the workers execute is pluggable: the [`transport`] submodule
//! provides the sequential in-process oracle and the persistent
//! thread-per-worker runtime behind one [`transport::Transport`] trait,
//! selected at [`Cluster::launch_with`] (or via `SODDA_EXECUTOR` /
//! [`ExecutorKind::resolve`] for [`Cluster::launch`]). The two modes are
//! bit-for-bit identical — see the determinism contract in the
//! `transport` module docs and the README's "Execution modes" section.
//!
//! ## Fault recovery
//!
//! Workers can die mid-phase — injected deterministically through
//! [`Cluster::inject_fault`] (the test/benchmark substrate for the
//! trainer's `FaultPlan`) or for real (a panicking worker thread).
//! Either way the transport converts the missing reply into a
//! synthetic [`transport::Reply::Fault`] instead of hanging the barrier,
//! and the leader:
//!
//! 1. rebuilds the worker from the retained shard store ([`Cluster`]
//!    keeps the launch [`Grid`] alive — the in-memory analogue of
//!    re-reading a durable shard),
//! 2. respawns the slot through [`transport::Transport::respawn`], and
//! 3. replays the in-flight command (every phase retains enough of its
//!    payload to resend — the SVRG phase keeps per-worker `Arc` clones
//!    of its task snapshots).
//!
//! Recovery consumes no RNG draws and re-executes a command that never
//! partially ran (kills are FIFO-ordered ahead of the phase command on
//! both transports), and the leader's reduces stage replies by worker
//! id — so a recovered run is **bit-for-bit identical** to the
//! fault-free run (`tests/faults.rs` pins this on both executors). A
//! worker death with no fault armed is a real bug and panics with the
//! dead worker's id, replacing the former silent hang of the threaded
//! recv. Recovery traffic is *not* charged to the [`SimNet`] cost
//! model — the paper's time axis excludes failure handling.
//!
//! ## Escalation to permanent loss
//!
//! Respawn is not guaranteed to succeed: the leader's
//! [`crate::config::RecoveryPolicy`] gives each fault `max_retries`
//! respawn attempts (with linear backoff between them) before giving
//! up, and a fault armed through [`Cluster::inject_permanent_fault`]
//! (the `!perm` fault-plan syntax) skips the attempts entirely. Either
//! way the in-flight phase stops and returns a typed
//! [`PermanentLoss`] carrying the dead worker's id — every phase
//! method is `Result`-returning for exactly this. A permanent loss is
//! *not* a dead-end error: the `Trainer` catches it, recomputes a
//! shrunk layout, restages the surviving shards onto a fresh cluster
//! (charging SimNet the shuffle bytes) and re-runs the interrupted
//! iteration — see `train/mod.rs` and the README's elastic
//! re-sharding section.
//!
//! ## Bounded-staleness quorums
//!
//! The µ and gradient phases also come in quorum flavors
//! ([`Cluster::partial_u_quorum_into`], [`Cluster::grad_quorum_into`])
//! for the trainer's bounded-staleness mode
//! ([`crate::config::StalenessPolicy`]): membership is decided by the
//! *trainer* on modeled per-worker phase times and passed down as a
//! [`QuorumCtx`] mask, replies outside the mask are parked in the
//! [`LateSet`] with per-block iteration tags, and parked replies drain
//! into the matching phase of a later iteration at an age-discounted
//! weight (or are dropped past `max_staleness_iters`). Collection still
//! physically receives every reply, so buffer recycling and the whole
//! fault-recovery seam above — including [`PermanentLoss`] escalation —
//! behave exactly as in barrier mode, and a full-true mask is
//! bit-identical to the barrier phases (README "Bounded-staleness
//! aggregation").
//!
//! ## Steady-state memory
//!
//! After warm-up the message protocol allocates nothing per phase:
//!
//! * every command that produces a vector reply carries a **recycled
//!   buffer** popped from the leader-side pool; the worker fills it via
//!   the engine's `_into` entry point and ships it back, and the leader
//!   returns it to the pool once the reduce has consumed it — buffers
//!   endlessly circulate leader → worker → leader;
//! * each worker holds **persistent scratch** (the margin buffer for
//!   fused objective evaluations, the working iterate of the averaged
//!   SVRG combiner) that lives as long as the thread;
//! * the leader keeps its own reduce workspaces (reply staging slots,
//!   the `z` accumulator and `y`-gather buffers of the `Q > 1` paths,
//!   the SVRG task-routing table) in a [`RefCell`], so every phase
//!   method stays `&self`.
//!
//! Pooling only recycles allocations — reduce orders are unchanged, so
//! trajectories are bit-for-bit identical to the fresh-allocation path
//! (`tests/alloc_regression.rs` pins both properties).
//!
//! ## Sampled-width phases
//!
//! The µ^t-estimate phases come in two flavors: the frozen full-width
//! commands (`cols: None` — RADiSA, `|B| == M`) and the sampled-width
//! ones ([`Cluster::partial_u_cols_into`], [`Cluster::grad_cols_into`]),
//! whose commands carry sorted block-local id lists of `B^t ∩ block` /
//! `C^t ∩ block` plus **compact** payloads — the `w` slice and the
//! gradient reply are exactly as long as the intersection, so wire
//! bytes and worker FLOPs scale with the sampled widths the SimNet
//! cost model charges (README "Sampled-width execution").

pub mod simnet;
pub mod transport;

pub use simnet::SimNet;

use std::cell::RefCell;
use std::fmt;
use std::ops::Range;
use std::sync::Arc;
use std::time::Duration;

use transport::{Cmd, Reply, Transport, WorkerCore};

use crate::config::{ExecutorKind, RecoveryPolicy};
use crate::data::{Grid, Layout};
use crate::engine::ComputeEngine;
use crate::loss::Loss;
use crate::util::arc_mut;

/// A worker the recovery machinery gave up on: every respawn attempt
/// allowed by the [`RecoveryPolicy`] failed, or the fault was armed
/// permanent ([`Cluster::inject_permanent_fault`]). Carried by every
/// phase method's `Err` — the in-flight phase is abandoned (surviving
/// workers may still hold queued commands; the cluster is meant to be
/// dropped wholesale). Not a dead-end: the `Trainer` catches this,
/// re-shards onto a shrunk grid and re-runs the interrupted iteration.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PermanentLoss {
    /// linear worker id (`p·Q + q`) on the grid that lost the worker
    pub worker: usize,
}

impl fmt::Display for PermanentLoss {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "worker {} permanently lost (recovery exhausted)", self.worker)
    }
}

impl std::error::Error for PermanentLoss {}

/// Per-worker fault arming state (see [`Cluster::inject_fault`] /
/// [`Cluster::inject_permanent_fault`]). A death with `Clear` armed is
/// a genuine bug and panics.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Armed {
    Clear,
    Transient,
    Perm,
}

/// One parked straggler reply, exactly as the worker shipped it.
///
/// The bounded-staleness quorum phases ([`Cluster::partial_u_quorum_into`],
/// [`Cluster::grad_quorum_into`]) physically collect every block reply —
/// preserving buffer recycling and the fault-recovery seam — but replies
/// outside the quorum mask are parked here instead of folded, and drained
/// into the *matching phase* of a later iteration with an age-discounted
/// weight (`LateSet::weight`). Shapes stay valid across iterations
/// because `|D^t ∩ partition p|` is constant (the `d` fraction is fixed)
/// and gradient slices carry their own global column ids.
#[derive(Debug, Clone, PartialEq)]
pub enum LateSlice {
    /// A phase-1 reply: the per-partition z margin part (`Q > 1`) or the
    /// fused u derivative part (`Q == 1`) of observation partition `p`.
    Mu { p: usize, part: Vec<f32> },
    /// A phase-2 gradient slice: `data[k]` belongs to global column
    /// `cols[k]`; `inv_d` is the origin iteration's `1/|D^t|` scale, so
    /// the fold lands directly in µ-units regardless of when it drains.
    Grad { cols: Vec<u32>, data: Vec<f32>, inv_d: f64 },
}

/// A [`LateSlice`] tagged with its origin iteration and worker.
#[derive(Debug, Clone, PartialEq)]
pub struct LateReply {
    /// outer iteration the reply was parked in
    pub iter: usize,
    /// linear worker id (`p·Q + q`) on the grid at park time
    pub worker: usize,
    pub slice: LateSlice,
}

/// The parked-reply store, owned by the trainer (it is run state: the
/// checkpoint serializes it so resume stays trajectory-exact, rollback
/// snapshots it, and a re-shard clears it — parked slices reference the
/// dead grid's shapes). Entries drain in park order, so folding is
/// deterministic on both executors.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct LateSet {
    pub entries: Vec<LateReply>,
}

impl LateSet {
    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn clear(&mut self) {
        self.entries.clear();
    }

    /// Age-discounted fold weight: a reply parked `age` iterations ago
    /// contributes `2^-age` of its raw value (age ≥ 1 by construction —
    /// a parked reply never folds into its own iteration).
    pub fn weight(age: usize) -> f32 {
        0.5f32.powi(age as i32)
    }

    /// Drain parked gradient slices from earlier iterations into the
    /// (already `1/|D|`-scaled) µ vector: each folded entry adds
    /// `weight(age) · inv_d₀ · v` at its recorded global columns, and
    /// entries older than `max_staleness_iters` are dropped instead.
    /// `on_fold(cols, weight)` fires per folded entry (the trainer uses
    /// it to damp per-block step sizes). Returns `(folds, drops)`.
    pub fn fold_grad_into(
        &mut self,
        iter: usize,
        max_staleness_iters: usize,
        mu: &mut [f32],
        mut on_fold: impl FnMut(&[u32], f32),
    ) -> (usize, usize) {
        let (mut folds, mut drops) = (0usize, 0usize);
        let mut i = 0;
        while i < self.entries.len() {
            let due = matches!(self.entries[i].slice, LateSlice::Grad { .. })
                && self.entries[i].iter < iter;
            if !due {
                i += 1;
                continue;
            }
            let e = self.entries.remove(i);
            let age = iter - e.iter;
            let LateSlice::Grad { cols, data, inv_d } = e.slice else { unreachable!() };
            if age > max_staleness_iters {
                drops += 1;
                continue;
            }
            folds += 1;
            let w = Self::weight(age);
            let scale = w * inv_d as f32;
            for (&c, &v) in cols.iter().zip(&data) {
                if let Some(slot) = mu.get_mut(c as usize) {
                    *slot += scale * v;
                }
            }
            on_fold(&cols, w);
        }
        (folds, drops)
    }

    /// Serialize for the checkpoint layer (offline build: in-tree json).
    pub fn to_json_value(&self) -> crate::util::json::Value {
        use crate::util::json::{self, Value};
        Value::Arr(
            self.entries
                .iter()
                .map(|e| {
                    let mut fields = vec![
                        ("iter", json::num(e.iter as f64)),
                        ("worker", json::num(e.worker as f64)),
                    ];
                    match &e.slice {
                        LateSlice::Mu { p, part } => {
                            fields.push(("kind", json::s("mu")));
                            fields.push(("p", json::num(*p as f64)));
                            fields.push((
                                "part",
                                Value::Arr(part.iter().map(|&v| json::num(v as f64)).collect()),
                            ));
                        }
                        LateSlice::Grad { cols, data, inv_d } => {
                            fields.push(("kind", json::s("grad")));
                            fields.push((
                                "cols",
                                Value::Arr(cols.iter().map(|&c| json::num(c as f64)).collect()),
                            ));
                            fields.push((
                                "data",
                                Value::Arr(data.iter().map(|&v| json::num(v as f64)).collect()),
                            ));
                            fields.push(("inv_d", json::num(*inv_d)));
                        }
                    }
                    json::obj(fields)
                })
                .collect(),
        )
    }

    /// Inverse of [`LateSet::to_json_value`] (f32 values round-trip
    /// exactly through the f64 JSON numbers).
    pub fn from_json_value(v: &crate::util::json::Value) -> anyhow::Result<LateSet> {
        let mut set = LateSet::default();
        for e in v.as_arr()? {
            let iter = e.get("iter")?.as_usize()?;
            let worker = e.get("worker")?.as_usize()?;
            let slice = match e.get("kind")?.as_str()? {
                "mu" => LateSlice::Mu {
                    p: e.get("p")?.as_usize()?,
                    part: e
                        .get("part")?
                        .as_arr()?
                        .iter()
                        .map(|x| x.as_f64().map(|f| f as f32))
                        .collect::<anyhow::Result<Vec<f32>>>()?,
                },
                "grad" => LateSlice::Grad {
                    cols: e
                        .get("cols")?
                        .as_arr()?
                        .iter()
                        .map(|x| x.as_usize().map(|c| c as u32))
                        .collect::<anyhow::Result<Vec<u32>>>()?,
                    data: e
                        .get("data")?
                        .as_arr()?
                        .iter()
                        .map(|x| x.as_f64().map(|f| f as f32))
                        .collect::<anyhow::Result<Vec<f32>>>()?,
                    inv_d: e.get("inv_d")?.as_f64()?,
                },
                other => anyhow::bail!("unknown late-reply kind {other:?}"),
            };
            set.entries.push(LateReply { iter, worker, slice });
        }
        Ok(set)
    }
}

/// Per-phase quorum outcome counters, merged by the trainer into its
/// per-iteration `StalenessRecord`.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct QuorumStats {
    /// replies inside the quorum mask (folded now)
    pub quorum: usize,
    /// replies parked into the [`LateSet`]
    pub parked: usize,
    /// previously parked replies folded this phase
    pub folds: usize,
    /// previously parked replies dropped (older than the staleness bound)
    pub drops: usize,
    /// summed age-discount weights of this phase's folds (drives the
    /// trainer's per-block step-size damping)
    pub fold_weight: f64,
}

/// Everything a quorum phase needs beyond the barrier arguments. The
/// mask is decided by the *trainer* on modeled per-worker phase times
/// (profile rates × armed slowdowns), never wall-clock — both executors
/// see the same membership and produce identical trajectories.
pub struct QuorumCtx<'a> {
    /// per-worker membership (`wid = p·Q + q` order, length P·Q): `true`
    /// folds now, `false` parks the reply
    pub mask: &'a [bool],
    /// current outer iteration (tags parked replies)
    pub iter: usize,
    /// parked replies older than this many iterations are dropped
    pub max_staleness_iters: usize,
    /// the current iteration's `1/|D^t|` scale, stamped on parked
    /// gradient slices (unused by the µ phase)
    pub inv_d: f64,
    pub late: &'a mut LateSet,
    pub stats: &'a mut QuorumStats,
}

/// Drain parked phase-1 (µ) replies from earlier iterations, in park
/// order: `fold(p, weight, part)` adds one age-discounted part to the
/// caller's accumulator (z margins on `Q > 1` grids, u derivative parts
/// on `Q == 1` grids). Entries older than the staleness bound are
/// dropped and counted; drained buffers are recycled into `pool`.
fn drain_mu_late(
    ctx: &mut QuorumCtx<'_>,
    pool: &mut Vec<Vec<f32>>,
    mut fold: impl FnMut(usize, f32, &[f32]),
) {
    let mut i = 0;
    while i < ctx.late.entries.len() {
        let due = matches!(ctx.late.entries[i].slice, LateSlice::Mu { .. })
            && ctx.late.entries[i].iter < ctx.iter;
        if !due {
            i += 1;
            continue;
        }
        let e = ctx.late.entries.remove(i);
        let age = ctx.iter - e.iter;
        let LateSlice::Mu { p, part } = e.slice else { unreachable!() };
        if age > ctx.max_staleness_iters {
            ctx.stats.drops += 1;
        } else {
            let w = LateSet::weight(age);
            fold(p, w, &part);
            ctx.stats.folds += 1;
            ctx.stats.fold_weight += w as f64;
        }
        pool.push(part);
    }
}

/// One SVRG assignment for the inner-loop phase.
pub struct SvrgTask {
    pub p: usize,
    pub q: usize,
    /// block-local column range — `Layout::sub_cols(q, k)` for every
    /// algorithm (widths are per-block ragged); RADiSA-avg differs only
    /// in the `avg` combiner below, not in the columns it owns
    pub cols: Range<usize>,
    /// global column range of the same sub-block — the window the worker
    /// slices out of the snapshots below
    pub gcols: Range<usize>,
    /// full-model snapshot of ω^t, shared by every task of the phase
    /// (serves as both w⁰ and the SVRG reference w̃)
    pub w: Arc<Vec<f32>>,
    /// full-model µ^t snapshot, shared by every task of the phase
    pub mu: Arc<Vec<f32>>,
    /// pre-sampled local row per inner step (per-task; the buffer is
    /// recycled through the leader pool — see
    /// [`Cluster::recycled_idx_buf`]; an `Arc` so the leader retains a
    /// replay clone for fault recovery without copying)
    pub idx: Arc<Vec<u32>>,
    pub gamma: f32,
    /// use the suffix-averaged combiner (RADiSA-avg)
    pub avg: bool,
}

/// Everything needed to replay one in-flight SVRG task after a worker
/// death: `Arc` clones of the shared snapshots plus the scalar knobs
/// (retaining these is allocation-free in the steady state).
struct RetainedSvrg {
    cols: Range<usize>,
    gcols: Range<usize>,
    w: Arc<Vec<f32>>,
    mu: Arc<Vec<f32>>,
    idx: Arc<Vec<u32>>,
    gamma: f32,
    avg: bool,
}

/// Leader-side recycled state: the reply-buffer pools plus the reduce
/// workspaces of the `&self` phase methods. Behind a [`RefCell`] — the
/// leader is single-threaded (the [`Transport`] is `Send` but not
/// `Sync`, pinning [`Cluster`] use to one thread at a time) and no
/// phase method re-enters another while holding a borrow.
struct LeaderScratch {
    /// drained f32 reply buffers, handed back out with the next commands
    f32_pool: Vec<Vec<f32>>,
    /// drained SVRG `idx` payload buffers (see [`Cluster::recycled_idx_buf`])
    idx_pool: Vec<Arc<Vec<u32>>>,
    /// per-worker replay state of the in-flight SVRG phase (fixed `P·Q`
    /// length; cleared as each reply lands so the pooled `idx` Arcs are
    /// uniquely owned again)
    svrg_retain: Vec<Option<RetainedSvrg>>,
    /// per-worker reply staging slots (fixed `P·Q` length) for reduces
    /// that must run in worker-id order
    slots: Vec<Option<Vec<f32>>>,
    /// worker id → task index routing of the in-flight SVRG phase
    /// (fixed `P·Q` length, `usize::MAX` = free)
    id_to_task: Vec<usize>,
    /// per-partition objective terms of the fused `Q == 1` loss phase
    loss_parts: Vec<f64>,
    /// per-partition reduced margins of the `Q > 1` paths
    z: Vec<Vec<f32>>,
    /// label gather buffer of the `Q > 1` dloss/loss passes
    y_rows: Vec<f32>,
}

/// Handle to the launched cluster (leader side).
pub struct Cluster {
    pub p: usize,
    pub q: usize,
    /// the grid's partition geometry (ragged boundary vectors) — the
    /// leader's only source of block dims after blocks move to workers
    pub layout: Layout,
    /// labels per observation partition (leader copy, for dloss/loss)
    pub y: Vec<Vec<f32>>,
    /// density (nnz fraction) per worker `[p][q]`, for the cost model
    pub density: Vec<f64>,
    transport: Box<dyn Transport>,
    scratch: RefCell<LeaderScratch>,
    /// the launch grid, retained so dead workers can be rebuilt from
    /// their shard — the in-memory analogue of a durable shard store
    /// (costs one extra copy of the block data, the price of recovery)
    store: Arc<Grid>,
    /// shared engine handle for rebuilding [`WorkerCore`]s
    engine: Arc<dyn ComputeEngine>,
    loss: Loss,
    /// workers with an injected (expected) kill not yet recovered —
    /// a fault from any other worker is a genuine bug and panics
    armed: RefCell<Vec<Armed>>,
    /// worker ids recovered so far, in recovery order
    fault_log: RefCell<Vec<usize>>,
    /// retry/backoff/escalation knobs for [`Cluster::recover`]
    policy: RecoveryPolicy,
}

impl Cluster {
    /// Move the grid's blocks into workers, picking the executor from
    /// the environment ([`ExecutorKind::resolve`] with no preference:
    /// `SODDA_EXECUTOR` if set, else the in-process oracle). Panics on
    /// an unparseable env value — config-driven callers go through
    /// [`crate::Trainer`], which surfaces that as an error instead.
    pub fn launch(grid: Grid, engine: Arc<dyn ComputeEngine>, loss: Loss) -> Cluster {
        let kind = ExecutorKind::resolve(None).expect("SODDA_EXECUTOR");
        Self::launch_with(grid, engine, loss, kind)
    }

    /// Move the grid's blocks into workers run by the given executor,
    /// recovering faults under the default [`RecoveryPolicy`].
    pub fn launch_with(
        grid: Grid,
        engine: Arc<dyn ComputeEngine>,
        loss: Loss,
        kind: ExecutorKind,
    ) -> Cluster {
        Self::launch_with_policy(grid, engine, loss, kind, RecoveryPolicy::default())
    }

    /// [`Cluster::launch_with`] with explicit recovery knobs: the
    /// threaded transport probes its reply channel every
    /// `policy.probe_ms`, and [`Cluster::recover`] retries respawn
    /// `policy.max_retries` times (linear `backoff_ms` between
    /// attempts) before escalating to [`PermanentLoss`].
    pub fn launch_with_policy(
        grid: Grid,
        engine: Arc<dyn ComputeEngine>,
        loss: Loss,
        kind: ExecutorKind,
        policy: RecoveryPolicy,
    ) -> Cluster {
        let layout = grid.layout.clone();
        let (p, q) = (layout.p, layout.q);
        let y: Vec<Vec<f32>> = (0..p).map(|pi| grid.block(pi, 0).y.clone()).collect();
        let density: Vec<f64> = grid
            .blocks()
            .map(|b| b.x.nnz() as f64 / (b.x.rows() as f64 * b.x.cols() as f64).max(1.0))
            .collect();

        // Grid stores blocks row-major [p][q]; worker ids follow it.
        let store = Arc::new(grid);
        let mut cores = Vec::with_capacity(p * q);
        for pi in 0..p {
            for qi in 0..q {
                cores.push(WorkerCore::new(store.block(pi, qi).clone(), Arc::clone(&engine), loss));
            }
        }
        let transport = transport::launch(kind, cores, Duration::from_millis(policy.probe_ms));
        let scratch = RefCell::new(LeaderScratch {
            f32_pool: Vec::new(),
            idx_pool: Vec::new(),
            svrg_retain: (0..p * q).map(|_| None).collect(),
            slots: (0..p * q).map(|_| None).collect(),
            id_to_task: vec![usize::MAX; p * q],
            loss_parts: Vec::new(),
            z: Vec::new(),
            y_rows: Vec::new(),
        });
        Cluster {
            p,
            q,
            layout,
            y,
            density,
            transport,
            scratch,
            store,
            engine,
            loss,
            armed: RefCell::new(vec![Armed::Clear; p * q]),
            fault_log: RefCell::new(Vec::new()),
            policy,
        }
    }

    /// Simulate a crash of worker `wid` (`p·Q + q`): the worker stops
    /// executing and the next command addressed to it surfaces as a
    /// fault, which the in-flight phase recovers from transparently —
    /// rebuild from the shard store, respawn, replay. Deterministic on
    /// both executors: the kill is FIFO-ordered ahead of the next
    /// phase's commands, so the victim never partially executes one and
    /// the recovered run stays bit-identical to a fault-free run.
    pub fn inject_fault(&self, wid: usize) {
        assert!(wid < self.p * self.q, "worker {wid} outside the {}x{} grid", self.p, self.q);
        self.armed.borrow_mut()[wid] = Armed::Transient;
        self.transport.kill(wid);
    }

    /// [`Cluster::inject_fault`] with no way back: the next phase that
    /// touches `wid` skips the respawn attempts and escalates straight
    /// to [`PermanentLoss`] — the `!perm` fault-plan syntax and the
    /// machine-loss half of `tests/faults.rs` ride on this.
    pub fn inject_permanent_fault(&self, wid: usize) {
        assert!(wid < self.p * self.q, "worker {wid} outside the {}x{} grid", self.p, self.q);
        self.armed.borrow_mut()[wid] = Armed::Perm;
        self.transport.kill(wid);
    }

    /// Make the next `n` transport respawn attempts fail (test hook for
    /// the retry/escalation path; a no-op on the in-process oracle,
    /// whose inline respawn cannot fail).
    pub fn refuse_respawns(&self, n: usize) {
        self.transport.refuse_respawns(n);
    }

    /// Worker ids recovered so far, in recovery order (observability for
    /// tests and the trainer's fault history).
    pub fn recovered_workers(&self) -> Vec<usize> {
        self.fault_log.borrow().clone()
    }

    /// Re-launch dead worker `wid` from the retained shard store, under
    /// the cluster's [`RecoveryPolicy`]: up to `max_retries` respawn
    /// attempts with linear backoff (`attempt · backoff_ms`) between
    /// them, then escalate to [`PermanentLoss`]. A fault armed
    /// permanent escalates immediately — no attempts. Panics when no
    /// fault was armed for `wid` — an *unexpected* worker death (e.g. a
    /// panicked thread) names the dead worker instead of silently
    /// hanging the barrier or masking a crash as recoverable.
    fn recover(&self, wid: usize) -> Result<(), PermanentLoss> {
        let arm = self.armed.borrow()[wid];
        assert!(
            arm != Armed::Clear,
            "worker {wid} died unexpectedly mid-phase (no fault was injected)"
        );
        self.armed.borrow_mut()[wid] = Armed::Clear;
        if arm == Armed::Perm {
            return Err(PermanentLoss { worker: wid });
        }
        let (pi, qi) = (wid / self.q, wid % self.q);
        for attempt in 1..=self.policy.max_retries {
            let core = WorkerCore::new(
                self.store.block(pi, qi).clone(),
                Arc::clone(&self.engine),
                self.loss,
            );
            if self.transport.respawn(wid, core) {
                self.fault_log.borrow_mut().push(wid);
                return Ok(());
            }
            if attempt < self.policy.max_retries && self.policy.backoff_ms > 0 {
                std::thread::sleep(Duration::from_millis(attempt as u64 * self.policy.backoff_ms));
            }
        }
        Err(PermanentLoss { worker: wid })
    }

    /// The executor running this cluster's workers.
    pub fn executor(&self) -> ExecutorKind {
        self.transport.kind()
    }

    /// Wire size of the retained shard store (matrix blocks + labels) —
    /// exactly the bytes a (re-)staging of this cluster puts on the
    /// network. The trainer debug-asserts its re-shard shuffle charge
    /// against this, keeping the SimNet accounting honest.
    pub fn staged_bytes(&self) -> u64 {
        self.store.blocks().map(|b| (b.x.approx_bytes() + 4 * b.y.len()) as u64).sum()
    }

    #[inline]
    fn wid(&self, p: usize, q: usize) -> usize {
        p * self.q + q
    }

    pub fn density_at(&self, p: usize, q: usize) -> f64 {
        self.density[self.wid(p, q)]
    }

    /// Pop a recycled SVRG `idx` buffer (returned to the pool by
    /// [`Cluster::svrg_run`] after each phase); fresh when the pool is
    /// dry. Callers fill it (uniquely owned by then — the replay clone
    /// is dropped before pooling, see [`crate::util::arc_mut`]) and
    /// hand it back through [`SvrgTask::idx`].
    pub fn recycled_idx_buf(&self) -> Arc<Vec<u32>> {
        self.scratch.borrow_mut().idx_pool.pop().unwrap_or_default()
    }

    /// Drop every pooled buffer and leader workspace, forcing the next
    /// phases back onto the cold (fresh-allocation) path. Numbers are
    /// unaffected — pooling only recycles allocations; the
    /// alloc-regression harness uses this to measure pooled vs fresh on
    /// the very same session.
    pub fn drop_scratch(&self) {
        let mut s = self.scratch.borrow_mut();
        s.f32_pool = Vec::new();
        s.idx_pool = Vec::new();
        s.loss_parts = Vec::new();
        s.z = Vec::new();
        s.y_rows = Vec::new();
        // slots / id_to_task / svrg_retain keep their fixed P·Q length
        // (allocated at launch, content-free between phases)
    }

    /// Phase 1 of the µ^t estimate: partial margins, reduced over feature
    /// partitions. `w_blocks[q]` is the (masked) parameter slice of block
    /// q; `rows[p]` the sampled local row ids of partition p. Returns
    /// `z[p][k] = x_{rows[p][k]}^{B} · w_B`.
    pub fn partial_z(
        &self,
        w_blocks: &[Arc<Vec<f32>>],
        rows: &[Arc<Vec<u32>>],
    ) -> Result<Vec<Vec<f32>>, PermanentLoss> {
        let mut z = Vec::new();
        self.partial_z_into(w_blocks, rows, &mut z)?;
        Ok(z)
    }

    /// In-place [`Cluster::partial_z`]: refills the caller's per-partition
    /// buffers (allocation-free once warm). Replies are staged by worker
    /// id and reduced in a fixed order — f32 addition is non-associative
    /// and runs must be reproducible — exactly like the allocating path.
    pub fn partial_z_into(
        &self,
        w_blocks: &[Arc<Vec<f32>>],
        rows: &[Arc<Vec<u32>>],
        z: &mut Vec<Vec<f32>>,
    ) -> Result<(), PermanentLoss> {
        self.partial_z_impl(w_blocks, None, rows, z, None)
    }

    /// Sampled-width [`Cluster::partial_z_into`]: `bcols[q]` is the
    /// sorted block-local id list of `B^t ∩ block q` and `w_blocks[q]`
    /// the matching **compact** parameter slice
    /// (`w_blocks[q].len() == bcols[q].len()`), so the wire carries
    /// O(|B∩block|) floats per worker and the workers do
    /// O(rows·|B∩block|) work. Reduce order is identical to the
    /// full-width path, so the sampled path is deterministic.
    pub fn partial_z_cols_into(
        &self,
        w_blocks: &[Arc<Vec<f32>>],
        bcols: &[Arc<Vec<u32>>],
        rows: &[Arc<Vec<u32>>],
        z: &mut Vec<Vec<f32>>,
    ) -> Result<(), PermanentLoss> {
        self.partial_z_impl(w_blocks, Some(bcols), rows, z, None)
    }

    fn partial_z_impl(
        &self,
        w_blocks: &[Arc<Vec<f32>>],
        bcols: Option<&[Arc<Vec<u32>>]>,
        rows: &[Arc<Vec<u32>>],
        z: &mut Vec<Vec<f32>>,
        mut quorum: Option<&mut QuorumCtx<'_>>,
    ) -> Result<(), PermanentLoss> {
        let mut s = self.scratch.borrow_mut();
        for pi in 0..self.p {
            for qi in 0..self.q {
                if let Some(bc) = bcols {
                    debug_assert_eq!(
                        w_blocks[qi].len(),
                        bc[qi].len(),
                        "compact w payload must match its id list"
                    );
                }
                let buf = s.f32_pool.pop().unwrap_or_default();
                self.transport.send(
                    self.wid(pi, qi),
                    Cmd::PartialZ {
                        w: Arc::clone(&w_blocks[qi]),
                        cols: bcols.map(|bc| Arc::clone(&bc[qi])),
                        rows: Arc::clone(&rows[pi]),
                        buf,
                    },
                );
            }
        }
        let mut remaining = self.p * self.q;
        while remaining > 0 {
            match self.transport.recv() {
                (id, Reply::Z(part)) => {
                    debug_assert!(s.slots[id].is_none(), "duplicate Z reply from worker {id}");
                    s.slots[id] = Some(part);
                    remaining -= 1;
                }
                (id, Reply::Fault) => {
                    self.recover(id)?;
                    let (pi, qi) = (id / self.q, id % self.q);
                    let buf = s.f32_pool.pop().unwrap_or_default();
                    self.transport.send(
                        id,
                        Cmd::PartialZ {
                            w: Arc::clone(&w_blocks[qi]),
                            cols: bcols.map(|bc| Arc::clone(&bc[qi])),
                            rows: Arc::clone(&rows[pi]),
                            buf,
                        },
                    );
                }
                _ => panic!("expected Z reply"),
            }
        }
        z.resize_with(self.p, Vec::new);
        for (pi, zp) in z.iter_mut().enumerate() {
            zp.clear();
            zp.resize(rows[pi].len(), 0.0);
        }
        for id in 0..self.p * self.q {
            let part = s.slots[id].take().expect("reply staged");
            let pi = id / self.q;
            match quorum.as_deref_mut() {
                Some(ctx) if !ctx.mask[id] => {
                    ctx.stats.parked += 1;
                    ctx.late.entries.push(LateReply {
                        iter: ctx.iter,
                        worker: id,
                        slice: LateSlice::Mu { p: pi, part },
                    });
                }
                other => {
                    for (acc, &v) in z[pi].iter_mut().zip(&part) {
                        *acc += v;
                    }
                    s.f32_pool.push(part);
                    if let Some(ctx) = other {
                        ctx.stats.quorum += 1;
                    }
                }
            }
        }
        if let Some(ctx) = quorum {
            // fold straggler z-parts from earlier iterations before the
            // leader applies the derivative
            drain_mu_late(ctx, &mut s.f32_pool, |p, w, part| {
                for (acc, &v) in z[p].iter_mut().zip(part) {
                    *acc += w * v;
                }
            });
        }
        Ok(())
    }

    /// Phase-1 derivative `u[p][k] = f'(z_k, y_k)`. On single-feature-
    /// block grids (`Q == 1`) each block already holds the complete
    /// margin, so workers compute `u` locally through the engines' fused
    /// batched `partial_u` entry point — no leader-side z reduce + dloss
    /// round. On `Q > 1` grids the margins are reduced across feature
    /// blocks here and `leader` applies the derivative; both paths
    /// produce bit-identical numbers.
    pub fn partial_u(
        &self,
        w_blocks: &[Arc<Vec<f32>>],
        rows: &[Arc<Vec<u32>>],
        leader: &dyn ComputeEngine,
        loss: Loss,
    ) -> Result<Vec<Vec<f32>>, PermanentLoss> {
        let mut u = Vec::new();
        self.partial_u_into(w_blocks, rows, leader, loss, &mut u)?;
        // the Arcs are uniquely owned here (fresh vector, phase barrier
        // passed), so this unwraps without copying
        Ok(u.into_iter()
            .map(|a| Arc::try_unwrap(a).unwrap_or_else(|a| a.as_ref().clone()))
            .collect())
    }

    /// In-place [`Cluster::partial_u`]: refills the caller's recycled
    /// per-partition `Arc` buffers (the consumers — the gradient phase,
    /// the trainer workspace — hand these out by `Arc::clone`, and by
    /// the next iteration the clones are back to one owner; see
    /// [`crate::util::arc_mut`]). The `Q > 1` path reuses the leader's
    /// `z`/`y_rows` workspaces, with the dloss gather hoisted out of any
    /// per-partition closure.
    pub fn partial_u_into(
        &self,
        w_blocks: &[Arc<Vec<f32>>],
        rows: &[Arc<Vec<u32>>],
        leader: &dyn ComputeEngine,
        loss: Loss,
        u: &mut Vec<Arc<Vec<f32>>>,
    ) -> Result<(), PermanentLoss> {
        self.partial_u_impl(w_blocks, None, rows, leader, loss, u, None)
    }

    /// Sampled-width [`Cluster::partial_u_into`]: compact `w_blocks`
    /// over the `bcols` id lists (see
    /// [`Cluster::partial_z_cols_into`]); both the `Q == 1` fused
    /// worker path and the `Q > 1` z-reduce path ship only the sampled
    /// widths.
    pub fn partial_u_cols_into(
        &self,
        w_blocks: &[Arc<Vec<f32>>],
        bcols: &[Arc<Vec<u32>>],
        rows: &[Arc<Vec<u32>>],
        leader: &dyn ComputeEngine,
        loss: Loss,
        u: &mut Vec<Arc<Vec<f32>>>,
    ) -> Result<(), PermanentLoss> {
        self.partial_u_impl(w_blocks, Some(bcols), rows, leader, loss, u, None)
    }

    /// Bounded-staleness phase 1: identical collection to
    /// [`Cluster::partial_u_into`] / [`Cluster::partial_u_cols_into`]
    /// (every reply is physically received, so buffer recycling and the
    /// fault-recovery seam — retry, respawn, [`PermanentLoss`]
    /// escalation — are untouched), but replies outside `ctx.mask` are
    /// parked in the [`LateSet`] instead of folded, and parked µ parts
    /// from *earlier* iterations are drained into this phase with
    /// age-discounted weights. Pass `bcols` exactly as for the barrier
    /// variants (`Some` on sampled-B iterations). A full-true mask is
    /// bit-identical to the barrier path.
    #[allow(clippy::too_many_arguments)]
    pub fn partial_u_quorum_into(
        &self,
        w_blocks: &[Arc<Vec<f32>>],
        bcols: Option<&[Arc<Vec<u32>>]>,
        rows: &[Arc<Vec<u32>>],
        leader: &dyn ComputeEngine,
        loss: Loss,
        u: &mut Vec<Arc<Vec<f32>>>,
        ctx: &mut QuorumCtx<'_>,
    ) -> Result<(), PermanentLoss> {
        self.partial_u_impl(w_blocks, bcols, rows, leader, loss, u, Some(ctx))
    }

    #[allow(clippy::too_many_arguments)]
    fn partial_u_impl(
        &self,
        w_blocks: &[Arc<Vec<f32>>],
        bcols: Option<&[Arc<Vec<u32>>]>,
        rows: &[Arc<Vec<u32>>],
        leader: &dyn ComputeEngine,
        loss: Loss,
        u: &mut Vec<Arc<Vec<f32>>>,
        mut quorum: Option<&mut QuorumCtx<'_>>,
    ) -> Result<(), PermanentLoss> {
        u.resize_with(self.p, Default::default);
        if self.q > 1 {
            let mut z = std::mem::take(&mut self.scratch.borrow_mut().z);
            self.partial_z_impl(w_blocks, bcols, rows, &mut z, quorum.as_deref_mut())?;
            let mut s = self.scratch.borrow_mut();
            let s = &mut *s;
            for (pi, up) in u.iter_mut().enumerate() {
                s.y_rows.clear();
                s.y_rows.extend(rows[pi].iter().map(|&r| self.y[pi][r as usize]));
                leader.dloss_u_into(loss, &z[pi], &s.y_rows, arc_mut(up));
            }
            s.z = z;
        } else {
            let mut s = self.scratch.borrow_mut();
            for pi in 0..self.p {
                let buf = s.f32_pool.pop().unwrap_or_default();
                self.transport.send(
                    self.wid(pi, 0),
                    Cmd::PartialU {
                        w: Arc::clone(&w_blocks[0]),
                        cols: bcols.map(|bc| Arc::clone(&bc[0])),
                        rows: Arc::clone(&rows[pi]),
                        buf,
                    },
                );
            }
            let mut remaining = self.p;
            while remaining > 0 {
                // worker id == p index when q == 1; assignment (not
                // reduction), so arrival order cannot change results
                match self.transport.recv() {
                    (id, Reply::U(mut ub)) => {
                        match quorum.as_deref_mut() {
                            Some(ctx) if !ctx.mask[id] => {
                                // straggler: the derivative part is
                                // parked and this partition contributes
                                // zeros until it folds back in
                                let up = arc_mut(&mut u[id]);
                                up.clear();
                                up.resize(rows[id].len(), 0.0);
                                ctx.stats.parked += 1;
                                ctx.late.entries.push(LateReply {
                                    iter: ctx.iter,
                                    worker: id,
                                    slice: LateSlice::Mu { p: id, part: ub },
                                });
                            }
                            other => {
                                std::mem::swap(arc_mut(&mut u[id]), &mut ub);
                                s.f32_pool.push(ub);
                                if let Some(ctx) = other {
                                    ctx.stats.quorum += 1;
                                }
                            }
                        }
                        remaining -= 1;
                    }
                    (id, Reply::Fault) => {
                        self.recover(id)?;
                        let buf = s.f32_pool.pop().unwrap_or_default();
                        self.transport.send(
                            id,
                            Cmd::PartialU {
                                w: Arc::clone(&w_blocks[0]),
                                cols: bcols.map(|bc| Arc::clone(&bc[0])),
                                rows: Arc::clone(&rows[id]),
                                buf,
                            },
                        );
                    }
                    _ => panic!("expected U reply"),
                }
            }
            if let Some(ctx) = quorum {
                // fold straggler u-parts from earlier iterations
                drain_mu_late(ctx, &mut s.f32_pool, |p, w, part| {
                    for (acc, &v) in arc_mut(&mut u[p]).iter_mut().zip(part) {
                        *acc += w * v;
                    }
                });
            }
        }
        Ok(())
    }

    /// Distributed objective term `Σ_k f(z_k, y_k)` over the given rows.
    /// `Q == 1` grids use the workers' fused `block_loss` entry point;
    /// `Q > 1` grids reduce z into the leader workspace and `leader` sums
    /// the loss values (gather buffer reused, loop hoisted). Either way
    /// the reduce runs in worker order, so the f64 total is
    /// deterministic — and the steady state allocates nothing.
    pub fn block_loss(
        &self,
        w_blocks: &[Arc<Vec<f32>>],
        rows: &[Arc<Vec<u32>>],
        leader: &dyn ComputeEngine,
        loss: Loss,
    ) -> Result<f64, PermanentLoss> {
        if self.q > 1 {
            let mut z = std::mem::take(&mut self.scratch.borrow_mut().z);
            self.partial_z_into(w_blocks, rows, &mut z)?;
            let mut s = self.scratch.borrow_mut();
            let s = &mut *s;
            let mut total = 0.0f64;
            for (pi, zp) in z.iter().enumerate() {
                s.y_rows.clear();
                s.y_rows.extend(rows[pi].iter().map(|&r| self.y[pi][r as usize]));
                total += leader.loss_from_z(loss, zp, &s.y_rows);
            }
            s.z = z;
            return Ok(total);
        }
        let mut s = self.scratch.borrow_mut();
        for pi in 0..self.p {
            self.transport.send(
                self.wid(pi, 0),
                Cmd::BlockLoss { w: Arc::clone(&w_blocks[0]), rows: Arc::clone(&rows[pi]) },
            );
        }
        s.loss_parts.clear();
        s.loss_parts.resize(self.p, 0.0);
        let mut remaining = self.p;
        while remaining > 0 {
            match self.transport.recv() {
                (id, Reply::Loss(v)) => {
                    s.loss_parts[id] = v;
                    remaining -= 1;
                }
                (id, Reply::Fault) => {
                    self.recover(id)?;
                    self.transport.send(
                        id,
                        Cmd::BlockLoss { w: Arc::clone(&w_blocks[0]), rows: Arc::clone(&rows[id]) },
                    );
                }
                _ => panic!("expected Loss reply"),
            }
        }
        Ok(s.loss_parts.iter().sum())
    }

    /// Phase 2: gradient slices. `u[p]` aligned with `rows[p]`. Returns
    /// the global gradient-sum vector (length `m_total`), summed over
    /// observation partitions per feature block.
    pub fn grad(
        &self,
        u: &[Arc<Vec<f32>>],
        rows: &[Arc<Vec<u32>>],
    ) -> Result<Vec<f32>, PermanentLoss> {
        let mut g = Vec::new();
        self.grad_into(u, rows, &mut g)?;
        Ok(g)
    }

    /// In-place [`Cluster::grad`]: zeroes and refills the caller's
    /// buffer, assembling slices in worker-id order exactly like the
    /// allocating path (bit-for-bit).
    pub fn grad_into(
        &self,
        u: &[Arc<Vec<f32>>],
        rows: &[Arc<Vec<u32>>],
        g: &mut Vec<f32>,
    ) -> Result<(), PermanentLoss> {
        self.grad_impl(u, None, rows, g, None)
    }

    /// Sampled-width [`Cluster::grad_into`]: workers return **compact**
    /// gradient slices over `ccols[q]` (the sorted block-local ids of
    /// `C^t ∩ block q`, reply length `|C∩block|` instead of the block
    /// width) and the leader scatters them into the full-length `g` at
    /// the global C^t offsets. `g` is zero outside C^t on return, i.e.
    /// already projected — callers skip the separate
    /// `project_inplace` pass. Assembly stays in worker-id order, so
    /// the sampled path is deterministic.
    pub fn grad_cols_into(
        &self,
        u: &[Arc<Vec<f32>>],
        ccols: &[Arc<Vec<u32>>],
        rows: &[Arc<Vec<u32>>],
        g: &mut Vec<f32>,
    ) -> Result<(), PermanentLoss> {
        self.grad_impl(u, Some(ccols), rows, g, None)
    }

    /// Bounded-staleness phase 2: as [`Cluster::grad_into`] /
    /// [`Cluster::grad_cols_into`], but slices outside `ctx.mask` are
    /// parked (tagged with their **global** column ids and the origin
    /// iteration's `1/|D^t|` from `ctx.inv_d`) instead of scattered.
    /// Parked gradient slices are *not* drained here — the trainer
    /// folds them into µ after the `1/|D|` scaling via
    /// [`LateSet::fold_grad_into`], so folds land in µ-units no matter
    /// which iteration (or sampling pattern) they drain into.
    pub fn grad_quorum_into(
        &self,
        u: &[Arc<Vec<f32>>],
        ccols: Option<&[Arc<Vec<u32>>]>,
        rows: &[Arc<Vec<u32>>],
        g: &mut Vec<f32>,
        ctx: &mut QuorumCtx<'_>,
    ) -> Result<(), PermanentLoss> {
        self.grad_impl(u, ccols, rows, g, Some(ctx))
    }

    fn grad_impl(
        &self,
        u: &[Arc<Vec<f32>>],
        ccols: Option<&[Arc<Vec<u32>>]>,
        rows: &[Arc<Vec<u32>>],
        g: &mut Vec<f32>,
        mut quorum: Option<&mut QuorumCtx<'_>>,
    ) -> Result<(), PermanentLoss> {
        let mut s = self.scratch.borrow_mut();
        for pi in 0..self.p {
            for qi in 0..self.q {
                let buf = s.f32_pool.pop().unwrap_or_default();
                self.transport.send(
                    self.wid(pi, qi),
                    Cmd::GradSlice {
                        u: Arc::clone(&u[pi]),
                        cols: ccols.map(|cc| Arc::clone(&cc[qi])),
                        rows: Arc::clone(&rows[pi]),
                        buf,
                    },
                );
            }
        }
        let mut remaining = self.p * self.q;
        while remaining > 0 {
            match self.transport.recv() {
                (id, Reply::Grad(slice)) => {
                    debug_assert!(s.slots[id].is_none(), "duplicate Grad reply from worker {id}");
                    s.slots[id] = Some(slice);
                    remaining -= 1;
                }
                (id, Reply::Fault) => {
                    self.recover(id)?;
                    let (pi, qi) = (id / self.q, id % self.q);
                    let buf = s.f32_pool.pop().unwrap_or_default();
                    self.transport.send(
                        id,
                        Cmd::GradSlice {
                            u: Arc::clone(&u[pi]),
                            cols: ccols.map(|cc| Arc::clone(&cc[qi])),
                            rows: Arc::clone(&rows[pi]),
                            buf,
                        },
                    );
                }
                _ => panic!("expected Grad reply"),
            }
        }
        g.clear();
        g.resize(self.layout.m_total, 0.0);
        for id in 0..self.p * self.q {
            let slice = s.slots[id].take().expect("reply staged");
            let qi = id % self.q;
            let base = self.layout.block_cols(qi).start;
            if let Some(ctx) = quorum.as_deref_mut() {
                if !ctx.mask[id] {
                    let cols: Vec<u32> = match ccols {
                        Some(cc) => cc[qi].iter().map(|&ci| (base + ci as usize) as u32).collect(),
                        None => (base as u32..(base + slice.len()) as u32).collect(),
                    };
                    ctx.stats.parked += 1;
                    ctx.late.entries.push(LateReply {
                        iter: ctx.iter,
                        worker: id,
                        slice: LateSlice::Grad { cols, data: slice, inv_d: ctx.inv_d },
                    });
                    continue;
                }
                ctx.stats.quorum += 1;
            }
            match ccols {
                Some(cc) => {
                    debug_assert_eq!(
                        slice.len(),
                        cc[qi].len(),
                        "compact grad reply must match its id list"
                    );
                    for (&ci, &v) in cc[qi].iter().zip(&slice) {
                        g[base + ci as usize] += v;
                    }
                }
                None => {
                    for (k, &v) in slice.iter().enumerate() {
                        g[base + k] += v;
                    }
                }
            }
            s.f32_pool.push(slice);
        }
        Ok(())
    }

    /// Phase 3: the parallel inner loops. Returns `(task_index, w_L)` in
    /// completion order.
    pub fn svrg(&self, mut tasks: Vec<SvrgTask>) -> Result<Vec<(usize, Vec<f32>)>, PermanentLoss> {
        let mut out = Vec::with_capacity(tasks.len());
        self.svrg_run(&mut tasks, |ti, w| out.push((ti, w.to_vec())))?;
        Ok(out)
    }

    /// Pooled [`Cluster::svrg`]: drains `tasks` (the vector keeps its
    /// capacity for the next iteration) and streams each finished
    /// sub-block through `apply(task_index, w_L)` in completion order.
    /// Reply and `idx` buffers go back to the pools, so a steady-state
    /// phase allocates nothing. Completion order is non-deterministic,
    /// but tasks own disjoint column ranges, so any write-back through
    /// `apply` lands bit-identically.
    pub fn svrg_run(
        &self,
        tasks: &mut Vec<SvrgTask>,
        mut apply: impl FnMut(usize, &[f32]),
    ) -> Result<(), PermanentLoss> {
        let n = tasks.len();
        {
            let mut s = self.scratch.borrow_mut();
            for (ti, t) in tasks.drain(..).enumerate() {
                let wid = self.wid(t.p, t.q);
                assert_eq!(s.id_to_task[wid], usize::MAX, "one task per worker per phase");
                s.id_to_task[wid] = ti;
                // retain a replay copy (Arc clones + scalars) in case
                // the worker dies before replying
                s.svrg_retain[wid] = Some(RetainedSvrg {
                    cols: t.cols.clone(),
                    gcols: t.gcols.clone(),
                    w: Arc::clone(&t.w),
                    mu: Arc::clone(&t.mu),
                    idx: Arc::clone(&t.idx),
                    gamma: t.gamma,
                    avg: t.avg,
                });
                let buf = s.f32_pool.pop().unwrap_or_default();
                self.transport.send(
                    wid,
                    Cmd::Svrg {
                        cols: t.cols,
                        gcols: t.gcols,
                        w: t.w,
                        mu: t.mu,
                        idx: t.idx,
                        gamma: t.gamma,
                        avg: t.avg,
                        buf,
                    },
                );
            }
        }
        let mut remaining = n;
        while remaining > 0 {
            match self.transport.recv() {
                (id, Reply::W { w, idx }) => {
                    // release the scratch borrow before the callback
                    // runs — `apply` is caller code and may legitimately
                    // re-enter the cluster (e.g. `recycled_idx_buf` to
                    // prep the next phase)
                    let ti = {
                        let mut s = self.scratch.borrow_mut();
                        let ti = s.id_to_task[id];
                        s.id_to_task[id] = usize::MAX;
                        // drop the replay clone *before* pooling, so the
                        // pooled idx Arc is uniquely owned again
                        s.svrg_retain[id] = None;
                        s.idx_pool.push(idx);
                        ti
                    };
                    apply(ti, &w);
                    self.scratch.borrow_mut().f32_pool.push(w);
                    remaining -= 1;
                }
                (id, Reply::Fault) => {
                    self.recover(id)?;
                    let cmd = {
                        let mut s = self.scratch.borrow_mut();
                        let buf = s.f32_pool.pop().unwrap_or_default();
                        let r = s.svrg_retain[id]
                            .as_ref()
                            .expect("fault from a worker with no retained SVRG task");
                        Cmd::Svrg {
                            cols: r.cols.clone(),
                            gcols: r.gcols.clone(),
                            w: Arc::clone(&r.w),
                            mu: Arc::clone(&r.mu),
                            idx: Arc::clone(&r.idx),
                            gamma: r.gamma,
                            avg: r.avg,
                            buf,
                        }
                    };
                    self.transport.send(id, cmd);
                }
                _ => panic!("expected W reply"),
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::engine::NativeEngine;
    use crate::util::testing::assert_close_slice;

    fn cluster(n: usize, m: usize, p: usize, q: usize, seed: u64) -> (Cluster, crate::data::Dataset) {
        let ds = synth::dense_zhang(n, m, seed);
        let grid = Grid::partition(&ds, p, q).unwrap();
        let c = Cluster::launch(grid, Arc::new(NativeEngine), Loss::Hinge);
        (c, ds)
    }

    #[test]
    fn partial_z_matches_serial_matvec() {
        let (c, ds) = cluster(30, 12, 3, 2, 1);
        let w: Vec<f32> = (0..12).map(|i| 0.1 * i as f32 - 0.5).collect();
        let w_blocks: Vec<Arc<Vec<f32>>> =
            (0..2).map(|qi| Arc::new(w[qi * 6..(qi + 1) * 6].to_vec())).collect();
        let rows: Vec<Arc<Vec<u32>>> = (0..3).map(|_| Arc::new((0..10u32).collect())).collect();
        let z = c.partial_z(&w_blocks, &rows).unwrap();
        for pi in 0..3 {
            for k in 0..10 {
                let gr = pi * 10 + k;
                let want = ds.x.row_dot_range(gr, 0, 12, &w);
                crate::assert_close!(z[pi][k], want, 1e-4, 1e-4);
            }
        }
    }

    #[test]
    fn pooled_phases_are_bit_identical_across_reuse() {
        // the same phase run again on a warm pool (recycled buffers) and
        // again after dropping every pooled buffer must not change bits
        let (c, _ds) = cluster(30, 12, 3, 2, 10);
        let w: Vec<f32> = (0..12).map(|i| (i as f32 * 0.37).sin() * 0.4).collect();
        let w_blocks: Vec<Arc<Vec<f32>>> =
            (0..2).map(|qi| Arc::new(w[qi * 6..(qi + 1) * 6].to_vec())).collect();
        let rows: Vec<Arc<Vec<u32>>> = (0..3).map(|_| Arc::new(vec![0u32, 2, 5, 9])).collect();
        let cold_z = c.partial_z(&w_blocks, &rows).unwrap();
        let warm_z = c.partial_z(&w_blocks, &rows).unwrap();
        assert_eq!(cold_z, warm_z);
        let cold_u = c.partial_u(&w_blocks, &rows, &NativeEngine, Loss::Hinge).unwrap();
        let warm_u = c.partial_u(&w_blocks, &rows, &NativeEngine, Loss::Hinge).unwrap();
        assert_eq!(cold_u, warm_u);
        let cold_l = c.block_loss(&w_blocks, &rows, &NativeEngine, Loss::Hinge).unwrap();
        let warm_l = c.block_loss(&w_blocks, &rows, &NativeEngine, Loss::Hinge).unwrap();
        assert_eq!(cold_l, warm_l);
        c.drop_scratch();
        assert_eq!(c.partial_z(&w_blocks, &rows).unwrap(), cold_z);
        assert_eq!(c.partial_u(&w_blocks, &rows, &NativeEngine, Loss::Hinge).unwrap(), cold_u);
        assert_eq!(c.block_loss(&w_blocks, &rows, &NativeEngine, Loss::Hinge).unwrap(), cold_l);
    }

    #[test]
    fn reply_buffers_return_to_the_pool() {
        let (c, _ds) = cluster(20, 8, 2, 2, 11);
        let w: Vec<f32> = (0..8).map(|i| 0.1 * i as f32).collect();
        let w_blocks: Vec<Arc<Vec<f32>>> =
            (0..2).map(|qi| Arc::new(w[qi * 4..(qi + 1) * 4].to_vec())).collect();
        let rows: Vec<Arc<Vec<u32>>> = (0..2).map(|_| Arc::new(vec![0u32, 3])).collect();
        let _ = c.partial_z(&w_blocks, &rows).unwrap();
        assert_eq!(c.scratch.borrow().f32_pool.len(), 4, "all 4 reply buffers recycled");
        let _ = c.partial_z(&w_blocks, &rows).unwrap();
        assert_eq!(c.scratch.borrow().f32_pool.len(), 4, "pool does not grow on reuse");
    }

    /// Split sorted global column ids into per-block (local ids, compact
    /// w) pairs — the leader-side prep the trainer does before a sampled
    /// phase.
    fn split_cols(
        c: &Cluster,
        ids: &[u32],
        w: &[f32],
    ) -> (Vec<Arc<Vec<u32>>>, Vec<Arc<Vec<f32>>>) {
        let mut cols = Vec::new();
        let mut ws = Vec::new();
        for qi in 0..c.q {
            let r = c.layout.block_cols(qi);
            let local: Vec<u32> = ids
                .iter()
                .filter(|&&i| (i as usize) >= r.start && (i as usize) < r.end)
                .map(|&i| i - r.start as u32)
                .collect();
            ws.push(Arc::new(local.iter().map(|&l| w[r.start + l as usize]).collect::<Vec<f32>>()));
            cols.push(Arc::new(local));
        }
        (cols, ws)
    }

    #[test]
    fn sampled_phases_match_masked_full_width() {
        let (c, _ds) = cluster(30, 12, 3, 2, 12);
        let w: Vec<f32> = (0..12).map(|i| (i as f32 * 0.29).sin() * 0.5).collect();
        // B = {1, 3, 6, 7, 11} spans both blocks; C = {3, 7} ⊂ B
        let b_ids = [1u32, 3, 6, 7, 11];
        let c_ids = [3u32, 7];
        let rows: Vec<Arc<Vec<u32>>> = (0..3).map(|_| Arc::new(vec![0u32, 2, 5, 9])).collect();
        let (bcols, w_compact) = split_cols(&c, &b_ids, &w);
        // masked reference: full-width blocks of w ∘ 1_B
        let mut w_masked = vec![0.0f32; 12];
        for &i in &b_ids {
            w_masked[i as usize] = w[i as usize];
        }
        let w_blocks: Vec<Arc<Vec<f32>>> =
            (0..2).map(|qi| Arc::new(w_masked[c.layout.block_cols(qi)].to_vec())).collect();

        let mut z_sampled = Vec::new();
        c.partial_z_cols_into(&w_compact, &bcols, &rows, &mut z_sampled).unwrap();
        let z_full = c.partial_z(&w_blocks, &rows).unwrap();
        for (zs, zf) in z_sampled.iter().zip(&z_full) {
            assert_close_slice(zs, zf, 1e-5, 1e-6, "sampled z vs masked z");
        }

        let mut u_sampled = Vec::new();
        c.partial_u_cols_into(&w_compact, &bcols, &rows, &NativeEngine, Loss::Hinge, &mut u_sampled).unwrap();
        let u_full = c.partial_u(&w_blocks, &rows, &NativeEngine, Loss::Hinge).unwrap();
        for (us, uf) in u_sampled.iter().zip(&u_full) {
            assert_close_slice(us, uf, 1e-5, 1e-6, "sampled u vs masked u");
        }

        let (ccols, _) = split_cols(&c, &c_ids, &w);
        let u_arcs: Vec<Arc<Vec<f32>>> =
            u_full.iter().map(|up| Arc::new(up.clone())).collect();
        let mut g_sampled = Vec::new();
        c.grad_cols_into(&u_arcs, &ccols, &rows, &mut g_sampled).unwrap();
        let g_full = c.grad(&u_arcs, &rows).unwrap();
        assert_eq!(g_sampled.len(), 12, "sampled g is full-length, projected");
        for i in 0..12u32 {
            if c_ids.contains(&i) {
                crate::assert_close!(g_sampled[i as usize], g_full[i as usize], 1e-5, 1e-6);
            } else {
                assert_eq!(g_sampled[i as usize], 0.0, "coordinate {i} outside C must be zero");
            }
        }
    }

    #[test]
    fn sampled_phases_are_deterministic_and_pool_friendly() {
        // rerun on warm pools and after dropping scratch: identical bits
        let (c, _ds) = cluster(21, 9, 2, 2, 13);
        let w: Vec<f32> = (0..9).map(|i| 0.07 * i as f32 - 0.3).collect();
        // C ⊄ block 0: every sampled id lands in block 1 — block 0's
        // intersection is empty (zero-length payloads must be fine)
        let b_ids = [5u32, 6, 8];
        let rows: Vec<Arc<Vec<u32>>> =
            (0..2).map(|pi| Arc::new((0..c.layout.rows_in(pi) as u32).collect())).collect();
        let (bcols, w_compact) = split_cols(&c, &b_ids, &w);
        assert!(bcols[0].is_empty(), "test premise: empty intersection in block 0");
        let mut cold = Vec::new();
        c.partial_u_cols_into(&w_compact, &bcols, &rows, &NativeEngine, Loss::Hinge, &mut cold).unwrap();
        let mut warm = Vec::new();
        c.partial_u_cols_into(&w_compact, &bcols, &rows, &NativeEngine, Loss::Hinge, &mut warm).unwrap();
        let cold_v: Vec<Vec<f32>> = cold.iter().map(|a| a.as_ref().clone()).collect();
        let warm_v: Vec<Vec<f32>> = warm.iter().map(|a| a.as_ref().clone()).collect();
        assert_eq!(cold_v, warm_v);
        let u_arcs = cold;
        let (ccols, _) = split_cols(&c, &b_ids, &w);
        let mut g1 = Vec::new();
        c.grad_cols_into(&u_arcs, &ccols, &rows, &mut g1).unwrap();
        let mut g2 = Vec::new();
        c.grad_cols_into(&u_arcs, &ccols, &rows, &mut g2).unwrap();
        assert_eq!(g1, g2);
        c.drop_scratch();
        let mut g3 = Vec::new();
        c.grad_cols_into(&u_arcs, &ccols, &rows, &mut g3).unwrap();
        assert_eq!(g1, g3, "pooled vs fresh sampled grad must not change bits");
    }

    #[test]
    fn sampled_fused_q1_matches_reduce_path() {
        // Q = 1: the fused on-worker subset partial_u vs manual subset
        // z + leader dloss
        let (c, _ds) = cluster(30, 12, 3, 1, 14);
        let w: Vec<f32> = (0..12).map(|i| 0.04 * i as f32 - 0.2).collect();
        let b_ids = [0u32, 2, 3, 9];
        let rows: Vec<Arc<Vec<u32>>> = (0..3).map(|_| Arc::new((0..10u32).collect())).collect();
        let (bcols, w_compact) = split_cols(&c, &b_ids, &w);
        let mut u = Vec::new();
        c.partial_u_cols_into(&w_compact, &bcols, &rows, &NativeEngine, Loss::Hinge, &mut u).unwrap();
        let mut z = Vec::new();
        c.partial_z_cols_into(&w_compact, &bcols, &rows, &mut z).unwrap();
        for pi in 0..3 {
            for k in 0..10 {
                let want = Loss::Hinge.dloss(z[pi][k], c.y[pi][k]);
                assert_eq!(u[pi][k], want, "p={pi} k={k}");
            }
        }
    }

    #[test]
    fn grad_matches_serial_rmatvec() {
        let (c, ds) = cluster(20, 8, 2, 2, 2);
        let rows: Vec<Arc<Vec<u32>>> = (0..2).map(|_| Arc::new((0..10u32).collect())).collect();
        let u: Vec<Arc<Vec<f32>>> =
            (0..2).map(|pi| Arc::new((0..10).map(|k| (pi * 10 + k) as f32 * 0.1).collect())).collect();
        let g = c.grad(&u, &rows).unwrap();
        let mut want = vec![0.0f32; 8];
        for gr in 0..20 {
            let uv = gr as f32 * 0.1;
            let mut row = vec![0.0f32; 8];
            ds.x.copy_row_range(gr, 0, 8, &mut row);
            for cidx in 0..8 {
                want[cidx] += uv * row[cidx];
            }
        }
        assert_close_slice(&g, &want, 1e-3, 1e-3, "grad");
    }

    #[test]
    fn svrg_tasks_route_to_correct_workers() {
        let (c, _ds) = cluster(20, 8, 2, 2, 3);
        // zero gamma => w_L == w0, so routing shows through the snapshot
        // windows: block q=0 sub-block 0 is global cols 0..2, block q=1
        // sub-block 1 is global cols 6..8
        let w = Arc::new(vec![1.0f32, 2.0, 0.0, 0.0, 0.0, 0.0, 3.0, 4.0]);
        let mu = Arc::new(vec![0.0f32; 8]);
        let tasks = vec![
            SvrgTask {
                p: 0,
                q: 0,
                cols: 0..2,
                gcols: 0..2,
                w: Arc::clone(&w),
                mu: Arc::clone(&mu),
                idx: Arc::new(vec![0; 4]),
                gamma: 0.0,
                avg: false,
            },
            SvrgTask {
                p: 1,
                q: 1,
                cols: 2..4,
                gcols: 6..8,
                w,
                mu,
                idx: Arc::new(vec![0; 4]),
                gamma: 0.0,
                avg: true,
            },
        ];
        let mut out = c.svrg(tasks).unwrap();
        out.sort_by_key(|(ti, _)| *ti);
        assert_eq!(out[0].1, vec![1.0, 2.0]);
        assert_eq!(out[1].1, vec![3.0, 4.0]);
    }

    #[test]
    fn fused_partial_u_matches_z_then_dloss_on_q1() {
        let (c, _ds) = cluster(30, 12, 3, 1, 6);
        let w: Vec<f32> = (0..12).map(|i| 0.05 * i as f32 - 0.2).collect();
        let w_blocks = vec![Arc::new(w)];
        let rows: Vec<Arc<Vec<u32>>> = (0..3).map(|_| Arc::new((0..10u32).collect())).collect();
        let u = c.partial_u(&w_blocks, &rows, &NativeEngine, Loss::Hinge).unwrap();
        let z = c.partial_z(&w_blocks, &rows).unwrap();
        for pi in 0..3 {
            for k in 0..10 {
                let want = Loss::Hinge.dloss(z[pi][k], c.y[pi][k]);
                assert_eq!(u[pi][k], want, "p={pi} k={k}");
            }
        }
    }

    #[test]
    fn fused_block_loss_matches_serial_objective_on_q1() {
        let (c, ds) = cluster(30, 12, 3, 1, 7);
        let w: Vec<f32> = (0..12).map(|i| (i as f32 * 0.4).sin() * 0.3).collect();
        let w_blocks = vec![Arc::new(w.clone())];
        let rows: Vec<Arc<Vec<u32>>> = (0..3).map(|_| Arc::new((0..10u32).collect())).collect();
        let total = c.block_loss(&w_blocks, &rows, &NativeEngine, Loss::Hinge).unwrap();
        crate::assert_close!(total / 30.0, ds.objective(&w, Loss::Hinge), 1e-4, 1e-5);
    }

    #[test]
    fn partial_u_reduce_path_matches_manual_composition_on_q2() {
        // Q > 1: partial_u must fall back to z-reduce + leader dloss,
        // bit-identical to composing the phases by hand
        let (c, _ds) = cluster(20, 8, 2, 2, 8);
        let w: Vec<f32> = (0..8).map(|i| (i as f32 * 0.3).cos() * 0.4).collect();
        let w_blocks: Vec<Arc<Vec<f32>>> =
            (0..2).map(|qi| Arc::new(w[qi * 4..(qi + 1) * 4].to_vec())).collect();
        let rows: Vec<Arc<Vec<u32>>> = (0..2).map(|_| Arc::new(vec![0u32, 3, 7])).collect();
        let u = c.partial_u(&w_blocks, &rows, &NativeEngine, Loss::Hinge).unwrap();
        let z = c.partial_z(&w_blocks, &rows).unwrap();
        for pi in 0..2 {
            let y_rows: Vec<f32> = rows[pi].iter().map(|&r| c.y[pi][r as usize]).collect();
            let want = NativeEngine.dloss_u(Loss::Hinge, &z[pi], &y_rows);
            assert_eq!(u[pi], want, "p={pi}");
        }
        let total = c.block_loss(&w_blocks, &rows, &NativeEngine, Loss::Hinge).unwrap();
        let want: f64 = (0..2)
            .map(|pi| {
                let y_rows: Vec<f32> = rows[pi].iter().map(|&r| c.y[pi][r as usize]).collect();
                NativeEngine.loss_from_z(Loss::Hinge, &z[pi], &y_rows)
            })
            .sum();
        assert_eq!(total, want);
    }

    #[test]
    fn ragged_partial_z_and_grad_match_serial() {
        // 21 rows over P=2 (10/11), 9 cols over Q=2 (4/5): exercises the
        // boundary-offset assembly paths with genuinely uneven blocks
        let (c, ds) = cluster(21, 9, 2, 2, 9);
        let w: Vec<f32> = (0..9).map(|i| 0.1 * i as f32 - 0.3).collect();
        let w_blocks: Vec<Arc<Vec<f32>>> =
            (0..2).map(|qi| Arc::new(w[c.layout.block_cols(qi)].to_vec())).collect();
        let rows: Vec<Arc<Vec<u32>>> = (0..2)
            .map(|pi| Arc::new((0..c.layout.rows_in(pi) as u32).collect()))
            .collect();
        let z = c.partial_z(&w_blocks, &rows).unwrap();
        for pi in 0..2 {
            assert_eq!(z[pi].len(), c.layout.rows_in(pi));
            for k in 0..c.layout.rows_in(pi) {
                let gr = c.layout.block_rows(pi).start + k;
                let want = ds.x.row_dot_range(gr, 0, 9, &w);
                crate::assert_close!(z[pi][k], want, 1e-4, 1e-4);
            }
        }
        let u: Vec<Arc<Vec<f32>>> = (0..2)
            .map(|pi| {
                let base = c.layout.block_rows(pi).start;
                Arc::new((0..c.layout.rows_in(pi)).map(|k| (base + k) as f32 * 0.1).collect())
            })
            .collect();
        let g = c.grad(&u, &rows).unwrap();
        let mut want = vec![0.0f32; 9];
        for gr in 0..21 {
            let uv = gr as f32 * 0.1;
            let mut row = vec![0.0f32; 9];
            ds.x.copy_row_range(gr, 0, 9, &mut row);
            for (cidx, &xv) in row.iter().enumerate() {
                want[cidx] += uv * xv;
            }
        }
        assert_close_slice(&g, &want, 1e-3, 1e-3, "ragged grad");
    }

    #[test]
    fn density_is_one_for_dense() {
        let (c, _) = cluster(10, 4, 1, 2, 4);
        crate::assert_close!(c.density_at(0, 0), 1.0, 1e-9, 1e-9);
    }

    #[test]
    fn shutdown_is_clean() {
        // threaded explicitly: its Drop sends Shutdown and joins every
        // worker thread; a hang here = test timeout
        let (c, _) = cluster_with(10, 4, 2, 2, 5, ExecutorKind::Threaded);
        drop(c);
    }

    fn cluster_with(
        n: usize,
        m: usize,
        p: usize,
        q: usize,
        seed: u64,
        kind: ExecutorKind,
    ) -> (Cluster, crate::data::Dataset) {
        let ds = synth::dense_zhang(n, m, seed);
        let grid = Grid::partition(&ds, p, q).unwrap();
        let c = Cluster::launch_with(grid, Arc::new(NativeEngine), Loss::Hinge, kind);
        (c, ds)
    }

    #[test]
    fn executor_kind_is_reported() {
        let (a, _) = cluster_with(10, 4, 1, 2, 15, ExecutorKind::InProcess);
        assert_eq!(a.executor(), ExecutorKind::InProcess);
        let (b, _) = cluster_with(10, 4, 1, 2, 15, ExecutorKind::Threaded);
        assert_eq!(b.executor(), ExecutorKind::Threaded);
    }

    #[test]
    fn executors_are_bit_identical_across_all_phases() {
        // the determinism contract at phase granularity: every protocol
        // phase — full-width, sampled-width, and SVRG with a live step
        // size — produces the same bits on the sequential oracle and on
        // real threads (ragged 21x9 grid so boundary paths run too)
        let (a, _) = cluster_with(21, 9, 2, 2, 16, ExecutorKind::InProcess);
        let (b, _) = cluster_with(21, 9, 2, 2, 16, ExecutorKind::Threaded);
        let w: Vec<f32> = (0..9).map(|i| (i as f32 * 0.31).sin() * 0.4).collect();
        let w_blocks: Vec<Arc<Vec<f32>>> =
            (0..2).map(|qi| Arc::new(w[a.layout.block_cols(qi)].to_vec())).collect();
        let rows: Vec<Arc<Vec<u32>>> = (0..2)
            .map(|pi| Arc::new((0..a.layout.rows_in(pi) as u32).collect()))
            .collect();

        assert_eq!(a.partial_z(&w_blocks, &rows).unwrap(), b.partial_z(&w_blocks, &rows).unwrap());
        let ua = a.partial_u(&w_blocks, &rows, &NativeEngine, Loss::Hinge).unwrap();
        let ub = b.partial_u(&w_blocks, &rows, &NativeEngine, Loss::Hinge).unwrap();
        assert_eq!(ua, ub);
        assert_eq!(
            a.block_loss(&w_blocks, &rows, &NativeEngine, Loss::Hinge).unwrap().to_bits(),
            b.block_loss(&w_blocks, &rows, &NativeEngine, Loss::Hinge).unwrap().to_bits()
        );
        let u_arcs: Vec<Arc<Vec<f32>>> = ua.into_iter().map(Arc::new).collect();
        assert_eq!(a.grad(&u_arcs, &rows).unwrap(), b.grad(&u_arcs, &rows).unwrap());

        // sampled-width phases: B spans both blocks, C ⊂ B
        let b_ids = [1u32, 3, 5, 7, 8];
        let (bcols, w_compact) = split_cols(&a, &b_ids, &w);
        let mut us_a = Vec::new();
        a.partial_u_cols_into(&w_compact, &bcols, &rows, &NativeEngine, Loss::Hinge, &mut us_a).unwrap();
        let mut us_b = Vec::new();
        b.partial_u_cols_into(&w_compact, &bcols, &rows, &NativeEngine, Loss::Hinge, &mut us_b).unwrap();
        assert_eq!(us_a, us_b);
        let (ccols, _) = split_cols(&a, &[3u32, 7], &w);
        let mut g_a = Vec::new();
        a.grad_cols_into(&u_arcs, &ccols, &rows, &mut g_a).unwrap();
        let mut g_b = Vec::new();
        b.grad_cols_into(&u_arcs, &ccols, &rows, &mut g_b).unwrap();
        assert_eq!(g_a, g_b);

        // SVRG with a nonzero step: real inner loops, plain and averaged
        // combiners, both sub-blocks (SvrgTask is not Clone — build the
        // identical task list once per cluster)
        let svrg = |c: &Cluster| {
            let w_snap = Arc::new(w.clone());
            let mu = Arc::new((0..9).map(|i| 0.01 * i as f32).collect::<Vec<f32>>());
            let tasks = vec![
                SvrgTask {
                    p: 0,
                    q: 0,
                    cols: 0..2,
                    gcols: 0..2,
                    w: Arc::clone(&w_snap),
                    mu: Arc::clone(&mu),
                    idx: Arc::new(vec![0, 3, 1, 2]),
                    gamma: 0.05,
                    avg: false,
                },
                SvrgTask {
                    p: 1,
                    q: 1,
                    cols: 0..2,
                    gcols: c.layout.block_cols(1).start..c.layout.block_cols(1).start + 2,
                    w: w_snap,
                    mu,
                    idx: Arc::new(vec![2, 0, 4, 1]),
                    gamma: 0.05,
                    avg: true,
                },
            ];
            let mut out = c.svrg(tasks).unwrap();
            out.sort_by_key(|(ti, _)| *ti);
            out
        };
        assert_eq!(svrg(&a), svrg(&b));
    }

    #[test]
    fn threaded_reply_buffers_return_to_the_pool() {
        // PR 4's pooling contract must survive the threaded transport:
        // buffers ride commands down and replies back, whatever the
        // substrate
        let (c, _ds) = cluster_with(20, 8, 2, 2, 11, ExecutorKind::Threaded);
        let w: Vec<f32> = (0..8).map(|i| 0.1 * i as f32).collect();
        let w_blocks: Vec<Arc<Vec<f32>>> =
            (0..2).map(|qi| Arc::new(w[qi * 4..(qi + 1) * 4].to_vec())).collect();
        let rows: Vec<Arc<Vec<u32>>> = (0..2).map(|_| Arc::new(vec![0u32, 3])).collect();
        let _ = c.partial_z(&w_blocks, &rows).unwrap();
        assert_eq!(c.scratch.borrow().f32_pool.len(), 4, "all 4 reply buffers recycled");
        let _ = c.partial_z(&w_blocks, &rows).unwrap();
        assert_eq!(c.scratch.borrow().f32_pool.len(), 4, "pool does not grow on reuse");
    }

    /// Every reduce phase, run fault-free on one cluster and with an
    /// injected kill on an identical twin, must produce the same bits —
    /// on both executors.
    #[test]
    fn injected_fault_recovers_bit_identically() {
        for kind in [ExecutorKind::InProcess, ExecutorKind::Threaded] {
            let (a, _) = cluster_with(21, 9, 2, 2, 17, kind);
            let (b, _) = cluster_with(21, 9, 2, 2, 17, kind);
            let w: Vec<f32> = (0..9).map(|i| (i as f32 * 0.23).sin() * 0.4).collect();
            let w_blocks: Vec<Arc<Vec<f32>>> =
                (0..2).map(|qi| Arc::new(w[a.layout.block_cols(qi)].to_vec())).collect();
            let rows: Vec<Arc<Vec<u32>>> = (0..2)
                .map(|pi| Arc::new((0..a.layout.rows_in(pi) as u32).collect()))
                .collect();

            let z_ok = a.partial_z(&w_blocks, &rows).unwrap();
            b.inject_fault(2);
            assert_eq!(z_ok, b.partial_z(&w_blocks, &rows).unwrap(), "{kind:?} partial_z");
            assert_eq!(b.recovered_workers(), vec![2]);

            let u_ok = a.partial_u(&w_blocks, &rows, &NativeEngine, Loss::Hinge).unwrap();
            b.inject_fault(0);
            assert_eq!(
                u_ok,
                b.partial_u(&w_blocks, &rows, &NativeEngine, Loss::Hinge).unwrap(),
                "{kind:?} partial_u"
            );

            let u_arcs: Vec<Arc<Vec<f32>>> = u_ok.into_iter().map(Arc::new).collect();
            let g_ok = a.grad(&u_arcs, &rows).unwrap();
            b.inject_fault(3);
            assert_eq!(g_ok, b.grad(&u_arcs, &rows).unwrap(), "{kind:?} grad");
            assert_eq!(b.recovered_workers(), vec![2, 0, 3]);

            let l_ok = a.block_loss(&w_blocks, &rows, &NativeEngine, Loss::Hinge).unwrap();
            b.inject_fault(1);
            assert_eq!(
                l_ok.to_bits(),
                b.block_loss(&w_blocks, &rows, &NativeEngine, Loss::Hinge).unwrap().to_bits(),
                "{kind:?} block_loss"
            );
        }
    }

    #[test]
    fn svrg_fault_replays_the_retained_task() {
        for kind in [ExecutorKind::InProcess, ExecutorKind::Threaded] {
            let (a, _) = cluster_with(20, 8, 2, 2, 18, kind);
            let (b, _) = cluster_with(20, 8, 2, 2, 18, kind);
            let run = |c: &Cluster| {
                let w = Arc::new((0..8).map(|i| 0.1 * i as f32 - 0.4).collect::<Vec<f32>>());
                let mu = Arc::new((0..8).map(|i| 0.01 * i as f32).collect::<Vec<f32>>());
                let tasks = vec![
                    SvrgTask {
                        p: 0,
                        q: 0,
                        cols: 0..2,
                        gcols: 0..2,
                        w: Arc::clone(&w),
                        mu: Arc::clone(&mu),
                        idx: Arc::new(vec![0, 3, 1, 2]),
                        gamma: 0.05,
                        avg: false,
                    },
                    SvrgTask {
                        p: 1,
                        q: 1,
                        cols: 2..4,
                        gcols: 6..8,
                        w,
                        mu,
                        idx: Arc::new(vec![2, 0, 4, 1]),
                        gamma: 0.05,
                        avg: true,
                    },
                ];
                let mut out = c.svrg(tasks).unwrap();
                out.sort_by_key(|(ti, _)| *ti);
                out
            };
            let ok = run(&a);
            // kill the worker holding the averaged task (p=1, q=1 → wid 3)
            b.inject_fault(3);
            assert_eq!(ok, run(&b), "{kind:?} svrg with fault");
            assert_eq!(b.recovered_workers(), vec![3]);
        }
    }

    #[test]
    fn consecutive_faults_on_the_same_worker_recover() {
        let (c, _) = cluster_with(20, 8, 2, 2, 19, ExecutorKind::Threaded);
        let w: Vec<f32> = (0..8).map(|i| 0.1 * i as f32).collect();
        let w_blocks: Vec<Arc<Vec<f32>>> =
            (0..2).map(|qi| Arc::new(w[qi * 4..(qi + 1) * 4].to_vec())).collect();
        let rows: Vec<Arc<Vec<u32>>> = (0..2).map(|_| Arc::new(vec![0u32, 3])).collect();
        let base = c.partial_z(&w_blocks, &rows).unwrap();
        for _ in 0..3 {
            c.inject_fault(1);
            assert_eq!(base, c.partial_z(&w_blocks, &rows).unwrap());
        }
        assert_eq!(c.recovered_workers(), vec![1, 1, 1]);
    }

    #[test]
    #[should_panic(expected = "died unexpectedly")]
    fn unexpected_worker_death_panics_with_its_id() {
        // a kill that bypasses inject_fault (no armed flag) models a
        // genuine worker crash: the phase must name the dead worker
        // instead of hanging the barrier
        let (c, _) = cluster(20, 8, 2, 2, 20);
        c.transport.kill(2);
        let w: Vec<f32> = (0..8).map(|i| 0.1 * i as f32).collect();
        let w_blocks: Vec<Arc<Vec<f32>>> =
            (0..2).map(|qi| Arc::new(w[qi * 4..(qi + 1) * 4].to_vec())).collect();
        let rows: Vec<Arc<Vec<u32>>> = (0..2).map(|_| Arc::new(vec![0u32, 3])).collect();
        let _ = c.partial_z(&w_blocks, &rows).unwrap();
    }

    #[test]
    fn permanent_fault_escalates_without_respawning() {
        for kind in [ExecutorKind::InProcess, ExecutorKind::Threaded] {
            let (c, _) = cluster_with(20, 8, 2, 2, 21, kind);
            let w: Vec<f32> = (0..8).map(|i| 0.1 * i as f32).collect();
            let w_blocks: Vec<Arc<Vec<f32>>> =
                (0..2).map(|qi| Arc::new(w[qi * 4..(qi + 1) * 4].to_vec())).collect();
            let rows: Vec<Arc<Vec<u32>>> = (0..2).map(|_| Arc::new(vec![0u32, 3])).collect();
            c.inject_permanent_fault(2);
            assert_eq!(
                c.partial_z(&w_blocks, &rows),
                Err(PermanentLoss { worker: 2 }),
                "{kind:?} perm fault must escalate"
            );
            assert_eq!(c.recovered_workers(), Vec::<usize>::new(), "no respawn on a perm fault");
        }
    }

    #[test]
    fn exhausted_respawn_retries_escalate_to_permanent_loss() {
        // threaded only: its respawn can be made to fail; the policy
        // allows 2 attempts, all refused -> escalation. With one refusal
        // fewer, the final attempt lands and the phase completes.
        let ds = synth::dense_zhang(20, 8, 22);
        let policy = RecoveryPolicy { max_retries: 2, backoff_ms: 0, probe_ms: 50 };
        let launch = || {
            let grid = Grid::partition(&ds, 2, 2).unwrap();
            Cluster::launch_with_policy(
                grid,
                Arc::new(NativeEngine),
                Loss::Hinge,
                ExecutorKind::Threaded,
                policy,
            )
        };
        let w: Vec<f32> = (0..8).map(|i| 0.1 * i as f32).collect();
        let w_blocks: Vec<Arc<Vec<f32>>> =
            (0..2).map(|qi| Arc::new(w[qi * 4..(qi + 1) * 4].to_vec())).collect();
        let rows: Vec<Arc<Vec<u32>>> = (0..2).map(|_| Arc::new(vec![0u32, 3])).collect();

        let c = launch();
        let base = c.partial_z(&w_blocks, &rows).unwrap();
        c.refuse_respawns(2);
        c.inject_fault(1);
        assert_eq!(c.partial_z(&w_blocks, &rows), Err(PermanentLoss { worker: 1 }));
        drop(c);

        let c = launch();
        c.refuse_respawns(1);
        c.inject_fault(1);
        assert_eq!(c.partial_z(&w_blocks, &rows), Ok(base), "second attempt must succeed");
        assert_eq!(c.recovered_workers(), vec![1]);
    }

    #[test]
    fn quorum_with_a_full_mask_is_bit_identical_to_the_barrier() {
        let (c, _ds) = cluster(30, 12, 3, 2, 23);
        let w: Vec<f32> = (0..12).map(|i| (i as f32 * 0.19).sin() * 0.4).collect();
        let w_blocks: Vec<Arc<Vec<f32>>> =
            (0..2).map(|qi| Arc::new(w[c.layout.block_cols(qi)].to_vec())).collect();
        let rows: Vec<Arc<Vec<u32>>> = (0..3).map(|_| Arc::new(vec![0u32, 2, 5, 9])).collect();

        let mut u_b = Vec::new();
        c.partial_u_into(&w_blocks, &rows, &NativeEngine, Loss::Hinge, &mut u_b).unwrap();
        let mask = vec![true; 6];
        let mut late = LateSet::default();
        let mut stats = QuorumStats::default();
        let mut u_q = Vec::new();
        let mut ctx = QuorumCtx {
            mask: &mask,
            iter: 0,
            max_staleness_iters: 2,
            inv_d: 0.25,
            late: &mut late,
            stats: &mut stats,
        };
        c.partial_u_quorum_into(&w_blocks, None, &rows, &NativeEngine, Loss::Hinge, &mut u_q, &mut ctx)
            .unwrap();
        assert_eq!(u_b, u_q);
        assert!(late.is_empty());
        assert_eq!(stats.quorum, 6);
        assert_eq!(stats.parked + stats.folds + stats.drops, 0);

        let g_b = c.grad(&u_b, &rows).unwrap();
        let mut stats = QuorumStats::default();
        let mut g_q = Vec::new();
        let mut ctx = QuorumCtx {
            mask: &mask,
            iter: 0,
            max_staleness_iters: 2,
            inv_d: 0.25,
            late: &mut late,
            stats: &mut stats,
        };
        c.grad_quorum_into(&u_b, None, &rows, &mut g_q, &mut ctx).unwrap();
        assert_eq!(g_b, g_q);
        assert!(late.is_empty());
        assert_eq!(stats.quorum, 6);
    }

    #[test]
    fn quorum_drops_replies_past_the_staleness_bound_without_touching_the_fold() {
        // park worker 1's z-part at iter 0, then run the next quorum
        // phase at iter 5 with a staleness bound of 2: the entry must be
        // dropped, leaving the phase bit-identical to the barrier
        let (c, _ds) = cluster(20, 8, 1, 2, 24);
        let w: Vec<f32> = (0..8).map(|i| (i as f32 * 0.27).sin() * 0.4).collect();
        let w_blocks: Vec<Arc<Vec<f32>>> =
            (0..2).map(|qi| Arc::new(w[c.layout.block_cols(qi)].to_vec())).collect();
        let rows: Vec<Arc<Vec<u32>>> = vec![Arc::new(vec![0u32, 3, 7, 11])];
        let mut u_b = Vec::new();
        c.partial_u_into(&w_blocks, &rows, &NativeEngine, Loss::Hinge, &mut u_b).unwrap();

        let mut late = LateSet::default();
        let mut stats = QuorumStats::default();
        let mut u_q = Vec::new();
        let mask = vec![true, false];
        let mut ctx = QuorumCtx {
            mask: &mask,
            iter: 0,
            max_staleness_iters: 2,
            inv_d: 0.25,
            late: &mut late,
            stats: &mut stats,
        };
        c.partial_u_quorum_into(&w_blocks, None, &rows, &NativeEngine, Loss::Hinge, &mut u_q, &mut ctx)
            .unwrap();
        assert_eq!((stats.quorum, stats.parked), (1, 1));
        assert_eq!(late.len(), 1);
        assert_eq!(late.entries[0].worker, 1);
        assert_eq!(late.entries[0].iter, 0);
        let LateSlice::Mu { p, ref part } = late.entries[0].slice else { panic!("mu slice") };
        assert_eq!((p, part.len()), (0, rows[0].len()));

        let full = vec![true, true];
        let mut stats = QuorumStats::default();
        let mut ctx = QuorumCtx {
            mask: &full,
            iter: 5,
            max_staleness_iters: 2,
            inv_d: 0.25,
            late: &mut late,
            stats: &mut stats,
        };
        c.partial_u_quorum_into(&w_blocks, None, &rows, &NativeEngine, Loss::Hinge, &mut u_q, &mut ctx)
            .unwrap();
        assert_eq!(u_q, u_b, "a dropped late reply must not perturb the phase");
        assert!(late.is_empty());
        assert_eq!((stats.folds, stats.drops), (0, 1));
    }

    #[test]
    fn quorum_folds_late_u_parts_with_age_discount() {
        // Q == 1 fused path: the straggler partition reads zero while
        // parked, then folds back at half weight one iteration later
        let (c, _ds) = cluster(30, 8, 3, 1, 25);
        let w: Vec<f32> = (0..8).map(|i| (i as f32 * 0.33).sin() * 0.4).collect();
        let w_blocks = vec![Arc::new(w.clone())];
        let rows: Vec<Arc<Vec<u32>>> = (0..3).map(|_| Arc::new(vec![0u32, 2, 5, 9])).collect();
        let mut u_b = Vec::new();
        c.partial_u_into(&w_blocks, &rows, &NativeEngine, Loss::Hinge, &mut u_b).unwrap();

        let mut late = LateSet::default();
        let mut stats = QuorumStats::default();
        let mut u_q = Vec::new();
        let mask = vec![true, false, true];
        let mut ctx = QuorumCtx {
            mask: &mask,
            iter: 0,
            max_staleness_iters: 2,
            inv_d: 0.25,
            late: &mut late,
            stats: &mut stats,
        };
        c.partial_u_quorum_into(&w_blocks, None, &rows, &NativeEngine, Loss::Hinge, &mut u_q, &mut ctx)
            .unwrap();
        assert_eq!(*u_q[1], vec![0.0f32; rows[1].len()], "parked partition reads zero");
        assert_eq!(u_q[0], u_b[0]);
        assert_eq!(u_q[2], u_b[2]);

        let full = vec![true; 3];
        let mut stats = QuorumStats::default();
        let mut ctx = QuorumCtx {
            mask: &full,
            iter: 1,
            max_staleness_iters: 2,
            inv_d: 0.25,
            late: &mut late,
            stats: &mut stats,
        };
        c.partial_u_quorum_into(&w_blocks, None, &rows, &NativeEngine, Loss::Hinge, &mut u_q, &mut ctx)
            .unwrap();
        // same w and rows, so the parked part equals the barrier part:
        // the fold lands exactly at u + 0.5·u
        let want: Vec<f32> = u_b[1].iter().map(|&v| v + 0.5 * v).collect();
        assert_eq!(*u_q[1], want);
        assert_eq!((stats.folds, stats.drops), (1, 0));
        crate::assert_close!(stats.fold_weight, 0.5, 1e-12, 1e-12);
    }

    #[test]
    fn quorum_folds_late_z_parts_before_the_derivative() {
        // Q > 1 reduce path, exact reconstruction: park worker 1's
        // z-part at iter 0, drain at iter 1 and check u against a
        // manually folded margin
        let (c, _ds) = cluster(20, 8, 1, 2, 26);
        let w: Vec<f32> = (0..8).map(|i| (i as f32 * 0.21).sin() * 0.4).collect();
        let w_blocks: Vec<Arc<Vec<f32>>> =
            (0..2).map(|qi| Arc::new(w[c.layout.block_cols(qi)].to_vec())).collect();
        let rows: Vec<Arc<Vec<u32>>> = vec![Arc::new(vec![0u32, 2, 5, 9, 13])];
        // worker 1's reply in isolation: zero the block-0 parameters
        let zero0 = vec![Arc::new(vec![0.0f32; w_blocks[0].len()]), Arc::clone(&w_blocks[1])];
        let part1 = c.partial_z(&zero0, &rows).unwrap().remove(0);
        let z_full = c.partial_z(&w_blocks, &rows).unwrap();

        let mut late = LateSet::default();
        let mut stats = QuorumStats::default();
        let mut u_q = Vec::new();
        let mask = vec![true, false];
        let mut ctx = QuorumCtx {
            mask: &mask,
            iter: 0,
            max_staleness_iters: 2,
            inv_d: 0.25,
            late: &mut late,
            stats: &mut stats,
        };
        c.partial_u_quorum_into(&w_blocks, None, &rows, &NativeEngine, Loss::Hinge, &mut u_q, &mut ctx)
            .unwrap();
        let full = vec![true, true];
        let mut stats = QuorumStats::default();
        let mut ctx = QuorumCtx {
            mask: &full,
            iter: 1,
            max_staleness_iters: 2,
            inv_d: 0.25,
            late: &mut late,
            stats: &mut stats,
        };
        c.partial_u_quorum_into(&w_blocks, None, &rows, &NativeEngine, Loss::Hinge, &mut u_q, &mut ctx)
            .unwrap();
        let zp: Vec<f32> = z_full[0].iter().zip(&part1).map(|(&a, &b)| a + 0.5 * b).collect();
        let y: Vec<f32> = rows[0].iter().map(|&r| c.y[0][r as usize]).collect();
        let mut want = Vec::new();
        NativeEngine.dloss_u_into(Loss::Hinge, &zp, &y, &mut want);
        assert_eq!(*u_q[0], want);
        assert_eq!((stats.folds, stats.drops), (1, 0));
    }

    #[test]
    fn grad_quorum_parks_global_slices_and_folds_into_mu() {
        let (c, _ds) = cluster(20, 8, 1, 2, 27);
        let rows: Vec<Arc<Vec<u32>>> = vec![Arc::new(vec![0u32, 3, 7, 11])];
        let u: Vec<Arc<Vec<f32>>> =
            vec![Arc::new((0..rows[0].len()).map(|k| 0.1 * k as f32 - 0.2).collect())];
        let g_full = c.grad(&u, &rows).unwrap();
        let r1 = c.layout.block_cols(1);

        let mut late = LateSet::default();
        let mut stats = QuorumStats::default();
        let mask = vec![true, false];
        let mut g_q = Vec::new();
        let mut ctx = QuorumCtx {
            mask: &mask,
            iter: 0,
            max_staleness_iters: 2,
            inv_d: 0.2,
            late: &mut late,
            stats: &mut stats,
        };
        c.grad_quorum_into(&u, None, &rows, &mut g_q, &mut ctx).unwrap();
        assert_eq!(g_q[..r1.start], g_full[..r1.start], "member block scattered as usual");
        assert!(g_q[r1.clone()].iter().all(|&v| v == 0.0), "parked block stays zero");
        assert_eq!(late.len(), 1);
        let LateSlice::Grad { ref cols, ref data, inv_d } = late.entries[0].slice else {
            panic!("grad slice")
        };
        assert_eq!(*cols, (r1.start as u32..r1.end as u32).collect::<Vec<u32>>());
        assert_eq!(*data, g_full[r1.clone()], "single partition: slice == assembled block");
        crate::assert_close!(inv_d, 0.2, 1e-12, 1e-12);
        let parked = data.clone();

        // fold one iteration later: µ gains weight · inv_d₀ · v
        let mut mu = vec![0.0f32; 8];
        let mut touched = Vec::new();
        let (folds, drops) =
            late.fold_grad_into(1, 2, &mut mu, |cols, w| touched.push((cols.len(), w)));
        assert_eq!((folds, drops), (1, 0));
        assert!(late.is_empty());
        assert_eq!(touched, vec![(r1.len(), 0.5)]);
        let scale = 0.5f32 * 0.2f32;
        for (k, gi) in r1.clone().enumerate() {
            assert_eq!(mu[gi], scale * parked[k]);
        }
        assert!(mu[..r1.start].iter().all(|&v| v == 0.0));

        // a reply older than the bound is dropped, not folded
        let mut stats = QuorumStats::default();
        let mut ctx = QuorumCtx {
            mask: &mask,
            iter: 0,
            max_staleness_iters: 2,
            inv_d: 0.2,
            late: &mut late,
            stats: &mut stats,
        };
        c.grad_quorum_into(&u, None, &rows, &mut g_q, &mut ctx).unwrap();
        let mut mu = vec![0.0f32; 8];
        let (folds, drops) = late.fold_grad_into(5, 2, &mut mu, |_, _| panic!("must not fold"));
        assert_eq!((folds, drops), (0, 1));
        assert!(mu.iter().all(|&v| v == 0.0));
        assert!(late.is_empty());
    }

    #[test]
    fn late_set_json_round_trips() {
        let mut set = LateSet::default();
        set.entries.push(LateReply {
            iter: 3,
            worker: 5,
            slice: LateSlice::Mu { p: 1, part: vec![0.5, -1.25, 3.0] },
        });
        set.entries.push(LateReply {
            iter: 4,
            worker: 2,
            slice: LateSlice::Grad { cols: vec![7, 9], data: vec![0.125, -2.5], inv_d: 0.0125 },
        });
        let text = set.to_json_value().to_string_pretty();
        let back =
            LateSet::from_json_value(&crate::util::json::Value::parse(&text).unwrap()).unwrap();
        assert_eq!(set, back);
        let empty = crate::util::json::Value::Arr(vec![]);
        assert_eq!(LateSet::from_json_value(&empty).unwrap(), LateSet::default());
    }
}
