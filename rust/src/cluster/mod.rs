//! Simulated doubly distributed cluster: one leader (the caller) and
//! `P×Q` persistent worker threads, message-passing only.
//!
//! Each worker owns its shard `x^{p,q}` outright (the leader never
//! touches block data after launch — exactly the paper's Spark layout
//! where partitions live on executors) plus a shared [`ComputeEngine`].
//! The leader orchestrates the three phases of Algorithm 1 through typed
//! commands and collects replies over a single mpsc channel; the
//! [`simnet::SimNet`] cost model charges each phase (see
//! [`simnet::CostModel`] and the README's "Steady-state memory"
//! section).
//!
//! ## Steady-state memory
//!
//! After warm-up the message protocol allocates nothing per phase:
//!
//! * every command that produces a vector reply carries a **recycled
//!   buffer** popped from the leader-side pool; the worker fills it via
//!   the engine's `_into` entry point and ships it back, and the leader
//!   returns it to the pool once the reduce has consumed it — buffers
//!   endlessly circulate leader → worker → leader;
//! * each worker holds **persistent scratch** (the margin buffer for
//!   fused objective evaluations, the working iterate of the averaged
//!   SVRG combiner) that lives as long as the thread;
//! * the leader keeps its own reduce workspaces (reply staging slots,
//!   the `z` accumulator and `y`-gather buffers of the `Q > 1` paths,
//!   the SVRG task-routing table) in a [`RefCell`], so every phase
//!   method stays `&self`.
//!
//! Pooling only recycles allocations — reduce orders are unchanged, so
//! trajectories are bit-for-bit identical to the fresh-allocation path
//! (`tests/alloc_regression.rs` pins both properties).
//!
//! ## Sampled-width phases
//!
//! The µ^t-estimate phases come in two flavors: the frozen full-width
//! commands (`cols: None` — RADiSA, `|B| == M`) and the sampled-width
//! ones ([`Cluster::partial_u_cols_into`], [`Cluster::grad_cols_into`]),
//! whose commands carry sorted block-local id lists of `B^t ∩ block` /
//! `C^t ∩ block` plus **compact** payloads — the `w` slice and the
//! gradient reply are exactly as long as the intersection, so wire
//! bytes and worker FLOPs scale with the sampled widths the SimNet
//! cost model charges (README "Sampled-width execution").

pub mod simnet;

pub use simnet::{CostModel, SimNet};

use std::cell::RefCell;
use std::ops::Range;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;

use crate::data::{Block, Grid, Layout};
use crate::engine::{BlockKey, ComputeEngine};
use crate::loss::Loss;
use crate::util::arc_mut;

/// Commands the leader sends to a worker. `buf` fields are recycled
/// reply buffers from the leader pool (arbitrary stale contents; the
/// worker clears and refills them). `cols` fields carry the sampled
/// sets as **sorted block-local column id lists**: `Some(ids)` selects
/// the sampled-width engine entry points with a **compact** `w`/reply
/// payload (length `|ids|`, not the zero-padded block width); `None` is
/// the frozen full-width path (RADiSA, `|B| == M`).
enum Cmd {
    /// z_part = X[rows, cols] · w — `cols: None`: w pre-masked by B^t,
    /// full block width; `cols: Some`: compact w over B^t ∩ block
    PartialZ { w: Arc<Vec<f32>>, cols: Option<Arc<Vec<u32>>>, rows: Arc<Vec<u32>>, buf: Vec<f32> },
    /// u = f'(X[rows, cols]·w, y[rows]) — fused margin + loss derivative
    /// (batched `partial_u` engine entry point); only dispatched on
    /// Q = 1 grids, where the block holds the complete margin
    PartialU { w: Arc<Vec<f32>>, cols: Option<Arc<Vec<u32>>>, rows: Arc<Vec<u32>>, buf: Vec<f32> },
    /// Σ_rows f(X[rows, :]·w, y[rows]) — fused objective term
    /// (batched `block_loss` engine entry point); Q = 1 grids only
    BlockLoss { w: Arc<Vec<f32>>, rows: Arc<Vec<u32>> },
    /// g = Σ_rows u·x_row — full block width (`cols: None`) or the
    /// compact C^t ∩ block slice (`cols: Some`, reply length `|ids|`)
    GradSlice { u: Arc<Vec<f32>>, cols: Option<Arc<Vec<u32>>>, rows: Arc<Vec<u32>>, buf: Vec<f32> },
    /// L SVRG steps on the sub-block `cols` (block-local range). The
    /// worker slices its `gcols` window out of the shared full-model
    /// `w`/`mu` snapshots (one allocation-free Arc clone per task
    /// instead of three owned copies); `avg` selects RADiSA-avg's
    /// suffix-averaged combiner. `idx` rides back with the reply so its
    /// buffer recycles too.
    Svrg {
        cols: Range<usize>,
        gcols: Range<usize>,
        w: Arc<Vec<f32>>,
        mu: Arc<Vec<f32>>,
        idx: Vec<u32>,
        gamma: f32,
        avg: bool,
        buf: Vec<f32>,
    },
    Shutdown,
}

/// Worker replies (tagged with the worker's linear id by the channel).
enum Reply {
    Z(Vec<f32>),
    U(Vec<f32>),
    Loss(f64),
    Grad(Vec<f32>),
    W { w: Vec<f32>, idx: Vec<u32> },
}

struct Worker {
    p: usize,
    q: usize,
    block: Block,
    engine: Arc<dyn ComputeEngine>,
    loss: Loss,
    /// persistent per-thread scratch: the fused objective evaluation's
    /// margin buffer and the averaged SVRG combiner's working iterate
    scratch: Vec<f32>,
}

impl Worker {
    fn run(mut self, rx: Receiver<Cmd>, tx: Sender<(usize, Reply)>, id: usize) {
        let key = BlockKey { p: self.p, q: self.q };
        let m = self.block.x.cols();
        while let Ok(cmd) = rx.recv() {
            let reply = match cmd {
                Cmd::PartialZ { w, cols, rows, mut buf } => {
                    match &cols {
                        Some(ids) => self
                            .engine
                            .partial_z_cols_into(key, &self.block.x, ids, &w, &rows, &mut buf),
                        None => {
                            self.engine.partial_z_into(key, &self.block.x, 0..m, &w, &rows, &mut buf)
                        }
                    }
                    Reply::Z(buf)
                }
                Cmd::PartialU { w, cols, rows, mut buf } => {
                    match &cols {
                        Some(ids) => self.engine.partial_u_cols_into(
                            key,
                            self.loss,
                            &self.block.x,
                            ids,
                            &w,
                            &rows,
                            &self.block.y,
                            &mut buf,
                        ),
                        None => self.engine.partial_u_into(
                            key,
                            self.loss,
                            &self.block.x,
                            0..m,
                            &w,
                            &rows,
                            &self.block.y,
                            &mut buf,
                        ),
                    }
                    Reply::U(buf)
                }
                Cmd::BlockLoss { w, rows } => Reply::Loss(self.engine.block_loss_scratch(
                    key,
                    self.loss,
                    &self.block.x,
                    0..m,
                    &w,
                    &rows,
                    &self.block.y,
                    &mut self.scratch,
                )),
                Cmd::GradSlice { u, cols, rows, mut buf } => {
                    match &cols {
                        Some(ids) => {
                            self.engine.grad_cols_into(key, &self.block.x, ids, &rows, &u, &mut buf)
                        }
                        None => {
                            self.engine.grad_slice_into(key, &self.block.x, 0..m, &rows, &u, &mut buf)
                        }
                    }
                    Reply::Grad(buf)
                }
                Cmd::Svrg { cols, gcols, w, mu, idx, gamma, avg, mut buf } => {
                    debug_assert_eq!(gcols.len(), cols.len(), "snapshot window ≠ sub-block");
                    let e = &self.engine;
                    let (x, y) = (&self.block.x, &self.block.y);
                    // w^t is both the starting iterate w⁰ and the SVRG
                    // reference w̃ (each sub-epoch starts at the
                    // reference point)
                    let w0 = &w[gcols.clone()];
                    let mu_s = &mu[gcols];
                    if avg {
                        e.svrg_inner_avg_into(
                            key,
                            self.loss,
                            x,
                            y,
                            cols,
                            w0,
                            w0,
                            mu_s,
                            &idx,
                            gamma,
                            &mut buf,
                            &mut self.scratch,
                        );
                    } else {
                        e.svrg_inner_into(
                            key, self.loss, x, y, cols, w0, w0, mu_s, &idx, gamma, &mut buf,
                        );
                    }
                    Reply::W { w: buf, idx }
                }
                Cmd::Shutdown => break,
            };
            if tx.send((id, reply)).is_err() {
                break;
            }
        }
    }
}

/// One SVRG assignment for the inner-loop phase.
pub struct SvrgTask {
    pub p: usize,
    pub q: usize,
    /// block-local column range — `Layout::sub_cols(q, k)` for every
    /// algorithm (widths are per-block ragged); RADiSA-avg differs only
    /// in the `avg` combiner below, not in the columns it owns
    pub cols: Range<usize>,
    /// global column range of the same sub-block — the window the worker
    /// slices out of the snapshots below
    pub gcols: Range<usize>,
    /// full-model snapshot of ω^t, shared by every task of the phase
    /// (serves as both w⁰ and the SVRG reference w̃)
    pub w: Arc<Vec<f32>>,
    /// full-model µ^t snapshot, shared by every task of the phase
    pub mu: Arc<Vec<f32>>,
    /// pre-sampled local row per inner step (per-task; the buffer is
    /// recycled through the leader pool — see
    /// [`Cluster::recycled_idx_buf`])
    pub idx: Vec<u32>,
    pub gamma: f32,
    /// use the suffix-averaged combiner (RADiSA-avg)
    pub avg: bool,
}

/// Leader-side recycled state: the reply-buffer pools plus the reduce
/// workspaces of the `&self` phase methods. Behind a [`RefCell`] — the
/// leader is single-threaded (the mpsc `Receiver` already pins
/// [`Cluster`] to one thread) and no phase method re-enters another
/// while holding a borrow.
struct LeaderScratch {
    /// drained f32 reply buffers, handed back out with the next commands
    f32_pool: Vec<Vec<f32>>,
    /// drained SVRG `idx` payload buffers (see [`Cluster::recycled_idx_buf`])
    idx_pool: Vec<Vec<u32>>,
    /// per-worker reply staging slots (fixed `P·Q` length) for reduces
    /// that must run in worker-id order
    slots: Vec<Option<Vec<f32>>>,
    /// worker id → task index routing of the in-flight SVRG phase
    /// (fixed `P·Q` length, `usize::MAX` = free)
    id_to_task: Vec<usize>,
    /// per-partition objective terms of the fused `Q == 1` loss phase
    loss_parts: Vec<f64>,
    /// per-partition reduced margins of the `Q > 1` paths
    z: Vec<Vec<f32>>,
    /// label gather buffer of the `Q > 1` dloss/loss passes
    y_rows: Vec<f32>,
}

/// Handle to the launched cluster (leader side).
pub struct Cluster {
    pub p: usize,
    pub q: usize,
    /// the grid's partition geometry (ragged boundary vectors) — the
    /// leader's only source of block dims after blocks move to workers
    pub layout: Layout,
    /// labels per observation partition (leader copy, for dloss/loss)
    pub y: Vec<Vec<f32>>,
    /// density (nnz fraction) per worker `[p][q]`, for the cost model
    pub density: Vec<f64>,
    cmd_txs: Vec<Sender<Cmd>>,
    reply_rx: Receiver<(usize, Reply)>,
    handles: Vec<JoinHandle<()>>,
    scratch: RefCell<LeaderScratch>,
}

impl Cluster {
    /// Move the grid's blocks into worker threads.
    pub fn launch(grid: Grid, engine: Arc<dyn ComputeEngine>, loss: Loss) -> Cluster {
        let layout = grid.layout.clone();
        let (p, q) = (layout.p, layout.q);
        let y: Vec<Vec<f32>> = (0..p).map(|pi| grid.block(pi, 0).y.clone()).collect();
        let density: Vec<f64> = grid
            .blocks()
            .map(|b| b.x.nnz() as f64 / (b.x.rows() as f64 * b.x.cols() as f64).max(1.0))
            .collect();

        let (reply_tx, reply_rx) = channel();
        let mut cmd_txs = Vec::with_capacity(p * q);
        let mut handles = Vec::with_capacity(p * q);
        // Grid stores blocks row-major [p][q]; consume it in that order.
        let mut blocks: Vec<Block> = Vec::with_capacity(p * q);
        for pi in 0..p {
            for qi in 0..q {
                blocks.push(grid.block(pi, qi).clone());
            }
        }
        for (id, block) in blocks.into_iter().enumerate() {
            let (tx, rx) = channel();
            cmd_txs.push(tx);
            let worker = Worker {
                p: block.p,
                q: block.q,
                block,
                engine: Arc::clone(&engine),
                loss,
                scratch: Vec::new(),
            };
            let reply = reply_tx.clone();
            handles.push(
                std::thread::Builder::new()
                    .name(format!("worker-{id}"))
                    .spawn(move || worker.run(rx, reply, id))
                    .expect("spawn worker"),
            );
        }
        let scratch = RefCell::new(LeaderScratch {
            f32_pool: Vec::new(),
            idx_pool: Vec::new(),
            slots: (0..p * q).map(|_| None).collect(),
            id_to_task: vec![usize::MAX; p * q],
            loss_parts: Vec::new(),
            z: Vec::new(),
            y_rows: Vec::new(),
        });
        Cluster { p, q, layout, y, density, cmd_txs, reply_rx, handles, scratch }
    }

    #[inline]
    fn wid(&self, p: usize, q: usize) -> usize {
        p * self.q + q
    }

    pub fn density_at(&self, p: usize, q: usize) -> f64 {
        self.density[self.wid(p, q)]
    }

    /// Pop a recycled SVRG `idx` buffer (returned to the pool by
    /// [`Cluster::svrg_run`] after each phase); fresh when the pool is
    /// dry. Callers fill it and hand it back through [`SvrgTask::idx`].
    pub fn recycled_idx_buf(&self) -> Vec<u32> {
        self.scratch.borrow_mut().idx_pool.pop().unwrap_or_default()
    }

    /// Drop every pooled buffer and leader workspace, forcing the next
    /// phases back onto the cold (fresh-allocation) path. Numbers are
    /// unaffected — pooling only recycles allocations; the
    /// alloc-regression harness uses this to measure pooled vs fresh on
    /// the very same session.
    pub fn drop_scratch(&self) {
        let mut s = self.scratch.borrow_mut();
        s.f32_pool = Vec::new();
        s.idx_pool = Vec::new();
        s.loss_parts = Vec::new();
        s.z = Vec::new();
        s.y_rows = Vec::new();
        // slots / id_to_task keep their fixed P·Q length (allocated at
        // launch, content-free between phases)
    }

    /// Phase 1 of the µ^t estimate: partial margins, reduced over feature
    /// partitions. `w_blocks[q]` is the (masked) parameter slice of block
    /// q; `rows[p]` the sampled local row ids of partition p. Returns
    /// `z[p][k] = x_{rows[p][k]}^{B} · w_B`.
    pub fn partial_z(&self, w_blocks: &[Arc<Vec<f32>>], rows: &[Arc<Vec<u32>>]) -> Vec<Vec<f32>> {
        let mut z = Vec::new();
        self.partial_z_into(w_blocks, rows, &mut z);
        z
    }

    /// In-place [`Cluster::partial_z`]: refills the caller's per-partition
    /// buffers (allocation-free once warm). Replies are staged by worker
    /// id and reduced in a fixed order — f32 addition is non-associative
    /// and runs must be reproducible — exactly like the allocating path.
    pub fn partial_z_into(
        &self,
        w_blocks: &[Arc<Vec<f32>>],
        rows: &[Arc<Vec<u32>>],
        z: &mut Vec<Vec<f32>>,
    ) {
        self.partial_z_impl(w_blocks, None, rows, z)
    }

    /// Sampled-width [`Cluster::partial_z_into`]: `bcols[q]` is the
    /// sorted block-local id list of `B^t ∩ block q` and `w_blocks[q]`
    /// the matching **compact** parameter slice
    /// (`w_blocks[q].len() == bcols[q].len()`), so the wire carries
    /// O(|B∩block|) floats per worker and the workers do
    /// O(rows·|B∩block|) work. Reduce order is identical to the
    /// full-width path, so the sampled path is deterministic.
    pub fn partial_z_cols_into(
        &self,
        w_blocks: &[Arc<Vec<f32>>],
        bcols: &[Arc<Vec<u32>>],
        rows: &[Arc<Vec<u32>>],
        z: &mut Vec<Vec<f32>>,
    ) {
        self.partial_z_impl(w_blocks, Some(bcols), rows, z)
    }

    fn partial_z_impl(
        &self,
        w_blocks: &[Arc<Vec<f32>>],
        bcols: Option<&[Arc<Vec<u32>>]>,
        rows: &[Arc<Vec<u32>>],
        z: &mut Vec<Vec<f32>>,
    ) {
        let mut s = self.scratch.borrow_mut();
        for pi in 0..self.p {
            for qi in 0..self.q {
                if let Some(bc) = bcols {
                    debug_assert_eq!(
                        w_blocks[qi].len(),
                        bc[qi].len(),
                        "compact w payload must match its id list"
                    );
                }
                let buf = s.f32_pool.pop().unwrap_or_default();
                self.cmd_txs[self.wid(pi, qi)]
                    .send(Cmd::PartialZ {
                        w: Arc::clone(&w_blocks[qi]),
                        cols: bcols.map(|bc| Arc::clone(&bc[qi])),
                        rows: Arc::clone(&rows[pi]),
                        buf,
                    })
                    .expect("worker alive");
            }
        }
        for _ in 0..self.p * self.q {
            let (id, reply) = self.reply_rx.recv().expect("worker alive");
            let Reply::Z(part) = reply else { panic!("expected Z reply") };
            debug_assert!(s.slots[id].is_none(), "duplicate Z reply from worker {id}");
            s.slots[id] = Some(part);
        }
        z.resize_with(self.p, Vec::new);
        for (pi, zp) in z.iter_mut().enumerate() {
            zp.clear();
            zp.resize(rows[pi].len(), 0.0);
        }
        for id in 0..self.p * self.q {
            let part = s.slots[id].take().expect("reply staged");
            let pi = id / self.q;
            for (acc, &v) in z[pi].iter_mut().zip(&part) {
                *acc += v;
            }
            s.f32_pool.push(part);
        }
    }

    /// Phase-1 derivative `u[p][k] = f'(z_k, y_k)`. On single-feature-
    /// block grids (`Q == 1`) each block already holds the complete
    /// margin, so workers compute `u` locally through the engines' fused
    /// batched `partial_u` entry point — no leader-side z reduce + dloss
    /// round. On `Q > 1` grids the margins are reduced across feature
    /// blocks here and `leader` applies the derivative; both paths
    /// produce bit-identical numbers.
    pub fn partial_u(
        &self,
        w_blocks: &[Arc<Vec<f32>>],
        rows: &[Arc<Vec<u32>>],
        leader: &dyn ComputeEngine,
        loss: Loss,
    ) -> Vec<Vec<f32>> {
        let mut u = Vec::new();
        self.partial_u_into(w_blocks, rows, leader, loss, &mut u);
        // the Arcs are uniquely owned here (fresh vector, phase barrier
        // passed), so this unwraps without copying
        u.into_iter()
            .map(|a| Arc::try_unwrap(a).unwrap_or_else(|a| a.as_ref().clone()))
            .collect()
    }

    /// In-place [`Cluster::partial_u`]: refills the caller's recycled
    /// per-partition `Arc` buffers (the consumers — the gradient phase,
    /// the trainer workspace — hand these out by `Arc::clone`, and by
    /// the next iteration the clones are back to one owner; see
    /// [`crate::util::arc_mut`]). The `Q > 1` path reuses the leader's
    /// `z`/`y_rows` workspaces, with the dloss gather hoisted out of any
    /// per-partition closure.
    pub fn partial_u_into(
        &self,
        w_blocks: &[Arc<Vec<f32>>],
        rows: &[Arc<Vec<u32>>],
        leader: &dyn ComputeEngine,
        loss: Loss,
        u: &mut Vec<Arc<Vec<f32>>>,
    ) {
        self.partial_u_impl(w_blocks, None, rows, leader, loss, u)
    }

    /// Sampled-width [`Cluster::partial_u_into`]: compact `w_blocks`
    /// over the `bcols` id lists (see
    /// [`Cluster::partial_z_cols_into`]); both the `Q == 1` fused
    /// worker path and the `Q > 1` z-reduce path ship only the sampled
    /// widths.
    pub fn partial_u_cols_into(
        &self,
        w_blocks: &[Arc<Vec<f32>>],
        bcols: &[Arc<Vec<u32>>],
        rows: &[Arc<Vec<u32>>],
        leader: &dyn ComputeEngine,
        loss: Loss,
        u: &mut Vec<Arc<Vec<f32>>>,
    ) {
        self.partial_u_impl(w_blocks, Some(bcols), rows, leader, loss, u)
    }

    fn partial_u_impl(
        &self,
        w_blocks: &[Arc<Vec<f32>>],
        bcols: Option<&[Arc<Vec<u32>>]>,
        rows: &[Arc<Vec<u32>>],
        leader: &dyn ComputeEngine,
        loss: Loss,
        u: &mut Vec<Arc<Vec<f32>>>,
    ) {
        u.resize_with(self.p, Default::default);
        if self.q > 1 {
            let mut z = std::mem::take(&mut self.scratch.borrow_mut().z);
            self.partial_z_impl(w_blocks, bcols, rows, &mut z);
            let mut s = self.scratch.borrow_mut();
            let s = &mut *s;
            for (pi, up) in u.iter_mut().enumerate() {
                s.y_rows.clear();
                s.y_rows.extend(rows[pi].iter().map(|&r| self.y[pi][r as usize]));
                leader.dloss_u_into(loss, &z[pi], &s.y_rows, arc_mut(up));
            }
            s.z = z;
        } else {
            let mut s = self.scratch.borrow_mut();
            for pi in 0..self.p {
                let buf = s.f32_pool.pop().unwrap_or_default();
                self.cmd_txs[self.wid(pi, 0)]
                    .send(Cmd::PartialU {
                        w: Arc::clone(&w_blocks[0]),
                        cols: bcols.map(|bc| Arc::clone(&bc[0])),
                        rows: Arc::clone(&rows[pi]),
                        buf,
                    })
                    .expect("worker alive");
            }
            for _ in 0..self.p {
                // worker id == p index when q == 1; assignment (not
                // reduction), so arrival order cannot change results
                let (id, reply) = self.reply_rx.recv().expect("worker alive");
                let Reply::U(mut ub) = reply else { panic!("expected U reply") };
                std::mem::swap(arc_mut(&mut u[id]), &mut ub);
                s.f32_pool.push(ub);
            }
        }
    }

    /// Distributed objective term `Σ_k f(z_k, y_k)` over the given rows.
    /// `Q == 1` grids use the workers' fused `block_loss` entry point;
    /// `Q > 1` grids reduce z into the leader workspace and `leader` sums
    /// the loss values (gather buffer reused, loop hoisted). Either way
    /// the reduce runs in worker order, so the f64 total is
    /// deterministic — and the steady state allocates nothing.
    pub fn block_loss(
        &self,
        w_blocks: &[Arc<Vec<f32>>],
        rows: &[Arc<Vec<u32>>],
        leader: &dyn ComputeEngine,
        loss: Loss,
    ) -> f64 {
        if self.q > 1 {
            let mut z = std::mem::take(&mut self.scratch.borrow_mut().z);
            self.partial_z_into(w_blocks, rows, &mut z);
            let mut s = self.scratch.borrow_mut();
            let s = &mut *s;
            let mut total = 0.0f64;
            for (pi, zp) in z.iter().enumerate() {
                s.y_rows.clear();
                s.y_rows.extend(rows[pi].iter().map(|&r| self.y[pi][r as usize]));
                total += leader.loss_from_z(loss, zp, &s.y_rows);
            }
            s.z = z;
            return total;
        }
        let mut s = self.scratch.borrow_mut();
        for pi in 0..self.p {
            self.cmd_txs[self.wid(pi, 0)]
                .send(Cmd::BlockLoss { w: Arc::clone(&w_blocks[0]), rows: Arc::clone(&rows[pi]) })
                .expect("worker alive");
        }
        s.loss_parts.clear();
        s.loss_parts.resize(self.p, 0.0);
        for _ in 0..self.p {
            let (id, reply) = self.reply_rx.recv().expect("worker alive");
            let Reply::Loss(v) = reply else { panic!("expected Loss reply") };
            s.loss_parts[id] = v;
        }
        s.loss_parts.iter().sum()
    }

    /// Phase 2: gradient slices. `u[p]` aligned with `rows[p]`. Returns
    /// the global gradient-sum vector (length `m_total`), summed over
    /// observation partitions per feature block.
    pub fn grad(&self, u: &[Arc<Vec<f32>>], rows: &[Arc<Vec<u32>>]) -> Vec<f32> {
        let mut g = Vec::new();
        self.grad_into(u, rows, &mut g);
        g
    }

    /// In-place [`Cluster::grad`]: zeroes and refills the caller's
    /// buffer, assembling slices in worker-id order exactly like the
    /// allocating path (bit-for-bit).
    pub fn grad_into(&self, u: &[Arc<Vec<f32>>], rows: &[Arc<Vec<u32>>], g: &mut Vec<f32>) {
        self.grad_impl(u, None, rows, g)
    }

    /// Sampled-width [`Cluster::grad_into`]: workers return **compact**
    /// gradient slices over `ccols[q]` (the sorted block-local ids of
    /// `C^t ∩ block q`, reply length `|C∩block|` instead of the block
    /// width) and the leader scatters them into the full-length `g` at
    /// the global C^t offsets. `g` is zero outside C^t on return, i.e.
    /// already projected — callers skip the separate
    /// `project_inplace` pass. Assembly stays in worker-id order, so
    /// the sampled path is deterministic.
    pub fn grad_cols_into(
        &self,
        u: &[Arc<Vec<f32>>],
        ccols: &[Arc<Vec<u32>>],
        rows: &[Arc<Vec<u32>>],
        g: &mut Vec<f32>,
    ) {
        self.grad_impl(u, Some(ccols), rows, g)
    }

    fn grad_impl(
        &self,
        u: &[Arc<Vec<f32>>],
        ccols: Option<&[Arc<Vec<u32>>]>,
        rows: &[Arc<Vec<u32>>],
        g: &mut Vec<f32>,
    ) {
        let mut s = self.scratch.borrow_mut();
        for pi in 0..self.p {
            for qi in 0..self.q {
                let buf = s.f32_pool.pop().unwrap_or_default();
                self.cmd_txs[self.wid(pi, qi)]
                    .send(Cmd::GradSlice {
                        u: Arc::clone(&u[pi]),
                        cols: ccols.map(|cc| Arc::clone(&cc[qi])),
                        rows: Arc::clone(&rows[pi]),
                        buf,
                    })
                    .expect("worker alive");
            }
        }
        for _ in 0..self.p * self.q {
            let (id, reply) = self.reply_rx.recv().expect("worker alive");
            let Reply::Grad(slice) = reply else { panic!("expected Grad reply") };
            debug_assert!(s.slots[id].is_none(), "duplicate Grad reply from worker {id}");
            s.slots[id] = Some(slice);
        }
        g.clear();
        g.resize(self.layout.m_total, 0.0);
        for id in 0..self.p * self.q {
            let slice = s.slots[id].take().expect("reply staged");
            let qi = id % self.q;
            let base = self.layout.block_cols(qi).start;
            match ccols {
                Some(cc) => {
                    debug_assert_eq!(
                        slice.len(),
                        cc[qi].len(),
                        "compact grad reply must match its id list"
                    );
                    for (&ci, &v) in cc[qi].iter().zip(&slice) {
                        g[base + ci as usize] += v;
                    }
                }
                None => {
                    for (k, &v) in slice.iter().enumerate() {
                        g[base + k] += v;
                    }
                }
            }
            s.f32_pool.push(slice);
        }
    }

    /// Phase 3: the parallel inner loops. Returns `(task_index, w_L)` in
    /// completion order.
    pub fn svrg(&self, mut tasks: Vec<SvrgTask>) -> Vec<(usize, Vec<f32>)> {
        let mut out = Vec::with_capacity(tasks.len());
        self.svrg_run(&mut tasks, |ti, w| out.push((ti, w.to_vec())));
        out
    }

    /// Pooled [`Cluster::svrg`]: drains `tasks` (the vector keeps its
    /// capacity for the next iteration) and streams each finished
    /// sub-block through `apply(task_index, w_L)` in completion order.
    /// Reply and `idx` buffers go back to the pools, so a steady-state
    /// phase allocates nothing. Completion order is non-deterministic,
    /// but tasks own disjoint column ranges, so any write-back through
    /// `apply` lands bit-identically.
    pub fn svrg_run(&self, tasks: &mut Vec<SvrgTask>, mut apply: impl FnMut(usize, &[f32])) {
        let n = tasks.len();
        {
            let mut s = self.scratch.borrow_mut();
            for (ti, t) in tasks.drain(..).enumerate() {
                let wid = self.wid(t.p, t.q);
                assert_eq!(s.id_to_task[wid], usize::MAX, "one task per worker per phase");
                s.id_to_task[wid] = ti;
                let buf = s.f32_pool.pop().unwrap_or_default();
                self.cmd_txs[wid]
                    .send(Cmd::Svrg {
                        cols: t.cols,
                        gcols: t.gcols,
                        w: t.w,
                        mu: t.mu,
                        idx: t.idx,
                        gamma: t.gamma,
                        avg: t.avg,
                        buf,
                    })
                    .expect("worker alive");
            }
        }
        for _ in 0..n {
            let (id, reply) = self.reply_rx.recv().expect("worker alive");
            let Reply::W { w, idx } = reply else { panic!("expected W reply") };
            // release the scratch borrow before the callback runs —
            // `apply` is caller code and may legitimately re-enter the
            // cluster (e.g. `recycled_idx_buf` to prep the next phase)
            let ti = {
                let mut s = self.scratch.borrow_mut();
                let ti = s.id_to_task[id];
                s.id_to_task[id] = usize::MAX;
                s.idx_pool.push(idx);
                ti
            };
            apply(ti, &w);
            self.scratch.borrow_mut().f32_pool.push(w);
        }
    }
}

impl Drop for Cluster {
    fn drop(&mut self) {
        for tx in &self.cmd_txs {
            let _ = tx.send(Cmd::Shutdown);
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::engine::NativeEngine;
    use crate::util::testing::assert_close_slice;

    fn cluster(n: usize, m: usize, p: usize, q: usize, seed: u64) -> (Cluster, crate::data::Dataset) {
        let ds = synth::dense_zhang(n, m, seed);
        let grid = Grid::partition(&ds, p, q).unwrap();
        let c = Cluster::launch(grid, Arc::new(NativeEngine), Loss::Hinge);
        (c, ds)
    }

    #[test]
    fn partial_z_matches_serial_matvec() {
        let (c, ds) = cluster(30, 12, 3, 2, 1);
        let w: Vec<f32> = (0..12).map(|i| 0.1 * i as f32 - 0.5).collect();
        let w_blocks: Vec<Arc<Vec<f32>>> =
            (0..2).map(|qi| Arc::new(w[qi * 6..(qi + 1) * 6].to_vec())).collect();
        let rows: Vec<Arc<Vec<u32>>> = (0..3).map(|_| Arc::new((0..10u32).collect())).collect();
        let z = c.partial_z(&w_blocks, &rows);
        for pi in 0..3 {
            for k in 0..10 {
                let gr = pi * 10 + k;
                let want = ds.x.row_dot_range(gr, 0, 12, &w);
                crate::assert_close!(z[pi][k], want, 1e-4, 1e-4);
            }
        }
    }

    #[test]
    fn pooled_phases_are_bit_identical_across_reuse() {
        // the same phase run again on a warm pool (recycled buffers) and
        // again after dropping every pooled buffer must not change bits
        let (c, _ds) = cluster(30, 12, 3, 2, 10);
        let w: Vec<f32> = (0..12).map(|i| (i as f32 * 0.37).sin() * 0.4).collect();
        let w_blocks: Vec<Arc<Vec<f32>>> =
            (0..2).map(|qi| Arc::new(w[qi * 6..(qi + 1) * 6].to_vec())).collect();
        let rows: Vec<Arc<Vec<u32>>> = (0..3).map(|_| Arc::new(vec![0u32, 2, 5, 9])).collect();
        let cold_z = c.partial_z(&w_blocks, &rows);
        let warm_z = c.partial_z(&w_blocks, &rows);
        assert_eq!(cold_z, warm_z);
        let cold_u = c.partial_u(&w_blocks, &rows, &NativeEngine, Loss::Hinge);
        let warm_u = c.partial_u(&w_blocks, &rows, &NativeEngine, Loss::Hinge);
        assert_eq!(cold_u, warm_u);
        let cold_l = c.block_loss(&w_blocks, &rows, &NativeEngine, Loss::Hinge);
        let warm_l = c.block_loss(&w_blocks, &rows, &NativeEngine, Loss::Hinge);
        assert_eq!(cold_l, warm_l);
        c.drop_scratch();
        assert_eq!(c.partial_z(&w_blocks, &rows), cold_z);
        assert_eq!(c.partial_u(&w_blocks, &rows, &NativeEngine, Loss::Hinge), cold_u);
        assert_eq!(c.block_loss(&w_blocks, &rows, &NativeEngine, Loss::Hinge), cold_l);
    }

    #[test]
    fn reply_buffers_return_to_the_pool() {
        let (c, _ds) = cluster(20, 8, 2, 2, 11);
        let w: Vec<f32> = (0..8).map(|i| 0.1 * i as f32).collect();
        let w_blocks: Vec<Arc<Vec<f32>>> =
            (0..2).map(|qi| Arc::new(w[qi * 4..(qi + 1) * 4].to_vec())).collect();
        let rows: Vec<Arc<Vec<u32>>> = (0..2).map(|_| Arc::new(vec![0u32, 3])).collect();
        let _ = c.partial_z(&w_blocks, &rows);
        assert_eq!(c.scratch.borrow().f32_pool.len(), 4, "all 4 reply buffers recycled");
        let _ = c.partial_z(&w_blocks, &rows);
        assert_eq!(c.scratch.borrow().f32_pool.len(), 4, "pool does not grow on reuse");
    }

    /// Split sorted global column ids into per-block (local ids, compact
    /// w) pairs — the leader-side prep the trainer does before a sampled
    /// phase.
    fn split_cols(
        c: &Cluster,
        ids: &[u32],
        w: &[f32],
    ) -> (Vec<Arc<Vec<u32>>>, Vec<Arc<Vec<f32>>>) {
        let mut cols = Vec::new();
        let mut ws = Vec::new();
        for qi in 0..c.q {
            let r = c.layout.block_cols(qi);
            let local: Vec<u32> = ids
                .iter()
                .filter(|&&i| (i as usize) >= r.start && (i as usize) < r.end)
                .map(|&i| i - r.start as u32)
                .collect();
            ws.push(Arc::new(local.iter().map(|&l| w[r.start + l as usize]).collect::<Vec<f32>>()));
            cols.push(Arc::new(local));
        }
        (cols, ws)
    }

    #[test]
    fn sampled_phases_match_masked_full_width() {
        let (c, _ds) = cluster(30, 12, 3, 2, 12);
        let w: Vec<f32> = (0..12).map(|i| (i as f32 * 0.29).sin() * 0.5).collect();
        // B = {1, 3, 6, 7, 11} spans both blocks; C = {3, 7} ⊂ B
        let b_ids = [1u32, 3, 6, 7, 11];
        let c_ids = [3u32, 7];
        let rows: Vec<Arc<Vec<u32>>> = (0..3).map(|_| Arc::new(vec![0u32, 2, 5, 9])).collect();
        let (bcols, w_compact) = split_cols(&c, &b_ids, &w);
        // masked reference: full-width blocks of w ∘ 1_B
        let mut w_masked = vec![0.0f32; 12];
        for &i in &b_ids {
            w_masked[i as usize] = w[i as usize];
        }
        let w_blocks: Vec<Arc<Vec<f32>>> =
            (0..2).map(|qi| Arc::new(w_masked[c.layout.block_cols(qi)].to_vec())).collect();

        let mut z_sampled = Vec::new();
        c.partial_z_cols_into(&w_compact, &bcols, &rows, &mut z_sampled);
        let z_full = c.partial_z(&w_blocks, &rows);
        for (zs, zf) in z_sampled.iter().zip(&z_full) {
            assert_close_slice(zs, zf, 1e-5, 1e-6, "sampled z vs masked z");
        }

        let mut u_sampled = Vec::new();
        c.partial_u_cols_into(&w_compact, &bcols, &rows, &NativeEngine, Loss::Hinge, &mut u_sampled);
        let u_full = c.partial_u(&w_blocks, &rows, &NativeEngine, Loss::Hinge);
        for (us, uf) in u_sampled.iter().zip(&u_full) {
            assert_close_slice(us, uf, 1e-5, 1e-6, "sampled u vs masked u");
        }

        let (ccols, _) = split_cols(&c, &c_ids, &w);
        let u_arcs: Vec<Arc<Vec<f32>>> =
            u_full.iter().map(|up| Arc::new(up.clone())).collect();
        let mut g_sampled = Vec::new();
        c.grad_cols_into(&u_arcs, &ccols, &rows, &mut g_sampled);
        let g_full = c.grad(&u_arcs, &rows);
        assert_eq!(g_sampled.len(), 12, "sampled g is full-length, projected");
        for i in 0..12u32 {
            if c_ids.contains(&i) {
                crate::assert_close!(g_sampled[i as usize], g_full[i as usize], 1e-5, 1e-6);
            } else {
                assert_eq!(g_sampled[i as usize], 0.0, "coordinate {i} outside C must be zero");
            }
        }
    }

    #[test]
    fn sampled_phases_are_deterministic_and_pool_friendly() {
        // rerun on warm pools and after dropping scratch: identical bits
        let (c, _ds) = cluster(21, 9, 2, 2, 13);
        let w: Vec<f32> = (0..9).map(|i| 0.07 * i as f32 - 0.3).collect();
        // C ⊄ block 0: every sampled id lands in block 1 — block 0's
        // intersection is empty (zero-length payloads must be fine)
        let b_ids = [5u32, 6, 8];
        let rows: Vec<Arc<Vec<u32>>> =
            (0..2).map(|pi| Arc::new((0..c.layout.rows_in(pi) as u32).collect())).collect();
        let (bcols, w_compact) = split_cols(&c, &b_ids, &w);
        assert!(bcols[0].is_empty(), "test premise: empty intersection in block 0");
        let mut cold = Vec::new();
        c.partial_u_cols_into(&w_compact, &bcols, &rows, &NativeEngine, Loss::Hinge, &mut cold);
        let mut warm = Vec::new();
        c.partial_u_cols_into(&w_compact, &bcols, &rows, &NativeEngine, Loss::Hinge, &mut warm);
        let cold_v: Vec<Vec<f32>> = cold.iter().map(|a| a.as_ref().clone()).collect();
        let warm_v: Vec<Vec<f32>> = warm.iter().map(|a| a.as_ref().clone()).collect();
        assert_eq!(cold_v, warm_v);
        let u_arcs = cold;
        let (ccols, _) = split_cols(&c, &b_ids, &w);
        let mut g1 = Vec::new();
        c.grad_cols_into(&u_arcs, &ccols, &rows, &mut g1);
        let mut g2 = Vec::new();
        c.grad_cols_into(&u_arcs, &ccols, &rows, &mut g2);
        assert_eq!(g1, g2);
        c.drop_scratch();
        let mut g3 = Vec::new();
        c.grad_cols_into(&u_arcs, &ccols, &rows, &mut g3);
        assert_eq!(g1, g3, "pooled vs fresh sampled grad must not change bits");
    }

    #[test]
    fn sampled_fused_q1_matches_reduce_path() {
        // Q = 1: the fused on-worker subset partial_u vs manual subset
        // z + leader dloss
        let (c, _ds) = cluster(30, 12, 3, 1, 14);
        let w: Vec<f32> = (0..12).map(|i| 0.04 * i as f32 - 0.2).collect();
        let b_ids = [0u32, 2, 3, 9];
        let rows: Vec<Arc<Vec<u32>>> = (0..3).map(|_| Arc::new((0..10u32).collect())).collect();
        let (bcols, w_compact) = split_cols(&c, &b_ids, &w);
        let mut u = Vec::new();
        c.partial_u_cols_into(&w_compact, &bcols, &rows, &NativeEngine, Loss::Hinge, &mut u);
        let mut z = Vec::new();
        c.partial_z_cols_into(&w_compact, &bcols, &rows, &mut z);
        for pi in 0..3 {
            for k in 0..10 {
                let want = Loss::Hinge.dloss(z[pi][k], c.y[pi][k]);
                assert_eq!(u[pi][k], want, "p={pi} k={k}");
            }
        }
    }

    #[test]
    fn grad_matches_serial_rmatvec() {
        let (c, ds) = cluster(20, 8, 2, 2, 2);
        let rows: Vec<Arc<Vec<u32>>> = (0..2).map(|_| Arc::new((0..10u32).collect())).collect();
        let u: Vec<Arc<Vec<f32>>> =
            (0..2).map(|pi| Arc::new((0..10).map(|k| (pi * 10 + k) as f32 * 0.1).collect())).collect();
        let g = c.grad(&u, &rows);
        let mut want = vec![0.0f32; 8];
        for gr in 0..20 {
            let uv = gr as f32 * 0.1;
            let mut row = vec![0.0f32; 8];
            ds.x.copy_row_range(gr, 0, 8, &mut row);
            for cidx in 0..8 {
                want[cidx] += uv * row[cidx];
            }
        }
        assert_close_slice(&g, &want, 1e-3, 1e-3, "grad");
    }

    #[test]
    fn svrg_tasks_route_to_correct_workers() {
        let (c, _ds) = cluster(20, 8, 2, 2, 3);
        // zero gamma => w_L == w0, so routing shows through the snapshot
        // windows: block q=0 sub-block 0 is global cols 0..2, block q=1
        // sub-block 1 is global cols 6..8
        let w = Arc::new(vec![1.0f32, 2.0, 0.0, 0.0, 0.0, 0.0, 3.0, 4.0]);
        let mu = Arc::new(vec![0.0f32; 8]);
        let tasks = vec![
            SvrgTask {
                p: 0,
                q: 0,
                cols: 0..2,
                gcols: 0..2,
                w: Arc::clone(&w),
                mu: Arc::clone(&mu),
                idx: vec![0; 4],
                gamma: 0.0,
                avg: false,
            },
            SvrgTask {
                p: 1,
                q: 1,
                cols: 2..4,
                gcols: 6..8,
                w,
                mu,
                idx: vec![0; 4],
                gamma: 0.0,
                avg: true,
            },
        ];
        let mut out = c.svrg(tasks);
        out.sort_by_key(|(ti, _)| *ti);
        assert_eq!(out[0].1, vec![1.0, 2.0]);
        assert_eq!(out[1].1, vec![3.0, 4.0]);
    }

    #[test]
    fn fused_partial_u_matches_z_then_dloss_on_q1() {
        let (c, _ds) = cluster(30, 12, 3, 1, 6);
        let w: Vec<f32> = (0..12).map(|i| 0.05 * i as f32 - 0.2).collect();
        let w_blocks = vec![Arc::new(w)];
        let rows: Vec<Arc<Vec<u32>>> = (0..3).map(|_| Arc::new((0..10u32).collect())).collect();
        let u = c.partial_u(&w_blocks, &rows, &NativeEngine, Loss::Hinge);
        let z = c.partial_z(&w_blocks, &rows);
        for pi in 0..3 {
            for k in 0..10 {
                let want = Loss::Hinge.dloss(z[pi][k], c.y[pi][k]);
                assert_eq!(u[pi][k], want, "p={pi} k={k}");
            }
        }
    }

    #[test]
    fn fused_block_loss_matches_serial_objective_on_q1() {
        let (c, ds) = cluster(30, 12, 3, 1, 7);
        let w: Vec<f32> = (0..12).map(|i| (i as f32 * 0.4).sin() * 0.3).collect();
        let w_blocks = vec![Arc::new(w.clone())];
        let rows: Vec<Arc<Vec<u32>>> = (0..3).map(|_| Arc::new((0..10u32).collect())).collect();
        let total = c.block_loss(&w_blocks, &rows, &NativeEngine, Loss::Hinge);
        crate::assert_close!(total / 30.0, ds.objective(&w, Loss::Hinge), 1e-4, 1e-5);
    }

    #[test]
    fn partial_u_reduce_path_matches_manual_composition_on_q2() {
        // Q > 1: partial_u must fall back to z-reduce + leader dloss,
        // bit-identical to composing the phases by hand
        let (c, _ds) = cluster(20, 8, 2, 2, 8);
        let w: Vec<f32> = (0..8).map(|i| (i as f32 * 0.3).cos() * 0.4).collect();
        let w_blocks: Vec<Arc<Vec<f32>>> =
            (0..2).map(|qi| Arc::new(w[qi * 4..(qi + 1) * 4].to_vec())).collect();
        let rows: Vec<Arc<Vec<u32>>> = (0..2).map(|_| Arc::new(vec![0u32, 3, 7])).collect();
        let u = c.partial_u(&w_blocks, &rows, &NativeEngine, Loss::Hinge);
        let z = c.partial_z(&w_blocks, &rows);
        for pi in 0..2 {
            let y_rows: Vec<f32> = rows[pi].iter().map(|&r| c.y[pi][r as usize]).collect();
            let want = NativeEngine.dloss_u(Loss::Hinge, &z[pi], &y_rows);
            assert_eq!(u[pi], want, "p={pi}");
        }
        let total = c.block_loss(&w_blocks, &rows, &NativeEngine, Loss::Hinge);
        let want: f64 = (0..2)
            .map(|pi| {
                let y_rows: Vec<f32> = rows[pi].iter().map(|&r| c.y[pi][r as usize]).collect();
                NativeEngine.loss_from_z(Loss::Hinge, &z[pi], &y_rows)
            })
            .sum();
        assert_eq!(total, want);
    }

    #[test]
    fn ragged_partial_z_and_grad_match_serial() {
        // 21 rows over P=2 (10/11), 9 cols over Q=2 (4/5): exercises the
        // boundary-offset assembly paths with genuinely uneven blocks
        let (c, ds) = cluster(21, 9, 2, 2, 9);
        let w: Vec<f32> = (0..9).map(|i| 0.1 * i as f32 - 0.3).collect();
        let w_blocks: Vec<Arc<Vec<f32>>> =
            (0..2).map(|qi| Arc::new(w[c.layout.block_cols(qi)].to_vec())).collect();
        let rows: Vec<Arc<Vec<u32>>> = (0..2)
            .map(|pi| Arc::new((0..c.layout.rows_in(pi) as u32).collect()))
            .collect();
        let z = c.partial_z(&w_blocks, &rows);
        for pi in 0..2 {
            assert_eq!(z[pi].len(), c.layout.rows_in(pi));
            for k in 0..c.layout.rows_in(pi) {
                let gr = c.layout.block_rows(pi).start + k;
                let want = ds.x.row_dot_range(gr, 0, 9, &w);
                crate::assert_close!(z[pi][k], want, 1e-4, 1e-4);
            }
        }
        let u: Vec<Arc<Vec<f32>>> = (0..2)
            .map(|pi| {
                let base = c.layout.block_rows(pi).start;
                Arc::new((0..c.layout.rows_in(pi)).map(|k| (base + k) as f32 * 0.1).collect())
            })
            .collect();
        let g = c.grad(&u, &rows);
        let mut want = vec![0.0f32; 9];
        for gr in 0..21 {
            let uv = gr as f32 * 0.1;
            let mut row = vec![0.0f32; 9];
            ds.x.copy_row_range(gr, 0, 9, &mut row);
            for (cidx, &xv) in row.iter().enumerate() {
                want[cidx] += uv * xv;
            }
        }
        assert_close_slice(&g, &want, 1e-3, 1e-3, "ragged grad");
    }

    #[test]
    fn density_is_one_for_dense() {
        let (c, _) = cluster(10, 4, 1, 2, 4);
        crate::assert_close!(c.density_at(0, 0), 1.0, 1e-9, 1e-9);
    }

    #[test]
    fn shutdown_is_clean() {
        let (c, _) = cluster(10, 4, 2, 2, 5);
        drop(c); // Drop joins all workers; hang = test timeout
    }
}
