//! SimNet: the deterministic cluster cost model.
//!
//! The paper's time axis is Spark wall-clock on a 4-node cluster; our
//! substitute charges every phase of the algorithm with an explicit,
//! reproducible model:
//!
//! * compute: `max_worker(flops_w / rate_w)` seconds — workers run in
//!   parallel at their profiled rates and the barrier waits for the
//!   slowest, exactly Spark's stage semantics. Under a uniform profile
//!   this is bit-identical to the historical single-rate charge
//!   (`max(f_w) / base == max(f_w / base)` exactly in IEEE-754, since
//!   division by one positive base is monotone).
//! * network: `total_bytes / bandwidth + 2·latency·link_mult` per
//!   barrier round — scatter + gather serialized through the leader's
//!   link like a Spark driver, with the round-trip waiting on the
//!   slowest worker's link (per-link skew collapses to its max at a
//!   barrier).
//!
//! The model's parameters arrive exclusively through the validated
//! config surface ([`ClusterProfile`] + [`NetworkConfig`]); the old
//! free-floating `CostModel` struct is gone, so an unvalidated rate
//! table can no longer reach the accounting. Being a *model* (instead
//! of wall-clock) keeps the figures independent of which engine
//! executes the kernels and of host noise; measured wall-clock is still
//! recorded separately in the history.

use crate::config::{ClusterProfile, NetworkConfig};

/// Mutable accumulator tracking simulated time and traffic for one run.
///
/// Built from a resolved [`ClusterProfile`] (one throughput rate per
/// worker in `wid = p·Q + q` order); callers fold per-worker charges
/// with [`SimNet::worker_s`] and commit the barrier via
/// [`SimNet::phase`].
#[derive(Debug, Clone)]
pub struct SimNet {
    net: NetworkConfig,
    flops_per_sec: f64,
    /// Relative throughput per worker (1.0 = `flops_per_sec`).
    rates: Vec<f64>,
    /// Barrier latency multiplier: the slowest link in the profile.
    latency_mult: f64,
    sim_s: f64,
    total_bytes: u64,
    total_msgs: u64,
}

impl SimNet {
    /// Stage the accounting for `workers` = P·Q workers under `profile`
    /// (already validated by the config layer).
    pub fn new(net: NetworkConfig, profile: &ClusterProfile, workers: usize) -> Self {
        Self {
            net,
            flops_per_sec: profile.flops_per_sec(),
            rates: profile.rates(workers),
            latency_mult: profile.link_latency_factor(),
            sim_s: 0.0,
            total_bytes: 0,
            total_msgs: 0,
        }
    }

    /// Seconds worker `wid` needs for `flops` at its profiled rate.
    /// Callers take the max across a phase's workers and hand it to
    /// [`SimNet::phase`].
    #[inline]
    pub fn worker_s(&self, wid: usize, flops: f64) -> f64 {
        flops / (self.flops_per_sec * self.rates[wid])
    }

    /// Charge one parallel phase: the slowest worker's compute seconds
    /// (pre-folded by the caller via [`SimNet::worker_s`]) plus the
    /// phase's aggregate traffic (scatter+gather serialized on the
    /// leader's link, like a Spark driver). `rounds` is the number of
    /// sequential barrier round-trips inside the phase (RADiSA-avg's
    /// rotating sub-epochs pay one per rotation); each waits for the
    /// profile's slowest link.
    pub fn phase(&mut self, max_worker_s: f64, bytes: u64, msgs: u64, rounds: u64) {
        let net = bytes as f64 / self.net.bandwidth_bps
            + if msgs > 0 {
                2.0 * self.net.latency_s * self.latency_mult * rounds.max(1) as f64
            } else {
                0.0
            };
        self.sim_s += max_worker_s + net;
        self.total_bytes += bytes;
        self.total_msgs += msgs;
    }

    /// Charge leader-local compute (no traffic; the leader runs at the
    /// base rate).
    pub fn local(&mut self, flops: f64) {
        self.sim_s += flops / self.flops_per_sec;
    }

    /// Bounded-staleness barrier cut: the simulated makespan of a phase
    /// that releases once `⌈quorum_frac·W⌉` workers have replied (the
    /// k-th order statistic of the per-worker times) or once the
    /// straggler timeout — `timeout_factor` times the *fastest* reply —
    /// fires, whichever comes first. `times` are the modeled per-worker
    /// phase seconds (caller-folded via [`SimNet::worker_s`], with any
    /// armed slowdown factors applied); `sorted` is reusable scratch.
    /// Workers with `time ≤ cut` are the quorum members. The timeout
    /// floor is the fastest reply, so the quorum is never empty.
    pub fn quorum_cut(
        times: &[f64],
        sorted: &mut Vec<f64>,
        quorum_frac: f64,
        timeout_factor: f64,
    ) -> f64 {
        sorted.clear();
        sorted.extend_from_slice(times);
        sorted.sort_unstable_by(|a, b| a.total_cmp(b));
        let w = sorted.len();
        let k = ((quorum_frac * w as f64).ceil() as usize).clamp(1, w);
        let t_quorum = sorted[k - 1];
        let deadline = timeout_factor * sorted[0];
        t_quorum.min(deadline)
    }

    /// Overwrite the accumulators from a checkpoint snapshot (the
    /// rates/link parameters are rebuilt from the config, which the
    /// checkpoint does not duplicate).
    pub fn restore(&mut self, sim_s: f64, total_bytes: u64, total_msgs: u64) {
        self.sim_s = sim_s;
        self.total_bytes = total_bytes;
        self.total_msgs = total_msgs;
    }

    pub fn sim_s(&self) -> f64 {
        self.sim_s
    }

    pub fn total_bytes(&self) -> u64 {
        self.total_bytes
    }

    pub fn total_msgs(&self) -> u64 {
        self.total_msgs
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::assert_close;

    fn net() -> NetworkConfig {
        NetworkConfig { latency_s: 1e-3, bandwidth_bps: 1e6 }
    }

    fn uniform(workers: usize) -> SimNet {
        SimNet::new(net(), &ClusterProfile::uniform().with_flops_per_sec(1e9), workers)
    }

    /// Fold a per-worker flops table the way callers do.
    fn makespan(s: &SimNet, flops: &[f64]) -> f64 {
        flops.iter().enumerate().map(|(w, &f)| s.worker_s(w, f)).fold(0.0, f64::max)
    }

    #[test]
    fn rounds_multiply_latency() {
        let mut a = uniform(4);
        a.phase(0.0, 0, 2, 1);
        let mut b = uniform(4);
        b.phase(0.0, 0, 2, 5);
        assert_close!(b.sim_s(), 5.0 * a.sim_s(), 1e-9);
    }

    #[test]
    fn phase_accounting() {
        let mut s = uniform(4);
        let compute = makespan(&s, &[2e9, 1e9, 5e8, 2e9]);
        s.phase(compute, 1_000_000, 4, 1);
        // 2 s compute (slowest worker) + 1 s transfer + 2 ms latency
        assert_close!(s.sim_s(), 3.002, 1e-9);
        assert_eq!(s.total_bytes(), 1_000_000);
        assert_eq!(s.total_msgs(), 4);
    }

    #[test]
    fn zero_message_phase_has_no_latency() {
        let mut s = uniform(4);
        s.phase(0.0, 0, 0, 1);
        assert_close!(s.sim_s(), 0.0, 1e-12, 1e-12);
    }

    #[test]
    fn local_compute_only() {
        let mut s = uniform(4);
        s.local(5e8);
        assert_close!(s.sim_s(), 0.5, 1e-9);
        assert_eq!(s.total_bytes(), 0);
    }

    #[test]
    fn monotone_accumulation() {
        let mut s = uniform(4);
        let mut last = 0.0;
        for _ in 0..5 {
            let c = makespan(&s, &[1e6; 4]);
            s.phase(c, 100, 1, 1);
            assert!(s.sim_s() > last);
            last = s.sim_s();
        }
    }

    #[test]
    fn uniform_profile_is_bit_identical_to_single_rate() {
        // the pre-profile charge was max(flops)/base; the per-worker fold
        // must reproduce it to the last bit under a uniform profile
        let s = uniform(6);
        let flops = [1.7e9, 3.3e8, 2.9e9, 1.0, 0.0, 2.9e9];
        let folded = makespan(&s, &flops);
        let legacy = flops.iter().fold(0.0f64, |a, &b| a.max(b)) / 1e9;
        assert_eq!(folded.to_bits(), legacy.to_bits());
    }

    #[test]
    fn straggler_dominates_the_barrier() {
        // one worker at 1/4 rate: the same flops cost 4x its peers, and
        // the barrier charge follows the straggler
        let s = SimNet::new(net(), &ClusterProfile::one_slow(4.0).with_flops_per_sec(1e9), 4);
        assert_close!(s.worker_s(0, 1e9), 4.0, 1e-12);
        assert_close!(s.worker_s(1, 1e9), 1.0, 1e-12);
        assert_close!(makespan(&s, &[1e9; 4]), 4.0, 1e-12);
        // shrink the straggler's shard 4x and the barrier drops to ~1.6s
        assert_close!(makespan(&s, &[0.4e9, 1.2e9, 1.2e9, 1.2e9]), 1.6, 1e-12);
    }

    #[test]
    fn link_factor_scales_barrier_latency() {
        let profile = ClusterProfile::uniform().with_flops_per_sec(1e9).with_link_latency_factor(3.0);
        let mut skewed = SimNet::new(net(), &profile, 4);
        skewed.phase(0.0, 0, 2, 1);
        let mut base = uniform(4);
        base.phase(0.0, 0, 2, 1);
        assert_close!(skewed.sim_s(), 3.0 * base.sim_s(), 1e-9);
    }

    #[test]
    fn quorum_cut_takes_the_kth_order_statistic() {
        // 6 workers, one 4x straggler: a 0.75 quorum releases after the
        // 5th reply (1 s), not the straggler's 4 s barrier
        let times = [4.0, 1.0, 1.0, 1.0, 1.0, 1.0];
        let mut scratch = Vec::new();
        assert_close!(SimNet::quorum_cut(&times, &mut scratch, 0.75, 4.0), 1.0, 1e-12);
        // a full quorum is the barrier max when the deadline allows it
        assert_close!(SimNet::quorum_cut(&times, &mut scratch, 1.0, 8.0), 4.0, 1e-12);
        // ... and the straggler timeout caps it when it does not:
        // deadline = 2x the fastest reply
        assert_close!(SimNet::quorum_cut(&times, &mut scratch, 1.0, 2.0), 2.0, 1e-12);
        // the cut never undercuts the fastest worker
        assert_close!(SimNet::quorum_cut(&[3.0, 5.0], &mut scratch, 0.1, 1.0), 3.0, 1e-12);
    }

    #[test]
    fn quorum_membership_follows_the_cut() {
        let times = [4.0, 1.0, 2.0, 1.0];
        let mut scratch = Vec::new();
        let cut = SimNet::quorum_cut(&times, &mut scratch, 0.75, 4.0);
        assert_close!(cut, 2.0, 1e-12);
        let mask: Vec<bool> = times.iter().map(|&t| t <= cut).collect();
        assert_eq!(mask, vec![false, true, true, true]);
    }

    #[test]
    fn restore_overwrites_accumulators() {
        let mut s = uniform(4);
        s.phase(1.5, 100, 2, 1);
        let (t, b, m) = (s.sim_s(), s.total_bytes(), s.total_msgs());
        let mut fresh = uniform(4);
        fresh.restore(t, b, m);
        assert_eq!(fresh.sim_s().to_bits(), s.sim_s().to_bits());
        assert_eq!(fresh.total_bytes(), b);
        assert_eq!(fresh.total_msgs(), m);
    }
}
