//! SimNet: the deterministic cluster cost model.
//!
//! The paper's time axis is Spark wall-clock on a 4-node cluster; our
//! substitute charges every phase of the algorithm with an explicit,
//! reproducible model:
//!
//! * compute: `max_worker(flops) / flops_per_sec` (workers run in
//!   parallel, the barrier waits for the slowest — exactly Spark's stage
//!   semantics),
//! * network: `total_bytes / bandwidth + 2·latency` per phase (scatter +
//!   gather through the leader's link, one barrier round-trip).
//!
//! Being a *model* (instead of wall-clock) keeps the figures independent
//! of which engine executes the kernels and of host noise; measured
//! wall-clock is still recorded separately in the history.

use crate::config::NetworkConfig;

/// Cost-model parameters. `flops_per_sec` defaults to 200 MFLOP/s per
/// worker — the effective rate of the paper's Scala/Spark executors on
/// boxed doubles (2.2 GHz Xeons lose ~10× to JVM overhead on this kind
/// of scalar-indexed loop), which puts laptop-scale instances in the same
/// compute-dominated regime as the paper's cluster-scale runs.
#[derive(Debug, Clone, Copy)]
pub struct CostModel {
    pub net: NetworkConfig,
    pub flops_per_sec: f64,
}

impl Default for CostModel {
    fn default() -> Self {
        Self { net: NetworkConfig::default(), flops_per_sec: 2e8 }
    }
}

/// Mutable accumulator tracking simulated time and traffic for one run.
#[derive(Debug, Clone)]
pub struct SimNet {
    pub model: CostModel,
    sim_s: f64,
    total_bytes: u64,
    total_msgs: u64,
}

impl SimNet {
    pub fn new(model: CostModel) -> Self {
        Self { model, sim_s: 0.0, total_bytes: 0, total_msgs: 0 }
    }

    /// Charge one parallel phase: the slowest worker's compute plus the
    /// phase's aggregate traffic (scatter+gather serialized on the
    /// leader's link, like a Spark driver). `rounds` is the number of
    /// sequential barrier round-trips inside the phase (RADiSA-avg's
    /// rotating sub-epochs pay one per rotation).
    pub fn phase(&mut self, max_worker_flops: f64, bytes: u64, msgs: u64, rounds: u64) {
        let compute = max_worker_flops / self.model.flops_per_sec;
        let net = bytes as f64 / self.model.net.bandwidth_bps
            + if msgs > 0 { 2.0 * self.model.net.latency_s * rounds.max(1) as f64 } else { 0.0 };
        self.sim_s += compute + net;
        self.total_bytes += bytes;
        self.total_msgs += msgs;
    }

    /// Charge leader-local compute (no traffic).
    pub fn local(&mut self, flops: f64) {
        self.sim_s += flops / self.model.flops_per_sec;
    }

    pub fn sim_s(&self) -> f64 {
        self.sim_s
    }

    pub fn total_bytes(&self) -> u64 {
        self.total_bytes
    }

    pub fn total_msgs(&self) -> u64 {
        self.total_msgs
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::assert_close;

    fn model() -> CostModel {
        CostModel {
            net: NetworkConfig { latency_s: 1e-3, bandwidth_bps: 1e6 },
            flops_per_sec: 1e9,
        }
    }

    #[test]
    fn rounds_multiply_latency() {
        let mut a = SimNet::new(model());
        a.phase(0.0, 0, 2, 1);
        let mut b = SimNet::new(model());
        b.phase(0.0, 0, 2, 5);
        assert_close!(b.sim_s(), 5.0 * a.sim_s(), 1e-9);
    }

    #[test]
    fn phase_accounting() {
        let mut net = SimNet::new(model());
        net.phase(2e9, 1_000_000, 4, 1);
        // 2 s compute + 1 s transfer + 2 ms latency
        assert_close!(net.sim_s(), 3.002, 1e-9);
        assert_eq!(net.total_bytes(), 1_000_000);
        assert_eq!(net.total_msgs(), 4);
    }

    #[test]
    fn zero_message_phase_has_no_latency() {
        let mut net = SimNet::new(model());
        net.phase(0.0, 0, 0, 1);
        assert_close!(net.sim_s(), 0.0, 1e-12, 1e-12);
    }

    #[test]
    fn local_compute_only() {
        let mut net = SimNet::new(model());
        net.local(5e8);
        assert_close!(net.sim_s(), 0.5, 1e-9);
        assert_eq!(net.total_bytes(), 0);
    }

    #[test]
    fn monotone_accumulation() {
        let mut net = SimNet::new(model());
        let mut last = 0.0;
        for _ in 0..5 {
            net.phase(1e6, 100, 1, 1);
            assert!(net.sim_s() > last);
            last = net.sim_s();
        }
    }
}
