//! Executor transports: *how* the P×Q workers execute the phase
//! protocol, decoupled from *what* they execute.
//!
//! The leader-side [`crate::cluster::Cluster`] speaks one message
//! protocol — typed [`Cmd`]s down, `(worker id, `[`Reply`]`)` pairs
//! back — and every command is executed by the same
//! [`WorkerCore::execute`] body regardless of the substrate. A
//! [`Transport`] owns the substrate:
//!
//! * [`InProcess`] — the deterministic sequential oracle. `send`
//!   executes the command inline on the leader thread; `recv` drains a
//!   FIFO of finished replies. No threads, no channels: the whole
//!   cluster is one core's worth of work in a fixed order, which makes
//!   it the bit-frozen reference the equivalence suite and the
//!   alloc-regression harness pin everything against.
//! * [`Threaded`] — the real runtime. One persistent thread per worker,
//!   each owning its shard and scratch outright ([`WorkerCore`] is
//!   `Send`; the shared [`ComputeEngine`] is `Send + Sync`), with an
//!   mpsc mailbox per worker and one shared reply channel back to the
//!   leader. Phases genuinely overlap across cores.
//!
//! ## Determinism contract
//!
//! This section is load-bearing: `xtask lint` (`doc_contract`) fails
//! the build if it disappears, the README's correctness-tooling
//! section points here, and the `rust-loom` / `rust-tsan` CI lanes
//! exist to enforce the clauses below mechanically.
//!
//! `Threaded` reproduces `InProcess` **bit-for-bit** (enforced by
//! `tests/executor.rs`), by construction rather than by luck:
//!
//! 1. both transports run the identical [`WorkerCore::execute`] body,
//!    so per-block numbers cannot differ;
//! 2. every leader-side reduce stages replies into per-worker slots and
//!    folds them in worker-id order — f32 addition is non-associative,
//!    so arrival order must never reach an accumulator;
//! 3. the SVRG phase applies results in completion order, but tasks own
//!    disjoint column ranges, so any apply order writes the same bits.
//!
//! The only observable difference between the two modes is wall-clock
//! (and thread identity). Reply buffers recycle through the leader pool
//! identically in both — commands carry the recycled buffer down and
//! the reply carries it back, whatever the substrate.
//!
//! The contract is checked from three directions: example-based
//! equality (`tests/executor.rs`, `tests/faults.rs`), exhaustive
//! interleaving exploration of the mailbox/reply/recovery protocol
//! under loom (`loom_tests.rs`, via the `sync.rs` shim), and data-race
//! detection on the real OS-thread runtime (the ThreadSanitizer CI
//! lane).

mod in_process;
mod sync;
mod threaded;

#[cfg(all(test, loom))]
mod loom_tests;

pub(crate) use in_process::InProcess;
pub(crate) use threaded::Threaded;

use std::ops::Range;
use std::sync::Arc;

use crate::config::ExecutorKind;
use crate::data::Block;
use crate::engine::{BlockKey, ComputeEngine};
use crate::loss::Loss;

/// Commands the leader sends to a worker. `buf` fields are recycled
/// reply buffers from the leader pool (arbitrary stale contents; the
/// worker clears and refills them). `cols` fields carry the sampled
/// sets as **sorted block-local column id lists**: `Some(ids)` selects
/// the sampled-width engine entry points with a **compact** `w`/reply
/// payload (length `|ids|`, not the zero-padded block width); `None` is
/// the frozen full-width path (RADiSA, `|B| == M`).
pub(crate) enum Cmd {
    /// z_part = X[rows, cols] · w — `cols: None`: w pre-masked by B^t,
    /// full block width; `cols: Some`: compact w over B^t ∩ block
    PartialZ { w: Arc<Vec<f32>>, cols: Option<Arc<Vec<u32>>>, rows: Arc<Vec<u32>>, buf: Vec<f32> },
    /// u = f'(X[rows, cols]·w, y[rows]) — fused margin + loss derivative
    /// (batched `partial_u` engine entry point); only dispatched on
    /// Q = 1 grids, where the block holds the complete margin
    PartialU { w: Arc<Vec<f32>>, cols: Option<Arc<Vec<u32>>>, rows: Arc<Vec<u32>>, buf: Vec<f32> },
    /// Σ_rows f(X[rows, :]·w, y[rows]) — fused objective term
    /// (batched `block_loss` engine entry point); Q = 1 grids only
    BlockLoss { w: Arc<Vec<f32>>, rows: Arc<Vec<u32>> },
    /// g = Σ_rows u·x_row — full block width (`cols: None`) or the
    /// compact C^t ∩ block slice (`cols: Some`, reply length `|ids|`)
    GradSlice { u: Arc<Vec<f32>>, cols: Option<Arc<Vec<u32>>>, rows: Arc<Vec<u32>>, buf: Vec<f32> },
    /// L SVRG steps on the sub-block `cols` (block-local range). The
    /// worker slices its `gcols` window out of the shared full-model
    /// `w`/`mu` snapshots (one allocation-free Arc clone per task
    /// instead of three owned copies); `avg` selects RADiSA-avg's
    /// suffix-averaged combiner. `idx` rides back with the reply so its
    /// buffer recycles too (an `Arc` so the leader can retain a clone
    /// for fault replay without copying the id list).
    Svrg {
        cols: Range<usize>,
        gcols: Range<usize>,
        w: Arc<Vec<f32>>,
        mu: Arc<Vec<f32>>,
        idx: Arc<Vec<u32>>,
        gamma: f32,
        avg: bool,
        buf: Vec<f32>,
    },
    /// Terminate the worker loop ([`Threaded`] only; [`InProcess`] has
    /// no loop to terminate and simply drops its cores).
    Shutdown,
    /// Simulated crash ([`Transport::kill`] delivery under [`Threaded`]):
    /// the worker loop exits *without* replying, exactly like a thread
    /// that died mid-phase. Never reaches [`WorkerCore::execute`] — the
    /// thread loop intercepts it ([`InProcess`] flags the worker dead
    /// without sending anything).
    Die,
    /// Liveness probe: alive workers swallow it without replying; a
    /// dead worker's closed mailbox rejects the send, which is how
    /// [`Threaded::recv`] distinguishes a crashed worker from a slow
    /// phase. Never reaches [`WorkerCore::execute`].
    Nop,
}

/// Worker replies (tagged with the worker's linear id by the transport).
/// `Debug` is for test diagnostics (the shutdown-edge and loom suites
/// print unexpected replies).
#[derive(Debug)]
pub(crate) enum Reply {
    Z(Vec<f32>),
    U(Vec<f32>),
    Loss(f64),
    Grad(Vec<f32>),
    W { w: Vec<f32>, idx: Arc<Vec<u32>> },
    /// The worker died before replying (killed via [`Transport::kill`]
    /// or an unexpected thread death). The transport synthesizes this
    /// so the send-all/recv-all barrier still sees one reply per send —
    /// the leader re-launches the worker and replays the command
    /// instead of hanging forever.
    Fault,
}

/// One worker's entire state: its shard, the shared engine, and the
/// persistent per-worker scratch. Owned by a thread under [`Threaded`],
/// by a `RefCell` slot under [`InProcess`] — either way there is exactly
/// one `&mut` executor of a core at any time, and the execution body is
/// the same function, so the two transports cannot diverge numerically.
pub(crate) struct WorkerCore {
    pub(crate) block: Block,
    pub(crate) engine: Arc<dyn ComputeEngine>,
    pub(crate) loss: Loss,
    /// persistent scratch: the fused objective evaluation's margin
    /// buffer and the averaged SVRG combiner's working iterate
    pub(crate) scratch: Vec<f32>,
}

impl WorkerCore {
    pub(crate) fn new(block: Block, engine: Arc<dyn ComputeEngine>, loss: Loss) -> WorkerCore {
        WorkerCore { block, engine, loss, scratch: Vec::new() }
    }

    /// Execute one command against this worker's shard. Returns `None`
    /// on [`Cmd::Shutdown`] (no reply; the caller's loop ends).
    pub(crate) fn execute(&mut self, cmd: Cmd) -> Option<Reply> {
        let key = BlockKey { p: self.block.p, q: self.block.q };
        let m = self.block.x.cols();
        let reply = match cmd {
            Cmd::PartialZ { w, cols, rows, mut buf } => {
                match &cols {
                    Some(ids) => self
                        .engine
                        .partial_z_cols_into(key, &self.block.x, ids, &w, &rows, &mut buf),
                    None => {
                        self.engine.partial_z_into(key, &self.block.x, 0..m, &w, &rows, &mut buf)
                    }
                }
                Reply::Z(buf)
            }
            Cmd::PartialU { w, cols, rows, mut buf } => {
                match &cols {
                    Some(ids) => self.engine.partial_u_cols_into(
                        key,
                        self.loss,
                        &self.block.x,
                        ids,
                        &w,
                        &rows,
                        &self.block.y,
                        &mut buf,
                    ),
                    None => self.engine.partial_u_into(
                        key,
                        self.loss,
                        &self.block.x,
                        0..m,
                        &w,
                        &rows,
                        &self.block.y,
                        &mut buf,
                    ),
                }
                Reply::U(buf)
            }
            Cmd::BlockLoss { w, rows } => Reply::Loss(self.engine.block_loss_scratch(
                key,
                self.loss,
                &self.block.x,
                0..m,
                &w,
                &rows,
                &self.block.y,
                &mut self.scratch,
            )),
            Cmd::GradSlice { u, cols, rows, mut buf } => {
                match &cols {
                    Some(ids) => {
                        self.engine.grad_cols_into(key, &self.block.x, ids, &rows, &u, &mut buf)
                    }
                    None => {
                        self.engine.grad_slice_into(key, &self.block.x, 0..m, &rows, &u, &mut buf)
                    }
                }
                Reply::Grad(buf)
            }
            Cmd::Svrg { cols, gcols, w, mu, idx, gamma, avg, mut buf } => {
                debug_assert_eq!(gcols.len(), cols.len(), "snapshot window ≠ sub-block");
                let e = &self.engine;
                let (x, y) = (&self.block.x, &self.block.y);
                // w^t is both the starting iterate w⁰ and the SVRG
                // reference w̃ (each sub-epoch starts at the
                // reference point)
                let w0 = &w[gcols.clone()];
                let mu_s = &mu[gcols];
                if avg {
                    e.svrg_inner_avg_into(
                        key,
                        self.loss,
                        x,
                        y,
                        cols,
                        w0,
                        w0,
                        mu_s,
                        &idx,
                        gamma,
                        &mut buf,
                        &mut self.scratch,
                    );
                } else {
                    e.svrg_inner_into(
                        key, self.loss, x, y, cols, w0, w0, mu_s, &idx, gamma, &mut buf,
                    );
                }
                Reply::W { w: buf, idx }
            }
            // the transports intercept Die/Nop before execute; treat
            // them like Shutdown defensively if one ever slips through
            Cmd::Shutdown | Cmd::Die | Cmd::Nop => return None,
        };
        Some(reply)
    }
}

/// Phase dispatch: deliver a command to worker `id`, collect the next
/// finished `(id, reply)` pair. The leader is the sole caller and every
/// phase is a strict send-all/receive-all barrier, so a transport never
/// sees interleaved phases. `Send` (not `Sync`): a [`Cluster`] can move
/// between threads wholesale but is driven from one thread at a time —
/// exactly the `Receiver`/`RefCell` contract the leader already had.
///
/// [`Cluster`]: crate::cluster::Cluster
pub(crate) trait Transport: Send {
    /// Deliver `cmd` to worker `id`. [`InProcess`] executes it inline
    /// before returning; [`Threaded`] enqueues it on the worker's
    /// mailbox. Either way exactly one reply per send is eventually
    /// observable through [`Transport::recv`] — a dead worker's send
    /// yields a synthetic [`Reply::Fault`] (and `false` here).
    fn send(&self, id: usize, cmd: Cmd) -> bool;

    /// Next finished `(worker id, reply)` pair; `(id, `[`Reply::Fault`]`)`
    /// when worker `id` died instead of replying. Panics ([`InProcess`])
    /// or blocks ([`Threaded`]) if called with no command in flight — a
    /// protocol bug, not a runtime condition.
    fn recv(&self) -> (usize, Reply);

    /// Simulated crash of worker `id`: it stops executing and every
    /// in-flight or subsequent command to it resolves to
    /// [`Reply::Fault`] until [`Transport::respawn`]. Delivery is
    /// FIFO-ordered with `send` on both transports, so a kill issued
    /// before a phase's sends takes effect before the phase command —
    /// the worker never partially executes it.
    fn kill(&self, id: usize);

    /// Re-launch worker `id` from a freshly rebuilt [`WorkerCore`]
    /// (shard + engine + empty scratch). Returns `true` when the slot
    /// is live again (the replacement sees only commands sent after
    /// this call) and `false` when the substrate could not bring the
    /// worker back — the leader's [`RecoveryPolicy`] retry loop reacts
    /// to `false`, eventually escalating to permanent loss.
    ///
    /// [`RecoveryPolicy`]: crate::config::RecoveryPolicy
    fn respawn(&self, id: usize, core: WorkerCore) -> bool;

    /// Make the next `n` [`Transport::respawn`] calls report failure
    /// without touching the slot (fault-injection hook for testing the
    /// retry/escalation path; default: respawns never refuse).
    fn refuse_respawns(&self, n: usize) {
        let _ = n;
    }

    /// Which executor this transport implements (selection reporting).
    fn kind(&self) -> ExecutorKind;
}

/// Build the transport for `kind` over the given worker cores.
/// `probe` is the threaded executor's liveness-probe timeout (from the
/// leader's recovery policy; ignored by the in-process oracle, which
/// detects death inline).
pub(crate) fn launch(
    kind: ExecutorKind,
    cores: Vec<WorkerCore>,
    probe: std::time::Duration,
) -> Box<dyn Transport> {
    match kind {
        ExecutorKind::InProcess => Box::new(InProcess::new(cores)),
        ExecutorKind::Threaded => Box::new(Threaded::spawn_with_probe(cores, probe)),
    }
}
