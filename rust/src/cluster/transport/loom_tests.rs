//! Exhaustive model checking of the threaded transport protocol.
//!
//! Compiled and run only under `RUSTFLAGS="--cfg loom"`:
//!
//! ```text
//! cd rust && RUSTFLAGS="--cfg loom" cargo test --release --lib loom_
//! ```
//!
//! Because `threaded.rs` takes every thread/channel primitive from the
//! `sync.rs` shim, the [`Threaded`] these scenarios drive is the real
//! protocol implementation — mailbox FIFOs, the shared reply channel,
//! the `recv_timeout` + `Nop` liveness probe, kill → respawn → replay,
//! and Drop's shutdown+join — executed under loom's scheduler, which
//! explores every interleaving up to the preemption bound instead of
//! the one the OS happens to produce. Each scenario body re-runs once
//! per explored schedule, so everything (dataset, cores, transport) is
//! rebuilt inside the closure and every assertion must hold on *all*
//! schedules: a reply that can be lost, a fault that can be reported
//! twice, or a shutdown that can deadlock shows up as a failing (or
//! hanging) schedule here rather than as a once-a-month CI flake.
//!
//! The preemption bound (3) is the standard loom state-space cap:
//! exhaustive over all schedules with at most three involuntary
//! context switches per thread, which is where virtually all real
//! channel/recovery bugs live (the PR 7 silent-hang bug needed one).

use std::sync::Arc;

use super::{Cmd, InProcess, Reply, Threaded, Transport, WorkerCore};
use crate::data::{synth, Grid};
use crate::engine::{ComputeEngine, NativeEngine};
use crate::loss::Loss;

/// Exhaustively check `f` over thread interleavings (≤3 preemptions).
fn model<F>(f: F)
where
    F: Fn() + Send + Sync + 'static,
{
    let mut b = loom::model::Builder::new();
    b.preemption_bound = Some(3);
    b.check(f);
}

/// Tiny deterministic cores: a 4×4 dense dataset split into `p`
/// row-blocks (one worker per block, full width). Rebuilt per
/// schedule — cheap, and free of sync operations, so it adds no
/// branching to the model.
fn cores(p: usize, seed: u64) -> Vec<WorkerCore> {
    let ds = synth::dense_zhang(4, 4, seed);
    let grid = Grid::partition(&ds, p, 1).unwrap();
    let engine: Arc<dyn ComputeEngine> = Arc::new(NativeEngine);
    grid.blocks()
        .map(|b| WorkerCore::new(b.clone(), Arc::clone(&engine), Loss::Hinge))
        .collect()
}

/// A full-width `BlockLoss` over all `n_per` rows of a block — the
/// simplest command with a value-carrying reply.
fn loss_cmd(n_per: usize) -> Cmd {
    let w: Vec<f32> = (0..4).map(|j| 0.3 * j as f32 - 0.4).collect();
    let rows: Vec<u32> = (0..n_per as u32).collect();
    Cmd::BlockLoss { w: Arc::new(w), rows: Arc::new(rows) }
}

/// What the sequential oracle computes for the same cores + commands,
/// keyed by worker id.
fn oracle_losses(p: usize, seed: u64, n_per: usize) -> Vec<f64> {
    let oracle = InProcess::new(cores(p, seed));
    for id in 0..p {
        assert!(oracle.send(id, loss_cmd(n_per)));
    }
    let mut out = vec![0.0; p];
    for _ in 0..p {
        match oracle.recv() {
            (id, Reply::Loss(l)) => out[id] = l,
            other => panic!("oracle returned {other:?}"),
        }
    }
    out
}

/// Scenario 1 — phase fan-in: two workers race their replies onto the
/// shared channel; whatever the arrival order, the leader must see
/// exactly one reply per worker and the oracle's bits for each.
#[test]
fn loom_phase_fan_in_is_exact_under_all_interleavings() {
    model(|| {
        let expected = oracle_losses(2, 1, 2);
        let t = Threaded::spawn(cores(2, 1));
        assert!(t.send(0, loss_cmd(2)));
        assert!(t.send(1, loss_cmd(2)));
        let mut got: [Option<f64>; 2] = [None, None];
        for _ in 0..2 {
            match t.recv() {
                (id, Reply::Loss(l)) => {
                    assert!(got[id].is_none(), "worker {id} replied twice");
                    got[id] = Some(l);
                }
                other => panic!("unexpected reply {other:?}"),
            }
        }
        for id in 0..2 {
            assert_eq!(
                got[id].unwrap().to_bits(),
                expected[id].to_bits(),
                "worker {id} diverged from the oracle"
            );
        }
    });
}

/// Scenario 2 — kill during a phase: whether the phase send beats the
/// `Die` into the mailbox, loses to it, or observes the mailbox
/// already closed, the barrier must get exactly one `Fault`, and a
/// respawn + replay must produce the oracle's bits.
#[test]
fn loom_kill_during_phase_recovers_bit_identically() {
    model(|| {
        let expected = oracle_losses(1, 2, 4)[0];
        let mut all = cores(1, 2);
        let core = all.pop().unwrap();
        let replacement =
            WorkerCore::new(core.block.clone(), Arc::clone(&core.engine), Loss::Hinge);
        let t = Threaded::spawn(vec![core]);
        t.kill(0);
        let _ = t.send(0, loss_cmd(4));
        assert!(
            matches!(t.recv(), (0, Reply::Fault)),
            "a killed worker must surface as exactly one Fault"
        );
        t.respawn(0, replacement);
        assert!(t.send(0, loss_cmd(4)), "respawned mailbox must accept commands");
        match t.recv() {
            (0, Reply::Loss(l)) => assert_eq!(l.to_bits(), expected.to_bits()),
            other => panic!("expected the replayed loss, got {other:?}"),
        }
    });
}

/// Scenario 3 — Drop racing an in-flight reply: the leader consumes
/// one of two outstanding replies and drops the transport while the
/// other may still be anywhere between `execute` and the reply
/// channel. Every schedule must shut down and join both workers —
/// loom flags the interleaving as a hang if any leaks or deadlocks.
#[test]
fn loom_drop_with_inflight_reply_never_deadlocks() {
    model(|| {
        let t = Threaded::spawn(cores(2, 3));
        assert!(t.send(0, loss_cmd(2)));
        assert!(t.send(1, loss_cmd(2)));
        let (id, reply) = t.recv();
        assert!(matches!(reply, Reply::Loss(_)), "worker {id} sent {reply:?}");
        drop(t);
    });
}

/// Scenario 4 — double-kill in one phase: the second `Die` lands in a
/// closing (or already closed) mailbox and must be swallowed; the
/// barrier still sees exactly one `Fault`, and recovery still replays
/// to the oracle's bits.
#[test]
fn loom_double_kill_faults_once_and_recovers() {
    model(|| {
        let expected = oracle_losses(1, 4, 4)[0];
        let mut all = cores(1, 4);
        let core = all.pop().unwrap();
        let replacement =
            WorkerCore::new(core.block.clone(), Arc::clone(&core.engine), Loss::Hinge);
        let t = Threaded::spawn(vec![core]);
        t.kill(0);
        t.kill(0);
        let _ = t.send(0, loss_cmd(4));
        assert!(matches!(t.recv(), (0, Reply::Fault)));
        t.respawn(0, replacement);
        assert!(t.send(0, loss_cmd(4)));
        match t.recv() {
            (0, Reply::Loss(l)) => assert_eq!(l.to_bits(), expected.to_bits()),
            other => panic!("expected the replayed loss, got {other:?}"),
        }
    });
}
