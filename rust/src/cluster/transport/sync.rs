//! Sync-primitive shim: `std` normally, `loom` under `--cfg loom`.
//!
//! The threaded transport is written against this module instead of
//! `std::sync::mpsc`/`std::thread` directly, so the *same* protocol
//! code (mailboxes down, shared reply channel up, `recv_timeout` +
//! `Nop` liveness probing, kill → respawn → replay, Drop shutdown+join)
//! can be run under loom's model checker, which exhaustively explores
//! thread interleavings (`cargo test --lib loom_tests` with
//! `RUSTFLAGS="--cfg loom"`; see `loom_tests.rs`).
//!
//! loom has no mpsc channel, so the `cfg(loom)` half hand-rolls one
//! from the primitives loom *does* model (`Mutex` + `Condvar` + a
//! `VecDeque`), with the mpsc API surface the transport uses: `send`
//! fails once the receiver is dropped, `recv` blocks until a value or
//! total sender disconnect, `try_recv` never blocks. The one semantic
//! liberty is [`Receiver::recv_timeout`]: loom has no notion of wall
//! time, so an empty queue reports `Timeout` immediately (after a
//! scheduler yield). That is a sound over-approximation — it makes the
//! model explore *every* probe round the real executor could ever take,
//! including the paths where the timeout fires while a worker is alive
//! and mid-compute.

#[cfg(not(loom))]
mod imp {
    pub(crate) use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
    pub(crate) use std::thread::JoinHandle;

    /// `std::thread::Builder` spawn with a thread name (visible in
    /// panics and debuggers). loom's side ignores the name — its
    /// threads are model entities, not OS threads.
    pub(crate) fn spawn_named<F>(name: String, f: F) -> JoinHandle<()>
    where
        F: FnOnce() + Send + 'static,
    {
        std::thread::Builder::new().name(name).spawn(f).expect("spawn worker thread")
    }
}

#[cfg(loom)]
mod imp {
    use std::collections::VecDeque;
    use std::time::Duration;

    use loom::sync::{Arc, Condvar, Mutex};

    pub(crate) use loom::thread::JoinHandle;

    pub(crate) fn spawn_named<F>(_name: String, f: F) -> JoinHandle<()>
    where
        F: FnOnce() + Send + 'static,
    {
        loom::thread::spawn(f)
    }

    struct State<T> {
        q: VecDeque<T>,
        senders: usize,
        rx_alive: bool,
    }

    struct Chan<T> {
        state: Mutex<State<T>>,
        cv: Condvar,
    }

    pub(crate) struct Sender<T>(Arc<Chan<T>>);
    pub(crate) struct Receiver<T>(Arc<Chan<T>>);

    /// Mirrors `std::sync::mpsc::SendError`: hands the value back.
    #[allow(dead_code)] // the payload is never inspected, only dropped
    pub(crate) struct SendError<T>(pub(crate) T);
    pub(crate) struct RecvError;
    pub(crate) enum RecvTimeoutError {
        Timeout,
        Disconnected,
    }
    #[allow(dead_code)] // variants mirror std's enum; callers only use Ok
    pub(crate) enum TryRecvError {
        Empty,
        Disconnected,
    }

    pub(crate) fn channel<T>() -> (Sender<T>, Receiver<T>) {
        let chan = Arc::new(Chan {
            state: Mutex::new(State { q: VecDeque::new(), senders: 1, rx_alive: true }),
            cv: Condvar::new(),
        });
        (Sender(Arc::clone(&chan)), Receiver(chan))
    }

    impl<T> Clone for Sender<T> {
        fn clone(&self) -> Sender<T> {
            self.0.state.lock().unwrap().senders += 1;
            Sender(Arc::clone(&self.0))
        }
    }

    impl<T> Drop for Sender<T> {
        fn drop(&mut self) {
            let mut s = self.0.state.lock().unwrap();
            s.senders -= 1;
            if s.senders == 0 {
                // wake a receiver blocked in `recv` so it can observe
                // the disconnect instead of sleeping forever
                self.0.cv.notify_all();
            }
        }
    }

    impl<T> Sender<T> {
        pub(crate) fn send(&self, value: T) -> Result<(), SendError<T>> {
            let mut s = self.0.state.lock().unwrap();
            if !s.rx_alive {
                return Err(SendError(value));
            }
            s.q.push_back(value);
            self.0.cv.notify_one();
            Ok(())
        }
    }

    impl<T> Drop for Receiver<T> {
        fn drop(&mut self) {
            // senders never block, so flipping the flag is enough for
            // them to start failing fast
            self.0.state.lock().unwrap().rx_alive = false;
        }
    }

    impl<T> Receiver<T> {
        pub(crate) fn recv(&self) -> Result<T, RecvError> {
            let mut s = self.0.state.lock().unwrap();
            loop {
                if let Some(v) = s.q.pop_front() {
                    return Ok(v);
                }
                if s.senders == 0 {
                    return Err(RecvError);
                }
                s = self.0.cv.wait(s).unwrap();
            }
        }

        /// An empty queue is an *instant* timeout under the model (loom
        /// has no clock). The `yield_now` is loom's spin-loop contract:
        /// it tells the scheduler to run the other threads before this
        /// one retries, so the probe loop in `Threaded::recv` always
        /// makes global progress and the model terminates.
        pub(crate) fn recv_timeout(&self, _timeout: Duration) -> Result<T, RecvTimeoutError> {
            {
                let mut s = self.0.state.lock().unwrap();
                if let Some(v) = s.q.pop_front() {
                    return Ok(v);
                }
                if s.senders == 0 {
                    return Err(RecvTimeoutError::Disconnected);
                }
            }
            loom::thread::yield_now();
            Err(RecvTimeoutError::Timeout)
        }

        pub(crate) fn try_recv(&self) -> Result<T, TryRecvError> {
            let mut s = self.0.state.lock().unwrap();
            if let Some(v) = s.q.pop_front() {
                return Ok(v);
            }
            if s.senders == 0 {
                return Err(TryRecvError::Disconnected);
            }
            Err(TryRecvError::Empty)
        }
    }
}

pub(crate) use imp::*;
