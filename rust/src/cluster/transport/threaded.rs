//! The real runtime: one persistent OS thread per worker, mailboxes
//! down, a shared reply channel up.

use std::cell::RefCell;
use std::collections::VecDeque;
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::thread::JoinHandle;
use std::time::Duration;

use crate::config::ExecutorKind;

use super::{Cmd, Reply, Transport, WorkerCore};

/// How long `recv` waits for a reply before probing in-flight workers
/// for liveness. Purely a detection latency: a slow-but-alive phase
/// survives any number of probe rounds untouched.
const PROBE_INTERVAL: Duration = Duration::from_millis(100);

/// Spawn one worker thread owning `core`, looping on its private
/// mailbox. [`Cmd::Nop`] (liveness probe) is swallowed without a reply;
/// [`Cmd::Die`] (simulated crash) exits the loop without replying —
/// both are intercepted here so [`WorkerCore::execute`] stays identical
/// across transports.
fn spawn_worker(
    id: usize,
    mut core: WorkerCore,
    rx: Receiver<Cmd>,
    reply_tx: Sender<(usize, Reply)>,
) -> JoinHandle<()> {
    std::thread::Builder::new()
        .name(format!("worker-{id}"))
        .spawn(move || {
            while let Ok(cmd) = rx.recv() {
                match cmd {
                    Cmd::Nop => continue,
                    Cmd::Die => break,
                    cmd => match core.execute(cmd) {
                        // a dead leader (dropped receiver) is a
                        // normal shutdown race, not an error
                        Some(reply) => {
                            if reply_tx.send((id, reply)).is_err() {
                                break;
                            }
                        }
                        None => break,
                    },
                }
            }
        })
        .expect("spawn worker thread")
}

/// Thread-per-worker executor. Each of the P×Q threads owns its
/// [`WorkerCore`] (shard + scratch) outright and loops on its private
/// mailbox; all threads share one `Sender` back to the leader. Phases
/// overlap across cores for real — the leader's send-all/recv-all
/// barriers plus id-staged reduces keep the numbers bit-identical to
/// the in-process oracle (see the module docs in `transport/mod.rs`).
///
/// Fault detection: `recv` waits with a timeout; on expiry it probes
/// every in-flight worker with [`Cmd::Nop`] — a closed mailbox means
/// the thread exited without replying (killed or panicked), and the
/// leader gets `(id, `[`Reply::Fault`]`)` instead of hanging forever on
/// a reply that will never come. The `RefCell`s exist for
/// [`Transport::respawn`], which swaps in a fresh channel + thread
/// through `&self` (same single-leader-thread contract as the
/// in-process transport).
pub(crate) struct Threaded {
    cmd_txs: RefCell<Vec<Sender<Cmd>>>,
    /// kept alive so `recv` can never see `Disconnected` even with
    /// every worker dead (faults are reported per-worker instead)
    reply_tx: Sender<(usize, Reply)>,
    reply_rx: Receiver<(usize, Reply)>,
    handles: RefCell<Vec<JoinHandle<()>>>,
    /// in-flight commands per worker (≤ 1 under the phase barriers);
    /// only in-flight workers are probed, so an idle dead worker is
    /// reported exactly once per command addressed to it
    pending: RefCell<Vec<u32>>,
    /// workers whose send already failed — their synthetic faults,
    /// drained by `recv` before touching the reply channel
    faulted: RefCell<VecDeque<usize>>,
}

impl Threaded {
    pub(crate) fn spawn(cores: Vec<WorkerCore>) -> Threaded {
        let n = cores.len();
        let (reply_tx, reply_rx) = channel::<(usize, Reply)>();
        let mut cmd_txs = Vec::with_capacity(n);
        let mut handles = Vec::with_capacity(n);
        for (id, core) in cores.into_iter().enumerate() {
            let (tx, rx) = channel::<Cmd>();
            handles.push(spawn_worker(id, core, rx, reply_tx.clone()));
            cmd_txs.push(tx);
        }
        Threaded {
            cmd_txs: RefCell::new(cmd_txs),
            reply_tx,
            reply_rx,
            handles: RefCell::new(handles),
            pending: RefCell::new(vec![0; n]),
            faulted: RefCell::new(VecDeque::new()),
        }
    }
}

impl Transport for Threaded {
    fn send(&self, id: usize, cmd: Cmd) -> bool {
        if self.cmd_txs.borrow()[id].send(cmd).is_ok() {
            self.pending.borrow_mut()[id] += 1;
            true
        } else {
            // mailbox closed: the thread already exited. Queue the
            // synthetic fault so the barrier still sees one reply.
            self.faulted.borrow_mut().push_back(id);
            false
        }
    }

    fn recv(&self) -> (usize, Reply) {
        if let Some(id) = self.faulted.borrow_mut().pop_front() {
            self.pending.borrow_mut()[id] = 0;
            return (id, Reply::Fault);
        }
        loop {
            match self.reply_rx.recv_timeout(PROBE_INTERVAL) {
                Ok((id, reply)) => {
                    let pending = &mut self.pending.borrow_mut()[id];
                    *pending = pending.saturating_sub(1);
                    return (id, reply);
                }
                Err(RecvTimeoutError::Timeout) => {
                    // probe every in-flight worker: an Err means its
                    // mailbox receiver is gone, i.e. the thread exited
                    // without replying
                    let dead = {
                        let pending = self.pending.borrow();
                        let txs = self.cmd_txs.borrow();
                        (0..txs.len())
                            .find(|&i| pending[i] > 0 && txs[i].send(Cmd::Nop).is_err())
                    };
                    if let Some(id) = dead {
                        // close the replied-then-died race: prefer any
                        // reply that landed while we probed
                        if let Ok((rid, reply)) = self.reply_rx.try_recv() {
                            let pending = &mut self.pending.borrow_mut()[rid];
                            *pending = pending.saturating_sub(1);
                            return (rid, reply);
                        }
                        self.pending.borrow_mut()[id] = 0;
                        return (id, Reply::Fault);
                    }
                    // everyone in flight is alive — just a slow phase
                }
                Err(RecvTimeoutError::Disconnected) => {
                    unreachable!("leader holds a reply_tx clone")
                }
            }
        }
    }

    fn kill(&self, id: usize) {
        // FIFO with send: the Die lands in the mailbox ahead of any
        // later phase command, so the victim never partially executes
        // one. Ignore the error if the worker is already gone.
        let _ = self.cmd_txs.borrow()[id].send(Cmd::Die);
    }

    fn respawn(&self, id: usize, core: WorkerCore) {
        let (tx, rx) = channel::<Cmd>();
        let handle = spawn_worker(id, core, rx, self.reply_tx.clone());
        let old_tx = std::mem::replace(&mut self.cmd_txs.borrow_mut()[id], tx);
        drop(old_tx);
        let old = std::mem::replace(&mut self.handles.borrow_mut()[id], handle);
        // the old thread has already exited (that is why we are here);
        // join reaps it without blocking the phase
        let _ = old.join();
        self.pending.borrow_mut()[id] = 0;
    }

    fn kind(&self) -> ExecutorKind {
        ExecutorKind::Threaded
    }
}

impl Drop for Threaded {
    fn drop(&mut self) {
        for tx in self.cmd_txs.get_mut() {
            // a worker that already exited (killed or panicked) has
            // dropped its receiver; ignore the send error and still
            // join below so no thread outlives the cluster
            let _ = tx.send(Cmd::Shutdown);
        }
        for handle in self.handles.get_mut().drain(..) {
            let _ = handle.join();
        }
    }
}
