//! The real runtime: one persistent OS thread per worker, mailboxes
//! down, a shared reply channel up.
//!
//! All thread/channel primitives come from the [`super::sync`] shim
//! (`std` normally, `loom` under `--cfg loom`), so this exact protocol
//! — not a test double of it — is what the loom suite model-checks.

use std::cell::{Cell, RefCell};
use std::collections::VecDeque;
use std::time::Duration;

use crate::config::ExecutorKind;

use super::sync::{channel, spawn_named, JoinHandle, Receiver, RecvTimeoutError, Sender};
use super::{Cmd, Reply, Transport, WorkerCore};

/// Default for how long `recv` waits for a reply before probing
/// in-flight workers for liveness (overridable per cluster through the
/// recovery policy's `probe_ms`). Purely a detection latency: a
/// slow-but-alive phase survives any number of probe rounds untouched.
const PROBE_INTERVAL: Duration = Duration::from_millis(100);

/// Spawn one worker thread owning `core`, looping on its private
/// mailbox. [`Cmd::Nop`] (liveness probe) is swallowed without a reply;
/// [`Cmd::Die`] (simulated crash) exits the loop without replying —
/// both are intercepted here so [`WorkerCore::execute`] stays identical
/// across transports.
fn spawn_worker(
    id: usize,
    mut core: WorkerCore,
    rx: Receiver<Cmd>,
    reply_tx: Sender<(usize, Reply)>,
) -> JoinHandle<()> {
    spawn_named(format!("worker-{id}"), move || {
        while let Ok(cmd) = rx.recv() {
            match cmd {
                Cmd::Nop => continue,
                Cmd::Die => break,
                cmd => match core.execute(cmd) {
                    // a dead leader (dropped receiver) is a
                    // normal shutdown race, not an error
                    Some(reply) => {
                        if reply_tx.send((id, reply)).is_err() {
                            break;
                        }
                    }
                    None => break,
                },
            }
        }
    })
}

/// Thread-per-worker executor. Each of the P×Q threads owns its
/// [`WorkerCore`] (shard + scratch) outright and loops on its private
/// mailbox; all threads share one `Sender` back to the leader. Phases
/// overlap across cores for real — the leader's send-all/recv-all
/// barriers plus id-staged reduces keep the numbers bit-identical to
/// the in-process oracle (see the module docs in `transport/mod.rs`).
///
/// Fault detection: `recv` waits with a timeout; on expiry it probes
/// every in-flight worker with [`Cmd::Nop`] — a closed mailbox means
/// the thread exited without replying (killed or panicked), and the
/// leader gets `(id, `[`Reply::Fault`]`)` instead of hanging forever on
/// a reply that will never come. The `RefCell`s exist for
/// [`Transport::respawn`], which swaps in a fresh channel + thread
/// through `&self` (same single-leader-thread contract as the
/// in-process transport).
pub(crate) struct Threaded {
    cmd_txs: RefCell<Vec<Sender<Cmd>>>,
    /// kept alive so `recv` can never see `Disconnected` even with
    /// every worker dead (faults are reported per-worker instead)
    reply_tx: Sender<(usize, Reply)>,
    reply_rx: Receiver<(usize, Reply)>,
    handles: RefCell<Vec<JoinHandle<()>>>,
    /// in-flight commands per worker (≤ 1 under the phase barriers);
    /// only in-flight workers are probed, so an idle dead worker is
    /// reported exactly once per command addressed to it
    pending: RefCell<Vec<u32>>,
    /// workers whose send already failed — their synthetic faults,
    /// drained by `recv` before touching the reply channel
    faulted: RefCell<VecDeque<usize>>,
    /// liveness-probe timeout for `recv` (the recovery policy's
    /// `probe_ms`)
    probe: Duration,
    /// respawns left to refuse (fault-injection hook, see
    /// [`Transport::refuse_respawns`])
    refusals: Cell<usize>,
}

impl Threaded {
    pub(crate) fn spawn(cores: Vec<WorkerCore>) -> Threaded {
        Self::spawn_with_probe(cores, PROBE_INTERVAL)
    }

    pub(crate) fn spawn_with_probe(cores: Vec<WorkerCore>, probe: Duration) -> Threaded {
        let n = cores.len();
        let (reply_tx, reply_rx) = channel::<(usize, Reply)>();
        let mut cmd_txs = Vec::with_capacity(n);
        let mut handles = Vec::with_capacity(n);
        for (id, core) in cores.into_iter().enumerate() {
            let (tx, rx) = channel::<Cmd>();
            handles.push(spawn_worker(id, core, rx, reply_tx.clone()));
            cmd_txs.push(tx);
        }
        Threaded {
            cmd_txs: RefCell::new(cmd_txs),
            reply_tx,
            reply_rx,
            handles: RefCell::new(handles),
            pending: RefCell::new(vec![0; n]),
            faulted: RefCell::new(VecDeque::new()),
            probe,
            refusals: Cell::new(0),
        }
    }
}

impl Transport for Threaded {
    fn send(&self, id: usize, cmd: Cmd) -> bool {
        if self.cmd_txs.borrow()[id].send(cmd).is_ok() {
            self.pending.borrow_mut()[id] += 1;
            true
        } else {
            // mailbox closed: the thread already exited. Queue the
            // synthetic fault so the barrier still sees one reply.
            self.faulted.borrow_mut().push_back(id);
            false
        }
    }

    fn recv(&self) -> (usize, Reply) {
        if let Some(id) = self.faulted.borrow_mut().pop_front() {
            self.pending.borrow_mut()[id] = 0;
            return (id, Reply::Fault);
        }
        loop {
            match self.reply_rx.recv_timeout(self.probe) {
                Ok((id, reply)) => {
                    let pending = &mut self.pending.borrow_mut()[id];
                    *pending = pending.saturating_sub(1);
                    return (id, reply);
                }
                Err(RecvTimeoutError::Timeout) => {
                    // probe every in-flight worker: an Err means its
                    // mailbox receiver is gone, i.e. the thread exited
                    // without replying
                    let dead = {
                        let pending = self.pending.borrow();
                        let txs = self.cmd_txs.borrow();
                        (0..txs.len())
                            .find(|&i| pending[i] > 0 && txs[i].send(Cmd::Nop).is_err())
                    };
                    if let Some(id) = dead {
                        // close the replied-then-died race: prefer any
                        // reply that landed while we probed
                        if let Ok((rid, reply)) = self.reply_rx.try_recv() {
                            let pending = &mut self.pending.borrow_mut()[rid];
                            *pending = pending.saturating_sub(1);
                            return (rid, reply);
                        }
                        self.pending.borrow_mut()[id] = 0;
                        return (id, Reply::Fault);
                    }
                    // everyone in flight is alive — just a slow phase
                }
                Err(RecvTimeoutError::Disconnected) => {
                    unreachable!("leader holds a reply_tx clone")
                }
            }
        }
    }

    fn kill(&self, id: usize) {
        // FIFO with send: the Die lands in the mailbox ahead of any
        // later phase command, so the victim never partially executes
        // one. Ignore the error if the worker is already gone.
        let _ = self.cmd_txs.borrow()[id].send(Cmd::Die);
    }

    fn respawn(&self, id: usize, core: WorkerCore) -> bool {
        if self.refusals.get() > 0 {
            self.refusals.set(self.refusals.get() - 1);
            return false;
        }
        let (tx, rx) = channel::<Cmd>();
        let handle = spawn_worker(id, core, rx, self.reply_tx.clone());
        let old_tx = std::mem::replace(&mut self.cmd_txs.borrow_mut()[id], tx);
        drop(old_tx);
        let old = std::mem::replace(&mut self.handles.borrow_mut()[id], handle);
        // the old thread has already exited (that is why we are here);
        // join reaps it without blocking the phase
        let _ = old.join();
        self.pending.borrow_mut()[id] = 0;
        true
    }

    fn refuse_respawns(&self, n: usize) {
        self.refusals.set(self.refusals.get() + n);
    }

    fn kind(&self) -> ExecutorKind {
        ExecutorKind::Threaded
    }
}

impl Drop for Threaded {
    fn drop(&mut self) {
        for tx in self.cmd_txs.get_mut() {
            // a worker that already exited (killed or panicked) has
            // dropped its receiver; ignore the send error and still
            // join below so no thread outlives the cluster
            let _ = tx.send(Cmd::Shutdown);
        }
        for handle in self.handles.get_mut().drain(..) {
            let _ = handle.join();
        }
    }
}

/// Shutdown/recovery edge cases that the phase barriers in `Cluster`
/// never produce on their own. They double as the seed scenarios for
/// the loom suite (`loom_tests.rs`), which replays the same shapes
/// under exhaustive interleaving; here they run once on real OS
/// threads. Gated out under `--cfg loom`: these construct `Threaded`
/// outside a `loom::model`, where loom primitives panic.
#[cfg(all(test, not(loom)))]
mod tests {
    use std::sync::Arc;

    use super::super::InProcess;
    use super::*;
    use crate::data::{synth, Grid};
    use crate::engine::{ComputeEngine, NativeEngine};
    use crate::loss::Loss;

    fn cores(n: usize, m: usize, p: usize, q: usize, seed: u64) -> Vec<WorkerCore> {
        let ds = synth::dense_zhang(n, m, seed);
        let grid = Grid::partition(&ds, p, q).unwrap();
        let engine: Arc<dyn ComputeEngine> = Arc::new(NativeEngine);
        grid.blocks()
            .map(|b| WorkerCore::new(b.clone(), Arc::clone(&engine), Loss::Hinge))
            .collect()
    }

    /// A full-width `BlockLoss` for a block of `m_per` columns and
    /// `n_per` rows — the simplest command with a value-carrying reply.
    fn loss_cmd(n_per: usize, m_per: usize) -> Cmd {
        let w: Vec<f32> = (0..m_per).map(|j| 0.3 * j as f32 - 0.4).collect();
        let rows: Vec<u32> = (0..n_per as u32).collect();
        Cmd::BlockLoss { w: Arc::new(w), rows: Arc::new(rows) }
    }

    /// What the in-process oracle computes for the same core + command.
    fn oracle_loss(core: WorkerCore, cmd: Cmd) -> f64 {
        let oracle = InProcess::new(vec![core]);
        assert!(oracle.send(0, cmd));
        match oracle.recv() {
            (0, Reply::Loss(l)) => l,
            other => panic!("oracle returned {other:?}"),
        }
    }

    #[test]
    fn drop_with_reply_still_queued_joins_cleanly() {
        let t = Threaded::spawn(cores(8, 4, 2, 1, 3));
        assert!(t.send(0, loss_cmd(4, 4)));
        assert!(t.send(1, loss_cmd(4, 4)));
        // consume one reply, leave the other queued (or in flight) and
        // drop: Shutdown must still reach both workers and join must
        // not hang on the unread reply
        let (_, reply) = t.recv();
        assert!(matches!(reply, Reply::Loss(_)), "got {reply:?}");
        drop(t);
    }

    #[test]
    fn drop_after_kill_without_respawn_joins_cleanly() {
        let t = Threaded::spawn(cores(8, 4, 2, 1, 4));
        t.kill(0);
        // Drop's Shutdown send to the dead mailbox fails silently; the
        // join must still reap the exited thread and worker 1
        drop(t);
    }

    #[test]
    fn respawn_then_immediate_drop_joins_the_replacement() {
        let mut all = cores(8, 4, 2, 1, 5);
        let spare = all.remove(0);
        let replacement =
            WorkerCore::new(spare.block.clone(), Arc::clone(&spare.engine), Loss::Hinge);
        all.insert(0, spare);
        let t = Threaded::spawn(all);
        t.kill(0);
        // whether the send beats the Die into the mailbox or observes
        // it closed, the barrier sees exactly one fault for worker 0
        let _ = t.send(0, loss_cmd(4, 4));
        assert!(matches!(t.recv(), (0, Reply::Fault)));
        assert!(t.respawn(0, replacement));
        // no further traffic: Drop must shut down and join the
        // replacement thread it never spoke to
        drop(t);
    }

    #[test]
    fn probe_storm_never_misclassifies_a_slow_worker_as_dead() {
        // regression for the timeout-vs-death discrimination in `recv`:
        // with a probe interval far below the phase's compute time the
        // leader probes the in-flight worker over and over — every
        // `Cmd::Nop` must be swallowed by the live thread and the
        // eventual reply must be the real value, never a synthetic
        // `Reply::Fault` (a slow worker is a straggler, not a corpse)
        let mut all = cores(20_000, 16, 1, 1, 7);
        let core = all.pop().unwrap();
        let expected = oracle_loss(
            WorkerCore::new(core.block.clone(), Arc::clone(&core.engine), Loss::Hinge),
            loss_cmd(20_000, 16),
        );
        let t = Threaded::spawn_with_probe(vec![core], Duration::from_micros(50));
        for _ in 0..3 {
            assert!(t.send(0, loss_cmd(20_000, 16)));
            match t.recv() {
                (0, Reply::Loss(l)) => assert_eq!(l.to_bits(), expected.to_bits()),
                other => panic!("slow-but-alive worker was misclassified: {other:?}"),
            }
        }
        drop(t);
    }

    #[test]
    fn dead_and_slow_workers_are_told_apart_in_one_phase() {
        // one killed worker and one alive-but-slow worker in flight
        // under a short probe: the Nop sweep must fault exactly the
        // dead one while the slow one's reply still lands intact
        let all = cores(20_000, 16, 2, 1, 8);
        let t = Threaded::spawn_with_probe(all, Duration::from_micros(50));
        t.kill(0);
        let _ = t.send(0, loss_cmd(10_000, 16));
        assert!(t.send(1, loss_cmd(10_000, 16)));
        let (mut got_fault, mut got_loss) = (false, false);
        for _ in 0..2 {
            match t.recv() {
                (0, Reply::Fault) => got_fault = true,
                (1, Reply::Loss(_)) => got_loss = true,
                other => panic!("unexpected {other:?}"),
            }
        }
        assert!(got_fault, "the killed worker must surface exactly one fault");
        assert!(got_loss, "the slow worker's reply must survive the probe sweep");
        drop(t);
    }

    #[test]
    fn double_kill_in_one_phase_faults_once_then_recovers() {
        let mut all = cores(8, 4, 1, 1, 6);
        let core = all.pop().unwrap();
        let replacement =
            WorkerCore::new(core.block.clone(), Arc::clone(&core.engine), Loss::Hinge);
        let expected = oracle_loss(
            WorkerCore::new(core.block.clone(), Arc::clone(&core.engine), Loss::Hinge),
            loss_cmd(8, 4),
        );
        let t = Threaded::spawn(vec![core]);
        t.kill(0);
        t.kill(0); // second Die lands in a closing/closed mailbox: must be a no-op
        // either the send observes the closed mailbox (synthetic fault
        // queued) or it lands and the probe path detects the exited
        // thread — both must surface exactly one Fault, not two
        let _ = t.send(0, loss_cmd(8, 4));
        assert!(matches!(t.recv(), (0, Reply::Fault)));
        assert!(t.respawn(0, replacement));
        assert!(t.send(0, loss_cmd(8, 4)), "respawned worker must accept commands");
        match t.recv() {
            (0, Reply::Loss(l)) => {
                assert_eq!(l.to_bits(), expected.to_bits(), "replayed phase must match oracle")
            }
            other => panic!("expected a loss reply after respawn, got {other:?}"),
        }
        drop(t);
    }
}
