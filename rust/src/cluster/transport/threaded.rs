//! The real runtime: one persistent OS thread per worker, mailboxes
//! down, a shared reply channel up.

use std::sync::mpsc::{channel, Receiver, Sender};
use std::thread::JoinHandle;

use crate::config::ExecutorKind;

use super::{Cmd, Reply, Transport, WorkerCore};

/// Thread-per-worker executor. Each of the P×Q threads owns its
/// [`WorkerCore`] (shard + scratch) outright and loops on its private
/// mailbox; all threads share one `Sender` back to the leader. Phases
/// overlap across cores for real — the leader's send-all/recv-all
/// barriers plus id-staged reduces keep the numbers bit-identical to
/// the in-process oracle (see the module docs in `transport/mod.rs`).
pub(crate) struct Threaded {
    cmd_txs: Vec<Sender<Cmd>>,
    reply_rx: Receiver<(usize, Reply)>,
    handles: Vec<JoinHandle<()>>,
}

impl Threaded {
    pub(crate) fn spawn(cores: Vec<WorkerCore>) -> Threaded {
        let (reply_tx, reply_rx) = channel::<(usize, Reply)>();
        let mut cmd_txs = Vec::with_capacity(cores.len());
        let mut handles = Vec::with_capacity(cores.len());
        for (id, mut core) in cores.into_iter().enumerate() {
            let (tx, rx) = channel::<Cmd>();
            let reply_tx = reply_tx.clone();
            let handle = std::thread::Builder::new()
                .name(format!("worker-{id}"))
                .spawn(move || {
                    while let Ok(cmd) = rx.recv() {
                        match core.execute(cmd) {
                            // a dead leader (dropped receiver) is a
                            // normal shutdown race, not an error
                            Some(reply) => {
                                if reply_tx.send((id, reply)).is_err() {
                                    break;
                                }
                            }
                            None => break,
                        }
                    }
                })
                .expect("spawn worker thread");
            cmd_txs.push(tx);
            handles.push(handle);
        }
        Threaded { cmd_txs, reply_rx, handles }
    }
}

impl Transport for Threaded {
    fn send(&self, id: usize, cmd: Cmd) {
        self.cmd_txs[id].send(cmd).expect("worker thread hung up");
    }

    fn recv(&self) -> (usize, Reply) {
        self.reply_rx.recv().expect("all worker threads hung up")
    }

    fn kind(&self) -> ExecutorKind {
        ExecutorKind::Threaded
    }
}

impl Drop for Threaded {
    fn drop(&mut self) {
        for tx in &self.cmd_txs {
            // a worker that already exited (panicked) has dropped its
            // receiver; ignore the send error and still join below so
            // its panic propagates nowhere silently
            let _ = tx.send(Cmd::Shutdown);
        }
        for handle in self.handles.drain(..) {
            let _ = handle.join();
        }
    }
}
