//! The sequential oracle: every command executes inline on the leader
//! thread, in send order, with replies queued FIFO.

use std::cell::RefCell;
use std::collections::VecDeque;

use crate::config::ExecutorKind;

use super::{Cmd, Reply, Transport, WorkerCore};

/// Deterministic single-threaded executor. `send(id, cmd)` runs
/// [`WorkerCore::execute`] immediately and parks the reply; `recv`
/// hands finished replies back in completion (= send) order, which is
/// exactly the arrival-order distribution the threaded mode can
/// produce — the leader's id-staged reduces make the order invisible
/// either way, but keeping the FIFO shape means both transports
/// exercise identical leader code paths.
pub(crate) struct InProcess {
    // RefCell, not Mutex: the Transport trait is `Send` but not `Sync`,
    // and the leader drives phases from a single thread — `send`/`recv`
    // take `&self` only because the threaded transport's channel
    // endpoints do. The borrows here are strictly scoped to one call,
    // so the dynamic checks can never trip.
    workers: Vec<RefCell<WorkerCore>>,
    ready: RefCell<VecDeque<(usize, Reply)>>,
}

impl InProcess {
    pub(crate) fn new(cores: Vec<WorkerCore>) -> InProcess {
        let n = cores.len();
        InProcess {
            workers: cores.into_iter().map(RefCell::new).collect(),
            // pre-size to the grid: a phase has at most one outstanding
            // reply per worker, so the deque never reallocates
            ready: RefCell::new(VecDeque::with_capacity(n)),
        }
    }
}

impl Transport for InProcess {
    fn send(&self, id: usize, cmd: Cmd) {
        if let Some(reply) = self.workers[id].borrow_mut().execute(cmd) {
            self.ready.borrow_mut().push_back((id, reply));
        }
    }

    fn recv(&self) -> (usize, Reply) {
        self.ready.borrow_mut().pop_front().expect("recv() with no command in flight")
    }

    fn kind(&self) -> ExecutorKind {
        ExecutorKind::InProcess
    }
}
