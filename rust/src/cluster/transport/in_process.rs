//! The sequential oracle: every command executes inline on the leader
//! thread, in send order, with replies queued FIFO.

use std::cell::{Cell, RefCell};
use std::collections::VecDeque;

use crate::config::ExecutorKind;

use super::{Cmd, Reply, Transport, WorkerCore};

/// Deterministic single-threaded executor. `send(id, cmd)` runs
/// [`WorkerCore::execute`] immediately and parks the reply; `recv`
/// hands finished replies back in completion (= send) order, which is
/// exactly the arrival-order distribution the threaded mode can
/// produce — the leader's id-staged reduces make the order invisible
/// either way, but keeping the FIFO shape means both transports
/// exercise identical leader code paths. Faults are simulated the same
/// way: a killed slot stops executing and synthesizes
/// [`Reply::Fault`]s in the FIFO, so the leader's recovery path is
/// byte-identical across transports.
pub(crate) struct InProcess {
    // RefCell, not Mutex: the Transport trait is `Send` but not `Sync`,
    // and the leader drives phases from a single thread — `send`/`recv`
    // take `&self` only because the threaded transport's channel
    // endpoints do. The borrows here are strictly scoped to one call,
    // so the dynamic checks can never trip.
    workers: Vec<RefCell<WorkerCore>>,
    /// killed-and-not-yet-respawned flags (the inline analogue of a
    /// worker thread having exited)
    dead: Vec<Cell<bool>>,
    ready: RefCell<VecDeque<(usize, Reply)>>,
}

impl InProcess {
    pub(crate) fn new(cores: Vec<WorkerCore>) -> InProcess {
        let n = cores.len();
        InProcess {
            workers: cores.into_iter().map(RefCell::new).collect(),
            dead: (0..n).map(|_| Cell::new(false)).collect(),
            // pre-size to the grid: a phase has at most one outstanding
            // reply per worker, so the deque never reallocates
            ready: RefCell::new(VecDeque::with_capacity(n)),
        }
    }
}

impl Transport for InProcess {
    fn send(&self, id: usize, cmd: Cmd) -> bool {
        if self.dead[id].get() {
            // preserve the one-reply-per-send invariant: the barrier
            // still collects P·Q replies, this one marked as a fault
            self.ready.borrow_mut().push_back((id, Reply::Fault));
            return false;
        }
        if let Some(reply) = self.workers[id].borrow_mut().execute(cmd) {
            self.ready.borrow_mut().push_back((id, reply));
        }
        true
    }

    fn recv(&self) -> (usize, Reply) {
        self.ready.borrow_mut().pop_front().expect("recv() with no command in flight")
    }

    fn kill(&self, id: usize) {
        self.dead[id].set(true);
    }

    fn respawn(&self, id: usize, core: WorkerCore) -> bool {
        // the inline oracle rebuilds in place — respawn cannot fail, so
        // retry/escalation behavior is exercised on the threaded side
        *self.workers[id].borrow_mut() = core;
        self.dead[id].set(false);
        true
    }

    fn kind(&self) -> ExecutorKind {
        ExecutorKind::InProcess
    }
}
