//! Named experiment presets mirroring the paper's Tables 1 and 3.
//!
//! The paper's absolute sizes (up to 300k × 27k dense, 5.6M × 27k sparse)
//! exceed a laptop-scale CI budget; each preset stores the paper's
//! dimensions and a default laptop `scale` divisor. The partition
//! structure (P=5, Q=3), the generator, the loss and the learning-rate
//! schedule are exactly the paper's. Pass `--scale 1` to run paper-sized.

use super::{DataConfig, SamplingFractions};

/// A named dataset preset (Table 1 / Table 3 row).
#[derive(Debug, Clone, Copy)]
pub struct Preset {
    pub name: &'static str,
    /// Paper-size rows per observation partition × P.
    pub paper_n: usize,
    pub paper_m: usize,
    /// paper's executor count, for Table 1 reporting
    pub executors: usize,
    pub sparse: bool,
    /// avg nnz/row for sparse presets (SemMed-like density)
    pub avg_nnz: usize,
    /// default laptop divisor applied to both dimensions
    pub default_scale: usize,
}

/// Table 1 (dense synthetic) + Table 3 (sparse SemMed substitutes).
pub const PRESETS: &[Preset] = &[
    // Table 1: size of each partition × (P=5, Q=3)
    Preset { name: "small", paper_n: 250_000, paper_m: 18_000, executors: 18, sparse: false, avg_nnz: 0, default_scale: 50 },
    Preset { name: "medium", paper_n: 300_000, paper_m: 21_000, executors: 25, sparse: false, avg_nnz: 0, default_scale: 50 },
    Preset { name: "large", paper_n: 300_000, paper_m: 27_000, executors: 25, sparse: false, avg_nnz: 0, default_scale: 50 },
    // Table 3 (N, M as published; m̃ rounded to make M divisible by QP)
    Preset { name: "diag-neg10", paper_n: 425_185, paper_m: 26_946, executors: 15, sparse: true, avg_nnz: 30, default_scale: 85 },
    Preset { name: "loc-neg5", paper_n: 5_638_696, paper_m: 26_966, executors: 15, sparse: true, avg_nnz: 30, default_scale: 220 },
];

pub fn preset(name: &str) -> Option<&'static Preset> {
    PRESETS.iter().find(|p| p.name == name)
}

impl Preset {
    /// Concrete data config at `scale` (divides both dimensions, then
    /// rounds to P / Q·P divisibility).
    pub fn data_config(&self, scale: usize, p: usize, q: usize) -> DataConfig {
        let scale = scale.max(1);
        let n = round_to(self.paper_n / scale, p).max(p);
        let m = round_to(self.paper_m / scale, p * q).max(p * q);
        if self.sparse {
            DataConfig::Sparse { n, m, avg_nnz: self.avg_nnz }
        } else {
            DataConfig::Dense { n, m }
        }
    }

    pub fn fractions(&self) -> SamplingFractions {
        SamplingFractions::PAPER
    }
}

fn round_to(v: usize, multiple: usize) -> usize {
    let down = (v / multiple) * multiple;
    if down == 0 {
        multiple
    } else {
        down
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_named() {
        assert!(preset("small").is_some());
        assert!(preset("loc-neg5").is_some());
        assert!(preset("nope").is_none());
    }

    #[test]
    fn scaled_configs_divide_evenly() {
        for pr in PRESETS {
            for scale in [1usize, 10, 50, 640] {
                let dc = pr.data_config(scale, 5, 3);
                assert_eq!(dc.n() % 5, 0, "{} scale {scale}", pr.name);
                assert_eq!(dc.m() % 15, 0, "{} scale {scale}", pr.name);
            }
        }
    }

    #[test]
    fn sparse_flag_respected() {
        assert!(matches!(preset("diag-neg10").unwrap().data_config(10, 5, 3), DataConfig::Sparse { .. }));
        assert!(matches!(preset("small").unwrap().data_config(10, 5, 3), DataConfig::Dense { .. }));
    }
}
