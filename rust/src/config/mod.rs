//! Typed experiment configuration: the single source of truth a run is
//! launched from (CLI flags build one; JSON files round-trip it; presets
//! mirror the paper's Tables 1 and 3 at configurable scale).
//!
//! Construct configs through [`ExperimentConfig::builder`] — the builder
//! applies the paper's defaults and validates at build time, so every
//! config that reaches a [`crate::train::Trainer`] is known-good.

mod builder;
mod presets;
mod schedule;

pub use builder::ExperimentConfigBuilder;
pub use presets::{preset, Preset, PRESETS};
pub use schedule::Schedule;

use anyhow::{ensure, Result};

use crate::loss::Loss;
use crate::util::json::{self, Value};

/// Which optimizer variant to run (paper §3 / §5).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum AlgorithmKind {
    /// Algorithm 1: stochastic (b, c, d)-sampled full-gradient estimate.
    Sodda,
    /// RADiSA: exact full gradient each outer iteration
    /// (`b = c = M, d = N`), sub-block updates concatenated.
    Radisa,
    /// RADiSA-avg: the paper's benchmark — like RADiSA but the sub-block
    /// solutions overlapping the same `w_[q]` are averaged across the P
    /// random assignments instead of concatenated once.
    RadisaAvg,
}

impl std::fmt::Display for AlgorithmKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            AlgorithmKind::Sodda => "sodda",
            AlgorithmKind::Radisa => "radisa",
            AlgorithmKind::RadisaAvg => "radisa-avg",
        })
    }
}

impl std::str::FromStr for AlgorithmKind {
    type Err = String;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s.to_ascii_lowercase().as_str() {
            "sodda" => Ok(Self::Sodda),
            "radisa" => Ok(Self::Radisa),
            "radisa-avg" | "radisa_avg" | "radisaavg" => Ok(Self::RadisaAvg),
            other => Err(format!("unknown algorithm {other:?}")),
        }
    }
}

/// Which compute backend executes the kernels.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum EngineKind {
    /// Pure-rust math (always available; sparse-aware).
    #[default]
    Native,
    /// AOT-compiled JAX/Pallas artifacts through the PJRT CPU client.
    Xla,
}

impl std::str::FromStr for EngineKind {
    type Err = String;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s.to_ascii_lowercase().as_str() {
            "native" => Ok(Self::Native),
            "xla" => Ok(Self::Xla),
            other => Err(format!("unknown engine {other:?} (native|xla)")),
        }
    }
}

/// Which executor runs the P×Q workers (see `cluster/transport/`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ExecutorKind {
    /// Sequential in-process oracle: every worker command executes
    /// inline on the leader thread, in a fixed order. Deterministic,
    /// thread-free, and the bit-frozen reference for the threaded mode.
    #[default]
    InProcess,
    /// Persistent thread-per-worker runtime: each of the P×Q workers
    /// owns its shard on its own OS thread; phases overlap across
    /// cores. Bit-identical trajectories to [`ExecutorKind::InProcess`]
    /// (see the determinism contract in `cluster/transport/`).
    Threaded,
}

impl std::fmt::Display for ExecutorKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            ExecutorKind::InProcess => "in-process",
            ExecutorKind::Threaded => "threaded",
        })
    }
}

impl std::str::FromStr for ExecutorKind {
    type Err = String;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s.to_ascii_lowercase().as_str() {
            "in-process" | "inprocess" | "in_process" | "sequential" => Ok(Self::InProcess),
            "threaded" | "threads" | "thread" => Ok(Self::Threaded),
            other => Err(format!("unknown executor {other:?} (in-process|threaded)")),
        }
    }
}

impl ExecutorKind {
    /// The env override knob read by [`ExecutorKind::resolve`].
    pub const ENV: &'static str = "SODDA_EXECUTOR";

    /// Resolve the executor to run: an explicit preference (the config's
    /// `executor` field) wins; otherwise a non-empty `SODDA_EXECUTOR`
    /// env value is parsed (errors on garbage rather than silently
    /// falling back — CI lanes rely on the knob actually engaging); with
    /// neither, the in-process oracle.
    pub fn resolve(pref: Option<ExecutorKind>) -> Result<ExecutorKind> {
        if let Some(kind) = pref {
            return Ok(kind);
        }
        match crate::util::env::read(Self::ENV) {
            Some(v) if !v.is_empty() => {
                v.parse().map_err(|e: String| anyhow::anyhow!("{}: {e}", Self::ENV))
            }
            _ => Ok(ExecutorKind::InProcess),
        }
    }
}

/// Dataset specification.
#[derive(Debug, Clone, PartialEq)]
pub enum DataConfig {
    /// §5.1 dense synthetic (Zhang et al. generator).
    Dense { n: usize, m: usize },
    /// §5.2 sparse SemMed/PRA substitute.
    Sparse { n: usize, m: usize, avg_nnz: usize },
    /// External dataset on disk (`.svm`/`.libsvm` text or `.bin` binary,
    /// written by `repro gen-data` or any LIBSVM tool). Dimensions are
    /// read at load time; `n`/`m` here are what the file is expected to
    /// contain (validated on materialize).
    File { path: String, n: usize, m: usize },
}

impl DataConfig {
    pub fn n(&self) -> usize {
        match self {
            DataConfig::Dense { n, .. }
            | DataConfig::Sparse { n, .. }
            | DataConfig::File { n, .. } => *n,
        }
    }

    pub fn m(&self) -> usize {
        match self {
            DataConfig::Dense { m, .. }
            | DataConfig::Sparse { m, .. }
            | DataConfig::File { m, .. } => *m,
        }
    }

    /// Generate (synthetic) or load (file) the dataset. Fallible: file
    /// configs can hit I/O or dimension-mismatch errors, and callers on
    /// the session path propagate them instead of panicking.
    pub fn try_materialize(&self, seed: u64) -> Result<crate::data::Dataset> {
        match self {
            &DataConfig::Dense { n, m } => Ok(crate::data::synth::dense_zhang(n, m, seed)),
            &DataConfig::Sparse { n, m, avg_nnz } => {
                Ok(crate::data::synth::sparse_pra(n, m, avg_nnz, seed))
            }
            DataConfig::File { path, n, m } => {
                let p = std::path::Path::new(path);
                let ds = if path.ends_with(".bin") {
                    crate::data::io::read_binary(p)?
                } else {
                    crate::data::io::read_libsvm(p, *m)?
                };
                ensure!(
                    ds.n() == *n && ds.m() == *m,
                    "{path}: contains {}x{}, config expects {n}x{m}",
                    ds.n(),
                    ds.m()
                );
                Ok(ds)
            }
        }
    }
}

/// Fractions of the paper's `(b^t, c^t, d^t)` sequences, as constants in
/// (0, 1]. The paper's tuned values are `(0.85, 0.80, 0.85)` (§5.3).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SamplingFractions {
    /// `b^t / M` — features used in inner products.
    pub b: f64,
    /// `c^t / b^t`-independent: `c^t / M` — gradient coordinates kept.
    pub c: f64,
    /// `d^t / N` — observations sampled for µ^t.
    pub d: f64,
}

impl SamplingFractions {
    pub const PAPER: SamplingFractions = SamplingFractions { b: 0.85, c: 0.80, d: 0.85 };
    pub const FULL: SamplingFractions = SamplingFractions { b: 1.0, c: 1.0, d: 1.0 };

    pub fn validate(&self) -> Result<()> {
        for (name, v) in [("b", self.b), ("c", self.c), ("d", self.d)] {
            ensure!(v > 0.0 && v <= 1.0, "fraction {name}={v} outside (0, 1]");
        }
        ensure!(self.c <= self.b, "c^t must be ≤ b^t (C^t ⊆ B^t), got c={} > b={}", self.c, self.b);
        Ok(())
    }
}

/// SimNet cost-model parameters (models the paper's 4-node cluster).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct NetworkConfig {
    /// Per-message latency, seconds.
    pub latency_s: f64,
    /// Link bandwidth, bytes/second.
    pub bandwidth_bps: f64,
}

impl Default for NetworkConfig {
    fn default() -> Self {
        // 1 GbE-ish with datacenter-LAN latency
        Self { latency_s: 50e-6, bandwidth_bps: 125e6 }
    }
}

/// Named heterogeneity shapes a [`ClusterProfile`] resolves against the
/// P·Q worker grid. Private on purpose: profiles are built through the
/// preset constructors so every shape that reaches the cost model has
/// been validated.
#[derive(Debug, Clone, PartialEq)]
enum ProfileShape {
    /// Every worker runs at the base rate.
    Uniform,
    /// Worker 0 runs `factor`× slower than the rest — the classic
    /// single-straggler regime.
    OneSlow { factor: f64 },
    /// Rates decay smoothly from the base rate down to `1/factor` with
    /// a cubic profile: most workers near full speed, a slow tail.
    LongTail { factor: f64 },
    /// One relative rate per worker, indexed by `wid = p·Q + q`.
    Explicit { rates: Vec<f64> },
}

/// Per-worker cluster heterogeneity: the simulated cost model's view of
/// relative worker throughput and link latency. This is the sealed
/// replacement for the old bare `CostModel` struct — profiles can only
/// be built through the preset constructors here and reach
/// `SimNet` via the validated config surface, so the cost model can no
/// longer be assembled ad hoc outside `config/`.
///
/// A profile is resolved against the concrete P·Q grid at staging time:
/// [`ClusterProfile::rates`] yields one relative-throughput multiplier
/// per worker (1.0 = the base `flops_per_sec`), and the simulated
/// makespan of a barrier phase becomes `max_worker(flops_w / rate_w)`.
/// Per-link latency skew collapses to a single multiplier at the
/// barrier (the leader waits for the slowest link), carried by
/// [`ClusterProfile::link_latency_factor`]; bandwidth remains
/// leader-serialized as before.
#[derive(Debug, Clone, PartialEq)]
pub struct ClusterProfile {
    /// Base worker throughput in flops/second (rate multiplier 1.0).
    flops_per_sec: f64,
    shape: ProfileShape,
    /// Latency multiplier on the slowest worker's link (≥ 1).
    link_latency_factor: f64,
}

impl Default for ClusterProfile {
    fn default() -> Self {
        ClusterProfile::uniform()
    }
}

impl ClusterProfile {
    /// Base throughput of the historical cost model (kept bit-compatible:
    /// a uniform profile at this rate reproduces pre-profile `sim_s`
    /// values exactly).
    pub const DEFAULT_FLOPS_PER_SEC: f64 = 2e8;

    fn with_shape(shape: ProfileShape) -> Self {
        ClusterProfile {
            flops_per_sec: Self::DEFAULT_FLOPS_PER_SEC,
            shape,
            link_latency_factor: 1.0,
        }
    }

    /// Every worker at the base rate — the pre-profile behavior.
    pub fn uniform() -> Self {
        Self::with_shape(ProfileShape::Uniform)
    }

    /// Worker 0 runs `factor`× slower than the rest.
    pub fn one_slow(factor: f64) -> Self {
        Self::with_shape(ProfileShape::OneSlow { factor })
    }

    /// Rates decay cubically from the base rate to `1/factor`: most
    /// workers fast, a slow tail.
    pub fn long_tail(factor: f64) -> Self {
        Self::with_shape(ProfileShape::LongTail { factor })
    }

    /// One relative rate per worker, indexed by `wid = p·Q + q`; the
    /// vector length must equal P·Q (validated at build time).
    pub fn explicit(rates: Vec<f64>) -> Self {
        Self::with_shape(ProfileShape::Explicit { rates })
    }

    /// Override the base worker throughput (flops/second).
    pub fn with_flops_per_sec(mut self, flops_per_sec: f64) -> Self {
        self.flops_per_sec = flops_per_sec;
        self
    }

    /// Multiply the slowest link's latency by `factor` (≥ 1); the
    /// barrier charge waits for that link every round.
    pub fn with_link_latency_factor(mut self, factor: f64) -> Self {
        self.link_latency_factor = factor;
        self
    }

    pub fn flops_per_sec(&self) -> f64 {
        self.flops_per_sec
    }

    pub fn link_latency_factor(&self) -> f64 {
        self.link_latency_factor
    }

    /// True when every worker runs at the same rate (the shape is
    /// uniform, or explicit with all-equal entries).
    pub fn is_uniform(&self) -> bool {
        match &self.shape {
            ProfileShape::Uniform => true,
            ProfileShape::OneSlow { factor } | ProfileShape::LongTail { factor } => *factor == 1.0,
            ProfileShape::Explicit { rates } => rates.windows(2).all(|w| w[0] == w[1]),
        }
    }

    /// The preset's wire name (serialization + CLI echo).
    pub fn preset_name(&self) -> &'static str {
        match self.shape {
            ProfileShape::Uniform => "uniform",
            ProfileShape::OneSlow { .. } => "one-slow",
            ProfileShape::LongTail { .. } => "long-tail",
            ProfileShape::Explicit { .. } => "explicit",
        }
    }

    /// Resolve the shape against a concrete grid: one relative rate per
    /// worker, in `wid = p·Q + q` order, each in `(0, 1]`-ish units of
    /// the base rate.
    pub fn rates(&self, workers: usize) -> Vec<f64> {
        match &self.shape {
            ProfileShape::Uniform => vec![1.0; workers],
            ProfileShape::OneSlow { factor } => {
                let mut r = vec![1.0; workers];
                if let Some(first) = r.first_mut() {
                    *first = 1.0 / factor;
                }
                r
            }
            ProfileShape::LongTail { factor } => (0..workers)
                .map(|i| {
                    let frac = if workers > 1 { i as f64 / (workers - 1) as f64 } else { 1.0 };
                    1.0 / (1.0 + (factor - 1.0) * frac * frac * frac)
                })
                .collect(),
            ProfileShape::Explicit { rates } => rates.clone(),
        }
    }

    /// Validate against the concrete worker count (called from
    /// [`ExperimentConfig::validate`], which knows P·Q).
    pub fn validate(&self, workers: usize) -> Result<()> {
        ensure!(
            self.flops_per_sec.is_finite() && self.flops_per_sec > 0.0,
            "cluster profile: flops_per_sec={} must be finite and positive",
            self.flops_per_sec
        );
        ensure!(
            self.link_latency_factor.is_finite() && self.link_latency_factor >= 1.0,
            "cluster profile: link_latency_factor={} must be ≥ 1",
            self.link_latency_factor
        );
        match &self.shape {
            ProfileShape::Uniform => {}
            ProfileShape::OneSlow { factor } | ProfileShape::LongTail { factor } => {
                ensure!(
                    factor.is_finite() && *factor >= 1.0,
                    "cluster profile: slowdown factor {factor} must be ≥ 1"
                );
            }
            ProfileShape::Explicit { rates } => {
                ensure!(
                    rates.len() == workers,
                    "cluster profile: {} explicit rates for {workers} workers (need P·Q)",
                    rates.len()
                );
                for (i, r) in rates.iter().enumerate() {
                    ensure!(
                        r.is_finite() && *r > 0.0,
                        "cluster profile: rate[{i}]={r} must be finite and positive"
                    );
                }
            }
        }
        Ok(())
    }

    fn to_json_value(&self) -> Value {
        let mut fields = vec![
            ("shape", json::s(self.preset_name())),
            ("flops_per_sec", json::num(self.flops_per_sec)),
        ];
        match &self.shape {
            ProfileShape::Uniform => {}
            ProfileShape::OneSlow { factor } | ProfileShape::LongTail { factor } => {
                fields.push(("factor", json::num(*factor)));
            }
            ProfileShape::Explicit { rates } => {
                fields.push(("rates", Value::Arr(rates.iter().map(|&r| json::num(r)).collect())));
            }
        }
        if self.link_latency_factor != 1.0 {
            fields.push(("link_latency_factor", json::num(self.link_latency_factor)));
        }
        json::obj(fields)
    }

    fn from_json_value(v: &Value) -> Result<Self> {
        let shape = match v.get("shape")?.as_str()? {
            "uniform" => ProfileShape::Uniform,
            "one-slow" => ProfileShape::OneSlow { factor: v.get("factor")?.as_f64()? },
            "long-tail" => ProfileShape::LongTail { factor: v.get("factor")?.as_f64()? },
            "explicit" => ProfileShape::Explicit {
                rates: v
                    .get("rates")?
                    .as_arr()?
                    .iter()
                    .map(|r| r.as_f64())
                    .collect::<Result<Vec<f64>>>()?,
            },
            other => anyhow::bail!("unknown cluster profile shape {other:?}"),
        };
        Ok(ClusterProfile {
            flops_per_sec: v.get("flops_per_sec")?.as_f64()?,
            shape,
            link_latency_factor: v
                .opt("link_latency_factor")
                .map(|f| f.as_f64())
                .transpose()?
                .unwrap_or(1.0),
        })
    }
}

impl std::fmt::Display for ClusterProfile {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match &self.shape {
            ProfileShape::Uniform => f.write_str("uniform"),
            ProfileShape::OneSlow { factor } => write!(f, "one-slow:{factor}"),
            ProfileShape::LongTail { factor } => write!(f, "long-tail:{factor}"),
            ProfileShape::Explicit { rates } => write!(f, "explicit({} rates)", rates.len()),
        }
    }
}

/// CLI syntax: `uniform`, `one-slow[:factor]`, `long-tail[:factor]`,
/// `explicit:r0,r1,...` (default factor 4).
impl std::str::FromStr for ClusterProfile {
    type Err = String;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        let (name, arg) = match s.split_once(':') {
            Some((n, a)) => (n, Some(a)),
            None => (s, None),
        };
        let factor = |default: f64| -> Result<f64, String> {
            match arg {
                Some(a) => a.parse::<f64>().map_err(|e| format!("profile factor {a:?}: {e}")),
                None => Ok(default),
            }
        };
        match name.to_ascii_lowercase().as_str() {
            "uniform" => Ok(ClusterProfile::uniform()),
            "one-slow" | "one_slow" | "oneslow" => Ok(ClusterProfile::one_slow(factor(4.0)?)),
            "long-tail" | "long_tail" | "longtail" => Ok(ClusterProfile::long_tail(factor(4.0)?)),
            "explicit" => {
                let list = arg.ok_or("explicit profile needs rates: explicit:r0,r1,...")?;
                let rates = list
                    .split(',')
                    .map(|r| r.trim().parse::<f64>().map_err(|e| format!("rate {r:?}: {e}")))
                    .collect::<Result<Vec<f64>, String>>()?;
                Ok(ClusterProfile::explicit(rates))
            }
            other => Err(format!(
                "unknown cluster profile {other:?} (uniform|one-slow[:f]|long-tail[:f]|explicit:r0,r1,...)"
            )),
        }
    }
}

/// How the `Trainer` sizes row shards across the P observation
/// partitions at staging time.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ShardWeighting {
    /// Equal-sized shards (floor-balanced boundary vectors) — the
    /// historical behavior.
    #[default]
    Balanced,
    /// Shards proportional to worker throughput from the cluster
    /// profile: a row partition's weight is the slowest rate among its
    /// Q workers, so barrier-bound phases finish together under skewed
    /// profiles.
    Throughput,
}

impl std::fmt::Display for ShardWeighting {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            ShardWeighting::Balanced => "balanced",
            ShardWeighting::Throughput => "throughput",
        })
    }
}

impl std::str::FromStr for ShardWeighting {
    type Err = String;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s.to_ascii_lowercase().as_str() {
            "balanced" => Ok(Self::Balanced),
            "throughput" | "weighted" => Ok(Self::Throughput),
            other => Err(format!("unknown shard weighting {other:?} (balanced|throughput)")),
        }
    }
}

/// Leader-side fault recovery policy: how many respawn attempts a dead
/// worker gets (with linear backoff between them) before the leader
/// *escalates* the fault to a permanent loss, and how long the threaded
/// executor waits on a silent reply channel before probing worker
/// liveness. `None` on the config means [`RecoveryPolicy::default`].
///
/// Escalation is not an error path: on permanent loss the `Trainer`
/// re-shards the surviving data onto a shrunk grid and continues (see
/// `Trainer::step`), charging SimNet the shuffle cost.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RecoveryPolicy {
    /// Respawn attempts per fault before escalating to permanent loss.
    pub max_retries: usize,
    /// Sleep between respawn attempts, milliseconds (attempt `k` waits
    /// `k · backoff_ms`). Real time, not simulated — SimNet cost is
    /// charged by the re-shard step, not the retry loop.
    pub backoff_ms: u64,
    /// Threaded-executor liveness probe timeout, milliseconds: how long
    /// the leader waits on a silent reply channel before pinging the
    /// in-flight workers.
    pub probe_ms: u64,
}

impl Default for RecoveryPolicy {
    fn default() -> Self {
        // probe_ms matches the pre-policy hardwired 100ms probe interval
        RecoveryPolicy { max_retries: 3, backoff_ms: 10, probe_ms: 100 }
    }
}

impl RecoveryPolicy {
    pub fn validate(&self) -> Result<()> {
        ensure!(self.max_retries >= 1, "recovery policy: max_retries must be ≥ 1");
        ensure!(self.probe_ms >= 1, "recovery policy: probe_ms must be ≥ 1");
        ensure!(self.backoff_ms <= 10_000, "recovery policy: backoff_ms={} > 10s is surely a typo", self.backoff_ms);
        Ok(())
    }

    fn to_json_value(&self) -> Value {
        json::obj(vec![
            ("max_retries", json::num(self.max_retries as f64)),
            ("backoff_ms", json::num(self.backoff_ms as f64)),
            ("probe_ms", json::num(self.probe_ms as f64)),
        ])
    }

    fn from_json_value(v: &Value) -> Result<Self> {
        Ok(RecoveryPolicy {
            max_retries: v.get("max_retries")?.as_usize()?,
            backoff_ms: v.get("backoff_ms")?.as_usize()? as u64,
            probe_ms: v.get("probe_ms")?.as_usize()? as u64,
        })
    }
}

impl std::fmt::Display for RecoveryPolicy {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}:{}:{}", self.max_retries, self.backoff_ms, self.probe_ms)
    }
}

/// CLI syntax: `retries[:backoff_ms[:probe_ms]]` — omitted fields keep
/// their defaults (`3:10:100`).
impl std::str::FromStr for RecoveryPolicy {
    type Err = String;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        let mut policy = RecoveryPolicy::default();
        let mut parts = s.split(':');
        let retries = parts.next().unwrap_or("").trim();
        policy.max_retries =
            retries.parse().map_err(|e| format!("recovery retries {retries:?}: {e}"))?;
        if let Some(b) = parts.next() {
            policy.backoff_ms =
                b.trim().parse().map_err(|e| format!("recovery backoff_ms {b:?}: {e}"))?;
        }
        if let Some(p) = parts.next() {
            policy.probe_ms =
                p.trim().parse().map_err(|e| format!("recovery probe_ms {p:?}: {e}"))?;
        }
        if let Some(extra) = parts.next() {
            return Err(format!(
                "recovery policy {s:?}: trailing {extra:?} (syntax: retries[:backoff_ms[:probe_ms]])"
            ));
        }
        Ok(policy)
    }
}

/// Bounded-staleness aggregation policy (ROADMAP item 3): the outer
/// loop's µ and gradient phases stop waiting for the full P·Q barrier
/// and proceed once `⌈quorum_frac · P·Q⌉` block replies land (or a
/// profile-derived timeout fires). Replies outside the quorum are
/// parked in a `LateSet` and folded into the matching phase of a later
/// iteration with an age-discounted weight; entries older than
/// `max_staleness_iters` are dropped and recorded. `None` on the config
/// (or `quorum_frac = 1.0`) is the hard barrier — bit-frozen.
///
/// Quorum membership is decided on *modeled* per-worker phase times
/// (the active [`ClusterProfile`] rates plus any armed `FaultPlan`
/// slowdowns), never wall-clock, so both executors produce identical
/// trajectories and staleness logs.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct StalenessPolicy {
    /// Fraction of the P·Q block replies the leader waits for before
    /// proceeding, in (0, 1]. `1.0` = the full barrier (bit-frozen).
    pub quorum_frac: f64,
    /// Parked replies older than this many outer iterations are dropped
    /// (and counted in the `StalenessRecord`) instead of folded.
    pub max_staleness_iters: usize,
    /// Straggler deadline as a multiple of the *fastest* worker's
    /// modeled phase time: replies that would land after
    /// `timeout_factor × t_min` are parked even if the quorum count has
    /// not been reached yet (≥ 1).
    pub timeout_factor: f64,
}

impl Default for StalenessPolicy {
    fn default() -> Self {
        // quorum_frac 1.0 = hard barrier: the default policy is
        // bit-identical to no policy at all
        StalenessPolicy { quorum_frac: 1.0, max_staleness_iters: 2, timeout_factor: 4.0 }
    }
}

impl StalenessPolicy {
    /// The env override knob: a non-empty `SODDA_STALENESS` value is
    /// parsed at Trainer staging when the config carries no explicit
    /// policy (an explicit `.staleness(...)` pin always wins).
    pub const ENV: &'static str = "SODDA_STALENESS";

    /// True when this policy is the hard barrier (no quorum cut, no
    /// timeouts, no late folding) — the bit-frozen default path.
    pub fn is_barrier(&self) -> bool {
        self.quorum_frac >= 1.0
    }

    pub fn validate(&self) -> Result<()> {
        ensure!(
            self.quorum_frac.is_finite() && self.quorum_frac > 0.0 && self.quorum_frac <= 1.0,
            "staleness policy: quorum_frac={} outside (0, 1]",
            self.quorum_frac
        );
        ensure!(
            self.max_staleness_iters >= 1,
            "staleness policy: max_staleness_iters must be ≥ 1"
        );
        ensure!(
            self.timeout_factor.is_finite() && self.timeout_factor >= 1.0,
            "staleness policy: timeout_factor={} must be ≥ 1",
            self.timeout_factor
        );
        Ok(())
    }

    fn to_json_value(&self) -> Value {
        json::obj(vec![
            ("quorum_frac", json::num(self.quorum_frac)),
            ("max_staleness_iters", json::num(self.max_staleness_iters as f64)),
            ("timeout_factor", json::num(self.timeout_factor)),
        ])
    }

    fn from_json_value(v: &Value) -> Result<Self> {
        Ok(StalenessPolicy {
            quorum_frac: v.get("quorum_frac")?.as_f64()?,
            max_staleness_iters: v.get("max_staleness_iters")?.as_usize()?,
            timeout_factor: v.get("timeout_factor")?.as_f64()?,
        })
    }
}

impl std::fmt::Display for StalenessPolicy {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}:{}:{}", self.quorum_frac, self.max_staleness_iters, self.timeout_factor)
    }
}

/// CLI syntax: `quorum_frac[:max_staleness[:timeout_factor]]` — omitted
/// fields keep their defaults (`1:2:4`).
impl std::str::FromStr for StalenessPolicy {
    type Err = String;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        let mut policy = StalenessPolicy::default();
        let mut parts = s.split(':');
        let frac = parts.next().unwrap_or("").trim();
        policy.quorum_frac =
            frac.parse().map_err(|e| format!("staleness quorum_frac {frac:?}: {e}"))?;
        if let Some(m) = parts.next() {
            policy.max_staleness_iters =
                m.trim().parse().map_err(|e| format!("staleness max_staleness {m:?}: {e}"))?;
        }
        if let Some(t) = parts.next() {
            policy.timeout_factor =
                t.trim().parse().map_err(|e| format!("staleness timeout_factor {t:?}: {e}"))?;
        }
        if let Some(extra) = parts.next() {
            return Err(format!(
                "staleness policy {s:?}: trailing {extra:?} (syntax: quorum[:max_stale[:timeout]])"
            ));
        }
        Ok(policy)
    }
}

/// Everything needed to launch one training run.
#[derive(Debug, Clone)]
pub struct ExperimentConfig {
    pub name: String,
    pub data: DataConfig,
    /// observation partitions (paper default 5)
    pub p: usize,
    /// feature partitions (paper default 3)
    pub q: usize,
    pub loss: Loss,
    pub algorithm: AlgorithmKind,
    pub fractions: SamplingFractions,
    /// inner-loop length L
    pub inner_steps: usize,
    /// outer iterations T
    pub outer_iters: usize,
    pub schedule: Schedule,
    pub seed: u64,
    pub engine: EngineKind,
    /// which executor runs the workers; `None` = auto (the
    /// `SODDA_EXECUTOR` env knob if set, else the in-process oracle —
    /// see [`ExecutorKind::resolve`])
    pub executor: Option<ExecutorKind>,
    pub network: Option<NetworkConfig>,
    /// per-worker throughput/latency heterogeneity for the simulated
    /// cost model; `None` = uniform workers at the default rate (the
    /// historical behavior, bit-frozen)
    pub cluster_profile: Option<ClusterProfile>,
    /// how row shards are sized across the P partitions (see
    /// [`ShardWeighting`]); `Balanced` is the historical behavior
    pub shard_weighting: ShardWeighting,
    /// fault retry/escalation policy (see [`RecoveryPolicy`]); `None` =
    /// the default policy (3 retries, 10ms backoff, 100ms probe)
    pub recovery: Option<RecoveryPolicy>,
    /// bounded-staleness aggregation policy (see [`StalenessPolicy`]);
    /// `None` = hard barrier unless the `SODDA_STALENESS` env knob is
    /// set at staging time (an explicit policy here always wins)
    pub staleness: Option<StalenessPolicy>,
    /// evaluate F(w) every k outer iterations (1 = every iteration)
    pub eval_every: usize,
    /// reject shapes that don't divide evenly into the grid (the paper's
    /// `n = N/P`, `m̃ = M/QP` assumption). Off by default: the
    /// partitioner balances ragged blocks automatically. Validation-only
    /// — it never changes how an accepted config trains.
    pub strict_even_grid: bool,
}

impl ExperimentConfig {
    pub fn validate(&self) -> Result<()> {
        ensure!(self.p > 0 && self.q > 0, "P, Q must be positive");
        ensure!(
            self.data.n() >= self.p,
            "N={} < P={} would leave empty observation partitions",
            self.data.n(),
            self.p
        );
        ensure!(
            self.data.m() >= self.p * self.q,
            "M={} < P·Q={} would leave empty sub-blocks",
            self.data.m(),
            self.p * self.q
        );
        if self.strict_even_grid {
            ensure!(self.data.n() % self.p == 0, "N={} % P={} != 0", self.data.n(), self.p);
            ensure!(
                self.data.m() % (self.p * self.q) == 0,
                "M={} % (Q·P)={} != 0",
                self.data.m(),
                self.p * self.q
            );
        }
        ensure!(self.inner_steps > 0, "inner_steps must be positive");
        ensure!(self.outer_iters > 0, "outer_iters must be positive");
        ensure!(self.eval_every > 0, "eval_every must be positive");
        if let Some(profile) = &self.cluster_profile {
            profile.validate(self.p * self.q)?;
        }
        if let Some(recovery) = &self.recovery {
            recovery.validate()?;
        }
        if let Some(staleness) = &self.staleness {
            staleness.validate()?;
        }
        if self.shard_weighting == ShardWeighting::Throughput {
            ensure!(
                self.engine != EngineKind::Xla,
                "throughput-weighted shards produce non-uniform layouts; the XLA engine \
                 requires uniform block shapes"
            );
            ensure!(
                !self.strict_even_grid,
                "strict_even_grid contradicts throughput weighting (weighted boundary \
                 vectors are deliberately uneven)"
            );
        }
        self.fractions.validate()?;
        self.schedule.validate()?;
        Ok(())
    }

    /// Serialize to pretty JSON (offline build: in-tree json, no serde).
    pub fn to_json(&self) -> String {
        let data = match self.data {
            DataConfig::Dense { n, m } => json::obj(vec![
                ("kind", json::s("dense")),
                ("n", json::num(n as f64)),
                ("m", json::num(m as f64)),
            ]),
            DataConfig::Sparse { n, m, avg_nnz } => json::obj(vec![
                ("kind", json::s("sparse")),
                ("n", json::num(n as f64)),
                ("m", json::num(m as f64)),
                ("avg_nnz", json::num(avg_nnz as f64)),
            ]),
            DataConfig::File { ref path, n, m } => json::obj(vec![
                ("kind", json::s("file")),
                ("path", json::s(path.clone())),
                ("n", json::num(n as f64)),
                ("m", json::num(m as f64)),
            ]),
        };
        let schedule = match self.schedule {
            Schedule::PaperSqrt => json::obj(vec![("kind", json::s("paper-sqrt"))]),
            Schedule::ScaledSqrt { gamma0 } => json::obj(vec![
                ("kind", json::s("scaled-sqrt")),
                ("gamma0", json::num(gamma0)),
            ]),
            Schedule::InvT { gamma0 } => json::obj(vec![
                ("kind", json::s("inv-t")),
                ("gamma0", json::num(gamma0)),
            ]),
            Schedule::Constant { gamma } => json::obj(vec![
                ("kind", json::s("constant")),
                ("gamma", json::num(gamma)),
            ]),
        };
        let mut fields = vec![
            ("name", json::s(self.name.clone())),
            ("data", data),
            ("p", json::num(self.p as f64)),
            ("q", json::num(self.q as f64)),
            ("loss", json::s(self.loss.name())),
            ("algorithm", json::s(self.algorithm.to_string())),
            (
                "fractions",
                json::obj(vec![
                    ("b", json::num(self.fractions.b)),
                    ("c", json::num(self.fractions.c)),
                    ("d", json::num(self.fractions.d)),
                ]),
            ),
            ("inner_steps", json::num(self.inner_steps as f64)),
            ("outer_iters", json::num(self.outer_iters as f64)),
            ("schedule", schedule),
            ("seed", json::num(self.seed as f64)),
            (
                "engine",
                json::s(match self.engine {
                    EngineKind::Native => "native",
                    EngineKind::Xla => "xla",
                }),
            ),
            ("eval_every", json::num(self.eval_every as f64)),
            ("strict_even_grid", Value::Bool(self.strict_even_grid)),
        ];
        if let Some(exec) = self.executor {
            fields.push(("executor", json::s(exec.to_string())));
        }
        if let Some(net) = self.network {
            fields.push((
                "network",
                json::obj(vec![
                    ("latency_s", json::num(net.latency_s)),
                    ("bandwidth_bps", json::num(net.bandwidth_bps)),
                ]),
            ));
        }
        if let Some(profile) = &self.cluster_profile {
            fields.push(("cluster_profile", profile.to_json_value()));
        }
        if self.shard_weighting != ShardWeighting::default() {
            fields.push(("shard_weighting", json::s(self.shard_weighting.to_string())));
        }
        if let Some(recovery) = &self.recovery {
            fields.push(("recovery", recovery.to_json_value()));
        }
        if let Some(staleness) = &self.staleness {
            fields.push(("staleness", staleness.to_json_value()));
        }
        json::obj(fields).to_string_pretty()
    }

    pub fn from_json(text: &str) -> Result<Self> {
        let v = Value::parse(text)?;
        let data_v = v.get("data")?;
        let data = match data_v.get("kind")?.as_str()? {
            "dense" => DataConfig::Dense {
                n: data_v.get("n")?.as_usize()?,
                m: data_v.get("m")?.as_usize()?,
            },
            "sparse" => DataConfig::Sparse {
                n: data_v.get("n")?.as_usize()?,
                m: data_v.get("m")?.as_usize()?,
                avg_nnz: data_v.get("avg_nnz")?.as_usize()?,
            },
            "file" => DataConfig::File {
                path: data_v.get("path")?.as_str()?.to_string(),
                n: data_v.get("n")?.as_usize()?,
                m: data_v.get("m")?.as_usize()?,
            },
            other => anyhow::bail!("unknown data kind {other:?}"),
        };
        let sched_v = v.get("schedule")?;
        let schedule = match sched_v.get("kind")?.as_str()? {
            "paper-sqrt" => Schedule::PaperSqrt,
            "scaled-sqrt" => Schedule::ScaledSqrt { gamma0: sched_v.get("gamma0")?.as_f64()? },
            "inv-t" => Schedule::InvT { gamma0: sched_v.get("gamma0")?.as_f64()? },
            "constant" => Schedule::Constant { gamma: sched_v.get("gamma")?.as_f64()? },
            other => anyhow::bail!("unknown schedule kind {other:?}"),
        };
        let fr = v.get("fractions")?;
        let network = match v.opt("network") {
            Some(net) => Some(NetworkConfig {
                latency_s: net.get("latency_s")?.as_f64()?,
                bandwidth_bps: net.get("bandwidth_bps")?.as_f64()?,
            }),
            None => None,
        };
        let cfg = ExperimentConfig {
            name: v.get("name")?.as_str()?.to_string(),
            data,
            p: v.get("p")?.as_usize()?,
            q: v.get("q")?.as_usize()?,
            loss: v.get("loss")?.as_str()?.parse().map_err(|e: String| anyhow::anyhow!(e))?,
            algorithm: v.get("algorithm")?.as_str()?.parse().map_err(|e: String| anyhow::anyhow!(e))?,
            fractions: SamplingFractions {
                b: fr.get("b")?.as_f64()?,
                c: fr.get("c")?.as_f64()?,
                d: fr.get("d")?.as_f64()?,
            },
            inner_steps: v.get("inner_steps")?.as_usize()?,
            outer_iters: v.get("outer_iters")?.as_usize()?,
            schedule,
            seed: v.get("seed")?.as_f64()? as u64,
            engine: match v.opt("engine").map(|e| e.as_str()).transpose()? {
                Some("xla") => EngineKind::Xla,
                _ => EngineKind::Native,
            },
            // absent = auto-resolve (legacy config files predate the knob)
            executor: match v.opt("executor").map(|e| e.as_str()).transpose()? {
                Some(s) => Some(s.parse().map_err(|e: String| anyhow::anyhow!(e))?),
                None => None,
            },
            network,
            cluster_profile: v
                .opt("cluster_profile")
                .map(ClusterProfile::from_json_value)
                .transpose()?,
            shard_weighting: match v.opt("shard_weighting").map(|w| w.as_str()).transpose()? {
                Some(s) => s.parse().map_err(|e: String| anyhow::anyhow!(e))?,
                None => ShardWeighting::default(),
            },
            recovery: v.opt("recovery").map(RecoveryPolicy::from_json_value).transpose()?,
            staleness: v.opt("staleness").map(StalenessPolicy::from_json_value).transpose()?,
            eval_every: v.opt("eval_every").map(|e| e.as_usize()).transpose()?.unwrap_or(1),
            strict_even_grid: v
                .opt("strict_even_grid")
                .map(|b| b.as_bool())
                .transpose()?
                .unwrap_or(false),
        };
        cfg.validate()?;
        Ok(cfg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> ExperimentConfig {
        ExperimentConfig {
            name: "t".into(),
            data: DataConfig::Dense { n: 100, m: 30 },
            p: 5,
            q: 3,
            loss: Loss::Hinge,
            algorithm: AlgorithmKind::Sodda,
            fractions: SamplingFractions::PAPER,
            inner_steps: 8,
            outer_iters: 10,
            schedule: Schedule::PaperSqrt,
            seed: 0,
            engine: EngineKind::Native,
            executor: None,
            network: None,
            cluster_profile: None,
            shard_weighting: ShardWeighting::Balanced,
            recovery: None,
            staleness: None,
            eval_every: 1,
            strict_even_grid: false,
        }
    }

    #[test]
    fn json_roundtrip() {
        let mut cfg = sample();
        cfg.network = Some(NetworkConfig::default());
        cfg.schedule = Schedule::Constant { gamma: 0.005 };
        let s = cfg.to_json();
        let back = ExperimentConfig::from_json(&s).unwrap();
        assert_eq!(back.name, cfg.name);
        assert_eq!(back.p, cfg.p);
        assert_eq!(back.schedule, cfg.schedule);
        assert_eq!(back.network, cfg.network);
        assert_eq!(back.fractions, cfg.fractions);
        assert!(matches!(back.data, DataConfig::Dense { n: 100, m: 30 }));
    }

    #[test]
    fn ragged_shapes_validate_unless_strict() {
        let mut cfg = sample();
        cfg.data = DataConfig::Dense { n: 101, m: 31 };
        assert!(cfg.validate().is_ok(), "ragged shapes are the normal case");
        cfg.strict_even_grid = true;
        assert!(cfg.validate().is_err(), "strict mode keeps the paper's divisibility");
        cfg.data = DataConfig::Dense { n: 100, m: 30 };
        assert!(cfg.validate().is_ok());
    }

    #[test]
    fn empty_partitions_always_rejected() {
        // P=5, Q=3: N < P and M < P·Q can't produce non-empty blocks
        let mut cfg = sample();
        cfg.data = DataConfig::Dense { n: 4, m: 30 };
        assert!(cfg.validate().is_err());
        let mut cfg = sample();
        cfg.data = DataConfig::Dense { n: 100, m: 14 };
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn strict_even_grid_round_trips_through_json() {
        let mut cfg = sample();
        cfg.strict_even_grid = true;
        let back = ExperimentConfig::from_json(&cfg.to_json()).unwrap();
        assert!(back.strict_even_grid);
        // absent key defaults to ragged (older config files)
        let json = sample().to_json();
        let legacy = json.replace(",\n  \"strict_even_grid\": false", "");
        assert_ne!(legacy, json, "test must actually strip the key");
        let back = ExperimentConfig::from_json(&legacy).unwrap();
        assert!(!back.strict_even_grid);
    }

    #[test]
    fn validation_catches_bad_fractions() {
        let mut cfg = sample();
        cfg.fractions = SamplingFractions { b: 0.5, c: 0.8, d: 0.5 };
        assert!(cfg.validate().is_err(), "c > b must be rejected");
        cfg.fractions = SamplingFractions { b: 0.0, c: 0.0, d: 0.5 };
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn algorithm_parse() {
        assert_eq!("radisa-avg".parse::<AlgorithmKind>().unwrap(), AlgorithmKind::RadisaAvg);
        assert_eq!("SODDA".parse::<AlgorithmKind>().unwrap(), AlgorithmKind::Sodda);
    }

    #[test]
    fn executor_parse_and_display() {
        assert_eq!("threaded".parse::<ExecutorKind>().unwrap(), ExecutorKind::Threaded);
        assert_eq!("THREADS".parse::<ExecutorKind>().unwrap(), ExecutorKind::Threaded);
        assert_eq!("in-process".parse::<ExecutorKind>().unwrap(), ExecutorKind::InProcess);
        assert_eq!("sequential".parse::<ExecutorKind>().unwrap(), ExecutorKind::InProcess);
        assert!("remote".parse::<ExecutorKind>().is_err());
        assert_eq!(ExecutorKind::Threaded.to_string(), "threaded");
        assert_eq!(ExecutorKind::InProcess.to_string(), "in-process");
    }

    #[test]
    fn cluster_profile_round_trips_through_json() {
        for profile in [
            ClusterProfile::uniform(),
            ClusterProfile::one_slow(4.0),
            ClusterProfile::long_tail(8.0).with_flops_per_sec(5e8),
            ClusterProfile::explicit(vec![1.0; 15]).with_link_latency_factor(2.5),
        ] {
            let mut cfg = sample();
            cfg.cluster_profile = Some(profile.clone());
            let back = ExperimentConfig::from_json(&cfg.to_json()).unwrap();
            assert_eq!(back.cluster_profile, Some(profile));
        }
        // unset profile is not emitted — legacy configs stay byte-identical
        let json = sample().to_json();
        assert!(!json.contains("cluster_profile"), "unset profile must not serialize");
        assert!(!json.contains("shard_weighting"), "default weighting must not serialize");
        let back = ExperimentConfig::from_json(&json).unwrap();
        assert_eq!(back.cluster_profile, None);
        assert_eq!(back.shard_weighting, ShardWeighting::Balanced);
    }

    #[test]
    fn shard_weighting_round_trips_through_json() {
        let mut cfg = sample();
        cfg.shard_weighting = ShardWeighting::Throughput;
        let back = ExperimentConfig::from_json(&cfg.to_json()).unwrap();
        assert_eq!(back.shard_weighting, ShardWeighting::Throughput);
    }

    #[test]
    fn profile_validation_checks_rates_and_length() {
        let mut cfg = sample(); // 5x3 grid: 15 workers
        cfg.cluster_profile = Some(ClusterProfile::explicit(vec![1.0; 14]));
        assert!(cfg.validate().is_err(), "explicit length must equal P·Q");
        cfg.cluster_profile = Some(ClusterProfile::explicit(vec![1.0; 15]));
        assert!(cfg.validate().is_ok());
        let mut rates = vec![1.0; 15];
        rates[3] = 0.0;
        cfg.cluster_profile = Some(ClusterProfile::explicit(rates));
        assert!(cfg.validate().is_err(), "zero rate must be rejected");
        cfg.cluster_profile = Some(ClusterProfile::one_slow(0.5));
        assert!(cfg.validate().is_err(), "slowdown factor < 1 must be rejected");
        cfg.cluster_profile = Some(ClusterProfile::uniform().with_flops_per_sec(-1.0));
        assert!(cfg.validate().is_err(), "negative base rate must be rejected");
    }

    #[test]
    fn throughput_weighting_rejects_xla_and_strict_grids() {
        let mut cfg = sample();
        cfg.shard_weighting = ShardWeighting::Throughput;
        assert!(cfg.validate().is_ok());
        cfg.engine = EngineKind::Xla;
        assert!(cfg.validate().is_err(), "weighted shards are non-uniform; XLA must reject");
        cfg.engine = EngineKind::Native;
        cfg.strict_even_grid = true;
        assert!(cfg.validate().is_err(), "strict even grid contradicts weighting");
    }

    #[test]
    fn profile_presets_parse_and_resolve() {
        let p: ClusterProfile = "one-slow:4".parse().unwrap();
        let r = p.rates(6);
        assert_eq!(r[0], 0.25);
        assert!(r[1..].iter().all(|&x| x == 1.0));
        let lt: ClusterProfile = "long-tail:8".parse().unwrap();
        let r = lt.rates(8);
        assert_eq!(r[0], 1.0);
        assert_eq!(*r.last().unwrap(), 0.125);
        assert!(r.windows(2).all(|w| w[0] >= w[1]), "long tail must be non-increasing");
        let ex: ClusterProfile = "explicit:1,0.5,0.25".parse().unwrap();
        assert_eq!(ex.rates(3), vec![1.0, 0.5, 0.25]);
        assert_eq!("uniform".parse::<ClusterProfile>().unwrap(), ClusterProfile::uniform());
        assert!("gpu".parse::<ClusterProfile>().is_err());
        assert!(ClusterProfile::uniform().is_uniform());
        assert!(!ClusterProfile::one_slow(4.0).is_uniform());
    }

    #[test]
    fn recovery_policy_parses_and_round_trips() {
        let p: RecoveryPolicy = "5".parse().unwrap();
        assert_eq!(p, RecoveryPolicy { max_retries: 5, ..RecoveryPolicy::default() });
        let p: RecoveryPolicy = "2:50".parse().unwrap();
        assert_eq!(p, RecoveryPolicy { max_retries: 2, backoff_ms: 50, probe_ms: 100 });
        let p: RecoveryPolicy = "4:0:250".parse().unwrap();
        assert_eq!(p, RecoveryPolicy { max_retries: 4, backoff_ms: 0, probe_ms: 250 });
        // Display → FromStr round trip
        assert_eq!(p.to_string().parse::<RecoveryPolicy>().unwrap(), p);
        assert!("".parse::<RecoveryPolicy>().is_err());
        assert!("3:1:2:9".parse::<RecoveryPolicy>().is_err(), "trailing field must be rejected");
        assert!("x".parse::<RecoveryPolicy>().is_err());

        let mut cfg = sample();
        cfg.recovery = Some(p);
        let back = ExperimentConfig::from_json(&cfg.to_json()).unwrap();
        assert_eq!(back.recovery, Some(p));
        // unset policy is not emitted — legacy configs stay byte-identical
        let json = sample().to_json();
        assert!(!json.contains("recovery"), "unset policy must not serialize");
        assert_eq!(ExperimentConfig::from_json(&json).unwrap().recovery, None);
    }

    #[test]
    fn recovery_policy_validation() {
        let mut cfg = sample();
        cfg.recovery = Some(RecoveryPolicy { max_retries: 0, backoff_ms: 1, probe_ms: 100 });
        assert!(cfg.validate().is_err(), "zero retries must be rejected");
        cfg.recovery = Some(RecoveryPolicy { max_retries: 1, backoff_ms: 1, probe_ms: 0 });
        assert!(cfg.validate().is_err(), "zero probe must be rejected");
        cfg.recovery = Some(RecoveryPolicy { max_retries: 1, backoff_ms: 60_000, probe_ms: 100 });
        assert!(cfg.validate().is_err(), "absurd backoff must be rejected");
        cfg.recovery = Some(RecoveryPolicy::default());
        assert!(cfg.validate().is_ok());
    }

    #[test]
    fn staleness_policy_parses_and_round_trips() {
        let p: StalenessPolicy = "0.75".parse().unwrap();
        assert_eq!(p, StalenessPolicy { quorum_frac: 0.75, ..StalenessPolicy::default() });
        let p: StalenessPolicy = "0.5:3".parse().unwrap();
        assert_eq!(
            p,
            StalenessPolicy { quorum_frac: 0.5, max_staleness_iters: 3, timeout_factor: 4.0 }
        );
        let p: StalenessPolicy = "0.8:1:2.5".parse().unwrap();
        assert_eq!(
            p,
            StalenessPolicy { quorum_frac: 0.8, max_staleness_iters: 1, timeout_factor: 2.5 }
        );
        // Display → FromStr round trip
        assert_eq!(p.to_string().parse::<StalenessPolicy>().unwrap(), p);
        assert!("".parse::<StalenessPolicy>().is_err());
        assert!(
            "0.8:1:2:9".parse::<StalenessPolicy>().is_err(),
            "trailing field must be rejected"
        );
        assert!("x".parse::<StalenessPolicy>().is_err());

        let mut cfg = sample();
        cfg.staleness = Some(p);
        let back = ExperimentConfig::from_json(&cfg.to_json()).unwrap();
        assert_eq!(back.staleness, Some(p));
        // unset policy is not emitted — legacy configs stay byte-identical
        let json = sample().to_json();
        assert!(!json.contains("staleness"), "unset policy must not serialize");
        assert_eq!(ExperimentConfig::from_json(&json).unwrap().staleness, None);
    }

    #[test]
    fn staleness_policy_validation() {
        let mut cfg = sample();
        cfg.staleness =
            Some(StalenessPolicy { quorum_frac: 0.0, max_staleness_iters: 2, timeout_factor: 4.0 });
        assert!(cfg.validate().is_err(), "zero quorum must be rejected");
        cfg.staleness =
            Some(StalenessPolicy { quorum_frac: 1.5, max_staleness_iters: 2, timeout_factor: 4.0 });
        assert!(cfg.validate().is_err(), "quorum above 1 must be rejected");
        cfg.staleness =
            Some(StalenessPolicy { quorum_frac: 0.5, max_staleness_iters: 0, timeout_factor: 4.0 });
        assert!(cfg.validate().is_err(), "zero staleness bound must be rejected");
        cfg.staleness =
            Some(StalenessPolicy { quorum_frac: 0.5, max_staleness_iters: 2, timeout_factor: 0.5 });
        assert!(cfg.validate().is_err(), "timeout below the fastest worker must be rejected");
        cfg.staleness = Some(StalenessPolicy::default());
        assert!(cfg.validate().is_ok());
        assert!(StalenessPolicy::default().is_barrier(), "default policy is the hard barrier");
        assert!(!StalenessPolicy { quorum_frac: 0.75, ..Default::default() }.is_barrier());
    }

    #[test]
    fn executor_round_trips_through_json() {
        let mut cfg = sample();
        cfg.executor = Some(ExecutorKind::Threaded);
        let back = ExperimentConfig::from_json(&cfg.to_json()).unwrap();
        assert_eq!(back.executor, Some(ExecutorKind::Threaded));
        // absent key = auto (None), and the pin is not emitted unset —
        // legacy configs stay byte-identical
        let json = sample().to_json();
        assert!(!json.contains("executor"), "unset knob must not serialize");
        let back = ExperimentConfig::from_json(&json).unwrap();
        assert_eq!(back.executor, None);
    }
}
