//! Typed experiment configuration: the single source of truth a run is
//! launched from (CLI flags build one; JSON files round-trip it; presets
//! mirror the paper's Tables 1 and 3 at configurable scale).
//!
//! Construct configs through [`ExperimentConfig::builder`] — the builder
//! applies the paper's defaults and validates at build time, so every
//! config that reaches a [`crate::train::Trainer`] is known-good.

mod builder;
mod presets;
mod schedule;

pub use builder::ExperimentConfigBuilder;
pub use presets::{preset, Preset, PRESETS};
pub use schedule::Schedule;

use anyhow::{ensure, Result};

use crate::loss::Loss;
use crate::util::json::{self, Value};

/// Which optimizer variant to run (paper §3 / §5).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum AlgorithmKind {
    /// Algorithm 1: stochastic (b, c, d)-sampled full-gradient estimate.
    Sodda,
    /// RADiSA: exact full gradient each outer iteration
    /// (`b = c = M, d = N`), sub-block updates concatenated.
    Radisa,
    /// RADiSA-avg: the paper's benchmark — like RADiSA but the sub-block
    /// solutions overlapping the same `w_[q]` are averaged across the P
    /// random assignments instead of concatenated once.
    RadisaAvg,
}

impl std::fmt::Display for AlgorithmKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            AlgorithmKind::Sodda => "sodda",
            AlgorithmKind::Radisa => "radisa",
            AlgorithmKind::RadisaAvg => "radisa-avg",
        })
    }
}

impl std::str::FromStr for AlgorithmKind {
    type Err = String;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s.to_ascii_lowercase().as_str() {
            "sodda" => Ok(Self::Sodda),
            "radisa" => Ok(Self::Radisa),
            "radisa-avg" | "radisa_avg" | "radisaavg" => Ok(Self::RadisaAvg),
            other => Err(format!("unknown algorithm {other:?}")),
        }
    }
}

/// Which compute backend executes the kernels.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum EngineKind {
    /// Pure-rust math (always available; sparse-aware).
    #[default]
    Native,
    /// AOT-compiled JAX/Pallas artifacts through the PJRT CPU client.
    Xla,
}

impl std::str::FromStr for EngineKind {
    type Err = String;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s.to_ascii_lowercase().as_str() {
            "native" => Ok(Self::Native),
            "xla" => Ok(Self::Xla),
            other => Err(format!("unknown engine {other:?} (native|xla)")),
        }
    }
}

/// Which executor runs the P×Q workers (see `cluster/transport/`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ExecutorKind {
    /// Sequential in-process oracle: every worker command executes
    /// inline on the leader thread, in a fixed order. Deterministic,
    /// thread-free, and the bit-frozen reference for the threaded mode.
    #[default]
    InProcess,
    /// Persistent thread-per-worker runtime: each of the P×Q workers
    /// owns its shard on its own OS thread; phases overlap across
    /// cores. Bit-identical trajectories to [`ExecutorKind::InProcess`]
    /// (see the determinism contract in `cluster/transport/`).
    Threaded,
}

impl std::fmt::Display for ExecutorKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            ExecutorKind::InProcess => "in-process",
            ExecutorKind::Threaded => "threaded",
        })
    }
}

impl std::str::FromStr for ExecutorKind {
    type Err = String;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s.to_ascii_lowercase().as_str() {
            "in-process" | "inprocess" | "in_process" | "sequential" => Ok(Self::InProcess),
            "threaded" | "threads" | "thread" => Ok(Self::Threaded),
            other => Err(format!("unknown executor {other:?} (in-process|threaded)")),
        }
    }
}

impl ExecutorKind {
    /// The env override knob read by [`ExecutorKind::resolve`].
    pub const ENV: &'static str = "SODDA_EXECUTOR";

    /// Resolve the executor to run: an explicit preference (the config's
    /// `executor` field) wins; otherwise a non-empty `SODDA_EXECUTOR`
    /// env value is parsed (errors on garbage rather than silently
    /// falling back — CI lanes rely on the knob actually engaging); with
    /// neither, the in-process oracle.
    pub fn resolve(pref: Option<ExecutorKind>) -> Result<ExecutorKind> {
        if let Some(kind) = pref {
            return Ok(kind);
        }
        match std::env::var(Self::ENV) {
            Ok(v) if !v.is_empty() => {
                v.parse().map_err(|e: String| anyhow::anyhow!("{}: {e}", Self::ENV))
            }
            _ => Ok(ExecutorKind::InProcess),
        }
    }
}

/// Dataset specification.
#[derive(Debug, Clone, PartialEq)]
pub enum DataConfig {
    /// §5.1 dense synthetic (Zhang et al. generator).
    Dense { n: usize, m: usize },
    /// §5.2 sparse SemMed/PRA substitute.
    Sparse { n: usize, m: usize, avg_nnz: usize },
    /// External dataset on disk (`.svm`/`.libsvm` text or `.bin` binary,
    /// written by `repro gen-data` or any LIBSVM tool). Dimensions are
    /// read at load time; `n`/`m` here are what the file is expected to
    /// contain (validated on materialize).
    File { path: String, n: usize, m: usize },
}

impl DataConfig {
    pub fn n(&self) -> usize {
        match self {
            DataConfig::Dense { n, .. }
            | DataConfig::Sparse { n, .. }
            | DataConfig::File { n, .. } => *n,
        }
    }

    pub fn m(&self) -> usize {
        match self {
            DataConfig::Dense { m, .. }
            | DataConfig::Sparse { m, .. }
            | DataConfig::File { m, .. } => *m,
        }
    }

    /// Generate (synthetic) or load (file) the dataset. Fallible: file
    /// configs can hit I/O or dimension-mismatch errors, and callers on
    /// the session path propagate them instead of panicking.
    pub fn try_materialize(&self, seed: u64) -> Result<crate::data::Dataset> {
        match self {
            &DataConfig::Dense { n, m } => Ok(crate::data::synth::dense_zhang(n, m, seed)),
            &DataConfig::Sparse { n, m, avg_nnz } => {
                Ok(crate::data::synth::sparse_pra(n, m, avg_nnz, seed))
            }
            DataConfig::File { path, n, m } => {
                let p = std::path::Path::new(path);
                let ds = if path.ends_with(".bin") {
                    crate::data::io::read_binary(p)?
                } else {
                    crate::data::io::read_libsvm(p, *m)?
                };
                ensure!(
                    ds.n() == *n && ds.m() == *m,
                    "{path}: contains {}x{}, config expects {n}x{m}",
                    ds.n(),
                    ds.m()
                );
                Ok(ds)
            }
        }
    }
}

/// Fractions of the paper's `(b^t, c^t, d^t)` sequences, as constants in
/// (0, 1]. The paper's tuned values are `(0.85, 0.80, 0.85)` (§5.3).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SamplingFractions {
    /// `b^t / M` — features used in inner products.
    pub b: f64,
    /// `c^t / b^t`-independent: `c^t / M` — gradient coordinates kept.
    pub c: f64,
    /// `d^t / N` — observations sampled for µ^t.
    pub d: f64,
}

impl SamplingFractions {
    pub const PAPER: SamplingFractions = SamplingFractions { b: 0.85, c: 0.80, d: 0.85 };
    pub const FULL: SamplingFractions = SamplingFractions { b: 1.0, c: 1.0, d: 1.0 };

    pub fn validate(&self) -> Result<()> {
        for (name, v) in [("b", self.b), ("c", self.c), ("d", self.d)] {
            ensure!(v > 0.0 && v <= 1.0, "fraction {name}={v} outside (0, 1]");
        }
        ensure!(self.c <= self.b, "c^t must be ≤ b^t (C^t ⊆ B^t), got c={} > b={}", self.c, self.b);
        Ok(())
    }
}

/// SimNet cost-model parameters (models the paper's 4-node cluster).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct NetworkConfig {
    /// Per-message latency, seconds.
    pub latency_s: f64,
    /// Link bandwidth, bytes/second.
    pub bandwidth_bps: f64,
}

impl Default for NetworkConfig {
    fn default() -> Self {
        // 1 GbE-ish with datacenter-LAN latency
        Self { latency_s: 50e-6, bandwidth_bps: 125e6 }
    }
}

/// Everything needed to launch one training run.
#[derive(Debug, Clone)]
pub struct ExperimentConfig {
    pub name: String,
    pub data: DataConfig,
    /// observation partitions (paper default 5)
    pub p: usize,
    /// feature partitions (paper default 3)
    pub q: usize,
    pub loss: Loss,
    pub algorithm: AlgorithmKind,
    pub fractions: SamplingFractions,
    /// inner-loop length L
    pub inner_steps: usize,
    /// outer iterations T
    pub outer_iters: usize,
    pub schedule: Schedule,
    pub seed: u64,
    pub engine: EngineKind,
    /// which executor runs the workers; `None` = auto (the
    /// `SODDA_EXECUTOR` env knob if set, else the in-process oracle —
    /// see [`ExecutorKind::resolve`])
    pub executor: Option<ExecutorKind>,
    pub network: Option<NetworkConfig>,
    /// evaluate F(w) every k outer iterations (1 = every iteration)
    pub eval_every: usize,
    /// reject shapes that don't divide evenly into the grid (the paper's
    /// `n = N/P`, `m̃ = M/QP` assumption). Off by default: the
    /// partitioner balances ragged blocks automatically. Validation-only
    /// — it never changes how an accepted config trains.
    pub strict_even_grid: bool,
}

impl ExperimentConfig {
    pub fn validate(&self) -> Result<()> {
        ensure!(self.p > 0 && self.q > 0, "P, Q must be positive");
        ensure!(
            self.data.n() >= self.p,
            "N={} < P={} would leave empty observation partitions",
            self.data.n(),
            self.p
        );
        ensure!(
            self.data.m() >= self.p * self.q,
            "M={} < P·Q={} would leave empty sub-blocks",
            self.data.m(),
            self.p * self.q
        );
        if self.strict_even_grid {
            ensure!(self.data.n() % self.p == 0, "N={} % P={} != 0", self.data.n(), self.p);
            ensure!(
                self.data.m() % (self.p * self.q) == 0,
                "M={} % (Q·P)={} != 0",
                self.data.m(),
                self.p * self.q
            );
        }
        ensure!(self.inner_steps > 0, "inner_steps must be positive");
        ensure!(self.outer_iters > 0, "outer_iters must be positive");
        ensure!(self.eval_every > 0, "eval_every must be positive");
        self.fractions.validate()?;
        self.schedule.validate()?;
        Ok(())
    }

    /// Serialize to pretty JSON (offline build: in-tree json, no serde).
    pub fn to_json(&self) -> String {
        let data = match self.data {
            DataConfig::Dense { n, m } => json::obj(vec![
                ("kind", json::s("dense")),
                ("n", json::num(n as f64)),
                ("m", json::num(m as f64)),
            ]),
            DataConfig::Sparse { n, m, avg_nnz } => json::obj(vec![
                ("kind", json::s("sparse")),
                ("n", json::num(n as f64)),
                ("m", json::num(m as f64)),
                ("avg_nnz", json::num(avg_nnz as f64)),
            ]),
            DataConfig::File { ref path, n, m } => json::obj(vec![
                ("kind", json::s("file")),
                ("path", json::s(path.clone())),
                ("n", json::num(n as f64)),
                ("m", json::num(m as f64)),
            ]),
        };
        let schedule = match self.schedule {
            Schedule::PaperSqrt => json::obj(vec![("kind", json::s("paper-sqrt"))]),
            Schedule::ScaledSqrt { gamma0 } => json::obj(vec![
                ("kind", json::s("scaled-sqrt")),
                ("gamma0", json::num(gamma0)),
            ]),
            Schedule::InvT { gamma0 } => json::obj(vec![
                ("kind", json::s("inv-t")),
                ("gamma0", json::num(gamma0)),
            ]),
            Schedule::Constant { gamma } => json::obj(vec![
                ("kind", json::s("constant")),
                ("gamma", json::num(gamma)),
            ]),
        };
        let mut fields = vec![
            ("name", json::s(self.name.clone())),
            ("data", data),
            ("p", json::num(self.p as f64)),
            ("q", json::num(self.q as f64)),
            ("loss", json::s(self.loss.name())),
            ("algorithm", json::s(self.algorithm.to_string())),
            (
                "fractions",
                json::obj(vec![
                    ("b", json::num(self.fractions.b)),
                    ("c", json::num(self.fractions.c)),
                    ("d", json::num(self.fractions.d)),
                ]),
            ),
            ("inner_steps", json::num(self.inner_steps as f64)),
            ("outer_iters", json::num(self.outer_iters as f64)),
            ("schedule", schedule),
            ("seed", json::num(self.seed as f64)),
            (
                "engine",
                json::s(match self.engine {
                    EngineKind::Native => "native",
                    EngineKind::Xla => "xla",
                }),
            ),
            ("eval_every", json::num(self.eval_every as f64)),
            ("strict_even_grid", Value::Bool(self.strict_even_grid)),
        ];
        if let Some(exec) = self.executor {
            fields.push(("executor", json::s(exec.to_string())));
        }
        if let Some(net) = self.network {
            fields.push((
                "network",
                json::obj(vec![
                    ("latency_s", json::num(net.latency_s)),
                    ("bandwidth_bps", json::num(net.bandwidth_bps)),
                ]),
            ));
        }
        json::obj(fields).to_string_pretty()
    }

    pub fn from_json(text: &str) -> Result<Self> {
        let v = Value::parse(text)?;
        let data_v = v.get("data")?;
        let data = match data_v.get("kind")?.as_str()? {
            "dense" => DataConfig::Dense {
                n: data_v.get("n")?.as_usize()?,
                m: data_v.get("m")?.as_usize()?,
            },
            "sparse" => DataConfig::Sparse {
                n: data_v.get("n")?.as_usize()?,
                m: data_v.get("m")?.as_usize()?,
                avg_nnz: data_v.get("avg_nnz")?.as_usize()?,
            },
            "file" => DataConfig::File {
                path: data_v.get("path")?.as_str()?.to_string(),
                n: data_v.get("n")?.as_usize()?,
                m: data_v.get("m")?.as_usize()?,
            },
            other => anyhow::bail!("unknown data kind {other:?}"),
        };
        let sched_v = v.get("schedule")?;
        let schedule = match sched_v.get("kind")?.as_str()? {
            "paper-sqrt" => Schedule::PaperSqrt,
            "scaled-sqrt" => Schedule::ScaledSqrt { gamma0: sched_v.get("gamma0")?.as_f64()? },
            "inv-t" => Schedule::InvT { gamma0: sched_v.get("gamma0")?.as_f64()? },
            "constant" => Schedule::Constant { gamma: sched_v.get("gamma")?.as_f64()? },
            other => anyhow::bail!("unknown schedule kind {other:?}"),
        };
        let fr = v.get("fractions")?;
        let network = match v.opt("network") {
            Some(net) => Some(NetworkConfig {
                latency_s: net.get("latency_s")?.as_f64()?,
                bandwidth_bps: net.get("bandwidth_bps")?.as_f64()?,
            }),
            None => None,
        };
        let cfg = ExperimentConfig {
            name: v.get("name")?.as_str()?.to_string(),
            data,
            p: v.get("p")?.as_usize()?,
            q: v.get("q")?.as_usize()?,
            loss: v.get("loss")?.as_str()?.parse().map_err(|e: String| anyhow::anyhow!(e))?,
            algorithm: v.get("algorithm")?.as_str()?.parse().map_err(|e: String| anyhow::anyhow!(e))?,
            fractions: SamplingFractions {
                b: fr.get("b")?.as_f64()?,
                c: fr.get("c")?.as_f64()?,
                d: fr.get("d")?.as_f64()?,
            },
            inner_steps: v.get("inner_steps")?.as_usize()?,
            outer_iters: v.get("outer_iters")?.as_usize()?,
            schedule,
            seed: v.get("seed")?.as_f64()? as u64,
            engine: match v.opt("engine").map(|e| e.as_str()).transpose()? {
                Some("xla") => EngineKind::Xla,
                _ => EngineKind::Native,
            },
            // absent = auto-resolve (legacy config files predate the knob)
            executor: match v.opt("executor").map(|e| e.as_str()).transpose()? {
                Some(s) => Some(s.parse().map_err(|e: String| anyhow::anyhow!(e))?),
                None => None,
            },
            network,
            eval_every: v.opt("eval_every").map(|e| e.as_usize()).transpose()?.unwrap_or(1),
            strict_even_grid: v
                .opt("strict_even_grid")
                .map(|b| b.as_bool())
                .transpose()?
                .unwrap_or(false),
        };
        cfg.validate()?;
        Ok(cfg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> ExperimentConfig {
        ExperimentConfig {
            name: "t".into(),
            data: DataConfig::Dense { n: 100, m: 30 },
            p: 5,
            q: 3,
            loss: Loss::Hinge,
            algorithm: AlgorithmKind::Sodda,
            fractions: SamplingFractions::PAPER,
            inner_steps: 8,
            outer_iters: 10,
            schedule: Schedule::PaperSqrt,
            seed: 0,
            engine: EngineKind::Native,
            executor: None,
            network: None,
            eval_every: 1,
            strict_even_grid: false,
        }
    }

    #[test]
    fn json_roundtrip() {
        let mut cfg = sample();
        cfg.network = Some(NetworkConfig::default());
        cfg.schedule = Schedule::Constant { gamma: 0.005 };
        let s = cfg.to_json();
        let back = ExperimentConfig::from_json(&s).unwrap();
        assert_eq!(back.name, cfg.name);
        assert_eq!(back.p, cfg.p);
        assert_eq!(back.schedule, cfg.schedule);
        assert_eq!(back.network, cfg.network);
        assert_eq!(back.fractions, cfg.fractions);
        assert!(matches!(back.data, DataConfig::Dense { n: 100, m: 30 }));
    }

    #[test]
    fn ragged_shapes_validate_unless_strict() {
        let mut cfg = sample();
        cfg.data = DataConfig::Dense { n: 101, m: 31 };
        assert!(cfg.validate().is_ok(), "ragged shapes are the normal case");
        cfg.strict_even_grid = true;
        assert!(cfg.validate().is_err(), "strict mode keeps the paper's divisibility");
        cfg.data = DataConfig::Dense { n: 100, m: 30 };
        assert!(cfg.validate().is_ok());
    }

    #[test]
    fn empty_partitions_always_rejected() {
        // P=5, Q=3: N < P and M < P·Q can't produce non-empty blocks
        let mut cfg = sample();
        cfg.data = DataConfig::Dense { n: 4, m: 30 };
        assert!(cfg.validate().is_err());
        let mut cfg = sample();
        cfg.data = DataConfig::Dense { n: 100, m: 14 };
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn strict_even_grid_round_trips_through_json() {
        let mut cfg = sample();
        cfg.strict_even_grid = true;
        let back = ExperimentConfig::from_json(&cfg.to_json()).unwrap();
        assert!(back.strict_even_grid);
        // absent key defaults to ragged (older config files)
        let json = sample().to_json();
        let legacy = json.replace(",\n  \"strict_even_grid\": false", "");
        assert_ne!(legacy, json, "test must actually strip the key");
        let back = ExperimentConfig::from_json(&legacy).unwrap();
        assert!(!back.strict_even_grid);
    }

    #[test]
    fn validation_catches_bad_fractions() {
        let mut cfg = sample();
        cfg.fractions = SamplingFractions { b: 0.5, c: 0.8, d: 0.5 };
        assert!(cfg.validate().is_err(), "c > b must be rejected");
        cfg.fractions = SamplingFractions { b: 0.0, c: 0.0, d: 0.5 };
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn algorithm_parse() {
        assert_eq!("radisa-avg".parse::<AlgorithmKind>().unwrap(), AlgorithmKind::RadisaAvg);
        assert_eq!("SODDA".parse::<AlgorithmKind>().unwrap(), AlgorithmKind::Sodda);
    }

    #[test]
    fn executor_parse_and_display() {
        assert_eq!("threaded".parse::<ExecutorKind>().unwrap(), ExecutorKind::Threaded);
        assert_eq!("THREADS".parse::<ExecutorKind>().unwrap(), ExecutorKind::Threaded);
        assert_eq!("in-process".parse::<ExecutorKind>().unwrap(), ExecutorKind::InProcess);
        assert_eq!("sequential".parse::<ExecutorKind>().unwrap(), ExecutorKind::InProcess);
        assert!("remote".parse::<ExecutorKind>().is_err());
        assert_eq!(ExecutorKind::Threaded.to_string(), "threaded");
        assert_eq!(ExecutorKind::InProcess.to_string(), "in-process");
    }

    #[test]
    fn executor_round_trips_through_json() {
        let mut cfg = sample();
        cfg.executor = Some(ExecutorKind::Threaded);
        let back = ExperimentConfig::from_json(&cfg.to_json()).unwrap();
        assert_eq!(back.executor, Some(ExecutorKind::Threaded));
        // absent key = auto (None), and the pin is not emitted unset —
        // legacy configs stay byte-identical
        let json = sample().to_json();
        assert!(!json.contains("executor"), "unset knob must not serialize");
        let back = ExperimentConfig::from_json(&json).unwrap();
        assert_eq!(back.executor, None);
    }
}
