//! The builder is the single supported way to construct an
//! [`ExperimentConfig`] outside this module: defaults mirror the paper's
//! experimental setup (P=5, Q=3, hinge loss, the tuned (b, c, d) of
//! §5.3, `γ_t = 0.08/(1+√(t−1))`), and [`ExperimentConfigBuilder::build`]
//! runs the full validation pass (non-empty partitions, fraction
//! ranges, schedule sanity — plus strict divisibility when
//! [`ExperimentConfigBuilder::require_even_grid`] is set) so an invalid
//! configuration can never reach a [`crate::train::Trainer`].
//! Arbitrary `N × M` shapes are accepted by default; the partitioner
//! hands out balanced ragged blocks.

use anyhow::{Context, Result};

use super::{
    AlgorithmKind, ClusterProfile, DataConfig, EngineKind, ExecutorKind, ExperimentConfig,
    NetworkConfig, RecoveryPolicy, SamplingFractions, Schedule, ShardWeighting, StalenessPolicy,
};
use crate::loss::Loss;

/// Fluent, validating builder for [`ExperimentConfig`].
///
/// ```no_run
/// use sodda::ExperimentConfig;
///
/// let cfg = ExperimentConfig::builder()
///     .name("demo")
///     .dense(5000, 360)
///     .grid(5, 3)
///     .outer_iters(25)
///     .build()?;
/// # anyhow::Ok(())
/// ```
#[derive(Debug, Clone)]
pub struct ExperimentConfigBuilder {
    name: String,
    data: Option<DataConfig>,
    p: usize,
    q: usize,
    loss: Loss,
    algorithm: AlgorithmKind,
    fractions: SamplingFractions,
    inner_steps: usize,
    outer_iters: usize,
    schedule: Schedule,
    seed: u64,
    engine: EngineKind,
    executor: Option<ExecutorKind>,
    network: Option<NetworkConfig>,
    cluster_profile: Option<ClusterProfile>,
    shard_weighting: ShardWeighting,
    recovery: Option<RecoveryPolicy>,
    staleness: Option<StalenessPolicy>,
    eval_every: usize,
    strict_even_grid: bool,
}

impl Default for ExperimentConfigBuilder {
    fn default() -> Self {
        Self {
            name: "experiment".into(),
            data: None,
            p: 5,
            q: 3,
            loss: Loss::Hinge,
            algorithm: AlgorithmKind::Sodda,
            fractions: SamplingFractions::PAPER,
            inner_steps: 32,
            outer_iters: 30,
            schedule: Schedule::ScaledSqrt { gamma0: 0.08 },
            seed: 1,
            engine: EngineKind::Native,
            executor: None,
            network: None,
            cluster_profile: None,
            shard_weighting: ShardWeighting::Balanced,
            recovery: None,
            staleness: None,
            eval_every: 1,
            strict_even_grid: false,
        }
    }
}

impl ExperimentConfigBuilder {
    /// Run name (labels history, CSV/JSON outputs and error messages).
    pub fn name(mut self, name: impl Into<String>) -> Self {
        self.name = name.into();
        self
    }

    /// Dataset specification (required — no default).
    pub fn data(mut self, data: DataConfig) -> Self {
        self.data = Some(data);
        self
    }

    /// Shorthand for a dense synthetic dataset (§5.1 Zhang generator).
    pub fn dense(self, n: usize, m: usize) -> Self {
        self.data(DataConfig::Dense { n, m })
    }

    /// Shorthand for a sparse synthetic dataset (§5.2 PRA substitute).
    pub fn sparse(self, n: usize, m: usize, avg_nnz: usize) -> Self {
        self.data(DataConfig::Sparse { n, m, avg_nnz })
    }

    /// Partition grid: `p` observation × `q` feature partitions.
    pub fn grid(mut self, p: usize, q: usize) -> Self {
        self.p = p;
        self.q = q;
        self
    }

    pub fn loss(mut self, loss: Loss) -> Self {
        self.loss = loss;
        self
    }

    pub fn algorithm(mut self, algorithm: AlgorithmKind) -> Self {
        self.algorithm = algorithm;
        self
    }

    pub fn fractions(mut self, fractions: SamplingFractions) -> Self {
        self.fractions = fractions;
        self
    }

    /// Shorthand for the three sampling fractions `(b^t, c^t, d^t)`.
    pub fn fractions_bcd(self, b: f64, c: f64, d: f64) -> Self {
        self.fractions(SamplingFractions { b, c, d })
    }

    /// Inner-loop length L (Algorithm 1 steps 13-17).
    pub fn inner_steps(mut self, steps: usize) -> Self {
        self.inner_steps = steps;
        self
    }

    /// Outer iterations T.
    pub fn outer_iters(mut self, iters: usize) -> Self {
        self.outer_iters = iters;
        self
    }

    pub fn schedule(mut self, schedule: Schedule) -> Self {
        self.schedule = schedule;
        self
    }

    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    pub fn engine(mut self, engine: EngineKind) -> Self {
        self.engine = engine;
        self
    }

    /// Pin the executor running the P×Q workers (in-process oracle or
    /// thread-per-worker). Unset = auto: the `SODDA_EXECUTOR` env knob
    /// if present, else in-process — see
    /// [`ExecutorKind::resolve`](super::ExecutorKind::resolve).
    pub fn executor(mut self, executor: ExecutorKind) -> Self {
        self.executor = Some(executor);
        self
    }

    /// Enable the SimNet cost model with explicit link parameters.
    pub fn network(mut self, network: NetworkConfig) -> Self {
        self.network = Some(network);
        self
    }

    /// Per-worker throughput/latency heterogeneity for the simulated
    /// cost model (preset constructors on [`ClusterProfile`]); unset =
    /// uniform workers at the default rate. Validated against the P·Q
    /// grid at build time (rates > 0, explicit length == P·Q).
    pub fn cluster_profile(mut self, profile: ClusterProfile) -> Self {
        self.cluster_profile = Some(profile);
        self
    }

    /// Size row shards by worker throughput instead of equally (see
    /// [`ShardWeighting`]).
    pub fn shard_weighting(mut self, weighting: ShardWeighting) -> Self {
        self.shard_weighting = weighting;
        self
    }

    /// Fault retry/escalation policy (see [`RecoveryPolicy`]); unset =
    /// the default (3 retries, 10ms backoff, 100ms probe).
    pub fn recovery(mut self, policy: RecoveryPolicy) -> Self {
        self.recovery = Some(policy);
        self
    }

    /// Bounded-staleness aggregation policy (see [`StalenessPolicy`]):
    /// quorum barriers, straggler timeouts and late-reply folding.
    /// Unset = hard barrier unless `SODDA_STALENESS` is set at staging
    /// time; an explicit policy here always wins over the env knob.
    pub fn staleness(mut self, policy: StalenessPolicy) -> Self {
        self.staleness = Some(policy);
        self
    }

    /// Evaluate F(ω) every `k` outer iterations (1 = every iteration).
    pub fn eval_every(mut self, k: usize) -> Self {
        self.eval_every = k;
        self
    }

    /// Reject shapes that don't divide evenly into the grid at build
    /// time (the paper's `n = N/P`, `m̃ = M/QP` assumption, and this
    /// crate's historical behavior). Without this knob the partitioner
    /// balances ragged blocks automatically; evenly divisible shapes
    /// train identically either way.
    pub fn require_even_grid(mut self) -> Self {
        self.strict_even_grid = true;
        self
    }

    /// Assemble and validate. This is the only path that hands out an
    /// [`ExperimentConfig`], so every config reaching a trainer has
    /// passed divisibility, fraction-range and schedule checks.
    pub fn build(self) -> Result<ExperimentConfig> {
        let data = self
            .data
            .context("ExperimentConfig::builder(): no dataset set (use .dense()/.sparse()/.data())")?;
        let cfg = ExperimentConfig {
            name: self.name,
            data,
            p: self.p,
            q: self.q,
            loss: self.loss,
            algorithm: self.algorithm,
            fractions: self.fractions,
            inner_steps: self.inner_steps,
            outer_iters: self.outer_iters,
            schedule: self.schedule,
            seed: self.seed,
            engine: self.engine,
            executor: self.executor,
            network: self.network,
            cluster_profile: self.cluster_profile,
            shard_weighting: self.shard_weighting,
            recovery: self.recovery,
            staleness: self.staleness,
            eval_every: self.eval_every,
            strict_even_grid: self.strict_even_grid,
        };
        cfg.validate().with_context(|| format!("invalid config {:?}", cfg.name))?;
        Ok(cfg)
    }
}

impl ExperimentConfig {
    /// Start a builder pre-loaded with the paper's defaults.
    pub fn builder() -> ExperimentConfigBuilder {
        ExperimentConfigBuilder::default()
    }

    /// Builder seeded from an existing config — the idiom for sweep
    /// variants: `base.to_builder().name("v2").fractions(f).build()?`.
    pub fn to_builder(&self) -> ExperimentConfigBuilder {
        ExperimentConfigBuilder {
            name: self.name.clone(),
            data: Some(self.data.clone()),
            p: self.p,
            q: self.q,
            loss: self.loss,
            algorithm: self.algorithm,
            fractions: self.fractions,
            inner_steps: self.inner_steps,
            outer_iters: self.outer_iters,
            schedule: self.schedule,
            seed: self.seed,
            engine: self.engine,
            executor: self.executor,
            network: self.network,
            cluster_profile: self.cluster_profile.clone(),
            shard_weighting: self.shard_weighting,
            recovery: self.recovery,
            staleness: self.staleness,
            eval_every: self.eval_every,
            strict_even_grid: self.strict_even_grid,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_build_with_divisible_data() {
        let cfg = ExperimentConfig::builder().dense(300, 60).grid(3, 2).build().unwrap();
        assert_eq!(cfg.p, 3);
        assert_eq!(cfg.q, 2);
        assert_eq!(cfg.loss, Loss::Hinge);
        assert_eq!(cfg.fractions, SamplingFractions::PAPER);
        assert_eq!(cfg.eval_every, 1);
    }

    #[test]
    fn missing_data_is_rejected() {
        assert!(ExperimentConfig::builder().build().is_err());
    }

    #[test]
    fn ragged_shapes_build_by_default() {
        // N=100 not divisible by P=3 — fine, the grid goes ragged
        assert!(ExperimentConfig::builder().dense(100, 30).grid(3, 2).build().is_ok());
        assert!(ExperimentConfig::builder().dense(100, 32).grid(5, 3).build().is_ok());
        // but empty partitions/sub-blocks can never work
        assert!(ExperimentConfig::builder().dense(2, 30).grid(3, 2).build().is_err());
        assert!(ExperimentConfig::builder().dense(100, 5).grid(3, 2).build().is_err());
    }

    #[test]
    fn require_even_grid_restores_divisibility_errors() {
        let b = |n, m| ExperimentConfig::builder().dense(n, m).grid(3, 2).require_even_grid();
        assert!(b(100, 30).build().is_err(), "N=100 % P=3 != 0");
        assert!(b(99, 32).build().is_err(), "M=32 % QP=6 != 0");
        assert!(b(99, 30).build().is_ok());
        // the knob survives to_builder round trips
        let strict = b(99, 30).build().unwrap();
        assert!(strict.strict_even_grid);
        assert!(strict.to_builder().dense(100, 30).build().is_err());
    }

    #[test]
    fn fraction_ranges_are_rejected_at_build_time() {
        let b = || ExperimentConfig::builder().dense(300, 60).grid(3, 2);
        assert!(b().fractions_bcd(0.0, 0.0, 0.5).build().is_err());
        assert!(b().fractions_bcd(0.5, 0.8, 0.5).build().is_err(), "c > b");
        assert!(b().fractions_bcd(0.9, 0.8, 1.5).build().is_err(), "d > 1");
        assert!(b().fractions_bcd(0.9, 0.8, 0.9).build().is_ok());
    }

    #[test]
    fn schedule_sanity_is_rejected_at_build_time() {
        let b = || ExperimentConfig::builder().dense(300, 60).grid(3, 2);
        assert!(b().schedule(Schedule::Constant { gamma: 0.0 }).build().is_err());
        assert!(b().schedule(Schedule::ScaledSqrt { gamma0: -1.0 }).build().is_err());
        assert!(b().schedule(Schedule::InvT { gamma0: f64::NAN }).build().is_err());
    }

    #[test]
    fn zero_iterations_rejected() {
        let b = || ExperimentConfig::builder().dense(300, 60).grid(3, 2);
        assert!(b().outer_iters(0).build().is_err());
        assert!(b().inner_steps(0).build().is_err());
        assert!(b().eval_every(0).build().is_err());
    }

    #[test]
    fn to_builder_roundtrips_and_overrides() {
        let base = ExperimentConfig::builder()
            .dense(300, 60)
            .grid(3, 2)
            .seed(9)
            .outer_iters(7)
            .build()
            .unwrap();
        let v = base.to_builder().name("variant").fractions_bcd(0.9, 0.7, 0.8).build().unwrap();
        assert_eq!(v.seed, 9);
        assert_eq!(v.outer_iters, 7);
        assert_eq!(v.name, "variant");
        assert_eq!(v.fractions.b, 0.9);
        assert_eq!(base.to_builder().build().unwrap().name, base.name);
    }

    #[test]
    fn cluster_profile_builds_validated_and_survives_to_builder() {
        let cfg = ExperimentConfig::builder()
            .dense(300, 60)
            .grid(3, 2)
            .cluster_profile(ClusterProfile::one_slow(4.0))
            .shard_weighting(ShardWeighting::Throughput)
            .build()
            .unwrap();
        assert_eq!(cfg.cluster_profile, Some(ClusterProfile::one_slow(4.0)));
        assert_eq!(cfg.shard_weighting, ShardWeighting::Throughput);
        let back = cfg.to_builder().build().unwrap();
        assert_eq!(back.cluster_profile, cfg.cluster_profile);
        assert_eq!(back.shard_weighting, ShardWeighting::Throughput);
        // explicit rate vectors are validated against the grid at build
        let bad = ExperimentConfig::builder()
            .dense(300, 60)
            .grid(3, 2)
            .cluster_profile(ClusterProfile::explicit(vec![1.0; 5]));
        assert!(bad.build().is_err(), "5 rates on a 3x2 grid must be rejected");
    }

    #[test]
    fn recovery_policy_survives_to_builder() {
        let policy = RecoveryPolicy { max_retries: 2, backoff_ms: 5, probe_ms: 50 };
        let cfg = ExperimentConfig::builder()
            .dense(300, 60)
            .grid(3, 2)
            .recovery(policy)
            .build()
            .unwrap();
        assert_eq!(cfg.recovery, Some(policy));
        assert_eq!(cfg.to_builder().build().unwrap().recovery, Some(policy));
        let bad = ExperimentConfig::builder()
            .dense(300, 60)
            .grid(3, 2)
            .recovery(RecoveryPolicy { max_retries: 0, backoff_ms: 5, probe_ms: 50 });
        assert!(bad.build().is_err(), "zero-retry policy must be rejected at build");
    }

    #[test]
    fn staleness_policy_survives_to_builder() {
        let policy =
            StalenessPolicy { quorum_frac: 0.75, max_staleness_iters: 2, timeout_factor: 4.0 };
        let cfg = ExperimentConfig::builder()
            .dense(300, 60)
            .grid(3, 2)
            .staleness(policy)
            .build()
            .unwrap();
        assert_eq!(cfg.staleness, Some(policy));
        assert_eq!(cfg.to_builder().build().unwrap().staleness, Some(policy));
        let bad = ExperimentConfig::builder().dense(300, 60).grid(3, 2).staleness(
            StalenessPolicy { quorum_frac: 2.0, max_staleness_iters: 2, timeout_factor: 4.0 },
        );
        assert!(bad.build().is_err(), "quorum_frac > 1 must be rejected at build");
    }

    #[test]
    fn executor_pin_defaults_to_auto_and_survives_to_builder() {
        let auto = ExperimentConfig::builder().dense(300, 60).grid(3, 2).build().unwrap();
        assert_eq!(auto.executor, None, "unset = auto-resolve");
        let pinned = auto.to_builder().executor(ExecutorKind::Threaded).build().unwrap();
        assert_eq!(pinned.executor, Some(ExecutorKind::Threaded));
        assert_eq!(pinned.to_builder().build().unwrap().executor, Some(ExecutorKind::Threaded));
    }
}
