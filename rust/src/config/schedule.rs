//! Learning-rate schedules.
//!
//! The paper's experiments (§5) use `γ_t = 1/(1+√(t−1))`; the analysis
//! covers diminishing `1/t` (Theorems 1-2) and constants (Theorems 3-4).

#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Schedule {
    /// `γ_t = γ0/(1+√(t−1))` — the §5 experimental schedule (γ0 = 1 in
    /// the paper's notation; the scale is a tuning constant shared by all
    /// algorithms in a comparison).
    PaperSqrt,
    /// `γ_t = γ0/(1+√(t−1))` with explicit scale.
    ScaledSqrt { gamma0: f64 },
    /// `γ_t = γ0/t` — Theorem 2's diminishing rate.
    InvT { gamma0: f64 },
    /// `γ_t = γ` — Theorems 3-4's constant rate.
    Constant { gamma: f64 },
}

impl Schedule {
    /// Sanity-check the schedule's scale: every variant must produce
    /// positive, finite rates (checked once at config build time).
    pub fn validate(&self) -> anyhow::Result<()> {
        let scale = match *self {
            Schedule::PaperSqrt => 1.0,
            Schedule::ScaledSqrt { gamma0 } | Schedule::InvT { gamma0 } => gamma0,
            Schedule::Constant { gamma } => gamma,
        };
        anyhow::ensure!(
            scale.is_finite() && scale > 0.0,
            "learning-rate scale must be positive and finite, got {scale} in {self:?}"
        );
        Ok(())
    }

    /// Learning rate for outer iteration `t` (1-based, like the paper).
    /// `t < 1` is clamped to the first iteration for **every** variant —
    /// without the clamp the √-schedules compute `(0 - 1).sqrt() = NaN`
    /// and `InvT` divides by zero, which a stray `gamma(0)` call would
    /// silently propagate through the whole weight vector.
    pub fn gamma(&self, t: usize) -> f64 {
        let t = t.max(1) as f64;
        match *self {
            Schedule::PaperSqrt => 1.0 / (1.0 + (t - 1.0).sqrt()),
            Schedule::ScaledSqrt { gamma0 } => gamma0 / (1.0 + (t - 1.0).sqrt()),
            Schedule::InvT { gamma0 } => gamma0 / t,
            Schedule::Constant { gamma } => gamma,
        }
    }

    /// Theorem 3's constraint `L·M3·γ·Q·P ≤ 1` solved for γ, used to
    /// sanity-check constant rates (M3 estimated as 1 for standardized
    /// hinge data).
    pub fn max_constant_gamma(inner_steps: usize, p: usize, q: usize) -> f64 {
        1.0 / (inner_steps as f64 * p as f64 * q as f64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::assert_close;

    #[test]
    fn paper_schedule_values() {
        let s = Schedule::PaperSqrt;
        assert_close!(s.gamma(1), 1.0);
        assert_close!(s.gamma(2), 0.5);
        assert_close!(s.gamma(5), 1.0 / 3.0);
    }

    #[test]
    fn inv_t_is_non_summable_but_square_summable_shape() {
        let s = Schedule::InvT { gamma0: 1.0 };
        assert_close!(s.gamma(10), 0.1);
        // monotone decreasing
        for t in 1..50 {
            assert!(s.gamma(t + 1) < s.gamma(t));
        }
    }

    #[test]
    fn constant_is_constant() {
        let s = Schedule::Constant { gamma: 0.01 };
        assert_eq!(s.gamma(1), s.gamma(1000));
    }

    #[test]
    fn theorem3_bound() {
        assert_close!(Schedule::max_constant_gamma(16, 5, 3), 1.0 / 240.0);
    }

    #[test]
    fn t_zero_clamps() {
        assert_close!(Schedule::PaperSqrt.gamma(0), 1.0);
    }

    #[test]
    fn gamma_zero_is_finite_positive_for_every_variant() {
        // regression: ScaledSqrt used to be the paper-sqrt formula without
        // PaperSqrt's t-clamp, so gamma(0) was sqrt(-1) = NaN
        let variants = [
            Schedule::PaperSqrt,
            Schedule::ScaledSqrt { gamma0: 0.08 },
            Schedule::InvT { gamma0: 0.5 },
            Schedule::Constant { gamma: 0.01 },
        ];
        for s in variants {
            let g0 = s.gamma(0);
            assert!(g0.is_finite() && g0 > 0.0, "{s:?}: gamma(0) = {g0}");
            assert_eq!(g0, s.gamma(1), "{s:?}: t = 0 must clamp to the first iteration");
        }
    }
}
