//! CSR sparse matrix — storage for the §5.2 SemMed-style experiments
//! ("all the datasets considered are in the sparse format").
//!
//! Like the dense storage, every batched accessor here
//! ([`CsrMatrix::rows_dot_range_into`], [`CsrMatrix::add_rows_scaled_range`])
//! writes into caller-provided slices and allocates nothing — the
//! storage layer beneath the `_into` kernels of the zero-allocation
//! steady state (README "Steady-state memory").

/// Compressed sparse row matrix, f32 values, u32 column indices.
#[derive(Debug, Clone, PartialEq)]
pub struct CsrMatrix {
    pub rows: usize,
    pub cols: usize,
    pub indptr: Vec<u32>,
    pub indices: Vec<u32>,
    pub values: Vec<f32>,
}

impl CsrMatrix {
    pub fn empty(rows: usize, cols: usize) -> Self {
        Self { rows, cols, indptr: vec![0; rows + 1], indices: vec![], values: vec![] }
    }

    /// Build from per-row (col, value) lists; cols must be in-range but
    /// need not be sorted (they are sorted here).
    pub fn from_row_entries(rows: usize, cols: usize, mut entries: Vec<Vec<(u32, f32)>>) -> Self {
        assert_eq!(entries.len(), rows);
        let mut indptr = Vec::with_capacity(rows + 1);
        let mut indices = Vec::new();
        let mut values = Vec::new();
        indptr.push(0u32);
        for row in entries.iter_mut() {
            row.sort_unstable_by_key(|(c, _)| *c);
            for &(c, v) in row.iter() {
                assert!((c as usize) < cols, "column {c} out of range {cols}");
                if v != 0.0 {
                    indices.push(c);
                    values.push(v);
                }
            }
            indptr.push(indices.len() as u32);
        }
        Self { rows, cols, indptr, indices, values }
    }

    #[inline]
    pub fn row_range(&self, r: usize) -> std::ops::Range<usize> {
        self.indptr[r] as usize..self.indptr[r + 1] as usize
    }

    #[inline]
    pub fn row_entries(&self, r: usize) -> impl Iterator<Item = (u32, f32)> + '_ {
        let rng = self.row_range(r);
        self.indices[rng.clone()].iter().copied().zip(self.values[rng].iter().copied())
    }

    /// `x_r[lo..hi] · w` with `w` local to the range (`w.len() == hi-lo`).
    #[inline]
    pub fn row_dot_range(&self, r: usize, lo: usize, hi: usize, w: &[f32]) -> f32 {
        debug_assert_eq!(w.len(), hi - lo);
        let rng = self.row_range(r);
        let (idx, val) = (&self.indices[rng.clone()], &self.values[rng]);
        // indices are sorted: binary-search the window once, then scan.
        let start = idx.partition_point(|&c| (c as usize) < lo);
        let mut s = 0.0f32;
        for k in start..idx.len() {
            let c = idx[k] as usize;
            if c >= hi {
                break;
            }
            s += val[k] * w[c - lo];
        }
        s
    }

    /// `(x_r[lo..hi] · wa, x_r[lo..hi] · wb)` in one scan of the row's
    /// stored entries; each dot matches [`Self::row_dot_range`]
    /// bit-for-bit (same entry order, same accumulator).
    #[inline]
    pub fn row_dot2_range(&self, r: usize, lo: usize, hi: usize, wa: &[f32], wb: &[f32]) -> (f32, f32) {
        debug_assert!(wa.len() == hi - lo && wb.len() == hi - lo);
        let rng = self.row_range(r);
        let (idx, val) = (&self.indices[rng.clone()], &self.values[rng]);
        let start = idx.partition_point(|&c| (c as usize) < lo);
        let (mut sa, mut sb) = (0.0f32, 0.0f32);
        for k in start..idx.len() {
            let c = idx[k] as usize;
            if c >= hi {
                break;
            }
            sa += val[k] * wa[c - lo];
            sb += val[k] * wb[c - lo];
        }
        (sa, sb)
    }

    /// Batched `out[k] = x_{rows[k]}[lo..hi] · w` — one monomorphized
    /// gather loop over the whole row set (no per-row `Store` dispatch).
    pub fn rows_dot_range_into(&self, rows: &[u32], lo: usize, hi: usize, w: &[f32], out: &mut [f32]) {
        debug_assert_eq!(out.len(), rows.len());
        for (o, &r) in out.iter_mut().zip(rows) {
            *o = self.row_dot_range(r as usize, lo, hi, w);
        }
    }

    /// Batched `out += Σ_k u[k] · x_{rows[k]}[lo..hi]` (zero-`u` rows
    /// skipped, row order preserved — bit-for-bit the per-row loop).
    pub fn add_rows_scaled_range(&self, rows: &[u32], u: &[f32], lo: usize, hi: usize, out: &mut [f32]) {
        debug_assert_eq!(rows.len(), u.len());
        for (&r, &uk) in rows.iter().zip(u) {
            self.add_row_scaled_range(r as usize, lo, hi, uk, out);
        }
    }

    /// `out += scale · x_r[lo..hi]`.
    #[inline]
    pub fn add_row_scaled_range(&self, r: usize, lo: usize, hi: usize, scale: f32, out: &mut [f32]) {
        debug_assert_eq!(out.len(), hi - lo);
        if scale == 0.0 {
            return;
        }
        let rng = self.row_range(r);
        let (idx, val) = (&self.indices[rng.clone()], &self.values[rng]);
        let start = idx.partition_point(|&c| (c as usize) < lo);
        for k in start..idx.len() {
            let c = idx[k] as usize;
            if c >= hi {
                break;
            }
            out[c - lo] += scale * val[k];
        }
    }

    /// The shared sorted-intersection state machine beneath
    /// [`Self::row_dot_cols`] and [`Self::add_row_scaled_cols`]: calls
    /// `hit(k, j)` — `k` an index into `self.values`, `j` into `idx` —
    /// for every stored entry of row `r` whose column is in the sorted
    /// subset, in column order. Two-pointer walk, galloping (binary
    /// search over the remaining tail) whenever one side falls behind —
    /// O(nnz_r + |idx|) worst case, much less when one list is far
    /// shorter.
    #[inline]
    fn for_each_intersection(&self, r: usize, idx: &[u32], mut hit: impl FnMut(usize, usize)) {
        let rng = self.row_range(r);
        let cols = &self.indices[rng.clone()];
        let (mut i, mut j) = (0usize, 0usize);
        while i < cols.len() && j < idx.len() {
            let (c, t) = (cols[i], idx[j]);
            match c.cmp(&t) {
                std::cmp::Ordering::Equal => {
                    hit(rng.start + i, j);
                    i += 1;
                    j += 1;
                }
                std::cmp::Ordering::Less => {
                    i += 1;
                    if i < cols.len() && cols[i] < t {
                        i += cols[i..].partition_point(|&v| v < t);
                    }
                }
                std::cmp::Ordering::Greater => {
                    j += 1;
                    if j < idx.len() && idx[j] < c {
                        j += idx[j..].partition_point(|&v| v < c);
                    }
                }
            }
        }
    }

    /// Subset dot `Σ x_r[idx[k]] · w[k]` over a **sorted** block-local
    /// column list (`w` compact, `w.len() == idx.len()`). Terms
    /// accumulate in column order, the same order the masked
    /// [`Self::row_dot_range`] visits the surviving entries.
    #[inline]
    pub fn row_dot_cols(&self, r: usize, idx: &[u32], w: &[f32]) -> f32 {
        debug_assert_eq!(w.len(), idx.len());
        let mut s = 0.0f32;
        self.for_each_intersection(r, idx, |k, j| s += self.values[k] * w[j]);
        s
    }

    /// Batched `out[k] = x_{rows[k]}[idx] · w` over a column subset —
    /// the CSR sampled-width phase-1 kernel.
    pub fn rows_dot_cols_into(&self, rows: &[u32], idx: &[u32], w: &[f32], out: &mut [f32]) {
        debug_assert_eq!(out.len(), rows.len());
        for (o, &r) in out.iter_mut().zip(rows) {
            *o = self.row_dot_cols(r as usize, idx, w);
        }
    }

    /// Compact axpy over a sorted column subset:
    /// `out[k] += scale · x_r[idx[k]]` (same intersection walk as
    /// [`Self::row_dot_cols`], `out.len() == idx.len()`).
    #[inline]
    pub fn add_row_scaled_cols(&self, r: usize, idx: &[u32], scale: f32, out: &mut [f32]) {
        debug_assert_eq!(out.len(), idx.len());
        if scale == 0.0 {
            return;
        }
        self.for_each_intersection(r, idx, |k, j| out[j] += scale * self.values[k]);
    }

    /// Batched compact gradient slice
    /// `out[k] += Σ_j u[j] · x_{rows[j]}[idx[k]]` (zero-`u` rows skipped,
    /// row order preserved).
    pub fn add_rows_scaled_cols(&self, rows: &[u32], u: &[f32], idx: &[u32], out: &mut [f32]) {
        debug_assert_eq!(rows.len(), u.len());
        for (&r, &uk) in rows.iter().zip(u) {
            self.add_row_scaled_cols(r as usize, idx, uk, out);
        }
    }

    /// Densify a row range into `out` (XLA buffer staging).
    pub fn copy_row_range(&self, r: usize, lo: usize, hi: usize, out: &mut [f32]) {
        out.fill(0.0);
        let rng = self.row_range(r);
        let (idx, val) = (&self.indices[rng.clone()], &self.values[rng]);
        let start = idx.partition_point(|&c| (c as usize) < lo);
        for k in start..idx.len() {
            let c = idx[k] as usize;
            if c >= hi {
                break;
            }
            out[c - lo] = val[k];
        }
    }

    /// Column-range slice with reindexed columns.
    pub fn slice_cols(&self, lo: usize, hi: usize) -> CsrMatrix {
        let mut entries = Vec::with_capacity(self.rows);
        for r in 0..self.rows {
            entries.push(
                self.row_entries(r)
                    .filter(|&(c, _)| (c as usize) >= lo && (c as usize) < hi)
                    .map(|(c, v)| (c - lo as u32, v))
                    .collect(),
            );
        }
        CsrMatrix::from_row_entries(self.rows, hi - lo, entries)
    }

    /// Row-range slice.
    pub fn slice_rows(&self, lo: usize, hi: usize) -> CsrMatrix {
        let mut entries = Vec::with_capacity(hi - lo);
        for r in lo..hi {
            entries.push(self.row_entries(r).collect());
        }
        CsrMatrix::from_row_entries(hi - lo, self.cols, entries)
    }

    pub fn nnz(&self) -> usize {
        self.values.len()
    }

    /// Stored entries in row `r` — the per-row work proxy that
    /// cost-balanced sharding splits on.
    #[inline]
    pub fn row_nnz(&self, r: usize) -> usize {
        (self.indptr[r + 1] - self.indptr[r]) as usize
    }

    /// Fraction of stored entries relative to the dense size.
    pub fn density(&self) -> f64 {
        self.nnz() as f64 / (self.rows as f64 * self.cols as f64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::assert_close;

    fn sample() -> CsrMatrix {
        // [ 1 0 2 0 ]
        // [ 0 0 0 3 ]
        // [ 4 5 0 6 ]
        CsrMatrix::from_row_entries(
            3,
            4,
            vec![
                vec![(0, 1.0), (2, 2.0)],
                vec![(3, 3.0)],
                vec![(3, 6.0), (0, 4.0), (1, 5.0)], // unsorted on purpose
            ],
        )
    }

    #[test]
    fn construction_sorts_and_drops_zeros() {
        let m = CsrMatrix::from_row_entries(1, 3, vec![vec![(2, 1.0), (0, 0.0), (1, 7.0)]]);
        assert_eq!(m.nnz(), 2);
        let row: Vec<_> = m.row_entries(0).collect();
        assert_eq!(row, vec![(1, 7.0), (2, 1.0)]);
    }

    #[test]
    fn row_dot_full_and_windowed() {
        let m = sample();
        let w4 = [1.0, 1.0, 1.0, 1.0];
        assert_close!(m.row_dot_range(2, 0, 4, &w4), 15.0);
        let w2 = [10.0, 100.0];
        // window cols [1,3): row2 has (1,5.0) only in range
        assert_close!(m.row_dot_range(2, 1, 3, &w2), 50.0);
        assert_close!(m.row_dot_range(1, 1, 3, &w2), 0.0);
    }

    #[test]
    fn dual_dot_matches_single_dots_exactly() {
        let m = sample();
        let wa = [0.5f32, -1.5, 2.0];
        let wb = [1.0f32, 0.25, -0.75];
        for r in 0..3 {
            let (sa, sb) = m.row_dot2_range(r, 1, 4, &wa, &wb);
            assert_eq!(sa, m.row_dot_range(r, 1, 4, &wa));
            assert_eq!(sb, m.row_dot_range(r, 1, 4, &wb));
        }
    }

    #[test]
    fn batched_accessors_match_per_row_exactly() {
        let m = sample();
        let w = [2.0f32, -0.5, 1.5];
        let rows = [2u32, 0, 1, 2];
        let mut z = vec![0.0f32; 4];
        m.rows_dot_range_into(&rows, 1, 4, &w, &mut z);
        let want: Vec<f32> = rows.iter().map(|&r| m.row_dot_range(r as usize, 1, 4, &w)).collect();
        assert_eq!(z, want);

        let u = [0.5f32, 0.0, -1.0, 2.0];
        let mut got = vec![0.25f32; 3];
        m.add_rows_scaled_range(&rows, &u, 1, 4, &mut got);
        let mut want = vec![0.25f32; 3];
        for (&r, &uk) in rows.iter().zip(&u) {
            m.add_row_scaled_range(r as usize, 1, 4, uk, &mut want);
        }
        assert_eq!(got, want);
    }

    #[test]
    fn subset_dot_intersects_correctly() {
        let m = sample();
        // row 2 = [4 5 0 6]; subset {0, 2, 3} → 4·w0 + 0·w1 + 6·w2
        let idx = [0u32, 2, 3];
        let w = [2.0f32, 10.0, 0.5];
        assert_close!(m.row_dot_cols(2, &idx, &w), 8.0 + 3.0);
        // row 1 = [0 0 0 3]; subset {0, 1} misses every entry
        assert_close!(m.row_dot_cols(1, &[0, 1], &[1.0, 1.0]), 0.0);
        // empty subset, empty w
        assert_eq!(m.row_dot_cols(0, &[], &[]), 0.0);
        // full subset equals the full-range dot bit-for-bit (same
        // entry visit order, same accumulator)
        let all = [0u32, 1, 2, 3];
        let w4 = [0.3f32, -1.2, 2.0, 0.7];
        for r in 0..3 {
            assert_eq!(m.row_dot_cols(r, &all, &w4), m.row_dot_range(r, 0, 4, &w4));
        }
    }

    #[test]
    fn subset_axpy_matches_masked_reference() {
        let m = sample();
        let idx = [1u32, 3];
        let rows = [2u32, 0, 1];
        let u = [0.5f32, -1.0, 2.0];
        let mut compact = vec![0.0f32; 2];
        m.add_rows_scaled_cols(&rows, &u, &idx, &mut compact);
        let mut full = vec![0.0f32; 4];
        for (&r, &uk) in rows.iter().zip(&u) {
            m.add_row_scaled_range(r as usize, 0, 4, uk, &mut full);
        }
        for (k, &i) in idx.iter().enumerate() {
            assert_close!(compact[k], full[i as usize], 1e-6, 1e-7);
        }
        let mut z = vec![9.0f32; 3];
        m.rows_dot_cols_into(&rows, &idx, &[1.0, 1.0], &mut z);
        let want: Vec<f32> =
            rows.iter().map(|&r| m.row_dot_cols(r as usize, &idx, &[1.0, 1.0])).collect();
        assert_eq!(z, want);
    }

    #[test]
    fn subset_walk_gallops_over_long_runs() {
        // one row with a long stretch of entries far below/above the
        // subset, plus a sparse subset with ids far apart — exercises
        // both gallop branches
        let entries: Vec<(u32, f32)> = (0..50u32).map(|c| (c, 1.0 + c as f32)).collect();
        let m = CsrMatrix::from_row_entries(1, 200, vec![entries]);
        let idx = [45u32, 120, 199];
        let w = [1.0f32, 1.0, 1.0];
        // only col 45 intersects → value 46
        assert_close!(m.row_dot_cols(0, &idx, &w), 46.0);
        let mut out = vec![0.0f32; 3];
        m.add_row_scaled_cols(0, &idx, 2.0, &mut out);
        assert_eq!(out, vec![92.0, 0.0, 0.0]);
    }

    #[test]
    fn add_row_scaled_windowed() {
        let m = sample();
        let mut out = vec![0.0; 2];
        m.add_row_scaled_range(0, 1, 3, 2.0, &mut out);
        assert_eq!(out, vec![0.0, 4.0]);
    }

    #[test]
    fn copy_row_range_densifies() {
        let m = sample();
        let mut out = vec![9.0; 3];
        m.copy_row_range(2, 1, 4, &mut out);
        assert_eq!(out, vec![5.0, 0.0, 6.0]);
    }

    #[test]
    fn slicing() {
        let m = sample();
        let c = m.slice_cols(1, 4);
        assert_eq!(c.cols, 3);
        let row2: Vec<_> = c.row_entries(2).collect();
        assert_eq!(row2, vec![(0, 5.0), (2, 6.0)]);
        let r = m.slice_rows(1, 3);
        assert_eq!(r.rows, 2);
        assert_eq!(r.row_entries(0).collect::<Vec<_>>(), vec![(3, 3.0)]);
    }

    #[test]
    fn density() {
        let m = sample();
        assert_close!(m.density(), 6.0 / 12.0);
    }
}
