//! The doubly distributed partitioner (paper Figure 1), generalized to
//! ragged grids.
//!
//! Splits a [`Dataset`] into `P` observation partitions × `Q` feature
//! partitions; each block's columns are further divided into `P`
//! sub-blocks. The paper's uniform `n = N/P`, `m̃ = M/QP` shapes are the
//! special case of an evenly divisible dataset; for arbitrary `N × M`
//! the [`Layout`] balances blocks with a ceil/floor split (sizes differ
//! by at most one), exactly like a Spark range partitioner hands
//! executors whatever slab the boundaries produce. All geometry lives in
//! explicit boundary vectors — consumers address sub-blocks through
//! [`Layout::sub_cols`] (block-local) and the global parameter vector
//! through [`Layout::global_cols`]; nothing downstream may assume
//! uniform widths.

use anyhow::{ensure, Result};

use super::{Dataset, Store};

/// One worker's local shard: the `n_p × m_q` slab `x^{p,q}` plus the
/// labels of its observation rows (replicated across the Q feature
/// partitions, exactly like a Spark copartitioning would).
#[derive(Debug, Clone)]
pub struct Block {
    pub p: usize,
    pub q: usize,
    pub x: Store,
    pub y: Vec<f32>,
}

/// Balanced boundaries splitting `0..total` into `parts` ranges whose
/// sizes differ by at most one (`bounds[i] = ⌊i·total/parts⌋`). On
/// divisible inputs this reproduces the uniform `i · total/parts` grid
/// exactly, which is what keeps ragged and legacy-uniform layouts
/// bit-for-bit identical on evenly divisible shapes.
pub fn split_points(total: usize, parts: usize) -> Vec<usize> {
    debug_assert!(parts > 0, "split into zero parts");
    (0..=parts).map(|i| i * total / parts).collect()
}

/// Weighted boundaries splitting `0..total` into `parts` ranges whose
/// sizes are proportional to `weights` (cumulative-weight rounding:
/// `bounds[i] = round(total · Σw_{<i} / Σw)`), then clamped so every
/// part is non-empty (requires `total ≥ parts`). Heterogeneous-cluster
/// layouts use this to size shards by worker throughput so the barrier
/// stops waiting on the straggler.
pub fn split_points_weighted(total: usize, weights: &[f64]) -> Vec<usize> {
    let parts = weights.len();
    debug_assert!(parts > 0, "split into zero parts");
    debug_assert!(total >= parts, "weighted split needs total >= parts");
    debug_assert!(weights.iter().all(|&w| w.is_finite() && w > 0.0), "weights must be positive");
    let sum: f64 = weights.iter().sum();
    let mut bounds = Vec::with_capacity(parts + 1);
    let mut cum = 0.0;
    bounds.push(0usize);
    for &w in &weights[..parts - 1] {
        cum += w;
        bounds.push(((total as f64 * cum / sum).round() as usize).min(total));
    }
    bounds.push(total);
    // clamp passes guarantee strictly increasing bounds (non-empty parts)
    for i in 1..=parts {
        bounds[i] = bounds[i].max(bounds[i - 1] + 1);
    }
    bounds[parts] = total;
    for i in (1..parts).rev() {
        bounds[i] = bounds[i].min(bounds[i + 1] - 1);
    }
    bounds
}

/// Cost-aware weighted boundaries: split `0..total` into `parts` ranges
/// whose summed per-item `costs` (not item counts) are proportional to
/// `weights` — each boundary is the prefix-sum index nearest to its
/// cumulative cost target, then clamped non-empty exactly like
/// [`split_points_weighted`]. Sparse (CSR) shards use this with per-row
/// nnz as the cost so skewed-density partitions carry equal *work*;
/// with uniform costs it degrades to count-proportional splitting.
pub fn split_points_by_cost(total: usize, weights: &[f64], costs: &[f64]) -> Vec<usize> {
    let parts = weights.len();
    debug_assert!(parts > 0, "split into zero parts");
    debug_assert!(total >= parts, "cost split needs total >= parts");
    debug_assert_eq!(costs.len(), total, "one cost per item");
    debug_assert!(weights.iter().all(|&w| w.is_finite() && w > 0.0), "weights must be positive");
    debug_assert!(costs.iter().all(|&c| c.is_finite() && c >= 0.0), "costs must be non-negative");
    let wsum: f64 = weights.iter().sum();
    let mut prefix = Vec::with_capacity(total + 1);
    prefix.push(0.0f64);
    for &c in costs {
        prefix.push(prefix.last().unwrap() + c);
    }
    let csum = *prefix.last().unwrap();
    if csum <= 0.0 {
        // all-zero costs carry no signal — fall back to count-proportional
        return split_points_weighted(total, weights);
    }
    let mut bounds = Vec::with_capacity(parts + 1);
    bounds.push(0usize);
    let mut cumw = 0.0;
    for &w in &weights[..parts - 1] {
        cumw += w;
        let target = csum * cumw / wsum;
        // nearest prefix index to the cumulative cost target
        let i = prefix.partition_point(|&c| c < target).min(total);
        let b = if i > 0 && (target - prefix[i - 1]) <= (prefix[i] - target) { i - 1 } else { i };
        bounds.push(b);
    }
    bounds.push(total);
    // clamp passes guarantee strictly increasing bounds (non-empty parts)
    for i in 1..=parts {
        bounds[i] = bounds[i].max(bounds[i - 1] + 1);
    }
    bounds[parts] = total;
    for i in (1..parts).rev() {
        bounds[i] = bounds[i].min(bounds[i + 1] - 1);
    }
    bounds
}

/// The partition geometry of a `P × Q` grid over an `N × M` dataset:
/// explicit per-partition row boundaries, per-block column boundaries,
/// and per-block sub-block boundaries. Shared verbatim between
/// [`Grid`] (which owns the data blocks) and the
/// [`crate::cluster::Cluster`] (whose leader needs the same geometry
/// after the blocks have moved into worker threads).
#[derive(Debug, Clone)]
pub struct Layout {
    /// observation partitions
    pub p: usize,
    /// feature partitions
    pub q: usize,
    pub n_total: usize,
    pub m_total: usize,
    /// global row boundaries, length `P + 1`
    row_bounds: Vec<usize>,
    /// global column boundaries of the feature blocks, length `Q + 1`
    col_bounds: Vec<usize>,
    /// block-local sub-block boundaries, `[q][0..=P]`
    sub_bounds: Vec<Vec<usize>>,
}

impl Layout {
    /// Balanced ragged layout for an `n_total × m_total` dataset on a
    /// `p × q` grid. Requires every partition and sub-block to be
    /// non-empty (`N ≥ P`, `M ≥ P·Q`).
    pub fn new(n_total: usize, m_total: usize, p: usize, q: usize) -> Result<Layout> {
        ensure!(p > 0 && q > 0, "P and Q must be positive");
        ensure!(n_total >= p, "N={n_total} < P={p} would leave empty observation partitions");
        ensure!(
            m_total >= p * q,
            "M={m_total} < P·Q={} would leave empty sub-blocks",
            p * q
        );
        let row_bounds = split_points(n_total, p);
        let col_bounds = split_points(m_total, q);
        let sub_bounds =
            (0..q).map(|qi| split_points(col_bounds[qi + 1] - col_bounds[qi], p)).collect();
        Ok(Layout { p, q, n_total, m_total, row_bounds, col_bounds, sub_bounds })
    }

    /// Throughput-weighted ragged layout: observation partition sizes
    /// are proportional to `row_weights` (one per partition, typically
    /// the slowest worker rate in that row of the grid) so faster rows
    /// get more rows and the phase barrier stops waiting on the
    /// straggler. Columns stay balanced — feature-block width governs
    /// the wire cost, which is rate-independent.
    pub fn weighted(
        n_total: usize,
        m_total: usize,
        p: usize,
        q: usize,
        row_weights: &[f64],
    ) -> Result<Layout> {
        ensure!(p > 0 && q > 0, "P and Q must be positive");
        ensure!(
            row_weights.len() == p,
            "row_weights has {} entries for P={p} partitions",
            row_weights.len()
        );
        ensure!(
            row_weights.iter().all(|w| w.is_finite() && *w > 0.0),
            "row weights must be finite and positive"
        );
        ensure!(n_total >= p, "N={n_total} < P={p} would leave empty observation partitions");
        ensure!(
            m_total >= p * q,
            "M={m_total} < P·Q={} would leave empty sub-blocks",
            p * q
        );
        let row_bounds = split_points_weighted(n_total, row_weights);
        let col_bounds = split_points(m_total, q);
        let sub_bounds =
            (0..q).map(|qi| split_points(col_bounds[qi + 1] - col_bounds[qi], p)).collect();
        Ok(Layout { p, q, n_total, m_total, row_bounds, col_bounds, sub_bounds })
    }

    /// [`Layout::weighted`] with per-row costs: observation partition
    /// boundaries place `row_costs` mass (per-row nnz for CSR data)
    /// proportional to `row_weights`, so a skewed-density sparse matrix
    /// yields shards of equal *work* per unit of worker rate rather
    /// than equal row counts. Columns stay balanced, like `weighted`.
    pub fn weighted_by_cost(
        n_total: usize,
        m_total: usize,
        p: usize,
        q: usize,
        row_weights: &[f64],
        row_costs: &[f64],
    ) -> Result<Layout> {
        ensure!(p > 0 && q > 0, "P and Q must be positive");
        ensure!(
            row_weights.len() == p,
            "row_weights has {} entries for P={p} partitions",
            row_weights.len()
        );
        ensure!(
            row_weights.iter().all(|w| w.is_finite() && *w > 0.0),
            "row weights must be finite and positive"
        );
        ensure!(
            row_costs.len() == n_total,
            "row_costs has {} entries for N={n_total} rows",
            row_costs.len()
        );
        ensure!(
            row_costs.iter().all(|c| c.is_finite() && *c >= 0.0),
            "row costs must be finite and non-negative"
        );
        ensure!(n_total >= p, "N={n_total} < P={p} would leave empty observation partitions");
        ensure!(
            m_total >= p * q,
            "M={m_total} < P·Q={} would leave empty sub-blocks",
            p * q
        );
        let row_bounds = split_points_by_cost(n_total, row_weights, row_costs);
        let col_bounds = split_points(m_total, q);
        let sub_bounds =
            (0..q).map(|qi| split_points(col_bounds[qi + 1] - col_bounds[qi], p)).collect();
        Ok(Layout { p, q, n_total, m_total, row_bounds, col_bounds, sub_bounds })
    }

    /// Is this the paper's uniform special case (`N % P == 0` and
    /// `M % (Q·P) == 0`)? Shape-specialized engines (the AOT XLA
    /// kernels) only support uniform layouts.
    pub fn is_uniform(&self) -> bool {
        Self::shape_is_uniform(self.n_total, self.m_total, self.p, self.q)
    }

    /// The uniformity predicate behind [`Layout::is_uniform`], usable
    /// before a layout exists — the single source of truth for shape
    /// gates like the XLA engine build (strict-mode config validation
    /// keeps its own per-dimension checks only for granular error
    /// messages).
    pub fn shape_is_uniform(n_total: usize, m_total: usize, p: usize, q: usize) -> bool {
        n_total % p == 0 && m_total % (p * q) == 0
    }

    /// Global row boundaries (length `P + 1`) — partition `p` owns rows
    /// `row_bounds()[p]..row_bounds()[p + 1]`.
    pub fn row_bounds(&self) -> &[usize] {
        &self.row_bounds
    }

    /// Global row range of observation partition `p`.
    #[inline]
    pub fn block_rows(&self, p: usize) -> std::ops::Range<usize> {
        self.row_bounds[p]..self.row_bounds[p + 1]
    }

    /// Rows owned by observation partition `p`.
    #[inline]
    pub fn rows_in(&self, p: usize) -> usize {
        self.row_bounds[p + 1] - self.row_bounds[p]
    }

    /// Global column boundaries (length `Q + 1`) — feature block `q`
    /// owns columns `col_bounds()[q]..col_bounds()[q + 1]`. The sampled
    /// sets are split into per-block local id lists by one boundary
    /// walk over these (see
    /// [`crate::coordinator::sampling::rows_per_partition_into`], which
    /// works for any sorted-ids-vs-boundaries split, columns included).
    pub fn col_bounds(&self) -> &[usize] {
        &self.col_bounds
    }

    /// Global column range of feature block `q`.
    #[inline]
    pub fn block_cols(&self, q: usize) -> std::ops::Range<usize> {
        self.col_bounds[q]..self.col_bounds[q + 1]
    }

    /// Columns owned by feature block `q`.
    #[inline]
    pub fn cols_in(&self, q: usize) -> usize {
        self.col_bounds[q + 1] - self.col_bounds[q]
    }

    /// Block-local column range of sub-block `k` of feature block `q`
    /// (`k ∈ 0..P`). Widths are ragged: query per `(q, k)`, never assume
    /// a uniform `m̃`.
    #[inline]
    pub fn sub_cols(&self, q: usize, k: usize) -> std::ops::Range<usize> {
        self.sub_bounds[q][k]..self.sub_bounds[q][k + 1]
    }

    /// Global column range of sub-block `k` of feature block `q`.
    #[inline]
    pub fn global_cols(&self, q: usize, k: usize) -> std::ops::Range<usize> {
        let base = self.col_bounds[q];
        base + self.sub_bounds[q][k]..base + self.sub_bounds[q][k + 1]
    }

    /// Which observation partition owns global row `r` (boundary
    /// bisection — no uniform-width arithmetic).
    #[inline]
    pub fn partition_of_row(&self, r: usize) -> usize {
        debug_assert!(r < self.n_total, "row {r} outside dataset of {} rows", self.n_total);
        self.row_bounds.partition_point(|&b| b <= r) - 1
    }
}

/// The full P×Q grid: the shared [`Layout`] plus the data blocks.
#[derive(Debug, Clone)]
pub struct Grid {
    pub layout: Layout,
    /// row-major `[p][q]` blocks
    blocks: Vec<Block>,
}

impl Grid {
    /// Partition `ds` into a ragged `p × q` grid (balanced ceil/floor
    /// boundaries; see [`Layout`]). Evenly divisible shapes produce the
    /// paper's uniform `n = N/P`, `m̃ = M/QP` blocks exactly.
    pub fn partition(ds: &Dataset, p: usize, q: usize) -> Result<Grid> {
        let layout = Layout::new(ds.n(), ds.m(), p, q)?;
        Self::partition_with_layout(ds, layout)
    }

    /// Partition `ds` along a pre-staged [`Layout`] (balanced or
    /// throughput-weighted — the blocks simply follow the boundary
    /// vectors).
    pub fn partition_with_layout(ds: &Dataset, layout: Layout) -> Result<Grid> {
        ensure!(
            layout.n_total == ds.n() && layout.m_total == ds.m(),
            "layout is {}x{} but dataset is {}x{}",
            layout.n_total,
            layout.m_total,
            ds.n(),
            ds.m()
        );
        let (p, q) = (layout.p, layout.q);
        let mut blocks = Vec::with_capacity(p * q);
        for pi in 0..p {
            let rr = layout.block_rows(pi);
            let rows = ds.x.slice_rows(rr.start, rr.end);
            let y = ds.y[rr].to_vec();
            for qi in 0..q {
                let cr = layout.block_cols(qi);
                let x = rows.slice_cols(cr.start, cr.end);
                blocks.push(Block { p: pi, q: qi, x, y: y.clone() });
            }
        }
        Ok(Grid { layout, blocks })
    }

    #[inline]
    pub fn block(&self, p: usize, q: usize) -> &Block {
        &self.blocks[p * self.layout.q + q]
    }

    pub fn blocks(&self) -> impl Iterator<Item = &Block> {
        self.blocks.iter()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;

    #[test]
    fn partition_shapes_uniform() {
        let ds = synth::dense_zhang(60, 24, 0);
        let g = Grid::partition(&ds, 3, 2).unwrap();
        assert!(g.layout.is_uniform());
        for pi in 0..3 {
            assert_eq!(g.layout.rows_in(pi), 20);
        }
        for qi in 0..2 {
            assert_eq!(g.layout.cols_in(qi), 12);
            for k in 0..3 {
                assert_eq!(g.layout.sub_cols(qi, k).len(), 4);
            }
        }
        assert_eq!(g.blocks().count(), 6);
        for b in g.blocks() {
            assert_eq!(b.x.rows(), 20);
            assert_eq!(b.x.cols(), 12);
            assert_eq!(b.y.len(), 20);
        }
    }

    #[test]
    fn ragged_shapes_are_balanced() {
        // N=61 over P=3 → 20/20/21; M=26 over Q=2 → 13/13, each split
        // into 3 sub-blocks of 4/4/5
        let ds = synth::dense_zhang(61, 26, 0);
        let g = Grid::partition(&ds, 3, 2).unwrap();
        assert!(!g.layout.is_uniform());
        let row_sizes: Vec<usize> = (0..3).map(|p| g.layout.rows_in(p)).collect();
        assert_eq!(row_sizes.iter().sum::<usize>(), 61);
        assert!(row_sizes.iter().all(|&s| s == 20 || s == 21));
        for qi in 0..2 {
            assert_eq!(g.layout.cols_in(qi), 13);
            let widths: Vec<usize> = (0..3).map(|k| g.layout.sub_cols(qi, k).len()).collect();
            assert_eq!(widths.iter().sum::<usize>(), 13);
            assert!(widths.iter().all(|&w| w == 4 || w == 5));
        }
        for b in g.blocks() {
            assert_eq!(b.x.rows(), g.layout.rows_in(b.p));
            assert_eq!(b.x.cols(), g.layout.cols_in(b.q));
            assert_eq!(b.y.len(), g.layout.rows_in(b.p));
        }
    }

    #[test]
    fn rejects_empty_partitions() {
        let ds = synth::dense_zhang(2, 24, 0);
        assert!(Grid::partition(&ds, 3, 2).is_err(), "N < P");
        let ds = synth::dense_zhang(60, 5, 0);
        assert!(Grid::partition(&ds, 3, 2).is_err(), "M < P·Q");
        let ds = synth::dense_zhang(60, 24, 0);
        assert!(Grid::partition(&ds, 0, 2).is_err(), "P = 0");
    }

    #[test]
    fn split_points_divisible_matches_uniform_arithmetic() {
        assert_eq!(split_points(60, 3), vec![0, 20, 40, 60]);
        assert_eq!(split_points(7, 3), vec![0, 2, 4, 7]);
        assert_eq!(split_points(3, 3), vec![0, 1, 2, 3]);
    }

    #[test]
    fn weighted_split_is_proportional_and_non_empty() {
        // rates 1:2:2 over 100 rows → ~20/40/40
        let b = split_points_weighted(100, &[1.0, 2.0, 2.0]);
        assert_eq!(b, vec![0, 20, 60, 100]);
        // extreme skew still leaves every part non-empty
        let b = split_points_weighted(5, &[1e-6, 1.0, 1e-6, 1.0, 1e-6]);
        assert_eq!(b.len(), 6);
        assert_eq!(*b.last().unwrap(), 5);
        assert!(b.windows(2).all(|w| w[0] < w[1]), "{b:?}");
        // equal weights need not equal split_points (round vs floor),
        // but must still be balanced within one row
        let b = split_points_weighted(61, &[1.0; 3]);
        let sizes: Vec<usize> = b.windows(2).map(|w| w[1] - w[0]).collect();
        assert!(sizes.iter().all(|&s| s == 20 || s == 21), "{sizes:?}");
    }

    #[test]
    fn cost_split_balances_mass_not_counts() {
        // rows 0..20 carry 30 cost units, rows 20..60 carry 3: equal
        // weights must put ~240 units in each of 3 parts, i.e. bounds
        // [0, 8, 16, 60] — nothing like the count-balanced [0,20,40,60]
        let costs: Vec<f64> = (0..60).map(|r| if r < 20 { 30.0 } else { 3.0 }).collect();
        let b = split_points_by_cost(60, &[1.0; 3], &costs);
        assert_eq!(b, vec![0, 8, 16, 60]);
        let mass: Vec<f64> =
            b.windows(2).map(|w| costs[w[0]..w[1]].iter().sum()).collect();
        assert!(mass.iter().all(|&m| m == 240.0), "{mass:?}");
        // uniform costs degrade to count-proportional splitting
        let flat = vec![1.0; 100];
        assert_eq!(
            split_points_by_cost(100, &[1.0, 2.0, 2.0], &flat),
            split_points_weighted(100, &[1.0, 2.0, 2.0])
        );
        // all-zero costs carry no signal — same fallback
        let zero = vec![0.0; 100];
        assert_eq!(
            split_points_by_cost(100, &[1.0, 2.0, 2.0], &zero),
            split_points_weighted(100, &[1.0, 2.0, 2.0])
        );
        // extreme skew still leaves every part non-empty
        let mut spike = vec![0.0; 6];
        spike[0] = 1e9;
        let b = split_points_by_cost(6, &[1.0; 3], &spike);
        assert_eq!(*b.last().unwrap(), 6);
        assert!(b.windows(2).all(|w| w[0] < w[1]), "{b:?}");
    }

    #[test]
    fn cost_layout_keeps_columns_balanced_and_rows_nonempty() {
        let costs: Vec<f64> = (0..60).map(|r| if r < 20 { 30.0 } else { 3.0 }).collect();
        let l = Layout::weighted_by_cost(60, 24, 3, 2, &[1.0; 3], &costs).unwrap();
        assert_eq!(l.row_bounds(), &[0, 8, 16, 60]);
        for qi in 0..2 {
            assert_eq!(l.cols_in(qi), 12);
        }
        assert!(Layout::weighted_by_cost(60, 24, 3, 2, &[1.0; 3], &costs[..59]).is_err());
        assert!(Layout::weighted_by_cost(60, 24, 3, 2, &[1.0; 2], &costs).is_err());
        assert!(
            Layout::weighted_by_cost(60, 24, 3, 2, &[1.0, -1.0, 1.0], &costs).is_err(),
            "negative weight must be rejected"
        );
    }

    #[test]
    fn weighted_layout_sizes_rows_by_throughput() {
        let l = Layout::weighted(400, 24, 4, 2, &[0.25, 1.0, 1.0, 1.0]).unwrap();
        // straggler row gets ~1/13 of the rows, fast rows ~4/13
        assert_eq!(l.rows_in(0), 31);
        assert!((1..4).all(|p| l.rows_in(p) == 123), "{:?}", l.row_bounds());
        // columns stay balanced
        for qi in 0..2 {
            assert_eq!(l.cols_in(qi), 12);
        }
        // geometry invariants hold for consumers
        assert_eq!(l.row_bounds().len(), 5);
        assert_eq!(*l.row_bounds().last().unwrap(), 400);
        for r in [0, 30, 31, 399] {
            let p = l.partition_of_row(r);
            assert!(l.block_rows(p).contains(&r));
        }
    }

    #[test]
    fn weighted_layout_rejects_bad_weights() {
        assert!(Layout::weighted(60, 24, 3, 2, &[1.0, 2.0]).is_err(), "wrong length");
        assert!(Layout::weighted(60, 24, 3, 2, &[1.0, 0.0, 2.0]).is_err(), "zero weight");
        assert!(Layout::weighted(60, 24, 3, 2, &[1.0, f64::NAN, 2.0]).is_err(), "NaN weight");
        assert!(Layout::weighted(2, 24, 3, 2, &[1.0; 3]).is_err(), "N < P");
    }

    #[test]
    fn partition_with_layout_checks_dataset_shape() {
        let ds = synth::dense_zhang(60, 24, 0);
        let l = Layout::new(61, 24, 3, 2).unwrap();
        assert!(Grid::partition_with_layout(&ds, l).is_err());
        let l = Layout::weighted(60, 24, 3, 2, &[0.5, 1.0, 1.0]).unwrap();
        let g = Grid::partition_with_layout(&ds, l).unwrap();
        let total: usize = (0..3).map(|p| g.layout.rows_in(p)).sum();
        assert_eq!(total, 60);
        for b in g.blocks() {
            assert_eq!(b.x.rows(), g.layout.rows_in(b.p));
            assert_eq!(b.y.len(), g.layout.rows_in(b.p));
        }
    }

    #[test]
    fn blocks_tile_the_matrix_exactly() {
        for (n, m) in [(30usize, 12usize), (31, 13), (29, 17)] {
            let ds = synth::dense_zhang(n, m, 2);
            let g = Grid::partition(&ds, 3, 2).unwrap();
            // reconstruct every entry through the block view
            for gr in 0..n {
                for gc in 0..m {
                    let p = g.layout.partition_of_row(gr);
                    let q = (0..2).find(|&qi| g.layout.block_cols(qi).contains(&gc)).unwrap();
                    let b = g.block(p, q);
                    let mut w = vec![0.0f32; 1];
                    let lc = gc - g.layout.block_cols(q).start;
                    let lr = gr - g.layout.block_rows(p).start;
                    b.x.copy_row_range(lr, lc, lc + 1, &mut w);
                    let mut orig = vec![0.0f32; 1];
                    ds.x.copy_row_range(gr, gc, gc + 1, &mut orig);
                    assert_eq!(w, orig, "mismatch at ({gr},{gc}) in {n}x{m}");
                }
            }
        }
    }

    #[test]
    fn sub_and_global_cols_cover_disjointly() {
        for m in [40usize, 41, 43] {
            let ds = synth::dense_zhang(20, m, 1);
            let g = Grid::partition(&ds, 2, 2).unwrap();
            let mut seen = vec![false; m];
            for q in 0..2 {
                for k in 0..2 {
                    for c in g.layout.global_cols(q, k) {
                        assert!(!seen[c], "overlap at {c} (m={m})");
                        seen[c] = true;
                    }
                }
            }
            assert!(seen.iter().all(|&s| s), "gap in cover (m={m})");
        }
    }

    #[test]
    fn partition_of_row_matches_boundaries() {
        let l = Layout::new(61, 26, 3, 2).unwrap();
        for r in 0..61 {
            let p = l.partition_of_row(r);
            assert!(l.block_rows(p).contains(&r), "row {r} → partition {p}");
        }
        assert_eq!(l.partition_of_row(0), 0);
        assert_eq!(l.partition_of_row(60), 2);
    }

    #[test]
    fn sparse_partition_roundtrip() {
        for (n, m) in [(40usize, 80usize), (41, 83)] {
            let ds = synth::sparse_pra(n, m, 6, 3);
            let g = Grid::partition(&ds, 2, 2).unwrap();
            let total_nnz: usize = g.blocks().map(|b| b.x.nnz()).sum();
            assert_eq!(total_nnz, ds.x.nnz());
        }
    }

    #[test]
    fn labels_replicated_across_feature_partitions() {
        let ds = synth::dense_zhang(21, 8, 4);
        let g = Grid::partition(&ds, 2, 2).unwrap();
        for p in 0..2 {
            assert_eq!(g.block(p, 0).y, g.block(p, 1).y);
        }
    }
}
