//! The doubly distributed partitioner (paper Figure 1).
//!
//! Splits a [`Dataset`] into `P` observation partitions × `Q` feature
//! partitions; each block's columns are further divided into `P`
//! sub-blocks of width `m̃ = M/(Q·P)`. Workers address their sub-block
//! through [`Grid::sub_cols`] (block-local column range) and the global
//! parameter vector through [`Grid::global_cols`].

use anyhow::{ensure, Result};

use super::{Dataset, Store};

/// One worker's local shard: the `n × m` slab `x^{p,q}` plus the labels
/// of its observation rows (replicated across the Q feature partitions,
/// exactly like a Spark copartitioning would).
#[derive(Debug, Clone)]
pub struct Block {
    pub p: usize,
    pub q: usize,
    pub x: Store,
    pub y: Vec<f32>,
}

/// The full P×Q grid plus all derived dimensions.
#[derive(Debug, Clone)]
pub struct Grid {
    pub p: usize,
    pub q: usize,
    /// rows per observation partition (`n = N/P`)
    pub n_per: usize,
    /// features per feature block (`m = M/Q`)
    pub m_per: usize,
    /// features per sub-block (`m̃ = M/QP`)
    pub mtilde: usize,
    pub n_total: usize,
    pub m_total: usize,
    /// row-major `[p][q]` blocks
    blocks: Vec<Block>,
}

impl Grid {
    /// Partition `ds` into a `p × q` grid. Requires `N % P == 0` and
    /// `M % (Q·P) == 0` (the paper's `n = N/P`, `m̃ = M/QP` assumption —
    /// generators and presets always satisfy it).
    pub fn partition(ds: &Dataset, p: usize, q: usize) -> Result<Grid> {
        let (n_total, m_total) = (ds.n(), ds.m());
        ensure!(p > 0 && q > 0, "P and Q must be positive");
        ensure!(n_total % p == 0, "N={n_total} not divisible by P={p}");
        ensure!(m_total % (q * p) == 0, "M={m_total} not divisible by Q·P={}", q * p);
        let n_per = n_total / p;
        let m_per = m_total / q;
        let mtilde = m_per / p;

        let mut blocks = Vec::with_capacity(p * q);
        for pi in 0..p {
            let rows = ds.x.slice_rows(pi * n_per, (pi + 1) * n_per);
            let y = ds.y[pi * n_per..(pi + 1) * n_per].to_vec();
            for qi in 0..q {
                let x = rows.slice_cols(qi * m_per, (qi + 1) * m_per);
                blocks.push(Block { p: pi, q: qi, x, y: y.clone() });
            }
        }
        Ok(Grid { p, q, n_per, m_per, mtilde, n_total, m_total, blocks })
    }

    #[inline]
    pub fn block(&self, p: usize, q: usize) -> &Block {
        &self.blocks[p * self.q + q]
    }

    pub fn blocks(&self) -> impl Iterator<Item = &Block> {
        self.blocks.iter()
    }

    /// Block-local column range of sub-block `k` (`k ∈ 0..P`).
    #[inline]
    pub fn sub_cols(&self, k: usize) -> std::ops::Range<usize> {
        k * self.mtilde..(k + 1) * self.mtilde
    }

    /// Global column range of sub-block `k` of feature block `q`.
    #[inline]
    pub fn global_cols(&self, q: usize, k: usize) -> std::ops::Range<usize> {
        let base = q * self.m_per;
        base + k * self.mtilde..base + (k + 1) * self.mtilde
    }

    /// Global column range of feature block `q`.
    #[inline]
    pub fn block_cols(&self, q: usize) -> std::ops::Range<usize> {
        q * self.m_per..(q + 1) * self.m_per
    }

    /// Global row range of observation partition `p`.
    #[inline]
    pub fn block_rows(&self, p: usize) -> std::ops::Range<usize> {
        p * self.n_per..(p + 1) * self.n_per
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;

    #[test]
    fn partition_shapes() {
        let ds = synth::dense_zhang(60, 24, 0);
        let g = Grid::partition(&ds, 3, 2).unwrap();
        assert_eq!((g.n_per, g.m_per, g.mtilde), (20, 12, 4));
        assert_eq!(g.blocks().count(), 6);
        for b in g.blocks() {
            assert_eq!(b.x.rows(), 20);
            assert_eq!(b.x.cols(), 12);
            assert_eq!(b.y.len(), 20);
        }
    }

    #[test]
    fn rejects_indivisible() {
        let ds = synth::dense_zhang(61, 24, 0);
        assert!(Grid::partition(&ds, 3, 2).is_err());
        let ds = synth::dense_zhang(60, 26, 0);
        assert!(Grid::partition(&ds, 3, 2).is_err());
    }

    #[test]
    fn blocks_tile_the_matrix_exactly() {
        let ds = synth::dense_zhang(30, 12, 2);
        let g = Grid::partition(&ds, 3, 2).unwrap();
        // reconstruct every entry through the block view
        for gr in 0..30 {
            for gc in 0..12 {
                let p = gr / g.n_per;
                let q = gc / g.m_per;
                let b = g.block(p, q);
                let mut w = vec![0.0f32; 1];
                let lc = gc - q * g.m_per;
                b.x.copy_row_range(gr - p * g.n_per, lc, lc + 1, &mut w);
                let mut orig = vec![0.0f32; 1];
                ds.x.copy_row_range(gr, gc, gc + 1, &mut orig);
                assert_eq!(w, orig, "mismatch at ({gr},{gc})");
            }
        }
    }

    #[test]
    fn sub_and_global_cols_cover_disjointly() {
        let ds = synth::dense_zhang(20, 40, 1);
        let g = Grid::partition(&ds, 2, 2).unwrap();
        let mut seen = vec![false; 40];
        for q in 0..2 {
            for k in 0..2 {
                for c in g.global_cols(q, k) {
                    assert!(!seen[c], "overlap at {c}");
                    seen[c] = true;
                }
            }
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn sparse_partition_roundtrip() {
        let ds = synth::sparse_pra(40, 80, 6, 3);
        let g = Grid::partition(&ds, 2, 2).unwrap();
        let total_nnz: usize = g.blocks().map(|b| b.x.nnz()).sum();
        assert_eq!(total_nnz, ds.x.nnz());
    }

    #[test]
    fn labels_replicated_across_feature_partitions() {
        let ds = synth::dense_zhang(20, 8, 4);
        let g = Grid::partition(&ds, 2, 2).unwrap();
        for p in 0..2 {
            assert_eq!(g.block(p, 0).y, g.block(p, 1).y);
        }
    }
}
