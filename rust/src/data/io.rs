//! Dataset I/O: LIBSVM text format (the lingua franca for sparse SVM
//! data — real SemMed-style matrices would arrive this way) and a
//! compact binary format for fast reloads of generated data.

use std::io::{BufRead, BufReader, BufWriter, Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use super::{CsrMatrix, Dataset, DenseMatrix, Store};

/// Parse LIBSVM text (`label idx:val idx:val …`, 1-based indices).
/// `m_hint` fixes the feature count (0 ⇒ infer from the max index).
pub fn read_libsvm(path: &Path, m_hint: usize) -> Result<Dataset> {
    let f = std::fs::File::open(path).with_context(|| format!("opening {path:?}"))?;
    let mut entries: Vec<Vec<(u32, f32)>> = Vec::new();
    let mut y = Vec::new();
    let mut max_col = 0u32;
    for (lno, line) in BufReader::new(f).lines().enumerate() {
        let line = line?;
        let line = line.split('#').next().unwrap_or("").trim();
        if line.is_empty() {
            continue;
        }
        let mut parts = line.split_whitespace();
        let label: f32 = parts
            .next()
            .unwrap()
            .parse()
            .with_context(|| format!("{path:?}:{}: bad label", lno + 1))?;
        let mut row = Vec::new();
        for tok in parts {
            let (idx, val) = tok
                .split_once(':')
                .with_context(|| format!("{path:?}:{}: bad feature {tok:?}", lno + 1))?;
            let idx: u32 = idx.parse().with_context(|| format!("bad index {idx:?}"))?;
            if idx == 0 {
                bail!("{path:?}:{}: LIBSVM indices are 1-based", lno + 1);
            }
            let val: f32 = val.parse().with_context(|| format!("bad value {val:?}"))?;
            max_col = max_col.max(idx);
            row.push((idx - 1, val));
        }
        entries.push(row);
        y.push(if label > 0.0 { 1.0 } else { -1.0 });
    }
    let m = if m_hint > 0 { m_hint } else { max_col as usize };
    if (max_col as usize) > m {
        bail!("feature index {max_col} exceeds m = {m}");
    }
    let rows = entries.len();
    let x = CsrMatrix::from_row_entries(rows, m, entries);
    Ok(Dataset {
        x: Store::Sparse(x),
        y,
        name: path.file_stem().map(|s| s.to_string_lossy().into_owned()).unwrap_or_default(),
    })
}

/// Write LIBSVM text.
pub fn write_libsvm(ds: &Dataset, path: &Path) -> Result<()> {
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    let mut w = BufWriter::new(std::fs::File::create(path)?);
    let m = ds.m();
    let mut buf = vec![0.0f32; m];
    for r in 0..ds.n() {
        write!(w, "{}", if ds.y[r] > 0.0 { "+1" } else { "-1" })?;
        match &ds.x {
            Store::Sparse(x) => {
                for (c, v) in x.row_entries(r) {
                    write!(w, " {}:{}", c + 1, v)?;
                }
            }
            Store::Dense(_) => {
                ds.x.copy_row_range(r, 0, m, &mut buf);
                for (c, &v) in buf.iter().enumerate() {
                    if v != 0.0 {
                        write!(w, " {}:{}", c + 1, v)?;
                    }
                }
            }
        }
        writeln!(w)?;
    }
    Ok(())
}

const BIN_MAGIC: &[u8; 8] = b"SODDAB01";

/// Compact binary dump (dense or CSR) for fast reloads.
pub fn write_binary(ds: &Dataset, path: &Path) -> Result<()> {
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    let mut w = BufWriter::new(std::fs::File::create(path)?);
    w.write_all(BIN_MAGIC)?;
    let put64 = |w: &mut BufWriter<std::fs::File>, v: u64| w.write_all(&v.to_le_bytes());
    match &ds.x {
        Store::Dense(x) => {
            w.write_all(&[0u8])?;
            put64(&mut w, x.rows as u64)?;
            put64(&mut w, x.cols as u64)?;
            for v in &x.data {
                w.write_all(&v.to_le_bytes())?;
            }
        }
        Store::Sparse(x) => {
            w.write_all(&[1u8])?;
            put64(&mut w, x.rows as u64)?;
            put64(&mut w, x.cols as u64)?;
            put64(&mut w, x.values.len() as u64)?;
            for v in &x.indptr {
                w.write_all(&v.to_le_bytes())?;
            }
            for v in &x.indices {
                w.write_all(&v.to_le_bytes())?;
            }
            for v in &x.values {
                w.write_all(&v.to_le_bytes())?;
            }
        }
    }
    for v in &ds.y {
        w.write_all(&v.to_le_bytes())?;
    }
    Ok(())
}

pub fn read_binary(path: &Path) -> Result<Dataset> {
    let mut f = BufReader::new(std::fs::File::open(path).with_context(|| format!("opening {path:?}"))?);
    let mut magic = [0u8; 8];
    f.read_exact(&mut magic)?;
    if &magic != BIN_MAGIC {
        bail!("{path:?} is not a SODDA binary dataset");
    }
    let mut kind = [0u8; 1];
    f.read_exact(&mut kind)?;
    let get64 = |f: &mut BufReader<std::fs::File>| -> Result<u64> {
        let mut b = [0u8; 8];
        f.read_exact(&mut b)?;
        Ok(u64::from_le_bytes(b))
    };
    let read_f32s = |f: &mut BufReader<std::fs::File>, n: usize| -> Result<Vec<f32>> {
        let mut raw = vec![0u8; n * 4];
        f.read_exact(&mut raw)?;
        Ok(raw.chunks_exact(4).map(|c| f32::from_le_bytes(c.try_into().unwrap())).collect())
    };
    let read_u32s = |f: &mut BufReader<std::fs::File>, n: usize| -> Result<Vec<u32>> {
        let mut raw = vec![0u8; n * 4];
        f.read_exact(&mut raw)?;
        Ok(raw.chunks_exact(4).map(|c| u32::from_le_bytes(c.try_into().unwrap())).collect())
    };
    let (x, rows) = match kind[0] {
        0 => {
            let rows = get64(&mut f)? as usize;
            let cols = get64(&mut f)? as usize;
            let data = read_f32s(&mut f, rows * cols)?;
            (Store::Dense(DenseMatrix::from_rows(rows, cols, data)), rows)
        }
        1 => {
            let rows = get64(&mut f)? as usize;
            let cols = get64(&mut f)? as usize;
            let nnz = get64(&mut f)? as usize;
            let indptr = read_u32s(&mut f, rows + 1)?;
            let indices = read_u32s(&mut f, nnz)?;
            let values = read_f32s(&mut f, nnz)?;
            (Store::Sparse(CsrMatrix { rows, cols, indptr, indices, values }), rows)
        }
        k => bail!("unknown storage kind {k}"),
    };
    let y = read_f32s(&mut f, rows)?;
    Ok(Dataset {
        x,
        y,
        name: path.file_stem().map(|s| s.to_string_lossy().into_owned()).unwrap_or_default(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;

    fn tmp(name: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("sodda-io-{name}"))
    }

    #[test]
    fn libsvm_roundtrip_sparse() {
        let ds = synth::sparse_pra(50, 80, 6, 1);
        let p = tmp("rt.svm");
        write_libsvm(&ds, &p).unwrap();
        let back = read_libsvm(&p, 80).unwrap();
        assert_eq!(back.n(), 50);
        assert_eq!(back.m(), 80);
        assert_eq!(back.y, ds.y);
        match (&ds.x, &back.x) {
            (Store::Sparse(a), Store::Sparse(b)) => {
                assert_eq!(a.indices, b.indices);
                for (va, vb) in a.values.iter().zip(&b.values) {
                    assert!((va - vb).abs() < 1e-5);
                }
            }
            _ => panic!("expected sparse"),
        }
    }

    #[test]
    fn libsvm_reads_dense_written_data() {
        let ds = synth::dense_zhang(10, 6, 2);
        let p = tmp("dense.svm");
        write_libsvm(&ds, &p).unwrap();
        let back = read_libsvm(&p, 6).unwrap();
        // dense data has no exact zeros generically; objective must agree
        let w = vec![0.1f32; 6];
        crate::assert_close!(
            back.objective(&w, crate::loss::Loss::Hinge),
            ds.objective(&w, crate::loss::Loss::Hinge),
            1e-4
        );
    }

    #[test]
    fn libsvm_rejects_zero_index() {
        let p = tmp("bad.svm");
        std::fs::write(&p, "+1 0:1.5\n").unwrap();
        assert!(read_libsvm(&p, 0).is_err());
    }

    #[test]
    fn libsvm_infers_m_and_skips_comments() {
        let p = tmp("infer.svm");
        std::fs::write(&p, "# header\n+1 3:1.0\n-1 7:2.0 # trailing\n\n").unwrap();
        let ds = read_libsvm(&p, 0).unwrap();
        assert_eq!(ds.m(), 7);
        assert_eq!(ds.y, vec![1.0, -1.0]);
    }

    #[test]
    fn binary_roundtrip_dense_and_sparse() {
        for ds in [synth::dense_zhang(20, 8, 3), synth::sparse_pra(20, 30, 5, 3)] {
            let p = tmp(&format!("bin-{}", ds.x.is_sparse()));
            write_binary(&ds, &p).unwrap();
            let back = read_binary(&p).unwrap();
            assert_eq!(back.n(), ds.n());
            assert_eq!(back.m(), ds.m());
            assert_eq!(back.y, ds.y);
            let w = vec![0.07f32; ds.m()];
            crate::assert_close!(
                back.objective(&w, crate::loss::Loss::Squared),
                ds.objective(&w, crate::loss::Loss::Squared),
                1e-5
            );
        }
    }

    #[test]
    fn binary_rejects_wrong_magic() {
        let p = tmp("magic");
        std::fs::write(&p, b"NOTSODDA....").unwrap();
        assert!(read_binary(&p).is_err());
    }
}
