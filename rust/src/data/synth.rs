//! Synthetic dataset generators.
//!
//! * [`dense_zhang`] — the §5.1 procedure (from Zhang, Lee & Shin 2012,
//!   also used by RADiSA): `x_i ~ U[-1,1]^M`, a planted `z ~ U[-1,1]^M`,
//!   `y_i = sgn(x_i·z)` with 1% label flips, features standardized to
//!   unit variance.
//! * [`sparse_pra`] — the §5.2 substitute for the SemMedDB/PRA datasets
//!   (not publicly available as matrices): binary-ish path-feature rows
//!   with power-law nnz, labels from a planted sparse hyperplane with
//!   flips. Preserves what matters for the experiment: a large sparse
//!   SVM problem in CSR format.

use crate::util::rng::Rng;

use super::{CsrMatrix, Dataset, DenseMatrix, Store};

/// Label-flip probability used by the paper ("probability 0.01 of
/// flipping the sign").
pub const FLIP_PROB: f64 = 0.01;

/// §5.1 dense generator. Deterministic in `seed`.
pub fn dense_zhang(n: usize, m: usize, seed: u64) -> Dataset {
    let mut rng = Rng::seed_from_u64(seed);
    let mut x = DenseMatrix::zeros(n, m);
    for v in x.data.iter_mut() {
        *v = rng.f32_range(-1.0, 1.0);
    }
    let z: Vec<f32> = (0..m).map(|_| rng.f32_range(-1.0, 1.0)).collect();

    // labels before standardization, as in the source procedure
    let mut y = Vec::with_capacity(n);
    for r in 0..n {
        let dot: f32 = x.row(r).iter().zip(&z).map(|(a, b)| a * b).sum();
        let mut label = if dot >= 0.0 { 1.0 } else { -1.0 };
        if rng.bool_with(FLIP_PROB) {
            label = -label;
        }
        y.push(label);
    }

    standardize(&mut x);
    Dataset { x: Store::Dense(x), y, name: format!("synthetic-dense-{n}x{m}") }
}

/// Standardize features to unit variance (mean untouched, matching the
/// paper's "features are standardized to have unit variance").
pub fn standardize(x: &mut DenseMatrix) {
    let n = x.rows as f32;
    for c in 0..x.cols {
        let mut sum = 0.0f32;
        let mut sumsq = 0.0f32;
        for r in 0..x.rows {
            let v = x.row(r)[c];
            sum += v;
            sumsq += v * v;
        }
        let mean = sum / n;
        let var = (sumsq / n - mean * mean).max(1e-12);
        let inv_sd = 1.0 / var.sqrt();
        for r in 0..x.rows {
            x.row_mut(r)[c] *= inv_sd;
        }
    }
}

/// §5.2 sparse substitute (SemMed/PRA-like). Deterministic in `seed`.
///
/// * nnz per row ~ clamp(Zipf-ish power law, 1, `max_nnz`) around
///   `avg_nnz` — PRA path-feature vectors are extremely sparse with a
///   heavy tail.
/// * values in (0, 1] (path probabilities), planted sparse hyperplane
///   over ~5% of features, `FLIP_PROB` label noise.
pub fn sparse_pra(n: usize, m: usize, avg_nnz: usize, seed: u64) -> Dataset {
    let mut rng = Rng::seed_from_u64(seed ^ 0x5EED_5EED);
    let support = (m / 20).max(1);
    let mut w_true = vec![0.0f32; m];
    for _ in 0..support {
        let c = rng.below(m);
        w_true[c] = rng.f32_range(-1.0, 1.0) * 2.0;
    }
    let max_nnz = (avg_nnz * 8).min(m);

    let mut entries = Vec::with_capacity(n);
    let mut y = Vec::with_capacity(n);
    for _ in 0..n {
        // heavy-tailed nnz: inverse-CDF of a truncated power law
        let u: f64 = rng.unit_f64().max(1e-6);
        let nnz = ((avg_nnz as f64 * 0.5) / u.powf(0.5)).round() as usize;
        let nnz = nnz.clamp(1, max_nnz);
        let mut row: Vec<(u32, f32)> = Vec::with_capacity(nnz);
        let mut seen = std::collections::HashSet::with_capacity(nnz);
        while row.len() < nnz {
            let c = rng.below(m) as u32;
            if seen.insert(c) {
                row.push((c, rng.f32_range(0.05, 1.0)));
            }
        }
        let dot: f32 = row.iter().map(|&(c, v)| v * w_true[c as usize]).sum();
        let mut label = if dot >= 0.0 { 1.0 } else { -1.0 };
        if rng.bool_with(FLIP_PROB) {
            label = -label;
        }
        entries.push(row);
        y.push(label);
    }
    let x = CsrMatrix::from_row_entries(n, m, entries);
    Dataset { x: Store::Sparse(x), y, name: format!("synthetic-pra-{n}x{m}") }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dense_is_deterministic_per_seed() {
        let a = dense_zhang(50, 20, 7);
        let b = dense_zhang(50, 20, 7);
        let c = dense_zhang(50, 20, 8);
        match (&a.x, &b.x, &c.x) {
            (Store::Dense(ma), Store::Dense(mb), Store::Dense(mc)) => {
                assert_eq!(ma.data, mb.data);
                assert_ne!(ma.data, mc.data);
            }
            _ => unreachable!(),
        }
        assert_eq!(a.y, b.y);
    }

    #[test]
    fn dense_features_have_unit_variance() {
        let ds = dense_zhang(2000, 10, 3);
        let Store::Dense(x) = &ds.x else { unreachable!() };
        for c in 0..10 {
            let vals: Vec<f32> = (0..x.rows).map(|r| x.row(r)[c]).collect();
            let mean: f32 = vals.iter().sum::<f32>() / vals.len() as f32;
            let var: f32 =
                vals.iter().map(|v| (v - mean) * (v - mean)).sum::<f32>() / vals.len() as f32;
            assert!((var - 1.0).abs() < 0.05, "col {c} var {var}");
        }
    }

    #[test]
    fn dense_labels_mostly_match_plant() {
        // 1% flips => a re-derived separator should classify ≳90% correctly;
        // we just assert labels are ±1 and both classes appear.
        let ds = dense_zhang(500, 30, 11);
        assert!(ds.y.iter().all(|&v| v == 1.0 || v == -1.0));
        assert!(ds.y.iter().any(|&v| v == 1.0) && ds.y.iter().any(|&v| v == -1.0));
    }

    #[test]
    fn sparse_has_requested_shape_and_density() {
        let ds = sparse_pra(400, 1000, 12, 5);
        let Store::Sparse(x) = &ds.x else { unreachable!() };
        assert_eq!((x.rows, x.cols), (400, 1000));
        let avg = x.nnz() as f64 / 400.0;
        assert!(avg > 2.0 && avg < 60.0, "avg nnz {avg}");
        assert!(x.density() < 0.06);
        assert!(ds.y.iter().all(|&v| v == 1.0 || v == -1.0));
    }

    #[test]
    fn sparse_is_deterministic_per_seed() {
        let a = sparse_pra(100, 200, 8, 1);
        let b = sparse_pra(100, 200, 8, 1);
        match (&a.x, &b.x) {
            (Store::Sparse(ma), Store::Sparse(mb)) => {
                assert_eq!(ma.indices, mb.indices);
                assert_eq!(ma.values, mb.values);
            }
            _ => unreachable!(),
        }
    }
}
