//! Dense row-major matrix — the storage for the paper's §5.1 synthetic
//! experiments ("all the data is in the dense format").

/// Row-major dense `n × m` block of the design matrix.
#[derive(Debug, Clone, PartialEq)]
pub struct DenseMatrix {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl DenseMatrix {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Self { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn from_rows(rows: usize, cols: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), rows * cols, "dense data length mismatch");
        Self { rows, cols, data }
    }

    #[inline]
    pub fn row(&self, r: usize) -> &[f32] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, r: usize) -> &mut [f32] {
        &mut self.data[r * self.cols..(r + 1) * self.cols]
    }

    /// `x_r[lo..hi] · w` where `w.len() == hi - lo`.
    #[inline]
    pub fn row_dot_range(&self, r: usize, lo: usize, hi: usize, w: &[f32]) -> f32 {
        debug_assert_eq!(w.len(), hi - lo);
        let row = &self.row(r)[lo..hi];
        // 4-way unrolled accumulation: this is the innermost hot loop of
        // the native engine (see EXPERIMENTS.md §Perf).
        let mut acc = [0.0f32; 4];
        let chunks = row.len() / 4;
        for c in 0..chunks {
            let i = c * 4;
            acc[0] += row[i] * w[i];
            acc[1] += row[i + 1] * w[i + 1];
            acc[2] += row[i + 2] * w[i + 2];
            acc[3] += row[i + 3] * w[i + 3];
        }
        let mut s = acc[0] + acc[1] + acc[2] + acc[3];
        for i in chunks * 4..row.len() {
            s += row[i] * w[i];
        }
        s
    }

    /// `out += scale · x_r[lo..hi]` where `out.len() == hi - lo`.
    #[inline]
    pub fn add_row_scaled_range(&self, r: usize, lo: usize, hi: usize, scale: f32, out: &mut [f32]) {
        debug_assert_eq!(out.len(), hi - lo);
        if scale == 0.0 {
            return; // hinge gradients are frequently exactly zero
        }
        let row = &self.row(r)[lo..hi];
        for (o, &v) in out.iter_mut().zip(row) {
            *o += scale * v;
        }
    }

    /// Copy a column range of a row into `out` (XLA buffer staging).
    pub fn copy_row_range(&self, r: usize, lo: usize, hi: usize, out: &mut [f32]) {
        out.copy_from_slice(&self.row(r)[lo..hi]);
    }

    /// Slice a sub-matrix by column range (partitioning path, not hot).
    pub fn slice_cols(&self, lo: usize, hi: usize) -> DenseMatrix {
        let cols = hi - lo;
        let mut data = Vec::with_capacity(self.rows * cols);
        for r in 0..self.rows {
            data.extend_from_slice(&self.row(r)[lo..hi]);
        }
        DenseMatrix { rows: self.rows, cols, data }
    }

    /// Slice a sub-matrix by row range.
    pub fn slice_rows(&self, lo: usize, hi: usize) -> DenseMatrix {
        DenseMatrix {
            rows: hi - lo,
            cols: self.cols,
            data: self.data[lo * self.cols..hi * self.cols].to_vec(),
        }
    }

    pub fn nnz(&self) -> usize {
        self.data.iter().filter(|v| **v != 0.0).count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::assert_close;

    fn sample() -> DenseMatrix {
        DenseMatrix::from_rows(3, 4, (0..12).map(|v| v as f32).collect())
    }

    #[test]
    fn row_access() {
        let m = sample();
        assert_eq!(m.row(1), &[4.0, 5.0, 6.0, 7.0]);
    }

    #[test]
    fn row_dot_range_matches_naive() {
        let m = sample();
        let w = [2.0, -1.0, 0.5];
        let got = m.row_dot_range(2, 1, 4, &w);
        let naive: f32 = m.row(2)[1..4].iter().zip(&w).map(|(a, b)| a * b).sum();
        assert_close!(got, naive);
    }

    #[test]
    fn row_dot_unroll_edge_cases() {
        // widths around the 4-way unroll boundary
        for cols in 1..=9 {
            let m = DenseMatrix::from_rows(1, cols, (0..cols).map(|v| v as f32 + 1.0).collect());
            let w: Vec<f32> = (0..cols).map(|v| 0.5 - v as f32).collect();
            let naive: f32 = m.row(0).iter().zip(&w).map(|(a, b)| a * b).sum();
            assert_close!(m.row_dot_range(0, 0, cols, &w), naive, 1e-4, 1e-5);
        }
    }

    #[test]
    fn add_row_scaled() {
        let m = sample();
        let mut out = vec![1.0; 2];
        m.add_row_scaled_range(0, 1, 3, 2.0, &mut out);
        assert_eq!(out, vec![1.0 + 2.0 * 1.0, 1.0 + 2.0 * 2.0]);
    }

    #[test]
    fn slices() {
        let m = sample();
        let c = m.slice_cols(1, 3);
        assert_eq!(c.rows, 3);
        assert_eq!(c.cols, 2);
        assert_eq!(c.row(2), &[9.0, 10.0]);
        let r = m.slice_rows(1, 3);
        assert_eq!(r.rows, 2);
        assert_eq!(r.row(0), m.row(1));
    }

    #[test]
    fn nnz_counts_nonzeros() {
        let m = DenseMatrix::from_rows(1, 4, vec![0.0, 1.0, 0.0, 2.0]);
        assert_eq!(m.nnz(), 2);
    }
}
