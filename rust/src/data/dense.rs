//! Dense row-major matrix — the storage for the paper's §5.1 synthetic
//! experiments ("all the data is in the dense format").
//!
//! The dot/axpy primitives here define the **one accumulation order**
//! shared by the per-row scalar path and the batched kernel layer
//! ([`crate::engine::kernels`]): 8-wide unrolled accumulators reduced
//! pairwise, remainder handled sequentially. Batched variants
//! ([`DenseMatrix::rows_dot_range_into`], [`DenseMatrix::add_rows_scaled_range`])
//! reuse that order per row, so batching changes throughput, never bits.
//!
//! All batched accessors write into caller-provided slices and allocate
//! nothing — they are the storage layer beneath the `_into` kernels of
//! the zero-allocation steady state (README "Steady-state memory"); the
//! kernels own the clear/resize of the recycled buffers, the accessors
//! only ever fill exactly `out.len()` elements.

/// 8-lane multiply-accumulate into `acc` (one unrolled chunk).
#[inline]
fn madd8(acc: &mut [f32; 8], a: &[f32], b: &[f32]) {
    for (acc_k, (&x, &y)) in acc.iter_mut().zip(a.iter().zip(b)) {
        *acc_k += x * y;
    }
}

/// Pairwise horizontal reduction of the 8 accumulator lanes.
#[inline]
fn hsum8(acc: &[f32; 8]) -> f32 {
    ((acc[0] + acc[1]) + (acc[2] + acc[3])) + ((acc[4] + acc[5]) + (acc[6] + acc[7]))
}

/// `a · b` with 8-wide unrolled accumulators — the innermost hot loop of
/// the native engine (see EXPERIMENTS.md §Perf).
#[inline]
pub(crate) fn dot8(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = [0.0f32; 8];
    let mut ca = a.chunks_exact(8);
    let mut cb = b.chunks_exact(8);
    for (xs, ys) in (&mut ca).zip(&mut cb) {
        madd8(&mut acc, xs, ys);
    }
    let mut s = hsum8(&acc);
    for (x, y) in ca.remainder().iter().zip(cb.remainder()) {
        s += x * y;
    }
    s
}

/// `(a0 · b, a1 · b)` in one streaming pass over `b` (two rows share the
/// weight loads). Each dot accumulates exactly as [`dot8`].
#[inline]
pub(crate) fn dot8_rows2(a0: &[f32], a1: &[f32], b: &[f32]) -> (f32, f32) {
    debug_assert!(a0.len() == b.len() && a1.len() == b.len());
    let mut acc0 = [0.0f32; 8];
    let mut acc1 = [0.0f32; 8];
    let split = b.len() - b.len() % 8;
    let (h0, t0) = a0.split_at(split);
    let (h1, t1) = a1.split_at(split);
    let (hb, tb) = b.split_at(split);
    for ((xs0, xs1), ys) in h0.chunks_exact(8).zip(h1.chunks_exact(8)).zip(hb.chunks_exact(8)) {
        madd8(&mut acc0, xs0, ys);
        madd8(&mut acc1, xs1, ys);
    }
    let (mut s0, mut s1) = (hsum8(&acc0), hsum8(&acc1));
    for ((&x0, &x1), &y) in t0.iter().zip(t1).zip(tb) {
        s0 += x0 * y;
        s1 += x1 * y;
    }
    (s0, s1)
}

/// `(a · b0, a · b1)` in one streaming pass over `a` (the SVRG inner
/// step's current/reference margins). Each dot accumulates as [`dot8`].
#[inline]
pub(crate) fn dot8_pair(a: &[f32], b0: &[f32], b1: &[f32]) -> (f32, f32) {
    debug_assert!(b0.len() == a.len() && b1.len() == a.len());
    let mut acc0 = [0.0f32; 8];
    let mut acc1 = [0.0f32; 8];
    let split = a.len() - a.len() % 8;
    let (ha, ta) = a.split_at(split);
    let (h0, t0) = b0.split_at(split);
    let (h1, t1) = b1.split_at(split);
    for ((xs, ys0), ys1) in ha.chunks_exact(8).zip(h0.chunks_exact(8)).zip(h1.chunks_exact(8)) {
        madd8(&mut acc0, xs, ys0);
        madd8(&mut acc1, xs, ys1);
    }
    let (mut s0, mut s1) = (hsum8(&acc0), hsum8(&acc1));
    for ((&x, &y0), &y1) in ta.iter().zip(t0).zip(t1) {
        s0 += x * y0;
        s1 += x * y1;
    }
    (s0, s1)
}

/// Row-major dense `n × m` block of the design matrix.
#[derive(Debug, Clone, PartialEq)]
pub struct DenseMatrix {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl DenseMatrix {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Self { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn from_rows(rows: usize, cols: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), rows * cols, "dense data length mismatch");
        Self { rows, cols, data }
    }

    #[inline]
    pub fn row(&self, r: usize) -> &[f32] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, r: usize) -> &mut [f32] {
        &mut self.data[r * self.cols..(r + 1) * self.cols]
    }

    /// `x_r[lo..hi] · w` where `w.len() == hi - lo`.
    #[inline]
    pub fn row_dot_range(&self, r: usize, lo: usize, hi: usize, w: &[f32]) -> f32 {
        debug_assert_eq!(w.len(), hi - lo);
        dot8(&self.row(r)[lo..hi], w)
    }

    /// `(x_r[lo..hi] · wa, x_r[lo..hi] · wb)` in a single traversal of
    /// the row; each dot matches [`Self::row_dot_range`] bit-for-bit.
    #[inline]
    pub fn row_dot2_range(&self, r: usize, lo: usize, hi: usize, wa: &[f32], wb: &[f32]) -> (f32, f32) {
        debug_assert!(wa.len() == hi - lo && wb.len() == hi - lo);
        dot8_pair(&self.row(r)[lo..hi], wa, wb)
    }

    /// Batched `out[k] = x_{rows[k]}[lo..hi] · w`: two rows per pass
    /// share one streaming read of `w`. Bit-for-bit equal to calling
    /// [`Self::row_dot_range`] once per row.
    pub fn rows_dot_range_into(&self, rows: &[u32], lo: usize, hi: usize, w: &[f32], out: &mut [f32]) {
        debug_assert_eq!(out.len(), rows.len());
        debug_assert_eq!(w.len(), hi - lo);
        let mut pairs = rows.chunks_exact(2);
        let mut outs = out.chunks_exact_mut(2);
        for (pr, o) in (&mut pairs).zip(&mut outs) {
            let (z0, z1) =
                dot8_rows2(&self.row(pr[0] as usize)[lo..hi], &self.row(pr[1] as usize)[lo..hi], w);
            o[0] = z0;
            o[1] = z1;
        }
        if let ([r], [o]) = (pairs.remainder(), outs.into_remainder()) {
            *o = dot8(&self.row(*r as usize)[lo..hi], w);
        }
    }

    /// Batched `out += Σ_k u[k] · x_{rows[k]}[lo..hi]`, four active rows
    /// per pass over `out`. Rows with `u[k] == 0` are skipped and the
    /// per-element adds stay in row order, so the result is bit-for-bit
    /// the sequential per-row [`Self::add_row_scaled_range`] loop while
    /// touching `out` a quarter as often.
    pub fn add_rows_scaled_range(&self, rows: &[u32], u: &[f32], lo: usize, hi: usize, out: &mut [f32]) {
        debug_assert_eq!(rows.len(), u.len());
        debug_assert_eq!(out.len(), hi - lo);
        let mut ridx = [0usize; 4];
        let mut scale = [0.0f32; 4];
        let mut fill = 0;
        for (&r, &uk) in rows.iter().zip(u) {
            if uk == 0.0 {
                continue; // hinge gradients are frequently exactly zero
            }
            ridx[fill] = r as usize;
            scale[fill] = uk;
            fill += 1;
            if fill == 4 {
                self.axpy4(ridx, scale, lo, hi, out);
                fill = 0;
            }
        }
        for (&ri, &sk) in ridx.iter().zip(&scale).take(fill) {
            self.add_row_scaled_range(ri, lo, hi, sk, out);
        }
    }

    /// `out += Σ s[i]·x_{r[i]}[lo..hi]` for four rows, element adds kept
    /// in row order (bit parity with the sequential per-row loop).
    fn axpy4(&self, r: [usize; 4], s: [f32; 4], lo: usize, hi: usize, out: &mut [f32]) {
        let r0 = &self.row(r[0])[lo..hi];
        let r1 = &self.row(r[1])[lo..hi];
        let r2 = &self.row(r[2])[lo..hi];
        let r3 = &self.row(r[3])[lo..hi];
        for ((((o, &a), &b), &c), &d) in out.iter_mut().zip(r0).zip(r1).zip(r2).zip(r3) {
            let mut t = *o;
            t += s[0] * a;
            t += s[1] * b;
            t += s[2] * c;
            t += s[3] * d;
            *o = t;
        }
    }

    /// `out += scale · x_r[lo..hi]` where `out.len() == hi - lo`.
    #[inline]
    pub fn add_row_scaled_range(&self, r: usize, lo: usize, hi: usize, scale: f32, out: &mut [f32]) {
        debug_assert_eq!(out.len(), hi - lo);
        if scale == 0.0 {
            return; // hinge gradients are frequently exactly zero
        }
        let row = &self.row(r)[lo..hi];
        for (o, &v) in out.iter_mut().zip(row) {
            *o += scale * v;
        }
    }

    /// Gather-dot `Σ_k x_r[idx[k]] · w[k]` over a sorted column-subset
    /// list (`idx` holds block-local column ids, `w` is compact —
    /// `w.len() == idx.len()`). Same accumulator structure as [`dot8`]:
    /// 8 lanes filled in subset order, pairwise horizontal reduction,
    /// sequential remainder — so the sum order depends only on the
    /// subset, never on how the caller batches rows.
    #[inline]
    pub fn row_dot_cols(&self, r: usize, idx: &[u32], w: &[f32]) -> f32 {
        debug_assert_eq!(w.len(), idx.len());
        let row = self.row(r);
        let mut acc = [0.0f32; 8];
        let mut ci = idx.chunks_exact(8);
        let mut cw = w.chunks_exact(8);
        for (is, ws) in (&mut ci).zip(&mut cw) {
            for (acc_k, (&i, &wv)) in acc.iter_mut().zip(is.iter().zip(ws)) {
                *acc_k += row[i as usize] * wv;
            }
        }
        let mut s = hsum8(&acc);
        for (&i, &wv) in ci.remainder().iter().zip(cw.remainder()) {
            s += row[i as usize] * wv;
        }
        s
    }

    /// Batched `out[k] = x_{rows[k]}[idx] · w` over a column subset —
    /// the dense sampled-width phase-1 kernel (see
    /// [`crate::engine::kernels::partial_z_cols_into`]).
    pub fn rows_dot_cols_into(&self, rows: &[u32], idx: &[u32], w: &[f32], out: &mut [f32]) {
        debug_assert_eq!(out.len(), rows.len());
        for (o, &r) in out.iter_mut().zip(rows) {
            *o = self.row_dot_cols(r as usize, idx, w);
        }
    }

    /// Scatter-free compact axpy over a column subset:
    /// `out[k] += scale · x_r[idx[k]]` (`out.len() == idx.len()`).
    #[inline]
    pub fn add_row_scaled_cols(&self, r: usize, idx: &[u32], scale: f32, out: &mut [f32]) {
        debug_assert_eq!(out.len(), idx.len());
        if scale == 0.0 {
            return; // hinge gradients are frequently exactly zero
        }
        let row = self.row(r);
        for (o, &i) in out.iter_mut().zip(idx) {
            *o += scale * row[i as usize];
        }
    }

    /// Batched `out[k] += Σ_j u[j] · x_{rows[j]}[idx[k]]` — the compact
    /// gradient slice of the sampled-width phase 2. Zero-`u` rows are
    /// skipped and per-element adds stay in row order, like
    /// [`Self::add_rows_scaled_range`].
    pub fn add_rows_scaled_cols(&self, rows: &[u32], u: &[f32], idx: &[u32], out: &mut [f32]) {
        debug_assert_eq!(rows.len(), u.len());
        for (&r, &uk) in rows.iter().zip(u) {
            self.add_row_scaled_cols(r as usize, idx, uk, out);
        }
    }

    /// Copy a column range of a row into `out` (XLA buffer staging).
    pub fn copy_row_range(&self, r: usize, lo: usize, hi: usize, out: &mut [f32]) {
        out.copy_from_slice(&self.row(r)[lo..hi]);
    }

    /// Slice a sub-matrix by column range (partitioning path, not hot).
    pub fn slice_cols(&self, lo: usize, hi: usize) -> DenseMatrix {
        let cols = hi - lo;
        let mut data = Vec::with_capacity(self.rows * cols);
        for r in 0..self.rows {
            data.extend_from_slice(&self.row(r)[lo..hi]);
        }
        DenseMatrix { rows: self.rows, cols, data }
    }

    /// Slice a sub-matrix by row range.
    pub fn slice_rows(&self, lo: usize, hi: usize) -> DenseMatrix {
        DenseMatrix {
            rows: hi - lo,
            cols: self.cols,
            data: self.data[lo * self.cols..hi * self.cols].to_vec(),
        }
    }

    pub fn nnz(&self) -> usize {
        self.data.iter().filter(|v| **v != 0.0).count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::assert_close;

    fn sample() -> DenseMatrix {
        DenseMatrix::from_rows(3, 4, (0..12).map(|v| v as f32).collect())
    }

    #[test]
    fn row_access() {
        let m = sample();
        assert_eq!(m.row(1), &[4.0, 5.0, 6.0, 7.0]);
    }

    #[test]
    fn row_dot_range_matches_naive() {
        let m = sample();
        let w = [2.0, -1.0, 0.5];
        let got = m.row_dot_range(2, 1, 4, &w);
        let naive: f32 = m.row(2)[1..4].iter().zip(&w).map(|(a, b)| a * b).sum();
        assert_close!(got, naive);
    }

    #[test]
    fn row_dot_unroll_edge_cases() {
        // widths around the 8-way unroll boundary (0, 1, 7, 8, 9, 15, 16, 17)
        for cols in 1..=17 {
            let m = DenseMatrix::from_rows(1, cols, (0..cols).map(|v| v as f32 + 1.0).collect());
            let w: Vec<f32> = (0..cols).map(|v| 0.5 - v as f32).collect();
            let naive: f32 = m.row(0).iter().zip(&w).map(|(a, b)| a * b).sum();
            assert_close!(m.row_dot_range(0, 0, cols, &w), naive, 1e-4, 1e-5);
        }
    }

    #[test]
    fn dual_dots_match_single_dots_exactly() {
        let m = DenseMatrix::from_rows(2, 11, (0..22).map(|v| (v as f32 * 0.7).sin()).collect());
        let wa: Vec<f32> = (0..9).map(|v| 0.3 - v as f32 * 0.11).collect();
        let wb: Vec<f32> = (0..9).map(|v| (v as f32).cos()).collect();
        let (za, zb) = m.row_dot2_range(1, 1, 10, &wa, &wb);
        assert_eq!(za, m.row_dot_range(1, 1, 10, &wa));
        assert_eq!(zb, m.row_dot_range(1, 1, 10, &wb));
        let (z0, z1) = dot8_rows2(&m.row(0)[1..10], &m.row(1)[1..10], &wa);
        assert_eq!(z0, m.row_dot_range(0, 1, 10, &wa));
        assert_eq!(z1, m.row_dot_range(1, 1, 10, &wa));
    }

    #[test]
    fn batched_rows_dot_matches_per_row_exactly() {
        let m = DenseMatrix::from_rows(7, 13, (0..91).map(|v| (v as f32 * 0.3).cos()).collect());
        let w: Vec<f32> = (0..10).map(|v| 0.2 * v as f32 - 0.9).collect();
        for rows in [vec![], vec![4u32], vec![0, 2, 5], vec![6, 1, 3, 3, 0]] {
            let mut out = vec![0.0f32; rows.len()];
            m.rows_dot_range_into(&rows, 2, 12, &w, &mut out);
            let want: Vec<f32> =
                rows.iter().map(|&r| m.row_dot_range(r as usize, 2, 12, &w)).collect();
            assert_eq!(out, want);
        }
    }

    #[test]
    fn batched_axpy_matches_per_row_exactly() {
        let m = DenseMatrix::from_rows(9, 6, (0..54).map(|v| (v as f32 * 0.9).sin()).collect());
        let rows: Vec<u32> = (0..9).collect();
        // exact zeros mixed in to exercise the skip path
        let u: Vec<f32> = (0..9).map(|v| if v % 3 == 0 { 0.0 } else { v as f32 * 0.1 - 0.4 }).collect();
        let mut got = vec![0.1f32; 4];
        m.add_rows_scaled_range(&rows, &u, 1, 5, &mut got);
        let mut want = vec![0.1f32; 4];
        for (&r, &uk) in rows.iter().zip(&u) {
            m.add_row_scaled_range(r as usize, 1, 5, uk, &mut want);
        }
        assert_eq!(got, want);
    }

    #[test]
    fn gather_dot_matches_masked_full_width() {
        // subset dot == full-width dot against w zeroed outside the
        // subset, up to accumulation-order rounding
        for cols in [1usize, 7, 8, 9, 16, 23] {
            let data: Vec<f32> = (0..2 * cols).map(|v| (v as f32 * 0.31).sin()).collect();
            let m = DenseMatrix::from_rows(2, cols, data);
            let idx: Vec<u32> = (0..cols as u32).step_by(2).collect();
            let w: Vec<f32> = (0..idx.len()).map(|v| 0.4 - v as f32 * 0.13).collect();
            let mut w_full = vec![0.0f32; cols];
            for (k, &i) in idx.iter().enumerate() {
                w_full[i as usize] = w[k];
            }
            for r in 0..2 {
                let got = m.row_dot_cols(r, &idx, &w);
                let want = m.row_dot_range(r, 0, cols, &w_full);
                assert_close!(got, want, 1e-5, 1e-6);
            }
        }
    }

    #[test]
    fn gather_dot_full_and_empty_subsets() {
        let m = DenseMatrix::from_rows(1, 11, (0..11).map(|v| v as f32 - 4.0).collect());
        let all: Vec<u32> = (0..11).collect();
        let w: Vec<f32> = (0..11).map(|v| (v as f32 * 0.7).cos()).collect();
        // contiguous full subset shares dot8's chunking exactly
        assert_eq!(m.row_dot_cols(0, &all, &w), m.row_dot_range(0, 0, 11, &w));
        assert_eq!(m.row_dot_cols(0, &[], &[]), 0.0);
    }

    #[test]
    fn batched_gather_accessors_match_per_row() {
        let m = DenseMatrix::from_rows(6, 10, (0..60).map(|v| (v as f32 * 0.9).sin()).collect());
        let idx: Vec<u32> = vec![0, 3, 4, 8, 9];
        let w: Vec<f32> = (0..5).map(|v| 0.2 * v as f32 - 0.5).collect();
        let rows: Vec<u32> = vec![5, 0, 2, 2];
        let mut out = vec![7.0f32; 4];
        m.rows_dot_cols_into(&rows, &idx, &w, &mut out);
        let want: Vec<f32> = rows.iter().map(|&r| m.row_dot_cols(r as usize, &idx, &w)).collect();
        assert_eq!(out, want);

        let u = [0.5f32, 0.0, -1.0, 2.0];
        let mut got = vec![0.25f32; 5];
        m.add_rows_scaled_cols(&rows, &u, &idx, &mut got);
        let mut want = vec![0.25f32; 5];
        for (&r, &uk) in rows.iter().zip(&u) {
            m.add_row_scaled_cols(r as usize, &idx, uk, &mut want);
        }
        assert_eq!(got, want);
        // compact axpy against the masked-range reference
        let mut full = vec![0.0f32; 10];
        for (&r, &uk) in rows.iter().zip(&u) {
            m.add_row_scaled_range(r as usize, 0, 10, uk, &mut full);
        }
        let mut compact = vec![0.0f32; 5];
        m.add_rows_scaled_cols(&rows, &u, &idx, &mut compact);
        for (k, &i) in idx.iter().enumerate() {
            assert_close!(compact[k], full[i as usize], 1e-5, 1e-6);
        }
    }

    #[test]
    fn add_row_scaled() {
        let m = sample();
        let mut out = vec![1.0; 2];
        m.add_row_scaled_range(0, 1, 3, 2.0, &mut out);
        assert_eq!(out, vec![1.0 + 2.0 * 1.0, 1.0 + 2.0 * 2.0]);
    }

    #[test]
    fn slices() {
        let m = sample();
        let c = m.slice_cols(1, 3);
        assert_eq!(c.rows, 3);
        assert_eq!(c.cols, 2);
        assert_eq!(c.row(2), &[9.0, 10.0]);
        let r = m.slice_rows(1, 3);
        assert_eq!(r.rows, 2);
        assert_eq!(r.row(0), m.row(1));
    }

    #[test]
    fn nnz_counts_nonzeros() {
        let m = DenseMatrix::from_rows(1, 4, vec![0.0, 1.0, 0.0, 2.0]);
        assert_eq!(m.nnz(), 2);
    }
}
