//! Doubly distributed data substrate.
//!
//! The paper assumes the `N × M` design matrix is stored as `P × Q`
//! partitions `x^{p,q}` (observation partition p, feature partition q),
//! each of which is further column-split into `P` sub-blocks
//! `x^{p,q,k}` (Figure 1; width `m̃ = M/QP` in the paper's evenly
//! divisible setting, balanced ragged widths otherwise). This module
//! provides:
//!
//! * [`dense::DenseMatrix`] / [`sparse::CsrMatrix`] storage,
//! * [`Store`] — the runtime-polymorphic block (both §5.1 dense and
//!   §5.2 sparse experiments run through the same coordinator),
//! * [`synth`] — the paper's synthetic generators,
//! * [`partition`] — the P×Q(×P) partitioner and [`partition::Grid`].

pub mod dense;
pub mod io;
pub mod partition;
pub mod sparse;
pub mod synth;

pub use dense::DenseMatrix;
pub use partition::{Block, Grid, Layout};
pub use sparse::CsrMatrix;

/// A data block in either storage format. All coordinator/engine code is
/// written against this enum so dense and sparse datasets share one path.
///
/// The per-row ops below dispatch through the enum **per call**; hot
/// loops should go through [`crate::engine::kernels`], which resolves
/// the format once per batch and then runs the monomorphized
/// dense/CSR accessors ([`DenseMatrix::rows_dot_range_into`] and
/// friends) with no per-row dispatch.
#[derive(Debug, Clone)]
pub enum Store {
    Dense(DenseMatrix),
    Sparse(CsrMatrix),
}

impl Store {
    pub fn rows(&self) -> usize {
        match self {
            Store::Dense(m) => m.rows,
            Store::Sparse(m) => m.rows,
        }
    }

    pub fn cols(&self) -> usize {
        match self {
            Store::Dense(m) => m.cols,
            Store::Sparse(m) => m.cols,
        }
    }

    pub fn nnz(&self) -> usize {
        match self {
            Store::Dense(m) => m.nnz(),
            Store::Sparse(m) => m.nnz(),
        }
    }

    pub fn is_sparse(&self) -> bool {
        matches!(self, Store::Sparse(_))
    }

    /// Per-row work proxy for cost-balanced sharding
    /// ([`Layout::weighted_by_cost`]): the per-row nnz for sparse
    /// stores, `None` for dense ones (every row costs the same, so
    /// count-proportional splitting is already exact — and stays
    /// bit-identical to the historical layouts).
    pub fn row_costs(&self) -> Option<Vec<f64>> {
        match self {
            Store::Dense(_) => None,
            Store::Sparse(m) => Some((0..m.rows).map(|r| m.row_nnz(r) as f64).collect()),
        }
    }

    /// `x_r[lo..hi] · w` (w local to the range).
    #[inline]
    pub fn row_dot_range(&self, r: usize, lo: usize, hi: usize, w: &[f32]) -> f32 {
        match self {
            Store::Dense(m) => m.row_dot_range(r, lo, hi, w),
            Store::Sparse(m) => m.row_dot_range(r, lo, hi, w),
        }
    }

    /// `out += scale · x_r[lo..hi]`.
    #[inline]
    pub fn add_row_scaled_range(&self, r: usize, lo: usize, hi: usize, scale: f32, out: &mut [f32]) {
        match self {
            Store::Dense(m) => m.add_row_scaled_range(r, lo, hi, scale, out),
            Store::Sparse(m) => m.add_row_scaled_range(r, lo, hi, scale, out),
        }
    }

    /// Densify `x_r[lo..hi]` into `out` (XLA staging).
    pub fn copy_row_range(&self, r: usize, lo: usize, hi: usize, out: &mut [f32]) {
        match self {
            Store::Dense(m) => m.copy_row_range(r, lo, hi, out),
            Store::Sparse(m) => m.copy_row_range(r, lo, hi, out),
        }
    }

    pub fn slice_cols(&self, lo: usize, hi: usize) -> Store {
        match self {
            Store::Dense(m) => Store::Dense(m.slice_cols(lo, hi)),
            Store::Sparse(m) => Store::Sparse(m.slice_cols(lo, hi)),
        }
    }

    pub fn slice_rows(&self, lo: usize, hi: usize) -> Store {
        match self {
            Store::Dense(m) => Store::Dense(m.slice_rows(lo, hi)),
            Store::Sparse(m) => Store::Sparse(m.slice_rows(lo, hi)),
        }
    }

    /// Bytes this block would occupy on the wire / on disk (the SimNet
    /// cost model charges data shuffles with this).
    pub fn approx_bytes(&self) -> usize {
        match self {
            Store::Dense(m) => m.data.len() * 4,
            Store::Sparse(m) => m.values.len() * 8 + m.indptr.len() * 4,
        }
    }
}

/// A labeled dataset before partitioning: global `N × M` matrix + labels.
#[derive(Debug, Clone)]
pub struct Dataset {
    pub x: Store,
    pub y: Vec<f32>,
    /// Human-readable provenance ("synthetic-small", "diag-neg10", …).
    pub name: String,
}

impl Dataset {
    pub fn n(&self) -> usize {
        self.x.rows()
    }

    pub fn m(&self) -> usize {
        self.x.cols()
    }

    /// Full objective `F(w) = (1/N) Σ f(x_i·w, y_i)` evaluated serially —
    /// the reporting oracle used by tests (the cluster evaluates it in a
    /// distributed reduce; both must agree).
    pub fn objective(&self, w: &[f32], loss: crate::loss::Loss) -> f64 {
        let m = self.m();
        let mut total = 0.0f64;
        for r in 0..self.n() {
            let z = self.x.row_dot_range(r, 0, m, w);
            total += loss.value(z, self.y[r]) as f64;
        }
        total / self.n() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dense_store() -> Store {
        Store::Dense(DenseMatrix::from_rows(2, 3, vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]))
    }

    fn sparse_store() -> Store {
        Store::Sparse(CsrMatrix::from_row_entries(
            2,
            3,
            vec![vec![(0, 1.0), (1, 2.0), (2, 3.0)], vec![(0, 4.0), (1, 5.0), (2, 6.0)]],
        ))
    }

    #[test]
    fn dense_and_sparse_agree_on_every_op() {
        let (d, s) = (dense_store(), sparse_store());
        let w = [0.5, -1.0, 2.0];
        for r in 0..2 {
            assert_eq!(d.row_dot_range(r, 0, 3, &w), s.row_dot_range(r, 0, 3, &w));
            assert_eq!(d.row_dot_range(r, 1, 3, &w[1..]), s.row_dot_range(r, 1, 3, &w[1..]));
            let mut od = vec![0.0; 2];
            let mut os = vec![0.0; 2];
            d.add_row_scaled_range(r, 0, 2, 1.5, &mut od);
            s.add_row_scaled_range(r, 0, 2, 1.5, &mut os);
            assert_eq!(od, os);
            let mut cd = vec![0.0; 3];
            let mut cs = vec![0.0; 3];
            d.copy_row_range(r, 0, 3, &mut cd);
            s.copy_row_range(r, 0, 3, &mut cs);
            assert_eq!(cd, cs);
        }
    }

    #[test]
    fn objective_is_mean_loss() {
        let ds = Dataset { x: dense_store(), y: vec![1.0, -1.0], name: "t".into() };
        let w = [0.0, 0.0, 0.0];
        // hinge at z=0: 1 for each row
        crate::assert_close!(ds.objective(&w, crate::loss::Loss::Hinge), 1.0);
    }
}
