//! Steady-state allocation regression harness.
//!
//! Installs the counting global allocator and drives warm `Trainer`
//! sessions to pin two properties of the pooled-buffer subsystem:
//!
//! 1. **budget** — after warm-up, one outer iteration (objective eval
//!    included) performs at most [`alloc_budget`] allocation events,
//!    on dense and sparse data, even and ragged grids, and the fused
//!    `Q == 1` path. The budget is executor-aware (the CI threaded lane
//!    runs this suite under `SODDA_EXECUTOR=threaded`): the in-process
//!    oracle expects single digits, the threaded transport adds mpsc
//!    channel-block churn that amortizes to a few more events per
//!    iteration. Both budgets leave headroom for channel-block
//!    lumpiness and rare capacity growth without letting any per-phase
//!    O(P·Q) allocation pattern back in (that costs hundreds per
//!    iteration);
//! 2. **bit-for-bit** — pooling changes no numbers: stepping a session
//!    with every pooled buffer dropped between steps (the cold,
//!    fresh-allocation path via `Trainer::drop_scratch`) produces the
//!    identical `History` and final iterate across random shapes,
//!    algorithms and storage formats — and allocates ≥ 10× more,
//!    which is the measured win recorded in BENCH_4.json.
//!
//! The counter is process-global, so every test here serializes on one
//! mutex — a concurrently running sibling test would otherwise bleed
//! its allocations into the measurement window.

use std::sync::Mutex;

use sodda::config::{AlgorithmKind, ExecutorKind};
use sodda::util::alloc::CountingAlloc;
use sodda::util::testing::forall;
use sodda::{ExperimentConfig, ExperimentConfigBuilder, Trainer};

#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc::new();

static SERIAL: Mutex<()> = Mutex::new(());

/// Absolute per-outer-iteration allocation budget after warm-up. The
/// fresh path costs a couple hundred events per iteration on these
/// shapes; the pooled in-process steady state measures single digits,
/// and the threaded transport's mpsc channels add bounded block churn
/// on top (PR 4's original 48 budget). Resolved per-lane so the CI
/// threaded lane gates its own documented budget.
fn alloc_budget() -> f64 {
    match ExecutorKind::resolve(None).expect("SODDA_EXECUTOR") {
        ExecutorKind::InProcess => 32.0,
        ExecutorKind::Threaded => 48.0,
    }
}

fn lock() -> std::sync::MutexGuard<'static, ()> {
    SERIAL.lock().unwrap_or_else(|e| e.into_inner())
}

fn base(n: usize, m: usize, p: usize, q: usize, iters: usize) -> ExperimentConfigBuilder {
    ExperimentConfig::builder()
        .name("alloc-regression")
        .dense(n, m)
        .grid(p, q)
        .inner_steps(8)
        .outer_iters(iters)
        .eval_every(1)
        .seed(5)
}

/// Average allocation events per `step()` over `iters` iterations,
/// after `warmup` unmeasured steps. `fresh` drops every pooled buffer
/// before each measured step, forcing the cold path.
fn measure(trainer: &mut Trainer, warmup: usize, iters: usize, fresh: bool) -> f64 {
    for _ in 0..warmup {
        trainer.step().unwrap();
    }
    let before = ALLOC.allocations();
    for _ in 0..iters {
        if fresh {
            trainer.drop_scratch();
        }
        trainer.step().unwrap();
    }
    (ALLOC.allocations() - before) as f64 / iters as f64
}

fn assert_budget(cfg: ExperimentConfig, label: &str) {
    let budget = alloc_budget();
    let mut t = Trainer::new(cfg).unwrap();
    let per_iter = measure(&mut t, 4, 24, false);
    assert!(
        per_iter <= budget,
        "{label}: {per_iter:.1} allocs per steady-state iteration exceeds the budget {budget}"
    );
}

#[test]
fn steady_state_budget_dense_even() {
    let _g = lock();
    assert_budget(base(240, 48, 3, 2, 40).build().unwrap(), "dense 240x48 on 3x2");
}

#[test]
fn steady_state_budget_dense_ragged() {
    let _g = lock();
    assert_budget(base(241, 49, 3, 2, 40).build().unwrap(), "dense 241x49 on 3x2 (ragged)");
}

#[test]
fn steady_state_budget_sparse_even() {
    let _g = lock();
    let cfg = base(240, 48, 3, 2, 40).sparse(240, 48, 8).build().unwrap();
    assert_budget(cfg, "sparse 240x48 on 3x2");
}

#[test]
fn steady_state_budget_sparse_ragged() {
    let _g = lock();
    let cfg = base(241, 49, 3, 2, 40).sparse(241, 49, 8).build().unwrap();
    assert_budget(cfg, "sparse 241x49 on 3x2 (ragged)");
}

#[test]
fn steady_state_budget_sampled_low_fraction() {
    // the sampled-width path (compact per-block id lists + w slices)
    // must stay inside the same pooled budget as the full-width path
    let _g = lock();
    let cfg = base(240, 48, 3, 2, 40).fractions_bcd(0.1, 0.05, 0.5).build().unwrap();
    assert_budget(cfg, "sodda b=0.1 c=0.05 dense 240x48 on 3x2");
    let cfg = base(241, 49, 3, 2, 40)
        .sparse(241, 49, 8)
        .fractions_bcd(0.1, 0.05, 0.5)
        .build()
        .unwrap();
    assert_budget(cfg, "sodda b=0.1 c=0.05 sparse 241x49 on 3x2 (ragged)");
}

#[test]
fn steady_state_budget_fused_q1_path() {
    let _g = lock();
    assert_budget(base(240, 24, 4, 1, 40).build().unwrap(), "dense 240x24 on 4x1 (fused)");
}

#[test]
fn steady_state_budget_radisa_avg() {
    let _g = lock();
    let cfg = base(240, 48, 3, 2, 40).algorithm(AlgorithmKind::RadisaAvg).build().unwrap();
    assert_budget(cfg, "radisa-avg 240x48 on 3x2");
}

#[test]
fn pooled_allocates_at_least_10x_less_than_fresh() {
    let _g = lock();
    for (cfg, label) in [
        (base(300, 60, 5, 3, 40).build().unwrap(), "dense 300x60 on 5x3"),
        (base(301, 61, 5, 3, 40).sparse(301, 61, 8).build().unwrap(), "sparse 301x61 on 5x3"),
    ] {
        let mut pooled = Trainer::new(cfg.clone()).unwrap();
        let pooled_per_iter = measure(&mut pooled, 4, 24, false);
        let mut fresh = Trainer::new(cfg).unwrap();
        let fresh_per_iter = measure(&mut fresh, 4, 24, true);
        assert!(
            fresh_per_iter >= 10.0 * pooled_per_iter,
            "{label}: fresh path {fresh_per_iter:.1} allocs/iter is less than 10x the pooled \
             {pooled_per_iter:.1} — either pooling regressed or the cold path got pooled"
        );
        // the two trainers ran the same config — trajectories must agree
        assert_eq!(pooled.weights(), fresh.weights(), "{label}: pooling changed the iterate");
    }
}

#[test]
fn pooled_and_fresh_histories_are_bit_identical_across_shapes() {
    let _g = lock();
    // property test: random shapes/grids/algorithms/formats, pooled run
    // vs drop-scratch-every-step run — History and final w must match
    // bit-for-bit (pooling recycles allocations, never changes numbers)
    forall(6, 4242, |rng| {
        let p = 1 + rng.below(3);
        let q = 1 + rng.below(3);
        let n = p * (4 + rng.below(40)) + rng.below(p);
        let m = (p * q) * (2 + rng.below(6)) + rng.below(3);
        let algo = match rng.below(3) {
            0 => AlgorithmKind::Sodda,
            1 => AlgorithmKind::Radisa,
            _ => AlgorithmKind::RadisaAvg,
        };
        let mut b = base(n, m, p, q, 3).algorithm(algo).seed(rng.below(1000) as u64);
        if rng.bool_with(0.5) {
            b = b.sparse(n, m, 4);
        }
        let cfg = b.build().unwrap();
        let mut warm = Trainer::new(cfg.clone()).unwrap();
        let a = warm.run().unwrap();
        let mut cold = Trainer::new(cfg).unwrap();
        while !cold.is_done() {
            cold.drop_scratch();
            cold.step().unwrap();
        }
        let o = cold.outcome();
        assert_eq!(a.w, o.w, "{n}x{m} on {p}x{q} {algo:?}");
        assert_eq!(a.history.losses(), o.history.losses(), "{n}x{m} on {p}x{q} {algo:?}");
    });
}
