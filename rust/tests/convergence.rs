//! Convergence behaviour the paper's theorems and experiments predict,
//! checked end-to-end on laptop-scale instances (native engine).

use std::sync::Arc;

use sodda::config::{AlgorithmKind, DataConfig, ExperimentConfig, Schedule};
use sodda::coordinator::{train, train_with_engine};
use sodda::data::Store;
use sodda::engine::NativeEngine;
use sodda::loss::Loss;

fn cfg(name: &str) -> ExperimentConfig {
    ExperimentConfig::builder()
        .name(name)
        .dense(600, 90)
        .grid(3, 3)
        .inner_steps(24)
        .outer_iters(40)
        .schedule(Schedule::ScaledSqrt { gamma0: 0.25 })
        .seed(5)
        .build()
        .unwrap()
}

#[test]
fn sodda_approaches_separable_optimum() {
    // Zhang-style data is ~separable (1% flips): hinge loss must get small.
    let out = train(&cfg("sep")).unwrap();
    let f0 = out.history.losses()[0];
    let fend = out.history.final_loss().unwrap();
    assert!(fend < 0.3 * f0, "F(ω^T)={fend} vs F(0)={f0}");
}

#[test]
fn diminishing_rate_converges_monotonically_in_trend() {
    let c = cfg("dim").to_builder().schedule(Schedule::InvT { gamma0: 1.0 }).build().unwrap();
    let out = train(&c).unwrap();
    let l = out.history.losses();
    // trend check: mean of last 5 well below mean of first 5
    let head: f64 = l[..5].iter().sum::<f64>() / 5.0;
    let tail: f64 = l[l.len() - 5..].iter().sum::<f64>() / 5.0;
    assert!(tail < 0.6 * head, "head {head} tail {tail}");
}

#[test]
fn constant_rate_within_theorem3_bound_decreases() {
    let base = cfg("const");
    // γ < 1/(L·M3·Q·P) with M3 ≈ 1 (standardized features)
    let gamma = Schedule::max_constant_gamma(base.inner_steps, base.p, base.q) * 0.5;
    let c = base.to_builder().schedule(Schedule::Constant { gamma }).build().unwrap();
    let out = train(&c).unwrap();
    assert!(out.history.final_loss().unwrap() < out.history.losses()[0]);
}

#[test]
fn squared_loss_approaches_least_squares_optimum() {
    let c = cfg("sq")
        .to_builder()
        .loss(Loss::Squared)
        .schedule(Schedule::Constant { gamma: 0.02 })
        .outer_iters(60)
        .build()
        .unwrap();
    let ds = c.data.try_materialize(c.seed).unwrap();
    let out = train_with_engine(&c, &ds, Arc::new(NativeEngine)).unwrap();

    // exact optimum via normal equations (ridge ε for conditioning)
    let (n, m) = (ds.n(), ds.m());
    let Store::Dense(x) = &ds.x else { unreachable!() };
    let mut xtx = vec![0.0f64; m * m];
    let mut xty = vec![0.0f64; m];
    for r in 0..n {
        let row = x.row(r);
        for i in 0..m {
            xty[i] += row[i] as f64 * ds.y[r] as f64;
            for j in i..m {
                xtx[i * m + j] += row[i] as f64 * row[j] as f64;
            }
        }
    }
    for i in 0..m {
        for j in 0..i {
            xtx[i * m + j] = xtx[j * m + i];
        }
        xtx[i * m + i] += 1e-6;
    }
    // gaussian elimination
    let mut a = xtx;
    let mut b = xty;
    for col in 0..m {
        let piv = (col..m).max_by(|&i, &j| a[i * m + col].abs().partial_cmp(&a[j * m + col].abs()).unwrap()).unwrap();
        a.swap(col * m + col, piv * m + col);
        if piv != col {
            for k in 0..m {
                a.swap(col * m + k, piv * m + k);
            }
            b.swap(col, piv);
        }
        let d = a[col * m + col];
        for i in col + 1..m {
            let f = a[i * m + col] / d;
            for k in col..m {
                a[i * m + k] -= f * a[col * m + k];
            }
            b[i] -= f * b[col];
        }
    }
    let mut wstar = vec![0.0f64; m];
    for i in (0..m).rev() {
        let mut s = b[i];
        for k in i + 1..m {
            s -= a[i * m + k] * wstar[k];
        }
        wstar[i] = s / a[i * m + i];
    }
    let wstar32: Vec<f32> = wstar.iter().map(|&v| v as f32).collect();
    let fstar = ds.objective(&wstar32, Loss::Squared);
    let fend = out.history.final_loss().unwrap();
    let f0 = out.history.losses()[0];
    // within 25% of the way-to-optimal gap closed... be generous but real:
    assert!(
        fend - fstar < 0.35 * (f0 - fstar),
        "F_end={fend}, F*={fstar}, F0={f0}"
    );
}

#[test]
fn sodda_beats_radisa_avg_early_in_sim_time() {
    // the paper's headline (Figures 2-4): SODDA reaches good solutions
    // faster in early iterations; RADiSA-avg catches up later.
    let base = cfg("h2h")
        .to_builder()
        .dense(2500, 180)
        .grid(5, 3)
        .inner_steps(32)
        .schedule(Schedule::ScaledSqrt { gamma0: 0.08 })
        .build()
        .unwrap();
    let ds = base.data.try_materialize(base.seed).unwrap();
    let sodda = train_with_engine(&base, &ds, Arc::new(NativeEngine)).unwrap();
    let cavg = base.to_builder().algorithm(AlgorithmKind::RadisaAvg).build().unwrap();
    let ravg = train_with_engine(&cavg, &ds, Arc::new(NativeEngine)).unwrap();

    // target: the loss RADiSA-avg reaches ~1/3 into its run; SODDA must
    // get there in less simulated time
    let third = ravg.history.records[ravg.history.records.len() / 3].loss;
    let t_sodda = sodda.history.time_to_loss(third);
    let t_ravg = ravg.history.time_to_loss(third);
    assert!(t_sodda.is_some(), "SODDA never reached RADiSA-avg's 1/3-run loss {third}");
    assert!(
        t_sodda.unwrap() < t_ravg.unwrap(),
        "SODDA {:?} should beat RADiSA-avg {:?} to loss {third}",
        t_sodda,
        t_ravg
    );
}

#[test]
fn logistic_trains_on_sparse_data() {
    let c = cfg("sparse-logistic")
        .to_builder()
        .data(DataConfig::Sparse { n: 600, m: 180, avg_nnz: 12 })
        .loss(Loss::Logistic)
        .build()
        .unwrap();
    let out = train(&c).unwrap();
    assert!(out.history.final_loss().unwrap() < out.history.losses()[0]);
}

#[test]
fn larger_d_gives_no_worse_final_loss_usually() {
    // Figure 2(a) trend: more observations in µ^t → better late accuracy.
    // Stochastic, so compare min losses with slack rather than strictly.
    let lo = cfg("d60").to_builder().fractions_bcd(1.0, 1.0, 0.6).build().unwrap();
    let hi = cfg("d90").to_builder().fractions_bcd(1.0, 1.0, 0.9).build().unwrap();
    let out_lo = train(&lo).unwrap();
    let out_hi = train(&hi).unwrap();
    assert!(
        out_hi.history.min_loss().unwrap() <= out_lo.history.min_loss().unwrap() * 1.5,
        "hi-d should not be much worse"
    );
}
