//! Sampled-width execution (README "Sampled-width execution").
//!
//! The column-subset kernels must match the masked full-width path they
//! replace to accumulation-order tolerance (the compacted dense dot
//! reorders the f32 sum; CSR visits the same surviving entries), across
//! random ragged shapes, both storage formats, and fractions from
//! |B| = 1 through |B| = M — including empty row sets and blocks the
//! subset misses entirely (C ⊄ block). End-to-end, the sampled SODDA
//! path must be run-to-run **and** pooled-vs-fresh deterministic, and
//! the SimNet bytes charged for phases 1/2 must equal the actual
//! compact buffer lengths put on the channel (cost-model honesty —
//! re-derived here from the config's set-draw RNG stream).

use sodda::config::{AlgorithmKind, ExperimentConfig, SamplingFractions};
use sodda::coordinator::sampling::SampleSets;
use sodda::data::{CsrMatrix, DenseMatrix, Store};
use sodda::engine::kernels;
use sodda::loss::Loss;
use sodda::util::rng::Rng;
use sodda::util::testing::{assert_close_slice, forall};
use sodda::Trainer;

fn dense(rng: &mut Rng, n: usize, m: usize) -> Store {
    let mut d = DenseMatrix::zeros(n, m);
    for v in d.data.iter_mut() {
        *v = rng.f32_range(-1.0, 1.0);
    }
    Store::Dense(d)
}

fn sparse(rng: &mut Rng, n: usize, m: usize) -> Store {
    let mut entries = Vec::with_capacity(n);
    for _ in 0..n {
        let nnz = rng.below(m + 1); // rows may be empty
        let cols = rng.sample_without_replacement(m, nnz);
        entries.push(cols.into_iter().map(|c| (c, rng.f32_range(-1.0, 1.0))).collect());
    }
    Store::Sparse(CsrMatrix::from_row_entries(n, m, entries))
}

struct Case {
    x: Store,
    y: Vec<f32>,
    m: usize,
    /// sorted block-local subset, |idx| swept from 1 through m (the
    /// C ⊄ block empty-intersection case is pinned at the cluster layer,
    /// where blocks exist)
    idx: Vec<u32>,
    /// compact parameter slice, `w.len() == idx.len()`
    w: Vec<f32>,
    /// full-width `w` scattered from the compact slice (the masked path)
    w_full: Vec<f32>,
    rows: Vec<u32>,
    u: Vec<f32>,
}

fn case(rng: &mut Rng, sparse_fmt: bool) -> Case {
    let n = 1 + rng.below(40);
    let m = 1 + rng.below(64);
    let x = if sparse_fmt { sparse(rng, n, m) } else { dense(rng, n, m) };
    let y: Vec<f32> = (0..n).map(|_| if rng.bool_with(0.5) { 1.0 } else { -1.0 }).collect();
    // subset size sweeps the full fraction range: 1, a few, most, all
    let ssz = match rng.below(4) {
        0 => 1,
        1 => m,
        _ => 1 + rng.below(m),
    };
    let idx = rng.sample_without_replacement(m, ssz);
    let w: Vec<f32> = (0..idx.len()).map(|_| rng.f32_range(-1.0, 1.0)).collect();
    let mut w_full = vec![0.0f32; m];
    for (&i, &wv) in idx.iter().zip(&w) {
        w_full[i as usize] = wv;
    }
    let k = rng.below(n + 1); // 0 => empty row set
    let rows = rng.sample_without_replacement(n, k);
    let u: Vec<f32> = (0..rows.len())
        .map(|i| if i % 3 == 0 { 0.0 } else { rng.f32_range(-1.0, 1.0) })
        .collect();
    Case { x, y, m, idx, w, w_full, rows, u }
}

#[test]
fn subset_partial_z_matches_masked_full_width() {
    for sparse_fmt in [false, true] {
        forall(150, 0x51 + sparse_fmt as u64, |rng| {
            let c = case(rng, sparse_fmt);
            let z = kernels::partial_z_cols(&c.x, &c.idx, &c.w, &c.rows);
            let want = kernels::partial_z(&c.x, 0..c.m, &c.w_full, &c.rows);
            assert_close_slice(&z, &want, 1e-4, 1e-5, &format!("sparse={sparse_fmt}"));
        });
    }
}

#[test]
fn subset_grad_matches_masked_full_width() {
    for sparse_fmt in [false, true] {
        forall(150, 0x61 + sparse_fmt as u64, |rng| {
            let c = case(rng, sparse_fmt);
            let g = kernels::grad_cols(&c.x, &c.idx, &c.rows, &c.u);
            assert_eq!(g.len(), c.idx.len(), "compact slice length");
            let full = kernels::grad_slice(&c.x, 0..c.m, &c.rows, &c.u);
            let want: Vec<f32> = c.idx.iter().map(|&i| full[i as usize]).collect();
            assert_close_slice(&g, &want, 1e-4, 1e-5, &format!("sparse={sparse_fmt}"));
        });
    }
}

#[test]
fn subset_partial_u_matches_masked_full_width() {
    for sparse_fmt in [false, true] {
        forall(100, 0x71 + sparse_fmt as u64, |rng| {
            let c = case(rng, sparse_fmt);
            for loss in Loss::ALL {
                let got = kernels::partial_u_cols(loss, &c.x, &c.idx, &c.w, &c.rows, &c.y);
                let want = kernels::partial_u(loss, &c.x, 0..c.m, &c.w_full, &c.rows, &c.y);
                assert_eq!(got.len(), want.len(), "sparse={sparse_fmt} {loss}");
                if loss != Loss::Hinge {
                    // smooth losses: dloss is Lipschitz in the margin, so
                    // the subset-vs-masked rounding stays within tolerance
                    let ctx = format!("sparse={sparse_fmt} {loss}");
                    assert_close_slice(&got, &want, 1e-3, 1e-4, &ctx);
                }
                // hinge's dloss jumps at the kink, so a reordered margin
                // sum can legitimately flip it — pin the fused subset path
                // against its own composition exactly instead
                let z = kernels::partial_z_cols(&c.x, &c.idx, &c.w, &c.rows);
                let want_u: Vec<f32> = z
                    .iter()
                    .zip(&c.rows)
                    .map(|(&zk, &r)| loss.dloss(zk, c.y[r as usize]))
                    .collect();
                assert_eq!(got, want_u, "sparse={sparse_fmt} {loss}: fused != composed");
            }
        });
    }
}

#[test]
fn subset_of_every_column_is_exact_on_csr() {
    // |B| = M on CSR visits exactly the stored entries in order — the
    // intersection walk must then be bit-for-bit the range dot
    forall(50, 0x81, |rng| {
        let n = 1 + rng.below(20);
        let m = 1 + rng.below(40);
        let Store::Sparse(x) = sparse(rng, n, m) else { unreachable!() };
        let idx: Vec<u32> = (0..m as u32).collect();
        let w: Vec<f32> = (0..m).map(|_| rng.f32_range(-1.0, 1.0)).collect();
        for r in 0..n {
            assert_eq!(x.row_dot_cols(r, &idx, &w), x.row_dot_range(r, 0, m, &w));
        }
    });
}

// ---------------------------------------------------------------------------
// end-to-end: determinism + frozen full path + cost honesty
// ---------------------------------------------------------------------------

fn low_fraction_cfg(n: usize, m: usize, p: usize, q: usize, seed: u64) -> ExperimentConfig {
    ExperimentConfig::builder()
        .name("sampled-e2e")
        .dense(n, m)
        .grid(p, q)
        .fractions_bcd(0.25, 0.10, 0.5)
        .inner_steps(6)
        .outer_iters(5)
        .seed(seed)
        .build()
        .unwrap()
}

#[test]
fn sampled_path_is_run_to_run_deterministic() {
    for (n, m, p, q) in [(120usize, 24usize, 3usize, 2usize), (121, 25, 3, 2), (90, 18, 3, 1)] {
        let cfg = low_fraction_cfg(n, m, p, q, 7);
        let a = Trainer::new(cfg.clone()).unwrap().run().unwrap();
        let b = Trainer::new(cfg).unwrap().run().unwrap();
        assert_eq!(a.w, b.w, "{n}x{m} on {p}x{q}");
        assert_eq!(a.history.losses(), b.history.losses(), "{n}x{m} on {p}x{q}");
        assert_eq!(a.comm_bytes, b.comm_bytes, "{n}x{m} on {p}x{q}");
    }
}

#[test]
fn sampled_path_pooled_vs_fresh_is_bit_identical() {
    // random shapes/grids/formats at low fractions: a warm pooled run vs
    // a drop-scratch-every-step run must not change a single bit
    forall(6, 0x91, |rng| {
        let p = 1 + rng.below(3);
        let q = 1 + rng.below(3);
        let n = p * (4 + rng.below(30)) + rng.below(p);
        let m = (p * q) * (2 + rng.below(5)) + rng.below(3);
        let mut b = ExperimentConfig::builder()
            .name("sampled-pooled")
            .dense(n, m)
            .grid(p, q)
            .fractions_bcd(0.3, 0.15, 0.6)
            .inner_steps(5)
            .outer_iters(3)
            .seed(rng.below(1000) as u64);
        if rng.bool_with(0.5) {
            b = b.sparse(n, m, 4);
        }
        let cfg = b.build().unwrap();
        let mut warm = Trainer::new(cfg.clone()).unwrap();
        let a = warm.run().unwrap();
        let mut cold = Trainer::new(cfg).unwrap();
        while !cold.is_done() {
            cold.drop_scratch();
            cold.step().unwrap();
        }
        let o = cold.outcome();
        assert_eq!(a.w, o.w, "{n}x{m} on {p}x{q}");
        assert_eq!(a.history.losses(), o.history.losses(), "{n}x{m} on {p}x{q}");
    });
}

#[test]
fn full_fraction_sodda_still_equals_radisa() {
    // |B| = M must keep taking the frozen full-width path: Corollary 1
    // (SODDA at full fractions ≡ RADiSA) stays bit-for-bit
    let mk = |algo| {
        ExperimentConfig::builder()
            .name("sampled-c1")
            .dense(90, 12)
            .grid(3, 2)
            .algorithm(algo)
            .fractions(SamplingFractions::FULL)
            .inner_steps(4)
            .outer_iters(4)
            .seed(13)
            .build()
            .unwrap()
    };
    let a = Trainer::new(mk(AlgorithmKind::Sodda)).unwrap().run().unwrap();
    let b = Trainer::new(mk(AlgorithmKind::Radisa)).unwrap().run().unwrap();
    assert_eq!(a.w, b.w);
    assert_eq!(a.history.losses(), b.history.losses());
    assert_eq!(a.comm_bytes, b.comm_bytes);
}

/// Cost-model honesty: the bytes SimNet charges for phases 1/2 must be
/// the actual lengths of the (now compact) buffers on the channel. The
/// expected total is re-derived here from scratch: replaying the
/// config's set-draw RNG stream (`seed → fork(0xB0)`, the trainer's
/// `rng_sets`) gives every iteration's `(B^t, C^t, D^t)`, and the wire
/// model is then pure arithmetic over the layout. Any padding the real
/// payloads carried beyond the charged widths (the old masked full-width
/// `w`) would make the two sides disagree — the cluster debug-asserts
/// payload lengths against the same id lists this test counts.
#[test]
fn charged_bytes_equal_actual_buffer_lengths() {
    let (n, m, p, q, l, iters, seed) = (121usize, 25usize, 3usize, 2usize, 6usize, 5usize, 7u64);
    let cfg = low_fraction_cfg(n, m, p, q, seed);
    assert_eq!((cfg.inner_steps, cfg.outer_iters), (l, iters), "formula inputs");
    let out = Trainer::new(cfg.clone()).unwrap().run().unwrap();

    let layout = sodda::data::Layout::new(n, m, p, q).unwrap();
    let mut rng_sets = Rng::seed_from_u64(seed).fork(0xB0);
    let mut expect = 0u64;
    for _ in 0..iters {
        let sets = SampleSets::draw(&mut rng_sets, n, m, &cfg.fractions);
        let rows_per: Vec<u64> = (0..p)
            .map(|pi| {
                let r = layout.block_rows(pi);
                SampleSets::count_in_range(&sets.d, r.start, r.end) as u64
            })
            .collect();
        for qi in 0..q {
            let c = layout.block_cols(qi);
            let bq = SampleSets::count_in_range(&sets.b, c.start, c.end) as u64;
            let cq = SampleSets::count_in_range(&sets.c, c.start, c.end) as u64;
            for &rp in &rows_per {
                expect += 4 * (bq + rp); // phase 1: compact w down, z/u up
                expect += 4 * (rp + cq); // phase 2: u down, compact slice up
            }
        }
        // phase 3 (unchanged by sampling): per task 3 sub-block vectors
        // down + idx down + w_L up; sub-block widths tile each block
        for qi in 0..q {
            for k in 0..p {
                let width = layout.sub_cols(qi, k).len() as u64;
                expect += 4 * (3 * width + l as u64 + width);
            }
        }
    }
    assert_eq!(out.comm_bytes, expect, "SimNet bytes != actual sampled buffer lengths");
}
