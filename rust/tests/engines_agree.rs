//! Integration: the XLA engine (AOT JAX/Pallas artifacts via PJRT) and
//! the native rust engine must produce the *same training trajectory* up
//! to f32 rounding — this is the end-to-end proof that all three layers
//! compose.
//!
//! Requires the `xla` cargo feature (`cargo test --features xla`) and
//! `make artifacts` (the tiny `artifacts/test` bucket). Tests skip with
//! a loud message when the bucket is missing so `cargo test` stays
//! usable before artifacts are built; without the feature this whole
//! file compiles away.
#![cfg(feature = "xla")]

use std::sync::Arc;

use sodda::config::{AlgorithmKind, ExperimentConfig, Schedule};
use sodda::coordinator::{train_with_engine, TrainOutcome};
use sodda::data::synth;
use sodda::engine::{BlockKey, ComputeEngine, NativeEngine, XlaEngine};
use sodda::loss::Loss;
use sodda::runtime::XlaRuntime;

fn test_bucket() -> Option<Arc<XlaRuntime>> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts/test");
    match XlaRuntime::load(&dir) {
        Ok(rt) => Some(Arc::new(rt)),
        Err(e) => {
            eprintln!("SKIP: artifacts/test not available ({e:#}); run `make artifacts`");
            None
        }
    }
}

fn cfg(algo: AlgorithmKind, loss: Loss) -> ExperimentConfig {
    // p=3, q=2 over 300×60 ⇒ blocks 100×30, sub-blocks 100×10: exactly
    // the artifacts/test bucket (n=100, m=30, m̃=10, L=16)
    ExperimentConfig::builder()
        .name("xla-vs-native")
        .dense(300, 60)
        .grid(3, 2)
        .loss(loss)
        .algorithm(algo)
        .inner_steps(16)
        .outer_iters(6)
        .schedule(Schedule::PaperSqrt)
        .seed(11)
        .build()
        .unwrap()
}

fn run(algo: AlgorithmKind, loss: Loss, engine: Arc<dyn ComputeEngine>) -> TrainOutcome {
    let c = cfg(algo, loss);
    let ds = c.data.try_materialize(c.seed).unwrap();
    train_with_engine(&c, &ds, engine).unwrap()
}

#[test]
fn sodda_trajectory_matches_across_engines() {
    let Some(rt) = test_bucket() else { return };
    for loss in [Loss::Hinge, Loss::Logistic, Loss::Squared] {
        let xla = Arc::new(XlaEngine::new(Arc::clone(&rt), 100, 30, 10, 16).unwrap());
        let a = run(AlgorithmKind::Sodda, loss, Arc::new(NativeEngine));
        let b = run(AlgorithmKind::Sodda, loss, xla);
        assert_eq!(a.w.len(), b.w.len());
        for (i, (x, y)) in a.w.iter().zip(&b.w).enumerate() {
            assert!(
                (x - y).abs() <= 1e-3 + 1e-3 * y.abs(),
                "{loss}: w[{i}] diverged: native={x} xla={y}"
            );
        }
        for (la, lb) in a.history.losses().iter().zip(b.history.losses()) {
            assert!((la - lb).abs() <= 1e-3 * (1.0 + lb.abs()), "{loss}: loss curves diverged: {la} vs {lb}");
        }
    }
}

#[test]
fn radisa_trajectory_matches_across_engines() {
    let Some(rt) = test_bucket() else { return };
    let xla = Arc::new(XlaEngine::new(rt, 100, 30, 10, 16).unwrap());
    let a = run(AlgorithmKind::Radisa, Loss::Hinge, Arc::new(NativeEngine));
    let b = run(AlgorithmKind::Radisa, Loss::Hinge, xla);
    for (x, y) in a.w.iter().zip(&b.w) {
        assert!((x - y).abs() <= 1e-3 + 1e-3 * y.abs());
    }
}

#[test]
fn xla_engine_rejects_wrong_shapes() {
    let Some(rt) = test_bucket() else { return };
    assert!(XlaEngine::new(Arc::clone(&rt), 100, 30, 10, 17).is_err(), "wrong L must fail");
    assert!(XlaEngine::new(rt, 128, 30, 10, 16).is_err(), "wrong n must fail");
}

#[test]
fn fused_partial_u_matches_across_engines() {
    // the XLA engine inherits the trait's *default* partial_u/block_loss
    // (partial_z + dloss_u / loss_from_z composition); the native engine
    // overrides them with the fused batched kernels — both must agree,
    // and the native fused path must equal its own composition exactly.
    let Some(rt) = test_bucket() else { return };
    let xla = XlaEngine::new(rt, 100, 30, 10, 16).unwrap();
    let native = NativeEngine;
    let ds = synth::dense_zhang(100, 30, 5);
    let key = BlockKey { p: 0, q: 0 };
    let w: Vec<f32> = (0..30).map(|i| (i as f32 * 0.21).cos() * 0.5).collect();
    let rows: Vec<u32> = (0..100u32).step_by(4).collect();
    for loss in [Loss::Hinge, Loss::Logistic, Loss::Squared] {
        let un = native.partial_u(key, loss, &ds.x, 0..30, &w, &rows, &ds.y);
        let ux = xla.partial_u(key, loss, &ds.x, 0..30, &w, &rows, &ds.y);
        for (a, b) in ux.iter().zip(&un) {
            assert!((a - b).abs() < 1e-3 + 1e-3 * b.abs(), "{loss}: partial_u {a} vs {b}");
        }
        let zn = native.partial_z(key, &ds.x, 0..30, &w, &rows);
        let y_rows: Vec<f32> = rows.iter().map(|&r| ds.y[r as usize]).collect();
        assert_eq!(un, native.dloss_u(loss, &zn, &y_rows), "{loss}: fused != composed");

        let ln = native.block_loss(key, loss, &ds.x, 0..30, &w, &rows, &ds.y);
        let lx = xla.block_loss(key, loss, &ds.x, 0..30, &w, &rows, &ds.y);
        assert!((lx - ln).abs() < 1e-3 * (1.0 + ln.abs()), "{loss}: block_loss {lx} vs {ln}");
    }
}

#[test]
fn xla_primitives_match_native_on_one_block() {
    let Some(rt) = test_bucket() else { return };
    let xla = XlaEngine::new(rt, 100, 30, 10, 16).unwrap();
    let native = NativeEngine;
    let ds = synth::dense_zhang(100, 30, 3);
    let key = BlockKey { p: 0, q: 0 };
    let w: Vec<f32> = (0..30).map(|i| (i as f32 * 0.37).sin() * 0.5).collect();
    let rows: Vec<u32> = (0..100u32).step_by(3).collect();

    let zx = xla.partial_z(key, &ds.x, 0..30, &w, &rows);
    let zn = native.partial_z(key, &ds.x, 0..30, &w, &rows);
    for (a, b) in zx.iter().zip(&zn) {
        assert!((a - b).abs() < 1e-4 + 1e-4 * b.abs(), "partial_z {a} vs {b}");
    }

    let u = native.dloss_u(Loss::Hinge, &zn, &vec![1.0; zn.len()]);
    let gx = xla.grad_slice(key, &ds.x, 0..30, &rows, &u);
    let gn = native.grad_slice(key, &ds.x, 0..30, &rows, &u);
    for (a, b) in gx.iter().zip(&gn) {
        assert!((a - b).abs() < 1e-3 + 1e-3 * b.abs(), "grad_slice {a} vs {b}");
    }

    let idx: Vec<u32> = (0..16).map(|i| (i * 7) % 100).collect();
    let mu = vec![0.01f32; 10];
    let wx = xla.svrg_inner(key, Loss::Hinge, &ds.x, &ds.y, 10..20, &w[10..20], &w[10..20], &mu, &idx, 0.05);
    let wn = native.svrg_inner(key, Loss::Hinge, &ds.x, &ds.y, 10..20, &w[10..20], &w[10..20], &mu, &idx, 0.05);
    for (a, b) in wx.iter().zip(&wn) {
        assert!((a - b).abs() < 1e-3 + 1e-3 * b.abs(), "svrg {a} vs {b}");
    }

    let lx = xla.loss_from_z(Loss::Hinge, &zn, &vec![1.0; zn.len()]);
    let ln = native.loss_from_z(Loss::Hinge, &zn, &vec![1.0; zn.len()]);
    assert!((lx - ln).abs() < 1e-3 * (1.0 + ln.abs()), "loss {lx} vs {ln}");
}
