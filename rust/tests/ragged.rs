//! Ragged partition grids end-to-end: arbitrary `N × M` shapes train on
//! any `P × Q` grid, evenly divisible shapes behave exactly as the
//! legacy uniform layout did (bit-for-bit trajectories, identical cost
//! accounting), and the strict-mode knob only validates — it never
//! changes numbers.

use std::sync::Arc;

use sodda::config::{AlgorithmKind, ExperimentConfig, SamplingFractions, Schedule};
use sodda::coordinator::{train, train_with_engine};
use sodda::engine::NativeEngine;
use sodda::metrics::History;
use sodda::util::testing::forall;

/// Compare everything a History records except wall-clock time (the only
/// nondeterministic field).
fn assert_history_identical(a: &History, b: &History, ctx: &str) {
    assert_eq!(a.records.len(), b.records.len(), "{ctx}: record counts");
    for (ra, rb) in a.records.iter().zip(&b.records) {
        assert_eq!(ra.iter, rb.iter, "{ctx}");
        assert_eq!(ra.loss, rb.loss, "{ctx}: loss at iter {}", ra.iter);
        assert_eq!(ra.sim_s, rb.sim_s, "{ctx}: sim_s at iter {}", ra.iter);
        assert_eq!(ra.comm_bytes, rb.comm_bytes, "{ctx}: comm_bytes at iter {}", ra.iter);
        assert_eq!(
            ra.grad_coord_evals, rb.grad_coord_evals,
            "{ctx}: grad_coord_evals at iter {}",
            ra.iter
        );
    }
}

// ---------------------------------------------------------------------------
// the acceptance shape: prime N and M
// ---------------------------------------------------------------------------

#[test]
fn prime_shape_trains_to_finite_decreasing_loss() {
    // 601 and 61 are prime — nothing about this shape divides into the
    // grid; the exact acceptance criterion of the ragged-grid issue
    let cfg = ExperimentConfig::builder()
        .name("ragged-prime")
        .dense(601, 61)
        .grid(3, 2)
        .build()
        .unwrap();
    let out = train(&cfg).unwrap();
    assert!(out.w.iter().all(|v| v.is_finite()));
    let losses = out.history.losses();
    assert!(losses.iter().all(|l| l.is_finite()));
    assert!(
        out.history.final_loss().unwrap() < losses[0]
            && out.history.min_loss().unwrap() < 0.85 * losses[0],
        "loss must decrease: {losses:?}"
    );
}

#[test]
fn all_algorithms_run_on_ragged_grids() {
    for algo in [AlgorithmKind::Sodda, AlgorithmKind::Radisa, AlgorithmKind::RadisaAvg] {
        let cfg = ExperimentConfig::builder()
            .name(format!("ragged-{algo}"))
            .dense(211, 23)
            .grid(3, 2)
            .inner_steps(8)
            .outer_iters(10)
            .seed(11)
            .build()
            .unwrap();
        let out = train(&cfg).unwrap();
        assert!(out.w.iter().all(|v| v.is_finite()), "{algo}");
        assert!(
            out.history.min_loss().unwrap() < out.history.losses()[0],
            "{algo} must make progress on a ragged grid"
        );
    }
}

#[test]
fn ragged_sparse_dataset_trains() {
    let cfg = ExperimentConfig::builder()
        .name("ragged-sparse")
        .sparse(607, 53, 8)
        .grid(3, 2)
        .inner_steps(8)
        .outer_iters(10)
        .seed(3)
        .build()
        .unwrap();
    let out = train(&cfg).unwrap();
    assert!(out.w.iter().all(|v| v.is_finite()));
    assert!(out.history.min_loss().unwrap() < out.history.losses()[0]);
}

// ---------------------------------------------------------------------------
// ragged indexing correctness: distributed == serial
// ---------------------------------------------------------------------------

#[test]
fn ragged_distributed_objective_matches_serial() {
    for (n, m, p, q) in [(601usize, 61usize, 3usize, 2usize), (97, 13, 4, 2), (123, 31, 5, 3)] {
        let cfg = ExperimentConfig::builder()
            .name("ragged-serial")
            .dense(n, m)
            .grid(p, q)
            .inner_steps(6)
            .outer_iters(4)
            .seed(17)
            .build()
            .unwrap();
        let ds = cfg.data.try_materialize(cfg.seed).unwrap();
        let out = train_with_engine(&cfg, &ds, Arc::new(NativeEngine)).unwrap();
        let serial = ds.objective(&out.w, cfg.loss);
        let reported = out.history.final_loss().unwrap();
        assert!(
            (serial - reported).abs() <= 1e-4 * (1.0 + serial.abs()),
            "{n}x{m} on {p}x{q}: serial {serial} vs distributed {reported}"
        );
    }
}

// ---------------------------------------------------------------------------
// evenly divisible shapes: ragged layout == legacy uniform layout
// ---------------------------------------------------------------------------

#[test]
fn even_shapes_identical_under_strict_and_ragged_validation() {
    // the strict knob is validation-only: same seed, same trajectory,
    // same cost accounting, bit for bit
    forall(6, 505, |rng| {
        let p = 1 + rng.below(3);
        let q = 1 + rng.below(2);
        let n = (1 + rng.below(4)) * p * 40;
        let m = (1 + rng.below(3)) * p * q * 4;
        let seed = rng.next_u64();
        let base = ExperimentConfig::builder()
            .name("even")
            .dense(n, m)
            .grid(p, q)
            .inner_steps(4)
            .outer_iters(3)
            .seed(seed);
        let ragged = base.clone().build().unwrap();
        let strict = base.require_even_grid().build().unwrap();
        assert!(strict.strict_even_grid && !ragged.strict_even_grid);
        let a = train(&ragged).unwrap();
        let b = train(&strict).unwrap();
        assert_eq!(a.w, b.w, "{n}x{m} on {p}x{q}");
        assert_history_identical(&a.history, &b.history, "strict vs ragged");
    });
}

#[test]
fn even_shape_cost_accounting_matches_uniform_closed_form() {
    // RADiSA uses the full (B, C, D) sets, so the per-iteration traffic
    // and gradient-coordinate counts of the legacy uniform accounting
    // have closed forms. The ragged bookkeeping must reproduce them
    // exactly on evenly divisible shapes.
    let (n, m, p, q, l, t) = (120usize, 24usize, 3usize, 2usize, 5usize, 4usize);
    let cfg = ExperimentConfig::builder()
        .name("uniform-cost")
        .dense(n, m)
        .grid(p, q)
        .algorithm(AlgorithmKind::Radisa)
        .inner_steps(l)
        .outer_iters(t)
        .seed(2)
        .build()
        .unwrap();
    let out = train(&cfg).unwrap();
    let (n_per, m_per) = (n / p, m / q);
    let mtilde = m_per / p;
    let phase_bytes = (m_per + n_per) as u64 + (n_per + m_per) as u64 + (4 * mtilde + l) as u64;
    let per_iter_bytes = (p * q) as u64 * 4 * phase_bytes;
    let per_iter_evals = (m * n) as u64 + (p * q * l * mtilde) as u64;
    let last = out.history.records.last().unwrap();
    assert_eq!(last.comm_bytes, t as u64 * per_iter_bytes, "legacy uniform byte accounting");
    assert_eq!(last.grad_coord_evals, t as u64 * per_iter_evals, "legacy uniform eval counts");
}

// ---------------------------------------------------------------------------
// ragged-specific invariants
// ---------------------------------------------------------------------------

#[test]
fn ragged_full_fraction_sodda_equals_radisa() {
    // Corollary 1 must survive ragged layouts: SODDA at (b,c,d) = full is
    // RADiSA, including the per-partition row splits
    let mk = |algo| {
        ExperimentConfig::builder()
            .name("ragged-c1")
            .dense(203, 26)
            .grid(3, 2)
            .algorithm(algo)
            .fractions(SamplingFractions::FULL)
            .inner_steps(6)
            .outer_iters(5)
            .schedule(Schedule::ScaledSqrt { gamma0: 0.05 })
            .seed(23)
            .build()
            .unwrap()
    };
    let a = train(&mk(AlgorithmKind::Sodda)).unwrap();
    let b = train(&mk(AlgorithmKind::Radisa)).unwrap();
    assert_eq!(a.w, b.w);
    assert_history_identical(&a.history, &b.history, "sodda vs radisa ragged");
}

#[test]
fn ragged_runs_reproduce_per_seed() {
    let cfg = ExperimentConfig::builder()
        .name("ragged-repro")
        .dense(601, 61)
        .grid(3, 2)
        .inner_steps(8)
        .outer_iters(6)
        .seed(31)
        .build()
        .unwrap();
    let a = train(&cfg).unwrap();
    let b = train(&cfg).unwrap();
    assert_eq!(a.w, b.w);
    assert_history_identical(&a.history, &b.history, "same-seed ragged runs");
}
