//! Property-based end-to-end invariants (in-tree `forall` driver; see
//! `util::testing` — proptest is unavailable offline).

use std::sync::Arc;

use sodda::config::{AlgorithmKind, DataConfig, EngineKind, ExperimentConfig, SamplingFractions, Schedule};
use sodda::coordinator::{train, train_with_engine};
use sodda::data::{synth, Grid};
use sodda::engine::NativeEngine;
use sodda::loss::Loss;
use sodda::util::testing::forall;

fn cfg_for(rng: &mut sodda::util::rng::Rng) -> ExperimentConfig {
    let p = 1 + rng.below(4);
    let q = 1 + rng.below(3);
    let n = (1 + rng.below(6)) * p * 50;
    let m = (1 + rng.below(4)) * p * q * 4;
    ExperimentConfig {
        name: "prop".into(),
        data: DataConfig::Dense { n, m },
        p,
        q,
        loss: [Loss::Hinge, Loss::Logistic, Loss::Squared][rng.below(3)],
        algorithm: AlgorithmKind::Sodda,
        fractions: SamplingFractions {
            b: 0.4 + rng.unit_f64() * 0.6,
            c: 0.3,
            d: 0.4 + rng.unit_f64() * 0.6,
        },
        inner_steps: 1 + rng.below(16),
        outer_iters: 2,
        schedule: Schedule::ScaledSqrt { gamma0: 0.05 },
        seed: rng.next_u64(),
        engine: EngineKind::Native,
        network: None,
        eval_every: 1,
    }
}

#[test]
fn training_never_produces_nonfinite_weights() {
    forall(12, 101, |rng| {
        let cfg = cfg_for(rng);
        let out = train(&cfg).unwrap();
        assert!(out.w.iter().all(|v| v.is_finite()), "{cfg:?}");
        assert!(out.history.losses().iter().all(|l| l.is_finite()));
    });
}

#[test]
fn sodda_with_full_fractions_equals_radisa_exactly() {
    // Corollary 1: RADiSA is SODDA at (b, c, d) = (M, M, N). The two code
    // paths must coincide bit-for-bit given the same seed.
    forall(8, 202, |rng| {
        let mut cfg = cfg_for(rng);
        cfg.fractions = SamplingFractions::FULL;
        cfg.algorithm = AlgorithmKind::Sodda;
        let a = train(&cfg).unwrap();
        cfg.algorithm = AlgorithmKind::Radisa;
        let b = train(&cfg).unwrap();
        assert_eq!(a.w, b.w, "full-fraction SODDA must equal RADiSA");
        assert_eq!(a.history.losses(), b.history.losses());
    });
}

#[test]
fn cluster_objective_matches_serial_objective() {
    forall(10, 303, |rng| {
        let cfg = cfg_for(rng);
        let ds = cfg.data.materialize(cfg.seed);
        let out = train_with_engine(&cfg, &ds, Arc::new(NativeEngine)).unwrap();
        let serial = ds.objective(&out.w, cfg.loss);
        let reported = out.history.final_loss().unwrap();
        assert!(
            (serial - reported).abs() <= 1e-4 * (1.0 + serial.abs()),
            "serial {serial} vs distributed {reported}"
        );
    });
}

#[test]
fn partition_blocks_cover_matrix_disjointly() {
    forall(15, 404, |rng| {
        let p = 1 + rng.below(4);
        let q = 1 + rng.below(4);
        let n = p * (1 + rng.below(20));
        let m = p * q * (1 + rng.below(6));
        let ds = synth::dense_zhang(n, m, rng.next_u64());
        let g = Grid::partition(&ds, p, q).unwrap();
        // total entries across blocks == N×M and every sub-block col range
        // is within its block
        let total: usize = g.blocks().map(|b| b.x.rows() * b.x.cols()).sum();
        assert_eq!(total, n * m);
        for k in 0..p {
            let r = g.sub_cols(k);
            assert!(r.end <= g.m_per);
            assert_eq!(r.len(), g.mtilde);
        }
        // global_cols tile [0, M) disjointly
        let mut seen = vec![false; m];
        for qi in 0..q {
            for k in 0..p {
                for c in g.global_cols(qi, k) {
                    assert!(!seen[c]);
                    seen[c] = true;
                }
            }
        }
        assert!(seen.iter().all(|&s| s));
    });
}

#[test]
fn grad_coord_evals_scale_with_fractions() {
    // the paper's §1 claim: fewer gradient coordinate computations in
    // early iterations is exactly what (b, c, d) < 1 buys
    let mk = |c: f64, d: f64| ExperimentConfig {
        name: "gc".into(),
        data: DataConfig::Dense { n: 400, m: 60 },
        p: 2,
        q: 2,
        loss: Loss::Hinge,
        algorithm: AlgorithmKind::Sodda,
        fractions: SamplingFractions { b: 1.0, c, d },
        inner_steps: 8,
        outer_iters: 3,
        schedule: Schedule::ScaledSqrt { gamma0: 0.05 },
        seed: 1,
        engine: EngineKind::Native,
        network: None,
        eval_every: 1,
    };
    let lo = train(&mk(0.4, 0.5)).unwrap();
    let hi = train(&mk(1.0, 1.0)).unwrap();
    let lo_evals = lo.history.records.last().unwrap().grad_coord_evals;
    let hi_evals = hi.history.records.last().unwrap().grad_coord_evals;
    assert!(
        lo_evals < hi_evals,
        "sampling must reduce coordinate evaluations: {lo_evals} vs {hi_evals}"
    );
}

#[test]
fn eval_every_thins_history_but_not_training() {
    let mut cfg = ExperimentConfig {
        name: "ee".into(),
        data: DataConfig::Dense { n: 200, m: 24 },
        p: 2,
        q: 2,
        loss: Loss::Hinge,
        algorithm: AlgorithmKind::Sodda,
        fractions: SamplingFractions::PAPER,
        inner_steps: 4,
        outer_iters: 9,
        schedule: Schedule::PaperSqrt,
        seed: 3,
        engine: EngineKind::Native,
        network: None,
        eval_every: 1,
    };
    let dense_hist = train(&cfg).unwrap();
    cfg.eval_every = 4;
    let thin_hist = train(&cfg).unwrap();
    assert_eq!(dense_hist.w, thin_hist.w, "eval cadence must not affect training");
    assert!(thin_hist.history.records.len() < dense_hist.history.records.len());
    // final iteration always recorded
    assert_eq!(thin_hist.history.records.last().unwrap().iter, 9);
}
