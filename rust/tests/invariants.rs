//! Property-based end-to-end invariants (in-tree `forall` driver; see
//! `util::testing` — proptest is unavailable offline).

use std::sync::Arc;

use sodda::config::{AlgorithmKind, ExperimentConfig, SamplingFractions, Schedule};
use sodda::coordinator::{train, train_with_engine};
use sodda::data::{synth, Grid};
use sodda::engine::NativeEngine;
use sodda::loss::Loss;
use sodda::util::testing::forall;

fn cfg_for(rng: &mut sodda::util::rng::Rng) -> ExperimentConfig {
    let p = 1 + rng.below(4);
    let q = 1 + rng.below(3);
    // evenly divisible and ragged shapes alike — the partitioner must
    // handle whatever N × M lands on the grid
    let n = (1 + rng.below(6)) * p * 50 + rng.below(7);
    let m = (1 + rng.below(4)) * p * q * 4 + rng.below(5);
    ExperimentConfig::builder()
        .name("prop")
        .dense(n, m)
        .grid(p, q)
        .loss([Loss::Hinge, Loss::Logistic, Loss::Squared][rng.below(3)])
        .fractions(SamplingFractions {
            b: 0.4 + rng.unit_f64() * 0.6,
            c: 0.3,
            d: 0.4 + rng.unit_f64() * 0.6,
        })
        .inner_steps(1 + rng.below(16))
        .outer_iters(2)
        .schedule(Schedule::ScaledSqrt { gamma0: 0.05 })
        .seed(rng.next_u64())
        .build()
        .expect("random config within builder invariants")
}

#[test]
fn training_never_produces_nonfinite_weights() {
    forall(12, 101, |rng| {
        let cfg = cfg_for(rng);
        let out = train(&cfg).unwrap();
        assert!(out.w.iter().all(|v| v.is_finite()), "{cfg:?}");
        assert!(out.history.losses().iter().all(|l| l.is_finite()));
    });
}

#[test]
fn sodda_with_full_fractions_equals_radisa_exactly() {
    // Corollary 1: RADiSA is SODDA at (b, c, d) = (M, M, N). The two code
    // paths must coincide bit-for-bit given the same seed.
    forall(8, 202, |rng| {
        let base = cfg_for(rng)
            .to_builder()
            .fractions(SamplingFractions::FULL)
            .algorithm(AlgorithmKind::Sodda)
            .build()
            .unwrap();
        let a = train(&base).unwrap();
        let radisa = base.to_builder().algorithm(AlgorithmKind::Radisa).build().unwrap();
        let b = train(&radisa).unwrap();
        assert_eq!(a.w, b.w, "full-fraction SODDA must equal RADiSA");
        assert_eq!(a.history.losses(), b.history.losses());
    });
}

#[test]
fn cluster_objective_matches_serial_objective() {
    forall(10, 303, |rng| {
        let cfg = cfg_for(rng);
        let ds = cfg.data.try_materialize(cfg.seed).unwrap();
        let out = train_with_engine(&cfg, &ds, Arc::new(NativeEngine)).unwrap();
        let serial = ds.objective(&out.w, cfg.loss);
        let reported = out.history.final_loss().unwrap();
        assert!(
            (serial - reported).abs() <= 1e-4 * (1.0 + serial.abs()),
            "serial {serial} vs distributed {reported}"
        );
    });
}

#[test]
fn partition_blocks_cover_matrix_disjointly() {
    forall(15, 404, |rng| {
        let p = 1 + rng.below(4);
        let q = 1 + rng.below(4);
        // arbitrary shapes with non-empty partitions (ragged included)
        let n = p + rng.below(80);
        let m = p * q + rng.below(24);
        let ds = synth::dense_zhang(n, m, rng.next_u64());
        let g = Grid::partition(&ds, p, q).unwrap();
        // total entries across blocks == N×M and every sub-block col range
        // is within its block, balanced to within one column
        let total: usize = g.blocks().map(|b| b.x.rows() * b.x.cols()).sum();
        assert_eq!(total, n * m);
        for qi in 0..q {
            let mq = g.layout.cols_in(qi);
            for k in 0..p {
                let r = g.layout.sub_cols(qi, k);
                assert!(r.end <= mq);
                assert!(r.len() == mq / p || r.len() == mq / p + 1, "balanced widths");
            }
        }
        // global_cols tile [0, M) disjointly
        let mut seen = vec![false; m];
        for qi in 0..q {
            for k in 0..p {
                for c in g.layout.global_cols(qi, k) {
                    assert!(!seen[c]);
                    seen[c] = true;
                }
            }
        }
        assert!(seen.iter().all(|&s| s));
    });
}

#[test]
fn grad_coord_evals_scale_with_fractions() {
    // the paper's §1 claim: fewer gradient coordinate computations in
    // early iterations is exactly what (b, c, d) < 1 buys
    let mk = |c: f64, d: f64| {
        ExperimentConfig::builder()
            .name("gc")
            .dense(400, 60)
            .grid(2, 2)
            .fractions_bcd(1.0, c, d)
            .inner_steps(8)
            .outer_iters(3)
            .schedule(Schedule::ScaledSqrt { gamma0: 0.05 })
            .build()
            .unwrap()
    };
    let lo = train(&mk(0.4, 0.5)).unwrap();
    let hi = train(&mk(1.0, 1.0)).unwrap();
    let lo_evals = lo.history.records.last().unwrap().grad_coord_evals;
    let hi_evals = hi.history.records.last().unwrap().grad_coord_evals;
    assert!(
        lo_evals < hi_evals,
        "sampling must reduce coordinate evaluations: {lo_evals} vs {hi_evals}"
    );
}

#[test]
fn eval_every_thins_history_but_not_training() {
    let cfg = ExperimentConfig::builder()
        .name("ee")
        .dense(200, 24)
        .grid(2, 2)
        .inner_steps(4)
        .outer_iters(9)
        .schedule(Schedule::PaperSqrt)
        .seed(3)
        .build()
        .unwrap();
    let dense_hist = train(&cfg).unwrap();
    let thin_cfg = cfg.to_builder().eval_every(4).build().unwrap();
    let thin_hist = train(&thin_cfg).unwrap();
    assert_eq!(dense_hist.w, thin_hist.w, "eval cadence must not affect training");
    assert!(thin_hist.history.records.len() < dense_hist.history.records.len());
    // final iteration always recorded
    assert_eq!(thin_hist.history.records.last().unwrap().iter, 9);
}
